# AI-Tax reproduction — build orchestration.
#
# `make artifacts` runs the Layer-2/Layer-1 Python AOT export that the Rust
# runtime loads at startup (see rust/src/runtime/). The Rust side is pure
# cargo; `make build` / `make test` mirror the tier-1 verify commands.

ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts build test bench bench-kernel bench-scale doc fmt clippy clean

# AOT-lower the JAX face-pipeline models to HLO text + manifest. Python
# (jax + the Pallas kernels) is required only for this step; everything
# else is Rust-only.
artifacts:
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS_DIR)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench

# The perf-trajectory benchmark: DES events/sec + parallel-sweep scaling,
# written to rust/BENCH_kernel.json (see README "Performance").
bench-kernel:
	cd rust && cargo run --release -- bench kernel

# Flow-aggregation perf trend: per-record vs flow wall clock at 10^4
# clients + the 10^6-client flow point, written to rust/BENCH_scale.json.
bench-scale:
	cd rust && cargo run --release -- bench scale

# Rustdoc with warnings denied (what CI enforces) + the doctests.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps && cargo test --doc -q

fmt:
	cd rust && cargo fmt --all --check

# Warnings denied, matching CI (same provisional allow-list; see
# .github/workflows/ci.yml for why each entry exists).
clippy:
	cd rust && cargo clippy --all-targets -- -D warnings \
	  -A clippy::field-reassign-with-default \
	  -A clippy::redundant-closure \
	  -A clippy::new-without-default \
	  -A clippy::unnecessary-map-or

clean:
	cd rust && cargo clean
	rm -rf $(ARTIFACTS_DIR)
