//! The acceleration story end to end: Amdahl limits (Fig 9), the
//! emulation sweep with its 8x instability (Fig 10), the bandwidth
//! culprit (Fig 11), and the three mitigations (Fig 15).
//!
//!     cargo run --release --example accel_sweep [-- --quick] [--skip-fig15]

use aitax::experiments::common::Fidelity;
use aitax::experiments::{fig09, fig10, fig11, fig15};
use aitax::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };
    println!("== What AI acceleration does to the AI tax ==");

    fig09::print(&fig09::run());
    fig10::print(&fig10::run(fidelity));
    fig11::print(&fig11::run(fidelity));
    if !args.flag("skip-fig15") {
        fig15::print(&fig15::run(fidelity));
    }
}
