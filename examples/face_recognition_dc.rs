//! Full data-center Face Recognition study: the paper's §4.2 deployment
//! (840 producers / 1680 consumers / 3 brokers) in virtual time, plus the
//! Fig-7 faces-vs-latency timeseries.
//!
//!     cargo run --release --example face_recognition_dc [-- --secs 30]

use aitax::experiments::common::Fidelity;
use aitax::experiments::{fig06, fig07};
use aitax::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };
    println!("== Face Recognition at data-center scale (virtual time) ==");
    println!("deployment: 840 ingest/detect + 1680 identification + 3 brokers\n");

    let report = fig06::run(fidelity);
    fig06::print(&report);

    let f7 = fig07::run(fidelity);
    fig07::print(&f7);

    println!("\nfaces in flight peaked at {}", report.population.iter().map(|p| p.1).max().unwrap_or(0));
}
