//! Mixed tenancy: Face Recognition and Object Detection sharing one
//! broker fabric — the scenario the `sim::world` component kernel exists
//! to enable. Sweeps the objdet fleet share and shows the cross-tenant
//! AI tax: facerec's broker wait grows although facerec itself never
//! changes.
//!
//!     cargo run --release --example mixed_tenancy [-- --quick]
//!     cargo run --release --example mixed_tenancy -- --fr-accel 4 --od-accel 8 --od-share 1.0

use aitax::experiments::common::Fidelity;
use aitax::experiments::mixed as exmixed;
use aitax::pipeline::mixed::MixedSim;
use aitax::util::cli::Args;
use aitax::util::units::fmt_us;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };
    println!("== Mixed tenancy: two AI pipelines, one broker substrate ==");

    if args.get("fr-accel").is_some() || args.get("od-accel").is_some() || args.get("od-share").is_some()
    {
        // Single custom point instead of the sweep.
        let share = args.get_f64("od-share", 1.0);
        let mut cfg = exmixed::mix_config(share, fidelity);
        cfg.facerec.accel = args.get_f64("fr-accel", exmixed::ACCEL_FACEREC);
        cfg.objdet.accel = args.get_f64("od-accel", exmixed::ACCEL_OBJDET);
        let r = MixedSim::new(cfg).run();
        println!(
            "facerec: wait {} | e2e p99 {} | {} faces | {}",
            fmt_us(r.facerec.wait_mean_us as u64),
            fmt_us(r.facerec.e2e_p99_us),
            r.facerec.faces_completed,
            if r.facerec.verdict.stable { "stable" } else { "UNSTABLE" },
        );
        println!(
            "objdet:  wait {} | e2e p99 {} | {} frames | {}",
            fmt_us(r.objdet.wait_mean_us as u64),
            fmt_us(r.objdet.e2e_p99_us),
            r.objdet.frames_detected,
            if r.objdet.verdict.stable { "stable" } else { "UNSTABLE" },
        );
        println!(
            "shared brokers: nvme write {:.1}% | nic rx {:.2}% | req cpu {:.2}% | {} events",
            100.0 * r.broker_storage_write_util,
            100.0 * r.broker_net_rx_util,
            100.0 * r.broker_cpu_util,
            r.events,
        );
        return;
    }

    exmixed::print(&exmixed::run(fidelity));
}
