//! The second application (§6): Object Detection through the same broker
//! substrate — baseline breakdown (Fig 13) and the acceleration sweep with
//! its "Delay" AI-tax component (Fig 14).
//!
//!     cargo run --release --example object_detection [-- --quick]

use aitax::experiments::common::Fidelity;
use aitax::experiments::{fig13, fig14};
use aitax::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };
    println!("== Object Detection (R-CNN) through the Kafka-like substrate ==");
    println!("deployment: 21 producers x 30 FPS -> 3 brokers -> 2016 detectors\n");

    let baseline = fig13::run(fidelity);
    fig13::print(&baseline);

    let sweep = fig14::run(fidelity);
    fig14::print(&sweep);
}
