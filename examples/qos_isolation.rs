//! Broker QoS: per-tenant scheduling classes + topic quotas turning a
//! multi-tenant SLO violation into isolation — the Sec.-8 mitigation
//! view for colocation. Four tenants (facerec 4x, objdet 6x, training
//! ingest, rpc) share one 3-broker fabric; the sweep grows the bulk
//! tenants' share and reports the rpc tenant's p99 against its SLO with
//! QoS off and on.
//!
//!     cargo run --release --example qos_isolation [-- --quick]
//!     cargo run --release --example qos_isolation -- --share 1.0

use aitax::experiments::common::Fidelity;
use aitax::experiments::qos as exqos;
use aitax::pipeline::mixed::MultiTenantSim;
use aitax::util::cli::Args;
use aitax::util::units::fmt_us;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };
    println!("== Broker QoS: N tenants, one substrate, one SLO ==");

    if args.get("share").is_some() {
        // One colocation point, off vs on, with per-tenant detail.
        let share = args.get_f64("share", 1.0);
        let slo = aitax::config::Config::default().calibration.rpc.slo_p99_us;
        for qos_on in [false, true] {
            let r = MultiTenantSim::new(exqos::registry(share, qos_on, fidelity)).run();
            println!(
                "\nshare {:.0}%, qos {}: nvme write {:.1}% | req cpu {:.2}% | {} events",
                100.0 * share,
                if qos_on { "on" } else { "off" },
                100.0 * r.broker_storage_write_util,
                100.0 * r.broker_cpu_util,
                r.events,
            );
            for t in &r.tenants {
                let slo_note = if t.name == "rpc" {
                    if t.e2e_p99_us <= slo { "  [slo met]" } else { "  [SLO MISSED]" }
                } else {
                    ""
                };
                println!(
                    "  {:<13} wait {:>10} | e2e p99 {:>10} | {:>9} done | {}{}",
                    t.name,
                    fmt_us(t.wait_mean_us as u64),
                    fmt_us(t.e2e_p99_us),
                    t.completed,
                    if t.stable { "stable" } else { "UNSTABLE" },
                    slo_note,
                );
            }
        }
        return;
    }

    exqos::print(&exqos::run(fidelity));
}
