//! Quickstart: the end-to-end three-layer stack on a real (small) workload.
//!
//! Runs the live Face Recognition pipeline for ~10 seconds: producer
//! threads synthesize video frames and run *real PJRT inference*
//! (preprocess → detect, compiled from the Pallas/JAX artifacts), publish
//! face thumbnails through the real Kafka-like broker substrate (linger
//! batching, 3× replication), and consumer threads fetch and identify the
//! faces. Prints the paper's Fig-6-style latency breakdown measured live.
//!
//!     make artifacts && cargo run --release --example quickstart

use aitax::coordinator::live::{LiveConfig, LiveRunner};
use aitax::util::units::fmt_bytes;

fn main() -> anyhow::Result<()> {
    println!("== AI-Tax quickstart: live three-layer Face Recognition ==\n");
    let cfg = LiveConfig {
        producers: 2,
        consumers: 4,
        brokers: 3,
        replication: 3,
        partitions: 8,
        duration: std::time::Duration::from_secs(10),
        ..LiveConfig::default()
    };
    println!(
        "{} ingest/detect containers -> {} brokers (3x replication) -> {} identification containers",
        cfg.producers, cfg.brokers, cfg.consumers
    );
    println!("loading + compiling AOT artifacts (per worker thread)...\n");
    let report = LiveRunner::new(cfg).run()?;

    print!(
        "{}",
        report
            .breakdown
            .render("live latency breakdown (cf. paper Fig 6)")
    );
    println!(
        "\nframes: {}   faces: {} produced -> {} identified",
        report.frames, report.faces_produced, report.faces_identified
    );
    println!(
        "throughput: {:.1} FPS   broker logs: {} (3x write amplification)",
        report.throughput_fps,
        fmt_bytes(report.broker_log_bytes as f64)
    );
    let wait_share = report
        .breakdown
        .fraction(aitax::metrics::event::EventKind::BrokerWait);
    println!(
        "broker-wait share of end-to-end latency: {:.1}%  <- the AI tax",
        100.0 * wait_share
    );
    Ok(())
}
