//! TCO planner (§7): price out the homogeneous vs purpose-built edge data
//! centers, then explore what-ifs over the price book.
//!
//!     cargo run --release --example tco_planner [-- --nvme-price 299]

use aitax::experiments::table34;
use aitax::tco::catalog::Catalog;
use aitax::tco::designs::{homogeneous_1024_upgraded, purpose_built, summarize};
use aitax::tco::power::PowerModel;
use aitax::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    table34::print(&table34::run());

    // What-if: sweep a couple of price-book knobs.
    println!("\n== what-ifs ==");
    let power = PowerModel::default();
    for (label, mutate) in [
        (
            "NVMe price drops to $299",
            Box::new(|c: &mut Catalog| c.nvme = 299.0) as Box<dyn Fn(&mut Catalog)>,
        ),
        (
            "100G switches drop 30%",
            Box::new(|c: &mut Catalog| c.switch_100g *= 0.7),
        ),
        (
            "broker servers cost like compute servers",
            Box::new(|c: &mut Catalog| c.broker_server = c.compute_server),
        ),
    ] {
        let mut catalog = Catalog::default();
        mutate(&mut catalog);
        if let Some(v) = args.get("nvme-price").and_then(|s| s.parse::<f64>().ok()) {
            catalog.nvme = v;
        }
        let homo = summarize(&homogeneous_1024_upgraded(&catalog), &power);
        let pb = summarize(&purpose_built(&catalog), &power);
        let savings = 1.0 - pb.yearly_total / homo.yearly_total;
        println!(
            "  {:<44} purpose-built saves {:>5.1}%  (${:.2}M vs ${:.2}M yearly)",
            label,
            100.0 * savings,
            pb.yearly_total / 1e6,
            homo.yearly_total / 1e6
        );
    }
}
