"""AOT export: lower the Layer-2 JAX graphs to HLO text for the Rust
runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. Lowered with
``return_tuple=True``; the Rust side unwraps with ``to_tuple*``.

Usage (from ``python/``)::

    python -m compile.aot --out ../artifacts          # write artifacts
    python -m compile.aot --report                    # HLO op-count report

Python runs only at build time; the Rust binary is self-contained once
``artifacts/`` exists (``make artifacts`` is incremental).
"""

import argparse
import collections
import json
import os
import re

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is essential: the default printer elides
    # big weight constants as `constant({...})`, which the xla_extension
    # 0.5.1 text parser silently reads back as ZEROS.
    return comp.as_hlo_text(print_large_constants=True)


def lower_entry(name):
    fn, shapes = model.ENTRY_POINTS[name]
    args = [jax.ShapeDtypeStruct(s, "float32") for s in shapes]
    lowered = jax.jit(fn).lower(*args)
    outs = [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in jax.tree_util.tree_leaves(lowered.out_info)
    ]
    return to_hlo_text(lowered), outs


def op_histogram(hlo_text):
    """Count HLO opcodes (the L2 profile: fusion/redundancy sanity)."""
    ops = collections.Counter()
    for m in re.finditer(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S+\s+(\w+)\(",
                         hlo_text, re.M):
        ops[m.group(1)] += 1
    return ops


def export_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"frame_side": model.FRAME_SIDE, "detect_side": model.DETECT_SIDE,
                "thumb_side": model.THUMB_SIDE, "embed_dim": model.EMBED_DIM,
                "gallery": model.GALLERY, "batch": model.BATCH, "entries": {}}
    for name in model.ENTRY_POINTS:
        hlo, outs = lower_entry(name)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        _, shapes = model.ENTRY_POINTS[name]
        manifest["entries"][name] = {
            "file": fname,
            "inputs": [{"shape": list(s), "dtype": "float32"} for s in shapes],
            "outputs": outs,
        }
        print(f"  {name:<16} {len(hlo):>9} chars  -> {fname}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest['entries'])} entries to {out_dir}")


def report():
    for name in model.ENTRY_POINTS:
        hlo, _ = lower_entry(name)
        ops = op_histogram(hlo)
        total = sum(ops.values())
        top = ", ".join(f"{op}:{n}" for op, n in ops.most_common(6))
        print(f"{name:<16} {total:>5} ops   {top}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--report", action="store_true", help="print HLO op stats")
    args = ap.parse_args()
    if args.report:
        report()
    else:
        export_all(args.out)


if __name__ == "__main__":
    main()
