"""Layer-1 Pallas kernels for the Face Recognition pipeline.

Three kernels cover the pipeline's compute:

* ``matmul``   — blocked matrix multiply (dense layers, SVM scores,
  im2col-style contractions). Tiled for the MXU's 128x128 systolic feeds.
* ``conv2d``   — direct 2D convolution, expressed as per-tap (rows*W, Cin)
  x (Cin, Cout) matmuls so every tap feeds the MXU.
* ``downsample`` — box down-sampling (the paper's 1920x1080 -> 960x540
  frame resize is an exact factor-2 box filter); the paper shows resizing
  alone is 17.8% of end-to-end cycles, which is why pre-processing gets a
  first-class kernel here.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom calls); their *structure* — BlockSpecs, VMEM tile footprints — is
what carries to real TPU. ``ref.py`` holds pure-jnp oracles.
"""

from . import conv2d, downsample, matmul, ref  # noqa: F401
