"""Direct 2D convolution Pallas kernel (VALID padding, stride 1).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CPU
TensorFlow convs become MXU-shaped tile matmuls. Each program owns a block
of output *rows*; for every filter tap (dy, dx) it multiplies the shifted
input band, flattened to (BH*W_out, Cin), against that tap's (Cin, Cout)
weight slice — so all arithmetic is MXU matmuls.

Note on staging: output-row bands need a kh-1 halo, and overlapping input
blocks are not expressible with standard `Blocked` BlockSpecs, so the
input is staged whole and each program slices its band with
``lax.dynamic_slice`` (the interpret-mode equivalent of a halo DMA; on
real TPU this would become a manual double-buffered copy — see
EXPERIMENTS.md §Perf for the VMEM budget).

VMEM per program (fp32): H*W*Cin (staged input) + kh*kw*Cin*Cout +
BH*W_out*Cout floats. For the pipeline's largest conv (64x64x3 input,
8 output channels, BH=16) that is ~0.3 MB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BH = 16


def _conv_kernel(kh, kw, bh, w_out, x_ref, w_ref, o_ref):
    cout = o_ref.shape[2]
    cin = x_ref.shape[2]
    row0 = pl.program_id(0) * bh
    acc = jnp.zeros((bh * w_out, cout), dtype=jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            # Shifted input band for this tap: (bh, w_out, cin).
            window = jax.lax.dynamic_slice(
                x_ref[...], (row0 + dy, dx, 0), (bh, w_out, cin)
            ).astype(jnp.float32)
            tap = w_ref[dy, dx].astype(jnp.float32)  # (cin, cout)
            acc += jnp.dot(
                window.reshape(bh * w_out, cin),
                tap,
                preferred_element_type=jnp.float32,
            )
    o_ref[...] = acc.reshape(bh, w_out, cout)


@functools.partial(jax.jit, static_argnames=("bh",))
def conv2d(x, w, bh=DEFAULT_BH):
    """VALID conv: ``x`` (H, W, Cin) * ``w`` (kh, kw, Cin, Cout) -> HWC.

    Output rows are padded to a multiple of the row-block and sliced back.
    """
    h, width, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, f"channel mismatch: {cin} != {cin2}"
    h_out = h - kh + 1
    w_out = width - kw + 1
    assert h_out > 0 and w_out > 0, "kernel larger than input"
    bh = min(bh, h_out)
    hp = pl.cdiv(h_out, bh) * bh
    # Pad input rows so the last block has a full (bh + kh - 1) window.
    pad_rows = hp + kh - 1 - h
    if pad_rows > 0:
        x = jnp.pad(x, ((0, pad_rows), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_conv_kernel, kh, kw, bh, w_out),
        grid=(hp // bh,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bh, w_out, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, w_out, cout), jnp.float32),
        interpret=True,
    )(x, w)
    return out[:h_out]


def vmem_bytes(h, w, cin, kh, kw, cout, bh=DEFAULT_BH, dtype_bytes=4):
    """Per-program VMEM footprint estimate (see module docs)."""
    w_out = w - kw + 1
    return dtype_bytes * (h * w * cin + kh * kw * cin * cout + bh * w_out * cout)
