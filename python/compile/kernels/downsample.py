"""Box down-sampling Pallas kernel — the pipeline's frame-resize hot path.

The paper's ingestion stage resizes 1920x1080 frames to 960x540 (an exact
factor-2 box filter) and Fig 8 attributes ~45% of ingestion CPU (and 17.8%
of end-to-end cycles) to resizing. This kernel is that operation, blocked
over output-row bands so each program stages a (BH*f, W, C) input band to
VMEM and reduces it to (BH, W/f, C).

VMEM per program (fp32): BH*f*W*C + BH*(W/f)*C floats; for 1080p f=2
BH=32: ~1.6 MB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BH = 32


def _down_kernel(factor, x_ref, o_ref):
    bh, w_out, c = o_ref.shape
    x = x_ref[...].astype(jnp.float32)  # (bh*f, w_out*f, c)
    x = x.reshape(bh, factor, w_out, factor, c)
    o_ref[...] = x.mean(axis=(1, 3))


@functools.partial(jax.jit, static_argnames=("factor", "bh"))
def downsample(x, factor=2, bh=DEFAULT_BH):
    """Box down-sample an (H, W, C) image by an integer ``factor``."""
    h, w, c = x.shape
    assert h % factor == 0 and w % factor == 0, "shape must divide the factor"
    h_out = h // factor
    w_out = w // factor
    bh = min(bh, h_out)
    assert h_out % bh == 0, f"row block {bh} must divide output height {h_out}"
    return pl.pallas_call(
        functools.partial(_down_kernel, factor),
        grid=(h_out // bh,),
        in_specs=[pl.BlockSpec((bh * factor, w, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bh, w_out, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out, w_out, c), jnp.float32),
        interpret=True,
    )(x)


def vmem_bytes(w, c, factor=2, bh=DEFAULT_BH, dtype_bytes=4):
    """Per-program VMEM footprint estimate (see module docs)."""
    return dtype_bytes * (bh * factor * w * c + bh * (w // factor) * c)
