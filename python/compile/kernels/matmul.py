"""Blocked matmul Pallas kernel.

The pipeline's dense compute (embedding projection, SVM scores, conv taps)
funnels through this kernel. Blocking strategy:

* grid = (M/BM, N/BN); each program owns one (BM, BN) output tile;
* A-tile (BM, K) and B-tile (K, BN) are staged HBM->VMEM by BlockSpec;
* accumulation is fp32 regardless of input dtype (MXU-native).

VMEM footprint per program (fp32): BM*K + K*BN + BM*BN floats. With the
default BM=BN=128 and the pipeline's K <= 2048 this stays under 2.2 MB —
comfortably inside a TPU core's ~16 MB VMEM, leaving room for
double-buffering (see DESIGN.md / EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(a, b, preferred_element_type=jnp.float32)


def _pad_to(x, rows, cols):
    pr = rows - x.shape[0]
    pc = cols - x.shape[1]
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(a, b, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """``a @ b`` with fp32 accumulation via a blocked Pallas kernel.

    Arbitrary (M, K) x (K, N); inputs are zero-padded up to tile multiples
    and the result is sliced back, so callers never see the blocking.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} != {k2}"
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    mp = pl.cdiv(m, bm) * bm
    np_ = pl.cdiv(n, bn) * bn
    a_p = _pad_to(a, mp, k)
    b_p = _pad_to(b, k, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def vmem_bytes(m, k, n, bm=DEFAULT_BM, bn=DEFAULT_BN, dtype_bytes=4):
    """Per-program VMEM footprint estimate (see module docs)."""
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    return dtype_bytes * (bm * k + k * bn + bm * bn)
