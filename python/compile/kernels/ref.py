"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels match these references.
"""

import jax.numpy as jnp
from jax import lax


def matmul_ref(a, b):
    """Plain matmul with fp32 accumulation."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def conv2d_ref(x, w):
    """VALID 2D convolution, HWC x HWIO -> HWC.

    ``x``: (H, W, Cin); ``w``: (kh, kw, Cin, Cout).
    """
    x4 = x[None].astype(jnp.float32)  # NHWC
    out = lax.conv_general_dilated(
        x4,
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[0]


def downsample_ref(x, factor):
    """Box down-sampling by an integer factor over H and W of an HWC image.

    For factor 2 this is exactly the bilinear half-resolution resize the
    pipeline uses (1920x1080 -> 960x540).
    """
    h, w, c = x.shape
    assert h % factor == 0 and w % factor == 0, "shape must divide the factor"
    x = x.astype(jnp.float32)
    x = x.reshape(h // factor, factor, w // factor, factor, c)
    return x.mean(axis=(1, 3))
