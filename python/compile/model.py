"""Layer-2 JAX models: the Face Recognition pipeline's compute graphs.

Stand-ins for the paper's MT-CNN + FaceNet + SVM stack with the same
pipeline topology and inter-stage data shapes (DESIGN.md §6): the AI-tax
claims depend on where time and bytes go, not on model accuracy. All four
graphs are built from the Layer-1 Pallas kernels so that lowering them
produces a single HLO module per stage with the kernels inlined.

Scaled geometry (the paper's 1920x1080 -> 960x540 -> 160x160 path, scaled
to CPU-interpretable sizes):

* frames   : 128x128x3  (FRAME_SIDE)
* detector : 64x64x3    (after the factor-2 preprocess downsample)
* thumbnail: 32x32x3    (THUMB_SIDE; the paper's 160x160 face crop)
* embedding: 128-d      (the paper's FaceNet width)
* gallery  : 32 known identities (SVM one-vs-all)

The face detector is architecturally a P-Net-style fully-convolutional
stack, but its channel-0 path is *hand-assembled* as a brightness
integrator so the end-to-end demo genuinely localizes the synthetic
bright-blob faces the Rust frame generator draws; remaining channels carry
seeded random weights. Identification is a random (but fixed) projection:
identities are consistent, not semantically meaningful — documented in
README §Limitations.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.conv2d import conv2d
from .kernels.downsample import downsample
from .kernels.matmul import matmul

FRAME_SIDE = 128
DETECT_SIDE = 64
THUMB_SIDE = 32
EMBED_DIM = 128
GALLERY = 32
SEED = 0xFACE


def _rng(salt):
    return np.random.default_rng(SEED + salt)


def _conv_weights(salt, kh, kw, cin, cout, passthrough=False):
    """Seeded He-scaled conv weights; optionally wire channel 0 as a
    brightness-passthrough (center tap averages/forwards channel 0)."""
    rng = _rng(salt)
    w = rng.normal(0.0, np.sqrt(2.0 / (kh * kw * cin)), (kh, kw, cin, cout))
    w = w.astype(np.float32)
    if passthrough:
        w[:, :, :, 0] = 0.0
        if cin >= 3:
            # First layer: channel 0 = mean brightness of the RGB window.
            w[:, :, :3, 0] = 1.0 / (kh * kw * 3)
        else:
            w[kh // 2, kw // 2, 0, 0] = 1.0
    return jnp.asarray(w)


# ---------------------------------------------------------------------------
# Stage graphs
# ---------------------------------------------------------------------------


def preprocess_fn(frame):
    """Ingestion resize: (128,128,3) -> (64,64,3) box downsample."""
    return (downsample(frame, factor=2),)


# Detector weights (module-level constants fold into the HLO).
_DW1 = _conv_weights(1, 3, 3, 3, 8, passthrough=True)
_DW2 = _conv_weights(2, 3, 3, 8, 16, passthrough=True)
_DW_PROB = _conv_weights(3, 1, 1, 16, 1)
_DW_BBOX = _conv_weights(4, 1, 1, 16, 4)
# Brightness channel -> positive logit for bright windows. The synthetic
# frames use background 0.1 and face blobs ~0.8 mean; threshold between.
_PROB_GAIN = 24.0
_PROB_BIAS = -24.0 * 0.45


def detect_fn(image):
    """P-Net-style detector: (64,64,3) -> prob map (60,60) + bbox (60,60,4).

    Two 3x3 VALID convs (so the map is 60x60; each cell sees an 8x8-ish
    receptive field at frame scale) followed by 1x1 heads.
    """
    h1 = jax.nn.relu(conv2d(image, _DW1))
    h2 = jax.nn.relu(conv2d(h1, _DW2))
    logits = conv2d(h2, _DW_PROB)[..., 0]
    # Channel 0 of h2 is the brightness integrator; mix it into the logit.
    prob = jax.nn.sigmoid(_PROB_GAIN * h2[..., 0] + _PROB_BIAS + 0.05 * logits)
    bbox = conv2d(h2, _DW_BBOX)
    return prob, bbox


# Embedder weights.
_EW1 = _conv_weights(10, 3, 3, 3, 16)
_EW2 = _conv_weights(11, 3, 3, 16, 32)
_EW3 = _conv_weights(12, 3, 3, 32, 32)
_EP = jnp.asarray(
    _rng(13).normal(0.0, 0.05, (13 * 13 * 32, EMBED_DIM)).astype(np.float32)
)


def embed_fn(thumb):
    """FaceNet stand-in: (32,32,3) -> unit-norm 128-d embedding."""
    h = jax.nn.relu(conv2d(thumb, _EW1))        # 30x30x16
    h = jax.nn.relu(conv2d(h, _EW2))            # 28x28x32
    h = jax.nn.relu(conv2d(h, _EW3))            # 26x26x32
    # 2x2 mean pool -> 13x13x32, flatten, project.
    h = h.reshape(13, 2, 13, 2, 32).mean(axis=(1, 3))
    flat = h.reshape(1, -1)
    emb = matmul(flat, _EP)[0]
    return (emb / (jnp.linalg.norm(emb) + 1e-6),)


# SVM one-vs-all gallery.
_SVM_W = jnp.asarray(_rng(20).normal(0.0, 1.0, (EMBED_DIM, GALLERY)).astype(np.float32))
_SVM_B = jnp.asarray(_rng(21).normal(0.0, 0.1, (GALLERY,)).astype(np.float32))


def classify_fn(embedding):
    """Linear SVM scores: (128,) -> (GALLERY,)."""
    scores = matmul(embedding.reshape(1, -1), _SVM_W)[0] + _SVM_B
    return (scores,)


def identify_fn(thumb):
    """Fused feature extraction + classification — the paper's
    'identification' stage is exactly this fusion (§3.3: 'feature
    extraction and classification are tightly coupled')."""
    (emb,) = embed_fn(thumb)
    (scores,) = classify_fn(emb)
    return emb, scores


def identify_batch_fn(thumbs):
    """Batched identification: (B,32,32,3) -> (B,128), (B,GALLERY).

    Used by the Rust coordinator's dynamic batcher; exported for B=8.
    """
    embs, scores = jax.vmap(identify_fn)(thumbs)
    return embs, scores


BATCH = 8

# ---------------------------------------------------------------------------
# Entry-point registry for AOT export (name -> (fn, example input shapes))
# ---------------------------------------------------------------------------

ENTRY_POINTS = {
    "preprocess": (preprocess_fn, [(FRAME_SIDE, FRAME_SIDE, 3)]),
    "detect": (detect_fn, [(DETECT_SIDE, DETECT_SIDE, 3)]),
    "embed": (embed_fn, [(THUMB_SIDE, THUMB_SIDE, 3)]),
    "classify": (classify_fn, [(EMBED_DIM,)]),
    "identify": (identify_fn, [(THUMB_SIDE, THUMB_SIDE, 3)]),
    "identify_batch": (identify_batch_fn, [(BATCH, THUMB_SIDE, THUMB_SIDE, 3)]),
}
