"""AOT export tests: every entry point lowers to loadable HLO text and the
manifest agrees with the model geometry."""

import json
import os
import subprocess
import sys
import tempfile

from compile import aot, model


def test_every_entry_lowers():
    for name in model.ENTRY_POINTS:
        hlo, outs = aot.lower_entry(name)
        assert "ENTRY" in hlo, f"{name}: not an HLO module"
        assert "main" in hlo
        assert len(outs) >= 1


def test_hlo_is_text_not_proto():
    hlo, _ = aot.lower_entry("classify")
    # Text HLO starts with the module header; serialized protos are binary.
    assert hlo.lstrip().startswith("HloModule")


def test_op_histogram_counts_something():
    hlo, _ = aot.lower_entry("embed")
    ops = aot.op_histogram(hlo)
    assert sum(ops.values()) > 10
    # The conv kernels lower to dot ops (the MXU path).
    assert ops.get("dot", 0) >= 1


def test_export_writes_manifest(tmp_path):
    aot.export_all(str(tmp_path))
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["embed_dim"] == model.EMBED_DIM
    assert set(manifest["entries"]) == set(model.ENTRY_POINTS)
    for name, e in manifest["entries"].items():
        assert (tmp_path / e["file"]).exists(), name
        assert e["inputs"][0]["dtype"] == "float32"


def test_detect_manifest_shapes(tmp_path):
    aot.export_all(str(tmp_path))
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    det = manifest["entries"]["detect"]
    assert det["inputs"][0]["shape"] == [64, 64, 3]
    assert det["outputs"][0]["shape"] == [60, 60]
    assert det["outputs"][1]["shape"] == [60, 60, 4]


def test_no_elided_constants():
    """Regression: the default HLO printer elides large constants as
    `constant({...})`, which xla_extension 0.5.1's text parser silently
    reads back as zeros — the Rust pipeline then detects nothing."""
    for name in model.ENTRY_POINTS:
        hlo, _ = aot.lower_entry(name)
        assert "constant({...})" not in hlo, f"{name} has elided constants"
