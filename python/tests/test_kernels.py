"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every property asserts allclose
against ``ref.py``. This is the CORE correctness signal for the AOT
artifacts — the same kernel code is inlined into every exported HLO.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, downsample, matmul, ref

F32 = np.float32
BF16 = jnp.bfloat16

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(F32)
    if dtype is BF16:
        return jnp.asarray(x, dtype=BF16)
    return jnp.asarray(x)


# ---------------------------------------------------------------- matmul

@given(
    m=st.integers(1, 70),
    k=st.integers(1, 80),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref_f32(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, k), F32)
    b = _rand(rng, (k, n), F32)
    out = matmul.matmul(a, b)
    assert out.shape == (m, n)
    np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 48),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref_bf16(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, k), BF16)
    b = _rand(rng, (k, n), BF16)
    out = matmul.matmul(a, b)
    # bf16 inputs, fp32 accumulation: tolerance set by input rounding.
    np.testing.assert_allclose(
        out, ref.matmul_ref(a, b), rtol=2e-2, atol=2e-2 * np.sqrt(k)
    )


@given(bm=st.sampled_from([8, 16, 32, 128]), bn=st.sampled_from([8, 16, 32, 128]))
def test_matmul_block_shape_invariant(bm, bn):
    """Result must not depend on the blocking (pure performance knob)."""
    rng = np.random.default_rng(7)
    a = _rand(rng, (50, 33), F32)
    b = _rand(rng, (33, 41), F32)
    out = matmul.matmul(a, b, bm=bm, bn=bn)
    np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)


def test_matmul_vmem_estimate_positive():
    assert matmul.vmem_bytes(512, 2048, 128) <= 2_300_000


# ---------------------------------------------------------------- conv2d

@given(
    h=st.integers(4, 36),
    w=st.integers(4, 36),
    cin=st.sampled_from([1, 3, 8]),
    cout=st.sampled_from([1, 4, 8]),
    kh=st.sampled_from([1, 3]),
    kw=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**31),
)
def test_conv2d_matches_ref(h, w, cin, cout, kh, kw, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (h, w, cin), F32)
    wts = _rand(rng, (kh, kw, cin, cout), F32)
    out = conv2d.conv2d(x, wts)
    assert out.shape == (h - kh + 1, w - kw + 1, cout)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, wts), rtol=1e-4, atol=1e-4)


@given(bh=st.sampled_from([1, 2, 5, 16, 64]))
def test_conv2d_row_block_invariant(bh):
    rng = np.random.default_rng(11)
    x = _rand(rng, (23, 19, 3), F32)
    w = _rand(rng, (3, 3, 3, 8), F32)
    out = conv2d.conv2d(x, w, bh=bh)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-4)


def test_conv2d_identity_kernel():
    """A 1x1 identity kernel must return the input."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((9, 7, 3)), F32)
    w = jnp.eye(3, dtype=F32).reshape(1, 1, 3, 3)
    np.testing.assert_allclose(conv2d.conv2d(x, w), x, rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------ downsample

@given(
    hb=st.integers(1, 12),
    wb=st.integers(1, 12),
    c=st.sampled_from([1, 3]),
    factor=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31),
)
def test_downsample_matches_ref(hb, wb, c, factor, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (hb * factor, wb * factor, c), F32)
    out = downsample.downsample(x, factor=factor, bh=1)
    assert out.shape == (hb, wb, c)
    np.testing.assert_allclose(out, ref.downsample_ref(x, factor), rtol=1e-5, atol=1e-6)


def test_downsample_constant_is_preserved():
    x = jnp.full((16, 8, 3), 0.37, F32)
    out = downsample.downsample(x, factor=2, bh=4)
    np.testing.assert_allclose(out, jnp.full((8, 4, 3), 0.37), rtol=1e-6)


def test_downsample_frame_geometry():
    """The pipeline's actual frame path: 128x128 -> 64x64."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (128, 128, 3), F32)
    out = downsample.downsample(x, factor=2)
    assert out.shape == (64, 64, 3)
    np.testing.assert_allclose(out, ref.downsample_ref(x, 2), rtol=1e-5, atol=1e-6)
