"""Layer-2 model tests: shapes, determinism and the detector's behavior
on the synthetic workload the Rust frame generator produces."""

import jax.numpy as jnp
import numpy as np

from compile import model


def synthetic_frame(faces):
    """Mirror of the Rust `Frame::synthetic` generator."""
    side = model.FRAME_SIDE
    f = np.full((side, side, 3), 0.1, np.float32)
    fs = side // 8
    for (cx, cy) in faces:
        f[cy : cy + fs, cx : cx + fs, 0] = 0.9
        f[cy : cy + fs, cx : cx + fs, 1] = 0.72
        f[cy : cy + fs, cx : cx + fs, 2] = 0.63
    return jnp.asarray(f)


def test_preprocess_shape():
    (out,) = model.preprocess_fn(synthetic_frame([]))
    assert out.shape == (model.DETECT_SIDE, model.DETECT_SIDE, 3)


def test_detect_finds_bright_faces():
    frame = synthetic_frame([(16, 16), (80, 80)])
    (small,) = model.preprocess_fn(frame)
    prob, bbox = model.detect_fn(small)
    assert prob.shape == (60, 60)
    assert bbox.shape == (60, 60, 4)
    # Face regions (frame coords /2 - conv offset) light up...
    assert float(prob[8:14, 8:14].max()) > 0.9
    assert float(prob[40:46, 40:46].max()) > 0.9
    # ...and empty regions stay dark.
    assert float(prob[25:35, 25:35].mean()) < 0.05


def test_detect_empty_frame_is_quiet():
    (small,) = model.preprocess_fn(synthetic_frame([]))
    prob, _ = model.detect_fn(small)
    assert float(prob.max()) < 0.05


def test_embedding_is_unit_norm_and_deterministic():
    rng = np.random.default_rng(5)
    thumb = jnp.asarray(rng.random((32, 32, 3)), jnp.float32)
    (e1,) = model.embed_fn(thumb)
    (e2,) = model.embed_fn(thumb)
    assert e1.shape == (model.EMBED_DIM,)
    np.testing.assert_allclose(e1, e2)
    assert abs(float(jnp.linalg.norm(e1)) - 1.0) < 1e-4


def test_distinct_thumbs_get_distinct_embeddings():
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.random((32, 32, 3)), jnp.float32)
    b = jnp.asarray(rng.random((32, 32, 3)), jnp.float32)
    (ea,) = model.embed_fn(a)
    (eb,) = model.embed_fn(b)
    assert float(jnp.dot(ea, eb)) < 0.99


def test_classify_scores_shape():
    (emb,) = model.embed_fn(jnp.ones((32, 32, 3)))
    (scores,) = model.classify_fn(emb)
    assert scores.shape == (model.GALLERY,)


def test_identify_fuses_embed_and_classify():
    thumb = jnp.ones((32, 32, 3)) * 0.5
    emb, scores = model.identify_fn(thumb)
    (emb2,) = model.embed_fn(thumb)
    (scores2,) = model.classify_fn(emb2)
    np.testing.assert_allclose(emb, emb2, rtol=1e-6)
    np.testing.assert_allclose(scores, scores2, rtol=1e-5, atol=1e-5)


def test_identify_batch_matches_unbatched():
    rng = np.random.default_rng(9)
    thumbs = jnp.asarray(rng.random((model.BATCH, 32, 32, 3)), jnp.float32)
    embs, scores = model.identify_batch_fn(thumbs)
    assert embs.shape == (model.BATCH, model.EMBED_DIM)
    assert scores.shape == (model.BATCH, model.GALLERY)
    e0, s0 = model.identify_fn(thumbs[0])
    np.testing.assert_allclose(embs[0], e0, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(scores[0], s0, rtol=1e-4, atol=1e-4)
