//! Bench: design-knob ablations (timer tuning, replication factor,
//! storage media) — the DESIGN.md §8 ablation suite.
use aitax::experiments::ablation;
use aitax::experiments::common::Fidelity;
use aitax::util::bench::Bench;

fn main() {
    let f = Fidelity::from_env();
    let mut b = Bench::new("ablations");
    let mut tuning = None;
    b.run_once("kafka timer tuning sweep (4 runs)", 4.0, || {
        tuning = Some(ablation::tuning_sweep(f));
    });
    ablation::print_tuning(&tuning.unwrap());

    let mut repl = None;
    b.run_once("replication sweep @6x (3 runs)", 3.0, || {
        repl = Some(ablation::replication_sweep(6.0, f));
    });
    ablation::print_replication(&repl.unwrap(), 6.0);

    let mut media = None;
    b.run_once("storage media sweep (6 runs)", 6.0, || {
        media = Some(ablation::storage_media_sweep(f));
    });
    ablation::print_storage_media(&media.unwrap());
}
