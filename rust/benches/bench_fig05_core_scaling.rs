//! Bench: regenerate Fig 5 (FR container core scaling) + time the model.
use aitax::experiments::fig05;
use aitax::util::bench::{paper_row, Bench};

fn main() {
    let r = fig05::run(16);
    fig05::print(&r);
    paper_row("ingest/detect latency @2 cores", r.ingest_detect[1].relative_latency, 0.84, "rel");
    paper_row("identification latency @2 cores", r.identification[1].relative_latency, 0.64, "rel");
    let mut b = Bench::new("fig05");
    b.run("core-scaling sweep (16 cores, both containers)", 32.0, || {
        std::hint::black_box(fig05::run(16));
    });
}
