//! Bench: regenerate Fig 6 + §4.2 tails (full paper-scale DES run).
use aitax::experiments::common::Fidelity;
use aitax::experiments::fig06;
use aitax::util::bench::{paper_row, Bench};

fn main() {
    let mut b = Bench::new("fig06");
    let fidelity = Fidelity::from_env();
    let mut out = None;
    b.run_once("facerec 840p/1680c/3b simulation", 1.0, || {
        out = Some(fig06::run(fidelity));
    });
    let r = out.unwrap();
    fig06::print(&r);
    paper_row("ingestion mean (ms)", r.ingest_mean_us / 1e3, 18.8, "ms");
    paper_row("detection mean (ms)", r.detect_mean_us / 1e3, 74.8, "ms");
    paper_row("broker wait mean (ms)", r.wait_mean_us / 1e3, 126.1, "ms");
    paper_row("identification mean (ms)", r.identify_mean_us / 1e3, 131.5, "ms");
    paper_row("end-to-end mean (ms)", r.e2e_mean_us / 1e3, 351.0, "ms");
    paper_row("end-to-end p99 (s)", r.e2e_p99_us as f64 / 1e6, 2.21, "s");
    paper_row("detection p99 (s)", r.detect_p99_us as f64 / 1e6, 1.84, "s");
    paper_row("ingestion p99 (ms)", r.ingest_p99_us as f64 / 1e3, 27.0, "ms");
}
