//! Bench: regenerate Fig 7 (latency tracks faces-in-system).
use aitax::experiments::common::Fidelity;
use aitax::experiments::fig07;
use aitax::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig07");
    let mut out = None;
    b.run_once("facerec run + timeseries extraction", 1.0, || {
        out = Some(fig07::run(Fidelity::from_env()));
    });
    let r = out.unwrap();
    fig07::print(&r);
    println!("\npaper: 'average end-to-end latency is clearly correlated to the number of");
    println!("        average faces per frame' — we measure r = {:.2}", r.correlation);
}
