//! Bench: regenerate Fig 8 (per-process CPU-time breakdowns).
use aitax::experiments::fig08;
use aitax::util::bench::paper_row;

fn main() {
    let stages = fig08::run();
    fig08::print(&stages);
    paper_row("detection AI share", stages[1].ai_fraction, 0.42, "frac");
    paper_row("identification AI share", stages[2].ai_fraction, 0.88, "frac");
    paper_row("end-to-end AI share", fig08::end_to_end_ai_share(), 0.552, "frac");
}
