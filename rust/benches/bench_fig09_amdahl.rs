//! Bench: regenerate Fig 9 (Amdahl projections).
use aitax::accel::amdahl::stage_speedup;
use aitax::experiments::fig09;
use aitax::util::bench::{paper_row, Bench};

fn main() {
    let r = fig09::run();
    fig09::print(&r);
    paper_row("detection speedup @8x", stage_speedup(0.42, 8.0), 1.59, "x");
    paper_row("detection speedup @16x", stage_speedup(0.42, 16.0), 1.66, "x");
    paper_row("identification speedup @16x", stage_speedup(0.88, 16.0), 5.6, "x");
    paper_row("identification speedup @32x", stage_speedup(0.88, 32.0), 6.6, "x");
    let mut b = Bench::new("fig09");
    b.run("amdahl full sweep", 21.0, || {
        std::hint::black_box(fig09::run());
    });
}
