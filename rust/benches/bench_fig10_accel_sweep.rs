//! Bench: regenerate Fig 10 + §5.5 (acceleration sweep, 8x instability).
use aitax::experiments::common::Fidelity;
use aitax::experiments::fig10;
use aitax::util::bench::{paper_row, Bench};

fn main() {
    let mut b = Bench::new("fig10");
    let mut out = None;
    b.run_once("facerec accel sweep 1..8x (5 DES runs)", 5.0, || {
        out = Some(fig10::run(Fidelity::from_env()));
    });
    let r = out.unwrap();
    fig10::print(&r);
    // §5.5 wait-share trend.
    let paper_shares = [64.6, 66.4, 68.0, 79.1];
    for (rep, paper) in r.reports.iter().zip(paper_shares) {
        paper_row(
            &format!("wait share @{}x (%)", rep.accel),
            100.0 * rep.wait_fraction,
            paper,
            "%",
        );
    }
    println!(
        "\n  8x unstable: measured {} | paper: yes (latency -> infinity)",
        !r.reports[4].verdict.stable
    );
}
