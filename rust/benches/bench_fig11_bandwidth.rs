//! Bench: regenerate Fig 11 (network vs storage bandwidth under accel).
use aitax::experiments::common::Fidelity;
use aitax::experiments::fig11;
use aitax::util::bench::{paper_row, Bench};

fn main() {
    let mut b = Bench::new("fig11");
    let mut out = None;
    b.run_once("facerec bandwidth sweep 1..8x", 5.0, || {
        out = Some(fig11::run(Fidelity::from_env()));
    });
    let r = out.unwrap();
    fig11::print(&r);
    paper_row("storage write util @1x (%)", 100.0 * r.reports[0].storage_write_util, 10.0, "%");
    paper_row("storage write util @8x (%)", 100.0 * r.reports[4].storage_write_util, 67.0, "%");
    paper_row("broker net rx util @8x (%)", 100.0 * r.reports[4].broker_net_rx_util, 6.0, "%");
}
