//! Bench: regenerate Fig 12 (Object Detection near-linear core scaling).
use aitax::experiments::fig12;
use aitax::util::bench::paper_row;

fn main() {
    let r = fig12::run(14);
    fig12::print(&r);
    paper_row("speedup @14 cores", r.detection[13].speedup, 12.0, "x");
    println!("  (paper shows 'very good efficiency'; 14 cores per container chosen)");
}
