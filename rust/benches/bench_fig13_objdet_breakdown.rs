//! Bench: regenerate Fig 13 (Object Detection latency breakdown).
use aitax::experiments::common::Fidelity;
use aitax::experiments::fig13;
use aitax::util::bench::{paper_row, Bench};

fn main() {
    let mut b = Bench::new("fig13");
    let mut out = None;
    b.run_once("objdet 21p/2016c/3b simulation", 1.0, || {
        out = Some(fig13::run(Fidelity::from_env()));
    });
    let r = out.unwrap();
    fig13::print(&r);
    paper_row("ingestion mean (ms)", r.ingest_mean_us / 1e3, 4.5, "ms");
    paper_row("broker wait mean (ms)", r.wait_mean_us / 1e3, 629.0, "ms");
    paper_row("detection mean (ms)", r.detect_mean_us / 1e3, 687.0, "ms");
    paper_row("throughput (FPS)", r.throughput_fps, 630.0, "fps");
}
