//! Bench: regenerate Fig 14 (Object Detection under acceleration).
use aitax::experiments::common::Fidelity;
use aitax::experiments::fig14;
use aitax::util::bench::{paper_row, Bench};

fn main() {
    let mut b = Bench::new("fig14");
    let mut out = None;
    b.run_once("objdet accel sweep 1..16x (6 DES runs)", 6.0, || {
        out = Some(fig14::run(Fidelity::from_env()));
    });
    let r = out.unwrap();
    fig14::print(&r);
    paper_row("throughput @1x (FPS)", r.reports[0].throughput_fps, 630.0, "fps");
    paper_row("throughput @8x (FPS)", r.reports[3].throughput_fps, 8.0 * 630.0, "fps");
    println!(
        "  16x saturated: measured {} | paper: yes",
        !r.reports[5].verdict.stable || r.reports[5].throughput_fps < 0.8 * 16.0 * 630.0
    );
}
