//! Bench: regenerate Fig 15 (drives / brokers / thumbnail mitigations).
//! This is the heaviest sweep (~60 DES runs); AITAX_QUICK=1 shortens it.
use aitax::experiments::common::Fidelity;
use aitax::experiments::fig15;
use aitax::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig15");
    let mut out = None;
    b.run_once("mitigation grid (12 variants x 5 factors)", 60.0, || {
        out = Some(fig15::run(Fidelity::from_env()));
    });
    let r = out.unwrap();
    fig15::print(&r);
    println!("\n  unlock summary (ours vs paper):");
    let paper_drives = ["<8x", "12x", "24x", "32x"];
    for (v, p) in r.drives.iter().zip(paper_drives) {
        println!(
            "    {:<22} {:>6} (paper {})",
            v.label,
            v.unlocked.map(|k| format!("{k}x")).unwrap_or("<8x".into()),
            p
        );
    }
    let paper_brokers = ["<8x", "8x", "16x", "32x"];
    for (v, p) in r.brokers.iter().zip(paper_brokers) {
        println!(
            "    {:<22} {:>6} (paper {})",
            v.label,
            v.unlocked.map(|k| format!("{k}x")).unwrap_or("<8x".into()),
            p
        );
    }
}
