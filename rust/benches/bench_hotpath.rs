//! Hot-path microbenchmarks — the §Perf numbers for Layer 3.
//!
//! Targets (EXPERIMENTS.md §Perf): the DES must sustain >=1M events/s so
//! paper-scale sweeps run in seconds; the broker append path must push
//! >=1 GB/s in memory (i.e. the *modeled* 1.1 GB/s device, not our code,
//! is the bottleneck — the paper's own L3 claim); record framing and the
//! RNG must be nanosecond-scale.

use aitax::broker::controller::Controller;
use aitax::broker::record::{Record, RecordBatch};
use aitax::broker::topic::TopicPartition;
use aitax::config::{Config, Deployment};
use aitax::pipeline::dc::{self, FabricSpec, TenantSpec, WorkloadKind};
use aitax::pipeline::facerec::FaceRecSim;
use aitax::sim::engine::EventQueue;
use aitax::sim::resource::FifoServer;
use aitax::storage::backend::MemBackend;
use aitax::util::bench::Bench;
use aitax::util::rng::Rng;
use aitax::util::stats::Histogram;

fn main() {
    let mut b = Bench::new("hotpath");

    // --- DES event queue throughput ---
    b.run("event queue push+pop (batch of 1024)", 1024.0, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut rng = Rng::new(1);
        for i in 0..1024u64 {
            q.at(rng.below(1 << 20), i);
        }
        while let Some(x) = q.pop() {
            std::hint::black_box(x);
        }
    });

    // Deep backlog: 64k pending events is the regime the 4-ary heap's
    // shallower sift-down is for (a paper-scale facerec world keeps tens
    // of thousands of events in flight).
    b.run("event queue push+pop (64k backlog)", 65_536.0, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut rng = Rng::new(2);
        for i in 0..65_536u64 {
            q.at(rng.below(1 << 20), i);
        }
        while let Some(x) = q.pop() {
            std::hint::black_box(x);
        }
    });

    // --- whole-simulation events/second ---
    let mut cfg = Config::default();
    cfg.deployment = Deployment::facerec_accel();
    cfg.duration_us = 10 * 1_000_000;
    cfg.accel = 4.0;
    let sim_events = {
        // Exact dispatch count from the kernel itself (one counting run).
        let spec = FabricSpec::from_config(&cfg);
        let mut world = dc::build(
            &[TenantSpec { kind: WorkloadKind::FaceRec, cfg: &cfg }],
            &spec,
            cfg.duration_us,
        );
        world.run_until(cfg.duration_us);
        world.processed() as f64
    };
    b.run_once("facerec DES 10s @4x (300p/455c)", sim_events, || {
        std::hint::black_box(FaceRecSim::new(cfg.clone()).run());
    });

    // --- broker append path (records/s, bytes/s) ---
    let payload = vec![0u8; 37_300];
    let mut ctl = Controller::new(64 << 20);
    for i in 0..3 {
        ctl.add_broker(i, Box::new(MemBackend::new()));
    }
    ctl.create_topic("faces", 64, 3).unwrap();
    let mut key = 0u64;
    b.run("broker produce 37.3kB, acks=all x3 (bytes)", 3.0 * 37_300.0, || {
        let mut batch = RecordBatch::new();
        batch.push(Record::new(key, key, payload.clone()));
        key += 1;
        let tp = TopicPartition::new("faces", (key % 64) as u32);
        ctl.produce(&tp, &batch).unwrap();
    });

    // --- record framing ---
    let mut batch = RecordBatch::new();
    for i in 0..8 {
        batch.push(Record::new(i, i, vec![0u8; 37_300]));
    }
    let wire = batch.encode();
    b.run("batch encode (8x37.3kB)", 8.0, || {
        std::hint::black_box(batch.encode());
    });
    b.run("batch decode (8x37.3kB)", 8.0, || {
        std::hint::black_box(RecordBatch::decode(&wire).unwrap());
    });

    // --- primitives ---
    let mut rng = Rng::new(7);
    b.run("rng lognormal sample", 1.0, || {
        std::hint::black_box(rng.lognormal_mean_cv(131_500.0, 0.5));
    });
    let mut server = FifoServer::new(1.1e9, 18);
    let mut t = 0u64;
    b.run("FifoServer submit", 1.0, || {
        t += 10;
        std::hint::black_box(server.submit(t, 37_300.0));
    });
    let mut hist = Histogram::new();
    let mut x = 1u64;
    b.run("histogram record", 1.0, || {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        hist.record((x >> 40).max(1));
    });
}
