//! Bench: the live three-layer pipeline (real PJRT inference through the
//! real broker substrate) — end-to-end FPS and per-stage inference times.
use aitax::coordinator::live::{LiveConfig, LiveRunner};
use aitax::pipeline::frame::Frame;
use aitax::runtime::engine::{Engine, FacePipeline};
use aitax::runtime::manifest::Manifest;
use aitax::runtime::tensor::Tensor;
use aitax::util::bench::Bench;

fn main() {
    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("bench_live_pipeline: artifacts missing; run `make artifacts` first");
        return;
    }
    let mut b = Bench::new("live");

    // Per-stage inference microbenches.
    let engine = Engine::load_default().expect("engine");
    let pipe = FacePipeline::new(engine);
    let f = Frame::synthetic(0, 0, 0, 128, &[(24, 24), (80, 80)]);
    let frame = Tensor::new(vec![128, 128, 3], f.pixels);
    let image = pipe.preprocess(&frame).unwrap();
    let dets = pipe.detect(&image).unwrap();
    let thumb = pipe.crop_thumb(&image, &dets[0]);
    b.run("preprocess (128^2 -> 64^2)", 1.0, || {
        std::hint::black_box(pipe.preprocess(&frame).unwrap());
    });
    b.run("detect (64^2, P-Net-style)", 1.0, || {
        std::hint::black_box(pipe.engine.run("detect", std::slice::from_ref(&image)).unwrap());
    });
    b.run("identify (32^2 thumb)", 1.0, || {
        std::hint::black_box(pipe.identify(&thumb).unwrap());
    });
    let thumbs: Vec<Tensor> = (0..8).map(|_| thumb.clone()).collect();
    b.run("identify_batch (8 thumbs)", 8.0, || {
        std::hint::black_box(pipe.identify_batch(&thumbs).unwrap());
    });

    // End-to-end live run.
    for (label, batched) in [("unbatched", false), ("batched", true)] {
        let cfg = LiveConfig {
            producers: 2,
            consumers: 4,
            partitions: 8,
            duration: std::time::Duration::from_secs(8),
            batched_identify: batched,
            ..LiveConfig::default()
        };
        let report = LiveRunner::new(cfg).run().expect("live run");
        println!(
            "  live e2e ({label:>9}): {:>6.1} FPS, {} faces identified, e2e mean {:.1} ms",
            report.throughput_fps,
            report.faces_identified,
            report.breakdown.e2e_mean_us / 1e3,
        );
    }
}
