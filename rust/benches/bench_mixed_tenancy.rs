//! Bench: the mixed-tenancy interference sweep (facerec + objdet on one
//! shared broker fabric — the scenario the component kernel enables).
use aitax::experiments::common::Fidelity;
use aitax::experiments::mixed;
use aitax::util::bench::Bench;

fn main() {
    let mut b = Bench::new("mixed_tenancy");
    let mut out = None;
    b.run_once("facerec+objdet mix sweep", mixed::MIX_SHARES.len() as f64, || {
        out = Some(mixed::run(Fidelity::from_env()));
    });
    let sweep = out.unwrap();
    mixed::print(&sweep);
    let solo = sweep.baseline.storage_write_util;
    let full = sweep.points.last().unwrap().report.broker_storage_write_util;
    println!(
        "interference: broker nvme write {:.1}% alone -> {:.1}% with the full objdet fleet",
        100.0 * solo,
        100.0 * full
    );
}
