//! Bench: the broker-QoS SLO sweep (scheduling classes + topic quotas
//! protecting the rpc tenant's p99 under N-tenant colocation).
use aitax::experiments::common::Fidelity;
use aitax::experiments::qos;
use aitax::util::bench::Bench;

fn main() {
    let mut b = Bench::new("qos_isolation");
    let mut out = None;
    b.run_once(
        "4-tenant p99-vs-share sweep (off+on)",
        2.0 * qos::QOS_SHARES.len() as f64,
        || {
            out = Some(qos::run(Fidelity::from_env()));
        },
    );
    let sweep = out.unwrap();
    qos::print(&sweep);
    if let (Some(off), Some(on)) = sweep.pair(1.0) {
        println!(
            "isolation: rpc p99 {} without QoS -> {} with QoS (slo {})",
            aitax::util::units::fmt_us(aitax::experiments::qos::QosSweep::rpc_p99(off)),
            aitax::util::units::fmt_us(aitax::experiments::qos::QosSweep::rpc_p99(on)),
            aitax::util::units::fmt_us(sweep.slo_p99_us),
        );
    }
}
