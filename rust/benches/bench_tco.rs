//! Bench: regenerate Tables 3 & 4 + the §7.3 TCO comparison.
use aitax::experiments::table34;
use aitax::util::bench::{paper_row, Bench};

fn main() {
    let r = table34::run();
    table34::print(&r);
    paper_row("Table 3 equipment ($M)", r.homogeneous.equipment_cost() / 1e6, 33.577760, "$M");
    paper_row("Table 4 equipment ($M)", r.purpose_built.equipment_cost() / 1e6, 27.878431, "$M");
    paper_row("homogeneous yearly TCO ($M)", r.homo_tco.yearly_total / 1e6, 12.9, "$M");
    paper_row("purpose-built yearly TCO ($M)", r.pb_tco.yearly_total / 1e6, 10.8, "$M");
    paper_row("savings (%)", 100.0 * r.savings, 16.6, "%");
    let mut b = Bench::new("tco");
    b.run("design + price both data centers", 2.0, || {
        std::hint::black_box(table34::run());
    });
}
