//! Amdahl's-law projections for AI acceleration (§5.1, Fig 9).
//!
//! "Amdahl's law dictates that the overall speedup of a system is limited
//! by the portion of execution that is not accelerated." Each stage has an
//! AI fraction (Fig 8); accelerating only that share gives
//! `speedup(k) = 1 / ((1 - f) + f/k)` with asymptote `1/(1 - f)`.

/// Overall stage speedup when its AI share `ai_frac` is accelerated `k`×.
pub fn stage_speedup(ai_frac: f64, k: f64) -> f64 {
    assert!((0.0..=1.0).contains(&ai_frac));
    assert!(k >= 1.0);
    1.0 / ((1.0 - ai_frac) + ai_frac / k)
}

/// A named Amdahl curve for one pipeline stage.
#[derive(Clone, Debug)]
pub struct AmdahlCurve {
    pub stage: &'static str,
    pub ai_frac: f64,
}

impl AmdahlCurve {
    /// The paper's three Face Recognition processes (Fig 9).
    pub fn facerec() -> Vec<AmdahlCurve> {
        vec![
            AmdahlCurve {
                stage: "ingestion",
                ai_frac: 0.0,
            },
            AmdahlCurve {
                stage: "detection",
                ai_frac: 0.42,
            },
            AmdahlCurve {
                stage: "identification",
                ai_frac: 0.88,
            },
        ]
    }

    pub fn speedup(&self, k: f64) -> f64 {
        stage_speedup(self.ai_frac, k)
    }

    /// Asymptotic speedup as k → ∞.
    pub fn asymptote(&self) -> f64 {
        if self.ai_frac >= 1.0 {
            f64::INFINITY
        } else {
            1.0 / (1.0 - self.ai_frac)
        }
    }

    /// Sweep over acceleration factors.
    pub fn sweep(&self, factors: &[f64]) -> Vec<(f64, f64)> {
        factors.iter().map(|&k| (k, self.speedup(k))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_quoted_values() {
        // "Detection ... rapidly approaches its asymptotic speedup of just
        //  1.74x, achieving 1.59x at 8x and 1.66x at 16x. Identification,
        //  at 88% AI, has an asymptotic limit of just 8.3x. At 16x it
        //  achieves 5.6x, and even at 32x it shows just 6.6x."
        // Tolerances cover the paper's rounding of the 42%/88% AI shares.
        assert!((stage_speedup(0.42, 8.0) - 1.59).abs() < 0.02);
        assert!((stage_speedup(0.42, 16.0) - 1.66).abs() < 0.02);
        assert!((stage_speedup(0.88, 16.0) - 5.6).abs() < 0.2);
        assert!((stage_speedup(0.88, 32.0) - 6.6).abs() < 0.2);
        let curves = AmdahlCurve::facerec();
        assert!((curves[1].asymptote() - 1.724).abs() < 0.01);
        assert!((curves[2].asymptote() - 8.33).abs() < 0.01);
    }

    #[test]
    fn ingestion_gains_nothing() {
        let c = &AmdahlCurve::facerec()[0];
        for k in [2.0, 8.0, 32.0] {
            assert_eq!(c.speedup(k), 1.0);
        }
        assert_eq!(c.asymptote(), 1.0);
    }

    #[test]
    fn speedup_monotone_in_k() {
        let c = AmdahlCurve {
            stage: "x",
            ai_frac: 0.6,
        };
        let sweep = c.sweep(&[1.0, 2.0, 4.0, 8.0, 16.0, 32.0]);
        for w in sweep.windows(2) {
            assert!(w[1].1 > w[0].1);
            assert!(w[1].1 < c.asymptote());
        }
    }

    #[test]
    fn full_ai_stage_unbounded() {
        assert_eq!(
            AmdahlCurve {
                stage: "pure",
                ai_frac: 1.0
            }
            .asymptote(),
            f64::INFINITY
        );
        assert!((stage_speedup(1.0, 32.0) - 32.0).abs() < 1e-9);
    }
}
