//! Acceleration analysis (§5).
//!
//! * [`amdahl`] — the §5.1 analytical model: per-stage speedup limits under
//!   AI-share-only acceleration (Fig 9).
//! * The emulation protocol itself (§5.2) lives in
//!   [`crate::pipeline::stage::StageModel`]; this module adds the
//!   system-level sweep helpers used by the Fig-10/14/15 benches.

pub mod amdahl;

pub use amdahl::{stage_speedup, AmdahlCurve};
