//! Consumer client with Kafka fetch semantics.
//!
//! §5.5: "when a consumer requests available messages from a broker, the
//! broker can withhold messages until there exists some minimum amount of
//! data" (`fetch.min.bytes`), bounded by a timeout (`fetch.max.wait`).
//! Both behaviors contribute to broker waiting time and both are
//! implemented here, time-driven so the same code runs live and simulated.

use anyhow::Result;

use crate::broker::controller::Controller;
use crate::broker::record::Record;
use crate::broker::topic::TopicPartition;
use crate::config::KafkaTuning;

/// Outcome of one fetch poll.
#[derive(Debug)]
pub enum FetchResult {
    /// Records delivered (flattened across batches, in order).
    Records(Vec<Record>),
    /// Not enough data yet; caller should retry at/after the given time
    /// (when fetch.max.wait would force a response).
    WaitUntil(u64),
}

/// A consumer pinned to a set of partitions (assigned by the group
/// coordinator; at most one consumer per partition).
pub struct Consumer {
    assignment: Vec<TopicPartition>,
    /// Next offset to fetch, per partition.
    positions: std::collections::HashMap<TopicPartition, u64>,
    tuning: KafkaTuning,
    /// Time at which the current min-bytes wait started, per partition.
    wait_started: std::collections::HashMap<TopicPartition, u64>,
    pub records_consumed: u64,
    pub fetch_requests: u64,
}

impl Consumer {
    pub fn new(tuning: KafkaTuning) -> Self {
        Consumer {
            assignment: Vec::new(),
            positions: Default::default(),
            tuning,
            wait_started: Default::default(),
            records_consumed: 0,
            fetch_requests: 0,
        }
    }

    /// Replace the assignment (rebalance). Positions of retained
    /// partitions survive; new partitions start at offset 0.
    pub fn assign(&mut self, partitions: Vec<TopicPartition>) {
        for tp in &partitions {
            self.positions.entry(tp.clone()).or_insert(0);
        }
        self.positions.retain(|tp, _| partitions.contains(tp));
        self.wait_started.retain(|tp, _| partitions.contains(tp));
        self.assignment = partitions;
    }

    pub fn assignment(&self) -> &[TopicPartition] {
        &self.assignment
    }

    pub fn position(&self, tp: &TopicPartition) -> u64 {
        self.positions.get(tp).copied().unwrap_or(0)
    }

    /// Poll one partition honoring fetch.min.bytes / fetch.max.wait.
    pub fn poll_partition(
        &mut self,
        controller: &mut Controller,
        tp: &TopicPartition,
        now: u64,
    ) -> Result<FetchResult> {
        let offset = self.position(tp);
        let available = controller.fetchable_bytes(tp, offset);
        let started = *self.wait_started.entry(tp.clone()).or_insert(now);
        let deadline = started + self.tuning.fetch_max_wait_us;
        if (available as usize) < self.tuning.fetch_min_bytes && now < deadline {
            // Broker withholds the response.
            return Ok(FetchResult::WaitUntil(deadline));
        }
        self.fetch_requests += 1;
        self.wait_started.remove(tp);
        if available == 0 {
            // Timed out with nothing: empty response, restart the wait.
            return Ok(FetchResult::Records(Vec::new()));
        }
        let (batches, next) = controller.fetch(tp, offset, self.tuning.batch_max_bytes)?;
        self.positions.insert(tp.clone(), next);
        let records: Vec<Record> = batches.into_iter().flat_map(|b| b.records).collect();
        self.records_consumed += records.len() as u64;
        Ok(FetchResult::Records(records))
    }

    /// Poll all assigned partitions; returns delivered records and, if
    /// everything is waiting, the earliest retry time.
    pub fn poll(&mut self, controller: &mut Controller, now: u64) -> Result<(Vec<Record>, Option<u64>)> {
        let mut all = Vec::new();
        let mut earliest: Option<u64> = None;
        let assignment = self.assignment.clone();
        for tp in &assignment {
            match self.poll_partition(controller, tp, now)? {
                FetchResult::Records(mut rs) => all.append(&mut rs),
                FetchResult::WaitUntil(t) => {
                    earliest = Some(earliest.map_or(t, |e: u64| e.min(t)));
                }
            }
        }
        let wait = if all.is_empty() { earliest } else { None };
        Ok((all, wait))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::record::RecordBatch;
    use crate::storage::backend::MemBackend;

    fn setup(partitions: u32) -> Controller {
        let mut c = Controller::new(1 << 20);
        for b in 0..3 {
            c.add_broker(b, Box::new(MemBackend::new()));
        }
        c.create_topic("faces", partitions, 3).unwrap();
        c
    }

    fn produce(c: &mut Controller, partition: u32, key: u64, bytes: usize) {
        let mut b = RecordBatch::new();
        b.push(Record::new(key, key, vec![0u8; bytes]));
        c.produce(&TopicPartition::new("faces", partition), &b).unwrap();
    }

    fn tuning(min_bytes: usize, max_wait: u64) -> KafkaTuning {
        KafkaTuning {
            fetch_min_bytes: min_bytes,
            fetch_max_wait_us: max_wait,
            ..KafkaTuning::default()
        }
    }

    #[test]
    fn immediate_fetch_with_min_one() {
        let mut c = setup(1);
        produce(&mut c, 0, 7, 100);
        let mut consumer = Consumer::new(tuning(1, 10_000));
        consumer.assign(vec![TopicPartition::new("faces", 0)]);
        let (records, wait) = consumer.poll(&mut c, 0).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].key, 7);
        assert!(wait.is_none());
    }

    #[test]
    fn min_bytes_withholds_until_enough() {
        let mut c = setup(1);
        produce(&mut c, 0, 1, 100);
        let mut consumer = Consumer::new(tuning(1000, 50_000));
        consumer.assign(vec![TopicPartition::new("faces", 0)]);
        // 100 bytes < 1000 min: withheld.
        let (records, wait) = consumer.poll(&mut c, 0).unwrap();
        assert!(records.is_empty());
        assert_eq!(wait, Some(50_000));
        // More data arrives -> released immediately.
        produce(&mut c, 0, 2, 2000);
        let (records, _) = consumer.poll(&mut c, 1_000).unwrap();
        assert_eq!(records.len(), 2);
    }

    #[test]
    fn max_wait_forces_release() {
        let mut c = setup(1);
        produce(&mut c, 0, 1, 100);
        let mut consumer = Consumer::new(tuning(1_000_000, 30_000));
        consumer.assign(vec![TopicPartition::new("faces", 0)]);
        assert!(matches!(
            consumer.poll_partition(&mut c, &TopicPartition::new("faces", 0), 0).unwrap(),
            FetchResult::WaitUntil(30_000)
        ));
        // At the deadline the broker answers with whatever it has.
        match consumer
            .poll_partition(&mut c, &TopicPartition::new("faces", 0), 30_000)
            .unwrap()
        {
            FetchResult::Records(rs) => assert_eq!(rs.len(), 1),
            other => panic!("expected records, got {other:?}"),
        }
    }

    #[test]
    fn position_advances_no_redelivery() {
        let mut c = setup(1);
        produce(&mut c, 0, 1, 10);
        produce(&mut c, 0, 2, 10);
        let mut consumer = Consumer::new(tuning(1, 1000));
        let tp = TopicPartition::new("faces", 0);
        consumer.assign(vec![tp.clone()]);
        let (r1, _) = consumer.poll(&mut c, 0).unwrap();
        assert_eq!(r1.len(), 2);
        assert_eq!(consumer.position(&tp), 2);
        produce(&mut c, 0, 3, 10);
        let (r2, _) = consumer.poll(&mut c, 10).unwrap();
        assert_eq!(r2.len(), 1);
        assert_eq!(r2[0].key, 3);
    }

    #[test]
    fn rebalance_preserves_position() {
        let mut c = setup(2);
        produce(&mut c, 0, 1, 10);
        let mut consumer = Consumer::new(tuning(1, 1000));
        let tp0 = TopicPartition::new("faces", 0);
        let tp1 = TopicPartition::new("faces", 1);
        consumer.assign(vec![tp0.clone()]);
        consumer.poll(&mut c, 0).unwrap();
        assert_eq!(consumer.position(&tp0), 1);
        // Rebalance adds tp1, keeps tp0: position survives.
        consumer.assign(vec![tp0.clone(), tp1.clone()]);
        assert_eq!(consumer.position(&tp0), 1);
        assert_eq!(consumer.position(&tp1), 0);
        // Rebalance away tp0 then back: position resets (group would
        // normally restore from committed offsets; we start at 0).
        consumer.assign(vec![tp1.clone()]);
        consumer.assign(vec![tp0.clone(), tp1]);
        assert_eq!(consumer.position(&tp0), 0);
    }

    #[test]
    fn multi_partition_poll_merges() {
        let mut c = setup(3);
        produce(&mut c, 0, 10, 10);
        produce(&mut c, 2, 30, 10);
        let mut consumer = Consumer::new(tuning(1, 1000));
        consumer.assign(vec![
            TopicPartition::new("faces", 0),
            TopicPartition::new("faces", 1),
            TopicPartition::new("faces", 2),
        ]);
        let (records, wait) = consumer.poll(&mut c, 0).unwrap();
        let mut keys: Vec<u64> = records.iter().map(|r| r.key).collect();
        keys.sort();
        assert_eq!(keys, vec![10, 30]);
        assert!(wait.is_none(), "got data so no wait hint");
    }
}
