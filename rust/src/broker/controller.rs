//! Cluster controller: broker registry, partition assignment, and the
//! produce/fetch entry points used by clients.
//!
//! Assignment follows Kafka's spread: partition `p` of a topic gets
//! replicas on brokers `(p + r) mod B` for `r` in `0..replication`, so
//! "both leader and follower partitions are spread among all available
//! brokers; thus, no one broker is more important or heavily utilized than
//! any other" (§3.4).
//!
//! Topic-level byte-rate **quotas** ([`Controller::set_topic_quota`])
//! reuse the QoS [`TokenBucket`]: [`Controller::produce_throttled`]
//! admits the batch and returns the Kafka-style mute delay the client
//! must observe before its next request. The bucket semantics are the
//! same ones the DES enforces (see [`crate::broker::qos`]); the live
//! coordinator's producers go through this entry point
//! (`LiveConfig::produce_quota_bytes_per_sec`). Operators who think in
//! device bandwidth instead of client bandwidth can hand
//! [`Controller::set_broker_write_budget`] a per-broker write budget and
//! let the controller translate it into per-topic client rates (divided
//! by each topic's replication factor).

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::broker::partition::Partition;
use crate::broker::qos::TokenBucket;
use crate::broker::record::RecordBatch;
use crate::broker::topic::{Topic, TopicPartition};
use crate::storage::backend::StorageBackend;

pub type BrokerId = u32;

/// The controller owns cluster metadata plus, in in-process mode, every
/// broker's storage backend and every partition's replica logs.
pub struct Controller {
    backends: HashMap<BrokerId, Box<dyn StorageBackend>>,
    alive: HashMap<BrokerId, bool>,
    topics: HashMap<String, Topic>,
    partitions: HashMap<TopicPartition, Partition>,
    /// Per-topic produce byte-rate quotas (QoS).
    topic_quotas: HashMap<String, TokenBucket>,
    segment_bytes: u64,
    /// Produce/fetch counters for observability.
    pub produces: u64,
    pub fetches: u64,
    /// Produce requests that came back with a non-zero throttle delay.
    pub throttled_produces: u64,
}

impl Controller {
    pub fn new(segment_bytes: u64) -> Self {
        Controller {
            backends: HashMap::new(),
            alive: HashMap::new(),
            topics: HashMap::new(),
            partitions: HashMap::new(),
            topic_quotas: HashMap::new(),
            segment_bytes,
            produces: 0,
            fetches: 0,
            throttled_produces: 0,
        }
    }

    pub fn add_broker(&mut self, id: BrokerId, backend: Box<dyn StorageBackend>) {
        self.backends.insert(id, backend);
        self.alive.insert(id, true);
    }

    pub fn broker_ids(&self) -> Vec<BrokerId> {
        let mut ids: Vec<BrokerId> = self.backends.keys().copied().collect();
        ids.sort();
        ids
    }

    pub fn alive_brokers(&self) -> usize {
        self.alive.values().filter(|&&a| a).count()
    }

    /// Create a topic, assigning partition replicas round-robin.
    pub fn create_topic(&mut self, name: &str, partitions: u32, replication: u32) -> Result<()> {
        let brokers = self.broker_ids();
        anyhow::ensure!(
            replication as usize <= brokers.len(),
            "replication {} > broker count {}",
            replication,
            brokers.len()
        );
        anyhow::ensure!(
            !self.topics.contains_key(name),
            "topic {name} already exists"
        );
        let topic = Topic::new(name, partitions, replication);
        for tp in topic.partition_ids() {
            let replicas: Vec<BrokerId> = (0..replication as usize)
                .map(|r| brokers[(tp.partition as usize + r) % brokers.len()])
                .collect();
            self.partitions
                .insert(tp.clone(), Partition::new(tp, &replicas, self.segment_bytes));
        }
        self.topics.insert(name.to_string(), topic);
        Ok(())
    }

    pub fn topic(&self, name: &str) -> Option<&Topic> {
        self.topics.get(name)
    }

    pub fn partition(&self, tp: &TopicPartition) -> Option<&Partition> {
        self.partitions.get(tp)
    }

    /// Leader broker for a partition (clients route produce/fetch here).
    pub fn leader_of(&self, tp: &TopicPartition) -> Result<BrokerId> {
        Ok(self
            .partitions
            .get(tp)
            .with_context(|| format!("unknown partition {tp}"))?
            .leader_broker())
    }

    /// Produce a batch to a partition (`acks=all`). Returns base offset.
    pub fn produce(&mut self, tp: &TopicPartition, batch: &RecordBatch) -> Result<u64> {
        let partition = self
            .partitions
            .get_mut(tp)
            .with_context(|| format!("unknown partition {tp}"))?;
        let base = partition.produce(&mut self.backends, batch)?;
        self.produces += 1;
        Ok(base)
    }

    /// Install a produce byte-rate quota on a topic (bytes/sec, with a
    /// 200 ms burst). Enforced by [`Controller::produce_throttled`];
    /// the plain [`Controller::produce`] path stays uncapped for
    /// backwards compatibility.
    pub fn set_topic_quota(&mut self, topic: &str, bytes_per_sec: f64) {
        self.topic_quotas
            .insert(topic.to_string(), TokenBucket::with_default_burst(bytes_per_sec));
    }

    /// Translate an operator's **per-broker write budget** (bytes/sec of
    /// device writes each broker can spend on this workload) into
    /// per-topic produce quotas. The cluster-wide budget
    /// (`budget × brokers`) splits evenly across the existing topics, and
    /// each topic's slice is divided by its replication factor — the
    /// produce bucket meters *client* bytes, so dividing by RF makes the
    /// admitted client rate cost exactly the budgeted device bytes once
    /// replicated. Returns the number of topics capped; re-call after
    /// creating topics to re-translate.
    pub fn set_broker_write_budget(&mut self, bytes_per_sec_per_broker: f64) -> usize {
        let brokers = self.backends.len();
        let topics: Vec<(String, u32)> = self
            .topics
            .values()
            .map(|t| (t.name.clone(), t.replication.max(1)))
            .collect();
        let n = topics.len();
        for (name, replication) in &topics {
            let rate = crate::broker::qos::write_budget_per_tenant_rate(
                bytes_per_sec_per_broker,
                brokers,
                n,
            ) / *replication as f64;
            self.set_topic_quota(name, rate);
        }
        n
    }

    /// Quota-aware produce: admits the batch (never rejects) and returns
    /// `(base_offset, throttle_us)` — the Kafka mute delay the client
    /// must wait before its next request to this topic. `now_us` is the
    /// client's clock (wall clock in the live coordinator, virtual time
    /// in tests).
    pub fn produce_throttled(
        &mut self,
        tp: &TopicPartition,
        batch: &RecordBatch,
        now_us: u64,
    ) -> Result<(u64, u64)> {
        let bytes = batch.wire_size() as f64;
        let base = self.produce(tp, batch)?;
        let throttle = match self.topic_quotas.get_mut(&tp.topic) {
            Some(bucket) => bucket.charge(now_us, bytes),
            None => 0,
        };
        if throttle > 0 {
            self.throttled_produces += 1;
        }
        Ok((base, throttle))
    }

    /// Fetch from a partition's leader starting at `offset`.
    pub fn fetch(
        &mut self,
        tp: &TopicPartition,
        offset: u64,
        max_bytes: usize,
    ) -> Result<(Vec<RecordBatch>, u64)> {
        let partition = self
            .partitions
            .get(tp)
            .with_context(|| format!("unknown partition {tp}"))?;
        let leader = partition.leader_broker();
        let backend = self
            .backends
            .get_mut(&leader)
            .context("leader backend missing")?;
        self.fetches += 1;
        partition.fetch(backend.as_mut(), offset, max_bytes)
    }

    /// Bytes fetchable from a partition at `offset` (fetch.min.bytes test).
    pub fn fetchable_bytes(&self, tp: &TopicPartition, offset: u64) -> u64 {
        self.partitions
            .get(tp)
            .map(|p| p.fetchable_bytes(offset))
            .unwrap_or(0)
    }

    /// Mark a broker dead; fail over all partitions it led.
    pub fn broker_failed(&mut self, id: BrokerId) -> usize {
        self.alive.insert(id, false);
        let mut leader_changes = 0;
        for p in self.partitions.values_mut() {
            if p.broker_failed(id) {
                leader_changes += 1;
            }
        }
        leader_changes
    }

    /// Total bytes appended across all replica logs (storage-amplification
    /// observability: with replication 3 this is ~3x the produced bytes).
    pub fn total_log_bytes(&self) -> u64 {
        self.partitions
            .values()
            .flat_map(|p| p.replicas.iter().map(|r| r.log.bytes()))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::record::Record;
    use crate::storage::backend::MemBackend;

    fn cluster(brokers: u32) -> Controller {
        let mut c = Controller::new(1 << 20);
        for b in 0..brokers {
            c.add_broker(b, Box::new(MemBackend::new()));
        }
        c
    }

    fn single(key: u64, bytes: usize) -> RecordBatch {
        let mut b = RecordBatch::new();
        b.push(Record::new(key, key, vec![1u8; bytes]));
        b
    }

    #[test]
    fn leaders_spread_across_brokers() {
        let mut c = cluster(3);
        c.create_topic("faces", 9, 3).unwrap();
        let mut counts = [0usize; 3];
        for p in 0..9 {
            let leader = c.leader_of(&TopicPartition::new("faces", p)).unwrap();
            counts[leader as usize] += 1;
        }
        assert_eq!(counts, [3, 3, 3], "leaders should spread evenly");
    }

    #[test]
    fn produce_fetch_roundtrip() {
        let mut c = cluster(3);
        c.create_topic("faces", 2, 3).unwrap();
        let tp = TopicPartition::new("faces", 0);
        c.produce(&tp, &single(42, 100)).unwrap();
        let (batches, next) = c.fetch(&tp, 0, usize::MAX).unwrap();
        assert_eq!(batches[0].records[0].key, 42);
        assert_eq!(next, 1);
    }

    #[test]
    fn replication_amplifies_storage() {
        let mut c = cluster(3);
        c.create_topic("faces", 1, 3).unwrap();
        let tp = TopicPartition::new("faces", 0);
        c.produce(&tp, &single(1, 10_000)).unwrap();
        let total = c.total_log_bytes();
        // 3 replicas wrote ~10kB each (plus framing).
        assert!(total > 30_000 && total < 31_000, "total={total}");
    }

    #[test]
    fn failover_keeps_data_available() {
        let mut c = cluster(3);
        c.create_topic("faces", 3, 3).unwrap();
        let tp = TopicPartition::new("faces", 1);
        c.produce(&tp, &single(7, 64)).unwrap();
        let old_leader = c.leader_of(&tp).unwrap();
        let changes = c.broker_failed(old_leader);
        assert!(changes >= 1);
        assert_ne!(c.leader_of(&tp).unwrap(), old_leader);
        let (batches, _) = c.fetch(&tp, 0, usize::MAX).unwrap();
        assert_eq!(batches[0].records[0].key, 7);
        assert_eq!(c.alive_brokers(), 2);
    }

    #[test]
    fn replication_capped_by_brokers() {
        let mut c = cluster(2);
        assert!(c.create_topic("t", 1, 3).is_err());
    }

    #[test]
    fn duplicate_topic_rejected() {
        let mut c = cluster(3);
        c.create_topic("t", 1, 1).unwrap();
        assert!(c.create_topic("t", 1, 1).is_err());
    }

    #[test]
    fn topic_quota_throttles_but_never_rejects() {
        let mut c = cluster(3);
        c.create_topic("shards", 1, 3).unwrap();
        // 1 MB/s quota; each ~100 kB batch is admitted, and once the
        // burst is spent the throttle delay grows with the debt.
        c.set_topic_quota("shards", 1_000_000.0);
        let tp = TopicPartition::new("shards", 0);
        let mut max_throttle = 0u64;
        for i in 0..10 {
            let (base, throttle) = c
                .produce_throttled(&tp, &single(i, 100_000), 0)
                .unwrap();
            assert_eq!(base, i, "every batch must be admitted");
            max_throttle = max_throttle.max(throttle);
        }
        // ~1 MB charged instantly against a 1 MB/s + 200 ms-burst bucket:
        // the last admission owes most of a second.
        assert!(
            (600_000..=1_100_000).contains(&max_throttle),
            "throttle {max_throttle}"
        );
        assert!(c.throttled_produces > 0);
        // All ten batches are durably readable despite the throttling.
        let (batches, next) = c.fetch(&tp, 0, usize::MAX).unwrap();
        assert_eq!(next, 10);
        assert_eq!(batches.len(), 10);
        // An unquota'd topic reports zero throttle.
        c.create_topic("free", 1, 3).unwrap();
        let free = TopicPartition::new("free", 0);
        let (_, throttle) = c.produce_throttled(&free, &single(1, 100_000), 0).unwrap();
        assert_eq!(throttle, 0);
    }

    #[test]
    fn write_budget_divides_by_replication() {
        let mut c = cluster(3);
        c.create_topic("rf3", 1, 3).unwrap();
        c.create_topic("rf1", 1, 1).unwrap();
        // 2 MB/s per broker × 3 brokers = 6 MB/s of device writes,
        // 3 MB/s of it per topic: 1 MB/s of client bytes on the RF=3
        // topic, 3 MB/s on the RF=1 topic.
        assert_eq!(c.set_broker_write_budget(2_000_000.0), 2);
        let rf3 = TopicPartition::new("rf3", 0);
        let rf1 = TopicPartition::new("rf1", 0);
        // Drain each bucket's 200 ms burst, then measure the marginal
        // throttle of one extra 100 kB batch: 100 ms at 1 MB/s vs
        // ~33 ms at 3 MB/s.
        for i in 0..20 {
            c.produce_throttled(&rf3, &single(i, 100_000), 0).unwrap();
            c.produce_throttled(&rf1, &single(i, 100_000), 0).unwrap();
        }
        let (_, t3a) = c.produce_throttled(&rf3, &single(90, 1), 0).unwrap();
        let (_, t3b) = c.produce_throttled(&rf3, &single(91, 100_000), 0).unwrap();
        let (_, t1a) = c.produce_throttled(&rf1, &single(90, 1), 0).unwrap();
        let (_, t1b) = c.produce_throttled(&rf1, &single(91, 100_000), 0).unwrap();
        let marginal_rf3 = t3b - t3a;
        let marginal_rf1 = t1b - t1a;
        assert!(
            (95_000..=110_000).contains(&marginal_rf3),
            "rf3 marginal throttle {marginal_rf3} should be ~100 ms at 1 MB/s"
        );
        assert!(
            (30_000..=40_000).contains(&marginal_rf1),
            "rf1 marginal throttle {marginal_rf1} should be ~33 ms at 3 MB/s"
        );
    }

    #[test]
    fn zero_write_budget_never_admits_within_horizon() {
        use crate::broker::qos::NEVER_US;
        let mut c = cluster(3);
        c.create_topic("t", 1, 3).unwrap();
        c.set_broker_write_budget(0.0);
        let tp = TopicPartition::new("t", 0);
        let (base, throttle) = c.produce_throttled(&tp, &single(0, 1_000), 0).unwrap();
        assert_eq!(base, 0, "batches are still admitted (debt model)");
        assert_eq!(throttle, NEVER_US, "zero budget mutes the channel forever");
    }

    #[test]
    fn unknown_partition_errors() {
        let mut c = cluster(1);
        let tp = TopicPartition::new("nope", 0);
        assert!(c.produce(&tp, &single(1, 1)).is_err());
        assert!(c.fetch(&tp, 0, 10).is_err());
        assert_eq!(c.fetchable_bytes(&tp, 0), 0);
    }
}
