//! Consumer-group coordination: membership and partition assignment.
//!
//! §3.4: "partitions may have a maximum of one consumer. Thus an
//! application should divide a topic into at least as many partitions as
//! there are consumers in order to maximize parallelism." The group
//! coordinator enforces exactly that: it assigns every partition to at
//! most one member (range assignment, Kafka's default), and rebalances on
//! membership changes, bumping a generation counter so stale members can
//! be fenced.

use std::collections::BTreeMap;

use crate::broker::topic::TopicPartition;

/// Coordinates one consumer group over one topic.
pub struct GroupCoordinator {
    topic: String,
    partitions: u32,
    /// Member id -> assigned partitions. BTreeMap for deterministic
    /// assignment order.
    members: BTreeMap<u64, Vec<TopicPartition>>,
    generation: u64,
    pub rebalances: u64,
}

impl GroupCoordinator {
    pub fn new(topic: impl Into<String>, partitions: u32) -> Self {
        GroupCoordinator {
            topic: topic.into(),
            partitions,
            members: BTreeMap::new(),
            generation: 0,
            rebalances: 0,
        }
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// Add a member and rebalance. Returns the new generation.
    pub fn join(&mut self, member: u64) -> u64 {
        self.members.entry(member).or_default();
        self.rebalance();
        self.generation
    }

    /// Remove a member (consumer crash / shutdown) and rebalance.
    pub fn leave(&mut self, member: u64) -> u64 {
        if self.members.remove(&member).is_some() {
            self.rebalance();
        }
        self.generation
    }

    /// Current assignment for a member.
    pub fn assignment(&self, member: u64) -> &[TopicPartition] {
        self.members
            .get(&member)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Range assignment: sort partitions and members, then hand out
    /// contiguous ranges, earlier members receiving the remainder.
    fn rebalance(&mut self) {
        self.generation += 1;
        self.rebalances += 1;
        let n = self.members.len();
        if n == 0 {
            return;
        }
        let per = self.partitions as usize / n;
        let extra = self.partitions as usize % n;
        let mut next = 0u32;
        for (i, (_, assigned)) in self.members.iter_mut().enumerate() {
            let take = per + usize::from(i < extra);
            assigned.clear();
            for _ in 0..take {
                assigned.push(TopicPartition::new(self.topic.clone(), next));
                next += 1;
            }
        }
        debug_assert_eq!(next, self.partitions);
    }

    /// Invariant: every partition is assigned to exactly one member (when
    /// the group is non-empty).
    pub fn assignment_is_valid(&self) -> bool {
        if self.members.is_empty() {
            return true;
        }
        let mut seen = vec![false; self.partitions as usize];
        for parts in self.members.values() {
            for tp in parts {
                if tp.topic != self.topic || tp.partition >= self.partitions {
                    return false;
                }
                if seen[tp.partition as usize] {
                    return false; // double-assigned
                }
                seen[tp.partition as usize] = true;
            }
        }
        seen.iter().all(|&s| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_member_owns_everything() {
        let mut g = GroupCoordinator::new("faces", 6);
        g.join(1);
        assert_eq!(g.assignment(1).len(), 6);
        assert!(g.assignment_is_valid());
    }

    #[test]
    fn even_split() {
        let mut g = GroupCoordinator::new("faces", 6);
        g.join(1);
        g.join(2);
        g.join(3);
        for m in [1, 2, 3] {
            assert_eq!(g.assignment(m).len(), 2);
        }
        assert!(g.assignment_is_valid());
    }

    #[test]
    fn remainder_goes_to_early_members() {
        let mut g = GroupCoordinator::new("faces", 7);
        g.join(1);
        g.join(2);
        g.join(3);
        let sizes: Vec<usize> = [1, 2, 3].iter().map(|&m| g.assignment(m).len()).collect();
        assert_eq!(sizes, vec![3, 2, 2]);
        assert!(g.assignment_is_valid());
    }

    #[test]
    fn leave_triggers_rebalance() {
        let mut g = GroupCoordinator::new("faces", 4);
        g.join(1);
        g.join(2);
        let gen_before = g.generation();
        g.leave(1);
        assert!(g.generation() > gen_before);
        assert_eq!(g.assignment(2).len(), 4);
        assert_eq!(g.assignment(1).len(), 0);
        assert!(g.assignment_is_valid());
    }

    #[test]
    fn more_members_than_partitions() {
        let mut g = GroupCoordinator::new("faces", 2);
        for m in 1..=4 {
            g.join(m);
        }
        let total: usize = (1..=4).map(|m| g.assignment(m).len()).sum();
        assert_eq!(total, 2, "only 2 partitions to hand out");
        assert!(g.assignment_is_valid());
    }

    #[test]
    fn generation_fences_each_change() {
        let mut g = GroupCoordinator::new("faces", 4);
        let g1 = g.join(1);
        let g2 = g.join(2);
        let g3 = g.leave(2);
        assert!(g1 < g2 && g2 < g3);
    }

    #[test]
    fn assignment_valid_property() {
        crate::util::prop::check(200, |rng| {
            let partitions = 1 + rng.below(64) as u32;
            let mut g = GroupCoordinator::new("t", partitions);
            let mut members: Vec<u64> = Vec::new();
            for _ in 0..rng.below(30) {
                if members.is_empty() || rng.chance(0.6) {
                    let m = rng.next_u64();
                    members.push(m);
                    g.join(m);
                } else {
                    let i = rng.below(members.len() as u64) as usize;
                    g.leave(members.swap_remove(i));
                }
                if !g.assignment_is_valid() {
                    return Err(format!(
                        "invalid assignment at {} members, {} partitions",
                        g.member_count(),
                        partitions
                    ));
                }
            }
            Ok(())
        });
    }
}
