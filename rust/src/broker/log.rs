//! Partition log: an append-only sequence of record batches stored in
//! rolling segments ("partitions—open file handles", §3.4).
//!
//! Each append assigns consecutive *offsets* to the batch's records and
//! writes the framed batch to the active segment through a
//! [`StorageBackend`]. An in-memory index maps offsets to (segment,
//! position, length) so fetches are O(log n) lookups plus one backend read.

use anyhow::Result;

use crate::broker::record::RecordBatch;
use crate::storage::backend::StorageBackend;

/// Index entry for one appended batch.
#[derive(Clone, Debug)]
struct BatchIndex {
    base_offset: u64,
    count: u64,
    segment: u32,
    position: u64,
    length: u32,
}

/// An append-only partition log over a storage backend.
pub struct PartitionLog {
    /// Used to namespace segment files in the backend.
    name: String,
    /// Roll to a new segment after this many bytes (Kafka default 1 GiB;
    /// we default lower so tests exercise rolling).
    segment_bytes: u64,
    index: Vec<BatchIndex>,
    active_segment: u32,
    active_size: u64,
    next_offset: u64,
    bytes_appended: u64,
}

impl PartitionLog {
    pub fn new(name: impl Into<String>, segment_bytes: u64) -> Self {
        PartitionLog {
            name: name.into(),
            segment_bytes: segment_bytes.max(1),
            index: Vec::new(),
            active_segment: 0,
            active_size: 0,
            next_offset: 0,
            bytes_appended: 0,
        }
    }

    fn segment_file(&self, segment: u32) -> String {
        format!("{}.seg{:06}", self.name, segment)
    }

    /// Next offset to be assigned (== log end offset).
    pub fn end_offset(&self) -> u64 {
        self.next_offset
    }

    pub fn bytes(&self) -> u64 {
        self.bytes_appended
    }

    pub fn segments(&self) -> u32 {
        self.active_segment + 1
    }

    /// Append a batch; returns the base offset assigned to its first
    /// record. Empty batches are rejected (they would create unfetchable
    /// index entries).
    pub fn append(&mut self, backend: &mut dyn StorageBackend, batch: &RecordBatch) -> Result<u64> {
        anyhow::ensure!(!batch.is_empty(), "refusing to append an empty batch");
        let wire = batch.encode();
        self.append_encoded(backend, &wire, batch.len() as u64)
    }

    /// Append pre-encoded wire bytes (§Perf: replication appends the same
    /// framed batch to every ISR member; encoding once at the leader and
    /// sharing the bytes mirrors Kafka's zero-re-serialization design and
    /// removes two of the three encodes from the produce hot path).
    pub fn append_encoded(
        &mut self,
        backend: &mut dyn StorageBackend,
        wire: &[u8],
        count: u64,
    ) -> Result<u64> {
        anyhow::ensure!(count > 0, "refusing to append an empty batch");
        if self.active_size + wire.len() as u64 > self.segment_bytes && self.active_size > 0 {
            self.active_segment += 1;
            self.active_size = 0;
        }
        let file = self.segment_file(self.active_segment);
        let position = backend.append(&file, wire)?;
        let base_offset = self.next_offset;
        self.index.push(BatchIndex {
            base_offset,
            count,
            segment: self.active_segment,
            position,
            length: wire.len() as u32,
        });
        self.next_offset += count;
        self.active_size += wire.len() as u64;
        self.bytes_appended += wire.len() as u64;
        Ok(base_offset)
    }

    /// Read batches starting at `offset`, up to `max_bytes` of wire data.
    /// Returns the decoded batches and the next offset to fetch from.
    /// Always returns at least one batch if any data exists at or after
    /// `offset` (Kafka semantics: max_bytes is a soft limit so a large
    /// record can still be consumed).
    pub fn read(
        &self,
        backend: &mut dyn StorageBackend,
        offset: u64,
        max_bytes: usize,
    ) -> Result<(Vec<RecordBatch>, u64)> {
        let mut batches = Vec::new();
        let mut next = offset;
        let mut budget = max_bytes.min(i64::MAX as usize) as i64;
        // Binary search for the first batch containing `offset`.
        let start = self
            .index
            .partition_point(|b| b.base_offset + b.count <= offset);
        for entry in &self.index[start..] {
            if !batches.is_empty() && budget <= 0 {
                break;
            }
            let wire = backend.read(
                &self.segment_file(entry.segment),
                entry.position,
                entry.length as usize,
            )?;
            let batch = RecordBatch::decode(&wire)?;
            budget -= wire.len() as i64;
            next = entry.base_offset + entry.count;
            batches.push(batch);
        }
        Ok((batches, next.max(offset)))
    }

    /// Bytes available at or after `offset` (the `fetch.min.bytes` check).
    pub fn bytes_available_from(&self, offset: u64) -> u64 {
        let start = self
            .index
            .partition_point(|b| b.base_offset + b.count <= offset);
        self.index[start..].iter().map(|b| b.length as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::record::Record;
    use crate::storage::backend::MemBackend;

    fn rec(key: u64, bytes: usize) -> Record {
        Record::new(key, key * 1000, vec![key as u8; bytes])
    }

    fn single(key: u64, bytes: usize) -> RecordBatch {
        let mut b = RecordBatch::new();
        b.push(rec(key, bytes));
        b
    }

    #[test]
    fn offsets_are_consecutive() {
        let mut backend = MemBackend::new();
        let mut log = PartitionLog::new("faces-0", 1 << 20);
        let mut batch = RecordBatch::new();
        batch.push(rec(1, 10));
        batch.push(rec(2, 10));
        assert_eq!(log.append(&mut backend, &batch).unwrap(), 0);
        assert_eq!(log.append(&mut backend, &single(3, 10)).unwrap(), 2);
        assert_eq!(log.end_offset(), 3);
    }

    #[test]
    fn read_back_in_order() {
        let mut backend = MemBackend::new();
        let mut log = PartitionLog::new("faces-0", 1 << 20);
        for k in 0..10 {
            log.append(&mut backend, &single(k, 100)).unwrap();
        }
        let (batches, next) = log.read(&mut backend, 0, usize::MAX).unwrap();
        let keys: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.records.iter().map(|r| r.key))
            .collect();
        assert_eq!(keys, (0..10).collect::<Vec<_>>());
        assert_eq!(next, 10);
    }

    #[test]
    fn read_from_middle_offset() {
        let mut backend = MemBackend::new();
        let mut log = PartitionLog::new("p", 1 << 20);
        for k in 0..10 {
            log.append(&mut backend, &single(k, 10)).unwrap();
        }
        let (batches, next) = log.read(&mut backend, 7, usize::MAX).unwrap();
        let keys: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.records.iter().map(|r| r.key))
            .collect();
        assert_eq!(keys, vec![7, 8, 9]);
        assert_eq!(next, 10);
    }

    #[test]
    fn max_bytes_soft_limit() {
        let mut backend = MemBackend::new();
        let mut log = PartitionLog::new("p", 1 << 20);
        for k in 0..5 {
            log.append(&mut backend, &single(k, 1000)).unwrap();
        }
        // Tiny budget still returns one batch.
        let (batches, next) = log.read(&mut backend, 0, 1).unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(next, 1);
        // Budget for ~2 batches returns 2 (may over-return by one).
        let (batches, _) = log.read(&mut backend, 0, 2100).unwrap();
        assert!(batches.len() >= 2 && batches.len() <= 3);
    }

    #[test]
    fn segments_roll() {
        let mut backend = MemBackend::new();
        let mut log = PartitionLog::new("p", 2000);
        for k in 0..10 {
            log.append(&mut backend, &single(k, 900)).unwrap();
        }
        assert!(log.segments() > 1, "expected rolling, got 1 segment");
        // Data still fully readable across segments.
        let (batches, next) = log.read(&mut backend, 0, usize::MAX).unwrap();
        assert_eq!(batches.len(), 10);
        assert_eq!(next, 10);
    }

    #[test]
    fn bytes_available_tracks_offset() {
        let mut backend = MemBackend::new();
        let mut log = PartitionLog::new("p", 1 << 20);
        for k in 0..4 {
            log.append(&mut backend, &single(k, 50)).unwrap();
        }
        let all = log.bytes_available_from(0);
        let half = log.bytes_available_from(2);
        assert!(all > half && half > 0);
        assert_eq!(log.bytes_available_from(4), 0);
    }

    #[test]
    fn empty_batch_rejected() {
        let mut backend = MemBackend::new();
        let mut log = PartitionLog::new("p", 1 << 20);
        assert!(log.append(&mut backend, &RecordBatch::new()).is_err());
    }

    #[test]
    fn read_past_end_is_empty() {
        let mut backend = MemBackend::new();
        let mut log = PartitionLog::new("p", 1 << 20);
        log.append(&mut backend, &single(0, 10)).unwrap();
        let (batches, next) = log.read(&mut backend, 99, usize::MAX).unwrap();
        assert!(batches.is_empty());
        assert_eq!(next, 99);
    }

    #[test]
    fn fifo_order_property() {
        crate::util::prop::check(50, |rng| {
            let mut backend = MemBackend::new();
            let mut log = PartitionLog::new("p", 1 + rng.below(5000));
            let mut expected = Vec::new();
            let n = 1 + rng.below(50);
            let mut key = 0u64;
            for _ in 0..n {
                let mut b = RecordBatch::new();
                for _ in 0..1 + rng.below(5) {
                    b.push(rec(key, rng.below(200) as usize));
                    expected.push(key);
                    key += 1;
                }
                log.append(&mut backend, &b)
                    .map_err(|e| format!("append: {e}"))?;
            }
            let (batches, _) = log
                .read(&mut backend, 0, usize::MAX)
                .map_err(|e| format!("read: {e}"))?;
            let got: Vec<u64> = batches
                .iter()
                .flat_map(|b| b.records.iter().map(|r| r.key))
                .collect();
            crate::util::prop::assert_holds(got == expected, "per-partition FIFO order")
        });
    }
}
