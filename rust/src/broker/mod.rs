//! Kafka-like publish-subscribe broker substrate (§3.4).
//!
//! The paper's communication layer is Apache Kafka; every AI-tax finding
//! about waiting time, batching, replication and storage pressure flows
//! through its mechanisms. This module implements those mechanisms:
//!
//! * **topics** divided into **partitions** — open segment files — spread
//!   across brokers ([`topic`], [`log`]);
//! * partitions have a **leader** and replicated **followers**; producers
//!   and consumers talk to the leader; `acks=all` semantics gate produce
//!   completion on the in-sync replica set ([`partition`]);
//! * **producers** batch records per partition with a linger timer and a
//!   max batch size ([`producer`]);
//! * **consumers** fetch with `fetch.min.bytes` / `fetch.max.wait`
//!   semantics and are grouped: a partition has *at most one* consumer in
//!   a group, so an application needs at least as many partitions as
//!   consumers (§3.4) ([`consumer`], [`group`]);
//! * a **controller** assigns partitions to brokers and fails leaders over
//!   to followers when a broker dies ([`controller`]);
//! * per-tenant **QoS** — request-CPU scheduling classes and topic-level
//!   byte-rate quotas with Kafka-style mute-the-channel backpressure —
//!   lives in [`qos`]. The DES broker fabric enforces it on the virtual
//!   clock; the controller exposes the same bucket semantics wall-clock
//!   via `produce_throttled` (not yet wired into the live coordinator's
//!   produce path).
//!
//! The implementation is *real* — records are framed, checksummed, appended
//! to segment logs through a [`crate::storage::StorageBackend`], and read
//! back on fetch. The live pipeline (`coordinator`) runs it on threads with
//! real files; unit tests run it in-memory; the DES models its timing with
//! the same tuning parameters (`config::KafkaTuning`).

pub mod consumer;
pub mod controller;
pub mod group;
pub mod log;
pub mod partition;
pub mod producer;
pub mod qos;
pub mod record;
pub mod topic;

pub use consumer::{Consumer, FetchResult};
pub use controller::{BrokerId, Controller};
pub use group::GroupCoordinator;
pub use log::PartitionLog;
pub use partition::Partition;
pub use producer::Producer;
pub use qos::{QosPolicy, TenantQuota, TokenBucket, WeightedCpuScheduler};
pub use record::{Record, RecordBatch};
pub use topic::{Topic, TopicPartition};
