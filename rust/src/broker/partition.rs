//! Partition replication state: leader, followers, in-sync replicas and
//! the high watermark.
//!
//! §3.4: "Each partition has a 'leader' and, in the presence of
//! replication, some number of followers. After new data is written to a
//! leader partition it is replicated to the followers. ... In the event of
//! a broker failure, one of the follower partitions will become the new
//! leader partition."
//!
//! We implement `acks=all` produce semantics: a produce completes when the
//! leader *and* every in-sync follower have appended the batch; the high
//! watermark (offset visible to consumers) advances to the minimum log end
//! offset across the ISR. This is the data-reliability safeguard whose
//! storage cost (3x write amplification) drives the paper's §5.4 findings.

use anyhow::Result;

use crate::broker::log::PartitionLog;
use crate::broker::record::RecordBatch;
use crate::broker::topic::TopicPartition;
use crate::storage::backend::StorageBackend;

/// Maps broker ids to their storage backends during a replicated produce.
pub trait BackendProvider {
    fn backend(&mut self, broker: u32) -> &mut dyn StorageBackend;
}

impl BackendProvider for std::collections::HashMap<u32, Box<dyn StorageBackend>> {
    fn backend(&mut self, broker: u32) -> &mut dyn StorageBackend {
        self.get_mut(&broker)
            .expect("backend registered for broker")
            .as_mut()
    }
}

/// Replica role + log for one partition on one broker.
pub struct Replica {
    pub broker: u32,
    pub log: PartitionLog,
}

/// A partition with its full replica set. In the live runtime each replica
/// lives on a different broker thread; this struct holds the shared
/// metadata and, in the in-process mode, the replica logs themselves.
pub struct Partition {
    pub tp: TopicPartition,
    /// Broker ids hosting replicas; `replicas[leader_idx]` is the leader.
    pub replicas: Vec<Replica>,
    leader_idx: usize,
    /// In-sync replica flags (parallel to `replicas`).
    in_sync: Vec<bool>,
    /// Offset below which data is replicated to the full ISR and visible
    /// to consumers.
    high_watermark: u64,
    epoch: u64,
}

impl Partition {
    pub fn new(tp: TopicPartition, brokers: &[u32], segment_bytes: u64) -> Self {
        assert!(!brokers.is_empty());
        let replicas = brokers
            .iter()
            .map(|&b| Replica {
                broker: b,
                log: PartitionLog::new(format!("b{}-{}", b, tp.log_name()), segment_bytes),
            })
            .collect::<Vec<_>>();
        let n = brokers.len();
        Partition {
            tp,
            replicas,
            leader_idx: 0,
            in_sync: vec![true; n],
            high_watermark: 0,
            epoch: 0,
        }
    }

    pub fn leader_broker(&self) -> u32 {
        self.replicas[self.leader_idx].broker
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn high_watermark(&self) -> u64 {
        self.high_watermark
    }

    pub fn isr_size(&self) -> usize {
        self.in_sync.iter().filter(|&&s| s).count()
    }

    /// Append through the leader and replicate to all in-sync followers
    /// (`acks=all`). Returns the base offset. Every ISR member performs a
    /// real backend append — the 3x storage amplification is real.
    pub fn produce(
        &mut self,
        backends: &mut dyn BackendProvider,
        batch: &RecordBatch,
    ) -> Result<u64> {
        anyhow::ensure!(!batch.is_empty(), "refusing to produce an empty batch");
        // Encode once; leader and followers append the same framed bytes
        // (Kafka never re-serializes for replication).
        let wire = batch.encode();
        let count = batch.len() as u64;
        let leader = self.leader_idx;
        let base = {
            let r = &mut self.replicas[leader];
            r.log.append_encoded(backends.backend(r.broker), &wire, count)?
        };
        for i in 0..self.replicas.len() {
            if i != leader && self.in_sync[i] {
                let r = &mut self.replicas[i];
                let follower_base =
                    r.log.append_encoded(backends.backend(r.broker), &wire, count)?;
                debug_assert_eq!(follower_base, base, "follower log diverged");
            }
        }
        self.advance_high_watermark();
        Ok(base)
    }

    fn advance_high_watermark(&mut self) {
        let min_end = self
            .replicas
            .iter()
            .zip(&self.in_sync)
            .filter(|(_, &sync)| sync)
            .map(|(r, _)| r.log.end_offset())
            .min()
            .unwrap_or(0);
        debug_assert!(min_end >= self.high_watermark, "high watermark regressed");
        self.high_watermark = min_end;
    }

    /// Fetch from the leader at `offset`, bounded by the high watermark
    /// (consumers never see unreplicated data).
    pub fn fetch(
        &self,
        backend: &mut dyn StorageBackend,
        offset: u64,
        max_bytes: usize,
    ) -> Result<(Vec<RecordBatch>, u64)> {
        if offset >= self.high_watermark {
            return Ok((Vec::new(), offset));
        }
        self.replicas[self.leader_idx]
            .log
            .read(backend, offset, max_bytes)
    }

    /// Bytes fetchable at `offset` (respecting the high watermark — data
    /// beyond it is invisible, so it can't satisfy `fetch.min.bytes`).
    pub fn fetchable_bytes(&self, offset: u64) -> u64 {
        if offset >= self.high_watermark {
            return 0;
        }
        self.replicas[self.leader_idx].log.bytes_available_from(offset)
    }

    /// Handle a broker failure: drop it from the ISR; if it led this
    /// partition, promote the first surviving in-sync follower. Returns
    /// true if leadership changed.
    pub fn broker_failed(&mut self, broker: u32) -> bool {
        let mut changed = false;
        for (i, r) in self.replicas.iter().enumerate() {
            if r.broker == broker {
                self.in_sync[i] = false;
            }
        }
        if self.replicas[self.leader_idx].broker == broker {
            if let Some(new_leader) = (0..self.replicas.len()).find(|&i| self.in_sync[i]) {
                self.leader_idx = new_leader;
                self.epoch += 1;
                changed = true;
            }
        }
        // HW may advance now that the failed replica no longer gates it.
        if self.isr_size() > 0 {
            self.advance_high_watermark();
        }
        changed
    }

    /// Follower-is-prefix-of-leader invariant (used by property tests).
    pub fn followers_are_prefixes(&self) -> bool {
        let leader_end = self.replicas[self.leader_idx].log.end_offset();
        self.replicas
            .iter()
            .zip(&self.in_sync)
            .all(|(r, &sync)| !sync || r.log.end_offset() <= leader_end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::record::Record;
    use crate::storage::backend::MemBackend;
    use std::collections::HashMap;

    struct Cluster {
        backends: HashMap<u32, MemBackend>,
    }

    impl Cluster {
        fn new(brokers: &[u32]) -> Self {
            Cluster {
                backends: brokers.iter().map(|&b| (b, MemBackend::new())).collect(),
            }
        }
    }

    impl super::BackendProvider for Cluster {
        fn backend(&mut self, broker: u32) -> &mut dyn StorageBackend {
            self.backends.get_mut(&broker).unwrap()
        }
    }

    fn single(key: u64) -> RecordBatch {
        let mut b = RecordBatch::new();
        b.push(Record::new(key, key, vec![0u8; 64]));
        b
    }

    fn produce(p: &mut Partition, c: &mut Cluster, key: u64) -> u64 {
        p.produce(c, &single(key)).unwrap()
    }

    #[test]
    fn replication_to_all_isr() {
        let mut c = Cluster::new(&[0, 1, 2]);
        let mut p = Partition::new(TopicPartition::new("faces", 0), &[0, 1, 2], 1 << 20);
        produce(&mut p, &mut c, 1);
        produce(&mut p, &mut c, 2);
        assert_eq!(p.high_watermark(), 2);
        for r in &p.replicas {
            assert_eq!(r.log.end_offset(), 2);
        }
        assert!(p.followers_are_prefixes());
    }

    #[test]
    fn consumers_gated_by_high_watermark() {
        let mut c = Cluster::new(&[0, 1, 2]);
        let mut p = Partition::new(TopicPartition::new("faces", 0), &[0, 1, 2], 1 << 20);
        produce(&mut p, &mut c, 1);
        let leader = p.leader_broker();
        let backend = c.backends.get_mut(&leader).unwrap();
        let (batches, next) = p.fetch(backend, 0, usize::MAX).unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(next, 1);
        // Nothing beyond the HW.
        let (batches, _) = p.fetch(backend, 1, usize::MAX).unwrap();
        assert!(batches.is_empty());
    }

    #[test]
    fn leader_failover_promotes_follower() {
        let mut c = Cluster::new(&[0, 1, 2]);
        let mut p = Partition::new(TopicPartition::new("faces", 0), &[0, 1, 2], 1 << 20);
        produce(&mut p, &mut c, 1);
        let old_leader = p.leader_broker();
        let old_epoch = p.epoch();
        assert!(p.broker_failed(old_leader));
        assert_ne!(p.leader_broker(), old_leader);
        assert_eq!(p.epoch(), old_epoch + 1);
        assert_eq!(p.isr_size(), 2);
        // Data survives: new leader serves the old record.
        let leader = p.leader_broker();
        let backend = c.backends.get_mut(&leader).unwrap();
        let (batches, _) = p.fetch(backend, 0, usize::MAX).unwrap();
        assert_eq!(batches.len(), 1);
    }

    #[test]
    fn follower_failure_no_leader_change() {
        let mut c = Cluster::new(&[0, 1, 2]);
        let mut p = Partition::new(TopicPartition::new("faces", 0), &[0, 1, 2], 1 << 20);
        produce(&mut p, &mut c, 1);
        let leader = p.leader_broker();
        let follower = p.replicas.iter().find(|r| r.broker != leader).unwrap().broker;
        assert!(!p.broker_failed(follower));
        assert_eq!(p.leader_broker(), leader);
        assert_eq!(p.isr_size(), 2);
        // Produce still works with the reduced ISR.
        produce(&mut p, &mut c, 2);
        assert_eq!(p.high_watermark(), 2);
    }

    #[test]
    fn replica_consistency_property() {
        crate::util::prop::check(50, |rng| {
            let brokers = [0u32, 1, 2];
            let mut c = Cluster::new(&brokers);
            let mut p = Partition::new(TopicPartition::new("t", 0), &brokers, 4096);
            let mut produced = 0u64;
            for _ in 0..rng.below(40) {
                if rng.chance(0.9) {
                    produce(&mut p, &mut c, produced);
                    produced += 1;
                } else if p.isr_size() > 1 {
                    p.broker_failed(rng.below(3) as u32);
                }
                if !p.followers_are_prefixes() {
                    return Err("follower ahead of leader".into());
                }
                if p.high_watermark() > produced {
                    return Err("HW beyond produced data".into());
                }
            }
            Ok(())
        });
    }
}
