//! Producer client with linger batching.
//!
//! §5.5: "A message from a producer can be held in the producer for a
//! small amount of time until a larger group of messages has been
//! accumulated to be sent as a batch." That hold time is the *linger*; a
//! batch is also shipped early when it reaches `batch_max_bytes`. Both
//! behaviors are the first component of the paper's broker waiting time.
//!
//! The producer is time-driven (callers pass `now`) so the identical code
//! serves the live runtime (wall-clock microseconds) and the DES (virtual
//! microseconds).

use crate::broker::record::{Record, RecordBatch};
use crate::broker::topic::TopicPartition;
use crate::config::KafkaTuning;

/// A batch ready to ship to a partition leader.
#[derive(Debug)]
pub struct ReadyBatch {
    pub tp: TopicPartition,
    pub batch: RecordBatch,
    /// When the oldest record in the batch was appended (for wait-time
    /// accounting).
    pub opened_at_us: u64,
}

struct Pending {
    batch: RecordBatch,
    opened_at_us: u64,
}

/// Partition-batching producer.
pub struct Producer {
    topic: String,
    partitions: u32,
    tuning: KafkaTuning,
    /// Round-robin cursor for records without key affinity.
    rr: u32,
    pending: std::collections::HashMap<u32, Pending>,
    pub records_sent: u64,
    pub batches_sent: u64,
    pub bytes_sent: u64,
}

impl Producer {
    pub fn new(topic: impl Into<String>, partitions: u32, tuning: KafkaTuning) -> Self {
        assert!(partitions > 0);
        Producer {
            topic: topic.into(),
            partitions,
            tuning,
            rr: 0,
            pending: Default::default(),
            records_sent: 0,
            batches_sent: 0,
            bytes_sent: 0,
        }
    }

    /// Choose a partition: sticky round-robin (Kafka's default partitioner
    /// for unkeyed records spreads batches across partitions).
    fn next_partition(&mut self) -> u32 {
        let p = self.rr % self.partitions;
        self.rr = self.rr.wrapping_add(1);
        p
    }

    /// Append a record to its partition's open batch. Returns a batch if
    /// this record filled one up (size-triggered send).
    pub fn send(&mut self, record: Record, now: u64) -> Option<ReadyBatch> {
        let p = self.next_partition();
        let entry = self.pending.entry(p).or_insert_with(|| Pending {
            batch: RecordBatch::new(),
            opened_at_us: now,
        });
        if entry.batch.is_empty() {
            entry.opened_at_us = now;
        }
        entry.batch.push(record);
        if entry.batch.payload_bytes() >= self.tuning.batch_max_bytes {
            return self.take(p);
        }
        None
    }

    /// Collect batches whose linger has expired.
    pub fn poll(&mut self, now: u64) -> Vec<ReadyBatch> {
        let expired: Vec<u32> = self
            .pending
            .iter()
            .filter(|(_, pend)| {
                !pend.batch.is_empty() && now >= pend.opened_at_us + self.tuning.linger_us
            })
            .map(|(&p, _)| p)
            .collect();
        expired.into_iter().filter_map(|p| self.take(p)).collect()
    }

    /// Flush everything regardless of linger (shutdown path).
    pub fn flush(&mut self) -> Vec<ReadyBatch> {
        let parts: Vec<u32> = self
            .pending
            .iter()
            .filter(|(_, pend)| !pend.batch.is_empty())
            .map(|(&p, _)| p)
            .collect();
        parts.into_iter().filter_map(|p| self.take(p)).collect()
    }

    /// Earliest deadline at which `poll` would release a batch.
    pub fn next_deadline(&self) -> Option<u64> {
        self.pending
            .values()
            .filter(|p| !p.batch.is_empty())
            .map(|p| p.opened_at_us + self.tuning.linger_us)
            .min()
    }

    fn take(&mut self, p: u32) -> Option<ReadyBatch> {
        let pend = self.pending.remove(&p)?;
        if pend.batch.is_empty() {
            return None;
        }
        self.records_sent += pend.batch.len() as u64;
        self.batches_sent += 1;
        self.bytes_sent += pend.batch.wire_size() as u64;
        Some(ReadyBatch {
            tp: TopicPartition::new(self.topic.clone(), p),
            batch: pend.batch,
            opened_at_us: pend.opened_at_us,
        })
    }

    pub fn pending_records(&self) -> usize {
        self.pending.values().map(|p| p.batch.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tuning(linger_us: u64, batch_max: usize) -> KafkaTuning {
        KafkaTuning {
            linger_us,
            batch_max_bytes: batch_max,
            ..KafkaTuning::default()
        }
    }

    fn rec(bytes: usize) -> Record {
        Record::new(0, 0, vec![0u8; bytes])
    }

    #[test]
    fn linger_holds_then_releases() {
        let mut p = Producer::new("faces", 1, tuning(10_000, usize::MAX));
        assert!(p.send(rec(100), 0).is_none());
        assert!(p.poll(5_000).is_empty(), "released before linger expired");
        let ready = p.poll(10_000);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].batch.len(), 1);
        assert_eq!(ready[0].opened_at_us, 0);
    }

    #[test]
    fn size_trigger_sends_early() {
        let mut p = Producer::new("faces", 1, tuning(1_000_000, 250));
        assert!(p.send(rec(100), 0).is_none());
        assert!(p.send(rec(100), 1).is_none());
        let ready = p.send(rec(100), 2);
        assert!(ready.is_some(), "300 bytes >= 250 threshold");
        assert_eq!(ready.unwrap().batch.len(), 3);
        assert_eq!(p.pending_records(), 0);
    }

    #[test]
    fn round_robin_spreads_partitions() {
        let mut p = Producer::new("faces", 4, tuning(0, usize::MAX));
        for i in 0..8 {
            p.send(rec(10), i);
        }
        let ready = p.poll(1_000_000);
        assert_eq!(ready.len(), 4, "all four partitions got batches");
        for r in &ready {
            assert_eq!(r.batch.len(), 2);
        }
    }

    #[test]
    fn batch_accumulates_multiple_records() {
        let mut p = Producer::new("faces", 1, tuning(50_000, usize::MAX));
        for i in 0..10 {
            assert!(p.send(rec(10), i * 1000).is_none());
        }
        let ready = p.poll(50_000);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].batch.len(), 10);
        // Linger measured from the first record.
        assert_eq!(ready[0].opened_at_us, 0);
    }

    #[test]
    fn flush_releases_everything() {
        let mut p = Producer::new("faces", 3, tuning(1_000_000, usize::MAX));
        for i in 0..6 {
            p.send(rec(10), i);
        }
        let flushed = p.flush();
        let total: usize = flushed.iter().map(|b| b.batch.len()).sum();
        assert_eq!(total, 6);
        assert_eq!(p.pending_records(), 0);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut p = Producer::new("faces", 2, tuning(10_000, usize::MAX));
        assert_eq!(p.next_deadline(), None);
        p.send(rec(10), 500);
        p.send(rec(10), 900);
        assert_eq!(p.next_deadline(), Some(10_500));
    }

    #[test]
    fn stats_accumulate() {
        let mut p = Producer::new("faces", 1, tuning(0, usize::MAX));
        p.send(rec(100), 0);
        p.poll(0);
        assert_eq!(p.records_sent, 1);
        assert_eq!(p.batches_sent, 1);
        assert!(p.bytes_sent > 100);
    }

    #[test]
    fn no_record_lost_property() {
        crate::util::prop::check(100, |rng| {
            let parts = 1 + rng.below(8) as u32;
            let mut p = Producer::new(
                "t",
                parts,
                tuning(rng.below(20_000), 1 + rng.below(4096) as usize),
            );
            let n = rng.below(200);
            let mut released = 0usize;
            let mut now = 0;
            for _ in 0..n {
                now += rng.below(1000);
                if let Some(b) = p.send(rec(rng.below(512) as usize), now) {
                    released += b.batch.len();
                }
                for b in p.poll(now) {
                    released += b.batch.len();
                }
            }
            for b in p.flush() {
                released += b.batch.len();
            }
            crate::util::prop::assert_holds(
                released == n as usize && p.pending_records() == 0,
                &format!("released {released} != sent {n}"),
            )
        });
    }
}
