//! Per-tenant broker QoS: scheduling classes and topic quotas (§8).
//!
//! The paper's mitigation discussion (Sec. 8 / Fig. 15) adds hardware —
//! drives and brokers — to push the saturation point out. This module adds
//! the *software* mitigation a real multi-tenant deployment reaches for
//! first: isolation at the broker, so that one tenant's acceleration does
//! not become every other tenant's broker wait. Two mechanisms, mirroring
//! Kafka's own request-quota machinery:
//!
//! * **Scheduling classes** ([`WeightedCpuScheduler`]) — the broker's
//!   request-handling CPU stops being a single FIFO and becomes a
//!   weighted queue: each tenant maps to a class with a weight, and under
//!   contention class `i` receives a `w_i / Σw` share of the request
//!   CPU. The implementation is the fluid (generalized-processor-sharing)
//!   limit of deficit-weighted round robin: backlogs drain concurrently
//!   in proportion to weight, with idle classes' shares redistributed to
//!   the busy ones, so the scheduler stays work-conserving.
//! * **Topic quotas** ([`TokenBucket`]) — per-tenant produce and fetch
//!   byte-rate caps, enforced Kafka-style: a request is never rejected,
//!   it is *admitted and the channel muted* for the time it takes the
//!   bucket to pay the debt back (`charge` returns that throttle delay).
//!   Producers see it as delayed dispatch, consumers as a muted poll
//!   loop — backpressure, not loss.
//!
//! PR 4 pushed the same discipline down the write path:
//! [`QosPolicy::storage_weights`] installs the GPS-fluid scheduler
//! (extracted to [`WeightedServer`]) on every broker's NVMe write queue,
//! and [`TenantQuota::replication_aware`] switches a produce bucket to
//! write-path-byte accounting (`bytes × RF` per record) — optionally
//! derived from an operator's per-broker write budget via
//! [`write_budget_per_tenant_rate`].
//!
//! [`QosPolicy`] bundles all of it per tenant. The policy is strictly
//! opt-in: with no policy installed the broker fabric and the deployment
//! layer behave bit-for-bit as before (the FIFO request CPU, the FIFO
//! write queue, no buckets), which `tests/qos_regression.rs` and
//! `tests/storage_qos_differential.rs` pin.
//!
//! The DES ([`crate::pipeline::fabric`], [`crate::pipeline::dc`]) uses
//! these types on the virtual clock; the in-process broker
//! ([`crate::broker::controller`]) reuses [`TokenBucket`] for its
//! wall-clock topic quotas.

use crate::sim::resource::WeightedServer;

/// Throttle delay returned when a bucket can never admit the request
/// (zero or negative quota rate). Far beyond any simulation horizon but
/// small enough that `now + NEVER_US` cannot overflow `u64`.
pub const NEVER_US: u64 = u64::MAX / 8;

/// Byte-rate token bucket with Kafka's debt semantics.
///
/// `charge(now, bytes)` always admits the request, decrementing the
/// token balance (possibly below zero), and returns how long the caller
/// must stay muted until the balance would return to zero. Steady-state
/// throughput therefore equals the configured rate regardless of burst
/// size, and a single oversized request cannot starve forever — it just
/// pays a proportional delay.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    /// Sustained rate in bytes per second; `<= 0` admits nothing.
    rate: f64,
    /// Maximum accumulated credit (bytes).
    burst: f64,
    /// Current balance; negative means debt being paid down at `rate`.
    tokens: f64,
    last_us: u64,
}

impl TokenBucket {
    pub fn new(rate_bytes_per_sec: f64, burst_bytes: f64) -> Self {
        let burst = burst_bytes.max(0.0);
        TokenBucket {
            rate: rate_bytes_per_sec,
            burst,
            tokens: burst,
            last_us: 0,
        }
    }

    /// Bucket with the default burst of 200 ms worth of rate.
    pub fn with_default_burst(rate_bytes_per_sec: f64) -> Self {
        Self::new(rate_bytes_per_sec, (rate_bytes_per_sec * 0.2).max(0.0))
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    fn refill(&mut self, now: u64) {
        if now > self.last_us {
            if self.rate > 0.0 {
                let credit = (now - self.last_us) as f64 * self.rate / 1e6;
                self.tokens = (self.tokens + credit).min(self.burst);
            }
            self.last_us = now;
        }
    }

    /// Admit `bytes` at `now`; returns the throttle delay in µs (0 when
    /// within quota). [`NEVER_US`] when the rate is non-positive.
    ///
    /// **Aggregate charging** (PR 6): a flow-aggregated producer charges
    /// one macro-record of `k × b` bytes where a per-record producer
    /// would charge `k` records of `b` bytes at the same instant. The
    /// two are equivalent by construction — refill happens once per
    /// distinct `now`, and the balance decrement is a plain sum — so a
    /// quota binds identically whether the tenant's bytes arrive one
    /// record or one macro-record at a time
    /// (`aggregate_charge_equals_same_instant_sub_charges` pins this).
    pub fn charge(&mut self, now: u64, bytes: f64) -> u64 {
        self.refill(now);
        if self.rate <= 0.0 {
            return NEVER_US;
        }
        self.tokens -= bytes;
        if self.tokens >= 0.0 {
            0
        } else {
            ((-self.tokens) / self.rate * 1e6).ceil() as u64
        }
    }

    /// Current balance (diagnostics; negative = debt in bytes).
    pub fn balance(&self) -> f64 {
        self.tokens
    }
}

/// Work-conserving weighted scheduler for the broker request CPU — the
/// fluid limit of a deficit-weighted round-robin queue.
///
/// Per-class backlogs (µs-of-work units, like
/// [`FifoServer`](crate::sim::resource::FifoServer)) drain concurrently:
/// while classes `A = {i : backlog_i > 0}` are active, class `i` drains
/// at `rate · w_i / Σ_{j∈A} w_j`. A submission's completion time is the
/// instant its class's backlog reaches zero assuming no further arrivals
/// — the same open-loop approximation `FifoServer` makes, so the two are
/// directly substitutable in the fabric.
///
/// The GPS-fluid core lives in [`WeightedServer`] (PR 4 extracted it so
/// the NVMe write path could reuse the identical discipline — see
/// [`crate::storage::device::StorageDevice::enable_write_qos`]); this
/// type is the request-CPU instantiation with zero device latency.
#[derive(Clone, Debug)]
pub struct WeightedCpuScheduler {
    inner: WeightedServer,
}

impl WeightedCpuScheduler {
    pub fn new(rate_per_sec: f64, weights: &[f64]) -> Self {
        WeightedCpuScheduler {
            inner: WeightedServer::new(rate_per_sec, 0, weights),
        }
    }

    pub fn classes(&self) -> usize {
        self.inner.classes()
    }

    /// Submit `work` units of class `class` at `now`; returns the
    /// completion time in µs. Classes out of range share the last class.
    pub fn submit(&mut self, now: u64, class: usize, work: f64) -> u64 {
        self.inner.submit(now, class, work)
    }

    /// Fraction of `[0, now]` the scheduler was busy (unclamped; >1 under
    /// overload, matching `FifoServer::utilization`).
    pub fn utilization(&self, now: u64) -> f64 {
        self.inner.utilization(now)
    }
}

/// Per-tenant quota settings (all optional; `None` = uncapped).
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantQuota {
    /// Produce-side byte-rate cap (producer → broker), bytes/sec.
    pub produce_bytes_per_sec: Option<f64>,
    /// Fetch-side byte-rate cap (broker → consumer), bytes/sec.
    pub fetch_bytes_per_sec: Option<f64>,
    /// Token-bucket burst; defaults to 200 ms of the rate.
    pub burst_bytes: Option<f64>,
    /// **Replication-aware accounting**: when set, the produce bucket is
    /// denominated in *write-path* bytes — the dispatch hook charges
    /// `bytes × replication` per record, so a tenant on an RF=3 topic
    /// spends its budget 3× as fast as one on RF=1, which is what the
    /// admitted bytes actually cost the shared NVMe write path. When
    /// unset (the default, and the pre-PR-4 behavior) the bucket meters
    /// client bytes as Kafka's own quotas do.
    pub replication_aware: bool,
}

impl TenantQuota {
    fn bucket(rate: Option<f64>, burst: Option<f64>) -> Option<TokenBucket> {
        rate.map(|r| match burst {
            Some(b) => TokenBucket::new(r, b),
            None => TokenBucket::with_default_burst(r),
        })
    }

    pub fn produce_bucket(&self) -> Option<TokenBucket> {
        Self::bucket(self.produce_bytes_per_sec, self.burst_bytes)
    }

    pub fn fetch_bucket(&self) -> Option<TokenBucket> {
        Self::bucket(self.fetch_bytes_per_sec, self.burst_bytes)
    }
}

/// The broker QoS policy for one multi-tenant world. Class `i` governs
/// tenant `i` (registration order in the tenant registry).
#[derive(Clone, Debug, Default)]
pub struct QosPolicy {
    /// Request-CPU scheduling-class weights, one per tenant. `None`
    /// keeps the FIFO request CPU (quotas can still apply).
    pub cpu_weights: Option<Vec<f64>>,
    /// NVMe write-path scheduling-class weights, one per tenant. `None`
    /// keeps the FIFO write queue (the default; bit-identical to the
    /// pre-QoS device). When set, every broker's storage device serves
    /// write submissions with the same GPS-fluid discipline as the
    /// request CPU, so a latency tenant's small appends no longer queue
    /// behind a bulk tenant's 1 MB batches (head-of-line blocking, the
    /// residual interference quotas alone cannot remove).
    pub storage_weights: Option<Vec<f64>>,
    /// Per-tenant quotas, one per tenant (missing entries = uncapped).
    pub quotas: Vec<TenantQuota>,
}

impl QosPolicy {
    /// Quota for tenant `t` (default uncapped when not listed).
    pub fn quota(&self, t: usize) -> TenantQuota {
        self.quotas.get(t).copied().unwrap_or_default()
    }
}

/// Translate an operator's **per-broker write budget** into the
/// per-tenant produce rate of a replication-aware bucket.
///
/// `budget × brokers` is the cluster-wide write-path byte budget; divided
/// evenly across `tenants` it is the write-path rate each tenant's bucket
/// may admit. Pair the result with
/// [`TenantQuota::replication_aware`]` = true` so the bucket spends
/// `bytes × RF` per record and the budget means device bytes, not client
/// bytes — the translation the DES registry
/// (`pipeline::mixed::MultiTenantConfig::with_broker_write_budget`) and
/// the wall-clock controller
/// ([`crate::broker::controller::Controller::set_broker_write_budget`])
/// both use.
pub fn write_budget_per_tenant_rate(
    budget_per_broker_bytes_per_sec: f64,
    brokers: usize,
    tenants: usize,
) -> f64 {
    if tenants == 0 {
        return 0.0;
    }
    budget_per_broker_bytes_per_sec * brokers as f64 / tenants as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_admits_within_rate() {
        let mut b = TokenBucket::new(1_000_000.0, 100_000.0); // 1 MB/s
        assert_eq!(b.charge(0, 50_000.0), 0);
        // Burst exhausted: 100 kB more at t=0 puts us 50 kB in debt
        // → 50 ms to pay back at 1 MB/s.
        let d = b.charge(0, 100_000.0);
        assert_eq!(d, 50_000);
        // After the debt is paid the bucket admits again.
        assert_eq!(b.charge(60_000, 10_000.0), 0);
    }

    #[test]
    fn bucket_steady_state_rate_is_the_quota() {
        // Offer 10× the quota for one virtual second; the cumulative
        // throttle of the last charge must defer it to ~10 s.
        let mut b = TokenBucket::new(1_000_000.0, 0.0);
        let mut last_delay = 0;
        for i in 0..100u64 {
            last_delay = b.charge(i * 10_000, 100_000.0);
        }
        let done = 99 * 10_000 + last_delay;
        assert!(
            (9_000_000..=11_000_000).contains(&done),
            "10 MB through a 1 MB/s bucket must take ~10 s, got {done}"
        );
    }

    #[test]
    fn aggregate_charge_equals_same_instant_sub_charges() {
        // The flow-producer contract: one macro charge of k·b bytes at
        // instant t leaves the bucket in the same state as k per-record
        // charges of b bytes at t. Exercise across refill boundaries and
        // into debt. b = 4096 keeps every partial sum exactly
        // representable, so the balances match to the bit.
        let mk = || TokenBucket::new(2_000_000.0, 262_144.0);
        let (mut agg, mut per) = (mk(), mk());
        for (t, k) in [(0u64, 16u64), (25_000, 64), (50_000, 512), (250_000, 3)] {
            let b = 4096.0;
            let d_agg = agg.charge(t, k as f64 * b);
            let mut d_per = 0;
            for _ in 0..k {
                d_per = per.charge(t, b);
            }
            assert_eq!(
                agg.balance().to_bits(),
                per.balance().to_bits(),
                "balances diverged at t={t} k={k}"
            );
            assert_eq!(d_agg, d_per, "throttle diverged at t={t} k={k}");
        }
    }

    #[test]
    fn zero_rate_never_admits() {
        let mut b = TokenBucket::new(0.0, 0.0);
        assert_eq!(b.charge(5, 1.0), NEVER_US);
        assert_eq!(b.charge(1_000_000, 1.0), NEVER_US);
    }

    #[test]
    fn wfq_single_class_matches_fifo_rate() {
        // One class: GPS degenerates to a plain rate server.
        let mut s = WeightedCpuScheduler::new(1e6, &[1.0]);
        assert_eq!(s.submit(0, 0, 500.0), 500);
        assert_eq!(s.submit(0, 0, 500.0), 1000);
        assert!((s.utilization(1000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn heavy_class_cannot_starve_light_class() {
        // Rate 1e6 units/s = 1 unit/µs. Class 0 (weight 1) dumps 1 s of
        // work; class 1 (weight 9) then submits a small request and must
        // see near-isolated service: it gets 90% of the CPU.
        let mut s = WeightedCpuScheduler::new(1e6, &[1.0, 9.0]);
        let t_heavy = s.submit(0, 0, 1_000_000.0);
        let t_light = s.submit(0, 1, 900.0);
        // Light class drains at 0.9 units/µs while the heavy backlog is
        // active: 900 units take 1000 µs.
        assert_eq!(t_light, 1000);
        // The heavy class loses exactly the light class's share and
        // finishes later than alone (1_000_000), not earlier.
        assert!(t_heavy >= 1_000_000, "t_heavy={t_heavy}");
        // A FIFO would have made the light request wait the full second.
        assert!(t_light < 10_000);
    }

    #[test]
    fn wfq_work_conserving_after_class_empties() {
        let mut s = WeightedCpuScheduler::new(1e6, &[1.0, 1.0]);
        // Completion is open-loop: the first submission sees only its
        // own backlog (500 ms); the second sees both and lands at 1 s.
        assert_eq!(s.submit(0, 0, 500_000.0), 500_000);
        assert_eq!(s.submit(0, 1, 500_000.0), 1_000_000);
        // By t=1s all 1e6 units of backlog have drained; a later arrival
        // on class 0 alone gets the full rate immediately.
        let t = s.submit(1_000_000, 0, 100.0);
        assert_eq!(t, 1_000_100);
    }

    #[test]
    fn wfq_redistributes_share_when_peer_finishes() {
        // Class 0: 100 units, then class 1: 1000 units, equal weights,
        // rate 1 unit/µs. From class 1's view: equal shares (0.5/µs)
        // until class 0 empties at t=200 (100 units each), then the full
        // rate for the remaining 900 → finishes at 1100, not 2000.
        let mut s = WeightedCpuScheduler::new(1e6, &[1.0, 1.0]);
        let _ = s.submit(0, 0, 100.0);
        let t1 = s.submit(0, 1, 1000.0);
        assert_eq!(t1, 1100);
    }

    #[test]
    fn policy_defaults_are_uncapped() {
        let p = QosPolicy::default();
        assert!(p.cpu_weights.is_none());
        assert!(p.storage_weights.is_none());
        assert!(p.quota(3).produce_bucket().is_none());
        assert!(p.quota(0).fetch_bucket().is_none());
        assert!(!p.quota(0).replication_aware);
    }

    #[test]
    fn write_budget_translation_divides_cluster_capacity() {
        // 300 MB/s per broker × 3 brokers = 900 MB/s cluster write
        // budget; 3 tenants get 300 MB/s of write-path bytes each.
        let rate = write_budget_per_tenant_rate(300e6, 3, 3);
        assert!((rate - 300e6).abs() < 1e-6);
        // On an RF=3 topic a replication-aware bucket at that rate admits
        // 100 MB/s of *client* bytes: 3 s of budget pays for 1 s of
        // client traffic.
        let mut b = TokenBucket::new(rate, 0.0);
        let throttle = b.charge(0, 300e6 * 3.0); // 300 MB client × RF 3
        assert_eq!(throttle, 3_000_000);
        // Degenerate cases.
        assert_eq!(write_budget_per_tenant_rate(300e6, 3, 0), 0.0);
        assert_eq!(write_budget_per_tenant_rate(0.0, 3, 4), 0.0);
    }
}
