//! Record and batch framing.
//!
//! Kafka's unit of transfer is the record batch: producers accumulate
//! records per partition and ship them as one framed, checksummed blob;
//! brokers append the blob to the partition log verbatim and consumers
//! decode it. We implement the same shape with a compact binary framing:
//!
//! ```text
//! batch   := magic(u32) base_ts(u64) count(u32) record* checksum(u64)
//! record  := key(u64) ts_delta(u32) len(u32) payload(bytes)
//! ```
//!
//! The checksum is FNV-1a over everything before it (crc32 is not available
//! offline; FNV is adequate for corruption detection in this context).

use anyhow::{bail, Result};

const MAGIC: u32 = 0xA17A_B417;

/// One record: a keyed payload with a timestamp.
///
/// In *Face Recognition* the key is the frame id and the payload is a face
/// thumbnail (avg 37.3 kB); in *Object Detection* the payload is a whole
/// frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Record {
    pub key: u64,
    pub timestamp_us: u64,
    pub payload: Vec<u8>,
}

impl Record {
    pub fn new(key: u64, timestamp_us: u64, payload: Vec<u8>) -> Self {
        Record {
            key,
            timestamp_us,
            payload,
        }
    }

    /// Framed size of this record within a batch.
    pub fn wire_size(&self) -> usize {
        8 + 4 + 4 + self.payload.len()
    }
}

/// A batch of records bound for one partition.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecordBatch {
    pub records: Vec<Record>,
}

impl RecordBatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Total payload bytes (what the batching size threshold counts).
    pub fn payload_bytes(&self) -> usize {
        self.records.iter().map(|r| r.payload.len()).sum()
    }

    /// Framed wire size.
    pub fn wire_size(&self) -> usize {
        4 + 8 + 4 + self.records.iter().map(Record::wire_size).sum::<usize>() + 8
    }

    /// Encode to the wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_size());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        let base_ts = self.records.first().map(|r| r.timestamp_us).unwrap_or(0);
        out.extend_from_slice(&base_ts.to_le_bytes());
        out.extend_from_slice(&(self.records.len() as u32).to_le_bytes());
        for r in &self.records {
            out.extend_from_slice(&r.key.to_le_bytes());
            let delta = r.timestamp_us.saturating_sub(base_ts);
            debug_assert!(delta <= u32::MAX as u64, "timestamp delta overflow");
            out.extend_from_slice(&(delta as u32).to_le_bytes());
            out.extend_from_slice(&(r.payload.len() as u32).to_le_bytes());
            out.extend_from_slice(&r.payload);
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Decode from the wire format, verifying magic and checksum.
    pub fn decode(buf: &[u8]) -> Result<RecordBatch> {
        if buf.len() < 4 + 8 + 4 + 8 {
            bail!("batch too short: {} bytes", buf.len());
        }
        let body = &buf[..buf.len() - 8];
        let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        let computed = fnv1a(body);
        if stored != computed {
            bail!("batch checksum mismatch: {stored:#x} != {computed:#x}");
        }
        let mut pos = 0usize;
        let magic = u32::from_le_bytes(body[pos..pos + 4].try_into().unwrap());
        pos += 4;
        if magic != MAGIC {
            bail!("bad batch magic: {magic:#x}");
        }
        let base_ts = u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap());
        pos += 8;
        let count = u32::from_le_bytes(body[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        let mut records = Vec::with_capacity(count);
        for _ in 0..count {
            if pos + 16 > body.len() {
                bail!("truncated record header");
            }
            let key = u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap());
            pos += 8;
            let delta = u32::from_le_bytes(body[pos..pos + 4].try_into().unwrap()) as u64;
            pos += 4;
            let len = u32::from_le_bytes(body[pos..pos + 4].try_into().unwrap()) as usize;
            pos += 4;
            if pos + len > body.len() {
                bail!("truncated record payload");
            }
            records.push(Record {
                key,
                timestamp_us: base_ts + delta,
                payload: body[pos..pos + len].to_vec(),
            });
            pos += len;
        }
        if pos != body.len() {
            bail!("trailing bytes in batch: {}", body.len() - pos);
        }
        Ok(RecordBatch { records })
    }
}

/// Word-wise mixing checksum (FNV-1a structure over u64 lanes).
///
/// §Perf: the original byte-serial FNV-1a processed ~1 B/cycle and
/// dominated the broker append path (encode+decode checksums held produce
/// at ~430 MB/s, below the 1 GB/s L3 target). Folding 8 bytes per
/// multiply is ~7x faster with equivalent corruption detection for this
/// use (framing errors, torn writes).
fn fnv1a(data: &[u8]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        hash = (hash ^ w).wrapping_mul(PRIME);
        hash ^= hash >> 29; // extra diffusion across lanes
    }
    for &b in chunks.remainder() {
        hash = (hash ^ b as u64).wrapping_mul(PRIME);
    }
    // Finalize so trailing zeros still affect the sum.
    hash ^= data.len() as u64;
    hash = hash.wrapping_mul(PRIME);
    hash
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(n: usize) -> RecordBatch {
        let mut b = RecordBatch::new();
        for i in 0..n {
            b.push(Record::new(
                i as u64,
                1_000_000 + i as u64,
                vec![i as u8; 10 + i],
            ));
        }
        b
    }

    #[test]
    fn encode_decode_roundtrip() {
        let b = batch(5);
        let wire = b.encode();
        assert_eq!(wire.len(), b.wire_size());
        let d = RecordBatch::decode(&wire).unwrap();
        assert_eq!(d, b);
    }

    #[test]
    fn empty_batch_roundtrip() {
        let b = RecordBatch::new();
        let d = RecordBatch::decode(&b.encode()).unwrap();
        assert!(d.is_empty());
    }

    #[test]
    fn corruption_detected() {
        let mut wire = batch(3).encode();
        let mid = wire.len() / 2;
        wire[mid] ^= 0xFF;
        assert!(RecordBatch::decode(&wire).is_err());
    }

    #[test]
    fn truncation_detected() {
        let wire = batch(3).encode();
        assert!(RecordBatch::decode(&wire[..wire.len() - 1]).is_err());
        assert!(RecordBatch::decode(&wire[..10]).is_err());
    }

    #[test]
    fn wrong_magic_detected() {
        let b = batch(1);
        let mut wire = b.encode();
        // Flip magic and re-checksum so only the magic check can fail.
        wire[0] ^= 0xFF;
        let body_len = wire.len() - 8;
        let sum = fnv1a(&wire[..body_len]);
        wire[body_len..].copy_from_slice(&sum.to_le_bytes());
        let err = RecordBatch::decode(&wire).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn payload_sizes() {
        let b = batch(4);
        assert_eq!(b.payload_bytes(), 10 + 11 + 12 + 13);
    }

    #[test]
    fn roundtrip_property() {
        crate::util::prop::check(100, |rng| {
            let mut b = RecordBatch::new();
            let n = rng.below(20);
            let base = rng.next_u64() >> 32;
            for i in 0..n {
                let len = rng.below(4096) as usize;
                b.push(Record::new(rng.next_u64(), base + i, vec![0xAB; len]));
            }
            let d = RecordBatch::decode(&b.encode())
                .map_err(|e| format!("decode failed: {e}"))?;
            crate::util::prop::assert_holds(d == b, "roundtrip equality")
        });
    }
}
