//! Topic naming and partition addressing.

use std::fmt;

/// A (topic, partition) address — the unit everything else routes on.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TopicPartition {
    pub topic: String,
    pub partition: u32,
}

impl TopicPartition {
    pub fn new(topic: impl Into<String>, partition: u32) -> Self {
        TopicPartition {
            topic: topic.into(),
            partition,
        }
    }

    /// Stable name for log segment files.
    pub fn log_name(&self) -> String {
        format!("{}-{}", self.topic, self.partition)
    }
}

impl fmt::Display for TopicPartition {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}-{}", self.topic, self.partition)
    }
}

/// Topic metadata.
#[derive(Clone, Debug)]
pub struct Topic {
    pub name: String,
    pub partitions: u32,
    pub replication: u32,
}

impl Topic {
    pub fn new(name: impl Into<String>, partitions: u32, replication: u32) -> Self {
        Topic {
            name: name.into(),
            partitions,
            replication,
        }
    }

    pub fn partition_ids(&self) -> impl Iterator<Item = TopicPartition> + '_ {
        (0..self.partitions).map(move |p| TopicPartition::new(self.name.clone(), p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_names_unique_per_partition() {
        let t = Topic::new("faces", 4, 3);
        let names: Vec<String> = t.partition_ids().map(|tp| tp.log_name()).collect();
        assert_eq!(names.len(), 4);
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(dedup, names);
        assert_eq!(names[0], "faces-0");
    }

    #[test]
    fn display_matches_log_name() {
        let tp = TopicPartition::new("frames", 7);
        assert_eq!(tp.to_string(), tp.log_name());
    }
}
