//! Cluster deployment: node inventory, container placement, and the
//! Kubernetes-role substrate (§3.2: "deployment of the various containers
//! is managed using Kubernetes").

pub mod placement;

pub use placement::{ContainerKind, NodeAllocation, Placement};
