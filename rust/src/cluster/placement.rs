//! Container placement over data-center nodes.
//!
//! §4.2's deployment: "840 ingest/detect processes executing on 15 nodes
//! (56 processes per node), 1680 identification processes executing on 30
//! nodes (56 per node), and 3 brokers (each given its own node)". This
//! module reproduces that bin-packing: containers request cores; nodes
//! offer `NodeSpec::cores`; brokers are exclusive.

use crate::config::hardware::NodeSpec;
use crate::config::Deployment;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ContainerKind {
    IngestDetect,
    Identification,
    Broker,
    /// Object Detection stages.
    ObjIngest,
    ObjDetect,
}

impl ContainerKind {
    pub fn name(&self) -> &'static str {
        match self {
            ContainerKind::IngestDetect => "ingest/detect",
            ContainerKind::Identification => "identification",
            ContainerKind::Broker => "broker",
            ContainerKind::ObjIngest => "objdet-ingest",
            ContainerKind::ObjDetect => "objdet-detect",
        }
    }
}

/// One node's allocation.
#[derive(Clone, Debug)]
pub struct NodeAllocation {
    pub node_id: u32,
    pub kind: ContainerKind,
    pub containers: usize,
    pub cores_per_container: usize,
}

/// A full placement plan.
#[derive(Clone, Debug)]
pub struct Placement {
    pub nodes: Vec<NodeAllocation>,
}

impl Placement {
    /// Pack `containers` of `kind` at `cores_each` onto nodes with
    /// `node.cores` cores, starting at node id `first_node`. Brokers are
    /// exclusive (one per node, §4.2).
    pub fn pack(
        kind: ContainerKind,
        containers: usize,
        cores_each: usize,
        node: &NodeSpec,
        first_node: u32,
    ) -> Placement {
        assert!(cores_each >= 1);
        let mut nodes = Vec::new();
        if kind == ContainerKind::Broker {
            for i in 0..containers {
                nodes.push(NodeAllocation {
                    node_id: first_node + i as u32,
                    kind,
                    containers: 1,
                    cores_per_container: node.cores,
                });
            }
            return Placement { nodes };
        }
        let per_node = (node.cores / cores_each).max(1);
        let mut remaining = containers;
        let mut id = first_node;
        while remaining > 0 {
            let here = remaining.min(per_node);
            nodes.push(NodeAllocation {
                node_id: id,
                kind,
                containers: here,
                cores_per_container: cores_each,
            });
            remaining -= here;
            id += 1;
        }
        Placement { nodes }
    }

    /// The paper's §4.2 Face Recognition placement for a given deployment.
    pub fn facerec(d: &Deployment, node: &NodeSpec) -> Placement {
        let mut p = Placement::pack(ContainerKind::IngestDetect, d.producers, 1, node, 0);
        let next = p.node_count() as u32;
        let c = Placement::pack(ContainerKind::Identification, d.consumers, 1, node, next);
        let next2 = next + c.node_count() as u32;
        let b = Placement::pack(ContainerKind::Broker, d.brokers, node.cores, node, next2);
        p.nodes.extend(c.nodes);
        p.nodes.extend(b.nodes);
        p
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn container_count(&self, kind: ContainerKind) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.kind == kind)
            .map(|n| n.containers)
            .sum()
    }

    pub fn nodes_of(&self, kind: ContainerKind) -> usize {
        self.nodes.iter().filter(|n| n.kind == kind).count()
    }

    /// No node is over-committed.
    pub fn validate(&self, node: &NodeSpec) -> bool {
        self.nodes
            .iter()
            .all(|n| n.containers * n.cores_per_container <= node.cores)
    }

    /// Total cores in use across the cluster.
    pub fn cores_used(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| n.containers * n.cores_per_container)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_42_deployment() {
        // 840 producers + 1680 consumers at 1 core on 56-core nodes,
        // 3 exclusive broker nodes: 15 + 30 + 3 = 48 nodes.
        let d = Deployment::facerec_paper();
        let node = NodeSpec::xeon_8176();
        let p = Placement::facerec(&d, &node);
        assert_eq!(p.nodes_of(ContainerKind::IngestDetect), 15);
        assert_eq!(p.nodes_of(ContainerKind::Identification), 30);
        assert_eq!(p.nodes_of(ContainerKind::Broker), 3);
        assert_eq!(p.container_count(ContainerKind::IngestDetect), 840);
        assert_eq!(p.container_count(ContainerKind::Identification), 1680);
        assert!(p.validate(&node));
        // "over 2200 processor cores spread across 40+ nodes"
        assert!(p.node_count() >= 40);
        let total_cores = p.node_count() * node.cores;
        assert!(total_cores > 2200);
    }

    #[test]
    fn objdet_14_core_packing() {
        // §6.1: "allocate 14 cores per container; this allows us to
        // instantiate 4 detection containers per server".
        let node = NodeSpec::xeon_8176();
        let p = Placement::pack(ContainerKind::ObjDetect, 96, 14, &node, 0);
        assert_eq!(p.node_count(), 24); // 96 / 4 per node
        assert!(p.validate(&node));
        assert_eq!(p.nodes[0].containers, 4);
    }

    #[test]
    fn brokers_are_exclusive() {
        let node = NodeSpec::xeon_8176();
        let p = Placement::pack(ContainerKind::Broker, 8, 1, &node, 100);
        assert_eq!(p.node_count(), 8);
        for n in &p.nodes {
            assert_eq!(n.containers, 1);
            assert_eq!(n.node_id >= 100, true);
        }
    }

    #[test]
    fn packing_never_overcommits_property() {
        crate::util::prop::check(200, |rng| {
            let node = NodeSpec::xeon_8176();
            let containers = 1 + rng.below(3000) as usize;
            let cores = 1 + rng.below(56) as usize;
            let p = Placement::pack(ContainerKind::Identification, containers, cores, &node, 0);
            crate::util::prop::assert_holds(
                p.validate(&node)
                    && p.container_count(ContainerKind::Identification) == containers,
                "pack validity + completeness",
            )
        });
    }
}
