//! Calibration constants mapping the paper's measured behavior onto the
//! simulator's device and cost models. Every constant lists its provenance.
//!
//! Two kinds of constants live here:
//!
//! 1. **Paper-reported values** (stage means, Fig-8 proportions, message
//!    sizes) — taken verbatim from the text.
//! 2. **Fitted constants** (storage small-write efficiency, broker-relief
//!    exponent, producer send cost) — fitted so the simulator's emergent
//!    behavior lands on the paper's reported saturation/unlock points, as
//!    described in DESIGN.md §4. These are inputs a reader can re-fit; the
//!    *mechanisms* (token-bucket storage, partition-pinned consumers,
//!    linger/fetch timers) are what the reproduction claims.

/// Per-stage compute-cost model for *Face Recognition* (§4.2-§4.3).
/// Plain scalars — `Copy`, so simulation builds pass it by value instead
/// of cloning through the config tree.
#[derive(Clone, Copy, Debug)]
pub struct StageCosts {
    /// Mean ingestion time per frame, us (paper: 18.8 ms).
    pub ingest_us: f64,
    /// Mean face-detection time per frame, us (paper: 74.8 ms).
    pub detect_us: f64,
    /// Mean identification time per face, us (paper: 131.5 ms).
    pub identify_us: f64,
    /// AI fraction of detection compute (Fig 8b: 42%).
    pub detect_ai_frac: f64,
    /// AI fraction of identification compute (Fig 8c: 88%).
    pub identify_ai_frac: f64,
    /// AI fraction of ingestion (Fig 8a: none).
    pub ingest_ai_frac: f64,
    /// Kafka-client fraction of identification (Fig 8c: 8%) — stays at
    /// native speed even under the §5.2 emulation protocol.
    pub identify_kafka_frac: f64,
    /// Coefficient of variation of the detection time's *body*
    /// (log-normal).
    pub detect_cv: f64,
    /// Probability a detection lands on the slow path (GC pauses, frame
    /// pyramid blowups, co-location contention).
    pub detect_slow_prob: f64,
    /// Slow-path multiplier. The §4.2 tail — detection p99 = 1.84 s vs a
    /// 74.8 ms mean, a 24.6x ratio — cannot come from any log-normal with
    /// a plausible cv (the p99/mean ratio of a log-normal maxes out around
    /// 15x); it requires a bimodal slow path, which `slow_prob`/`slow_mult`
    /// model. Fitted so p99 lands near the paper's 1.84 s while the mean
    /// stays 74.8 ms.
    pub detect_slow_mult: f64,
    /// Extra detection time per face found in the frame, us (more faces =>
    /// more pyramid/NMS/crop work).
    pub detect_per_face_us: f64,
    /// Coefficient of variation of identification time (mild).
    pub identify_cv: f64,
    /// Coefficient of variation of ingestion time (§4.2 p99 27 ms vs
    /// 18.8 ms mean => cv ~= 0.2).
    pub ingest_cv: f64,
}

impl Default for StageCosts {
    fn default() -> Self {
        StageCosts {
            ingest_us: 18_800.0,
            detect_us: 74_800.0,
            identify_us: 131_500.0,
            detect_ai_frac: 0.42,
            identify_ai_frac: 0.88,
            ingest_ai_frac: 0.0,
            identify_kafka_frac: 0.08,
            detect_cv: 0.7,
            detect_slow_prob: 0.016,
            detect_slow_mult: 45.0,
            detect_per_face_us: 9_000.0,
            identify_cv: 0.5,
            ingest_cv: 0.2,
        }
    }
}

/// Fig-8 component-level CPU-time proportions (sum to 1.0 per stage).
#[derive(Clone, Debug)]
pub struct CpuBreakdown {
    pub ingestion: &'static [(&'static str, f64)],
    pub detection: &'static [(&'static str, f64)],
    pub identification: &'static [(&'static str, f64)],
}

impl Default for CpuBreakdown {
    fn default() -> Self {
        CpuBreakdown {
            // Fig 8a: "nearly even split between frame extraction and frame
            // resizing", remainder = event logging + other (incl. IPC).
            ingestion: &[
                ("extract", 0.45),
                ("resize", 0.45),
                ("event logging", 0.05),
                ("other", 0.05),
            ],
            // Fig 8b: 42% AI, 25% crop+resize, 6% TF support, 4% NumPy,
            // 13% other, remainder event logging + IPC.
            detection: &[
                ("ai (tensorflow)", 0.42),
                ("crop+resize", 0.25),
                ("tf support", 0.06),
                ("numpy", 0.04),
                ("other", 0.13),
                ("event logging + ipc", 0.10),
            ],
            // Fig 8c: 88% AI, 8% Kafka, remainder split.
            identification: &[
                ("ai (tensorflow)", 0.88),
                ("kafka client", 0.08),
                ("other", 0.04),
            ],
        }
    }
}

/// Storage & broker saturation model (fitted; DESIGN.md §4).
#[derive(Clone, Debug)]
pub struct BrokerModel {
    /// Effective fraction of spec write bandwidth reachable with Kafka's
    /// many-small-appends pattern on one drive. Fitted to Fig 11b: the
    /// paper calls 67% utilization "effectively saturated" (OS, filesystem,
    /// small-request coordination overhead).
    pub small_write_eff: f64,
    /// Per-drive efficiency exponent: d drives yield `d^(1+alpha)` times
    /// one drive's effective bandwidth (higher aggregate queue depth
    /// amortizes the small-write overhead). Fitted to Fig 15a unlock points
    /// (1 drive < 8x, 2 -> 12x, 3 -> 24x, 4 -> 32x).
    pub drive_scale_alpha: f64,
    /// Broker-count relief exponent: b brokers yield `(b/3)^relief` extra
    /// per-broker effective capacity on top of the 1/b load split, modeling
    /// the CPU/memory-bandwidth contention relief the paper infers in §7.1
    /// ("brokers may also benefit from having additional compute capacity").
    /// Fitted to Fig 15b unlock points (3 -> <8x, 4 -> 8x, 6 -> 16x,
    /// 8 -> 32x).
    pub broker_relief_exp: f64,
    /// **Calibration target** for the measured read path (paper §5.4:
    /// reads "use essentially none of the available bandwidth"): the
    /// byte-weighted cache hit ratio the default page-cache capacity
    /// ([`BrokerModel::page_cache_frac`]) must reproduce under nominal
    /// lag — streaming consumers reading right behind the appenders.
    /// `experiments::read_path` pins this
    /// (`default_cache_reproduces_the_calibrated_hit_rate`); the DES
    /// does not substitute the constant for the model — hits and misses
    /// come from per-group offsets against the cached window.
    pub read_cache_hit: f64,
    /// Fraction of broker-node RAM given to the OS page cache when the
    /// measured read path derives its default capacity
    /// ([`Calibration::page_cache_capacity`]). Kafka brokers run with a
    /// small JVM heap and leave the rest of their 384 GB (Table 2) to
    /// the page cache; 0.75 is the operator rule of thumb.
    pub page_cache_frac: f64,
}

impl Default for BrokerModel {
    fn default() -> Self {
        BrokerModel {
            small_write_eff: 0.70,
            drive_scale_alpha: 0.17,
            broker_relief_exp: 0.58,
            read_cache_hit: 0.995,
            page_cache_frac: 0.75,
        }
    }
}

/// Object Detection cost model (§6).
#[derive(Clone, Debug)]
pub struct ObjDetCosts {
    /// Ingestion per frame, us (paper: 4.5 ms; rate-limited to 30 FPS).
    pub ingest_us: f64,
    /// Frame tick interval, us (30 FPS).
    pub tick_us: u64,
    /// Detection per frame at the experiment's 1-core allocation, us
    /// (paper Fig 13: 687 ms).
    pub detect_us: f64,
    pub detect_cv: f64,
    /// Whole-frame message bytes sent through Kafka (960x540 re-encoded
    /// frame; fitted so broker storage nears saturation at ~12x, Fig 14).
    pub frame_bytes: f64,
    /// Producer-side cost to serialize + hand one frame to the Kafka
    /// client, us. Fitted so the producer send path overruns the 33.3 ms
    /// tick between 12x and 16x (Fig 14's "Delay" component).
    pub send_frame_us: f64,
    /// Batching amortization: with k frames per tick the effective per-
    /// frame send cost is `send_frame_us * (1-batch_amort) +
    /// send_frame_us * batch_amort / k` ("Kafka is well designed ... the
    /// producers and the brokers manage to intelligently batch").
    pub batch_amort: f64,
    /// Detection AI fraction (stage is overwhelmingly the R-CNN; §6.1 "AI
    /// compute is exclusively performed in this later stage").
    pub detect_ai_frac: f64,
    /// Consumer fetch tuning for Object Detection: the deployment is tuned
    /// for throughput with a large `fetch.min.bytes` and a long max wait,
    /// which makes the broker wait comparable to detection time (Fig 13's
    /// 629 ms vs 687 ms) and keeps it roughly constant under acceleration
    /// ("the broker time grows with the decrease in compute time to
    /// improve batching", §5.5).
    pub fetch_min_bytes: usize,
    pub fetch_max_wait_us: u64,
}

impl Default for ObjDetCosts {
    fn default() -> Self {
        ObjDetCosts {
            ingest_us: 4_500.0,
            tick_us: 33_333,
            detect_us: 687_000.0,
            detect_cv: 0.30,
            frame_bytes: 100_000.0,
            send_frame_us: 4_300.0,
            batch_amort: 0.45,
            detect_ai_frac: 0.94,
            fetch_min_bytes: 1_000_000,
            fetch_max_wait_us: 550_000,
        }
    }
}

/// Training-ingest cost model (ROADMAP follow-up to §8: a tenant whose
/// signature is large sequential writes — data-loader shards streamed
/// through the broker to training readers). Values are design targets for
/// the QoS experiments, not paper measurements: the tenant exists to
/// stress the shared NVMe write path the way Fig 11b's producer traffic
/// does, with ~1 MB batches instead of 37 kB thumbnails.
#[derive(Clone, Debug)]
pub struct TrainCosts {
    /// Writer cadence, µs (default 100 ms → 10 batches/s per writer).
    pub tick_us: u64,
    /// Serialized shard batch size, bytes (~1 MB sequential append).
    pub batch_bytes: f64,
    pub batches_per_tick: usize,
    /// Lognormal cv of the batch size (shards are near-constant).
    pub bytes_cv: f64,
    /// Producer-side shard assembly per batch, µs.
    pub prep_us: f64,
    pub prep_cv: f64,
    /// Serialization + client hand-off per batch on the send path, µs.
    pub send_batch_us: f64,
    /// Consumer training-step time per batch, µs.
    pub step_us: f64,
    pub step_cv: f64,
    /// Throughput-tuned fetch: wait for several batches before fetching.
    pub fetch_min_bytes: usize,
    pub fetch_max_wait_us: u64,
}

impl Default for TrainCosts {
    fn default() -> Self {
        TrainCosts {
            tick_us: 100_000,
            batch_bytes: 1_000_000.0,
            batches_per_tick: 1,
            bytes_cv: 0.05,
            prep_us: 2_000.0,
            prep_cv: 0.2,
            send_batch_us: 900.0,
            step_us: 40_000.0,
            step_cv: 0.2,
            fetch_min_bytes: 4_000_000,
            fetch_max_wait_us: 500_000,
        }
    }
}

/// RPC-style low-latency tenant (ROADMAP follow-up to §8): small
/// request records, `fetch.min.bytes` = 1 so every commit is fetched
/// immediately, and a p99 SLO — the tenant that *feels* cross-tenant
/// interference first, because its latency budget is microscopic next to
/// the bulk tenants' batching slack.
#[derive(Clone, Debug)]
pub struct RpcCosts {
    /// Request cadence per client, µs (default 10 ms → 100 req/s).
    pub period_us: u64,
    /// Serialized request bytes.
    pub request_bytes: f64,
    pub bytes_cv: f64,
    /// Client-side marshalling per request, µs.
    pub prep_us: f64,
    pub prep_cv: f64,
    /// Send-path cost per request, µs.
    pub send_request_us: f64,
    /// Server-side handler time per request, µs.
    pub handle_us: f64,
    pub handle_cv: f64,
    /// Latency-tuned fetch: any visible byte is fetched at once.
    pub fetch_min_bytes: usize,
    pub fetch_max_wait_us: u64,
    /// End-to-end p99 service-level objective, µs.
    pub slo_p99_us: u64,
}

impl Default for RpcCosts {
    fn default() -> Self {
        RpcCosts {
            period_us: 10_000,
            request_bytes: 2_000.0,
            bytes_cv: 0.2,
            prep_us: 150.0,
            prep_cv: 0.3,
            send_request_us: 20.0,
            handle_us: 500.0,
            handle_cv: 0.3,
            fetch_min_bytes: 1,
            fetch_max_wait_us: 1_000,
            slo_p99_us: 75_000,
        }
    }
}

/// Core-scaling model constants (Figs 5 and 12):
/// `latency(c) = serial + parallel/c + interference * (c - 1)`, normalized
/// to latency(1) = 1. Fitted to the paper's quoted points: 2 cores give a
/// 16% (ingest/detect) / 36% (identification) reduction, with an upturn at
/// higher counts; Object Detection scales near-linearly.
#[derive(Clone, Copy, Debug)]
pub struct CoreScaling {
    pub serial: f64,
    pub parallel: f64,
    pub interference: f64,
}

impl CoreScaling {
    pub fn ingest_detect() -> Self {
        CoreScaling {
            serial: 0.64,
            parallel: 0.36,
            interference: 0.02,
        }
    }

    pub fn identification() -> Self {
        CoreScaling {
            serial: 0.22,
            parallel: 0.78,
            interference: 0.03,
        }
    }

    pub fn objdet_detection() -> Self {
        CoreScaling {
            serial: 0.016,
            parallel: 0.984,
            interference: 0.0007,
        }
    }

    /// Relative latency at `c` cores (1.0 at one core).
    pub fn latency(&self, cores: usize) -> f64 {
        assert!(cores >= 1);
        self.serial + self.parallel / cores as f64 + self.interference * (cores as f64 - 1.0)
    }
}

/// Face-arrival process for the synthetic video stream (§3.3: "our video
/// yields zero to five faces and averages 0.64 faces per frame").
#[derive(Clone, Debug)]
pub struct FaceArrival {
    /// Mean faces per frame.
    pub mean_faces: f64,
    /// Maximum faces in one frame.
    pub max_faces: usize,
    /// Probability of staying in the current burst state per frame (the
    /// Markov modulation that creates Fig 7's surges) — used by the
    /// per-producer `VideoSource` (live mode).
    pub burst_persistence: f64,
    /// Mean burst dwell time on the shared `BurstSchedule` timeline, us
    /// (simulation mode; all producers replay the same video, §3.3).
    pub burst_dwell_us: u64,
    /// Mean faces per frame while in a burst.
    pub burst_mean: f64,
    /// Stationary probability of being in a burst.
    pub burst_prob: f64,
}

impl Default for FaceArrival {
    fn default() -> Self {
        FaceArrival {
            mean_faces: 0.64,
            max_faces: 5,
            burst_persistence: 0.995,
            burst_dwell_us: 3_000_000,
            burst_mean: 1.2,
            burst_prob: 0.12,
        }
    }
}

/// Bundle of all calibration constants.
#[derive(Clone, Debug, Default)]
pub struct Calibration {
    pub stages: StageCosts,
    pub cpu_breakdown: CpuBreakdown,
    pub broker: BrokerModel,
    pub objdet: ObjDetCosts,
    pub train: TrainCosts,
    pub rpc: RpcCosts,
    pub faces: FaceArrival,
}

impl Calibration {
    /// Effective aggregate write bandwidth of a broker node with `drives`
    /// drives and `brokers` total brokers in the cluster (bytes/s).
    pub fn broker_write_capacity(
        &self,
        spec_write_bw: f64,
        drives: usize,
        brokers: usize,
    ) -> f64 {
        let d = drives as f64;
        let relief = ((brokers as f64) / 3.0).powf(self.broker.broker_relief_exp);
        spec_write_bw * self.broker.small_write_eff * d.powf(1.0 + self.broker.drive_scale_alpha)
            * relief.max(1.0) // adding brokers never *hurts* a broker
    }

    /// Default per-broker page-cache capacity for the measured read
    /// path: the configured fraction of the broker node's RAM (bytes).
    pub fn page_cache_capacity(&self, node_memory_bytes: u64) -> f64 {
        node_memory_bytes as f64 * self.broker.page_cache_frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_quoted_points() {
        // "Doubling the core count from one to two yields only a 16%
        //  reduction in latency in ingest/detect and a 36% reduction in
        //  identification."
        let id = CoreScaling::ingest_detect();
        let ident = CoreScaling::identification();
        assert!((id.latency(2) - 0.84).abs() < 0.01, "{}", id.latency(2));
        assert!((ident.latency(2) - 0.64).abs() < 0.01, "{}", ident.latency(2));
        // "At larger core counts, the computational latency actually
        //  increases for both containers."
        assert!(id.latency(16) > id.latency(4));
        assert!(ident.latency(16) > ident.latency(4));
    }

    #[test]
    fn fig12_near_linear() {
        let od = CoreScaling::objdet_detection();
        // 14 cores should give close to 14x speedup (>10x).
        assert!(1.0 / od.latency(14) > 10.0);
        // And still be monotone down to 14 cores.
        for c in 1..14 {
            assert!(od.latency(c + 1) < od.latency(c));
        }
    }

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let b = CpuBreakdown::default();
        for stage in [b.ingestion, b.detection, b.identification] {
            let sum: f64 = stage.iter().map(|(_, f)| f).sum();
            assert!((sum - 1.0).abs() < 1e-9, "sum={sum}");
        }
    }

    #[test]
    fn capacity_monotone_in_drives_and_brokers() {
        let c = Calibration::default();
        let bw = 1.1e9;
        let mut prev = 0.0;
        for d in 1..=4 {
            let cap = c.broker_write_capacity(bw, d, 3);
            assert!(cap > prev);
            prev = cap;
        }
        assert!(c.broker_write_capacity(bw, 1, 8) > c.broker_write_capacity(bw, 1, 3));
    }

    #[test]
    fn one_drive_three_brokers_matches_fig11() {
        // Effective capacity ~ 0.70 x 1.1 GB/s = 770 MB/s; the paper calls
        // 67% of spec (737 MB/s) "effectively saturated".
        let c = Calibration::default();
        let cap = c.broker_write_capacity(1.1e9, 1, 3);
        assert!((cap - 0.77e9).abs() < 1e7, "cap={cap}");
    }

    #[test]
    fn page_cache_capacity_is_a_ram_fraction() {
        let c = Calibration::default();
        let node = crate::config::NodeSpec::xeon_8176();
        let cap = c.page_cache_capacity(node.memory);
        assert!((cap - 0.75 * node.memory as f64).abs() < 1.0);
        // ~288 GB of window: at the fabric's ~770 MB/s effective write
        // bandwidth that is >5 minutes of residency, so nominal-lag
        // consumers must land at/above the §5.4 calibration target.
        assert!(cap > 250e9);
        assert!(c.broker.read_cache_hit >= 0.99);
    }

    #[test]
    fn stage_costs_match_fig6() {
        let s = StageCosts::default();
        assert_eq!(s.ingest_us, 18_800.0);
        assert_eq!(s.detect_us, 74_800.0);
        assert_eq!(s.identify_us, 131_500.0);
    }
}
