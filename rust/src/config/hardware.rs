//! Hardware specifications — the paper's Table 2 server, verbatim.

/// One-way wire propagation + switching latency within the data center
/// (a few fat-tree switch hops), microseconds. The single source of
/// truth consumed by both the broker fabric's hop latency
/// (`pipeline::fabric::WIRE_US`) and the node NIC model
/// (`net::nic::Nic::transit_us`).
pub const WIRE_TRANSIT_US: u64 = 30;

/// Intel SSD DC P4510 1 TB (Table 2).
#[derive(Clone, Copy, Debug)]
pub struct NvmeSpec {
    /// Sequential read bandwidth, bytes/s.
    pub read_bw: f64,
    /// Sequential write bandwidth, bytes/s.
    pub write_bw: f64,
    /// Read latency, microseconds.
    pub read_latency_us: u64,
    /// Write latency, microseconds.
    pub write_latency_us: u64,
    /// Capacity, bytes.
    pub capacity: u64,
}

impl NvmeSpec {
    pub fn p4510_1tb() -> Self {
        NvmeSpec {
            read_bw: 2.85e9,
            write_bw: 1.1e9,
            read_latency_us: 77,
            write_latency_us: 18,
            capacity: 1_000_000_000_000,
        }
    }

    /// Intel Optane-class device (§7.1 mentions faster storage as one
    /// mitigation; modeled after P5800X-era specs for the ablation bench).
    pub fn optane() -> Self {
        NvmeSpec {
            read_bw: 7.2e9,
            write_bw: 6.2e9,
            read_latency_us: 6,
            write_latency_us: 5,
            capacity: 800_000_000_000,
        }
    }
}

/// A data-center node (Table 2: 2x Intel Xeon Platinum 8176).
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub name: &'static str,
    /// Physical cores per node (2 sockets x 28).
    pub cores: usize,
    pub base_ghz: f64,
    pub turbo_ghz: f64,
    pub smt: usize,
    /// Last-level cache, bytes (per socket).
    pub llc_bytes: u64,
    /// Memory, bytes.
    pub memory: u64,
    pub nvme: NvmeSpec,
    /// Network bandwidth, bytes/s (full duplex; this is each direction).
    pub net_bw: f64,
}

impl NodeSpec {
    /// Table 2 server.
    pub fn xeon_8176() -> Self {
        NodeSpec {
            name: "2x Xeon Platinum 8176",
            cores: 56,
            base_ghz: 2.10,
            turbo_ghz: 3.80,
            smt: 2,
            llc_bytes: 38_500_000,
            memory: 384 * 1024 * 1024 * 1024,
            nvme: NvmeSpec::p4510_1tb(),
            net_bw: crate::util::units::gbps(100),
        }
    }

    /// The purpose-built data center's broker node (Table 4: Xeon Bronze
    /// 3104, 50 GbE, 4x NVMe).
    pub fn broker_bronze() -> Self {
        NodeSpec {
            name: "2x Xeon Bronze 3104",
            cores: 12,
            base_ghz: 1.70,
            turbo_ghz: 1.70,
            smt: 1,
            llc_bytes: 8_250_000,
            memory: 384 * 1024 * 1024 * 1024,
            nvme: NvmeSpec::p4510_1tb(),
            net_bw: crate::util::units::gbps(50),
        }
    }

    /// Purpose-built compute node: same CPUs, 10 GbE, no NVMe data drive.
    pub fn compute_10g() -> Self {
        let mut n = Self::xeon_8176();
        n.net_bw = crate::util::units::gbps(10);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        let n = NodeSpec::xeon_8176();
        assert_eq!(n.cores, 56);
        assert_eq!(n.nvme.write_bw, 1.1e9);
        assert_eq!(n.nvme.read_bw, 2.85e9);
        assert_eq!(n.nvme.read_latency_us, 77);
        assert_eq!(n.nvme.write_latency_us, 18);
        assert_eq!(n.net_bw, 12.5e9);
    }

    #[test]
    fn purpose_built_nodes() {
        assert_eq!(NodeSpec::broker_bronze().net_bw, crate::util::units::gbps(50));
        assert_eq!(NodeSpec::compute_10g().net_bw, crate::util::units::gbps(10));
        assert!(NvmeSpec::optane().write_bw > NvmeSpec::p4510_1tb().write_bw);
    }
}
