//! Typed configuration for deployments, hardware, and experiments.
//!
//! Defaults reproduce the paper's setup: Table 2 hardware, §4.2 deployment
//! (840 producers / 1680 consumers / 3 brokers), §5.3 acceleration-emulation
//! deployment, and the §6 *Object Detection* deployment. Everything can be
//! overridden from JSON config files (see [`Config::from_json`]) or CLI
//! flags, so the experiments are sweepable.

pub mod calibration;
pub mod hardware;

use crate::util::json::Json;

pub use calibration::Calibration;
pub use hardware::{NodeSpec, NvmeSpec};

/// Which of the paper's two measurement protocols the pipeline runs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccelProtocol {
    /// §5.1 / Fig 9: only the *AI share* of each stage is divided by the
    /// acceleration factor (Amdahl's-law view).
    AiShareOnly,
    /// §5.2 / Figs 10-15: emulation — all stage compute is divided by the
    /// factor; only Kafka-client code and basic loop control stay at native
    /// speed (the paper's sleep-replacement emulation).
    Emulation,
}

/// Deployment of a pipeline onto the (simulated or live) cluster.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub producers: usize,
    pub consumers: usize,
    pub brokers: usize,
    /// NVMe drives per broker node (Fig 15a sweeps this).
    pub drives_per_broker: usize,
    /// Replication factor for every topic partition (paper: 3).
    pub replication: usize,
    /// Partitions for the "faces"/"frames" topic. Kafka requires at least
    /// one partition per consumer for full parallelism; default = consumers.
    pub partitions: usize,
}

impl Deployment {
    /// §4.2 Face Recognition measurement deployment.
    pub fn facerec_paper() -> Self {
        Deployment {
            producers: 840,
            consumers: 1680,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 1680,
        }
    }

    /// §5.3 acceleration-emulation deployment (one face per frame,
    /// "fewer identification instances"). Producer/consumer counts are
    /// calibrated so the 1x broker storage-write utilization lands at the
    /// paper's ~10% (Fig 11b) and consumer utilization at ~0.9.
    pub fn facerec_accel() -> Self {
        Deployment {
            producers: 300,
            consumers: 455,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 455,
        }
    }

    /// §6.3 Object Detection acceleration deployment: 21 producers on one
    /// node, 36 consumer nodes x 56 = 2016 consumers, 3 brokers.
    pub fn objdet_accel() -> Self {
        Deployment {
            producers: 21,
            consumers: 2016,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 2016,
        }
    }

    /// Training-ingest tenant (QoS experiments): 16 shard writers at
    /// ~1 MB × 10/s each ≈ 160 MB/s of sequential produce — enough to
    /// push a colocated fabric over its effective write bandwidth.
    pub fn train_ingest() -> Self {
        Deployment {
            producers: 16,
            consumers: 16,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 16,
        }
    }

    /// RPC-style low-latency tenant (QoS experiments): 20 clients at
    /// 100 req/s × 2 kB — byte-wise negligible, latency-wise the canary.
    pub fn rpc_service() -> Self {
        Deployment {
            producers: 20,
            consumers: 40,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 40,
        }
    }

    pub fn with_brokers(mut self, brokers: usize) -> Self {
        self.brokers = brokers;
        self
    }

    pub fn with_drives(mut self, drives: usize) -> Self {
        self.drives_per_broker = drives;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.producers > 0, "need at least one producer");
        anyhow::ensure!(self.consumers > 0, "need at least one consumer");
        anyhow::ensure!(self.brokers > 0, "need at least one broker");
        anyhow::ensure!(self.replication >= 1, "replication must be >= 1");
        anyhow::ensure!(
            self.replication <= self.brokers,
            "replication factor {} exceeds broker count {}",
            self.replication,
            self.brokers
        );
        anyhow::ensure!(
            self.partitions >= self.consumers,
            "Kafka semantics: a partition has at most one consumer, so \
             partitions ({}) must be >= consumers ({}) for full parallelism",
            self.partitions,
            self.consumers
        );
        anyhow::ensure!(self.drives_per_broker >= 1, "brokers need storage");
        Ok(())
    }
}

/// Kafka-style client/broker tuning parameters (§3.4, §5.5: "we have tuned
/// these parameters to find settings that ensure good behavior").
/// Plain scalars — `Copy`, so the fabric and every per-build consumer take
/// it by value instead of cloning through the config tree.
#[derive(Clone, Copy, Debug)]
pub struct KafkaTuning {
    /// Producer linger: how long a producer holds a batch open waiting for
    /// more records before sending (microseconds).
    pub linger_us: u64,
    /// Producer max batch size in bytes; a batch is sent early when full.
    pub batch_max_bytes: usize,
    /// Consumer fetch: broker withholds a response until at least this many
    /// bytes are available...
    pub fetch_min_bytes: usize,
    /// ...or this much time has elapsed (microseconds).
    pub fetch_max_wait_us: u64,
    /// Broker CPU cost to handle one produce/fetch request (microseconds).
    pub request_cpu_us: f64,
    /// Broker CPU cost per byte moved (serialization, checksumming), us/byte.
    pub per_byte_cpu_us: f64,
    /// Cores a broker dedicates to request handling (Kafka network +
    /// I/O threads; the broker nodes have 56 cores, §3.2).
    pub request_handler_cores: usize,
    /// `max.partition.fetch.bytes`: per-partition byte cap on one poll's
    /// fetch; a capped drain immediately re-polls for the remainder.
    /// `usize::MAX` (the default) is unbounded — the pre-cap behavior,
    /// bit for bit.
    pub max_partition_fetch_bytes: usize,
}

impl Default for KafkaTuning {
    fn default() -> Self {
        KafkaTuning {
            linger_us: 30_000,
            batch_max_bytes: 512 * 1024,
            fetch_min_bytes: 40_000,
            fetch_max_wait_us: 45_000,
            request_cpu_us: 90.0,
            per_byte_cpu_us: 0.0006,
            request_handler_cores: 16,
            max_partition_fetch_bytes: usize::MAX,
        }
    }
}

/// Top-level config bundle.
#[derive(Clone, Debug)]
pub struct Config {
    pub deployment: Deployment,
    pub tuning: KafkaTuning,
    pub node: NodeSpec,
    pub calibration: Calibration,
    pub seed: u64,
    /// Virtual experiment duration (microseconds of simulated time).
    pub duration_us: u64,
    /// Warmup fraction excluded from statistics.
    pub warmup_frac: f64,
    pub accel: f64,
    pub protocol: AccelProtocol,
    /// Mean face thumbnail bytes (paper: 37.3 kB). Fig 15c sweeps this.
    pub face_bytes: f64,
    /// Catch-up scenarios: this tenant's consumers do not poll before
    /// this virtual instant (µs), then drain the accumulated backlog —
    /// through cold device reads once it ages out of the page-cache
    /// window (the measured read path). 0 = consumers start live.
    pub consumer_lag_start_us: u64,
    /// Hybrid fluid/discrete scaling: aggregate this many clients into
    /// flow rate processes instead of per-record tick producers
    /// (tick workloads only). 0 (the default) = per-record simulation.
    pub flow_clients: u64,
    /// Coalescing quantum for flow producers (µs): all flows in the
    /// world wake on this shared grid and emit one macro-record per
    /// owned partition per wake.
    pub flow_quantum_us: u64,
    /// Number of flow rate processes per tenant; 0 (the default) =
    /// auto, `min(partitions, 32)` (capped at the client count).
    pub flow_processes: usize,
    /// Optional `(start_us, end_us)` observation window: end-to-end
    /// latencies of items *created* inside it are additionally recorded
    /// in a windowed histogram (`TenantSummary::e2e_p99_window_us`), so
    /// a failover experiment can measure a tenant's p99 *through* the
    /// failure window instead of diluting it over the whole run.
    /// `None` (the default) leaves the windowed histogram empty.
    pub observe_window_us: Option<(u64, u64)>,
    /// Client produce resilience: total send attempts per record. 0
    /// (the default) disables the retry layer entirely — the PR 7
    /// reject-is-loss client, bit for bit. See
    /// [`RetryPolicy`](crate::pipeline::dc::RetryPolicy).
    pub retry_max_attempts: u32,
    /// Backoff before re-offering failed attempt 1; doubles per attempt.
    pub retry_base_backoff_us: u64,
    /// Exponential retry backoff cap.
    pub retry_max_backoff_us: u64,
    /// Producer ack timeout (Kafka's `request.timeout.ms`): an admitted
    /// record unacked this long is retransmitted.
    pub retry_request_timeout_us: u64,
    /// In-client retry buffer bound (`buffer.memory`): bytes of
    /// rejected records a client may hold awaiting backoff before it
    /// starts dropping (counted as `client_dropped`).
    pub retry_buffer_bytes: f64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            deployment: Deployment::facerec_paper(),
            tuning: KafkaTuning::default(),
            node: NodeSpec::xeon_8176(),
            calibration: Calibration::default(),
            seed: 0xFACE,
            duration_us: 60 * crate::util::units::SEC,
            warmup_frac: 0.2,
            accel: 1.0,
            protocol: AccelProtocol::Emulation,
            face_bytes: 37_300.0,
            consumer_lag_start_us: 0,
            flow_clients: 0,
            flow_quantum_us: 25_000,
            flow_processes: 0,
            observe_window_us: None,
            retry_max_attempts: 0,
            retry_base_backoff_us: 50_000,
            retry_max_backoff_us: 800_000,
            retry_request_timeout_us: 1_000_000,
            retry_buffer_bytes: 32e6,
        }
    }
}

impl Config {
    /// Overlay values from a JSON object; unknown keys are rejected so
    /// config typos fail loudly.
    pub fn from_json(mut self, j: &Json) -> anyhow::Result<Config> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config must be a JSON object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "producers" => self.deployment.producers = req_u64(v, k)? as usize,
                "consumers" => self.deployment.consumers = req_u64(v, k)? as usize,
                "brokers" => self.deployment.brokers = req_u64(v, k)? as usize,
                "drives_per_broker" => {
                    self.deployment.drives_per_broker = req_u64(v, k)? as usize
                }
                "replication" => self.deployment.replication = req_u64(v, k)? as usize,
                "partitions" => self.deployment.partitions = req_u64(v, k)? as usize,
                "linger_us" => self.tuning.linger_us = req_u64(v, k)?,
                "batch_max_bytes" => self.tuning.batch_max_bytes = req_u64(v, k)? as usize,
                "fetch_min_bytes" => self.tuning.fetch_min_bytes = req_u64(v, k)? as usize,
                "fetch_max_wait_us" => self.tuning.fetch_max_wait_us = req_u64(v, k)?,
                "seed" => self.seed = req_u64(v, k)?,
                "duration_us" => self.duration_us = req_u64(v, k)?,
                "warmup_frac" => self.warmup_frac = req_f64(v, k)?,
                "accel" => self.accel = req_f64(v, k)?,
                "face_bytes" => self.face_bytes = req_f64(v, k)?,
                "consumer_lag_start_us" => self.consumer_lag_start_us = req_u64(v, k)?,
                "max_partition_fetch_bytes" => {
                    self.tuning.max_partition_fetch_bytes = req_u64(v, k)? as usize
                }
                "flow_clients" => self.flow_clients = req_u64(v, k)?,
                "flow_quantum_us" => self.flow_quantum_us = req_u64(v, k)?,
                "flow_processes" => self.flow_processes = req_u64(v, k)? as usize,
                "retry_max_attempts" => self.retry_max_attempts = req_u64(v, k)? as u32,
                "retry_base_backoff_us" => self.retry_base_backoff_us = req_u64(v, k)?,
                "retry_max_backoff_us" => self.retry_max_backoff_us = req_u64(v, k)?,
                "retry_request_timeout_us" => self.retry_request_timeout_us = req_u64(v, k)?,
                "retry_buffer_bytes" => self.retry_buffer_bytes = req_f64(v, k)?,
                "protocol" => {
                    self.protocol = match v.as_str() {
                        Some("ai_share") => AccelProtocol::AiShareOnly,
                        Some("emulation") => AccelProtocol::Emulation,
                        other => anyhow::bail!("bad protocol: {:?}", other),
                    }
                }
                other => anyhow::bail!("unknown config key: {other}"),
            }
        }
        // Keep partition count consistent if consumers changed.
        if self.deployment.partitions < self.deployment.consumers {
            self.deployment.partitions = self.deployment.consumers;
        }
        Ok(self)
    }

    pub fn load_file(self, path: &str) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        self.from_json(&j)
    }

    /// The client retry policy these knobs describe, or `None` when
    /// retries are disabled (`retry_max_attempts == 0` — the default,
    /// and the PR 7 client bit for bit).
    pub fn retry_policy(&self) -> Option<crate::pipeline::dc::RetryPolicy> {
        (self.retry_max_attempts > 0).then(|| crate::pipeline::dc::RetryPolicy {
            max_attempts: self.retry_max_attempts,
            base_backoff_us: self.retry_base_backoff_us.max(1),
            max_backoff_us: self.retry_max_backoff_us.max(self.retry_base_backoff_us.max(1)),
            request_timeout_us: self.retry_request_timeout_us.max(1),
            buffer_bytes: self.retry_buffer_bytes.max(0.0),
        })
    }
}

fn req_u64(v: &Json, key: &str) -> anyhow::Result<u64> {
    v.as_u64()
        .ok_or_else(|| anyhow::anyhow!("config key {key} must be a non-negative integer"))
}

fn req_f64(v: &Json, key: &str) -> anyhow::Result<f64> {
    v.as_f64()
        .ok_or_else(|| anyhow::anyhow!("config key {key} must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_deployments_validate() {
        Deployment::facerec_paper().validate().unwrap();
        Deployment::facerec_accel().validate().unwrap();
        Deployment::objdet_accel().validate().unwrap();
        Deployment::train_ingest().validate().unwrap();
        Deployment::rpc_service().validate().unwrap();
    }

    #[test]
    fn replication_cannot_exceed_brokers() {
        let mut d = Deployment::facerec_paper();
        d.brokers = 2;
        assert!(d.validate().is_err());
    }

    #[test]
    fn partitions_must_cover_consumers() {
        let mut d = Deployment::facerec_paper();
        d.partitions = d.consumers - 1;
        assert!(d.validate().is_err());
    }

    #[test]
    fn json_overlay() {
        let j = Json::parse(r#"{"producers": 10, "accel": 4.0, "protocol": "ai_share"}"#).unwrap();
        let c = Config::default().from_json(&j).unwrap();
        assert_eq!(c.deployment.producers, 10);
        assert_eq!(c.accel, 4.0);
        assert_eq!(c.protocol, AccelProtocol::AiShareOnly);
    }

    #[test]
    fn json_overlay_rejects_unknown_key() {
        let j = Json::parse(r#"{"producrs": 10}"#).unwrap();
        assert!(Config::default().from_json(&j).is_err());
    }

    #[test]
    fn consumer_increase_bumps_partitions() {
        let j = Json::parse(r#"{"consumers": 5000}"#).unwrap();
        let c = Config::default().from_json(&j).unwrap();
        assert!(c.deployment.partitions >= 5000);
    }
}
