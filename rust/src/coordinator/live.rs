//! The live (wall-clock) Face Recognition deployment.
//!
//! Topology mirrors Fig 4 at laptop scale: N ingest/detect threads →
//! broker substrate (in-process [`Controller`] guarded by a mutex — the
//! paper's three broker nodes collapse to one lock domain, which is fine
//! at demo scale) → M identification threads in one consumer group.
//!
//! Every stage measures the paper's Listing-1 events with wall-clock
//! timestamps, so the run produces a genuine Fig-6-style breakdown with
//! *real* inference, *real* bytes and *real* broker mechanics.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::broker::consumer::Consumer;
use crate::broker::controller::Controller;
use crate::broker::group::GroupCoordinator;
use crate::broker::producer::Producer;
use crate::broker::record::Record;
use crate::config::KafkaTuning;
use crate::metrics::breakdown::Breakdown;
use crate::metrics::event::{Event, EventKind, EventLog};
use crate::pipeline::frame::{Face, Frame};
use crate::pipeline::video::VideoSource;
use crate::runtime::engine::{Engine, FacePipeline};
use crate::runtime::tensor::Tensor;
use crate::storage::backend::{FileBackend, MemBackend, StorageBackend};
use crate::util::rng::Rng;

/// Live-run configuration.
#[derive(Clone, Debug)]
pub struct LiveConfig {
    pub producers: usize,
    pub consumers: usize,
    pub brokers: usize,
    pub replication: usize,
    pub partitions: u32,
    pub duration: Duration,
    /// Frames per second per producer (0 = as fast as inference allows).
    pub fps_limit: f64,
    /// Store broker segments on the real filesystem (vs in memory).
    pub file_backed: bool,
    /// Use the batched identification executable on the consumer side.
    pub batched_identify: bool,
    /// Produce byte-rate quota on the `faces` topic (bytes/sec; 0 =
    /// uncapped). Producers publish through
    /// [`Controller::produce_throttled`] and honor its Kafka-style mute
    /// delay wall-clock, so the live path shares the simulator's quota
    /// semantics (`broker::qos::TokenBucket`).
    pub produce_quota_bytes_per_sec: f64,
    pub tuning: KafkaTuning,
    pub seed: u64,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            producers: 2,
            consumers: 4,
            brokers: 3,
            replication: 3,
            partitions: 8,
            duration: Duration::from_secs(10),
            fps_limit: 0.0,
            file_backed: false,
            batched_identify: false,
            produce_quota_bytes_per_sec: 0.0,
            tuning: KafkaTuning {
                // Live scale is tiny; shorten the timers accordingly.
                linger_us: 4_000,
                fetch_max_wait_us: 10_000,
                fetch_min_bytes: 1,
                ..KafkaTuning::default()
            },
            seed: 0xFACE,
        }
    }
}

/// Results of a live run.
#[derive(Clone, Debug)]
pub struct LiveReport {
    pub breakdown: Breakdown,
    pub frames: u64,
    pub faces_produced: u64,
    pub faces_identified: u64,
    pub elapsed: Duration,
    /// Total bytes appended across all replica logs (3x amplification).
    pub broker_log_bytes: u64,
    pub throughput_fps: f64,
    pub identities: Vec<(u32, u64)>,
}

/// Shared run state.
struct Shared {
    controller: Mutex<Controller>,
    group: Mutex<GroupCoordinator>,
    log: Mutex<EventLog>,
    stop: AtomicBool,
    frames: AtomicU64,
    faces_produced: AtomicU64,
    faces_identified: AtomicU64,
    /// Wall-clock epoch for event timestamps.
    epoch: Instant,
}

impl Shared {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// Orchestrates a live run.
pub struct LiveRunner {
    cfg: LiveConfig,
}

impl LiveRunner {
    pub fn new(cfg: LiveConfig) -> LiveRunner {
        LiveRunner { cfg }
    }

    pub fn run(&self) -> Result<LiveReport> {
        let cfg = &self.cfg;
        anyhow::ensure!(cfg.partitions as usize >= cfg.consumers);

        // Broker substrate.
        let mut controller = Controller::new(8 * 1024 * 1024);
        let log_dir = std::env::temp_dir().join(format!("aitax-live-{}", std::process::id()));
        for b in 0..cfg.brokers {
            let backend: Box<dyn StorageBackend> = if cfg.file_backed {
                Box::new(FileBackend::new(log_dir.join(format!("broker-{b}")))?)
            } else {
                Box::new(MemBackend::new())
            };
            controller.add_broker(b as u32, backend);
        }
        controller.create_topic("faces", cfg.partitions, cfg.replication as u32)?;
        if cfg.produce_quota_bytes_per_sec > 0.0 {
            controller.set_topic_quota("faces", cfg.produce_quota_bytes_per_sec);
        }

        let shared = Arc::new(Shared {
            controller: Mutex::new(controller),
            group: Mutex::new(GroupCoordinator::new("faces", cfg.partitions)),
            log: Mutex::new(EventLog::new()),
            stop: AtomicBool::new(false),
            frames: AtomicU64::new(0),
            faces_produced: AtomicU64::new(0),
            faces_identified: AtomicU64::new(0),
            epoch: Instant::now(),
        });
        let identity_counts = Arc::new(Mutex::new(vec![0u64; 64]));

        std::thread::scope(|scope| -> Result<()> {
            // ---- producers (ingest/detect containers) ----
            for p in 0..cfg.producers {
                let shared = Arc::clone(&shared);
                let cfg = cfg.clone();
                scope.spawn(move || {
                    if let Err(e) = producer_loop(p as u64, &cfg, &shared) {
                        eprintln!("producer {p} failed: {e:#}");
                        shared.stop.store(true, Ordering::SeqCst);
                    }
                });
            }
            // ---- consumers (identification containers) ----
            for c in 0..cfg.consumers {
                let shared = Arc::clone(&shared);
                let cfg = cfg.clone();
                let ids = Arc::clone(&identity_counts);
                scope.spawn(move || {
                    if let Err(e) = consumer_loop(c as u64, &cfg, &shared, &ids) {
                        eprintln!("consumer {c} failed: {e:#}");
                        shared.stop.store(true, Ordering::SeqCst);
                    }
                });
            }
            std::thread::sleep(cfg.duration);
            shared.stop.store(true, Ordering::SeqCst);
            Ok(())
        })?;

        if cfg.file_backed {
            let _ = std::fs::remove_dir_all(&log_dir);
        }

        let log = shared.log.lock().unwrap();
        let breakdown = Breakdown::from_log(
            &log,
            &[
                EventKind::Ingestion,
                EventKind::FaceDetection,
                EventKind::BrokerWait,
                EventKind::Identification,
            ],
        );
        let elapsed = shared.epoch.elapsed();
        let faces_identified = shared.faces_identified.load(Ordering::SeqCst);
        let controller = shared.controller.lock().unwrap();
        let counts = identity_counts.lock().unwrap();
        Ok(LiveReport {
            breakdown,
            frames: shared.frames.load(Ordering::SeqCst),
            faces_produced: shared.faces_produced.load(Ordering::SeqCst),
            faces_identified,
            elapsed,
            broker_log_bytes: controller.total_log_bytes(),
            throughput_fps: shared.frames.load(Ordering::SeqCst) as f64 / elapsed.as_secs_f64(),
            identities: counts
                .iter()
                .enumerate()
                .filter(|(_, &n)| n > 0)
                .map(|(i, &n)| (i as u32, n))
                .collect(),
        })
    }
}

/// Honor a quota mute delay without overshooting shutdown: sleep in
/// short slices and bail as soon as the run's stop flag is set (a tiny
/// quota can return mute delays far longer than the run itself).
fn throttle_sleep(shared: &Shared, throttle_us: u64) {
    let mut left = throttle_us;
    while left > 0 && !shared.stop.load(Ordering::SeqCst) {
        let slice = left.min(50_000);
        std::thread::sleep(Duration::from_micros(slice));
        left -= slice;
    }
}

/// Generate frames, run preprocess+detect inference, publish faces.
fn producer_loop(id: u64, cfg: &LiveConfig, shared: &Shared) -> Result<()> {
    let engine = Engine::load_producer_side()
        .context("loading artifacts (run `make artifacts`)")?;
    let pipe = FacePipeline::new(engine);
    let mut rng = Rng::new(cfg.seed ^ (id << 8));
    let mut video = VideoSource::new(Default::default(), rng.fork());
    let mut producer = Producer::new("faces", cfg.partitions, cfg.tuning.clone());
    let side = pipe.engine.manifest.frame_side as u32;
    let mut frame_id = id << 40;

    while !shared.stop.load(Ordering::SeqCst) {
        let cycle_start = Instant::now();
        // ---- ingestion: synthesize + resize ----
        let t0 = shared.now_us();
        let n_faces = video.next_faces();
        let centers: Vec<(u32, u32)> = (0..n_faces)
            .map(|_| {
                let m = side - side / 8 - 4;
                (4 + rng.below((m - 4) as u64) as u32, 4 + rng.below((m - 4) as u64) as u32)
            })
            .collect();
        let frame = Frame::synthetic(frame_id, id as u32, t0, side, &centers);
        frame_id += 1;
        let tensor = Tensor::new(
            vec![side as usize, side as usize, 3],
            frame.pixels.clone(),
        );
        let image = pipe.preprocess(&tensor)?;
        let t1 = shared.now_us();

        // ---- face detection (AI) + crop (support code) ----
        let dets = pipe.detect(&image)?;
        let faces: Vec<Face> = dets
            .iter()
            .map(|d| {
                let thumb = pipe.crop_thumb(&image, d);
                Face {
                    frame_id: frame.id,
                    stream: id as u32,
                    detected_at_us: 0, // stamped below, after detect ends
                    thumbnail: thumb.data,
                    wire_bytes: 0,
                }
            })
            .collect();
        let t2 = shared.now_us();
        shared.frames.fetch_add(1, Ordering::Relaxed);
        {
            let mut log = shared.log.lock().unwrap();
            log.log(Event {
                kind: EventKind::Ingestion,
                frame_id: frame.id,
                start_us: t0,
                compute_us: t1 - t0,
                face_count: dets.len() as u32,
                data_bytes: frame.bytes() as u64,
            });
            log.log(Event {
                kind: EventKind::FaceDetection,
                frame_id: frame.id,
                start_us: t1,
                compute_us: t2 - t1,
                face_count: dets.len() as u32,
                data_bytes: faces.iter().map(|f| f.payload_bytes() as u64).sum(),
            });
        }

        // ---- publish through the broker client ----
        // The quota-aware produce path: every batch goes through
        // `produce_throttled`, and a non-zero throttle mutes this
        // producer for the delay (Kafka's throttled-channel semantics),
        // honored wall-clock *outside* the controller lock.
        for mut face in faces {
            face.detected_at_us = t2;
            let payload = face.encode();
            shared.faces_produced.fetch_add(1, Ordering::Relaxed);
            if let Some(batch) = producer.send(Record::new(face.frame_id, t2, payload), shared.now_us())
            {
                let throttle_us = {
                    let mut ctl = shared.controller.lock().unwrap();
                    ctl.produce_throttled(&batch.tp, &batch.batch, shared.now_us())?.1
                };
                throttle_sleep(shared, throttle_us);
            }
        }
        for batch in producer.poll(shared.now_us()) {
            let throttle_us = {
                let mut ctl = shared.controller.lock().unwrap();
                ctl.produce_throttled(&batch.tp, &batch.batch, shared.now_us())?.1
            };
            throttle_sleep(shared, throttle_us);
        }

        // ---- optional frame pacing ----
        if cfg.fps_limit > 0.0 {
            let period = Duration::from_secs_f64(1.0 / cfg.fps_limit);
            if let Some(rest) = period.checked_sub(cycle_start.elapsed()) {
                std::thread::sleep(rest);
            }
        }
    }
    // Flush the tail so consumers can drain. Still metered through the
    // quota bucket, but the run is over — no further sends exist for a
    // mute delay to pace, so the tail drains without sleeping.
    for batch in producer.flush() {
        let mut ctl = shared.controller.lock().unwrap();
        ctl.produce_throttled(&batch.tp, &batch.batch, shared.now_us())?;
    }
    Ok(())
}

/// Fetch faces from the group's partitions and run identification.
fn consumer_loop(
    id: u64,
    cfg: &LiveConfig,
    shared: &Shared,
    identity_counts: &Mutex<Vec<u64>>,
) -> Result<()> {
    let engine = Engine::load_consumer_side()?;
    let pipe = FacePipeline::new(engine);
    let mut consumer = Consumer::new(cfg.tuning.clone());
    let mut generation = 0;
    {
        let mut group = shared.group.lock().unwrap();
        group.join(id);
    }
    let thumb_side = pipe.engine.manifest.thumb_side;

    loop {
        // Refresh assignment on rebalance.
        {
            let group = shared.group.lock().unwrap();
            if group.generation() != generation {
                generation = group.generation();
                consumer.assign(group.assignment(id).to_vec());
            }
        }
        // Poll the broker.
        let now = shared.now_us();
        let (records, wait_hint) = {
            let mut ctl = shared.controller.lock().unwrap();
            consumer.poll(&mut ctl, now)?
        };
        if records.is_empty() {
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let hint_us = wait_hint
                .map(|t| t.saturating_sub(now).clamp(200, 20_000))
                .unwrap_or(1_000);
            std::thread::sleep(Duration::from_micros(hint_us));
            continue;
        }
        // Decode + identify, batched or one-by-one.
        let faces: Vec<Face> = records
            .iter()
            .map(|r| Face::decode(&r.payload))
            .collect::<Result<_>>()?;
        let fetch_done = shared.now_us();
        let run_batch = cfg.batched_identify && faces.len() > 1;
        if run_batch {
            for chunk in faces.chunks(pipe.engine.manifest.batch) {
                let t_start = shared.now_us();
                let thumbs: Vec<Tensor> = chunk
                    .iter()
                    .map(|f| Tensor::new(vec![thumb_side, thumb_side, 3], f.thumbnail.clone()))
                    .collect();
                let results = pipe.identify_batch(&thumbs)?;
                let t_end = shared.now_us();
                let per_face = (t_end - t_start) / chunk.len() as u64;
                let mut log = shared.log.lock().unwrap();
                let mut ids = identity_counts.lock().unwrap();
                for (face, (person, _score)) in chunk.iter().zip(&results) {
                    log.log(Event {
                        kind: EventKind::BrokerWait,
                        frame_id: face.frame_id,
                        start_us: face.detected_at_us,
                        compute_us: fetch_done.saturating_sub(face.detected_at_us),
                        face_count: 1,
                        data_bytes: face.payload_bytes() as u64,
                    });
                    log.log(Event {
                        kind: EventKind::Identification,
                        frame_id: face.frame_id,
                        start_us: t_start,
                        compute_us: per_face,
                        face_count: 1,
                        data_bytes: face.payload_bytes() as u64,
                    });
                    let slot = *person % ids.len();
                    ids[slot] += 1;
                }
                shared
                    .faces_identified
                    .fetch_add(chunk.len() as u64, Ordering::Relaxed);
            }
        } else {
            for face in &faces {
                let t_start = shared.now_us();
                let thumb = Tensor::new(vec![thumb_side, thumb_side, 3], face.thumbnail.clone());
                let (_emb, person, _score) = pipe.identify(&thumb)?;
                let t_end = shared.now_us();
                {
                    let mut log = shared.log.lock().unwrap();
                    log.log(Event {
                        kind: EventKind::BrokerWait,
                        frame_id: face.frame_id,
                        start_us: face.detected_at_us,
                        compute_us: t_start.saturating_sub(face.detected_at_us),
                        face_count: 1,
                        data_bytes: face.payload_bytes() as u64,
                    });
                    log.log(Event {
                        kind: EventKind::Identification,
                        frame_id: face.frame_id,
                        start_us: t_start,
                        compute_us: t_end - t_start,
                        face_count: 1,
                        data_bytes: face.payload_bytes() as u64,
                    });
                    let mut ids = identity_counts.lock().unwrap();
                    let slot = person % ids.len();
                    ids[slot] += 1;
                }
                shared.faces_identified.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    let mut group = shared.group.lock().unwrap();
    group.leave(id);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn short_live_run_end_to_end() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let cfg = LiveConfig {
            producers: 1,
            consumers: 2,
            partitions: 4,
            duration: Duration::from_secs(10),
            ..LiveConfig::default()
        };
        let report = LiveRunner::new(cfg).run().expect("live run");
        assert!(report.frames > 2, "frames={}", report.frames);
        // Faces flow all the way through (0.64/frame on average).
        assert!(report.faces_produced > 0);
        assert!(
            report.faces_identified as f64 >= 0.5 * report.faces_produced as f64,
            "identified {} of {}",
            report.faces_identified,
            report.faces_produced
        );
        // 3x replication amplification is visible in the broker logs.
        assert!(report.broker_log_bytes > 0);
        // All four stages produced events.
        for kind in [
            EventKind::Ingestion,
            EventKind::FaceDetection,
            EventKind::Identification,
        ] {
            assert!(
                report.breakdown.stage_mean(kind) > 0.0,
                "no events for {kind:?}"
            );
        }
    }

    #[test]
    fn produce_quota_caps_live_wire_bytes() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let quota = 200_000.0; // bytes/sec on the faces topic
        let secs = 6u64;
        let cfg = LiveConfig {
            producers: 1,
            consumers: 1,
            partitions: 2,
            duration: Duration::from_secs(secs),
            produce_quota_bytes_per_sec: quota,
            ..LiveConfig::default()
        };
        let report = LiveRunner::new(cfg).run().expect("live run");
        // The pipeline still flows under the cap...
        assert!(report.faces_produced > 0);
        // ...but the broker log (client bytes x3 replication) tracks the
        // quota instead of the uncapped inference rate. x2 slack covers
        // the 200 ms burst allowance, framing, and the flush tail.
        let budget = quota * secs as f64 * 3.0;
        assert!(
            (report.broker_log_bytes as f64) < budget * 2.0,
            "log bytes {} must track the {} B/s quota",
            report.broker_log_bytes,
            quota
        );
    }

    #[test]
    fn file_backed_run_writes_real_segments() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let cfg = LiveConfig {
            producers: 1,
            consumers: 1,
            partitions: 2,
            brokers: 3,
            duration: Duration::from_secs(8),
            file_backed: true,
            ..LiveConfig::default()
        };
        let report = LiveRunner::new(cfg).run().expect("live run");
        assert!(report.faces_identified > 0);
        assert!(report.broker_log_bytes > 10_000);
    }
}
