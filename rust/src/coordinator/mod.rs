//! Live-mode coordination: the three-layer stack running for real.
//!
//! Threads stand in for the paper's containers: producer threads generate
//! synthetic video frames and run *real* PJRT inference (preprocess +
//! detect), publish face thumbnails through the real broker substrate
//! (`broker::Controller` + linger-batching `Producer` clients, 3x
//! replication, real segment files when a `FileBackend` is used), and
//! consumer threads fetch with real `fetch.min.bytes` semantics and run
//! identification inference. Python never runs.

pub mod live;

pub use live::{LiveConfig, LiveReport, LiveRunner};
