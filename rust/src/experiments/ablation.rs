//! Ablations of the design knobs the paper calls out but does not sweep.
//!
//! §5.5: "Both batching behaviors are limited by timeouts ... We have
//! tuned these parameters to find settings that ensure good behavior" —
//! [`tuning_sweep`] maps that tradeoff (linger / fetch.max.wait vs wait
//! latency vs broker request load).
//!
//! §3.4/§4.2: 3× replication is "standard practice for disaster
//! recovery" — [`replication_sweep`] prices that durability in storage
//! bandwidth and in the acceleration ceiling.
//!
//! §7.1 footnote: faster storage media (Optane) as the fourth mitigation —
//! [`storage_media_sweep`].

use crate::config::NvmeSpec;
use crate::experiments::common::{facerec_accel, facerec_baseline, Fidelity};
use crate::experiments::runner;
use crate::pipeline::facerec::{FaceRecSim, SimReport};

/// One Kafka-tuning ablation point.
#[derive(Clone, Debug)]
pub struct TuningPoint {
    pub linger_ms: u64,
    pub fetch_wait_ms: u64,
    pub wait_mean_us: f64,
    pub e2e_mean_us: f64,
    pub broker_cpu_util: f64,
}

pub fn tuning_sweep(fidelity: Fidelity) -> Vec<TuningPoint> {
    let grid = vec![(1u64, 5u64), (10, 15), (30, 45), (100, 150)];
    runner::map(grid, |(linger_ms, fetch_ms)| {
        let mut cfg = facerec_baseline(fidelity);
        cfg.tuning.linger_us = linger_ms * 1000;
        cfg.tuning.fetch_max_wait_us = fetch_ms * 1000;
        let r = FaceRecSim::new(cfg).run();
        TuningPoint {
            linger_ms,
            fetch_wait_ms: fetch_ms,
            wait_mean_us: r.wait_mean_us,
            e2e_mean_us: r.e2e_mean_us,
            broker_cpu_util: r.broker_cpu_util,
        }
    })
}

/// Replication-factor ablation at a given acceleration.
pub fn replication_sweep(k: f64, fidelity: Fidelity) -> Vec<(usize, SimReport)> {
    runner::map(vec![1usize, 2, 3], |repl| {
        let mut cfg = facerec_accel(k, fidelity);
        cfg.deployment.replication = repl;
        (repl, FaceRecSim::new(cfg).run())
    })
}

/// Storage-media ablation (P4510 vs Optane-class) across acceleration.
pub fn storage_media_sweep(fidelity: Fidelity) -> Vec<(&'static str, f64, SimReport)> {
    let grid: Vec<(&'static str, NvmeSpec, f64)> =
        [("P4510", NvmeSpec::p4510_1tb()), ("Optane", NvmeSpec::optane())]
            .into_iter()
            .flat_map(|(name, nvme)| [8.0, 16.0, 32.0].map(|k| (name, nvme, k)))
            .collect();
    runner::map(grid, |(name, nvme, k)| {
        let mut cfg = facerec_accel(k, fidelity);
        cfg.node.nvme = nvme;
        (name, k, FaceRecSim::new(cfg).run())
    })
}

pub fn print_tuning(points: &[TuningPoint]) {
    println!("\nAblation — Kafka timer tuning (baseline deployment)");
    println!(
        "  {:>10} {:>12} {:>12} {:>12} {:>12}",
        "linger", "fetch wait", "broker wait", "e2e", "broker cpu"
    );
    for p in points {
        println!(
            "  {:>8}ms {:>10}ms {:>10.1}ms {:>10.1}ms {:>11.1}%",
            p.linger_ms,
            p.fetch_wait_ms,
            p.wait_mean_us / 1000.0,
            p.e2e_mean_us / 1000.0,
            100.0 * p.broker_cpu_util
        );
    }
    println!("  (shorter timers cut wait latency but raise broker request load — §5.5's tradeoff)");
}

pub fn print_replication(rows: &[(usize, SimReport)], k: f64) {
    println!("\nAblation — replication factor at {k}x acceleration");
    println!(
        "  {:>6} {:>14} {:>12} {:>8}",
        "repl", "storage write", "e2e", "stable?"
    );
    for (repl, r) in rows {
        println!(
            "  {:>6} {:>13.1}% {:>12} {:>8}",
            repl,
            100.0 * r.storage_write_util,
            crate::experiments::common::fmt_latency(r.verdict.latency_or_inf(r.e2e_mean_us as u64)),
            if r.verdict.stable { "yes" } else { "NO" }
        );
    }
    println!("  (the paper's 3x 'data reliability safeguard' is what saturates storage at 8x)");
}

pub fn print_storage_media(rows: &[(&'static str, f64, SimReport)]) {
    println!("\nAblation — storage media (§7.1's 'faster storage medium' option)");
    println!("  {:>8} {:>5} {:>14} {:>8}", "media", "k", "storage write", "stable?");
    for (name, k, r) in rows {
        println!(
            "  {:>8} {:>5} {:>13.1}% {:>8}",
            name,
            k,
            100.0 * r.storage_write_util,
            if r.verdict.stable { "yes" } else { "NO" }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shorter_timers_cut_wait() {
        let pts = tuning_sweep(Fidelity::Quick);
        assert!(pts[0].wait_mean_us < pts[3].wait_mean_us,
            "1ms timers {} should beat 100ms timers {}",
            pts[0].wait_mean_us, pts[3].wait_mean_us);
        // And the longest timers still keep the system stable.
        assert!(pts[3].e2e_mean_us > 0.0);
    }

    #[test]
    fn replication_is_the_storage_multiplier() {
        let rows = replication_sweep(6.0, Fidelity::Quick);
        let u1 = rows[0].1.storage_write_util;
        let u3 = rows[2].1.storage_write_util;
        assert!((u3 / u1 - 3.0).abs() < 0.6, "u1={u1} u3={u3}");
    }

    #[test]
    fn optane_lifts_the_ceiling() {
        let rows = storage_media_sweep(Fidelity::Quick);
        let p4510_16x = rows.iter().find(|(n, k, _)| *n == "P4510" && *k == 16.0).unwrap();
        let optane_16x = rows.iter().find(|(n, k, _)| *n == "Optane" && *k == 16.0).unwrap();
        assert!(!p4510_16x.2.verdict.stable);
        assert!(optane_16x.2.verdict.stable);
    }
}
