//! Cascade: the cascading-failure resilience sweep
//! (`aitax experiment cascade`).
//!
//! The failover sweep measures one crash on an otherwise healthy
//! fabric. This sweep measures the case operators actually plan for: a
//! *correlated* second failure — both surviving brokers down — landing
//! while the first victim is still replaying its backlog
//! ([`crate::pipeline::cascade`]). For a window the cluster has no
//! in-sync replica at all, and what happens next is pure policy:
//!
//! * **retry arm** — off: the PR 7 client, every rejected produce is a
//!   permanently lost record. On ([`CascadeSpec::default_retry`]):
//!   producers buffer and re-offer with exponential backoff against an
//!   idempotent (deduplicating) fabric, converting outage loss into
//!   bounded tail-latency inflation plus `client_dropped` overflow.
//! * **election arm** — `Clean`: the leaderless partitions refuse all
//!   produces until a victim restarts (availability gap, zero loss).
//!   `Unclean`: the catching-up first victim is elected leader and its
//!   un-replayed backlog is discarded, counted byte-for-byte in
//!   `unclean_lost_bytes` (availability now, loss measured).
//! * **kill gap** — how far the first victim's catch-up has progressed
//!   when the second failure lands; the unclean divergence shrinks
//!   monotonically as the gap grows.
//!
//! Every point carries the extended conservation residual
//! ([`FaultReport::conservation_residual`]) — offered records minus
//! retries must equal commits + final rejections + losses + in-flight +
//! client drops, u64-exact, or the accounting (not the simulation) is
//! wrong. CI gates on residual 0 across all eight points.
//!
//! `run` returns structured results; [`print`] renders the table plus a
//! machine-readable JSON report (written to
//! `artifacts/cascade_report.json` when the artifacts directory is
//! present).
//!
//! [`FaultReport::conservation_residual`]: crate::pipeline::mixed::FaultReport::conservation_residual

use crate::config::Config;
use crate::experiments::common::Fidelity;
use crate::experiments::runner;
use crate::pipeline::cascade::{self, CascadeSpec, FIRST_VICTIM, OBSERVE_TAIL_US};
use crate::pipeline::catchup;
use crate::pipeline::fabric::ElectionPolicy;
use crate::pipeline::mixed::MultiTenantReport;
use crate::util::json::Json;
use crate::util::units::{fmt_us, SEC};

/// Gaps between the first victim's restart and the correlated second
/// kill: early (catch-up barely started, maximal unclean divergence)
/// and late (mostly caught up, minimal divergence).
pub const KILL_GAPS_US: [u64; 2] = [SEC / 2, 5 * SEC / 2];
/// First kill / restart instants — fixed, so the swept gap is the only
/// thing moving the second failure.
pub const FIRST_KILL_US: u64 = 5 * SEC;
pub const FIRST_RESTART_US: u64 = 6 * SEC;
/// How long the correlated outage lasts before brokers 0 and 2 return.
pub const OUTAGE_US: u64 = SEC;
/// Re-replication pacing — above the world's ongoing write rate so
/// every arm's recovery converges inside the horizon.
pub const RECOVERY_BYTES_PER_SEC: f64 = 1.2e9;
/// Per-broker page cache (same sizing rationale as the failover sweep).
pub const CACHE_BYTES: f64 = 2e9;

/// One sweep point: kill gap × retry arm × election policy.
pub struct CascadePoint {
    pub kill_gap_us: u64,
    pub retry: bool,
    pub unclean: bool,
    pub report: MultiTenantReport,
}

impl CascadePoint {
    /// The rpc canary's e2e p99 over the outage window (µs).
    pub fn rpc_window_p99_us(&self) -> u64 {
        self.report
            .tenant("rpc")
            .map(|t| t.e2e_p99_window_us)
            .unwrap_or(0)
    }

    /// The extended conservation residual — must be 0 on every point.
    pub fn conservation_residual(&self) -> i64 {
        self.report
            .fault
            .as_ref()
            .map(|f| f.conservation_residual())
            .unwrap_or(0)
    }
}

/// The full sweep plus the RPC tenant's SLO for verdicts.
pub struct CascadeSweep {
    pub slo_p99_us: u64,
    pub horizon_us: u64,
    pub points: Vec<CascadePoint>,
}

impl CascadeSweep {
    pub fn point(&self, kill_gap_us: u64, retry: bool, unclean: bool) -> Option<&CascadePoint> {
        self.points
            .iter()
            .find(|p| p.kill_gap_us == kill_gap_us && p.retry == retry && p.unclean == unclean)
    }
}

fn spec_for(kill_gap_us: u64, retry: bool, unclean: bool) -> CascadeSpec {
    CascadeSpec {
        first_kill_at_us: FIRST_KILL_US,
        first_restart_at_us: FIRST_RESTART_US,
        kill_gap_us,
        outage_us: OUTAGE_US,
        retry: retry.then(CascadeSpec::default_retry),
        election: if unclean {
            ElectionPolicy::Unclean
        } else {
            ElectionPolicy::Clean
        },
        classed: true,
        recovery_bytes_per_sec: RECOVERY_BYTES_PER_SEC,
        cache_bytes: CACHE_BYTES,
    }
}

/// Run an explicit set of `(kill_gap_us, retry, unclean)` points, fanned
/// out over the deterministic parallel runner.
pub fn run_points(points: Vec<(u64, bool, bool)>, fidelity: Fidelity) -> CascadeSweep {
    let slo_p99_us = Config::default().calibration.rpc.slo_p99_us;
    let horizon = fidelity.horizon_us();
    let points = runner::map(points, move |(kill_gap_us, retry, unclean)| CascadePoint {
        kill_gap_us,
        retry,
        unclean,
        report: cascade::run(spec_for(kill_gap_us, retry, unclean), horizon),
    });
    CascadeSweep { slo_p99_us, horizon_us: horizon, points }
}

/// Run the sweep over the gap × retry × election grid (8 points).
pub fn run_grid(kill_gaps_us: &[u64], fidelity: Fidelity) -> CascadeSweep {
    let grid: Vec<(u64, bool, bool)> = kill_gaps_us
        .iter()
        .flat_map(|&gap| {
            [false, true]
                .into_iter()
                .flat_map(move |retry| [(gap, retry, false), (gap, retry, true)])
        })
        .collect();
    run_points(grid, fidelity)
}

pub fn run(fidelity: Fidelity) -> CascadeSweep {
    run_grid(&KILL_GAPS_US, fidelity)
}

/// The machine-readable report.
pub fn to_json(sweep: &CascadeSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("cascade".into())),
        ("slo_p99_us", Json::Num(sweep.slo_p99_us as f64)),
        ("horizon_us", Json::Num(sweep.horizon_us as f64)),
        ("first_victim", Json::Num(FIRST_VICTIM as f64)),
        ("first_kill_us", Json::Num(FIRST_KILL_US as f64)),
        ("first_restart_us", Json::Num(FIRST_RESTART_US as f64)),
        ("outage_us", Json::Num(OUTAGE_US as f64)),
        ("observe_tail_us", Json::Num(OBSERVE_TAIL_US as f64)),
        ("accel_facerec", Json::Num(catchup::ACCEL_FACEREC)),
        (
            "points",
            Json::arr(sweep.points.iter().map(point_json).collect()),
        ),
    ])
}

fn point_json(p: &CascadePoint) -> Json {
    let f = p.report.fault.as_ref();
    Json::obj(vec![
        ("kill_gap_us", Json::Num(p.kill_gap_us as f64)),
        ("retry", Json::Bool(p.retry)),
        (
            "election",
            Json::Str(if p.unclean { "unclean" } else { "clean" }.into()),
        ),
        ("conservation_residual", Json::Num(p.conservation_residual() as f64)),
        ("rpc_window_p99_us", Json::Num(p.rpc_window_p99_us() as f64)),
        (
            "records_committed",
            Json::Num(f.map(|f| f.records_committed).unwrap_or(0) as f64),
        ),
        (
            "retries",
            Json::Num(f.map(|f| f.records_retried).unwrap_or(0) as f64),
        ),
        (
            "records_rejected_final",
            Json::Num(f.map(|f| f.records_rejected_final).unwrap_or(0) as f64),
        ),
        (
            "client_dropped",
            Json::Num(f.map(|f| f.records_client_dropped).unwrap_or(0) as f64),
        ),
        (
            "dedup_suppressed",
            Json::Num(f.map(|f| f.records_dedup_suppressed).unwrap_or(0) as f64),
        ),
        (
            "records_lost",
            Json::Num(f.map(|f| f.records_lost).unwrap_or(0) as f64),
        ),
        (
            "unclean_elections",
            Json::Num(f.map(|f| f.unclean_elections).unwrap_or(0) as f64),
        ),
        (
            "unclean_lost_bytes",
            Json::Num(f.map(|f| f.unclean_lost_bytes).unwrap_or(0.0)),
        ),
        (
            "min_isr_violations",
            Json::Num(f.map(|f| f.min_isr_violations).unwrap_or(0) as f64),
        ),
        (
            "metrics",
            crate::metrics::registry::MetricsRegistry::from_report(&p.report).to_json(),
        ),
        (
            "tenants",
            Json::arr(
                p.report
                    .tenants
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("name", Json::Str(t.name.clone())),
                            ("completed", Json::Num(t.completed as f64)),
                            ("retries", Json::Num(t.retries as f64)),
                            ("client_dropped", Json::Num(t.client_dropped as f64)),
                            (
                                "e2e_p99_window_us",
                                Json::Num(t.e2e_p99_window_us as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the JSON report next to the AOT artifacts when that directory
/// exists (same lookup as the other sweep drivers).
fn write_report(json: &Json) -> Option<std::path::PathBuf> {
    let dir = crate::runtime::Manifest::default_dir();
    if !dir.is_dir() {
        return None;
    }
    let path = dir.join("cascade_report.json");
    std::fs::write(&path, json.pretty()).ok()?;
    Some(path)
}

pub fn print(sweep: &CascadeSweep) {
    println!(
        "\nCascade — broker {} killed at {}, back at {}; brokers 0+2 both killed \
         gap after the restart, back {} later; {{retry off/on}} x {{clean, unclean}}",
        FIRST_VICTIM,
        fmt_us(FIRST_KILL_US),
        fmt_us(FIRST_RESTART_US),
        fmt_us(OUTAGE_US),
    );
    println!(
        "  rpc SLO: e2e p99 <= {} over the outage window (2nd kill, +{})",
        fmt_us(sweep.slo_p99_us),
        fmt_us(OBSERVE_TAIL_US),
    );
    println!(
        "  {:>6} {:>5} {:>7} {:>12} {:>9} {:>9} {:>8} {:>9} {:>10} {:>5}",
        "gap", "retry", "elect", "rpc p99(w)", "retries", "rej(fin)", "dropped", "dedup", "unclean", "resid"
    );
    for p in &sweep.points {
        let f = p.report.fault.as_ref();
        let rpc_p99 = p.rpc_window_p99_us();
        println!(
            "  {:>6} {:>5} {:>7} {:>10}{} {:>9} {:>9} {:>8} {:>9} {:>9}M {:>5}",
            fmt_us(p.kill_gap_us),
            if p.retry { "on" } else { "off" },
            if p.unclean { "unclean" } else { "clean" },
            fmt_us(rpc_p99),
            if rpc_p99 <= sweep.slo_p99_us { " " } else { "!" },
            f.map(|f| f.records_retried).unwrap_or(0),
            f.map(|f| f.records_rejected_final).unwrap_or(0),
            f.map(|f| f.records_client_dropped).unwrap_or(0),
            f.map(|f| f.records_dedup_suppressed).unwrap_or(0),
            f.map(|f| (f.unclean_lost_bytes / 1e6) as u64).unwrap_or(0),
            p.conservation_residual(),
        );
    }
    println!(
        "  takeaway: the double kill converts the fabric's loss model into \
         client policy — retries turn outage rejections into delayed commits \
         (p99 inflation, not loss), and unclean election buys availability \
         during the gap at a measured, monotone-in-gap byte cost"
    );
    let json = to_json(sweep);
    match write_report(&json) {
        Some(path) => println!("  json report written to {}", path.display()),
        None => println!("  json report:\n{}", json.pretty()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_arm_saves_records_and_conserves() {
        let sweep = run_points(
            vec![(SEC / 2, false, false), (SEC / 2, true, false)],
            Fidelity::Quick,
        );
        let bare = sweep.point(SEC / 2, false, false).unwrap();
        let armed = sweep.point(SEC / 2, true, false).unwrap();
        let fb = bare.report.fault.as_ref().unwrap();
        let fa = armed.report.fault.as_ref().unwrap();
        assert!(fa.records_retried > 0, "the outage must trigger retries");
        assert!(
            fa.records_rejected_final + fa.records_client_dropped < fb.records_rejected_final,
            "retries must convert final rejections into commits"
        );
        for p in &sweep.points {
            assert_eq!(p.conservation_residual(), 0, "identity must close");
            let f = p.report.fault.as_ref().unwrap();
            assert_eq!(f.min_isr_violations, 0, "no commit below quorum, ever");
        }
    }

    #[test]
    fn json_report_carries_every_point_and_tenant() {
        let sweep = run_points(vec![(SEC / 2, true, true)], Fidelity::Quick);
        let j = to_json(&sweep);
        let points = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 1);
        for p in points {
            let tenants = p.get("tenants").and_then(|t| t.as_arr()).unwrap();
            assert_eq!(tenants.len(), 3);
            assert_eq!(
                p.get("conservation_residual").and_then(|v| v.as_f64()),
                Some(0.0)
            );
            assert!(p.get("unclean_lost_bytes").and_then(|v| v.as_f64()).is_some());
            assert_eq!(p.get("election").and_then(|e| e.as_str()), Some("unclean"));
        }
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            reparsed.get("experiment").and_then(|e| e.as_str()),
            Some("cascade")
        );
    }
}
