//! Shared experiment plumbing.

use crate::config::{Config, Deployment};
use crate::util::units::SEC;

/// Experiment fidelity: quick runs for CI/tests, full runs for benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fidelity {
    Quick,
    Full,
}

impl Fidelity {
    pub fn from_env() -> Fidelity {
        if std::env::var("AITAX_QUICK").is_ok() {
            Fidelity::Quick
        } else {
            Fidelity::Full
        }
    }

    /// Simulation horizon in microseconds.
    pub fn horizon_us(&self) -> u64 {
        match self {
            Fidelity::Quick => 20 * SEC,
            Fidelity::Full => 30 * SEC,
        }
    }
}

/// Baseline §4.2 Face Recognition config.
pub fn facerec_baseline(fidelity: Fidelity) -> Config {
    let mut cfg = Config::default();
    cfg.deployment = Deployment::facerec_paper();
    cfg.duration_us = fidelity.horizon_us();
    cfg.seed = 0xBEEF;
    cfg
}

/// §5.3 acceleration-emulation config at factor `k`.
pub fn facerec_accel(k: f64, fidelity: Fidelity) -> Config {
    let mut cfg = Config::default();
    cfg.deployment = Deployment::facerec_accel();
    cfg.duration_us = fidelity.horizon_us();
    cfg.accel = k;
    cfg.seed = 0xACCE1;
    cfg
}

/// §6.3 Object Detection config at factor `k`.
pub fn objdet_accel(k: f64, fidelity: Fidelity) -> Config {
    let mut cfg = Config::default();
    cfg.deployment = Deployment::objdet_accel();
    cfg.duration_us = fidelity.horizon_us();
    cfg.accel = k;
    cfg.seed = 0xD07;
    cfg
}

/// Format an optional latency, `None` printing as the paper's "∞" bars.
pub fn fmt_latency(lat: Option<u64>) -> String {
    match lat {
        Some(us) => crate::util::units::fmt_us(us),
        None => "∞ (unstable)".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_validate() {
        for f in [Fidelity::Quick, Fidelity::Full] {
            facerec_baseline(f).deployment.validate().unwrap();
            facerec_accel(8.0, f).deployment.validate().unwrap();
            objdet_accel(4.0, f).deployment.validate().unwrap();
        }
        assert!(Fidelity::Quick.horizon_us() < Fidelity::Full.horizon_us());
    }

    #[test]
    fn latency_formatting() {
        assert_eq!(fmt_latency(None), "∞ (unstable)");
        assert!(fmt_latency(Some(351_200)).contains("ms"));
    }
}
