//! Failover: the broker-crash sweep (`aitax experiment failover`).
//!
//! Every figure in the paper is measured on a healthy fabric; the AI
//! tax has a second, sharper edge the moment membership changes. This
//! sweep quantifies it on the failover scenario
//! ([`crate::pipeline::failover`]): facerec(4×) + train-ingest + rpc on
//! the 3-broker fabric, one broker killed mid-run and restarted a fixed
//! downtime later. The kill re-elects leadership and pauses the
//! affected consumers; the restart replays the victim's missed bytes as
//! a maximally-lagged consumer — cold reads off the surviving spindles,
//! classed writes into the recovering one — until it rejoins the ISR.
//!
//! Three axes:
//!
//! * **kill time** — when in the run the broker dies (as a fraction of
//!   the horizon: how much log the world has built up by then);
//! * **storage arm** — the recovery stream on the seed FIFO spindle vs
//!   carried through the per-class GPS scheduler at the tenant weights
//!   ([`MultiTenantConfig::with_storage_qos`]);
//! * **recovery bandwidth** — how hard catch-up reads the missed bytes
//!   back. It must outrun the ~640 MB/s the world keeps writing while
//!   the victim is out of sync, so the sweep brackets the spindle spec
//!   from above.
//!
//! Reported per point: recovery duration (restart → ISR rejoin), the
//! rpc canary's e2e p99 over the re-replication window
//! ([`FailoverSpec::observe_window`]), and the share of device-read
//! bytes consumed by re-replication. The headline is the same shape as
//! the read-path sweep's, now for repair traffic: unclassed, the
//! catch-up burst blows the canary's tail through the SLO; classed, the
//! replay drains at the bulk weights and the canary holds.
//!
//! `run` returns structured results; [`print`] renders the table plus a
//! machine-readable JSON report (written to
//! `artifacts/failover_report.json` when the artifacts directory is
//! present).
//!
//! [`MultiTenantConfig::with_storage_qos`]: crate::pipeline::mixed::MultiTenantConfig::with_storage_qos

use crate::config::Config;
use crate::experiments::common::Fidelity;
use crate::experiments::runner;
use crate::pipeline::catchup;
use crate::pipeline::failover::{self, FailoverSpec, OBSERVE_TAIL_US, VICTIM};
use crate::pipeline::mixed::MultiTenantReport;
use crate::util::json::Json;
use crate::util::units::{fmt_us, SEC};

/// Kill instants as fractions of the horizon.
pub const KILL_FRACS: [f64; 2] = [0.3, 0.5];
/// Recovery bandwidths (GB/s). Both sit above the scenario's ~640 MB/s
/// of ongoing replication (catch-up converges) and bracket the
/// 1.1 GB/s drive spec.
pub const RECOVERY_GBPS: [f64; 2] = [0.8, 1.6];
/// How long the victim stays down before rejoining.
pub const DOWNTIME_US: u64 = SEC;
/// Per-broker page-cache capacity: ~3 s of residency at this world's
/// write rate, so the victim's missed window has aged out of the
/// survivors' caches and catch-up reads go to the device.
pub const CACHE_BYTES: f64 = 2e9;

/// One sweep point: kill-time × storage arm × recovery bandwidth.
pub struct FailoverPoint {
    pub kill_frac: f64,
    pub classed: bool,
    pub recovery_gbps: f64,
    pub kill_at_us: u64,
    pub restart_at_us: u64,
    pub report: MultiTenantReport,
}

impl FailoverPoint {
    /// Restart → ISR rejoin (µs); `None` if recovery never finished
    /// inside the horizon.
    pub fn recovery_duration_us(&self) -> Option<u64> {
        let f = self.report.fault.as_ref()?;
        Some(f.recovery_done_us?.saturating_sub(self.restart_at_us))
    }

    /// The rpc canary's e2e p99 over the re-replication window (µs).
    pub fn rpc_window_p99_us(&self) -> u64 {
        self.report
            .tenant("rpc")
            .map(|t| t.e2e_p99_window_us)
            .unwrap_or(0)
    }
}

/// The full sweep plus the RPC tenant's SLO for verdicts.
pub struct FailoverSweep {
    pub slo_p99_us: u64,
    pub horizon_us: u64,
    pub points: Vec<FailoverPoint>,
}

impl FailoverSweep {
    pub fn point(
        &self,
        kill_frac: f64,
        classed: bool,
        recovery_gbps: f64,
    ) -> Option<&FailoverPoint> {
        self.points.iter().find(|p| {
            p.kill_frac == kill_frac
                && p.classed == classed
                && p.recovery_gbps == recovery_gbps
        })
    }
}

/// Run an explicit set of `(kill_frac, classed, recovery_gbps)` points,
/// fanned out over the deterministic parallel runner.
pub fn run_points(points: Vec<(f64, bool, f64)>, fidelity: Fidelity) -> FailoverSweep {
    let slo_p99_us = Config::default().calibration.rpc.slo_p99_us;
    let horizon = fidelity.horizon_us();
    let points = runner::map(points, move |(kill_frac, classed, recovery_gbps)| {
        let kill_at_us = (kill_frac * horizon as f64) as u64;
        let restart_at_us = kill_at_us + DOWNTIME_US;
        let spec = FailoverSpec {
            kill_at_us,
            restart_at_us,
            classed,
            recovery_bytes_per_sec: recovery_gbps * 1e9,
            cache_bytes: CACHE_BYTES,
        };
        FailoverPoint {
            kill_frac,
            classed,
            recovery_gbps,
            kill_at_us,
            restart_at_us,
            report: failover::run(spec, horizon),
        }
    });
    FailoverSweep { slo_p99_us, horizon_us: horizon, points }
}

/// Run the sweep over the kill-time × arm × bandwidth grid.
pub fn run_grid(
    kill_fracs: &[f64],
    recovery_gbps: &[f64],
    fidelity: Fidelity,
) -> FailoverSweep {
    let grid: Vec<(f64, bool, f64)> = kill_fracs
        .iter()
        .flat_map(|&frac| {
            recovery_gbps
                .iter()
                .flat_map(move |&gbps| [(frac, false, gbps), (frac, true, gbps)])
        })
        .collect();
    run_points(grid, fidelity)
}

pub fn run(fidelity: Fidelity) -> FailoverSweep {
    run_grid(&KILL_FRACS, &RECOVERY_GBPS, fidelity)
}

/// The machine-readable report.
pub fn to_json(sweep: &FailoverSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("failover".into())),
        ("slo_p99_us", Json::Num(sweep.slo_p99_us as f64)),
        ("horizon_us", Json::Num(sweep.horizon_us as f64)),
        ("victim_broker", Json::Num(VICTIM as f64)),
        ("downtime_us", Json::Num(DOWNTIME_US as f64)),
        ("observe_tail_us", Json::Num(OBSERVE_TAIL_US as f64)),
        ("accel_facerec", Json::Num(catchup::ACCEL_FACEREC)),
        (
            "storage_weights",
            Json::obj(vec![
                ("facerec", Json::Num(catchup::FACEREC_WEIGHT)),
                ("train-ingest", Json::Num(catchup::TRAIN_WEIGHT)),
                ("rpc", Json::Num(catchup::RPC_WEIGHT)),
            ]),
        ),
        (
            "points",
            Json::arr(sweep.points.iter().map(point_json).collect()),
        ),
    ])
}

fn point_json(p: &FailoverPoint) -> Json {
    let f = p.report.fault.as_ref();
    Json::obj(vec![
        ("kill_frac", Json::Num(p.kill_frac)),
        ("classed", Json::Bool(p.classed)),
        ("recovery_gbps", Json::Num(p.recovery_gbps)),
        ("kill_at_us", Json::Num(p.kill_at_us as f64)),
        ("restart_at_us", Json::Num(p.restart_at_us as f64)),
        (
            "recovery_duration_us",
            match p.recovery_duration_us() {
                Some(us) => Json::Num(us as f64),
                None => Json::Null,
            },
        ),
        ("rpc_window_p99_us", Json::Num(p.rpc_window_p99_us() as f64)),
        (
            "missed_bytes",
            Json::Num(f.map(|f| f.missed_bytes).unwrap_or(0.0)),
        ),
        (
            "rereplicated_bytes",
            Json::Num(f.map(|f| f.rereplicated_bytes).unwrap_or(0.0)),
        ),
        (
            "rereplication_read_share",
            Json::Num(f.map(|f| f.rereplication_read_share).unwrap_or(0.0)),
        ),
        (
            "records_lost",
            Json::Num(f.map(|f| f.records_lost).unwrap_or(0) as f64),
        ),
        (
            "records_rejected",
            Json::Num(f.map(|f| f.records_rejected).unwrap_or(0) as f64),
        ),
        (
            "min_isr_violations",
            Json::Num(f.map(|f| f.min_isr_violations).unwrap_or(0) as f64),
        ),
        (
            "backlog_bytes",
            Json::Num(f.map(|f| f.backlog_bytes).unwrap_or(0.0)),
        ),
        ("device_read_share", Json::Num(p.report.device_read_share)),
        ("cache_hit_ratio", Json::Num(p.report.cache_hit_ratio)),
        (
            "metrics",
            crate::metrics::registry::MetricsRegistry::from_report(&p.report).to_json(),
        ),
        (
            "tenants",
            Json::arr(
                p.report
                    .tenants
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("name", Json::Str(t.name.clone())),
                            ("completed", Json::Num(t.completed as f64)),
                            ("e2e_p99_us", Json::Num(t.e2e_p99_us as f64)),
                            (
                                "e2e_p99_window_us",
                                Json::Num(t.e2e_p99_window_us as f64),
                            ),
                            ("stable", Json::Bool(t.stable)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the JSON report next to the AOT artifacts when that directory
/// exists (same lookup as the other sweep drivers).
fn write_report(json: &Json) -> Option<std::path::PathBuf> {
    let dir = crate::runtime::Manifest::default_dir();
    if !dir.is_dir() {
        return None;
    }
    let path = dir.join("failover_report.json");
    std::fs::write(&path, json.pretty()).ok()?;
    Some(path)
}

pub fn print(sweep: &FailoverSweep) {
    println!(
        "\nFailover — facerec({}x) + train-ingest + rpc; broker {} killed at \
         frac×horizon, back {} later, catch-up at N GB/s, {{FIFO, classed}} storage",
        catchup::ACCEL_FACEREC,
        VICTIM,
        fmt_us(DOWNTIME_US),
    );
    println!(
        "  rpc SLO: e2e p99 <= {} over the re-replication window \
         (restart, +{})",
        fmt_us(sweep.slo_p99_us),
        fmt_us(OBSERVE_TAIL_US),
    );
    println!(
        "  {:>5} {:>7} {:>6} {:>10} {:>12} {:>9} {:>9} {:>8} {:>6}",
        "kill", "classed", "GB/s", "recovery", "rpc p99(w)", "missed", "replayed", "rerep%", "lost"
    );
    for p in &sweep.points {
        let f = p.report.fault.as_ref();
        let rpc_p99 = p.rpc_window_p99_us();
        println!(
            "  {:>4.1}h {:>7} {:>6.1} {:>10} {:>10}{} {:>8}M {:>8}M {:>7.1}% {:>6}",
            p.kill_frac,
            if p.classed { "yes" } else { "no" },
            p.recovery_gbps,
            match p.recovery_duration_us() {
                Some(us) => fmt_us(us),
                None => "never".into(),
            },
            fmt_us(rpc_p99),
            if rpc_p99 <= sweep.slo_p99_us { " " } else { "!" },
            f.map(|f| (f.missed_bytes / 1e6) as u64).unwrap_or(0),
            f.map(|f| (f.rereplicated_bytes / 1e6) as u64).unwrap_or(0),
            100.0 * f.map(|f| f.rereplication_read_share).unwrap_or(0.0),
            f.map(|f| f.records_lost).unwrap_or(0),
        );
    }
    println!(
        "  takeaway: repair traffic is the read-path tax at its worst — on the \
         FIFO spindle the catch-up burst rides ahead of the canary's 2 kB \
         commits; classed, the replay drains at the bulk weights and the \
         canary holds its SLO while the fabric heals"
    );
    let json = to_json(sweep);
    match write_report(&json) {
        Some(path) => println!("  json report written to {}", path.display()),
        None => println!("  json report:\n{}", json.pretty()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The SLO acceptance pin — classed storage holds the rpc canary
    // through recovery while the FIFO arm blows the SLO — lives with
    // the rest of the failover differential suite
    // (`tests/failover_differential.rs`), on the same full-size points
    // this sweep runs.

    #[test]
    fn recovery_duration_shrinks_with_bandwidth() {
        let sweep = run_points(
            vec![(0.3, true, 0.8), (0.3, true, 1.6)],
            Fidelity::Quick,
        );
        let slow = sweep.point(0.3, true, 0.8).unwrap();
        let fast = sweep.point(0.3, true, 1.6).unwrap();
        let (ds, df) = (
            slow.recovery_duration_us().expect("slow arm finishes"),
            fast.recovery_duration_us().expect("fast arm finishes"),
        );
        assert!(
            df < ds,
            "2x catch-up bandwidth must shorten the outage: {df} vs {ds}"
        );
        // And the repair consumed a visible share of the device reads.
        for p in [slow, fast] {
            let f = p.report.fault.as_ref().unwrap();
            assert!(f.rereplicated_bytes > 0.0);
            assert!(f.rereplication_read_share > 0.0);
            assert!(p.report.device_read_share > 0.0);
        }
    }

    #[test]
    fn json_report_carries_every_point_and_tenant() {
        let sweep = run_points(vec![(0.3, true, 1.6)], Fidelity::Quick);
        let j = to_json(&sweep);
        let points = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 1);
        for p in points {
            let tenants = p.get("tenants").and_then(|t| t.as_arr()).unwrap();
            assert_eq!(tenants.len(), 3);
            assert!(p.get("recovery_duration_us").is_some());
            assert!(p
                .get("rpc_window_p99_us")
                .and_then(|v| v.as_f64())
                .is_some());
            assert!(p
                .get("rereplication_read_share")
                .and_then(|v| v.as_f64())
                .is_some());
        }
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            reparsed.get("experiment").and_then(|e| e.as_str()),
            Some("failover")
        );
        assert_eq!(
            reparsed.get("victim_broker").and_then(|v| v.as_f64()),
            Some(VICTIM as f64)
        );
    }
}
