//! Fig 5: relative computational latency of Face Recognition containers
//! with core scaling.
//!
//! Paper: "Doubling the core count from one to two yields only a 16%
//! reduction in latency in ingest/detect and a 36% reduction in
//! identification. At larger core counts, the computational latency
//! actually increases for both containers."

use crate::config::calibration::CoreScaling;
use crate::pipeline::scaling::{sweep, throughput_optimal_cores, ScalingPoint};

pub struct Fig05 {
    pub ingest_detect: Vec<ScalingPoint>,
    pub identification: Vec<ScalingPoint>,
    pub best_throughput_cores: usize,
}

pub fn run(max_cores: usize) -> Fig05 {
    Fig05 {
        ingest_detect: sweep(&CoreScaling::ingest_detect(), max_cores),
        identification: sweep(&CoreScaling::identification(), max_cores),
        best_throughput_cores: throughput_optimal_cores(&CoreScaling::identification(), 56),
    }
}

pub fn print(r: &Fig05) {
    println!("\nFig 5 — FR container core scaling (relative latency, 1.0 = one core)");
    println!(
        "  {:>6} {:>16} {:>16}   paper: 2 cores -> 0.84 / 0.64",
        "cores", "ingest/detect", "identification"
    );
    for (a, b) in r.ingest_detect.iter().zip(&r.identification) {
        println!(
            "  {:>6} {:>16.3} {:>16.3}",
            a.cores, a.relative_latency, b.relative_latency
        );
    }
    println!(
        "  throughput-optimal allocation: {} core(s)/container (paper §3.5: 1)",
        r.best_throughput_cores
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quoted_points_and_upturn() {
        let r = run(16);
        assert!((r.ingest_detect[1].relative_latency - 0.84).abs() < 0.01);
        assert!((r.identification[1].relative_latency - 0.64).abs() < 0.01);
        // The upturn: 16 cores worse than 4.
        assert!(r.ingest_detect[15].relative_latency > r.ingest_detect[3].relative_latency);
        assert_eq!(r.best_throughput_cores, 1);
    }
}
