//! Fig 6 + §4.2: end-to-end frame latency breakdown of Face Recognition
//! at native speed on the paper's deployment (840 producers / 1680
//! consumers / 3 brokers, 0.64 faces/frame).
//!
//! Paper values: ingestion 18.8 ms, detection 74.8 ms, broker wait
//! 126.1 ms (>1/3 of the total), identification 131.5 ms; end-to-end
//! 351 ms mean, 2.21 s p99; detection p99 1.84 s.

use crate::experiments::common::{facerec_baseline, Fidelity};
use crate::pipeline::facerec::{FaceRecSim, SimReport};
use crate::util::units::fmt_us;

pub fn run(fidelity: Fidelity) -> SimReport {
    FaceRecSim::new(facerec_baseline(fidelity)).run()
}

pub fn print(r: &SimReport) {
    println!("\nFig 6 — end-to-end frame latency breakdown (native speed)");
    println!(
        "  {:<16} {:>12} {:>12} | {:>12}",
        "stage", "measured", "p99", "paper mean"
    );
    let rows = [
        ("ingestion", r.ingest_mean_us, r.ingest_p99_us, 18_800.0),
        ("detection", r.detect_mean_us, r.detect_p99_us, 74_800.0),
        ("broker wait", r.wait_mean_us, r.wait_p99_us, 126_100.0),
        ("identification", r.identify_mean_us, r.identify_p99_us, 131_500.0),
    ];
    for (name, mean, p99, paper) in rows {
        println!(
            "  {:<16} {:>12} {:>12} | {:>12}",
            name,
            fmt_us(mean as u64),
            fmt_us(p99),
            fmt_us(paper as u64)
        );
    }
    println!(
        "  {:<16} {:>12} {:>12} | {:>12}",
        "end-to-end",
        fmt_us(r.e2e_mean_us as u64),
        fmt_us(r.e2e_p99_us),
        "351.2 ms / p99 2.21 s"
    );
    println!(
        "  wait fraction {:.1}% (paper: >33%) | throughput {:.0} faces/s | {:.2} faces/frame",
        100.0 * r.wait_fraction,
        r.throughput_fps,
        r.mean_faces_per_frame
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_fig6_shape() {
        let r = run(Fidelity::Quick);
        // Stage means within 15% of the paper (quick horizon).
        assert!((r.ingest_mean_us - 18_800.0).abs() / 18_800.0 < 0.15);
        assert!((r.detect_mean_us - 80_000.0).abs() / 80_000.0 < 0.15);
        assert!((r.identify_mean_us - 131_500.0).abs() / 131_500.0 < 0.15);
        // "over a third of a frame's lifetime is spent in brokers" — our
        // broker wait is a large fraction; accept a generous band but
        // require it to be substantial.
        assert!(r.wait_fraction > 0.15, "wait fraction {}", r.wait_fraction);
        assert!(r.verdict.stable);
        // The paper's headline tail: e2e p99 ~ 2.21 s.
        assert!(
            (1.0e6..4.0e6).contains(&(r.e2e_p99_us as f64)),
            "e2e p99 {}",
            r.e2e_p99_us
        );
    }
}
