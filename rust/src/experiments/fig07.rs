//! Fig 7: "Latency tracks the total number of faces in the system."
//!
//! We run the baseline deployment and correlate the faces-in-system
//! population timeseries with the end-to-end latency series; the paper's
//! claim is a clear positive correlation driven by face-arrival surges.

use crate::experiments::common::{facerec_baseline, Fidelity};
use crate::pipeline::facerec::{FaceRecSim, SimReport};
use crate::util::stats::correlation;

pub struct Fig07 {
    pub report: SimReport,
    /// (time s, faces in system, mean latency ms) resampled series.
    pub series: Vec<(f64, f64, f64)>,
    pub correlation: f64,
}

pub fn run(fidelity: Fidelity) -> Fig07 {
    // Fig 7 needs several burst/drain cycles in-window, and the latency
    // response trails the arrival surge by the queue-drain time, so this
    // experiment uses a longer horizon and coarse (5 s) buckets that
    // absorb the response lag — the paper's own curves are coarsely
    // averaged over a much longer run.
    let mut cfg = facerec_baseline(fidelity);
    // Both fidelities use the same 90 s horizon: the correlation needs
    // several burst/drain cycles in-window to be meaningful.
    let _ = fidelity;
    cfg.duration_us = 90 * crate::util::units::SEC;
    let report = FaceRecSim::new(cfg).run();
    const BUCKET_S: u64 = 5;
    let horizon_s = (report.elapsed_us / 1_000_000 / BUCKET_S) as usize;
    let mut pop = vec![0.0f64; horizon_s + 1];
    let mut pop_n = vec![0u32; horizon_s + 1];
    for &(t, c) in &report.population {
        let b = (t / 1_000_000 / BUCKET_S) as usize;
        if b <= horizon_s {
            pop[b] += c as f64;
            pop_n[b] += 1;
        }
    }
    let mut lat = vec![0.0f64; horizon_s + 1];
    let mut lat_n = vec![0u32; horizon_s + 1];
    for &(t, l) in &report.latency_series {
        let b = (t / 1_000_000 / BUCKET_S) as usize;
        if b <= horizon_s {
            lat[b] += l as f64 / 1000.0;
            lat_n[b] += 1;
        }
    }
    let mut series = Vec::new();
    for s in 0..=horizon_s {
        if pop_n[s] > 0 && lat_n[s] > 0 {
            series.push((
                (s as u64 * BUCKET_S) as f64,
                pop[s] / pop_n[s] as f64,
                lat[s] / lat_n[s] as f64,
            ));
        }
    }
    // Latency responds to the population with a short queueing lag (a
    // face that joins a deep queue finishes — and is *measured* — seconds
    // later), while arrival-bucketed latency can *lead* the population
    // peak (congestion is felt while the queue is still building).
    // Correlate at small lags either way and report the best alignment,
    // matching the paper's visual claim that the two curves track.
    let by_bucket: std::collections::BTreeMap<i64, (f64, f64)> = series
        .iter()
        .map(|&(t, p, l)| (t as i64 / BUCKET_S as i64, (p, l)))
        .collect();
    let mut best = f64::MIN;
    for lag in -2..=2i64 {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for (&t, &(p, _)) in &by_bucket {
            if let Some(&(_, l)) = by_bucket.get(&(t + lag)) {
                xs.push(p);
                ys.push(l);
            }
        }
        if xs.len() >= 4 {
            best = best.max(correlation(&xs, &ys));
        }
    }
    Fig07 {
        report,
        correlation: best,
        series,
    }
}

pub fn print(r: &Fig07) {
    println!("\nFig 7 — latency tracks faces in the system");
    println!("  {:>6} {:>16} {:>16}", "t (s)", "faces in system", "latency (ms)");
    for (t, pop, lat) in &r.series {
        println!("  {:>6.0} {:>16.0} {:>16.1}", t, pop, lat);
    }
    println!(
        "  correlation(population, latency) = {:.2}  (paper: 'clearly correlated')",
        r.correlation
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_correlates_with_population() {
        let r = run(Fidelity::Quick);
        assert!(r.series.len() >= 6, "series too short: {}", r.series.len());
        assert!(
            r.correlation > 0.3,
            "expected positive correlation, got {:.2}",
            r.correlation
        );
    }
}
