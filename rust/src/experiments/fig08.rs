//! Fig 8: per-process CPU-time breakdowns.
//!
//! Paper: ingestion splits ~evenly between extraction and resizing;
//! detection is only 42% AI (25% crop/resize, 13% "other", ...);
//! identification is 88% AI with 8% Kafka client. These proportions are
//! both an *input* to the stage cost models (calibration) and an *output*
//! of the live run: with artifacts present, the live three-layer pipeline
//! measures its own AI-vs-support split for comparison.

use crate::config::calibration::CpuBreakdown;

pub struct StageRows {
    pub stage: &'static str,
    pub rows: Vec<(&'static str, f64)>,
    pub ai_fraction: f64,
}

pub fn run() -> Vec<StageRows> {
    let b = CpuBreakdown::default();
    let ai_of = |rows: &[(&str, f64)]| {
        rows.iter()
            .filter(|(n, _)| n.starts_with("ai"))
            .map(|(_, f)| f)
            .sum()
    };
    vec![
        StageRows {
            stage: "ingestion",
            rows: b.ingestion.to_vec(),
            ai_fraction: ai_of(b.ingestion),
        },
        StageRows {
            stage: "detection",
            rows: b.detection.to_vec(),
            ai_fraction: ai_of(b.detection),
        },
        StageRows {
            stage: "identification",
            rows: b.identification.to_vec(),
            ai_fraction: ai_of(b.identification),
        },
    ]
}

/// End-to-end cycle accounting (§4.3): AI constitutes 55.2% of cycles.
pub fn end_to_end_ai_share() -> f64 {
    // Weight each stage's AI share by its share of total compute cycles
    // (per-frame: ingest 18.8 + detect 74.8 + identify 0.64*131.5).
    let ingest = 18_800.0;
    let detect = 74_800.0;
    let identify = 0.64 * 131_500.0;
    let total = ingest + detect + identify;
    (0.0 * ingest + 0.42 * detect + 0.88 * identify) / total
}

pub fn print(stages: &[StageRows]) {
    println!("\nFig 8 — per-process CPU-time breakdowns");
    for s in stages {
        println!("  {} (AI share {:.0}%):", s.stage, 100.0 * s.ai_fraction);
        for (name, frac) in &s.rows {
            println!("    {:<24} {:>5.1}%", name, frac * 100.0);
        }
    }
    println!(
        "  end-to-end AI share: {:.1}% (paper §4.3: 55.2%)",
        100.0 * end_to_end_ai_share()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ai_shares() {
        let stages = run();
        assert_eq!(stages[0].ai_fraction, 0.0);
        assert!((stages[1].ai_fraction - 0.42).abs() < 1e-9);
        assert!((stages[2].ai_fraction - 0.88).abs() < 1e-9);
    }

    #[test]
    fn end_to_end_share_near_paper() {
        // Paper: 55.2% of end-to-end cycles are AI. Our stage-weighted
        // estimate lands slightly higher because the paper's denominator
        // also counts cycles outside the three stage means (networking
        // 9.0%, Kafka processing 3.6%, tensor prep 5.2% — §4.3).
        let s = end_to_end_ai_share();
        assert!((0.50..0.65).contains(&s), "share={s}");
        assert!(s > 0.5, "AI is the majority but far from all of it");
    }
}
