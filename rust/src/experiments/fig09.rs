//! Fig 9: Amdahl's-law projected speedups of individual processes under
//! AI-only acceleration.

use crate::accel::amdahl::AmdahlCurve;

pub const FACTORS: [f64; 7] = [1.0, 2.0, 4.0, 8.0, 16.0, 24.0, 32.0];

pub struct Fig09 {
    pub curves: Vec<(AmdahlCurve, Vec<(f64, f64)>)>,
}

pub fn run() -> Fig09 {
    Fig09 {
        curves: AmdahlCurve::facerec()
            .into_iter()
            .map(|c| {
                let sweep = c.sweep(&FACTORS);
                (c, sweep)
            })
            .collect(),
    }
}

pub fn print(r: &Fig09) {
    println!("\nFig 9 — Amdahl projections (overall stage speedup at AI speedup k)");
    print!("  {:>16}", "k");
    for k in FACTORS {
        print!(" {:>8.0}", k);
    }
    println!(" {:>10}", "asymptote");
    for (curve, sweep) in &r.curves {
        print!("  {:>16}", curve.stage);
        for (_, s) in sweep {
            print!(" {:>8.2}", s);
        }
        if curve.asymptote().is_finite() {
            println!(" {:>10.2}", curve.asymptote());
        } else {
            println!(" {:>10}", "∞");
        }
    }
    println!("  paper: detection 1.59x@8x, 1.66x@16x (asym 1.74); identification 5.6x@16x, 6.6x@32x (asym 8.3)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_cover_all_three_stages() {
        let r = run();
        assert_eq!(r.curves.len(), 3);
        let det = &r.curves[1];
        // k=8 is index 3.
        assert!((det.1[3].1 - 1.59).abs() < 0.02);
    }
}
