//! Fig 10 + §5.5: Face Recognition latency/throughput under increasing
//! AI acceleration (emulation protocol, 1 face/frame).
//!
//! Paper: latency falls and throughput rises through 6×; at 8× "latency
//! tending toward infinity — an unstable system in queueing theory".
//! §5.5: the waiting-time share grows 64.6% → 66.4% → 68.0% → 79.1%
//! (1×, 2×, 4×, 6×).

use crate::experiments::common::{facerec_accel, Fidelity};
use crate::experiments::runner;
use crate::pipeline::facerec::{FaceRecSim, SimReport};
use crate::util::units::fmt_us;

pub const FACTORS: [f64; 5] = [1.0, 2.0, 4.0, 6.0, 8.0];

pub struct Fig10 {
    pub reports: Vec<SimReport>,
}

pub fn run(fidelity: Fidelity) -> Fig10 {
    Fig10 {
        reports: runner::map(FACTORS.to_vec(), |k| {
            FaceRecSim::new(facerec_accel(k, fidelity)).run()
        }),
    }
}

pub fn print(r: &Fig10) {
    println!("\nFig 10 — FR latency & throughput under AI acceleration (1 face/frame)");
    println!(
        "  {:>5} {:>16} {:>14} {:>12} {:>10}",
        "k", "mean latency", "throughput", "wait share", "stable?"
    );
    for rep in &r.reports {
        let lat = rep.verdict.latency_or_inf(rep.e2e_mean_us as u64);
        println!(
            "  {:>5} {:>16} {:>11.0} f/s {:>11.1}% {:>10}",
            rep.accel,
            crate::experiments::common::fmt_latency(lat),
            rep.throughput_fps,
            100.0 * rep.wait_fraction,
            if rep.verdict.stable { "yes" } else { "NO" }
        );
    }
    println!("  paper: stable through 6x; ∞ at 8x; wait share 64.6/66.4/68.0/79.1%");
    let one = &r.reports[0];
    println!(
        "  1x reference: e2e {} (higher than Fig 6's 351 ms — 1 face/frame, §5.3)",
        fmt_us(one.e2e_mean_us as u64)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_instability_at_8x() {
        let r = run(Fidelity::Quick);
        // Stable through 6x, unstable at 8x — the paper's headline.
        for rep in &r.reports[..4] {
            assert!(rep.verdict.stable, "{}x should be stable", rep.accel);
        }
        assert!(!r.reports[4].verdict.stable, "8x should be unstable");
    }

    #[test]
    fn throughput_scales_until_saturation() {
        let r = run(Fidelity::Quick);
        let t1 = r.reports[0].throughput_fps;
        let t4 = r.reports[2].throughput_fps;
        assert!(t4 > 3.0 * t1, "t1={t1} t4={t4}");
    }

    #[test]
    fn wait_share_grows_with_acceleration() {
        let r = run(Fidelity::Quick);
        // §5.5's monotone trend over the stable region.
        let shares: Vec<f64> = r.reports[..4].iter().map(|x| x.wait_fraction).collect();
        assert!(
            shares.windows(2).all(|w| w[1] > w[0] - 0.02),
            "wait shares not rising: {shares:?}"
        );
        assert!(shares[0] > 0.5 && shares[3] > shares[0]);
    }

    #[test]
    fn latency_decreases_while_stable() {
        let r = run(Fidelity::Quick);
        assert!(r.reports[2].e2e_mean_us < r.reports[0].e2e_mean_us);
    }
}
