//! Fig 11: network and storage bandwidth utilization under acceleration.
//!
//! Paper: broker network peaks ~6 Gbps of 100 Gbps (6%) at 8×, while
//! broker storage *write* utilization goes 10% (1×) → 67%+ (8×), which
//! "has effectively saturated the available bandwidth"; reads stay ~0
//! thanks to the page cache.

use crate::experiments::common::{facerec_accel, Fidelity};
use crate::experiments::runner;
use crate::pipeline::facerec::{FaceRecSim, SimReport};

pub const FACTORS: [f64; 5] = [1.0, 2.0, 4.0, 6.0, 8.0];

pub struct Fig11 {
    pub reports: Vec<SimReport>,
}

pub fn run(fidelity: Fidelity) -> Fig11 {
    Fig11 {
        reports: runner::map(FACTORS.to_vec(), |k| {
            FaceRecSim::new(facerec_accel(k, fidelity)).run()
        }),
    }
}

pub fn print(r: &Fig11) {
    println!("\nFig 11a — network utilization (fraction of 100 Gbps per node)");
    println!(
        "  {:>5} {:>14} {:>14} {:>14} {:>14}",
        "k", "producer tx", "consumer rx", "broker rx", "broker tx"
    );
    for rep in &r.reports {
        println!(
            "  {:>5} {:>13.2}% {:>13.2}% {:>13.2}% {:>13.2}%",
            rep.accel,
            100.0 * rep.producer_net_tx_util,
            100.0 * rep.consumer_net_rx_util,
            100.0 * rep.broker_net_rx_util,
            100.0 * rep.broker_net_tx_util,
        );
    }
    println!("  paper: broker network peaks ~6% at 8x — never the bottleneck");

    println!("\nFig 11b — broker storage utilization (fraction of 1.1 GB/s per drive)");
    println!("  {:>5} {:>14} {:>14}", "k", "write", "read");
    for rep in &r.reports {
        println!(
            "  {:>5} {:>13.1}% {:>13.2}%",
            rep.accel,
            100.0 * rep.storage_write_util,
            100.0 * rep.storage_read_util,
        );
    }
    println!("  paper: write 10% at 1x -> 67%+ at 8x (saturated); reads ~0 (page cache)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_is_the_bottleneck_not_network() {
        let r = run(Fidelity::Quick);
        let k1 = &r.reports[0];
        let k8 = &r.reports[4];
        // Fig 11b: ~10% at 1x.
        assert!((0.06..0.16).contains(&k1.storage_write_util), "{}", k1.storage_write_util);
        // At 8x storage demand is at/above the saturation band while the
        // network stays in single digits.
        assert!(k8.storage_write_util > 0.6, "{}", k8.storage_write_util);
        assert!(k8.broker_net_rx_util < 0.10, "{}", k8.broker_net_rx_util);
        // Reads are served from the page cache.
        for rep in &r.reports {
            assert!(rep.storage_read_util < 0.01);
        }
    }

    #[test]
    fn write_util_scales_linearly_while_stable() {
        let r = run(Fidelity::Quick);
        let u1 = r.reports[0].storage_write_util;
        let u4 = r.reports[2].storage_write_util;
        assert!((u4 / u1 - 4.0).abs() < 1.0, "u1={u1} u4={u4}");
    }
}
