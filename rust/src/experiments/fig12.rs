//! Fig 12: Object Detection core scaling — near-linear, unlike FR.
//!
//! Paper: "the detection stage of Object Detection shows near linear
//! speedups with increasing core count. Through testing, we determined to
//! allocate 14 cores per container."

use crate::config::calibration::CoreScaling;
use crate::pipeline::scaling::{best_cores, sweep, ScalingPoint};

pub struct Fig12 {
    pub detection: Vec<ScalingPoint>,
    pub best_cores: usize,
}

pub fn run(max_cores: usize) -> Fig12 {
    Fig12 {
        detection: sweep(&CoreScaling::objdet_detection(), max_cores),
        best_cores: best_cores(&CoreScaling::objdet_detection(), max_cores),
    }
}

pub fn print(r: &Fig12) {
    println!("\nFig 12 — Object Detection core scaling (relative latency)");
    println!("  {:>6} {:>16} {:>10}", "cores", "rel latency", "speedup");
    for p in &r.detection {
        println!("  {:>6} {:>16.3} {:>10.2}", p.cores, p.relative_latency, p.speedup);
    }
    println!(
        "  latency-optimal cores (within 28): {} (paper allocates 14/container)",
        r.best_cores
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_linear_scaling() {
        let r = run(14);
        // ≥10x speedup at 14 cores and monotone improvement throughout.
        assert!(r.detection[13].speedup > 10.0, "{}", r.detection[13].speedup);
        for w in r.detection.windows(2) {
            assert!(w[1].relative_latency < w[0].relative_latency);
        }
    }
}
