//! Fig 13: Object Detection end-to-end frame latency breakdown at 1×.
//!
//! Paper: ingestion 4.5 ms (rate-limited to a 33.3 ms tick), broker wait
//! 629 ms, detection 687 ms.

use crate::experiments::common::{objdet_accel, Fidelity};
use crate::pipeline::objdet::{ObjDetReport, ObjDetSim};
use crate::util::units::fmt_us;

pub fn run(fidelity: Fidelity) -> ObjDetReport {
    ObjDetSim::new(objdet_accel(1.0, fidelity)).run()
}

pub fn print(r: &ObjDetReport) {
    println!("\nFig 13 — Object Detection latency breakdown (native speed)");
    let rows = [
        ("ingestion", r.ingest_mean_us, 4_500.0),
        ("delay", r.delay_mean_us, 0.0),
        ("broker wait", r.wait_mean_us, 629_000.0),
        ("detection", r.detect_mean_us, 687_000.0),
    ];
    println!("  {:<14} {:>12} | {:>12}", "stage", "measured", "paper");
    for (name, mean, paper) in rows {
        println!(
            "  {:<14} {:>12} | {:>12}",
            name,
            fmt_us(mean as u64),
            fmt_us(paper as u64)
        );
    }
    println!(
        "  throughput {:.0} FPS (paper: 630 = 21 producers x 30 FPS)",
        r.throughput_fps
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_fig13() {
        let r = run(Fidelity::Quick);
        assert!((r.ingest_mean_us - 4_500.0).abs() / 4_500.0 < 0.15, "{}", r.ingest_mean_us);
        assert!((r.detect_mean_us - 687_000.0).abs() / 687_000.0 < 0.15, "{}", r.detect_mean_us);
        // Broker wait comparable to detection (paper: 629 vs 687 ms).
        assert!(
            (400_000.0..900_000.0).contains(&r.wait_mean_us),
            "wait={}",
            r.wait_mean_us
        );
        assert!(r.verdict.stable);
    }
}
