//! Fig 14: Object Detection under acceleration.
//!
//! Paper: throughput 630 FPS at 1×, "scales pretty well up to 8×, but it
//! falls short of what is expected at 12× and the system saturates by
//! 16×"; a new "Delay" component appears as the producer send path
//! overruns the 33.3 ms tick.

use crate::experiments::common::{objdet_accel, Fidelity};
use crate::experiments::runner;
use crate::pipeline::objdet::{ObjDetReport, ObjDetSim};

pub const FACTORS: [f64; 6] = [1.0, 2.0, 4.0, 8.0, 12.0, 16.0];

pub struct Fig14 {
    pub reports: Vec<ObjDetReport>,
}

pub fn run(fidelity: Fidelity) -> Fig14 {
    Fig14 {
        reports: runner::map(FACTORS.to_vec(), |k| {
            ObjDetSim::new(objdet_accel(k, fidelity)).run()
        }),
    }
}

pub fn print(r: &Fig14) {
    println!("\nFig 14 — Object Detection latency & throughput under acceleration");
    println!(
        "  {:>5} {:>12} {:>12} {:>12} {:>12} {:>10} {:>8}",
        "k", "delay", "wait", "detect", "e2e", "FPS", "stable?"
    );
    for rep in &r.reports {
        let e2e = rep.verdict.latency_or_inf(rep.e2e_mean_us as u64);
        println!(
            "  {:>5} {:>10.1}ms {:>10.1}ms {:>10.1}ms {:>12} {:>10.0} {:>8}",
            rep.accel,
            rep.delay_mean_us / 1000.0,
            rep.wait_mean_us / 1000.0,
            rep.detect_mean_us / 1000.0,
            crate::experiments::common::fmt_latency(e2e),
            rep.throughput_fps,
            if rep.verdict.stable { "yes" } else { "NO" }
        );
    }
    println!("  paper: 630 FPS at 1x; scales to 8x; falls short at 12x; saturates >=16x;");
    println!("         the Delay component appears when the send path overruns the tick");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_scaling_shape() {
        let r = run(Fidelity::Quick);
        let fps: Vec<f64> = r.reports.iter().map(|x| x.throughput_fps).collect();
        // ~630 at 1x (within 10%).
        assert!((fps[0] - 630.0).abs() < 63.0, "{}", fps[0]);
        // Scales well to 8x...
        assert!(fps[3] > 0.85 * 8.0 * 630.0, "8x fps {}", fps[3]);
        // ...saturates by 16x (well short of 16x the baseline).
        assert!(fps[5] < 0.85 * 16.0 * 630.0, "16x fps {}", fps[5]);
    }

    #[test]
    fn sixteen_x_unstable_with_delay() {
        let r = run(Fidelity::Quick);
        let k16 = &r.reports[5];
        assert!(!k16.verdict.stable || k16.delay_mean_us > 30_000.0);
        assert!(k16.producer_send_util > 0.9, "{}", k16.producer_send_util);
        // Stable through 8x.
        for rep in &r.reports[..4] {
            assert!(rep.verdict.stable, "{}x unstable", rep.accel);
        }
    }
}
