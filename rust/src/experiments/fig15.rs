//! Fig 15: unlocking higher speedups — the three §7.1 mitigations.
//!
//! (a) more NVMe drives per broker: 1 drive < 8×; 2 → 12×; 3 → 24×;
//!     4 → 32×.
//! (b) more brokers: 3 → <8×; 4 → 8×; 6 → 16×; 8 → 32×.
//! (c) smaller thumbnails: ÷2, ÷4, ÷8 raise the supportable factor
//!     proportionally.

use crate::experiments::common::{facerec_accel, Fidelity};
use crate::experiments::runner;
use crate::pipeline::facerec::FaceRecSim;

pub const FACTORS: [f64; 5] = [8.0, 12.0, 16.0, 24.0, 32.0];

/// One sweep cell: is the system stable at this (variant, k)?
#[derive(Clone, Debug)]
pub struct Cell {
    pub k: f64,
    pub stable: bool,
    pub latency_us: Option<u64>,
    pub storage_write_util: f64,
}

#[derive(Clone, Debug)]
pub struct Variant {
    pub label: String,
    pub cells: Vec<Cell>,
    /// Highest stable factor ("unlocked speedup").
    pub unlocked: Option<f64>,
}

/// Sweep one family of mitigation variants: the whole `params × FACTORS`
/// grid is flattened into a single [`runner::map`] pool (20 independent
/// simulations fan out together), then regrouped per variant in order.
fn sweep_family<P: Copy + Send + Sync>(
    fidelity: Fidelity,
    params: &[P],
    apply: impl Fn(&mut crate::config::Config, P) + Sync,
    label: impl Fn(P) -> String,
) -> Vec<Variant> {
    let points: Vec<(P, f64)> = params
        .iter()
        .flat_map(|&p| FACTORS.iter().map(move |&k| (p, k)))
        .collect();
    let cells: Vec<Cell> = runner::map(points, |(p, k)| {
        let mut cfg = facerec_accel(k, fidelity);
        apply(&mut cfg, p);
        let r = FaceRecSim::new(cfg).run();
        Cell {
            k,
            stable: r.verdict.stable,
            latency_us: r.verdict.latency_or_inf(r.e2e_mean_us as u64),
            storage_write_util: r.storage_write_util,
        }
    });
    params
        .iter()
        .zip(cells.chunks(FACTORS.len()))
        .map(|(&p, chunk)| {
            let cells = chunk.to_vec();
            let unlocked = cells
                .iter()
                .filter(|c| c.stable)
                .map(|c| c.k)
                .fold(None, |m: Option<f64>, k| Some(m.map_or(k, |m| m.max(k))));
            Variant { label: label(p), cells, unlocked }
        })
        .collect()
}

/// One labeled variant (kept for the focused per-mitigation tests).
fn sweep_variant(
    label: String,
    fidelity: Fidelity,
    mutate: impl Fn(&mut crate::config::Config) + Sync,
) -> Variant {
    sweep_family(fidelity, &[()], |cfg, _: ()| mutate(cfg), |_| label.clone())
        .pop()
        .expect("single-variant family")
}

pub struct Fig15 {
    pub drives: Vec<Variant>,
    pub brokers: Vec<Variant>,
    pub sizes: Vec<Variant>,
}

pub fn run(fidelity: Fidelity) -> Fig15 {
    let drives = sweep_family(
        fidelity,
        &[1usize, 2, 3, 4],
        |cfg, d| cfg.deployment.drives_per_broker = d,
        |d| format!("{d} drive(s)/broker"),
    );
    let brokers = sweep_family(
        fidelity,
        &[3usize, 4, 6, 8],
        |cfg, b| cfg.deployment.brokers = b,
        |b| format!("{b} brokers"),
    );
    let sizes = sweep_family(
        fidelity,
        &[1.0f64, 0.5, 0.25, 0.125],
        |cfg, s| cfg.face_bytes = 37_300.0 * s,
        |s| format!("{:.0}% thumbnails", s * 100.0),
    );
    Fig15 {
        drives,
        brokers,
        sizes,
    }
}

fn print_block(title: &str, variants: &[Variant], paper: &str) {
    println!("\n{title}");
    print!("  {:<22}", "");
    for k in FACTORS {
        print!(" {:>9}", format!("{k}x"));
    }
    println!(" {:>10}", "unlocked");
    for v in variants {
        print!("  {:<22}", v.label);
        for c in &v.cells {
            print!(
                " {:>9}",
                if c.stable {
                    format!("{:.0}ms", c.latency_us.unwrap_or(0) as f64 / 1000.0)
                } else {
                    "∞".to_string()
                }
            );
        }
        println!(
            " {:>10}",
            v.unlocked
                .map(|k| format!("{k}x"))
                .unwrap_or_else(|| "<8x".into())
        );
    }
    println!("  paper: {paper}");
}

pub fn print(r: &Fig15) {
    print_block(
        "Fig 15a — additional storage drives per broker",
        &r.drives,
        "1 drive <8x; 2 drives ->12x; 3 ->24x; 4 ->32x",
    );
    print_block(
        "Fig 15b — additional brokers",
        &r.brokers,
        "3 <8x; 4 ->8x; 6 ->16x; 8 ->32x",
    );
    print_block(
        "Fig 15c — smaller face thumbnails",
        &r.sizes,
        "halving sizes raises the supportable factor proportionally",
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    // One focused test per mitigation keeps test time manageable; the
    // full grid runs in the bench.

    #[test]
    fn drives_unlock_higher_factors() {
        let f = Fidelity::Quick;
        let one = sweep_variant("1".into(), f, |c| c.deployment.drives_per_broker = 1);
        let four = sweep_variant("4".into(), f, |c| c.deployment.drives_per_broker = 4);
        assert_eq!(one.unlocked, None, "1 drive should fail at 8x: {:?}", one.cells);
        assert_eq!(four.unlocked, Some(32.0), "{:?}", four.cells);
    }

    #[test]
    fn brokers_unlock_higher_factors() {
        let f = Fidelity::Quick;
        let eight = sweep_variant("8".into(), f, |c| c.deployment.brokers = 8);
        assert_eq!(eight.unlocked, Some(32.0), "{:?}", eight.cells);
    }

    #[test]
    fn smaller_thumbs_unlock_higher_factors() {
        let f = Fidelity::Quick;
        let eighth = sweep_variant("1/8".into(), f, |c| c.face_bytes = 37_300.0 / 8.0);
        assert_eq!(eighth.unlocked, Some(32.0), "{:?}", eighth.cells);
    }
}
