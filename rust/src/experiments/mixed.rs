//! Mixed tenancy: the facerec:objdet interference sweep.
//!
//! A Fig-11/Fig-15-style experiment the paper could not run: Face
//! Recognition at its §5.3 acceleration deployment (4×) shares the
//! 3-broker fabric with an Object Detection tenant (6×) whose fleet is
//! scaled from 0 to 100% of its §6.3 nominal size.
//!
//! The punchline mirrors the paper's Fig-10 cliff, but *cross-tenant*:
//! each workload passes capacity planning on its own — facerec at 4×
//! drives the shared NVMe write path to ~55% of effective bandwidth,
//! objdet at 6× alone to ~50% — yet their colocation crosses saturation,
//! and Face Recognition's latency diverges with zero change to its own
//! deployment. The AI tax is a property of the *shared substrate*, not of
//! any single pipeline.

use crate::experiments::common::{facerec_accel, objdet_accel, Fidelity};
use crate::experiments::runner;
use crate::pipeline::facerec::FaceRecSim;
use crate::pipeline::mixed::{MixedConfig, MixedReport, MixedSim};
use crate::pipeline::SimReport;
use crate::util::units::fmt_us;

/// Object Detection fleet share of its §6.3 nominal size.
pub const MIX_SHARES: [f64; 4] = [0.25, 0.5, 0.75, 1.0];
/// Face Recognition acceleration (stable alone: Fig 10/11).
pub const ACCEL_FACEREC: f64 = 4.0;
/// Object Detection acceleration (stable alone: Fig 14).
pub const ACCEL_OBJDET: f64 = 6.0;

pub struct MixPoint {
    /// facerec:objdet mix, expressed as the objdet share of nominal.
    pub objdet_share: f64,
    pub report: MixedReport,
}

pub struct MixedSweep {
    /// Face Recognition running the same deployment *alone* (the 0% mix).
    pub baseline: SimReport,
    pub points: Vec<MixPoint>,
}

/// Build the mixed config for one sweep point.
pub fn mix_config(objdet_share: f64, fidelity: Fidelity) -> MixedConfig {
    let fr = facerec_accel(ACCEL_FACEREC, fidelity);
    let mut od = objdet_accel(ACCEL_OBJDET, fidelity);
    let nominal = od.deployment.clone();
    od.deployment.producers = ((nominal.producers as f64 * objdet_share).round() as usize).max(1);
    od.deployment.consumers = ((nominal.consumers as f64 * objdet_share).round() as usize).max(1);
    od.deployment.partitions = od.deployment.consumers;
    let duration_us = fr.duration_us;
    MixedConfig {
        fabric: fr.clone(),
        facerec: fr,
        objdet: od,
        duration_us,
    }
}

pub fn run(fidelity: Fidelity) -> MixedSweep {
    let baseline = FaceRecSim::new(facerec_accel(ACCEL_FACEREC, fidelity)).run();
    let points = runner::map(MIX_SHARES.to_vec(), |share| MixPoint {
        objdet_share: share,
        report: MixedSim::new(mix_config(share, fidelity)).run(),
    });
    MixedSweep { baseline, points }
}

pub fn print(sweep: &MixedSweep) {
    println!(
        "\nMixed tenancy — facerec ({ACCEL_FACEREC}x) + objdet ({ACCEL_OBJDET}x) on one fabric"
    );
    println!(
        "  {:>9} {:>12} {:>12} {:>12} {:>12} {:>12} {:>11} {:>11}",
        "od share", "fr wait", "fr e2e p99", "od wait", "od e2e p99", "nvme write", "nic rx", "req cpu"
    );
    let b = &sweep.baseline;
    println!(
        "  {:>9} {:>12} {:>12} {:>12} {:>12} {:>11.1}% {:>10.2}% {:>10.2}%   (facerec alone)",
        "0%",
        fmt_us(b.wait_mean_us as u64),
        fmt_us(b.e2e_p99_us),
        "-",
        "-",
        100.0 * b.storage_write_util,
        100.0 * b.broker_net_rx_util,
        100.0 * b.broker_cpu_util,
    );
    for p in &sweep.points {
        let r = &p.report;
        let stability = if r.stable() { "" } else { "  UNSTABLE (latency -> inf)" };
        println!(
            "  {:>8.0}% {:>12} {:>12} {:>12} {:>12} {:>11.1}% {:>10.2}% {:>10.2}%{}",
            100.0 * p.objdet_share,
            fmt_us(r.facerec.wait_mean_us as u64),
            fmt_us(r.facerec.e2e_p99_us),
            fmt_us(r.objdet.wait_mean_us as u64),
            fmt_us(r.objdet.e2e_p99_us),
            100.0 * r.broker_storage_write_util,
            100.0 * r.broker_net_rx_util,
            100.0 * r.broker_cpu_util,
            stability,
        );
    }
    println!(
        "  takeaway: each tenant is stable alone; the full colocation saturates the \
         shared NVMe write path and facerec's latency diverges unchanged-by-itself"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn co_tenant_inflates_shared_storage_pressure() {
        let sweep = run(Fidelity::Quick);
        // Write pressure is additive in the co-tenant's share.
        let mut last_util = sweep.baseline.storage_write_util;
        for p in &sweep.points {
            assert!(
                p.report.broker_storage_write_util > last_util,
                "write util must grow with the objdet share: {} after {}",
                p.report.broker_storage_write_util,
                last_util
            );
            last_util = p.report.broker_storage_write_util;
        }
    }

    #[test]
    fn full_colocation_crosses_the_cliff() {
        let sweep = run(Fidelity::Quick);
        // Small co-tenant: everything still works.
        let first = &sweep.points[0].report;
        assert!(
            first.facerec.verdict.stable,
            "25% objdet share must leave facerec stable"
        );
        // Full co-tenant: the shared write path saturates; facerec either
        // destabilizes (the expected cliff) or at minimum its broker wait
        // inflates well past the solo baseline.
        let full = &sweep.points.last().unwrap().report;
        assert!(
            !full.facerec.verdict.stable
                || full.facerec.wait_mean_us > 1.5 * sweep.baseline.wait_mean_us,
            "full colocation shows no interference: wait {} vs solo {} (stable={})",
            full.facerec.wait_mean_us,
            sweep.baseline.wait_mean_us,
            full.facerec.verdict.stable
        );
    }
}
