//! Experiment drivers: one module per figure/table of the paper, plus
//! extensions the component kernel enables ([`mixed`] — the cross-tenant
//! interference sweep; [`qos`] — the N-tenant p99-vs-share SLO sweep with
//! broker scheduling classes and topic quotas as the mitigation;
//! [`storage_qos`] — the write-path sweep pitting the seed FIFO NVMe
//! queue against per-class GPS write scheduling; [`read_path`] — the
//! lagging-consumer sweep that turns Fig 11's "reads are free"
//! assumption into a measured threshold: catch-up lag × page-cache size
//! × {unclassed, classed} device reads; [`failover`] — the broker-crash
//! sweep: kill time × storage arm × recovery bandwidth, measuring
//! recovery duration and the rpc tail through the re-replication
//! window; [`cascade`] — the cascading-failure resilience sweep: a
//! correlated second kill during the first victim's catch-up, crossed
//! with retrying producers (idempotent commits) and clean vs unclean
//! election; [`net_path`] — the network-contention sweep: the failover
//! world on a max-min fair ToR/spine fabric, acceleration ×
//! oversubscription × broker placement; [`scale`] — the million-client
//! sweep pitting per-record replay against the hybrid fluid/discrete
//! flow producers, cost and convergence side by side; [`tax`] — the
//! latency-provenance sweep: per-record AI-vs-tax attribution across
//! acceleration × {baseline, network, catch-up} arms).
//!
//! Each module exposes a `run(...)` returning structured results and a
//! `print_*` helper producing the same rows/series the paper reports with
//! the paper's values side by side. The `cargo bench` targets and the
//! `aitax experiment <id>` CLI both call into these.
//!
//! Sweep drivers fan their independent points out over [`runner`] —
//! deterministic scoped-thread parallelism whose results come back in
//! input order, so reports are byte-identical at any `AITAX_JOBS`.

pub mod ablation;
pub mod cascade;
pub mod common;
pub mod failover;
pub mod fig05;
pub mod fig06;
pub mod fig07;
pub mod fig08;
pub mod fig09;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod mixed;
pub mod net_path;
pub mod qos;
pub mod read_path;
pub mod runner;
pub mod scale;
pub mod storage_qos;
pub mod table34;
pub mod tax;
