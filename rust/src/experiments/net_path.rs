//! Net-path: the network-contention sweep (`aitax experiment net-path`).
//!
//! Every sweep so far priced the wire at a fixed 30 µs hop: the AI tax
//! showed up in broker CPU, spindles, and repair traffic, never in the
//! fabric between them. This sweep turns the network on
//! ([`MultiTenantConfig::with_network`]): every producer send, fetch
//! response, replication copy, and recovery byte now crosses a two-tier
//! ToR/spine topology ([`crate::net::path`]) whose links hand out
//! max-min fair shares ([`crate::net::link`]), recomputed at every
//! transfer entry and exit.
//!
//! The scenario is the failover world ([`crate::pipeline::failover`]):
//! facerec + train-ingest + rpc on the 3-broker fabric, one broker
//! killed mid-run, restarted a second later, its missed bytes replayed
//! as a catch-up stream. Three axes:
//!
//! * **acceleration** — facerec at 1× vs 4×: how much produce/fetch
//!   pressure the racks carry before anything breaks;
//! * **oversubscription** — rack uplink capacity =
//!   `rack_size × link / oversub`; 1:1 is non-blocking, 8:1 is the
//!   classic cost-reduced ToR where one busy node starves the rack;
//! * **placement** — brokers striped across racks with their clients
//!   ([`Placement::CoLocated`]: replication and recovery cross the
//!   oversubscribed uplinks) vs packed into their own rack
//!   ([`Placement::BrokerIsolated`]: broker↔broker traffic — including
//!   the entire recovery stream — stays on intra-rack links).
//!
//! A per-acceleration *network-disabled* baseline anchors each group:
//! that arm is bit-exact to the PR 8 fabric
//! (`tests/net_differential.rs` pins it), so every delta in the table
//! is pure fabric contention. Reported per point: the rpc canary's e2e
//! p99 over the recovery window, facerec's windowed p99 (its fetch
//! path is the heaviest uplink consumer), recovery duration, the count
//! of transfers that ran below their solo share, and the peak uplink
//! utilization. The headline: on shared uplinks recovery stretches and
//! the tails grow with oversubscription; isolating the brokers takes
//! the recovery stream off the uplinks and claws most of it back.
//!
//! [`MultiTenantConfig::with_network`]: crate::pipeline::mixed::MultiTenantConfig::with_network

use crate::experiments::common::Fidelity;
use crate::experiments::runner;
use crate::net::{NetworkSpec, Placement};
use crate::pipeline::failover::{self, FailoverSpec, VICTIM};
use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantReport, MultiTenantSim};
use crate::util::json::Json;
use crate::util::units::{fmt_us, gbps, SEC};

/// Facerec acceleration factors swept.
pub const ACCELS: [f64; 2] = [1.0, 4.0];
/// Rack-uplink oversubscription factors swept (1.0 = non-blocking).
pub const OVERSUBS: [f64; 3] = [1.0, 4.0, 8.0];
/// Per-node access-link rate: the purpose-built compute node's 10 GbE
/// (Table 4) — tight enough that a busy broker is a meaningful fraction
/// of its rack's uplink.
pub const LINK_BW: f64 = gbps(10);
/// Kill instant as a fraction of the horizon.
pub const KILL_FRAC: f64 = 0.3;
/// How long the victim stays down before rejoining.
pub const DOWNTIME_US: u64 = SEC;
/// Re-replication pacing — above the world's ongoing write rate on an
/// uncontended fabric, so any arm where recovery stretches or never
/// finishes is showing *network* throttling, not pacing.
pub const RECOVERY_GBPS: f64 = 0.8;
/// Per-broker page cache, as in the failover sweep: the victim's missed
/// window has aged out and catch-up reads go to the device.
pub const CACHE_BYTES: f64 = 2e9;

/// One network arm: `None` = network disabled (the PR 8 fixed-latency
/// wire), `Some((oversub, placement))` = contention-aware fabric.
pub type NetArm = Option<(f64, Placement)>;

/// One sweep point: acceleration × network arm, on the failover
/// scenario.
pub struct NetPathPoint {
    pub accel: f64,
    pub arm: NetArm,
    pub restart_at_us: u64,
    pub report: MultiTenantReport,
}

impl NetPathPoint {
    /// Restart → ISR rejoin (µs); `None` if recovery never finished
    /// inside the horizon (on a squeezed uplink it may not).
    pub fn recovery_duration_us(&self) -> Option<u64> {
        let f = self.report.fault.as_ref()?;
        Some(f.recovery_done_us?.saturating_sub(self.restart_at_us))
    }

    /// The rpc canary's e2e p99 over the recovery window (µs).
    pub fn rpc_window_p99_us(&self) -> u64 {
        self.report.tenant("rpc").map(|t| t.e2e_p99_window_us).unwrap_or(0)
    }

    /// Facerec's e2e p99 over the recovery window (µs) — its fetch
    /// responses are the heaviest uplink flows in the world.
    pub fn facerec_window_p99_us(&self) -> u64 {
        self.report
            .tenant("facerec")
            .map(|t| t.e2e_p99_window_us)
            .unwrap_or(0)
    }

    fn arm_label(&self) -> String {
        match self.arm {
            None => "off".into(),
            Some((o, Placement::CoLocated)) => format!("{o}:1 colo"),
            Some((o, Placement::BrokerIsolated)) => format!("{o}:1 isol"),
        }
    }
}

/// The full sweep.
pub struct NetPathSweep {
    pub horizon_us: u64,
    pub points: Vec<NetPathPoint>,
}

impl NetPathSweep {
    pub fn point(&self, accel: f64, arm: NetArm) -> Option<&NetPathPoint> {
        self.points.iter().find(|p| p.accel == accel && p.arm == arm)
    }
}

/// The failover registry at one (accel, arm) point.
pub fn registry_for(accel: f64, arm: NetArm, horizon_us: u64) -> MultiTenantConfig {
    let kill_at_us = (KILL_FRAC * horizon_us as f64) as u64;
    let spec = FailoverSpec {
        kill_at_us,
        restart_at_us: kill_at_us + DOWNTIME_US,
        classed: true,
        recovery_bytes_per_sec: RECOVERY_GBPS * 1e9,
        cache_bytes: CACHE_BYTES,
    };
    let mut cfg = failover::registry(spec, horizon_us);
    cfg.tenants[0].cfg.accel = accel;
    cfg.fabric.accel = accel;
    match arm {
        Some((oversub, placement)) => {
            cfg.with_network(NetworkSpec::new(oversub, LINK_BW).with_placement(placement))
        }
        None => cfg,
    }
}

/// Run an explicit set of `(accel, arm)` points, fanned out over the
/// deterministic parallel runner.
pub fn run_points(points: Vec<(f64, NetArm)>, fidelity: Fidelity) -> NetPathSweep {
    let horizon = fidelity.horizon_us();
    let points = runner::map(points, move |(accel, arm)| {
        let restart_at_us = (KILL_FRAC * horizon as f64) as u64 + DOWNTIME_US;
        NetPathPoint {
            accel,
            arm,
            restart_at_us,
            report: MultiTenantSim::new(registry_for(accel, arm, horizon)).run(),
        }
    });
    NetPathSweep { horizon_us: horizon, points }
}

/// The full grid: per acceleration, a disabled baseline plus
/// oversubscription × placement.
pub fn run(fidelity: Fidelity) -> NetPathSweep {
    let mut grid: Vec<(f64, NetArm)> = Vec::new();
    for &accel in &ACCELS {
        grid.push((accel, None));
        for &oversub in &OVERSUBS {
            grid.push((accel, Some((oversub, Placement::CoLocated))));
            grid.push((accel, Some((oversub, Placement::BrokerIsolated))));
        }
    }
    run_points(grid, fidelity)
}

/// The machine-readable report.
pub fn to_json(sweep: &NetPathSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("net-path".into())),
        ("horizon_us", Json::Num(sweep.horizon_us as f64)),
        ("link_gbps", Json::Num(LINK_BW * 8.0 / 1e9)),
        ("victim_broker", Json::Num(VICTIM as f64)),
        ("downtime_us", Json::Num(DOWNTIME_US as f64)),
        ("recovery_gbps", Json::Num(RECOVERY_GBPS)),
        (
            "points",
            Json::arr(sweep.points.iter().map(point_json).collect()),
        ),
    ])
}

fn point_json(p: &NetPathPoint) -> Json {
    Json::obj(vec![
        ("accel", Json::Num(p.accel)),
        ("network", Json::Bool(p.arm.is_some())),
        (
            "oversub",
            match p.arm {
                Some((o, _)) => Json::Num(o),
                None => Json::Null,
            },
        ),
        (
            "placement",
            Json::Str(
                match p.arm {
                    None => "none",
                    Some((_, Placement::CoLocated)) => "co-located",
                    Some((_, Placement::BrokerIsolated)) => "broker-isolated",
                }
                .into(),
            ),
        ),
        ("rpc_window_p99_us", Json::Num(p.rpc_window_p99_us() as f64)),
        (
            "facerec_window_p99_us",
            Json::Num(p.facerec_window_p99_us() as f64),
        ),
        (
            "recovery_duration_us",
            match p.recovery_duration_us() {
                Some(us) => Json::Num(us as f64),
                None => Json::Null,
            },
        ),
        (
            "net_contended_transfers",
            Json::Num(p.report.net_contended_transfers as f64),
        ),
        (
            "net_max_uplink_util",
            Json::Num(p.report.net_max_uplink_util),
        ),
        (
            "metrics",
            crate::metrics::registry::MetricsRegistry::from_report(&p.report).to_json(),
        ),
        (
            "tenants",
            Json::arr(
                p.report
                    .tenants
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("name", Json::Str(t.name.clone())),
                            ("completed", Json::Num(t.completed as f64)),
                            ("e2e_p99_us", Json::Num(t.e2e_p99_us as f64)),
                            (
                                "e2e_p99_window_us",
                                Json::Num(t.e2e_p99_window_us as f64),
                            ),
                            ("net_tx_bytes", Json::Num(t.net_tx_bytes)),
                            ("net_rx_bytes", Json::Num(t.net_rx_bytes)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the JSON report next to the AOT artifacts when that directory
/// exists (same lookup as the other sweep drivers).
fn write_report(json: &Json) -> Option<std::path::PathBuf> {
    let dir = crate::runtime::Manifest::default_dir();
    if !dir.is_dir() {
        return None;
    }
    let path = dir.join("net_path_report.json");
    std::fs::write(&path, json.pretty()).ok()?;
    Some(path)
}

pub fn print(sweep: &NetPathSweep) {
    println!(
        "\nNet-path — failover world on a ToR/spine fabric ({} GbE access, \
         rack uplinks at N:1); broker {} killed at {}×horizon, back {} later",
        (LINK_BW * 8.0 / 1e9) as u64,
        VICTIM,
        KILL_FRAC,
        fmt_us(DOWNTIME_US),
    );
    println!(
        "  {:>5} {:>9} {:>10} {:>12} {:>12} {:>10} {:>8}",
        "accel", "network", "recovery", "rpc p99(w)", "fr p99(w)", "contended", "uplink%"
    );
    for p in &sweep.points {
        println!(
            "  {:>4}x {:>9} {:>10} {:>12} {:>12} {:>10} {:>7.1}%",
            p.accel,
            p.arm_label(),
            match p.recovery_duration_us() {
                Some(us) => fmt_us(us),
                None => "never".into(),
            },
            fmt_us(p.rpc_window_p99_us()),
            fmt_us(p.facerec_window_p99_us()),
            p.report.net_contended_transfers,
            100.0 * p.report.net_max_uplink_util,
        );
    }
    println!(
        "  takeaway: the wire is only free while it is non-blocking — on \
         oversubscribed uplinks the recovery stream and the fetch fan-out \
         fight for the same rack links and both lose; packing the brokers \
         into their own rack takes replication and repair off the uplinks \
         and restores most of the disabled-arm numbers"
    );
    let json = to_json(sweep);
    match write_report(&json) {
        Some(path) => println!("  json report written to {}", path.display()),
        None => println!("  json report:\n{}", json.pretty()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_arm_has_no_network_numbers_and_json_is_complete() {
        let sweep = run_points(
            vec![(4.0, None), (4.0, Some((8.0, Placement::CoLocated)))],
            Fidelity::Quick,
        );
        let off = sweep.point(4.0, None).unwrap();
        assert_eq!(off.report.net_contended_transfers, 0);
        assert_eq!(off.report.net_max_uplink_util, 0.0);
        let on = sweep.point(4.0, Some((8.0, Placement::CoLocated))).unwrap();
        assert!(
            on.report.net_contended_transfers > 0,
            "an 8:1 co-located fabric must see some transfer below its solo share"
        );
        assert!(on.report.net_max_uplink_util > 0.0);
        // Both arms survive the failure and keep every tenant alive.
        for p in [off, on] {
            assert!(p.report.fault.is_some());
            for t in &p.report.tenants {
                assert!(t.completed > 0, "tenant {} starved", t.name);
            }
        }
        let j = to_json(&sweep);
        let points = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 2);
        for p in points {
            assert!(p.get("rpc_window_p99_us").and_then(|v| v.as_f64()).is_some());
            assert!(p.get("net_contended_transfers").is_some());
            assert_eq!(p.get("tenants").and_then(|t| t.as_arr()).unwrap().len(), 3);
        }
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            reparsed.get("experiment").and_then(|e| e.as_str()),
            Some("net-path")
        );
    }
}
