//! Broker QoS: the N-tenant p99-vs-share SLO sweep (`aitax experiment qos`).
//!
//! The Fig-15-style *mitigation view* for multi-tenancy. Four tenants
//! colocate on the paper's 3-broker fabric:
//!
//! * **facerec** — §5.3 acceleration deployment at 4× (stable alone);
//! * **objdet** — §6.3 deployment at 6×, fleet scaled by the sweep share;
//! * **train-ingest** — large sequential shard writes, scaled by share;
//! * **rpc** — small-record low-latency tenant with a p99 SLO, constant.
//!
//! Each share runs twice: QoS **off** (the pre-PR shared-FIFO broker) and
//! QoS **on** (scheduling classes + produce quotas on the bulk tenants).
//! Without QoS, growing the colocated share pushes the shared NVMe write
//! path past saturation and the RPC tenant's p99 — a tenant whose byte
//! footprint is ~0.5% of the fabric's — blows through its SLO purely on
//! inherited broker wait. With QoS the bulk tenants are throttled to a
//! byte budget and the RPC class is weighted up, so its p99 stays inside
//! the SLO at every share: isolation, not hardware, is the mitigation.
//!
//! `run` returns structured results; [`print`] renders the table plus a
//! machine-readable JSON report (also written to `artifacts/qos_report.json`
//! when the artifacts directory is present).

use crate::config::{Config, Deployment};
use crate::experiments::common::{facerec_accel, objdet_accel, Fidelity};
use crate::experiments::runner;
use crate::pipeline::dc::WorkloadKind;
use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantReport, MultiTenantSim, TenantDef};
use crate::util::json::Json;
use crate::util::units::fmt_us;

/// Colocated share of the bulk tenants' nominal fleets (objdet + train).
pub const QOS_SHARES: [f64; 4] = [0.25, 0.5, 0.75, 1.0];
/// Face Recognition acceleration (stable alone; same as `mixed`).
pub const ACCEL_FACEREC: f64 = 4.0;
/// Object Detection acceleration (stable alone; same as `mixed`).
pub const ACCEL_OBJDET: f64 = 6.0;
/// Produce-byte budget for each bulk tenant when QoS is on (B/s). Sized
/// so facerec (~420 MB/s) + 2 × 60 MB/s stays under the fabric's
/// ~770 MB/s effective write bandwidth with headroom for bursts.
pub const BULK_PRODUCE_QUOTA: f64 = 60e6;
/// Scheduling-class weights: the latency tenant outranks the bulk ones.
pub const RPC_WEIGHT: f64 = 8.0;
pub const FACEREC_WEIGHT: f64 = 2.0;
pub const BULK_WEIGHT: f64 = 1.0;

/// Scale a deployment's producer/consumer fleet (partitions follow).
fn scale_fleet(d: &mut Deployment, share: f64) {
    d.producers = ((d.producers as f64 * share).round() as usize).max(1);
    d.consumers = ((d.consumers as f64 * share).round() as usize).max(1);
    d.partitions = d.consumers;
}

/// The 4-tenant registry at one sweep point. The QoS specs (weights +
/// quotas) are always attached; `qos_on` decides whether they bind.
pub fn registry(share: f64, qos_on: bool, fidelity: Fidelity) -> MultiTenantConfig {
    let fr = facerec_accel(ACCEL_FACEREC, fidelity);
    let mut od = objdet_accel(ACCEL_OBJDET, fidelity);
    scale_fleet(&mut od.deployment, share);

    let mut tr = Config::default();
    tr.deployment = Deployment::train_ingest();
    scale_fleet(&mut tr.deployment, share);
    tr.duration_us = fidelity.horizon_us();
    tr.seed = 0x7EA1;

    let mut rpc = Config::default();
    rpc.deployment = Deployment::rpc_service();
    rpc.duration_us = fidelity.horizon_us();
    rpc.seed = 0x59C;

    let fabric = fr.clone();
    let duration = fr.duration_us;
    MultiTenantConfig::new(fabric, duration)
        .tenant(
            TenantDef::new("facerec", WorkloadKind::FaceRec, fr).with_weight(FACEREC_WEIGHT),
        )
        .tenant(
            TenantDef::new("objdet", WorkloadKind::ObjDet, od)
                .with_weight(BULK_WEIGHT)
                .with_produce_quota(BULK_PRODUCE_QUOTA),
        )
        .tenant(
            TenantDef::new("train-ingest", WorkloadKind::TrainIngest, tr)
                .with_weight(BULK_WEIGHT)
                .with_produce_quota(BULK_PRODUCE_QUOTA),
        )
        .tenant(TenantDef::new("rpc", WorkloadKind::Rpc, rpc).with_weight(RPC_WEIGHT))
        .with_qos(qos_on)
}

/// One sweep point: a share × {off,on} run.
pub struct QosPoint {
    pub share: f64,
    pub qos_on: bool,
    pub report: MultiTenantReport,
}

/// The full sweep plus the RPC tenant's SLO for verdicts.
pub struct QosSweep {
    pub slo_p99_us: u64,
    pub points: Vec<QosPoint>,
}

impl QosSweep {
    /// The (off, on) pair of points at one share.
    pub fn pair(&self, share: f64) -> (Option<&QosPoint>, Option<&QosPoint>) {
        let find = |on: bool| {
            self.points
                .iter()
                .find(|p| p.share == share && p.qos_on == on)
        };
        (find(false), find(true))
    }

    /// RPC p99 at one point (µs).
    pub fn rpc_p99(p: &QosPoint) -> u64 {
        p.report.tenant("rpc").map(|t| t.e2e_p99_us).unwrap_or(0)
    }
}

/// Run the sweep at the given shares (each share twice: QoS off and on).
/// The share × {off,on} grid fans out over the deterministic parallel
/// runner; points come back in grid order.
pub fn run_at(shares: &[f64], fidelity: Fidelity) -> QosSweep {
    let slo_p99_us = Config::default().calibration.rpc.slo_p99_us;
    let grid: Vec<(f64, bool)> = shares
        .iter()
        .flat_map(|&share| [(share, false), (share, true)])
        .collect();
    let points = runner::map(grid, |(share, qos_on)| QosPoint {
        share,
        qos_on,
        report: MultiTenantSim::new(registry(share, qos_on, fidelity)).run(),
    });
    QosSweep { slo_p99_us, points }
}

pub fn run(fidelity: Fidelity) -> QosSweep {
    run_at(&QOS_SHARES, fidelity)
}

/// The machine-readable per-tenant p99-vs-share report.
pub fn to_json(sweep: &QosSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("qos".into())),
        ("slo_p99_us", Json::Num(sweep.slo_p99_us as f64)),
        (
            "accel",
            Json::obj(vec![
                ("facerec", Json::Num(ACCEL_FACEREC)),
                ("objdet", Json::Num(ACCEL_OBJDET)),
            ]),
        ),
        ("bulk_produce_quota_bytes_per_sec", Json::Num(BULK_PRODUCE_QUOTA)),
        (
            "points",
            Json::arr(
                sweep
                    .points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("share", Json::Num(p.share)),
                            ("qos", Json::Bool(p.qos_on)),
                            (
                                "broker_storage_write_util",
                                Json::Num(p.report.broker_storage_write_util),
                            ),
                            ("broker_cpu_util", Json::Num(p.report.broker_cpu_util)),
                            ("events", Json::Num(p.report.events as f64)),
                            (
                                "metrics",
                                crate::metrics::registry::MetricsRegistry::from_report(&p.report)
                                    .to_json(),
                            ),
                            (
                                "tenants",
                                Json::arr(
                                    p.report
                                        .tenants
                                        .iter()
                                        .map(|t| {
                                            Json::obj(vec![
                                                ("name", Json::Str(t.name.clone())),
                                                ("kind", Json::Str(t.kind.label().into())),
                                                ("completed", Json::Num(t.completed as f64)),
                                                (
                                                    "throughput_per_sec",
                                                    Json::Num(t.throughput_per_sec),
                                                ),
                                                ("wait_mean_us", Json::Num(t.wait_mean_us)),
                                                (
                                                    "e2e_p99_us",
                                                    Json::Num(t.e2e_p99_us as f64),
                                                ),
                                                ("stable", Json::Bool(t.stable)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the JSON report next to the AOT artifacts when that directory
/// exists (reusing `runtime::Manifest::default_dir`'s lookup so the
/// report always lands where the manifest machinery looks).
fn write_report(json: &Json) -> Option<std::path::PathBuf> {
    let dir = crate::runtime::Manifest::default_dir();
    if !dir.is_dir() {
        return None;
    }
    let path = dir.join("qos_report.json");
    std::fs::write(&path, json.pretty()).ok()?;
    Some(path)
}

pub fn print(sweep: &QosSweep) {
    println!(
        "\nBroker QoS — facerec({ACCEL_FACEREC}x) + objdet({ACCEL_OBJDET}x·share) + \
         train-ingest(·share) + rpc on one fabric"
    );
    println!(
        "  rpc SLO: e2e p99 <= {} | bulk produce quota when on: {:.0} MB/s each",
        fmt_us(sweep.slo_p99_us),
        BULK_PRODUCE_QUOTA / 1e6
    );
    println!(
        "  {:>6} {:>4} {:>12} {:>9} {:>12} {:>12} {:>12} {:>11} {:>9}",
        "share", "qos", "rpc p99", "rpc slo", "rpc wait", "fr p99", "train p99", "nvme write", "req cpu"
    );
    for p in &sweep.points {
        let rpc = p.report.tenant("rpc");
        let fr = p.report.tenant("facerec");
        let tr = p.report.tenant("train-ingest");
        let rpc_p99 = rpc.map(|t| t.e2e_p99_us).unwrap_or(0);
        println!(
            "  {:>5.0}% {:>4} {:>12} {:>9} {:>12} {:>12} {:>12} {:>10.1}% {:>8.2}%",
            100.0 * p.share,
            if p.qos_on { "on" } else { "off" },
            fmt_us(rpc_p99),
            if rpc_p99 <= sweep.slo_p99_us { "met" } else { "MISSED" },
            fmt_us(rpc.map(|t| t.wait_mean_us as u64).unwrap_or(0)),
            fmt_us(fr.map(|t| t.e2e_p99_us).unwrap_or(0)),
            fmt_us(tr.map(|t| t.e2e_p99_us).unwrap_or(0)),
            100.0 * p.report.broker_storage_write_util,
            100.0 * p.report.broker_cpu_util,
        );
    }
    println!(
        "  takeaway: the rpc tenant misses its SLO on inherited broker wait as the \
         colocated share grows; scheduling classes + quotas hold it inside the SLO \
         at every share"
    );
    let json = to_json(sweep);
    match write_report(&json) {
        Some(path) => println!("  json report written to {}", path.display()),
        None => println!("  json report:\n{}", json.pretty()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rpc_degrades_without_qos_and_holds_with_it() {
        // Full ≥3-tenant colocation, the acceptance point: QoS off must
        // break the RPC SLO (shared write path past saturation), QoS on
        // must hold it.
        let sweep = run_at(&[1.0], Fidelity::Quick);
        let (off, on) = sweep.pair(1.0);
        let (off, on) = (off.unwrap(), on.unwrap());
        let p99_off = QosSweep::rpc_p99(off);
        let p99_on = QosSweep::rpc_p99(on);
        assert!(
            p99_off > sweep.slo_p99_us,
            "without QoS the rpc p99 must blow the SLO: {} vs {}",
            p99_off,
            sweep.slo_p99_us
        );
        assert!(
            p99_on <= sweep.slo_p99_us,
            "with QoS the rpc p99 must hold the SLO: {} vs {}",
            p99_on,
            sweep.slo_p99_us
        );
        assert!(p99_on < p99_off);
        // The mechanism: quotas pull the shared write path back from
        // saturation.
        assert!(
            off.report.broker_storage_write_util > 0.85,
            "off-point write util {} should be near/past saturation",
            off.report.broker_storage_write_util
        );
        assert!(
            on.report.broker_storage_write_util
                < 0.9 * off.report.broker_storage_write_util,
            "quotas must relieve the write path: {} vs {}",
            on.report.broker_storage_write_util,
            off.report.broker_storage_write_util
        );
    }

    #[test]
    fn low_share_is_gentle_even_without_qos() {
        let sweep = run_at(&[0.25], Fidelity::Quick);
        let (off, _) = sweep.pair(0.25);
        let off = off.unwrap();
        // A quarter of the bulk fleets leaves headroom: every tenant
        // keeps completing and the rpc p99 stays within an order of
        // magnitude of its SLO (the cliff is a *share* effect).
        for t in &off.report.tenants {
            assert!(t.completed > 0, "tenant {} starved at low share", t.name);
        }
        assert!(
            QosSweep::rpc_p99(off) < 10 * sweep.slo_p99_us,
            "rpc p99 at 25% share should not be catastrophic: {}",
            QosSweep::rpc_p99(off)
        );
    }

    #[test]
    fn json_report_carries_every_point_and_tenant() {
        let sweep = run_at(&[0.5], Fidelity::Quick);
        let j = to_json(&sweep);
        let points = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 2); // off + on
        for p in points {
            let tenants = p.get("tenants").and_then(|t| t.as_arr()).unwrap();
            assert_eq!(tenants.len(), 4);
            assert!(p.get("share").and_then(|s| s.as_f64()).is_some());
        }
        // Round-trips through the parser.
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed.get("experiment").and_then(|e| e.as_str()), Some("qos"));
    }
}
