//! Read path: the lagging-consumer sweep (`aitax experiment read-path`).
//!
//! Fig 11's storage story is one-sided by assumption: producer writes
//! saturate the NVMe while consumer reads are "free" because they hit
//! the OS page cache. The measured read path
//! ([`Fabric::enable_read_path`]) replaces that assumption with a model
//! — per-broker page caches keyed by partition group, consumer offsets,
//! cold reads contending with replicated writes on the spindle — and
//! this sweep quantifies where the assumption *breaks*: the catch-up
//! scenario ([`crate::pipeline::catchup`]), facerec(4×) + train-ingest
//! + rpc, where the train consumers start `lag` seconds behind and
//! drain their backlog at resume.
//!
//! Three axes:
//!
//! * **lag depth** — how far behind the catch-up consumers start;
//! * **cache size** — the per-broker page-cache capacity (residency
//!   window ≈ capacity / per-broker log write rate, ~640 MB/s here);
//! * **reads unclassed vs classed** — the cold burst on the seed FIFO
//!   spindle versus carried through the per-class GPS write scheduler
//!   at the tenant weights.
//!
//! Reported per point: byte-weighted cache hit ratio, device read
//! share, and the per-tenant p99s. Past the lag threshold (lag >
//! residency) device reads appear; unclassed, the cold burst head-of-
//! line blocks every tenant's produce path and the facerec/rpc p99s
//! spike; classed, the replay drains at weight 1 and the latency
//! tenants hold.
//!
//! `run` returns structured results; [`print`] renders the table plus a
//! machine-readable JSON report (written to
//! `artifacts/read_path_report.json` when the artifacts directory is
//! present).
//!
//! [`Fabric::enable_read_path`]: crate::pipeline::fabric::Fabric::enable_read_path

use crate::config::Config;
use crate::experiments::common::Fidelity;
use crate::experiments::runner;
use crate::pipeline::catchup::{self, CatchupSpec};
use crate::pipeline::mixed::MultiTenantReport;
use crate::util::json::Json;
use crate::util::units::{fmt_us, SEC};

/// Catch-up consumer lag depths (seconds behind at start).
pub const LAG_SECS: [f64; 3] = [0.0, 5.0, 10.0];
/// Per-broker page-cache capacities (GB). At this scenario's ~640 MB/s
/// of per-broker log traffic (facerec ~478 + train 160 + rpc 4, each
/// broker carrying leader plus follower copies), 2 GB is a ~3 s
/// residency window (both nonzero lags go cold) and 16 GB is ~25 s
/// (everything stays warm across the sweep horizons).
pub const CACHE_GB: [f64; 2] = [2.0, 16.0];

/// One sweep point: lag × cache × {unclassed, classed} run.
pub struct ReadPathPoint {
    pub lag_secs: f64,
    pub cache_gb: f64,
    pub classed_reads: bool,
    pub report: MultiTenantReport,
}

/// The full sweep plus the RPC tenant's SLO for verdicts.
pub struct ReadPathSweep {
    pub slo_p99_us: u64,
    pub points: Vec<ReadPathPoint>,
}

impl ReadPathSweep {
    /// The (unclassed, classed) pair of points at one (lag, cache).
    pub fn pair(
        &self,
        lag_secs: f64,
        cache_gb: f64,
    ) -> (Option<&ReadPathPoint>, Option<&ReadPathPoint>) {
        let find = |classed: bool| {
            self.points.iter().find(|p| {
                p.lag_secs == lag_secs && p.cache_gb == cache_gb && p.classed_reads == classed
            })
        };
        (find(false), find(true))
    }

    /// A tenant's e2e p99 at one point (µs).
    pub fn p99(p: &ReadPathPoint, tenant: &str) -> u64 {
        p.report.tenant(tenant).map(|t| t.e2e_p99_us).unwrap_or(0)
    }
}

/// Run an explicit set of `(lag_secs, cache_gb, classed_reads)` points,
/// fanned out over the deterministic parallel runner.
pub fn run_points(points: Vec<(f64, f64, bool)>, fidelity: Fidelity) -> ReadPathSweep {
    let slo_p99_us = Config::default().calibration.rpc.slo_p99_us;
    let horizon = fidelity.horizon_us();
    let points = runner::map(points, move |(lag_secs, cache_gb, classed_reads)| {
        let spec = CatchupSpec {
            lag_us: (lag_secs * SEC as f64) as u64,
            cache_bytes: cache_gb * 1e9,
            classed_reads,
        };
        ReadPathPoint {
            lag_secs,
            cache_gb,
            classed_reads,
            report: catchup::run(spec, horizon),
        }
    });
    ReadPathSweep { slo_p99_us, points }
}

/// Run the sweep over a lag × cache grid (each point twice: reads
/// unclassed and classed).
pub fn run_grid(lags_secs: &[f64], caches_gb: &[f64], fidelity: Fidelity) -> ReadPathSweep {
    let grid: Vec<(f64, f64, bool)> = lags_secs
        .iter()
        .flat_map(|&lag| {
            caches_gb
                .iter()
                .flat_map(move |&gb| [(lag, gb, false), (lag, gb, true)])
        })
        .collect();
    run_points(grid, fidelity)
}

pub fn run(fidelity: Fidelity) -> ReadPathSweep {
    run_grid(&LAG_SECS, &CACHE_GB, fidelity)
}

/// The machine-readable report.
pub fn to_json(sweep: &ReadPathSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("read-path".into())),
        ("slo_p99_us", Json::Num(sweep.slo_p99_us as f64)),
        ("accel_facerec", Json::Num(catchup::ACCEL_FACEREC)),
        (
            "storage_weights",
            Json::obj(vec![
                ("facerec", Json::Num(catchup::FACEREC_WEIGHT)),
                ("train-ingest", Json::Num(catchup::TRAIN_WEIGHT)),
                ("rpc", Json::Num(catchup::RPC_WEIGHT)),
            ]),
        ),
        (
            "points",
            Json::arr(
                sweep
                    .points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("lag_secs", Json::Num(p.lag_secs)),
                            ("cache_gb", Json::Num(p.cache_gb)),
                            ("classed_reads", Json::Bool(p.classed_reads)),
                            ("cache_hit_ratio", Json::Num(p.report.cache_hit_ratio)),
                            (
                                "device_read_share",
                                Json::Num(p.report.device_read_share),
                            ),
                            (
                                "broker_storage_read_util",
                                Json::Num(p.report.broker_storage_read_util),
                            ),
                            (
                                "broker_storage_write_util",
                                Json::Num(p.report.broker_storage_write_util),
                            ),
                            ("events", Json::Num(p.report.events as f64)),
                            (
                                "metrics",
                                crate::metrics::registry::MetricsRegistry::from_report(&p.report)
                                    .to_json(),
                            ),
                            (
                                "tenants",
                                Json::arr(
                                    p.report
                                        .tenants
                                        .iter()
                                        .map(|t| {
                                            Json::obj(vec![
                                                ("name", Json::Str(t.name.clone())),
                                                ("kind", Json::Str(t.kind.label().into())),
                                                ("completed", Json::Num(t.completed as f64)),
                                                (
                                                    "throughput_per_sec",
                                                    Json::Num(t.throughput_per_sec),
                                                ),
                                                ("wait_mean_us", Json::Num(t.wait_mean_us)),
                                                (
                                                    "e2e_p99_us",
                                                    Json::Num(t.e2e_p99_us as f64),
                                                ),
                                                (
                                                    "consumer_lag_bytes",
                                                    Json::Num(t.consumer_lag_bytes as f64),
                                                ),
                                                ("stable", Json::Bool(t.stable)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the JSON report next to the AOT artifacts when that directory
/// exists (same lookup as `experiments::qos` / `storage_qos`).
fn write_report(json: &Json) -> Option<std::path::PathBuf> {
    let dir = crate::runtime::Manifest::default_dir();
    if !dir.is_dir() {
        return None;
    }
    let path = dir.join("read_path_report.json");
    std::fs::write(&path, json.pretty()).ok()?;
    Some(path)
}

pub fn print(sweep: &ReadPathSweep) {
    println!(
        "\nRead path — facerec({}x) + train-ingest(consumers lag N s) + rpc, \
         per-broker page cache × catch-up lag × {{unclassed, classed}} device reads",
        catchup::ACCEL_FACEREC
    );
    println!(
        "  write/read weights: facerec {:.0} | train {:.0} | rpc {:.0} \
         | rpc SLO: e2e p99 <= {}",
        catchup::FACEREC_WEIGHT,
        catchup::TRAIN_WEIGHT,
        catchup::RPC_WEIGHT,
        fmt_us(sweep.slo_p99_us)
    );
    println!(
        "  {:>5} {:>6} {:>7} {:>7} {:>8} {:>12} {:>12} {:>12} {:>10}",
        "lag", "cache", "classed", "hit", "dev rd", "fr p99", "rpc p99", "train p99", "end lag"
    );
    for p in &sweep.points {
        let fr = p.report.tenant("facerec");
        let tr = p.report.tenant("train-ingest");
        let rpc = p.report.tenant("rpc");
        println!(
            "  {:>4.0}s {:>5.0}G {:>7} {:>6.2}% {:>7.2}% {:>12} {:>12} {:>12} {:>9}M",
            p.lag_secs,
            p.cache_gb,
            if p.classed_reads { "yes" } else { "no" },
            100.0 * p.report.cache_hit_ratio,
            100.0 * p.report.device_read_share,
            fmt_us(fr.map(|t| t.e2e_p99_us).unwrap_or(0)),
            fmt_us(rpc.map(|t| t.e2e_p99_us).unwrap_or(0)),
            fmt_us(tr.map(|t| t.e2e_p99_us).unwrap_or(0)),
            tr.map(|t| t.consumer_lag_bytes / 1_000_000).unwrap_or(0),
        );
    }
    println!(
        "  takeaway: past the residency threshold (lag > cache/write-rate) the \
         catch-up drain comes cold off the producers' spindle; unclassed it taxes \
         every tenant's produce path, classed the replayer absorbs its own backlog"
    );
    let json = to_json(sweep);
    match write_report(&json) {
        Some(path) => println!("  json report written to {}", path.display()),
        None => println!("  json report:\n{}", json.pretty()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lag_threshold_brings_device_reads() {
        // The acceptance point: with a 2 GB window (~3.5 s of residency)
        // a 10 s lag must surface device reads, while the zero-lag
        // control stays effectively all-hit.
        let sweep = run_points(
            vec![(0.0, 2.0, false), (10.0, 2.0, false)],
            Fidelity::Quick,
        );
        let (warm, _) = sweep.pair(0.0, 2.0);
        let (cold, _) = sweep.pair(10.0, 2.0);
        let (warm, cold) = (warm.unwrap(), cold.unwrap());
        assert!(
            warm.report.cache_hit_ratio > 0.99,
            "streaming world must stay warm: hit {}",
            warm.report.cache_hit_ratio
        );
        assert!(warm.report.device_read_share < 0.01);
        assert!(
            cold.report.cache_hit_ratio < 0.99,
            "10 s of lag must fall out of a ~3.5 s window: hit {}",
            cold.report.cache_hit_ratio
        );
        assert!(cold.report.device_read_share > 0.01);
        assert!(cold.report.broker_storage_read_util > 0.0);
    }

    #[test]
    fn classed_reads_hold_facerec_and_rpc_at_full_catchup() {
        // Full catch-up load on the small window: unclassed, the cold
        // burst head-of-line blocks the latency tenants' produce paths;
        // classed, the replay drains at weight 1 and both hold.
        let sweep = run_grid(&[10.0], &[2.0], Fidelity::Quick);
        let (off, on) = sweep.pair(10.0, 2.0);
        let (off, on) = (off.unwrap(), on.unwrap());
        let fr_off = ReadPathSweep::p99(off, "facerec");
        let fr_on = ReadPathSweep::p99(on, "facerec");
        let rpc_off = ReadPathSweep::p99(off, "rpc");
        let rpc_on = ReadPathSweep::p99(on, "rpc");
        assert!(
            fr_on < fr_off,
            "classed reads must hold facerec p99: on {fr_on} vs off {fr_off}"
        );
        assert!(
            rpc_on < rpc_off,
            "classed reads must hold rpc p99: on {rpc_on} vs off {rpc_off}"
        );
        // The replay itself still drains in both arms (tax, not
        // starvation): every tenant completes work.
        for p in [off, on] {
            for t in &p.report.tenants {
                assert!(t.completed > 0, "tenant {} starved", t.name);
            }
        }
    }

    #[test]
    fn hit_ratio_monotone_in_cache_size_and_lag_depth() {
        // Unclassed arm only — the property is about the cache model,
        // not the scheduler.
        let grid: Vec<(f64, f64, bool)> = [0.0, 5.0, 10.0]
            .iter()
            .flat_map(|&lag| [(lag, 2.0, false), (lag, 16.0, false)])
            .collect();
        let sweep = run_points(grid, Fidelity::Quick);
        let hit = |lag: f64, gb: f64| {
            sweep.pair(lag, gb).0.unwrap().report.cache_hit_ratio
        };
        // Non-increasing in lag at fixed cache size.
        for &gb in &[2.0, 16.0] {
            assert!(
                hit(0.0, gb) >= hit(5.0, gb) && hit(5.0, gb) >= hit(10.0, gb),
                "hit ratio must not rise with lag at {gb} GB: {} {} {}",
                hit(0.0, gb),
                hit(5.0, gb),
                hit(10.0, gb)
            );
        }
        // Non-decreasing in cache size at fixed lag.
        for &lag in &[0.0, 5.0, 10.0] {
            assert!(
                hit(lag, 16.0) >= hit(lag, 2.0),
                "a bigger cache must not hit less at lag {lag}: {} vs {}",
                hit(lag, 16.0),
                hit(lag, 2.0)
            );
        }
    }

    #[test]
    fn default_cache_reproduces_the_calibrated_hit_rate() {
        // The §5.4 calibration target (`BrokerModel::read_cache_hit`):
        // under nominal lag — every consumer streaming — the default
        // page-cache capacity must reproduce at least the calibrated
        // hit ratio. This is what makes the 0.995 constant a *checked
        // consequence* of the model instead of a dead number.
        let horizon = Fidelity::Quick.horizon_us();
        let cfg = catchup::registry(
            CatchupSpec { lag_us: 0, cache_bytes: 0.0, classed_reads: false },
            horizon,
        )
        .with_default_read_cache();
        let target = Config::default().calibration.broker.read_cache_hit;
        let report = crate::pipeline::mixed::MultiTenantSim::new(cfg).run();
        assert!(
            report.cache_hit_ratio >= target,
            "default cache must reproduce the §5.4 hit target: {} < {target}",
            report.cache_hit_ratio
        );
    }

    #[test]
    fn json_report_carries_every_point_and_tenant() {
        let sweep = run_grid(&[5.0], &[2.0], Fidelity::Quick);
        let j = to_json(&sweep);
        let points = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 2); // unclassed + classed
        for p in points {
            let tenants = p.get("tenants").and_then(|t| t.as_arr()).unwrap();
            assert_eq!(tenants.len(), 3);
            assert!(p.get("cache_hit_ratio").and_then(|h| h.as_f64()).is_some());
        }
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            reparsed.get("experiment").and_then(|e| e.as_str()),
            Some("read-path")
        );
    }
}
