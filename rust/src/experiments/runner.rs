//! Deterministic parallel sweep runner.
//!
//! Every experiment driver is a sweep: N independent, deterministic
//! simulations (one per acceleration factor, mitigation variant, tenant
//! share, …) whose results are reported in input order. Until PR 3 each
//! driver ran its points strictly sequentially on one core; this module
//! fans the points out over scoped threads (`std::thread::scope`, the
//! same zero-dependency pattern as `coordinator::live`) and reassembles
//! the results **in input order**, so the output of [`map`] is a pure
//! function of its inputs no matter how many workers ran.
//!
//! # Determinism model
//!
//! Parallelism cannot perturb results here because the unit of
//! parallelism is an entire simulation:
//!
//! * every sweep point owns its whole world — RNG streams, event queue,
//!   metrics — and shares nothing mutable with its siblings;
//! * workers pull indices from an atomic counter, so *scheduling* is
//!   racy, but each result lands in its input-index slot and [`map`]
//!   returns them in input order;
//! * therefore `AITAX_JOBS=1` and `AITAX_JOBS=64` produce byte-identical
//!   reports (pinned by `tests/runner_determinism.rs`); jobs=1 also runs
//!   the exact pre-PR sequential path (same thread, no pool).
//!
//! # Choosing the worker count
//!
//! [`jobs`] resolves, in order: the programmatic override
//! ([`set_jobs_override`], used by `aitax bench kernel` to time jobs=1 vs
//! jobs=N), the `AITAX_JOBS` environment variable, then
//! `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Programmatic worker-count override; 0 = none. Takes precedence over
/// the `AITAX_JOBS` environment variable.
static JOBS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the worker count for subsequent [`map`] calls (`None` clears
/// the override). Used by benchmarks to compare jobs=1 vs jobs=N within
/// one process without touching the environment.
pub fn set_jobs_override(jobs: Option<usize>) {
    JOBS_OVERRIDE.store(jobs.unwrap_or(0), Ordering::SeqCst);
}

/// The worker count [`map`] will use: the programmatic override, else
/// `AITAX_JOBS`, else the machine's available parallelism.
pub fn jobs() -> usize {
    let o = JOBS_OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    if let Ok(v) = std::env::var("AITAX_JOBS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f` over every input, up to [`jobs`] at a time, and return the
/// results **in input order**.
///
/// With one worker (or one input) this degenerates to a plain sequential
/// map on the calling thread — the exact pre-runner code path. A panic in
/// any worker propagates to the caller once the scope joins.
pub fn map<T, R, F>(inputs: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = inputs.len();
    let workers = jobs().min(n);
    if workers <= 1 {
        return inputs.into_iter().map(f).collect();
    }
    // Each input moves to exactly one worker; each result lands in its
    // input-index slot. The mutexes are uncontended (one lock per item).
    let items: Vec<Mutex<Option<T>>> =
        inputs.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = items[i]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("runner input claimed twice");
                let out = f(item);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("runner worker exited before filling its slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The override is process-global and the test harness runs tests
    /// concurrently, so every test that touches it holds this lock.
    static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

    /// Run `body` with a fixed worker count, clearing the override
    /// afterwards. Serialized via [`OVERRIDE_LOCK`].
    fn with_jobs<R>(n: usize, body: impl FnOnce() -> R) -> R {
        let _guard = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_jobs_override(Some(n));
        let out = body();
        set_jobs_override(None);
        out
    }

    #[test]
    fn results_come_back_in_input_order() {
        for workers in [1usize, 2, 8] {
            let out = with_jobs(workers, || map((0..50u64).collect(), |i| i * 10));
            assert_eq!(out, (0..50u64).map(|i| i * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_and_single_inputs() {
        let empty: Vec<u32> = with_jobs(8, || map(Vec::<u32>::new(), |x| x));
        assert!(empty.is_empty());
        let one = with_jobs(8, || map(vec![7u32], |x| x + 1));
        assert_eq!(one, vec![8]);
    }

    #[test]
    fn parallel_equals_sequential_on_stateful_work() {
        // Each item does enough work that scheduling order varies run to
        // run; the output must not.
        let work = |seed: u64| {
            let mut rng = crate::util::rng::Rng::new(seed);
            (0..10_000).map(|_| rng.below(1000)).sum::<u64>()
        };
        let seq = with_jobs(1, || map((0..32u64).collect(), work));
        let par = with_jobs(8, || map((0..32u64).collect(), work));
        assert_eq!(seq, par);
    }

    #[test]
    fn jobs_override_takes_precedence() {
        let _guard = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_jobs_override(Some(3));
        assert_eq!(jobs(), 3);
        set_jobs_override(None);
        assert!(jobs() >= 1);
    }
}
