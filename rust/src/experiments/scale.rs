//! Scale: the million-client sweep (`aitax experiment scale`).
//!
//! The paper's AI tax is measured on fleets of tens to hundreds of
//! clients; an AI data center front-end sees orders of magnitude more.
//! A per-record DES spends one event chain per record, so the event
//! rate — and the wall clock — grows linearly with the client count:
//! 10^6 clients at even 2 req/s is ~2 M record chains per virtual
//! second, far past what one core can replay interactively. The hybrid
//! fluid/discrete layer ([`ProducerKind::Flow`]) collapses a tenant's
//! client population into a handful of deterministic rate processes
//! emitting batched macro-records on a coalescing quantum, so the event
//! rate scales with *partitions × quanta* instead of *clients ×
//! requests* while the broker fabric still sees the same offered byte
//! stream, aggregate request CPU, quota charges, and read-path traffic.
//!
//! This sweep quantifies both halves of that trade:
//!
//! * **cost** — wall-clock and events per simulated run, per-record vs
//!   flow, clients ∈ {10^3 .. 10^6} (per-record stops at
//!   [`PER_RECORD_CAP`]: beyond it the exact replay is exactly the
//!   problem);
//! * **fidelity** — per-tenant means (throughput, byte meters, broker
//!   utilizations, cache hit ratio) flow vs per-record at the same
//!   offered load. Means must converge as N grows (the fluid limit);
//!   latency *tails* are intentionally not pinned — coalescing moves
//!   intra-quantum waits around, which is the approximation being
//!   bought. `tests/flow_differential.rs` enforces the convergence
//!   contract; this sweep reports the deltas.
//!
//! The scenario is a single "edge" RPC tenant — N clients at 2 req/s ×
//! 2 kB — on a fabric whose consumer/broker fleet scales with N, with
//! the measured read path on (finite per-broker page cache) so the
//! flow byte stream exercises produce, replication, quota, *and* fetch
//! accounting.
//!
//! `run` returns structured results; [`print`] renders the table plus a
//! machine-readable JSON report (written to
//! `artifacts/scale_report.json` when the artifacts directory is
//! present). `aitax bench scale` reuses [`run_points`] for the
//! wall-clock speedup figure (`BENCH_scale.json`).
//!
//! [`ProducerKind::Flow`]: crate::pipeline::dc::ProducerKind

use crate::config::{Config, Deployment};
use crate::experiments::common::Fidelity;
use crate::experiments::runner;
use crate::pipeline::dc::WorkloadKind;
use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantSim, TenantDef};
use crate::util::json::Json;
use crate::util::units::fmt_us;

/// Client populations swept (10^3 .. 10^6).
pub const CLIENTS: [u64; 4] = [1_000, 10_000, 100_000, 1_000_000];
/// Largest population the per-record arm replays. Past this the exact
/// simulation is the very cost being measured (≥ 10^5 clients is tens
/// of millions of events per run); the flow arm covers the rest and
/// the differential contract is pinned at this N, where both arms run.
pub const PER_RECORD_CAP: u64 = 10_000;
/// Per-client request cadence, µs (2 req/s — an edge session's
/// heartbeat-ish rate, so 10^6 clients offer 2 M req/s).
pub const CLIENT_PERIOD_US: u64 = 500_000;
/// Per-broker page-cache capacity (bytes) for the measured read path.
pub const CACHE_PER_BROKER: f64 = 8e9;

/// The N-client edge-RPC tenant config: request cadence
/// [`CLIENT_PERIOD_US`], 2 kB records, 250 µs handler, latency-tuned
/// fetch. Consumer / partition / broker fleets scale with the client
/// count so the per-node load stays in the stable regime at every N
/// (util ~50%), which is what makes the flow-vs-per-record means
/// comparable instead of both saturating.
pub fn edge_config(clients: u64, horizon_us: u64) -> Config {
    let mut cfg = Config::default();
    let consumers = (clients / 1_000).clamp(8, 1_024) as usize;
    let brokers = ((clients / 20_000) as usize).clamp(3, 64);
    cfg.deployment = Deployment {
        // Per-record mode instantiates one producer unit per client;
        // flow mode replaces the fleet with ≤ 32 rate processes and
        // only reads this for validation.
        producers: clients.max(1) as usize,
        consumers,
        brokers,
        drives_per_broker: 1,
        replication: 3,
        partitions: consumers,
    };
    cfg.calibration.rpc.period_us = CLIENT_PERIOD_US;
    cfg.calibration.rpc.handle_us = 250.0;
    cfg.duration_us = horizon_us;
    cfg.seed = 0x5CA1E;
    cfg
}

/// The one-tenant registry for a `(clients, flow)` point. Public so the
/// differential tests drive the identical scenario.
pub fn registry(clients: u64, flow: bool, horizon_us: u64) -> MultiTenantConfig {
    let cfg = edge_config(clients, horizon_us);
    let fabric = cfg.clone();
    let mut def = TenantDef::new("edge", WorkloadKind::Rpc, cfg);
    if flow {
        def = def.with_flow_clients(clients);
    }
    MultiTenantConfig::new(fabric, horizon_us)
        .tenant(def)
        .with_read_cache(CACHE_PER_BROKER)
}

/// One sweep point: N clients, per-record or flow, with both the cost
/// (wall clock, events) and the fidelity (tenant means) sides.
pub struct ScalePoint {
    pub clients: u64,
    pub flow: bool,
    /// Host wall-clock for the run, milliseconds (not deterministic —
    /// excluded from [`to_json_model`]).
    pub wall_ms: f64,
    pub events: u64,
    pub clamped: u64,
    pub produced: u64,
    pub completed: u64,
    pub throughput_per_sec: f64,
    pub e2e_mean_us: f64,
    pub e2e_p99_us: u64,
    pub wait_p99_us: u64,
    pub net_tx_bytes: f64,
    pub net_rx_bytes: f64,
    pub broker_write_util: f64,
    pub broker_cpu_util: f64,
    pub cache_hit_ratio: f64,
    pub stable: bool,
}

impl ScalePoint {
    /// DES throughput: events dispatched per host-second.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.events as f64 * 1e3 / self.wall_ms
    }
}

pub struct ScaleSweep {
    pub points: Vec<ScalePoint>,
}

impl ScaleSweep {
    pub fn point(&self, clients: u64, flow: bool) -> Option<&ScalePoint> {
        self.points
            .iter()
            .find(|p| p.clients == clients && p.flow == flow)
    }

    /// (per-record, flow) pair at one N, when both arms ran.
    pub fn pair(&self, clients: u64) -> Option<(&ScalePoint, &ScalePoint)> {
        Some((self.point(clients, false)?, self.point(clients, true)?))
    }
}

/// Relative delta |a−b| / max(|a|, tiny) — 0 when both sides are ~0.
pub fn rel_delta(a: f64, b: f64) -> f64 {
    let scale = a.abs().max(1e-12);
    if a == 0.0 && b == 0.0 {
        0.0
    } else {
        (a - b).abs() / scale
    }
}

fn run_one(clients: u64, flow: bool, horizon_us: u64) -> ScalePoint {
    let sim = MultiTenantSim::new(registry(clients, flow, horizon_us));
    let t0 = std::time::Instant::now();
    let r = sim.run();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t = r.tenant("edge").expect("edge tenant");
    ScalePoint {
        clients,
        flow,
        wall_ms,
        events: r.events,
        clamped: r.clamped_events,
        produced: t.produced,
        completed: t.completed,
        throughput_per_sec: t.throughput_per_sec,
        e2e_mean_us: t.e2e_mean_us,
        e2e_p99_us: t.e2e_p99_us,
        wait_p99_us: t.wait_p99_us,
        net_tx_bytes: t.net_tx_bytes,
        net_rx_bytes: t.net_rx_bytes,
        broker_write_util: r.broker_storage_write_util,
        broker_cpu_util: r.broker_cpu_util,
        cache_hit_ratio: r.cache_hit_ratio,
        stable: t.stable,
    }
}

/// Run an explicit set of `(clients, flow)` points, fanned out over the
/// deterministic parallel runner. Wall-clock per point is measured
/// inside the worker, so jobs>1 timings are noisier but the model
/// outputs stay byte-identical at any `AITAX_JOBS`.
pub fn run_points(points: Vec<(u64, bool)>, fidelity: Fidelity) -> ScaleSweep {
    let horizon = fidelity.horizon_us();
    let points = runner::map(points, move |(clients, flow)| {
        run_one(clients, flow, horizon)
    });
    ScaleSweep { points }
}

/// The default grid: flow at every N in [`CLIENTS`], per-record up to
/// [`PER_RECORD_CAP`].
pub fn grid() -> Vec<(u64, bool)> {
    let mut g = Vec::new();
    for &n in &CLIENTS {
        if n <= PER_RECORD_CAP {
            g.push((n, false));
        }
        g.push((n, true));
    }
    g
}

pub fn run(fidelity: Fidelity) -> ScaleSweep {
    run_points(grid(), fidelity)
}

fn point_json(p: &ScalePoint, with_timing: bool) -> Json {
    let mut fields = vec![
        ("clients", Json::Num(p.clients as f64)),
        ("mode", Json::Str(if p.flow { "flow" } else { "per-record" }.into())),
        ("events", Json::Num(p.events as f64)),
        ("clamped_events", Json::Num(p.clamped as f64)),
        ("produced", Json::Num(p.produced as f64)),
        ("completed", Json::Num(p.completed as f64)),
        ("throughput_per_sec", Json::Num(p.throughput_per_sec)),
        ("e2e_mean_us", Json::Num(p.e2e_mean_us)),
        ("e2e_p99_us", Json::Num(p.e2e_p99_us as f64)),
        ("wait_p99_us", Json::Num(p.wait_p99_us as f64)),
        ("net_tx_bytes", Json::Num(p.net_tx_bytes)),
        ("net_rx_bytes", Json::Num(p.net_rx_bytes)),
        ("broker_write_util", Json::Num(p.broker_write_util)),
        ("broker_cpu_util", Json::Num(p.broker_cpu_util)),
        ("cache_hit_ratio", Json::Num(p.cache_hit_ratio)),
        ("stable", Json::Bool(p.stable)),
    ];
    if with_timing {
        fields.push(("wall_ms", Json::Num(p.wall_ms)));
        fields.push(("events_per_sec", Json::Num(p.events_per_sec())));
    }
    Json::obj(fields)
}

fn convergence_json(sweep: &ScaleSweep) -> Json {
    Json::arr(
        CLIENTS
            .iter()
            .filter_map(|&n| sweep.pair(n))
            .map(|(pr, fl)| {
                Json::obj(vec![
                    ("clients", Json::Num(pr.clients as f64)),
                    (
                        "throughput_delta",
                        Json::Num(rel_delta(pr.throughput_per_sec, fl.throughput_per_sec)),
                    ),
                    (
                        "net_tx_delta",
                        Json::Num(rel_delta(pr.net_tx_bytes, fl.net_tx_bytes)),
                    ),
                    (
                        "write_util_delta",
                        Json::Num(rel_delta(pr.broker_write_util, fl.broker_write_util)),
                    ),
                    (
                        "cache_hit_delta",
                        Json::Num(rel_delta(pr.cache_hit_ratio, fl.cache_hit_ratio)),
                    ),
                    (
                        "e2e_mean_delta",
                        Json::Num(rel_delta(pr.e2e_mean_us, fl.e2e_mean_us)),
                    ),
                    (
                        "event_reduction",
                        Json::Num(pr.events as f64 / (fl.events as f64).max(1.0)),
                    ),
                ])
            })
            .collect(),
    )
}

/// The machine-readable report, timing included (host-dependent).
pub fn to_json(sweep: &ScaleSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("scale".into())),
        ("per_record_cap", Json::Num(PER_RECORD_CAP as f64)),
        ("client_period_us", Json::Num(CLIENT_PERIOD_US as f64)),
        ("cache_per_broker_bytes", Json::Num(CACHE_PER_BROKER)),
        (
            "points",
            Json::arr(sweep.points.iter().map(|p| point_json(p, true)).collect()),
        ),
        ("convergence", convergence_json(sweep)),
    ])
}

/// Model outputs only — no wall-clock fields — so runs on different
/// hosts (or at different `AITAX_JOBS`) serialize byte-identically.
/// `tests/runner_determinism.rs` pins jobs=1 ≡ jobs=8 on this form.
pub fn to_json_model(sweep: &ScaleSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("scale".into())),
        (
            "points",
            Json::arr(sweep.points.iter().map(|p| point_json(p, false)).collect()),
        ),
        ("convergence", convergence_json(sweep)),
    ])
}

fn write_report(json: &Json) -> Option<std::path::PathBuf> {
    let dir = crate::runtime::Manifest::default_dir();
    if !dir.is_dir() {
        return None;
    }
    let path = dir.join("scale_report.json");
    std::fs::write(&path, json.pretty()).ok()?;
    Some(path)
}

pub fn print(sweep: &ScaleSweep) {
    println!(
        "\nScale — edge tenant, N clients × 2 req/s × 2 kB, per-record vs \
         flow-aggregated producers (macro-records on the coalescing quantum)"
    );
    println!(
        "  per-record arm capped at {PER_RECORD_CAP} clients; \
         read path on at {:.0} GB/broker",
        CACHE_PER_BROKER / 1e9
    );
    println!(
        "  {:>9} {:>10} {:>9} {:>9} {:>10} {:>10} {:>10} {:>9} {:>7} {:>7}",
        "clients", "mode", "wall", "events", "thru/s", "e2e mean", "e2e p99", "tx MB", "wr util", "hit"
    );
    for p in &sweep.points {
        println!(
            "  {:>9} {:>10} {:>8.2}s {:>9} {:>10.0} {:>10} {:>10} {:>9.0} {:>6.1}% {:>6.2}%",
            p.clients,
            if p.flow { "flow" } else { "per-record" },
            p.wall_ms / 1e3,
            p.events,
            p.throughput_per_sec,
            fmt_us(p.e2e_mean_us.round() as u64),
            fmt_us(p.e2e_p99_us),
            p.net_tx_bytes / 1e6,
            100.0 * p.broker_write_util,
            100.0 * p.cache_hit_ratio,
        );
    }
    for &n in &CLIENTS {
        if let Some((pr, fl)) = sweep.pair(n) {
            println!(
                "  convergence @ {n}: thru Δ {:.2}% | tx Δ {:.2}% | wr-util Δ {:.2}% \
                 | hit Δ {:.2}% | e2e-mean Δ {:.2}% | {:.0}x fewer events",
                100.0 * rel_delta(pr.throughput_per_sec, fl.throughput_per_sec),
                100.0 * rel_delta(pr.net_tx_bytes, fl.net_tx_bytes),
                100.0 * rel_delta(pr.broker_write_util, fl.broker_write_util),
                100.0 * rel_delta(pr.cache_hit_ratio, fl.cache_hit_ratio),
                100.0 * rel_delta(pr.e2e_mean_us, fl.e2e_mean_us),
                pr.events as f64 / (fl.events as f64).max(1.0),
            );
        }
    }
    println!(
        "  takeaway: the fluid layer trades per-record event chains for \
         per-quantum macro-records — tenant means (throughput, bytes, \
         utilization, cache hits) converge to the exact replay while the \
         event count stops scaling with the client population; latency \
         tails are the knowingly-coarsened axis"
    );
    let json = to_json(sweep);
    match write_report(&json) {
        Some(path) => println!("  json report written to {}", path.display()),
        None => println!("  json report:\n{}", json.pretty()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flow_mode_slashes_event_count_at_equal_offered_load() {
        let sweep = run_points(vec![(1_000, false), (1_000, true)], Fidelity::Quick);
        let (pr, fl) = sweep.pair(1_000).expect("both arms");
        assert_eq!(pr.clamped, 0);
        assert_eq!(fl.clamped, 0);
        assert!(pr.stable && fl.stable);
        assert!(
            (fl.events as f64) < 0.25 * pr.events as f64,
            "flow must coalesce events: {} vs {}",
            fl.events,
            pr.events
        );
        // Same offered load: the byte stream and throughput agree
        // loosely even at this small N (the tight 5% contract at
        // larger N lives in tests/flow_differential.rs).
        assert!(rel_delta(pr.net_tx_bytes, fl.net_tx_bytes) < 0.10);
        assert!(rel_delta(pr.throughput_per_sec, fl.throughput_per_sec) < 0.10);
    }

    #[test]
    fn json_report_carries_points_and_convergence() {
        let sweep = run_points(vec![(1_000, false), (1_000, true)], Fidelity::Quick);
        let j = to_json(&sweep);
        let points = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 2);
        assert!(points[0].get("wall_ms").is_some());
        let conv = j.get("convergence").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(conv.len(), 1);
        assert!(
            conv[0].get("event_reduction").and_then(|e| e.as_f64()).unwrap() > 4.0
        );
        // The model form drops host-dependent timing.
        let m = to_json_model(&sweep);
        let mp = m.get("points").and_then(|p| p.as_arr()).unwrap();
        assert!(mp[0].get("wall_ms").is_none());
        let reparsed = Json::parse(&m.to_string()).unwrap();
        assert_eq!(reparsed.get("experiment").and_then(|e| e.as_str()), Some("scale"));
    }

    #[test]
    fn grid_runs_flow_everywhere_and_per_record_below_the_cap() {
        let g = grid();
        assert_eq!(g.iter().filter(|(_, flow)| *flow).count(), CLIENTS.len());
        assert!(g
            .iter()
            .filter(|(_, flow)| !*flow)
            .all(|&(n, _)| n <= PER_RECORD_CAP));
        assert!(g.contains(&(1_000_000, true)));
    }
}
