//! Storage QoS: the write-path scheduling-class sweep
//! (`aitax experiment storage-qos`).
//!
//! The broker QoS sweep (`experiments::qos`) mitigates cross-tenant
//! interference at the broker front door — quotas and request-CPU
//! classes. This experiment isolates the layer below, the one the paper's
//! §5.4 names as the real bottleneck: the NVMe write path. Three tenants
//! colocate on the paper's 3-broker fabric with **no quotas and no CPU
//! weights** in either arm:
//!
//! * **facerec** — §5.3 acceleration deployment at 4× (stable alone);
//! * **train-ingest** — 1 MB sequential shard writes, scaled by the
//!   sweep share (the head-of-line blocker);
//! * **rpc** — small-record latency canary.
//!
//! Each share runs twice: storage QoS **off** (the seed FIFO write queue)
//! and **on** (per-class GPS write scheduling,
//! [`crate::broker::qos::QosPolicy::storage_weights`]). As the train
//! share grows past the device's effective write bandwidth, the FIFO
//! queue backs up and every tenant's records — including a facerec append
//! that is byte-for-byte quota-compliant — wait out the full backlog
//! behind the 1 MB batches. With the write scheduler on, facerec and rpc
//! drain at their weighted shares and their p99 holds while the train
//! tenant alone absorbs the overload it created.
//!
//! `run` returns structured results; [`print`] renders the table plus a
//! machine-readable JSON report (written to
//! `artifacts/storage_qos_report.json` when the artifacts directory is
//! present).

use crate::config::{Config, Deployment};
use crate::experiments::common::{facerec_accel, Fidelity};
use crate::experiments::runner;
use crate::pipeline::dc::WorkloadKind;
use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantReport, MultiTenantSim, TenantDef};
use crate::util::json::Json;
use crate::util::units::fmt_us;

/// Train-ingest write share of its nominal maximum (scales
/// `batches_per_tick`, i.e. the tenant's sequential-write rate).
pub const TRAIN_SHARES: [f64; 4] = [0.25, 0.5, 0.75, 1.0];
/// Face Recognition acceleration (stable alone; same as `qos`).
pub const ACCEL_FACEREC: f64 = 4.0;
/// Train batches per 100 ms tick at share 1.0 (16 writers × 10 ticks/s
/// × 4 MB = 640 MB/s of client bytes — past the fabric's ~770 MB/s
/// effective write bandwidth once facerec's ~420 MB/s joins it).
pub const TRAIN_MAX_BATCHES_PER_TICK: f64 = 4.0;
/// Write scheduling-class weights: the latency tenants outrank bulk.
pub const FACEREC_WEIGHT: f64 = 4.0;
pub const TRAIN_WEIGHT: f64 = 1.0;
pub const RPC_WEIGHT: f64 = 8.0;

/// The 3-tenant registry at one sweep point. Storage weights are always
/// attached; `storage_on` decides whether the write scheduler binds.
/// Quotas and CPU weights stay off in both arms so the sweep isolates
/// the write-path mechanism.
pub fn registry(share: f64, storage_on: bool, fidelity: Fidelity) -> MultiTenantConfig {
    let fr = facerec_accel(ACCEL_FACEREC, fidelity);

    let mut tr = Config::default();
    tr.deployment = Deployment::train_ingest();
    tr.calibration.train.batches_per_tick =
        ((TRAIN_MAX_BATCHES_PER_TICK * share).round() as usize).max(1);
    tr.duration_us = fidelity.horizon_us();
    tr.seed = 0x7EA1;

    let mut rpc = Config::default();
    rpc.deployment = Deployment::rpc_service();
    rpc.duration_us = fidelity.horizon_us();
    rpc.seed = 0x59C;

    let fabric = fr.clone();
    let duration = fr.duration_us;
    MultiTenantConfig::new(fabric, duration)
        .tenant(
            TenantDef::new("facerec", WorkloadKind::FaceRec, fr).with_weight(FACEREC_WEIGHT),
        )
        .tenant(
            TenantDef::new("train-ingest", WorkloadKind::TrainIngest, tr)
                .with_weight(TRAIN_WEIGHT),
        )
        .tenant(TenantDef::new("rpc", WorkloadKind::Rpc, rpc).with_weight(RPC_WEIGHT))
        .with_storage_qos(storage_on)
}

/// One sweep point: a share × {off,on} run.
pub struct StorageQosPoint {
    pub share: f64,
    pub storage_on: bool,
    pub report: MultiTenantReport,
}

/// The full sweep plus the RPC tenant's SLO for verdicts.
pub struct StorageQosSweep {
    pub slo_p99_us: u64,
    pub points: Vec<StorageQosPoint>,
}

impl StorageQosSweep {
    /// The (off, on) pair of points at one share.
    pub fn pair(&self, share: f64) -> (Option<&StorageQosPoint>, Option<&StorageQosPoint>) {
        let find = |on: bool| {
            self.points
                .iter()
                .find(|p| p.share == share && p.storage_on == on)
        };
        (find(false), find(true))
    }

    /// A tenant's e2e p99 at one point (µs).
    pub fn p99(p: &StorageQosPoint, tenant: &str) -> u64 {
        p.report.tenant(tenant).map(|t| t.e2e_p99_us).unwrap_or(0)
    }
}

/// Run the sweep at the given shares (each share twice: storage QoS off
/// and on), fanned out over the deterministic parallel runner.
pub fn run_at(shares: &[f64], fidelity: Fidelity) -> StorageQosSweep {
    let slo_p99_us = Config::default().calibration.rpc.slo_p99_us;
    let grid: Vec<(f64, bool)> = shares
        .iter()
        .flat_map(|&share| [(share, false), (share, true)])
        .collect();
    let points = runner::map(grid, |(share, storage_on)| StorageQosPoint {
        share,
        storage_on,
        report: MultiTenantSim::new(registry(share, storage_on, fidelity)).run(),
    });
    StorageQosSweep { slo_p99_us, points }
}

pub fn run(fidelity: Fidelity) -> StorageQosSweep {
    run_at(&TRAIN_SHARES, fidelity)
}

/// The machine-readable per-tenant p99-vs-share report.
pub fn to_json(sweep: &StorageQosSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("storage-qos".into())),
        ("slo_p99_us", Json::Num(sweep.slo_p99_us as f64)),
        ("accel_facerec", Json::Num(ACCEL_FACEREC)),
        (
            "storage_weights",
            Json::obj(vec![
                ("facerec", Json::Num(FACEREC_WEIGHT)),
                ("train-ingest", Json::Num(TRAIN_WEIGHT)),
                ("rpc", Json::Num(RPC_WEIGHT)),
            ]),
        ),
        (
            "points",
            Json::arr(
                sweep
                    .points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("share", Json::Num(p.share)),
                            ("storage_qos", Json::Bool(p.storage_on)),
                            (
                                "broker_storage_write_util",
                                Json::Num(p.report.broker_storage_write_util),
                            ),
                            ("events", Json::Num(p.report.events as f64)),
                            (
                                "metrics",
                                crate::metrics::registry::MetricsRegistry::from_report(&p.report)
                                    .to_json(),
                            ),
                            (
                                "tenants",
                                Json::arr(
                                    p.report
                                        .tenants
                                        .iter()
                                        .map(|t| {
                                            Json::obj(vec![
                                                ("name", Json::Str(t.name.clone())),
                                                ("kind", Json::Str(t.kind.label().into())),
                                                ("completed", Json::Num(t.completed as f64)),
                                                (
                                                    "throughput_per_sec",
                                                    Json::Num(t.throughput_per_sec),
                                                ),
                                                ("wait_mean_us", Json::Num(t.wait_mean_us)),
                                                (
                                                    "e2e_p99_us",
                                                    Json::Num(t.e2e_p99_us as f64),
                                                ),
                                                ("stable", Json::Bool(t.stable)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the JSON report next to the AOT artifacts when that directory
/// exists (same lookup as `experiments::qos`).
fn write_report(json: &Json) -> Option<std::path::PathBuf> {
    let dir = crate::runtime::Manifest::default_dir();
    if !dir.is_dir() {
        return None;
    }
    let path = dir.join("storage_qos_report.json");
    std::fs::write(&path, json.pretty()).ok()?;
    Some(path)
}

pub fn print(sweep: &StorageQosSweep) {
    println!(
        "\nStorage QoS — facerec({ACCEL_FACEREC}x) + train-ingest(·share) + rpc, \
         NVMe write scheduling classes off vs on (no quotas, no CPU weights)"
    );
    println!(
        "  write weights: facerec {FACEREC_WEIGHT:.0} | train {TRAIN_WEIGHT:.0} | rpc {RPC_WEIGHT:.0} \
         | rpc SLO: e2e p99 <= {}",
        fmt_us(sweep.slo_p99_us)
    );
    println!(
        "  {:>6} {:>4} {:>12} {:>12} {:>12} {:>12} {:>11}",
        "share", "qos", "fr p99", "fr wait", "rpc p99", "train p99", "nvme write"
    );
    for p in &sweep.points {
        let fr = p.report.tenant("facerec");
        let tr = p.report.tenant("train-ingest");
        let rpc = p.report.tenant("rpc");
        println!(
            "  {:>5.0}% {:>4} {:>12} {:>12} {:>12} {:>12} {:>10.1}%",
            100.0 * p.share,
            if p.storage_on { "on" } else { "off" },
            fmt_us(fr.map(|t| t.e2e_p99_us).unwrap_or(0)),
            fmt_us(fr.map(|t| t.wait_mean_us as u64).unwrap_or(0)),
            fmt_us(rpc.map(|t| t.e2e_p99_us).unwrap_or(0)),
            fmt_us(tr.map(|t| t.e2e_p99_us).unwrap_or(0)),
            100.0 * p.report.broker_storage_write_util,
        );
    }
    println!(
        "  takeaway: past write saturation the FIFO queue taxes every tenant with \
         head-of-line blocking behind 1 MB train batches; per-class write scheduling \
         confines the overload to the tenant that offered it"
    );
    let json = to_json(sweep);
    match write_report(&json) {
        Some(path) => println!("  json report written to {}", path.display()),
        None => println!("  json report:\n{}", json.pretty()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_qos_protects_facerec_and_rpc_under_full_train_load() {
        // The acceptance point: at full train share the shared write
        // path is past saturation. FIFO taxes facerec and rpc with the
        // whole backlog; the write scheduler must pull both back.
        let sweep = run_at(&[1.0], Fidelity::Quick);
        let (off, on) = sweep.pair(1.0);
        let (off, on) = (off.unwrap(), on.unwrap());
        let fr_off = StorageQosSweep::p99(off, "facerec");
        let fr_on = StorageQosSweep::p99(on, "facerec");
        let rpc_off = StorageQosSweep::p99(off, "rpc");
        let rpc_on = StorageQosSweep::p99(on, "rpc");
        assert!(
            fr_on < fr_off / 2,
            "storage QoS must at least halve facerec p99: on {fr_on} vs off {fr_off}"
        );
        assert!(
            rpc_on < rpc_off,
            "storage QoS must improve rpc p99: on {rpc_on} vs off {rpc_off}"
        );
        // Every tenant still completes work in both arms (backpressure,
        // not starvation).
        for p in [off, on] {
            for t in &p.report.tenants {
                assert!(t.completed > 0, "tenant {} starved", t.name);
            }
        }
    }

    #[test]
    fn low_share_arms_are_near_identical() {
        // Under light train load the write path never saturates, so the
        // scheduler has (almost) nothing to reorder: both arms complete
        // the same work and facerec stays stable.
        let sweep = run_at(&[0.25], Fidelity::Quick);
        let (off, on) = sweep.pair(0.25);
        let (off, on) = (off.unwrap(), on.unwrap());
        for arm in [off, on] {
            let fr = arm.report.tenant("facerec").unwrap();
            assert!(fr.stable, "facerec must be stable at low train share");
        }
    }

    #[test]
    fn json_report_carries_every_point_and_tenant() {
        let sweep = run_at(&[0.5], Fidelity::Quick);
        let j = to_json(&sweep);
        let points = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 2); // off + on
        for p in points {
            let tenants = p.get("tenants").and_then(|t| t.as_arr()).unwrap();
            assert_eq!(tenants.len(), 3);
        }
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            reparsed.get("experiment").and_then(|e| e.as_str()),
            Some("storage-qos")
        );
    }
}
