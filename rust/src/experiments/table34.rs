//! Tables 3 & 4 + §7.2–§7.3: equipment bills and the TCO comparison.
//!
//! Paper: homogeneous 1024-node DC equipment $33,577,760; purpose-built
//! $27,878,431; yearly TCO $12.9M vs $10.8M — 16.6% lower (abstract: 15%).

use crate::tco::catalog::Catalog;
use crate::tco::designs::{
    homogeneous_1024, homogeneous_1024_upgraded, purpose_built, savings_fraction, summarize,
    DataCenterDesign, TcoSummary,
};
use crate::tco::power::PowerModel;

pub struct Table34 {
    pub homogeneous: DataCenterDesign,
    pub homogeneous_upgraded: DataCenterDesign,
    pub purpose_built: DataCenterDesign,
    pub homo_tco: TcoSummary,
    pub homo_up_tco: TcoSummary,
    pub pb_tco: TcoSummary,
    pub savings: f64,
}

pub fn run() -> Table34 {
    let catalog = Catalog::default();
    let power = PowerModel::default();
    let homogeneous = homogeneous_1024(&catalog);
    let homogeneous_upgraded = homogeneous_1024_upgraded(&catalog);
    let purpose = purpose_built(&catalog);
    Table34 {
        homo_tco: summarize(&homogeneous, &power),
        homo_up_tco: summarize(&homogeneous_upgraded, &power),
        pb_tco: summarize(&purpose, &power),
        savings: savings_fraction(&power, &catalog),
        homogeneous,
        homogeneous_upgraded,
        purpose_built: purpose,
    }
}

fn print_design(d: &DataCenterDesign, t: &TcoSummary) {
    println!("\n  {} data center:", d.name);
    for item in &d.items {
        println!(
            "    {:<56} ${:>12.0}  x{}",
            item.name,
            item.unit_price,
            item.quantity
        );
    }
    println!("    {:<56} ${:>12.0}", "TOTAL EQUIPMENT", d.equipment_cost());
    println!(
        "    yearly: equipment ${:.2}M + power ${:.2}M + facilities ${:.2}M = ${:.2}M",
        t.yearly_equipment / 1e6,
        t.yearly_power / 1e6,
        t.yearly_facilities / 1e6,
        t.yearly_total / 1e6
    );
}

pub fn print(r: &Table34) {
    println!("\nTables 3 & 4 — data-center designs and TCO");
    print_design(&r.homogeneous, &r.homo_tco);
    println!("    paper Table 3 total: $33,577,760; yearly ~$12.9M");
    print_design(&r.purpose_built, &r.pb_tco);
    println!("    paper Table 4 total: $27,878,431; yearly ~$10.8M");
    println!(
        "\n  purpose-built saves {:.1}% yearly vs the 32x-ready homogeneous design",
        100.0 * r.savings
    );
    println!("  (paper §7.3: 16.6% lower; abstract: >15%)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equipment_totals_match_paper_exactly() {
        let r = run();
        assert_eq!(r.homogeneous.equipment_cost(), 33_577_760.0);
        assert_eq!(r.purpose_built.equipment_cost(), 27_878_431.0);
    }

    #[test]
    fn savings_in_paper_band() {
        let r = run();
        assert!((0.14..0.19).contains(&r.savings), "{}", r.savings);
    }
}
