//! Tax attribution: the latency-provenance sweep (`aitax experiment
//! tax`).
//!
//! Every record in a provenance-armed world carries a per-segment µs
//! ledger ([`crate::metrics::tax::TaxCell`]), charged at each hop from
//! client buffer to consumer service. This sweep runs the paper's core
//! question through that machinery: *as the AI work accelerates, what
//! fraction of the end-to-end latency is AI computation and what
//! fraction is infrastructure tax?* Three arms, each at facerec
//! acceleration 1–8×:
//!
//! * **baseline** — the streaming catch-up registry (facerec +
//!   train-ingest + rpc, measured read path, classed spindle, zero lag):
//!   the healthy shared fabric.
//! * **network** — the same world on an 8:1 oversubscribed co-located
//!   ToR/spine fabric: wire contention inflates the Network segment.
//! * **catch-up** — the failover world (broker killed at 0.3×horizon,
//!   back a second later, missed bytes replayed): elections, rebalance
//!   pauses, and recovery reads land in the wait segments.
//!
//! Per point we report facerec's [`TaxSummary`] — the `ai_us` vs
//! `tax_us` split, per-segment means and p99s, and the reconciliation
//! residual (0 µs: the segments partition the measured e2e exactly) —
//! plus the full [`MetricsRegistry`] dump. The headline reproduces the
//! paper: the AI time shrinks ∝ 1/k while the tax does not, so the tax
//! *share* of the end-to-end latency rises monotonically with
//! acceleration on every arm.
//!
//! [`TaxSummary`]: crate::metrics::tax::TaxSummary

use crate::experiments::common::Fidelity;
use crate::experiments::runner;
use crate::metrics::registry::MetricsRegistry;
use crate::metrics::tax::TaxSummary;
use crate::metrics::trace::TraceSpec;
use crate::net::{NetworkSpec, Placement};
use crate::pipeline::catchup::{self, CatchupSpec};
use crate::pipeline::failover::{self, FailoverSpec};
use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantReport, MultiTenantSim};
use crate::util::json::Json;
use crate::util::units::{fmt_us, gbps, SEC};

/// Facerec acceleration factors swept (§5.3 emulation ladder).
pub const ACCELS: [f64; 4] = [1.0, 2.0, 4.0, 8.0];
/// Access-link rate on the network arm (Table 4's 10 GbE nodes).
pub const LINK_BW: f64 = gbps(10);
/// Rack-uplink oversubscription on the network arm — the squeezed end
/// of the net-path sweep, where contention is unambiguous.
pub const OVERSUB: f64 = 8.0;
/// Catch-up arm: kill instant as a fraction of the horizon.
pub const KILL_FRAC: f64 = 0.3;
/// Catch-up arm: victim downtime before it rejoins.
pub const DOWNTIME_US: u64 = SEC;
/// Catch-up arm: re-replication pacing (above the steady write rate).
pub const RECOVERY_GBPS: f64 = 0.8;
/// Per-broker page cache, shared by all arms.
pub const CACHE_BYTES: f64 = 2e9;

/// One scenario arm (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaxArm {
    Baseline,
    Network,
    CatchUp,
}

impl TaxArm {
    pub const ALL: [TaxArm; 3] = [TaxArm::Baseline, TaxArm::Network, TaxArm::CatchUp];

    pub fn label(&self) -> &'static str {
        match self {
            TaxArm::Baseline => "baseline",
            TaxArm::Network => "network",
            TaxArm::CatchUp => "catch-up",
        }
    }
}

/// One sweep point: acceleration × arm, provenance armed.
pub struct TaxPoint {
    pub accel: f64,
    pub arm: TaxArm,
    pub report: MultiTenantReport,
}

impl TaxPoint {
    /// Facerec's per-segment attribution (always `Some`: every point in
    /// this sweep runs with provenance armed).
    pub fn facerec_tax(&self) -> Option<&TaxSummary> {
        self.report.tenant("facerec").and_then(|t| t.tax.as_ref())
    }
}

/// The full sweep.
pub struct TaxSweep {
    pub horizon_us: u64,
    pub points: Vec<TaxPoint>,
}

impl TaxSweep {
    pub fn point(&self, accel: f64, arm: TaxArm) -> Option<&TaxPoint> {
        self.points.iter().find(|p| p.accel == accel && p.arm == arm)
    }

    /// Baseline-arm facerec tax shares in ascending-accel order — the
    /// series the monotonicity claim is about.
    pub fn baseline_shares(&self) -> Vec<f64> {
        let mut shares: Vec<(f64, f64)> = self
            .points
            .iter()
            .filter(|p| p.arm == TaxArm::Baseline)
            .filter_map(|p| p.facerec_tax().map(|t| (p.accel, t.tax_share)))
            .collect();
        shares.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        shares.into_iter().map(|(_, s)| s).collect()
    }
}

/// The provenance-armed registry at one (accel, arm) point.
pub fn registry_for(
    accel: f64,
    arm: TaxArm,
    horizon_us: u64,
    trace: bool,
) -> MultiTenantConfig {
    let mut cfg = match arm {
        TaxArm::Baseline | TaxArm::Network => catchup::registry(
            CatchupSpec { lag_us: 0, cache_bytes: CACHE_BYTES, classed_reads: true },
            horizon_us,
        ),
        TaxArm::CatchUp => {
            let kill_at_us = (KILL_FRAC * horizon_us as f64) as u64;
            failover::registry(
                FailoverSpec {
                    kill_at_us,
                    restart_at_us: kill_at_us + DOWNTIME_US,
                    classed: true,
                    recovery_bytes_per_sec: RECOVERY_GBPS * 1e9,
                    cache_bytes: CACHE_BYTES,
                },
                horizon_us,
            )
        }
    };
    cfg.tenants[0].cfg.accel = accel;
    cfg.fabric.accel = accel;
    if arm == TaxArm::Network {
        cfg = cfg
            .with_network(NetworkSpec::new(OVERSUB, LINK_BW).with_placement(Placement::CoLocated));
    }
    cfg = cfg.with_provenance();
    if trace {
        cfg = cfg.with_trace(TraceSpec::default());
    }
    cfg
}

/// Run an explicit set of `(accel, arm)` points, fanned out over the
/// deterministic parallel runner.
pub fn run_points(points: Vec<(f64, TaxArm)>, fidelity: Fidelity, trace: bool) -> TaxSweep {
    let horizon = fidelity.horizon_us();
    let points = runner::map(points, move |(accel, arm)| TaxPoint {
        accel,
        arm,
        report: MultiTenantSim::new(registry_for(accel, arm, horizon, trace)).run(),
    });
    TaxSweep { horizon_us: horizon, points }
}

/// The full grid: every arm at every acceleration.
pub fn run(fidelity: Fidelity, trace: bool) -> TaxSweep {
    let mut grid: Vec<(f64, TaxArm)> = Vec::new();
    for &arm in &TaxArm::ALL {
        for &accel in &ACCELS {
            grid.push((accel, arm));
        }
    }
    run_points(grid, fidelity, trace)
}

/// The machine-readable report.
pub fn to_json(sweep: &TaxSweep) -> Json {
    Json::obj(vec![
        ("experiment", Json::Str("tax".into())),
        ("horizon_us", Json::Num(sweep.horizon_us as f64)),
        ("oversub", Json::Num(OVERSUB)),
        ("link_gbps", Json::Num(LINK_BW * 8.0 / 1e9)),
        ("recovery_gbps", Json::Num(RECOVERY_GBPS)),
        (
            "points",
            Json::arr(sweep.points.iter().map(point_json).collect()),
        ),
    ])
}

fn point_json(p: &TaxPoint) -> Json {
    Json::obj(vec![
        ("arm", Json::Str(p.arm.label().into())),
        ("accel", Json::Num(p.accel)),
        (
            "tax",
            p.facerec_tax().map(|t| t.to_json()).unwrap_or(Json::Null),
        ),
        ("metrics", MetricsRegistry::from_report(&p.report).to_json()),
    ])
}

/// Write a JSON artifact next to the AOT artifacts when that directory
/// exists (same lookup as the other sweep drivers).
fn write_artifact(name: &str, json: &Json) -> Option<std::path::PathBuf> {
    let dir = crate::runtime::Manifest::default_dir();
    if !dir.is_dir() {
        return None;
    }
    let path = dir.join(name);
    std::fs::write(&path, json.pretty()).ok()?;
    Some(path)
}

/// The run whose full registry becomes `metrics.json` and whose trace
/// (when recorded) becomes `tax_trace.json`: the most eventful point of
/// the grid — catch-up arm at the highest acceleration.
fn flagship(sweep: &TaxSweep) -> Option<&TaxPoint> {
    sweep
        .points
        .iter()
        .filter(|p| p.arm == TaxArm::CatchUp)
        .max_by(|a, b| a.accel.partial_cmp(&b.accel).unwrap())
        .or_else(|| sweep.points.last())
}

pub fn print(sweep: &TaxSweep) {
    println!(
        "\nTax attribution — per-record latency provenance, facerec accel \
         1–8x across {{baseline, +network ({OVERSUB}:1 colo), +catch-up}}"
    );
    println!(
        "  {:>5} {:>9} {:>12} {:>12} {:>12} {:>7} {:>9}",
        "accel", "arm", "e2e mean", "ai", "tax", "share", "residual"
    );
    for p in &sweep.points {
        if let Some(t) = p.facerec_tax() {
            println!(
                "  {:>4}x {:>9} {:>12} {:>12} {:>12} {:>6.1}% {:>8}",
                p.accel,
                p.arm.label(),
                fmt_us(t.e2e_mean_us as u64),
                fmt_us(t.ai_us as u64),
                fmt_us(t.tax_us as u64),
                100.0 * t.tax_share,
                fmt_us(t.max_residual_us),
            );
        }
    }
    println!(
        "  takeaway: accelerating the AI work shrinks only the Service \
         segment — the broker waits, quota throttles, storage queues, and \
         wire time it exposes do not shrink with it, so the tax share of \
         every end-to-end microsecond rises with acceleration; network \
         contention and failure recovery stack further tax on top"
    );
    let json = to_json(sweep);
    match write_artifact("tax_report.json", &json) {
        Some(path) => println!("  json report written to {}", path.display()),
        None => println!("  json report:\n{}", json.pretty()),
    }
    if let Some(p) = flagship(sweep) {
        let reg = MetricsRegistry::from_report(&p.report).to_json();
        if let Some(path) = write_artifact("metrics.json", &reg) {
            println!(
                "  metrics registry ({} arm at {}x) written to {}",
                p.arm.label(),
                p.accel,
                path.display()
            );
        }
        if let Some(trace) = &p.report.trace {
            if let Some(path) = write_artifact("tax_trace.json", trace) {
                println!("  chrome trace written to {}", path.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tax_share_rises_with_acceleration_and_sums_reconcile() {
        let sweep = run_points(
            vec![(1.0, TaxArm::Baseline), (8.0, TaxArm::Baseline)],
            Fidelity::Quick,
            false,
        );
        let slow = sweep.point(1.0, TaxArm::Baseline).unwrap().facerec_tax().unwrap().clone();
        let fast = sweep.point(8.0, TaxArm::Baseline).unwrap().facerec_tax().unwrap().clone();
        assert!(slow.records > 0 && fast.records > 0);
        // The paper's core finding: acceleration shrinks the AI time,
        // not the tax, so the tax *share* grows.
        assert!(
            fast.tax_share > slow.tax_share,
            "tax share must rise with acceleration: {} (1x) vs {} (8x)",
            slow.tax_share,
            fast.tax_share
        );
        assert!(fast.ai_us < slow.ai_us, "8x must spend less on AI per record");
        // Exact attribution: the segments partition the measured e2e.
        assert_eq!(slow.max_residual_us, 0);
        assert_eq!(fast.max_residual_us, 0);
        for t in [&slow, &fast] {
            let seg_sum: f64 = t.seg_mean_us.iter().sum();
            assert!(
                (seg_sum - t.e2e_mean_us).abs() <= 1.0,
                "segment means must reconcile with the e2e mean: {} vs {}",
                seg_sum,
                t.e2e_mean_us
            );
        }
        // The report JSON carries the attribution and the registry.
        let j = to_json(&sweep);
        let points = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 2);
        for p in points {
            assert!(p.get("tax").and_then(|t| t.get("tax_share")).is_some());
            assert!(p
                .get("metrics")
                .and_then(|m| m.get("tenant.facerec.tax_share"))
                .is_some());
        }
    }

    #[test]
    fn trace_armed_point_exports_chrome_events() {
        let sweep = run_points(vec![(4.0, TaxArm::Baseline)], Fidelity::Quick, true);
        let trace = sweep.points[0].report.trace.as_ref().expect("trace armed");
        let events = trace.as_arr().expect("chrome trace is an array");
        assert!(!events.is_empty(), "a 20 s run must sample some spans");
        // Every event is a well-formed Chrome trace event.
        for e in events {
            let ph = e.get("ph").and_then(|v| v.as_str()).unwrap();
            assert!(ph == "X" || ph == "i");
            assert!(e.get("ts").and_then(|v| v.as_f64()).is_some());
        }
        assert!(
            events.iter().any(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X")),
            "sampled record spans must be present"
        );
    }
}
