//! # aitax
//!
//! End-to-end reproduction of *AI Tax: The Hidden Cost of AI Data Center
//! Applications* (Richins et al.).
//!
//! The crate implements the paper's full system as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the data-center coordination substrate: a
//!   Kafka-like broker ([`broker`]), storage and network device models
//!   ([`storage`], [`net`]), a discrete-event simulator ([`sim`]), the
//!   *Face Recognition* and *Object Detection* pipelines ([`pipeline`]),
//!   acceleration emulation ([`accel`]), cluster deployment ([`cluster`]),
//!   instrumentation ([`metrics`]), the TCO model ([`tco`]), and the
//!   experiment drivers that regenerate every figure and table of the paper
//!   ([`experiments`]).
//! * **Layer 2 (python/compile/model.py)** — the JAX face pipeline models
//!   (detect / embed / classify / preprocess), AOT-lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (conv2d, matmul,
//!   bilinear resize) the Layer-2 models are built from.
//!
//! At run time only Rust executes: [`runtime`] loads the AOT artifacts via
//! PJRT and [`coordinator`] drives live, threaded deployments where real
//! bytes flow through the broker substrate and real inference runs on the
//! consumer hot path.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod accel;
pub mod broker;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod metrics;
pub mod net;
pub mod pipeline;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod tco;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
