//! `aitax` — CLI for the AI-Tax reproduction.
//!
//! Subcommands:
//!   run         live three-layer pipeline (PJRT inference + real broker)
//!   experiment  regenerate a paper figure/table (fig5..fig15, tco) or an
//!               extension scenario (mixed, qos), or all of them
//!   sim         one Face Recognition simulation with overrides
//!   amdahl      Fig-9 analytic projections
//!   artifacts   check/describe the AOT artifacts

use aitax::coordinator::live::{LiveConfig, LiveRunner};
use aitax::experiments as ex;
use aitax::experiments::common::Fidelity;
use aitax::pipeline::facerec::FaceRecSim;
use aitax::util::cli::Args;
use aitax::util::units::fmt_us;

const USAGE: &str = "\
aitax — reproduction of 'AI Tax: The Hidden Cost of AI Data Center Applications'

USAGE:
  aitax run [--secs N] [--producers N] [--consumers N] [--fps F]
            [--file-backed] [--batched]
  aitax experiment <fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|fig13|fig14|fig15|tco|mixed|qos|all>
            [--quick]
  aitax sim [--accel K] [--producers N] [--consumers N] [--brokers N]
            [--drives N] [--face-bytes B] [--secs N] [--seed S] [--config FILE]
  aitax amdahl
  aitax artifacts
";

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("sim") => cmd_sim(&args),
        Some("amdahl") => {
            ex::fig09::print(&ex::fig09::run());
            Ok(())
        }
        Some("artifacts") => cmd_artifacts(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = LiveConfig {
        producers: args.get_u64("producers", 2) as usize,
        consumers: args.get_u64("consumers", 4) as usize,
        partitions: args.get_u64("partitions", 8) as u32,
        duration: std::time::Duration::from_secs(args.get_u64("secs", 10)),
        fps_limit: args.get_f64("fps", 0.0),
        file_backed: args.flag("file-backed"),
        batched_identify: args.flag("batched"),
        ..LiveConfig::default()
    };
    println!(
        "live run: {} producers, {} consumers, {} brokers, {:?} ...",
        cfg.producers, cfg.consumers, cfg.brokers, cfg.duration
    );
    let report = LiveRunner::new(cfg).run()?;
    print!("{}", report.breakdown.render("live latency breakdown"));
    println!(
        "frames {} | faces {} -> identified {} | {:.1} FPS | broker logs {}",
        report.frames,
        report.faces_produced,
        report.faces_identified,
        report.throughput_fps,
        aitax::util::units::fmt_bytes(report.broker_log_bytes as f64),
    );
    if !report.identities.is_empty() {
        let top: Vec<String> = report
            .identities
            .iter()
            .take(6)
            .map(|(p, n)| format!("#{p}x{n}"))
            .collect();
        println!("identities seen: {}", top.join(" "));
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let run_one = |name: &str| -> anyhow::Result<()> {
        match name {
            "fig5" => ex::fig05::print(&ex::fig05::run(16)),
            "fig6" => ex::fig06::print(&ex::fig06::run(fidelity)),
            "fig7" => ex::fig07::print(&ex::fig07::run(fidelity)),
            "fig8" => ex::fig08::print(&ex::fig08::run()),
            "fig9" => ex::fig09::print(&ex::fig09::run()),
            "fig10" => ex::fig10::print(&ex::fig10::run(fidelity)),
            "fig11" => ex::fig11::print(&ex::fig11::run(fidelity)),
            "fig12" => ex::fig12::print(&ex::fig12::run(14)),
            "fig13" => ex::fig13::print(&ex::fig13::run(fidelity)),
            "fig14" => ex::fig14::print(&ex::fig14::run(fidelity)),
            "fig15" => ex::fig15::print(&ex::fig15::run(fidelity)),
            "tco" | "table3" | "table4" => ex::table34::print(&ex::table34::run()),
            "mixed" => ex::mixed::print(&ex::mixed::run(fidelity)),
            "qos" => ex::qos::print(&ex::qos::run(fidelity)),
            other => anyhow::bail!("unknown experiment: {other}\n{USAGE}"),
        }
        Ok(())
    };
    if which == "all" {
        for name in [
            "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
            "fig15", "tco", "mixed", "qos",
        ] {
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let mut cfg = aitax::config::Config::default();
    if let Some(path) = args.get("config") {
        cfg = cfg.load_file(path)?;
    }
    if args.get("accel").is_some() {
        cfg.deployment = aitax::config::Deployment::facerec_accel();
        cfg.accel = args.get_f64("accel", 1.0);
    }
    cfg.deployment.producers = args.get_u64("producers", cfg.deployment.producers as u64) as usize;
    cfg.deployment.consumers = args.get_u64("consumers", cfg.deployment.consumers as u64) as usize;
    cfg.deployment.brokers = args.get_u64("brokers", cfg.deployment.brokers as u64) as usize;
    cfg.deployment.drives_per_broker =
        args.get_u64("drives", cfg.deployment.drives_per_broker as u64) as usize;
    cfg.deployment.partitions = cfg.deployment.partitions.max(cfg.deployment.consumers);
    cfg.face_bytes = args.get_f64("face-bytes", cfg.face_bytes);
    cfg.duration_us = args.get_u64("secs", cfg.duration_us / 1_000_000) * 1_000_000;
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.deployment.validate()?;
    println!(
        "sim: {}p/{}c/{}b x{} drives, accel {}x, {}s, {} faces",
        cfg.deployment.producers,
        cfg.deployment.consumers,
        cfg.deployment.brokers,
        cfg.deployment.drives_per_broker,
        cfg.accel,
        cfg.duration_us / 1_000_000,
        aitax::util::units::fmt_bytes(cfg.face_bytes),
    );
    let r = FaceRecSim::new(cfg).run();
    println!(
        "  ingest {} | detect {} | wait {} | identify {} | e2e {} (p99 {})",
        fmt_us(r.ingest_mean_us as u64),
        fmt_us(r.detect_mean_us as u64),
        fmt_us(r.wait_mean_us as u64),
        fmt_us(r.identify_mean_us as u64),
        fmt_us(r.e2e_mean_us as u64),
        fmt_us(r.e2e_p99_us),
    );
    println!(
        "  throughput {:.0} faces/s | wait share {:.1}% | storage write {:.1}% | {}",
        r.throughput_fps,
        100.0 * r.wait_fraction,
        100.0 * r.storage_write_util,
        if r.verdict.stable {
            "stable".to_string()
        } else {
            format!("UNSTABLE (+{:.0} faces/s)", r.verdict.growth_per_sec)
        }
    );
    Ok(())
}

fn cmd_artifacts() -> anyhow::Result<()> {
    let dir = aitax::runtime::Manifest::default_dir();
    let manifest = aitax::runtime::Manifest::load(&dir)?;
    println!("artifacts at {}:", dir.display());
    for (name, e) in &manifest.entries {
        let size = std::fs::metadata(&e.file).map(|m| m.len()).unwrap_or(0);
        println!(
            "  {:<16} in {:?} -> out {:?}  ({})",
            name,
            e.input_shapes,
            e.output_shapes,
            aitax::util::units::fmt_bytes(size as f64)
        );
    }
    let engine = aitax::runtime::Engine::load(&dir)?;
    println!("compiled OK on {}", engine.platform());
    Ok(())
}
