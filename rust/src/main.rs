//! `aitax` — CLI for the AI-Tax reproduction.
//!
//! Subcommands:
//!   run         live three-layer pipeline (PJRT inference + real broker)
//!   experiment  regenerate a paper figure/table (fig5..fig15, tco) or an
//!               extension scenario (mixed, qos, storage-qos, read-path,
//!               failover, cascade, net-path, scale, tax), or all of them
//!   sim         one Face Recognition simulation with overrides
//!   amdahl      Fig-9 analytic projections
//!   bench       perf-trajectory benchmarks (kernel: events/sec + sweep
//!               scaling, emits BENCH_kernel.json; scale: per-record vs
//!               flow-aggregated wall clock, emits BENCH_scale.json)
//!   artifacts   check/describe the AOT artifacts

use aitax::coordinator::live::{LiveConfig, LiveRunner};
use aitax::experiments as ex;
use aitax::experiments::common::Fidelity;
use aitax::pipeline::facerec::FaceRecSim;
use aitax::util::cli::Args;
use aitax::util::units::fmt_us;

const USAGE: &str = "\
aitax — reproduction of 'AI Tax: The Hidden Cost of AI Data Center Applications'

USAGE:
  aitax run [--secs N] [--producers N] [--consumers N] [--fps F]
            [--file-backed] [--batched] [--produce-quota BYTES_PER_SEC]
  aitax experiment <fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|fig13|fig14|fig15|tco|mixed|qos|storage-qos|read-path|failover|cascade|net-path|scale|tax|all>
            [--quick] [--trace]
  aitax sim [--accel K] [--producers N] [--consumers N] [--brokers N]
            [--drives N] [--face-bytes B] [--secs N] [--seed S] [--config FILE]
  aitax amdahl
  aitax bench kernel [--quick] [--out FILE]
  aitax bench scale [--quick] [--out FILE]
  aitax artifacts

Sweep drivers honor AITAX_JOBS (default: all cores); jobs=1 reproduces
the sequential reports byte for byte.
";

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("sim") => cmd_sim(&args),
        Some("amdahl") => {
            ex::fig09::print(&ex::fig09::run());
            Ok(())
        }
        Some("bench") => cmd_bench(&args),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = LiveConfig {
        producers: args.get_u64("producers", 2) as usize,
        consumers: args.get_u64("consumers", 4) as usize,
        partitions: args.get_u64("partitions", 8) as u32,
        duration: std::time::Duration::from_secs(args.get_u64("secs", 10)),
        fps_limit: args.get_f64("fps", 0.0),
        file_backed: args.flag("file-backed"),
        batched_identify: args.flag("batched"),
        produce_quota_bytes_per_sec: args.get_f64("produce-quota", 0.0),
        ..LiveConfig::default()
    };
    println!(
        "live run: {} producers, {} consumers, {} brokers, {:?} ...",
        cfg.producers, cfg.consumers, cfg.brokers, cfg.duration
    );
    let report = LiveRunner::new(cfg).run()?;
    print!("{}", report.breakdown.render("live latency breakdown"));
    println!(
        "frames {} | faces {} -> identified {} | {:.1} FPS | broker logs {}",
        report.frames,
        report.faces_produced,
        report.faces_identified,
        report.throughput_fps,
        aitax::util::units::fmt_bytes(report.broker_log_bytes as f64),
    );
    if !report.identities.is_empty() {
        let top: Vec<String> = report
            .identities
            .iter()
            .take(6)
            .map(|(p, n)| format!("#{p}x{n}"))
            .collect();
        println!("identities seen: {}", top.join(" "));
    }
    Ok(())
}

/// Every experiment id `aitax experiment all` runs, in order. The kernel
/// benchmark times exactly this list (minus printing), so the measured
/// workload cannot drift from the command.
const ALL_EXPERIMENTS: [&str; 20] = [
    "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
    "fig15", "tco", "mixed", "qos", "storage-qos", "read-path", "failover", "cascade",
    "net-path", "tax",
];

/// Print an experiment's report, or (on the benchmark path) just keep
/// the computed result from being optimized away.
fn emit<T>(r: T, quiet: bool, print: impl Fn(&T)) {
    if quiet {
        std::hint::black_box(&r);
    } else {
        print(&r);
    }
}

/// Run one experiment by id; `quiet` skips the report output (the
/// sweep-scaling benchmark wants the work without the printing);
/// `trace` arms the flight recorder on the experiments that support it
/// (currently `tax`).
fn run_experiment(name: &str, fidelity: Fidelity, quiet: bool, trace: bool) -> anyhow::Result<()> {
    match name {
        "fig5" => emit(ex::fig05::run(16), quiet, |r| ex::fig05::print(r)),
        "fig6" => emit(ex::fig06::run(fidelity), quiet, |r| ex::fig06::print(r)),
        "fig7" => emit(ex::fig07::run(fidelity), quiet, |r| ex::fig07::print(r)),
        "fig8" => emit(ex::fig08::run(), quiet, |r| ex::fig08::print(r)),
        "fig9" => emit(ex::fig09::run(), quiet, |r| ex::fig09::print(r)),
        "fig10" => emit(ex::fig10::run(fidelity), quiet, |r| ex::fig10::print(r)),
        "fig11" => emit(ex::fig11::run(fidelity), quiet, |r| ex::fig11::print(r)),
        "fig12" => emit(ex::fig12::run(14), quiet, |r| ex::fig12::print(r)),
        "fig13" => emit(ex::fig13::run(fidelity), quiet, |r| ex::fig13::print(r)),
        "fig14" => emit(ex::fig14::run(fidelity), quiet, |r| ex::fig14::print(r)),
        "fig15" => emit(ex::fig15::run(fidelity), quiet, |r| ex::fig15::print(r)),
        "tco" | "table3" | "table4" => emit(ex::table34::run(), quiet, |r| ex::table34::print(r)),
        "mixed" => emit(ex::mixed::run(fidelity), quiet, |r| ex::mixed::print(r)),
        "qos" => emit(ex::qos::run(fidelity), quiet, |r| ex::qos::print(r)),
        "storage-qos" => {
            emit(ex::storage_qos::run(fidelity), quiet, |r| ex::storage_qos::print(r))
        }
        "read-path" => {
            emit(ex::read_path::run(fidelity), quiet, |r| ex::read_path::print(r))
        }
        "failover" => {
            emit(ex::failover::run(fidelity), quiet, |r| ex::failover::print(r))
        }
        "cascade" => {
            emit(ex::cascade::run(fidelity), quiet, |r| ex::cascade::print(r))
        }
        "net-path" => {
            emit(ex::net_path::run(fidelity), quiet, |r| ex::net_path::print(r))
        }
        "tax" => emit(ex::tax::run(fidelity, trace), quiet, |r| ex::tax::print(r)),
        // Runnable by name but not part of `all` / ALL_EXPERIMENTS: the
        // sweep measures its own wall clock per point, so folding it
        // into the timed `experiment all` suite (which the kernel bench
        // replays twice) would both skew and be skewed by the
        // benchmark; `aitax bench scale` owns its perf trend instead.
        "scale" => emit(ex::scale::run(fidelity), quiet, |r| ex::scale::print(r)),
        other => anyhow::bail!("unknown experiment: {other}\n{USAGE}"),
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };
    let trace = args.flag("trace");
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    if which == "all" {
        for name in ALL_EXPERIMENTS {
            run_experiment(name, fidelity, false, trace)?;
        }
        Ok(())
    } else {
        run_experiment(which, fidelity, false, trace)
    }
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let mut cfg = aitax::config::Config::default();
    if let Some(path) = args.get("config") {
        cfg = cfg.load_file(path)?;
    }
    if args.get("accel").is_some() {
        cfg.deployment = aitax::config::Deployment::facerec_accel();
        cfg.accel = args.get_f64("accel", 1.0);
    }
    cfg.deployment.producers = args.get_u64("producers", cfg.deployment.producers as u64) as usize;
    cfg.deployment.consumers = args.get_u64("consumers", cfg.deployment.consumers as u64) as usize;
    cfg.deployment.brokers = args.get_u64("brokers", cfg.deployment.brokers as u64) as usize;
    cfg.deployment.drives_per_broker =
        args.get_u64("drives", cfg.deployment.drives_per_broker as u64) as usize;
    cfg.deployment.partitions = cfg.deployment.partitions.max(cfg.deployment.consumers);
    cfg.face_bytes = args.get_f64("face-bytes", cfg.face_bytes);
    cfg.duration_us = args.get_u64("secs", cfg.duration_us / 1_000_000) * 1_000_000;
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.deployment.validate()?;
    println!(
        "sim: {}p/{}c/{}b x{} drives, accel {}x, {}s, {} faces",
        cfg.deployment.producers,
        cfg.deployment.consumers,
        cfg.deployment.brokers,
        cfg.deployment.drives_per_broker,
        cfg.accel,
        cfg.duration_us / 1_000_000,
        aitax::util::units::fmt_bytes(cfg.face_bytes),
    );
    let r = FaceRecSim::new(cfg).run();
    println!(
        "  ingest {} | detect {} | wait {} | identify {} | e2e {} (p99 {})",
        fmt_us(r.ingest_mean_us as u64),
        fmt_us(r.detect_mean_us as u64),
        fmt_us(r.wait_mean_us as u64),
        fmt_us(r.identify_mean_us as u64),
        fmt_us(r.e2e_mean_us as u64),
        fmt_us(r.e2e_p99_us),
    );
    println!(
        "  throughput {:.0} faces/s | wait share {:.1}% | storage write {:.1}% | {}",
        r.throughput_fps,
        100.0 * r.wait_fraction,
        100.0 * r.storage_write_util,
        if r.verdict.stable {
            "stable".to_string()
        } else {
            format!("UNSTABLE (+{:.0} faces/s)", r.verdict.growth_per_sec)
        }
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("kernel") => bench_kernel(args),
        Some("scale") => bench_scale(args),
        other => {
            anyhow::bail!("unknown bench target {other:?} (expected: kernel, scale)\n{USAGE}")
        }
    }
}

/// The exact `aitax experiment all` workload (same [`ALL_EXPERIMENTS`]
/// list), reports discarded — what the sweep-scaling benchmark times at
/// jobs=1 vs jobs=N.
fn run_experiment_suite(fidelity: Fidelity) {
    for name in ALL_EXPERIMENTS {
        run_experiment(name, fidelity, true, false).expect("known experiment id");
    }
}

/// `aitax bench kernel`: the perf-trajectory benchmark behind
/// `BENCH_kernel.json` — raw event-kernel throughput, whole-simulation
/// events/sec on the Fig-10 hotpath world, and `experiment all`
/// wall-clock at jobs=1 vs jobs=N (the parallel-runner speedup).
fn bench_kernel(args: &Args) -> anyhow::Result<()> {
    use aitax::experiments::runner;
    use aitax::pipeline::dc::{self, FabricSpec, TenantSpec, WorkloadKind};
    use aitax::sim::engine::EventQueue;
    use aitax::util::json::Json;
    use aitax::util::rng::Rng;
    use std::time::Instant;

    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };

    // --- raw event-kernel throughput (push+pop through the 4-ary heap) ---
    const QUEUE_EVENTS: u64 = 1 << 18;
    let mut queue_eps = 0.0f64;
    for _ in 0..3 {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut rng = Rng::new(0x4A7);
        let t0 = Instant::now();
        for i in 0..QUEUE_EVENTS {
            q.at(rng.below(1 << 20), i);
        }
        while let Some(x) = q.pop() {
            std::hint::black_box(x);
        }
        let eps = (2 * QUEUE_EVENTS) as f64 / t0.elapsed().as_secs_f64();
        queue_eps = queue_eps.max(eps);
    }

    // --- whole-simulation events/sec (Fig-10 hotpath: facerec @4x, 10 s) ---
    let mut cfg = aitax::config::Config::default();
    cfg.deployment = aitax::config::Deployment::facerec_accel();
    cfg.duration_us = 10 * 1_000_000;
    cfg.accel = 4.0;
    let spec = FabricSpec::from_config(&cfg);
    let t0 = Instant::now();
    let mut world = dc::build(
        &[TenantSpec { kind: WorkloadKind::FaceRec, cfg: &cfg }],
        &spec,
        cfg.duration_us,
    );
    world.run_until(cfg.duration_us);
    let sim_wall = t0.elapsed();
    let sim_events = world.processed();
    let sim_eps = sim_events as f64 / sim_wall.as_secs_f64().max(1e-9);

    // --- sweep scaling: `experiment all` at jobs=1 vs jobs=N ---
    let jobs = runner::jobs().max(2);
    runner::set_jobs_override(Some(1));
    let t1 = Instant::now();
    run_experiment_suite(fidelity);
    let wall_jobs1 = t1.elapsed();
    runner::set_jobs_override(Some(jobs));
    let tn = Instant::now();
    run_experiment_suite(fidelity);
    let wall_jobsn = tn.elapsed();
    runner::set_jobs_override(None);
    let speedup = wall_jobs1.as_secs_f64() / wall_jobsn.as_secs_f64().max(1e-9);

    let fidelity_label = match fidelity {
        Fidelity::Quick => "quick",
        Fidelity::Full => "full",
    };
    let json = Json::obj(vec![
        ("bench", Json::Str("kernel".into())),
        ("fidelity", Json::Str(fidelity_label.into())),
        ("queue_events_per_sec", Json::Num(queue_eps)),
        ("sim_events", Json::Num(sim_events as f64)),
        ("sim_wall_ms", Json::Num(sim_wall.as_secs_f64() * 1e3)),
        ("sim_events_per_sec", Json::Num(sim_eps)),
        ("sweep_jobs", Json::Num(jobs as f64)),
        ("sweep_wall_jobs1_ms", Json::Num(wall_jobs1.as_secs_f64() * 1e3)),
        ("sweep_wall_jobsN_ms", Json::Num(wall_jobsn.as_secs_f64() * 1e3)),
        ("sweep_speedup", Json::Num(speedup)),
    ]);
    let out = args.get_str("out", "BENCH_kernel.json").to_string();
    std::fs::write(&out, json.pretty())?;
    println!("kernel bench ({fidelity_label} fidelity):");
    println!("  event queue   {queue_eps:>14.0} events/s (push+pop, {QUEUE_EVENTS} events)");
    println!(
        "  whole sim     {sim_eps:>14.0} events/s ({sim_events} events in {:.1} ms)",
        sim_wall.as_secs_f64() * 1e3
    );
    println!(
        "  experiment all: jobs=1 {:.1} s vs jobs={jobs} {:.1} s -> {speedup:.2}x",
        wall_jobs1.as_secs_f64(),
        wall_jobsn.as_secs_f64()
    );
    println!("  report written to {out}");
    Ok(())
}

/// `aitax bench scale`: the flow-aggregation perf trend behind
/// `BENCH_scale.json` — per-record vs flow wall clock at the largest N
/// both arms replay, plus the million-client flow point the per-record
/// path cannot touch (the acceptance bar: it must finish in interactive
/// time single-threaded).
fn bench_scale(args: &Args) -> anyhow::Result<()> {
    use aitax::experiments::runner;
    use aitax::experiments::scale;
    use aitax::util::json::Json;

    let fidelity = if args.flag("quick") {
        Fidelity::Quick
    } else {
        Fidelity::from_env()
    };
    // Wall clock is the measurement: run every point sequentially.
    runner::set_jobs_override(Some(1));
    let sweep = scale::run_points(
        vec![
            (scale::PER_RECORD_CAP, false),
            (scale::PER_RECORD_CAP, true),
            (1_000_000, true),
        ],
        fidelity,
    );
    runner::set_jobs_override(None);
    let pr = sweep.point(scale::PER_RECORD_CAP, false).expect("per-record arm");
    let fl = sweep.point(scale::PER_RECORD_CAP, true).expect("flow arm");
    let million = sweep.point(1_000_000, true).expect("10^6 flow arm");
    let speedup = pr.wall_ms / fl.wall_ms.max(1e-9);

    let fidelity_label = match fidelity {
        Fidelity::Quick => "quick",
        Fidelity::Full => "full",
    };
    let json = Json::obj(vec![
        ("bench", Json::Str("scale".into())),
        ("fidelity", Json::Str(fidelity_label.into())),
        ("clients", Json::Num(scale::PER_RECORD_CAP as f64)),
        ("per_record_wall_ms", Json::Num(pr.wall_ms)),
        ("per_record_events", Json::Num(pr.events as f64)),
        ("flow_wall_ms", Json::Num(fl.wall_ms)),
        ("flow_events", Json::Num(fl.events as f64)),
        ("flow_speedup", Json::Num(speedup)),
        (
            "event_reduction",
            Json::Num(pr.events as f64 / (fl.events as f64).max(1.0)),
        ),
        ("million_flow_wall_ms", Json::Num(million.wall_ms)),
        ("million_flow_events", Json::Num(million.events as f64)),
        (
            "million_flow_events_per_sec",
            Json::Num(million.events_per_sec()),
        ),
        (
            "throughput_delta",
            Json::Num(scale::rel_delta(pr.throughput_per_sec, fl.throughput_per_sec)),
        ),
    ]);
    let out = args.get_str("out", "BENCH_scale.json").to_string();
    std::fs::write(&out, json.pretty())?;
    println!("scale bench ({fidelity_label} fidelity, jobs=1):");
    println!(
        "  {} clients   per-record {:.1} s ({} events) vs flow {:.2} s ({} events) -> {speedup:.1}x",
        scale::PER_RECORD_CAP,
        pr.wall_ms / 1e3,
        pr.events,
        fl.wall_ms / 1e3,
        fl.events,
    );
    println!(
        "  1000000 clients  flow {:.2} s ({} events, {:.0} events/s)",
        million.wall_ms / 1e3,
        million.events,
        million.events_per_sec(),
    );
    println!("  report written to {out}");
    Ok(())
}

fn cmd_artifacts() -> anyhow::Result<()> {
    let dir = aitax::runtime::Manifest::default_dir();
    let manifest = aitax::runtime::Manifest::load(&dir)?;
    println!("artifacts at {}:", dir.display());
    for (name, e) in &manifest.entries {
        let size = std::fs::metadata(&e.file).map(|m| m.len()).unwrap_or(0);
        println!(
            "  {:<16} in {:?} -> out {:?}  ({})",
            name,
            e.input_shapes,
            e.output_shapes,
            aitax::util::units::fmt_bytes(size as f64)
        );
    }
    let engine = aitax::runtime::Engine::load(&dir)?;
    println!("compiled OK on {}", engine.platform());
    Ok(())
}
