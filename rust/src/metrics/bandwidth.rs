//! Byte meters for the Fig-11 bandwidth-utilization breakdowns.
//!
//! The paper plots, per container class (producer / consumer / broker) and
//! direction (read / write), network and storage bandwidth as a fraction of
//! capacity. A [`BandwidthMeter`] accumulates bytes per (class, channel,
//! direction) tuple and converts to utilization given the elapsed virtual
//! time and the per-node capacity.

/// Node class, matching the paper's container classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Class {
    Producer,
    Consumer,
    Broker,
}

impl Class {
    pub fn name(&self) -> &'static str {
        match self {
            Class::Producer => "producer",
            Class::Consumer => "consumer",
            Class::Broker => "broker",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Channel {
    Network,
    Storage,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Dir {
    Read,
    Write,
}

/// Flat accumulator index for a (class, channel, direction) tuple. The
/// key space is tiny and fixed, and [`BandwidthMeter::add`] sits on the
/// fabric's per-hop path — an array add beats a map walk there.
#[inline]
fn slot(class: Class, channel: Channel, dir: Dir) -> usize {
    (class as usize) * 4 + (channel as usize) * 2 + (dir as usize)
}

/// Accumulates bytes by (class, channel, direction).
#[derive(Clone, Debug, Default)]
pub struct BandwidthMeter {
    bytes: [f64; 12],
    /// Node count per class, to report *per-node* utilization like Fig 11
    /// (0 = unset, treated as 1 node).
    nodes: [usize; 3],
}

impl BandwidthMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set_nodes(&mut self, class: Class, count: usize) {
        self.nodes[class as usize] = count.max(1);
    }

    #[inline]
    pub fn add(&mut self, class: Class, channel: Channel, dir: Dir, bytes: f64) {
        self.bytes[slot(class, channel, dir)] += bytes;
    }

    pub fn total(&self, class: Class, channel: Channel, dir: Dir) -> f64 {
        self.bytes[slot(class, channel, dir)]
    }

    /// Mean per-node bandwidth in bytes/s over `[0, elapsed_us]`.
    pub fn per_node_bw(&self, class: Class, channel: Channel, dir: Dir, elapsed_us: u64) -> f64 {
        if elapsed_us == 0 {
            return 0.0;
        }
        let nodes = self.nodes[class as usize].max(1) as f64;
        self.total(class, channel, dir) * 1e6 / (elapsed_us as f64 * nodes)
    }

    /// Per-node utilization as a fraction of `capacity_bytes_per_sec`.
    pub fn utilization(
        &self,
        class: Class,
        channel: Channel,
        dir: Dir,
        elapsed_us: u64,
        capacity: f64,
    ) -> f64 {
        if capacity <= 0.0 {
            return 0.0;
        }
        self.per_node_bw(class, channel, dir, elapsed_us) / capacity
    }

    /// Render the Fig-11-style table.
    pub fn render(&self, elapsed_us: u64, net_capacity: f64, storage_capacity: f64) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "  {:<10} {:<8} {:>14} {:>14} {:>12}\n",
            "class", "channel", "read", "write", "unit"
        ));
        for class in [Class::Producer, Class::Consumer, Class::Broker] {
            for (channel, cap) in [(Channel::Network, net_capacity), (Channel::Storage, storage_capacity)] {
                let r = self.utilization(class, channel, Dir::Read, elapsed_us, cap);
                let w = self.utilization(class, channel, Dir::Write, elapsed_us, cap);
                if r == 0.0 && w == 0.0 {
                    continue;
                }
                out.push_str(&format!(
                    "  {:<10} {:<8} {:>13.2}% {:>13.2}% {:>12}\n",
                    class.name(),
                    match channel {
                        Channel::Network => "net",
                        Channel::Storage => "disk",
                    },
                    r * 100.0,
                    w * 100.0,
                    "of capacity"
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_and_utilize() {
        let mut m = BandwidthMeter::new();
        m.set_nodes(Class::Broker, 3);
        // 3 brokers write 330 MB total over 1s -> 110 MB/s per node ->
        // 10% of 1.1 GB/s (the paper's 1x Fig-11b point).
        m.add(Class::Broker, Channel::Storage, Dir::Write, 330e6);
        let u = m.utilization(Class::Broker, Channel::Storage, Dir::Write, 1_000_000, 1.1e9);
        assert!((u - 0.10).abs() < 1e-9, "u={u}");
    }

    #[test]
    fn missing_entries_are_zero() {
        let m = BandwidthMeter::new();
        assert_eq!(m.total(Class::Producer, Channel::Network, Dir::Read), 0.0);
        assert_eq!(
            m.utilization(Class::Producer, Channel::Network, Dir::Read, 100, 1e9),
            0.0
        );
    }

    #[test]
    fn render_skips_empty_rows() {
        let mut m = BandwidthMeter::new();
        m.set_nodes(Class::Broker, 1);
        m.add(Class::Broker, Channel::Network, Dir::Read, 1e6);
        let text = m.render(1_000_000, 12.5e9, 1.1e9);
        assert!(text.contains("broker"));
        assert!(!text.contains("producer"));
    }
}
