//! Stage-latency breakdowns (Figs 6, 13) and tail summaries (§4.2).

use crate::metrics::event::{EventKind, EventLog};
use crate::util::stats::Histogram;
use crate::util::units::fmt_us;

/// Aggregated stats for one pipeline stage.
#[derive(Clone, Debug)]
pub struct StageStat {
    pub kind: EventKind,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    pub count: u64,
}

/// A full end-to-end latency breakdown.
#[derive(Clone, Debug)]
pub struct Breakdown {
    pub stages: Vec<StageStat>,
    /// Per-frame end-to-end latency (sum over that frame's stage events).
    pub e2e_mean_us: f64,
    pub e2e_p99_us: u64,
    pub frames: u64,
}

impl Breakdown {
    /// Compute the breakdown from an event log. End-to-end latency per
    /// frame is the sum of that frame's serial stage durations (the paper's
    /// "total time of a frame progressing serially from ingestion through
    /// identification").
    pub fn from_log(log: &EventLog, kinds: &[EventKind]) -> Breakdown {
        let mut stages = Vec::new();
        for &kind in kinds {
            let mut hist = Histogram::new();
            for e in log.events().filter(|e| e.kind == kind) {
                hist.record(e.compute_us.max(1));
            }
            stages.push(StageStat {
                kind,
                mean_us: hist.mean(),
                p50_us: hist.p50(),
                p99_us: hist.p99(),
                max_us: hist.max() as u64,
                count: hist.count(),
            });
        }

        // Per-frame end-to-end totals, ingested in sorted frame_id order.
        // `Histogram` bucket counts are ingestion-order-insensitive, but
        // the `Running` mean/m2 embedded in it accumulates in float order
        // — iterating the HashMap directly would make the report JSON
        // depend on the hasher's per-process seed. Sorting first keeps
        // every derived report byte-stable across runs and hosts.
        let mut per_frame: std::collections::HashMap<u64, u64> = Default::default();
        for e in log.events() {
            if kinds.contains(&e.kind) {
                *per_frame.entry(e.frame_id).or_insert(0) += e.compute_us;
            }
        }
        let mut totals: Vec<(u64, u64)> = per_frame.into_iter().collect();
        totals.sort_unstable_by_key(|&(frame_id, _)| frame_id);
        let mut e2e = Histogram::new();
        for (_, total) in totals {
            e2e.record(total.max(1));
        }
        Breakdown {
            stages,
            e2e_mean_us: e2e.mean(),
            e2e_p99_us: e2e.p99(),
            frames: e2e.count(),
        }
    }

    /// Mean of one stage.
    pub fn stage_mean(&self, kind: EventKind) -> f64 {
        self.stages
            .iter()
            .find(|s| s.kind == kind)
            .map(|s| s.mean_us)
            .unwrap_or(0.0)
    }

    /// Fraction of the mean end-to-end latency spent in `kind` (the §4.2 /
    /// §5.5 "waiting time constitutes X% of total latency" metric).
    pub fn fraction(&self, kind: EventKind) -> f64 {
        let total: f64 = self.stages.iter().map(|s| s.mean_us).sum();
        if total == 0.0 {
            0.0
        } else {
            self.stage_mean(kind) / total
        }
    }

    /// Sum of per-stage means — the Fig-6 bar total.
    pub fn total_mean_us(&self) -> f64 {
        self.stages.iter().map(|s| s.mean_us).sum()
    }

    /// Render as an aligned text table (what the benches print).
    pub fn render(&self, title: &str) -> String {
        let mut out = format!("{title}\n");
        out.push_str(&format!(
            "  {:<16} {:>12} {:>12} {:>12} {:>8} {:>8}\n",
            "stage", "mean", "p50", "p99", "count", "share"
        ));
        let total = self.total_mean_us();
        for s in &self.stages {
            out.push_str(&format!(
                "  {:<16} {:>12} {:>12} {:>12} {:>8} {:>7.1}%\n",
                s.kind.name(),
                fmt_us(s.mean_us as u64),
                fmt_us(s.p50_us),
                fmt_us(s.p99_us),
                s.count,
                if total > 0.0 { 100.0 * s.mean_us / total } else { 0.0 },
            ));
        }
        out.push_str(&format!(
            "  {:<16} {:>12} {:>12} {:>12} {:>8}\n",
            "end-to-end",
            fmt_us(self.e2e_mean_us as u64),
            "",
            fmt_us(self.e2e_p99_us),
            self.frames
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::event::Event;

    fn ev(kind: EventKind, frame: u64, dur: u64) -> Event {
        Event {
            kind,
            frame_id: frame,
            start_us: 10,
            compute_us: dur,
            face_count: 1,
            data_bytes: 0,
        }
    }

    const FR: &[EventKind] = &[
        EventKind::Ingestion,
        EventKind::FaceDetection,
        EventKind::BrokerWait,
        EventKind::Identification,
    ];

    #[test]
    fn breakdown_sums_and_fractions() {
        let mut log = EventLog::new();
        for f in 0..10 {
            log.log(ev(EventKind::Ingestion, f, 18_800));
            log.log(ev(EventKind::FaceDetection, f, 74_800));
            log.log(ev(EventKind::BrokerWait, f, 126_100));
            log.log(ev(EventKind::Identification, f, 131_500));
        }
        let b = Breakdown::from_log(&log, FR);
        assert!((b.total_mean_us() - 351_200.0).abs() < 1.0);
        // "over a third of the end-to-end latency is spent waiting"
        let wait_frac = b.fraction(EventKind::BrokerWait);
        assert!((wait_frac - 126_100.0 / 351_200.0).abs() < 1e-6);
        assert!(wait_frac > 1.0 / 3.0);
        assert_eq!(b.frames, 10);
        assert!((b.e2e_mean_us - 351_200.0).abs() < 400.0); // histogram precision
    }

    #[test]
    fn missing_stage_is_zero() {
        let mut log = EventLog::new();
        log.log(ev(EventKind::Ingestion, 0, 100));
        let b = Breakdown::from_log(&log, FR);
        assert_eq!(b.stage_mean(EventKind::Identification), 0.0);
        assert_eq!(b.fraction(EventKind::Ingestion), 1.0);
    }

    #[test]
    fn per_frame_aggregation_is_ingestion_order_invariant() {
        // Same events, reversed log order: identical breakdown — the
        // per-frame totals are ingested in sorted frame_id order, so no
        // HashMap seed or log ordering can leak into the float mean.
        let evs: Vec<Event> = (0..50)
            .flat_map(|f| {
                vec![
                    ev(EventKind::Ingestion, f, 100 + f * 7),
                    ev(EventKind::BrokerWait, f, 300 + f * 13),
                ]
            })
            .collect();
        let mut fwd = EventLog::new();
        for e in &evs {
            fwd.log(*e);
        }
        let mut rev = EventLog::new();
        for e in evs.iter().rev() {
            rev.log(*e);
        }
        let a = Breakdown::from_log(&fwd, FR);
        let b = Breakdown::from_log(&rev, FR);
        assert_eq!(a.e2e_mean_us.to_bits(), b.e2e_mean_us.to_bits());
        assert_eq!(a.e2e_p99_us, b.e2e_p99_us);
        assert_eq!(a.frames, b.frames);
    }

    #[test]
    fn render_contains_all_stages() {
        let mut log = EventLog::new();
        log.log(ev(EventKind::Ingestion, 0, 100));
        log.log(ev(EventKind::BrokerWait, 0, 300));
        let b = Breakdown::from_log(&log, FR);
        let text = b.render("test");
        assert!(text.contains("ingestion"));
        assert!(text.contains("broker wait"));
        assert!(text.contains("end-to-end"));
    }
}
