//! Event log: the paper's Listing-1 instrumentation.
//!
//! "We term the units of application progress 'events'; these are
//! high-level steps in the application ... We capture high-level event
//! information, such as the execution time of detect_faces, the number of
//! faces found, and the size of the face data." (§4.1)
//!
//! The log is append-only and cheap (a Vec push), matching the paper's
//! "negligible overhead" claim; aggregation happens after the run.

/// Which pipeline step an event describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EventKind {
    Ingestion,
    FaceDetection,
    BrokerWait,
    Identification,
    /// Object Detection's pre-send delay (Fig 14's "Delay" component).
    IngestDelay,
    /// Object Detection's R-CNN stage.
    ObjDetection,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Ingestion => "ingestion",
            EventKind::FaceDetection => "face detection",
            EventKind::BrokerWait => "broker wait",
            EventKind::Identification => "identification",
            EventKind::IngestDelay => "delay",
            EventKind::ObjDetection => "detection",
        }
    }
}

/// One logged event (Listing 1's `logging.info` payload).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub kind: EventKind,
    /// Frame this event belongs to.
    pub frame_id: u64,
    /// Virtual time the step started (us).
    pub start_us: u64,
    /// Step duration (us) — Listing 1's `compute_time`.
    pub compute_us: u64,
    /// Faces involved — Listing 1's `face_count`.
    pub face_count: u32,
    /// Payload bytes — Listing 1's `data_size`.
    pub data_bytes: u64,
}

/// Append-only event log with a warmup cutoff.
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    events: Vec<Event>,
    /// Events with `start_us` before this are excluded from aggregation
    /// (simulation warmup).
    pub warmup_us: u64,
}

impl EventLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_warmup(warmup_us: u64) -> Self {
        EventLog {
            events: Vec::new(),
            warmup_us,
        }
    }

    #[inline]
    pub fn log(&mut self, e: Event) {
        self.events.push(e);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Post-warmup events.
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        let w = self.warmup_us;
        self.events.iter().filter(move |e| e.start_us >= w)
    }

    pub fn all_events(&self) -> &[Event] {
        &self.events
    }

    /// Mean duration of a given kind (us).
    pub fn mean_us(&self, kind: EventKind) -> f64 {
        let mut sum = 0u64;
        let mut n = 0u64;
        for e in self.events().filter(|e| e.kind == kind) {
            sum += e.compute_us;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }

    pub fn count(&self, kind: EventKind) -> u64 {
        self.events().filter(|e| e.kind == kind).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, start: u64, dur: u64) -> Event {
        Event {
            kind,
            frame_id: 0,
            start_us: start,
            compute_us: dur,
            face_count: 1,
            data_bytes: 37_300,
        }
    }

    #[test]
    fn mean_per_kind() {
        let mut log = EventLog::new();
        log.log(ev(EventKind::Ingestion, 0, 10));
        log.log(ev(EventKind::Ingestion, 0, 30));
        log.log(ev(EventKind::BrokerWait, 0, 100));
        assert_eq!(log.mean_us(EventKind::Ingestion), 20.0);
        assert_eq!(log.mean_us(EventKind::BrokerWait), 100.0);
        assert_eq!(log.mean_us(EventKind::Identification), 0.0);
        assert_eq!(log.count(EventKind::Ingestion), 2);
    }

    #[test]
    fn warmup_excluded() {
        let mut log = EventLog::with_warmup(1000);
        log.log(ev(EventKind::Ingestion, 500, 999_999));
        log.log(ev(EventKind::Ingestion, 1500, 10));
        assert_eq!(log.mean_us(EventKind::Ingestion), 10.0);
        assert_eq!(log.len(), 2); // raw log keeps everything
    }
}
