//! Instrumentation: the paper's event-based measurement methodology
//! (Listing 1 + §4.1) implemented in-process.
//!
//! * [`event`] — high-level per-frame events (ingest, detect, broker wait,
//!   identify) with compute time, face count and payload size, exactly the
//!   fields the paper logs to Elasticsearch.
//! * [`breakdown`] — aggregates events into the Fig-6/Fig-13 stage-latency
//!   breakdowns and §4.2 tail-latency summaries.
//! * [`bandwidth`] — per-class byte meters producing Fig 11.
//! * [`tax`] — per-record latency provenance: the per-segment µs
//!   accumulator every `Item` carries and its per-tenant aggregate (the
//!   paper's AI-tax attribution, §4–§6).
//! * [`trace`] — opt-in bounded flight recorder exporting sampled record
//!   spans + world events as Chrome trace-event JSON.
//! * [`registry`] — every counter of a run flattened into one
//!   deterministic `metrics.json` object.

pub mod bandwidth;
pub mod query;
pub mod breakdown;
pub mod event;
pub mod registry;
pub mod tax;
pub mod trace;

pub use bandwidth::BandwidthMeter;
pub use breakdown::{Breakdown, StageStat};
pub use event::{Event, EventKind, EventLog};
pub use registry::MetricsRegistry;
pub use tax::{Segment, TaxBreakdown, TaxCell, TaxSummary};
pub use trace::{TraceRecorder, TraceSpec};
