//! Instrumentation: the paper's event-based measurement methodology
//! (Listing 1 + §4.1) implemented in-process.
//!
//! * [`event`] — high-level per-frame events (ingest, detect, broker wait,
//!   identify) with compute time, face count and payload size, exactly the
//!   fields the paper logs to Elasticsearch.
//! * [`breakdown`] — aggregates events into the Fig-6/Fig-13 stage-latency
//!   breakdowns and §4.2 tail-latency summaries.
//! * [`bandwidth`] — per-class byte meters producing Fig 11.

pub mod bandwidth;
pub mod query;
pub mod breakdown;
pub mod event;

pub use bandwidth::BandwidthMeter;
pub use breakdown::{Breakdown, StageStat};
pub use event::{Event, EventKind, EventLog};
