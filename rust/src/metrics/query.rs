//! Event-log queries: the Elasticsearch/Logstash half of the paper's
//! instrumentation stack (§4.1 logs events to ES "running on a separate
//! server" and aggregates offline).
//!
//! [`Query`] is a small filter → group-by → aggregate pipeline over an
//! [`EventLog`], enough to reproduce every aggregation the paper performs
//! (per-stage means, per-frame sums, percentiles by window, face-count
//! conditioned latency).

use std::collections::BTreeMap;

use crate::metrics::event::{Event, EventKind, EventLog};
use crate::util::stats::Histogram;

/// A filtered view over an event log.
#[derive(Clone, Copy)]
pub struct Query<'a> {
    log: &'a EventLog,
    kind: Option<EventKind>,
    time_range: Option<(u64, u64)>,
    min_faces: Option<u32>,
    frame_range: Option<(u64, u64)>,
}

impl<'a> Query<'a> {
    pub fn over(log: &'a EventLog) -> Query<'a> {
        Query {
            log,
            kind: None,
            time_range: None,
            min_faces: None,
            frame_range: None,
        }
    }

    pub fn kind(mut self, kind: EventKind) -> Self {
        self.kind = Some(kind);
        self
    }

    /// Keep events whose start time is in `[from, to)`.
    pub fn between(mut self, from: u64, to: u64) -> Self {
        self.time_range = Some((from, to));
        self
    }

    /// Keep events with at least this many faces (Fig-7-style surge
    /// conditioning).
    pub fn min_faces(mut self, n: u32) -> Self {
        self.min_faces = Some(n);
        self
    }

    pub fn frames(mut self, from: u64, to: u64) -> Self {
        self.frame_range = Some((from, to));
        self
    }

    fn matches(&self, e: &Event) -> bool {
        if let Some(k) = self.kind {
            if e.kind != k {
                return false;
            }
        }
        if let Some((a, b)) = self.time_range {
            if e.start_us < a || e.start_us >= b {
                return false;
            }
        }
        if let Some(n) = self.min_faces {
            if e.face_count < n {
                return false;
            }
        }
        if let Some((a, b)) = self.frame_range {
            if e.frame_id < a || e.frame_id >= b {
                return false;
            }
        }
        true
    }

    pub fn iter(&self) -> impl Iterator<Item = &'a Event> + '_ {
        self.log.events().filter(move |e| self.matches(e))
    }

    pub fn count(&self) -> usize {
        self.iter().count()
    }

    /// Mean of `compute_us`.
    pub fn mean_us(&self) -> f64 {
        let (mut sum, mut n) = (0u64, 0u64);
        for e in self.iter() {
            sum += e.compute_us;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }

    /// Percentile of `compute_us`.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let mut h = Histogram::new();
        for e in self.iter() {
            h.record(e.compute_us.max(1));
        }
        h.quantile(q)
    }

    /// Total payload bytes (the Listing-1 `data_size` aggregation that
    /// yields the 37.3 kB mean face size).
    pub fn total_bytes(&self) -> u64 {
        self.iter().map(|e| e.data_bytes).sum()
    }

    /// Group by time buckets of `width_us`, returning per-bucket means —
    /// the timeseries behind Fig 7.
    pub fn mean_by_time(&self, width_us: u64) -> BTreeMap<u64, f64> {
        let mut sums: BTreeMap<u64, (u64, u64)> = BTreeMap::new();
        for e in self.iter() {
            let bucket = e.start_us / width_us * width_us;
            let s = sums.entry(bucket).or_insert((0, 0));
            s.0 += e.compute_us;
            s.1 += 1;
        }
        sums.into_iter()
            .map(|(b, (sum, n))| (b, sum as f64 / n as f64))
            .collect()
    }

    /// Group by frame id, summing durations — per-frame end-to-end
    /// latency when applied over all stage kinds.
    pub fn sum_by_frame(&self) -> BTreeMap<u64, u64> {
        let mut out: BTreeMap<u64, u64> = BTreeMap::new();
        for e in self.iter() {
            *out.entry(e.frame_id).or_insert(0) += e.compute_us;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log() -> EventLog {
        let mut log = EventLog::new();
        for f in 0..100u64 {
            log.log(Event {
                kind: EventKind::FaceDetection,
                frame_id: f,
                start_us: f * 1000,
                compute_us: 70_000 + (f % 10) * 1000,
                face_count: (f % 4) as u32,
                data_bytes: 37_300 * (f % 4),
            });
            log.log(Event {
                kind: EventKind::Identification,
                frame_id: f,
                start_us: f * 1000 + 500,
                compute_us: 130_000,
                face_count: 1,
                data_bytes: 0,
            });
        }
        log
    }

    #[test]
    fn filter_by_kind_and_time() {
        let log = log();
        let q = Query::over(&log).kind(EventKind::FaceDetection);
        assert_eq!(q.count(), 100);
        let windowed = q.between(10_000, 20_000);
        assert_eq!(windowed.count(), 10);
        assert!(windowed.mean_us() > 70_000.0);
    }

    #[test]
    fn face_count_conditioning() {
        let log = log();
        let crowded = Query::over(&log)
            .kind(EventKind::FaceDetection)
            .min_faces(2);
        assert_eq!(crowded.count(), 50); // f % 4 in {2, 3}
    }

    #[test]
    fn per_frame_sums_give_e2e() {
        let log = log();
        let sums = Query::over(&log).sum_by_frame();
        assert_eq!(sums.len(), 100);
        // detect + identify per frame.
        assert!(sums[&0] >= 200_000);
    }

    #[test]
    fn time_bucketing() {
        let log = log();
        let buckets = Query::over(&log)
            .kind(EventKind::Identification)
            .mean_by_time(25_000);
        assert_eq!(buckets.len(), 4);
        for v in buckets.values() {
            assert_eq!(*v, 130_000.0);
        }
    }

    #[test]
    fn quantiles_and_bytes() {
        let log = log();
        let q = Query::over(&log).kind(EventKind::FaceDetection);
        assert!(q.quantile_us(0.99) >= q.quantile_us(0.5));
        // Mean face payload: total / faces — the paper's 37.3 kB stat.
        let faces: u64 = q.iter().map(|e| e.face_count as u64).sum();
        assert_eq!(q.total_bytes() / faces.max(1), 37_300);
    }
}
