//! Machine-readable metrics registry: every counter one multi-tenant
//! run produces, flattened into a single deterministic JSON object.
//!
//! Before this module each experiment cherry-picked its own ad-hoc
//! subset of [`MultiTenantReport`] fields into its point JSON, so
//! counters like `unclean_lost_bytes`, `net_contended_transfers`, and
//! `clamped_events` were visible in some reports and silently absent
//! from others. [`MetricsRegistry::from_report`] dumps the *whole*
//! report — world counters, shared-broker utilizations, cache and
//! network stats, the full fault ledger, and every per-tenant summary —
//! under stable dotted keys in a `BTreeMap`, so the serialized form is
//! byte-stable and key order never depends on hash seeds. Every
//! experiment embeds it as the point's `"metrics"` object, and
//! `aitax experiment tax` additionally writes one `metrics.json` per
//! run.
//!
//! Fault keys are always present (zeros when no [`FaultPlan`] was
//! installed, with `fault.armed` discriminating "healthy" from
//! "unmeasured"), so downstream tooling can jq the same path in every
//! report.
//!
//! [`FaultPlan`]: crate::pipeline::fabric::FaultPlan

use std::collections::BTreeMap;

use crate::pipeline::mixed::MultiTenantReport;
use crate::util::json::Json;

/// Flat `key → value` view of one run (see the module docs).
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    entries: BTreeMap<String, Json>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry { entries: BTreeMap::new() }
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) {
        self.entries.insert(key.to_string(), value.into());
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.entries.get(key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Collect every counter of one finished run.
    pub fn from_report(r: &MultiTenantReport) -> Self {
        let mut reg = MetricsRegistry::new();
        reg.set("events", r.events);
        reg.set("clamped_events", r.clamped_events);
        reg.set("broker.storage_write_util", r.broker_storage_write_util);
        reg.set("broker.storage_read_util", r.broker_storage_read_util);
        reg.set("broker.net_rx_util", r.broker_net_rx_util);
        reg.set("broker.cpu_util", r.broker_cpu_util);
        reg.set("cache.hit_ratio", r.cache_hit_ratio);
        reg.set("cache.device_read_share", r.device_read_share);
        reg.set("net.contended_transfers", r.net_contended_transfers);
        reg.set("net.max_uplink_util", r.net_max_uplink_util);

        reg.set("fault.armed", r.fault.is_some());
        let f = r.fault.as_ref();
        reg.set("fault.records_offered", f.map_or(0, |f| f.records_offered));
        reg.set("fault.records_committed", f.map_or(0, |f| f.records_committed));
        reg.set("fault.records_in_flight", f.map_or(0, |f| f.records_in_flight));
        reg.set("fault.records_lost", f.map_or(0, |f| f.records_lost));
        reg.set("fault.records_rejected", f.map_or(0, |f| f.records_rejected));
        reg.set("fault.records_rejected_final", f.map_or(0, |f| f.records_rejected_final));
        reg.set("fault.records_retried", f.map_or(0, |f| f.records_retried));
        reg.set("fault.records_client_dropped", f.map_or(0, |f| f.records_client_dropped));
        reg.set("fault.records_dedup_suppressed", f.map_or(0, |f| f.records_dedup_suppressed));
        reg.set("fault.min_isr_violations", f.map_or(0, |f| f.min_isr_violations));
        reg.set("fault.missed_bytes", f.map_or(0.0, |f| f.missed_bytes));
        reg.set("fault.rereplicated_bytes", f.map_or(0.0, |f| f.rereplicated_bytes));
        reg.set("fault.backlog_bytes", f.map_or(0.0, |f| f.backlog_bytes));
        reg.set(
            "fault.rereplication_read_share",
            f.map_or(0.0, |f| f.rereplication_read_share),
        );
        reg.set("fault.unclean_lost_bytes", f.map_or(0.0, |f| f.unclean_lost_bytes));
        reg.set("fault.unclean_elections", f.map_or(0, |f| f.unclean_elections));
        reg.set("fault.conservation_residual", f.map_or(0, |f| f.conservation_residual()));
        reg.set(
            "fault.recovery_done_us",
            f.and_then(|f| f.recovery_done_us).map_or(Json::Null, Json::from),
        );

        for t in &r.tenants {
            let k = |field: &str| format!("tenant.{}.{}", t.name, field);
            reg.entries.insert(k("produced"), Json::from(t.produced));
            reg.entries.insert(k("completed"), Json::from(t.completed));
            reg.entries
                .insert(k("throughput_per_sec"), Json::from(t.throughput_per_sec));
            reg.entries.insert(k("e2e_mean_us"), Json::from(t.e2e_mean_us));
            reg.entries.insert(k("e2e_p99_us"), Json::from(t.e2e_p99_us));
            reg.entries.insert(k("wait_p99_us"), Json::from(t.wait_p99_us));
            reg.entries.insert(k("net_tx_bytes"), Json::from(t.net_tx_bytes));
            reg.entries.insert(k("net_rx_bytes"), Json::from(t.net_rx_bytes));
            reg.entries
                .insert(k("consumer_lag_bytes"), Json::from(t.consumer_lag_bytes));
            reg.entries.insert(k("retries"), Json::from(t.retries));
            reg.entries.insert(k("client_dropped"), Json::from(t.client_dropped));
            reg.entries
                .insert(k("absorbed_rejects"), Json::from(t.absorbed_rejects));
            reg.entries.insert(k("stable"), Json::from(t.stable));
            if let Some(tax) = &t.tax {
                reg.entries.insert(k("tax_share"), Json::from(tax.tax_share));
                reg.entries.insert(k("tax_us"), Json::from(tax.tax_us));
                reg.entries.insert(k("ai_us"), Json::from(tax.ai_us));
                reg.entries
                    .insert(k("tax_max_residual_us"), Json::from(tax.max_residual_us));
            }
        }
        reg
    }

    /// The registry as one flat JSON object (`BTreeMap` ⇒ sorted keys).
    pub fn to_json(&self) -> Json {
        Json::Obj(self.entries.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::pipeline::dc::WorkloadKind;
    use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantSim, TenantDef};
    use crate::util::units::SEC;

    fn tiny_report() -> MultiTenantReport {
        let mut cfg = Config::default();
        cfg.deployment = crate::config::Deployment {
            producers: 10,
            consumers: 10,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 10,
        };
        cfg.seed = 0xACCE1;
        let fabric = cfg.clone();
        MultiTenantSim::new(
            MultiTenantConfig::new(fabric, 2 * SEC)
                .tenant(TenantDef::new("facerec", WorkloadKind::FaceRec, cfg)),
        )
        .run()
    }

    #[test]
    fn registry_carries_world_broker_and_tenant_counters() {
        let r = tiny_report();
        let reg = MetricsRegistry::from_report(&r);
        assert_eq!(reg.get("events").and_then(|v| v.as_f64()), Some(r.events as f64));
        assert_eq!(reg.get("clamped_events").and_then(|v| v.as_f64()), Some(0.0));
        assert!(reg.get("broker.storage_write_util").is_some());
        assert!(reg.get("tenant.facerec.completed").and_then(|v| v.as_f64()).unwrap() > 0.0);
        // No tax arming ⇒ no tax keys.
        assert!(reg.get("tenant.facerec.tax_share").is_none());
    }

    #[test]
    fn fault_keys_are_uniform_even_without_a_plan() {
        let reg = MetricsRegistry::from_report(&tiny_report());
        assert_eq!(reg.get("fault.armed").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(reg.get("fault.unclean_lost_bytes").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(reg.get("fault.conservation_residual").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(reg.get("net.contended_transfers").and_then(|v| v.as_f64()), Some(0.0));
        assert!(matches!(reg.get("fault.recovery_done_us"), Some(Json::Null)));
    }

    #[test]
    fn json_form_is_a_single_sorted_object() {
        let reg = MetricsRegistry::from_report(&tiny_report());
        let j = reg.to_json();
        let obj = j.as_obj().expect("one flat object");
        assert_eq!(obj.len(), reg.len());
        // BTreeMap: serialization order is key order, not hash order.
        let keys: Vec<&String> = obj.keys().collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }
}
