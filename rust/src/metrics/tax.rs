//! Latency provenance: per-record AI-tax attribution.
//!
//! The paper's headline result is an *attribution*, not a p99: as AI is
//! accelerated, the pre/post-processing, broker wait, storage, and
//! network shares grow from a footnote into the dominant "AI tax" slice
//! of end-to-end time (AI Tax §4–§6). This module gives every record a
//! compact per-segment µs accumulator ([`TaxCell`], embedded in
//! `pipeline::dc::Item` and `pipeline::fabric::InFlight`) that is
//! charged at every hop, and a per-tenant aggregate ([`TaxBreakdown`])
//! surfaced as `TenantSummary::tax`.
//!
//! ## The telescoping contract
//!
//! A cell remembers only the **last charged instant** (`last_us`). Each
//! `charge(seg, now)` attributes the whole interval `[last, now]` to one
//! segment and advances `last` to `now`; [`TaxCell::charge_split`]
//! divides one interval between two segments without changing its
//! total. Because every hop charges with the timestamps the simulator
//! already computes — and those are non-decreasing along a record's path
//! — the segment sums telescope: **Σ segments == final `last_us` −
//! `created_us` exactly**, so per-record residual against measured e2e
//! is 0 µs by construction ([`TaxBreakdown::max_residual_us`] pins it).
//!
//! Retransmits are the one place two copies of a record exist at once
//! (client retries, PR 8): the client charges its wait to
//! [`Segment::ClientWait`] while the original attempt may still commit.
//! [`TaxCell::reconcile`] absorbs the winning fabric copy's cell and
//! settles the signed residual against `ClientWait` — the segment that
//! double-charged — restoring the exact identity.
//!
//! Flow macro-records (PR 6) carry `Item.count` aggregated clients;
//! [`TaxBreakdown::record`] weights every ingest by that count so the
//! aggregates stay per-record-faithful.
//!
//! Segment widths are `u32` µs: saturating, and ample for the ≤ 30 s
//! (3 × 10⁷ µs) virtual horizons the experiments run.

use crate::util::json::Json;
use crate::util::stats::{Histogram, Running};

/// Number of provenance segments ([`Segment::ALL`] has this length).
pub const SEG_COUNT: usize = 11;

/// One attributable slice of a record's end-to-end latency.
///
/// Everything except [`Segment::Service`] is **tax** — time the record
/// spent waiting on or moving through the coordination substrate rather
/// than being processed by the AI application itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Segment {
    /// Client-side buffer/linger, retry backoff, and loss windows
    /// before the record is (re)offered to the fabric.
    ClientWait = 0,
    /// Quota-throttle delay imposed by broker QoS (PR 2/4).
    Throttle = 1,
    /// Wire + NIC serialization, producer → leader (contention-inflated
    /// when the PR 9 network is installed).
    Network = 2,
    /// Broker request-CPU queueing (time beyond the ideal service).
    CpuQueue = 3,
    /// Broker request-CPU service at the ideal (uncontended) rate.
    CpuService = 4,
    /// NVMe write path: queue + device time for the leader append.
    StorageWrite = 5,
    /// Waiting for the ISR follower quorum to acknowledge.
    Replication = 6,
    /// Committed and visible, waiting for a consumer poll (plus the
    /// consumer's serve queue).
    BrokerWait = 7,
    /// Fetch transfer: page-cache or cold NVMe read plus the reply wire.
    Fetch = 8,
    /// Visible time overlapped by a leader-election rebalance pause.
    Rebalance = 9,
    /// The AI application's own processing — the *accelerated* side of
    /// the tax ratio.
    Service = 10,
}

impl Segment {
    /// Canonical charging order (the order segments occur along a
    /// record's path; trace reconstruction relies on it).
    pub const ALL: [Segment; SEG_COUNT] = [
        Segment::ClientWait,
        Segment::Throttle,
        Segment::Network,
        Segment::CpuQueue,
        Segment::CpuService,
        Segment::StorageWrite,
        Segment::Replication,
        Segment::BrokerWait,
        Segment::Fetch,
        Segment::Rebalance,
        Segment::Service,
    ];

    pub fn idx(self) -> usize {
        self as usize
    }

    /// Stable snake_case label used in report JSON and trace events.
    pub fn label(self) -> &'static str {
        match self {
            Segment::ClientWait => "client_wait",
            Segment::Throttle => "throttle",
            Segment::Network => "network",
            Segment::CpuQueue => "cpu_queue",
            Segment::CpuService => "cpu_service",
            Segment::StorageWrite => "storage_write",
            Segment::Replication => "replication",
            Segment::BrokerWait => "broker_wait",
            Segment::Fetch => "fetch",
            Segment::Rebalance => "rebalance",
            Segment::Service => "service",
        }
    }

    /// True for the non-AI segments (everything but [`Segment::Service`]).
    pub fn is_tax(self) -> bool {
        !matches!(self, Segment::Service)
    }
}

fn as_u32(us: u64) -> u32 {
    us.min(u32::MAX as u64) as u32
}

/// Compact per-record segment accumulator (52 bytes, `Copy`).
///
/// Embedded in every `Item` and `InFlight`; charging is gated by the
/// provenance flag at the call sites, so a disabled world never touches
/// the cell after construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TaxCell {
    /// Last charged instant (µs). Starts at the record's creation time.
    pub last_us: u64,
    seg: [u32; SEG_COUNT],
}

impl TaxCell {
    pub fn new(created_us: u64) -> Self {
        TaxCell { last_us: created_us, seg: [0; SEG_COUNT] }
    }

    /// Attribute the whole interval `[last_us, now_us]` to `seg` and
    /// advance `last_us`. Out-of-order timestamps (now < last) charge
    /// nothing and leave `last_us` untouched, so a cell can never
    /// over-charge past the clock.
    pub fn charge(&mut self, seg: Segment, now_us: u64) {
        let dt = now_us.saturating_sub(self.last_us);
        let s = &mut self.seg[seg.idx()];
        *s = s.saturating_add(as_u32(dt));
        self.last_us = self.last_us.max(now_us);
    }

    /// Split the interval `[last_us, now_us]` between two segments:
    /// up to `first_us` goes to `first`, the remainder to `rest`. The
    /// interval total is preserved exactly whatever `first_us` claims.
    pub fn charge_split(&mut self, first: Segment, first_us: u64, rest: Segment, now_us: u64) {
        let dt = now_us.saturating_sub(self.last_us);
        let a = first_us.min(dt);
        let f = &mut self.seg[first.idx()];
        *f = f.saturating_add(as_u32(a));
        let r = &mut self.seg[rest.idx()];
        *r = r.saturating_add(as_u32(dt - a));
        self.last_us = self.last_us.max(now_us);
    }

    pub fn seg_us(&self, seg: Segment) -> u64 {
        self.seg[seg.idx()] as u64
    }

    /// Sum of all segment charges (µs).
    pub fn total_us(&self) -> u64 {
        self.seg.iter().map(|&v| v as u64).sum()
    }

    /// Absorb the committed fabric copy of this record and settle the
    /// residual so that `total_us() == commit_us − created_us` exactly.
    ///
    /// The fabric cell covers `[send, commit]`; this (client) cell
    /// covers `[created, last]`. In the common case `last == send` and
    /// plain addition already telescopes. Under retransmits the client
    /// kept charging [`Segment::ClientWait`] past the *winning* copy's
    /// send time (or an unacked loss window never got charged at all),
    /// so the signed difference is settled against `ClientWait` — the
    /// exact segment that double- or under-charged.
    pub fn reconcile(&mut self, fabric: &TaxCell, created_us: u64, commit_us: u64) {
        for (mine, theirs) in self.seg.iter_mut().zip(fabric.seg.iter()) {
            *mine = mine.saturating_add(*theirs);
        }
        let target = commit_us.saturating_sub(created_us);
        let have = self.total_us();
        let cw = &mut self.seg[Segment::ClientWait.idx()];
        if target >= have {
            *cw = cw.saturating_add(as_u32(target - have));
        } else {
            *cw = cw.saturating_sub(as_u32(have - target));
        }
        self.last_us = self.last_us.max(commit_us);
    }
}

/// Per-tenant aggregate of record [`TaxCell`]s: a [`Running`] (exact
/// mean/variance) plus a [`Histogram`] (tail quantiles) per segment,
/// weighted by the record's client `count`.
#[derive(Clone, Debug)]
pub struct TaxBreakdown {
    seg_stats: [Running; SEG_COUNT],
    seg_hist: Box<[Histogram; SEG_COUNT]>,
    e2e: Running,
    records: u64,
    max_residual_us: u64,
}

impl TaxBreakdown {
    pub fn new() -> Self {
        TaxBreakdown {
            seg_stats: std::array::from_fn(|_| Running::new()),
            seg_hist: Box::new(std::array::from_fn(|_| Histogram::new())),
            e2e: Running::new(),
            records: 0,
            max_residual_us: 0,
        }
    }

    /// Ingest one completed record (or flow macro-record of `count`
    /// clients). `e2e_us` is the measured end-to-end latency the serve
    /// loop already computed; the |e2e − Σ segments| residual is
    /// tracked so tests can pin it at 0.
    pub fn record(&mut self, cell: &TaxCell, e2e_us: u64, count: u64) {
        if count == 0 {
            return;
        }
        for seg in Segment::ALL {
            let v = cell.seg_us(seg);
            self.seg_stats[seg.idx()].add_n(v as f64, count);
            self.seg_hist[seg.idx()].record_n(v, count);
        }
        self.e2e.add_n(e2e_us as f64, count);
        self.records += count;
        let residual = e2e_us.abs_diff(cell.total_us());
        self.max_residual_us = self.max_residual_us.max(residual);
    }

    pub fn records(&self) -> u64 {
        self.records
    }

    pub fn max_residual_us(&self) -> u64 {
        self.max_residual_us
    }

    pub fn summary(&self) -> TaxSummary {
        let mut seg_mean_us = [0.0; SEG_COUNT];
        let mut seg_p99_us = [0u64; SEG_COUNT];
        let mut ai_us = 0.0;
        let mut tax_us = 0.0;
        for seg in Segment::ALL {
            let mean = self.seg_stats[seg.idx()].mean();
            seg_mean_us[seg.idx()] = mean;
            seg_p99_us[seg.idx()] = self.seg_hist[seg.idx()].p99();
            if seg.is_tax() {
                tax_us += mean;
            } else {
                ai_us += mean;
            }
        }
        let denom = ai_us + tax_us;
        TaxSummary {
            records: self.records,
            e2e_mean_us: self.e2e.mean(),
            ai_us,
            tax_us,
            tax_share: if denom > 0.0 { tax_us / denom } else { 0.0 },
            seg_mean_us,
            seg_p99_us,
            max_residual_us: self.max_residual_us,
        }
    }
}

impl Default for TaxBreakdown {
    fn default() -> Self {
        Self::new()
    }
}

/// Report-ready snapshot of a [`TaxBreakdown`].
#[derive(Clone, Debug)]
pub struct TaxSummary {
    /// Client-weighted record count the means are over.
    pub records: u64,
    pub e2e_mean_us: f64,
    /// Mean µs/record in [`Segment::Service`] — the AI side.
    pub ai_us: f64,
    /// Mean µs/record summed over every non-`Service` segment.
    pub tax_us: f64,
    /// `tax_us / (ai_us + tax_us)` — the paper's headline ratio.
    pub tax_share: f64,
    pub seg_mean_us: [f64; SEG_COUNT],
    pub seg_p99_us: [u64; SEG_COUNT],
    /// Worst |e2e − Σ segments| seen (µs) — 0 by construction.
    pub max_residual_us: u64,
}

impl TaxSummary {
    pub fn to_json(&self) -> Json {
        let segments = Json::obj(
            Segment::ALL
                .iter()
                .map(|&seg| {
                    (
                        seg.label(),
                        Json::obj(vec![
                            ("mean_us", Json::from(self.seg_mean_us[seg.idx()])),
                            ("p99_us", Json::from(self.seg_p99_us[seg.idx()])),
                        ]),
                    )
                })
                .collect::<Vec<_>>(),
        );
        Json::obj(vec![
            ("records", Json::from(self.records)),
            ("e2e_mean_us", Json::from(self.e2e_mean_us)),
            ("ai_us", Json::from(self.ai_us)),
            ("tax_us", Json::from(self.tax_us)),
            ("tax_share", Json::from(self.tax_share)),
            ("max_residual_us", Json::from(self.max_residual_us)),
            ("segments", segments),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_are_canonical_and_labeled() {
        assert_eq!(Segment::ALL.len(), SEG_COUNT);
        for (i, seg) in Segment::ALL.iter().enumerate() {
            assert_eq!(seg.idx(), i, "ALL must be in discriminant order");
        }
        let mut labels: Vec<&str> = Segment::ALL.iter().map(|s| s.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), SEG_COUNT, "labels must be unique");
        assert!(Segment::ClientWait.is_tax());
        assert!(!Segment::Service.is_tax());
    }

    #[test]
    fn charges_telescope_to_the_elapsed_interval() {
        // Any monotone sequence of charges must sum to exactly
        // last − created, whatever the segment pattern.
        let created = 1_000;
        let stamps = [1_000, 1_003, 1_050, 1_050, 2_000, 2_777, 10_000];
        let mut cell = TaxCell::new(created);
        for (i, &t) in stamps.iter().enumerate() {
            cell.charge(Segment::ALL[i % SEG_COUNT], t);
        }
        assert_eq!(cell.total_us(), 10_000 - created);
        assert_eq!(cell.last_us, 10_000);
    }

    #[test]
    fn out_of_order_charge_is_a_no_op() {
        let mut cell = TaxCell::new(500);
        cell.charge(Segment::Network, 700);
        cell.charge(Segment::Fetch, 600); // behind last — charges nothing
        assert_eq!(cell.seg_us(Segment::Fetch), 0);
        assert_eq!(cell.last_us, 700);
        assert_eq!(cell.total_us(), 200);
    }

    #[test]
    fn charge_split_preserves_the_interval_total() {
        let mut cell = TaxCell::new(0);
        // Claim more service than the interval holds: the cap wins.
        cell.charge_split(Segment::CpuService, 500, Segment::CpuQueue, 300);
        assert_eq!(cell.seg_us(Segment::CpuService), 300);
        assert_eq!(cell.seg_us(Segment::CpuQueue), 0);
        // Claim part of a later interval: the rest goes to the queue.
        cell.charge_split(Segment::CpuService, 100, Segment::CpuQueue, 1_000);
        assert_eq!(cell.seg_us(Segment::CpuService), 400);
        assert_eq!(cell.seg_us(Segment::CpuQueue), 600);
        assert_eq!(cell.total_us(), 1_000);
    }

    #[test]
    fn reconcile_settles_the_plain_case_exactly() {
        // Client: created 0, ClientWait to 100, send at 100.
        let mut item = TaxCell::new(0);
        item.charge(Segment::ClientWait, 100);
        // Fabric copy: send 100 → commit 900.
        let mut fab = TaxCell::new(100);
        fab.charge(Segment::Network, 200);
        fab.charge(Segment::StorageWrite, 600);
        fab.charge(Segment::Replication, 900);
        item.reconcile(&fab, 0, 900);
        assert_eq!(item.total_us(), 900, "Σ segments == commit − created");
        assert_eq!(item.seg_us(Segment::ClientWait), 100);
        assert_eq!(item.last_us, 900);
    }

    #[test]
    fn reconcile_absorbs_retransmit_overlap_into_client_wait() {
        // Client sends at 100, times out, charges ClientWait to the
        // retransmit at 400 — but the ORIGINAL copy wins at 900. The
        // overlap [100, 400] was charged twice (client ClientWait +
        // fabric segments); reconcile must claw it back.
        let mut item = TaxCell::new(0);
        item.charge(Segment::ClientWait, 100); // pre-send buffer
        item.charge(Segment::ClientWait, 400); // timeout window
        let mut fab = TaxCell::new(100);
        fab.charge(Segment::Network, 300);
        fab.charge(Segment::Replication, 900);
        item.reconcile(&fab, 0, 900);
        assert_eq!(item.total_us(), 900);
        assert_eq!(item.seg_us(Segment::ClientWait), 100, "overlap clawed back");
    }

    #[test]
    fn reconcile_fills_uncharged_loss_windows() {
        // A lost attempt nobody charged: item last stops at 100, the
        // winning copy was sent at 500. The [100, 500] gap lands in
        // ClientWait.
        let mut item = TaxCell::new(0);
        item.charge(Segment::ClientWait, 100);
        let mut fab = TaxCell::new(500);
        fab.charge(Segment::Network, 600);
        item.reconcile(&fab, 0, 600);
        assert_eq!(item.total_us(), 600);
        assert_eq!(item.seg_us(Segment::ClientWait), 500);
    }

    #[test]
    fn breakdown_weights_by_count_and_pins_residual() {
        let mut tb = TaxBreakdown::new();
        let mut a = TaxCell::new(0);
        a.charge(Segment::Network, 100);
        a.charge(Segment::Service, 300);
        tb.record(&a, 300, 10); // flow macro-record: 10 clients
        let mut b = TaxCell::new(0);
        b.charge(Segment::Network, 500);
        b.charge(Segment::Service, 600);
        tb.record(&b, 600, 1);
        assert_eq!(tb.records(), 11);
        assert_eq!(tb.max_residual_us(), 0);
        let s = tb.summary();
        // Count-weighted means: network (10×100 + 1×500)/11, service
        // (10×200 + 1×100)/11.
        assert!((s.seg_mean_us[Segment::Network.idx()] - 1500.0 / 11.0).abs() < 1e-9);
        assert!((s.ai_us - 2100.0 / 11.0).abs() < 1e-9);
        assert!((s.tax_us - 1500.0 / 11.0).abs() < 1e-9);
        assert!((s.tax_share - 1500.0 / 3600.0).abs() < 1e-9);
        assert!((s.e2e_mean_us - (10.0 * 300.0 + 600.0) / 11.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_flags_nonzero_residuals() {
        let mut tb = TaxBreakdown::new();
        let mut cell = TaxCell::new(0);
        cell.charge(Segment::Service, 100);
        tb.record(&cell, 105, 1); // e2e disagrees by 5 µs
        assert_eq!(tb.max_residual_us(), 5);
    }

    #[test]
    fn summary_json_carries_every_segment() {
        let mut tb = TaxBreakdown::new();
        let mut cell = TaxCell::new(0);
        cell.charge(Segment::Throttle, 50);
        cell.charge(Segment::Service, 150);
        tb.record(&cell, 150, 1);
        let j = tb.summary().to_json();
        let segs = j.get("segments").and_then(|s| s.as_obj()).expect("segments");
        assert_eq!(segs.len(), SEG_COUNT);
        for seg in Segment::ALL {
            assert!(segs.contains_key(seg.label()), "missing {}", seg.label());
        }
        assert_eq!(j.path(&["segments", "throttle", "mean_us"]).and_then(|v| v.as_f64()), Some(50.0));
    }
}
