//! Flight-recorder tracing: a bounded ring buffer of sampled record
//! spans and world events, exported as Chrome trace-event JSON.
//!
//! Opt-in (off by default, like every provenance feature): when a
//! [`TraceSpec`] is installed, the consumer serve loop offers every
//! completed record to [`TraceRecorder::record_span`], which keeps one
//! in `sample_every` and expands its [`TaxCell`] into per-segment `"X"`
//! duration events — the timestamps are reconstructed cumulatively from
//! the record's creation time in [`Segment::ALL`] order, which is
//! exactly the order the segments occur along the path. World events
//! (broker kills/restarts, partitions, leader elections, sampled
//! network-transfer epochs) land as `"i"` instant events. The buffer is
//! a fixed-capacity ring: old events fall off the front, so a trace
//! costs bounded memory however long the run ([`TraceRecorder::dropped`]
//! counts the overflow).
//!
//! The output loads directly in Perfetto / `chrome://tracing`: records
//! are grouped per tenant (`pid`) with one track per sampled record
//! (`tid` = sample sequence number).

use std::collections::VecDeque;

use crate::metrics::tax::{Segment, TaxCell};
use crate::util::json::Json;

/// Flight-recorder parameters. `Default` is a 4096-event ring sampling
/// one record in 64.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    /// Ring capacity in trace events (spans + instants).
    pub capacity: usize,
    /// Keep one completed record in this many (1 = every record).
    pub sample_every: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec { capacity: 4096, sample_every: 64 }
    }
}

#[derive(Clone, Debug)]
enum TraceEvent {
    /// One segment of one sampled record ("X" duration event).
    Span { tenant: u8, seq: u64, seg: Segment, ts_us: u64, dur_us: u64 },
    /// One world event ("i" instant event).
    Instant { name: &'static str, ts_us: u64 },
}

/// Bounded flight recorder (see the module docs).
#[derive(Clone, Debug)]
pub struct TraceRecorder {
    spec: TraceSpec,
    /// Completed records offered so far (drives span sampling).
    seen: u64,
    /// Instants offered to the *sampled* instant channel so far.
    ticks: u64,
    dropped: u64,
    events: VecDeque<TraceEvent>,
}

impl TraceRecorder {
    pub fn new(spec: TraceSpec) -> Self {
        TraceRecorder {
            spec,
            seen: 0,
            ticks: 0,
            dropped: 0,
            events: VecDeque::with_capacity(spec.capacity.min(4096)),
        }
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.spec.capacity == 0 {
            self.dropped += 1;
            return;
        }
        if self.events.len() >= self.spec.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    /// Offer one completed record; every `sample_every`-th is expanded
    /// into per-segment spans reconstructed from `created_us` in
    /// canonical segment order.
    pub fn record_span(&mut self, tenant: u8, created_us: u64, cell: &TaxCell) {
        self.seen += 1;
        if self.spec.sample_every > 1 && self.seen % self.spec.sample_every != 0 {
            return;
        }
        let seq = self.seen;
        let mut ts = created_us;
        for seg in Segment::ALL {
            let dur = cell.seg_us(seg);
            if dur > 0 {
                self.push(TraceEvent::Span { tenant, seq, seg, ts_us: ts, dur_us: dur });
            }
            ts += dur;
        }
    }

    /// Record a world event (fault, election, rebalance) unconditionally.
    pub fn instant(&mut self, name: &'static str, ts_us: u64) {
        self.push(TraceEvent::Instant { name, ts_us });
    }

    /// Record a high-frequency world event (e.g. network-transfer
    /// epochs) through the same 1-in-`sample_every` decimation as spans.
    pub fn instant_sampled(&mut self, name: &'static str, ts_us: u64) {
        self.ticks += 1;
        if self.spec.sample_every > 1 && self.ticks % self.spec.sample_every != 0 {
            return;
        }
        self.push(TraceEvent::Instant { name, ts_us });
    }

    /// Events currently held in the ring.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events that fell off the front (or were refused by a zero-capacity
    /// ring).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Chrome trace-event JSON array (Perfetto's legacy-JSON format).
    pub fn to_chrome_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|ev| match ev {
                TraceEvent::Span { tenant, seq, seg, ts_us, dur_us } => Json::obj(vec![
                    ("name", Json::from(seg.label())),
                    ("cat", Json::from("record")),
                    ("ph", Json::from("X")),
                    ("ts", Json::from(*ts_us)),
                    ("dur", Json::from(*dur_us)),
                    ("pid", Json::from(*tenant as u64)),
                    ("tid", Json::from(*seq)),
                ]),
                TraceEvent::Instant { name, ts_us } => Json::obj(vec![
                    ("name", Json::from(*name)),
                    ("cat", Json::from("world")),
                    ("ph", Json::from("i")),
                    ("s", Json::from("g")),
                    ("ts", Json::from(*ts_us)),
                    ("pid", Json::from(0u64)),
                    ("tid", Json::from(0u64)),
                ]),
            })
            .collect();
        Json::Arr(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell() -> TaxCell {
        let mut c = TaxCell::new(1_000);
        c.charge(Segment::Network, 1_100);
        c.charge(Segment::BrokerWait, 1_400);
        c.charge(Segment::Service, 1_600);
        c
    }

    #[test]
    fn sampling_keeps_one_record_in_n() {
        let mut tr = TraceRecorder::new(TraceSpec { capacity: 1024, sample_every: 4 });
        for _ in 0..8 {
            tr.record_span(0, 1_000, &cell());
        }
        // 2 sampled records × 3 nonzero segments.
        assert_eq!(tr.len(), 6);
    }

    #[test]
    fn spans_reconstruct_cumulative_timestamps() {
        let mut tr = TraceRecorder::new(TraceSpec { capacity: 1024, sample_every: 1 });
        tr.record_span(2, 1_000, &cell());
        let arr = tr.to_chrome_json();
        let events = arr.as_arr().expect("array");
        assert_eq!(events.len(), 3);
        // Network starts at creation; BrokerWait and Service stack after.
        let ts: Vec<f64> =
            events.iter().map(|e| e.get("ts").and_then(|v| v.as_f64()).unwrap()).collect();
        assert_eq!(ts, vec![1_000.0, 1_100.0, 1_400.0]);
        let durs: Vec<f64> =
            events.iter().map(|e| e.get("dur").and_then(|v| v.as_f64()).unwrap()).collect();
        assert_eq!(durs, vec![100.0, 300.0, 200.0]);
        assert!(events.iter().all(|e| e.get("pid").and_then(|v| v.as_f64()) == Some(2.0)));
        assert!(events.iter().all(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X")));
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let mut tr = TraceRecorder::new(TraceSpec { capacity: 4, sample_every: 1 });
        for i in 0..10 {
            tr.instant("fault", i * 100);
        }
        assert_eq!(tr.len(), 4);
        assert_eq!(tr.dropped(), 6);
        // The ring keeps the *latest* events.
        let arr = tr.to_chrome_json();
        let first_ts = arr.as_arr().unwrap()[0].get("ts").and_then(|v| v.as_f64());
        assert_eq!(first_ts, Some(600.0));
    }

    #[test]
    fn instants_carry_the_world_category() {
        let mut tr = TraceRecorder::new(TraceSpec::default());
        tr.instant("broker-kill", 3_000_000);
        let arr = tr.to_chrome_json();
        let ev = &arr.as_arr().unwrap()[0];
        assert_eq!(ev.get("ph").and_then(|v| v.as_str()), Some("i"));
        assert_eq!(ev.get("cat").and_then(|v| v.as_str()), Some("world"));
        assert_eq!(ev.get("name").and_then(|v| v.as_str()), Some("broker-kill"));
    }

    #[test]
    fn sampled_instants_decimate() {
        let mut tr = TraceRecorder::new(TraceSpec { capacity: 1024, sample_every: 8 });
        for i in 0..64 {
            tr.instant_sampled("net-epoch", i);
        }
        assert_eq!(tr.len(), 8);
    }
}
