//! Max-min fair-share link capacity allocation.
//!
//! A [`Link`] is a directed capacity (bytes/sec). Concurrent flows that
//! cross a link split its capacity **max-min fairly**: capacity is
//! raised uniformly across all flows until some link saturates, the
//! flows crossing that link are frozen at their current rate, and the
//! residual headroom is shared among the rest — the classic
//! *progressive filling* (water-filling) algorithm, the same shape
//! dslab's `throughput-model` crate uses for flow-level network
//! simulation.
//!
//! The allocator is deterministic: plain `f64` arithmetic over slices
//! in index order, no RNG, no wall clock, and it terminates in at most
//! `flows` iterations (every iteration freezes at least one flow or
//! exits). [`crate::net::path::PathNet`] calls it at every transfer
//! entry/exit epoch; the property tests at the bottom pin the max-min
//! invariants (per-link conservation, bottleneck saturation, and the
//! "no flow can gain without shrinking a smaller one" optimality
//! condition).

/// Longest path supported: src access up, source-rack uplink,
/// destination-rack downlink, dst access down.
pub const MAX_PATH_LINKS: usize = 4;

/// Saturation slack, relative to link capacity: a link whose residual
/// headroom is below `capacity * REL_EPS + ABS_EPS` is treated as full.
const REL_EPS: f64 = 1e-9;
const ABS_EPS: f64 = 1e-6;

/// One directed link: fixed capacity plus cumulative carried bytes
/// (utilization accounting) and per-recompute scratch.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Capacity in bytes/sec.
    pub capacity: f64,
    /// Total bytes ever routed across this link (charged at transfer
    /// entry — the utilization numerator).
    pub bytes_carried: f64,
    /// Scratch: capacity consumed so far this recompute.
    alloc: f64,
    /// Scratch: unfrozen flows currently crossing this link.
    load: u32,
}

impl Link {
    pub fn new(capacity: f64) -> Link {
        Link { capacity, bytes_carried: 0.0, alloc: 0.0, load: 0 }
    }

    /// Mean utilization over `[0, elapsed_us]`.
    pub fn utilization(&self, elapsed_us: u64) -> f64 {
        if elapsed_us == 0 || self.capacity <= 0.0 {
            return 0.0;
        }
        self.bytes_carried * 1e6 / (elapsed_us as f64 * self.capacity)
    }

    fn headroom(&self) -> f64 {
        self.capacity - self.alloc
    }

    fn saturated(&self) -> bool {
        self.headroom() <= self.capacity * REL_EPS + ABS_EPS
    }
}

/// The (at most [`MAX_PATH_LINKS`]) link indices one flow crosses.
/// An empty path (loopback, `src == dst`) is unconstrained: the
/// allocator assigns it `f64::INFINITY` (zero transmission time).
#[derive(Clone, Copy, Debug, Default)]
pub struct FlowPath {
    links: [u32; MAX_PATH_LINKS],
    nlinks: u8,
}

impl FlowPath {
    pub fn push(&mut self, link: u32) {
        debug_assert!((self.nlinks as usize) < MAX_PATH_LINKS);
        self.links[self.nlinks as usize] = link;
        self.nlinks += 1;
    }

    pub fn is_empty(&self) -> bool {
        self.nlinks == 0
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.links[..self.nlinks as usize].iter().map(|&l| l as usize)
    }
}

/// Progressive-filling max-min allocation: assign `rates[i]` to flow
/// `i` of `flows`. `frozen` is caller-owned scratch (cleared here) so
/// the steady-state recompute allocates nothing.
///
/// Empty-path flows get `f64::INFINITY`; every other flow gets a
/// strictly positive rate as long as each link it crosses has positive
/// capacity.
pub fn fair_share(links: &mut [Link], flows: &[FlowPath], rates: &mut [f64], frozen: &mut Vec<bool>) {
    debug_assert_eq!(flows.len(), rates.len());
    for l in links.iter_mut() {
        l.alloc = 0.0;
        l.load = 0;
    }
    frozen.clear();
    frozen.resize(flows.len(), false);
    let mut unfrozen = 0usize;
    for (i, f) in flows.iter().enumerate() {
        if f.is_empty() {
            // Loopback: no shared medium, infinite rate.
            rates[i] = f64::INFINITY;
            frozen[i] = true;
            continue;
        }
        rates[i] = 0.0;
        unfrozen += 1;
        for li in f.iter() {
            links[li].load += 1;
        }
    }
    while unfrozen > 0 {
        // Uniform raise until the tightest loaded link fills.
        let mut theta = f64::INFINITY;
        for l in links.iter() {
            if l.load > 0 {
                theta = theta.min(l.headroom().max(0.0) / l.load as f64);
            }
        }
        if !theta.is_finite() {
            break;
        }
        for (r, fz) in rates.iter_mut().zip(frozen.iter()) {
            if !*fz {
                *r += theta;
            }
        }
        for l in links.iter_mut() {
            if l.load > 0 {
                l.alloc += theta * l.load as f64;
            }
        }
        // Freeze every flow crossing a now-saturated link; it stops
        // contending for the residual headroom (its links' loads drop,
        // its allocation stays).
        let mut froze_any = false;
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            if f.iter().any(|li| links[li].saturated()) {
                frozen[i] = true;
                froze_any = true;
                unfrozen -= 1;
                for li in f.iter() {
                    links[li].load -= 1;
                }
            }
        }
        if !froze_any {
            // Numerical guard: theta was finite but nothing saturated
            // (capacities within epsilon of each other). Rates are
            // already feasible; stop rather than loop.
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn share(links: &mut [Link], flows: &[FlowPath]) -> Vec<f64> {
        let mut rates = vec![0.0; flows.len()];
        let mut frozen = Vec::new();
        fair_share(links, flows, &mut rates, &mut frozen);
        rates
    }

    fn path(ls: &[u32]) -> FlowPath {
        let mut p = FlowPath::default();
        for &l in ls {
            p.push(l);
        }
        p
    }

    #[test]
    fn single_flow_gets_the_bottleneck_capacity() {
        let mut links = vec![Link::new(1e9), Link::new(2.5e8), Link::new(1e9)];
        let rates = share(&mut links, &[path(&[0, 1, 2])]);
        assert!((rates[0] - 2.5e8).abs() < 1.0, "rate {}", rates[0]);
    }

    #[test]
    fn two_equal_flows_split_a_link_in_half() {
        let mut links = vec![Link::new(1e9)];
        let rates = share(&mut links, &[path(&[0]), path(&[0])]);
        assert!((rates[0] - 5e8).abs() < 1.0);
        assert!((rates[1] - 5e8).abs() < 1.0);
    }

    #[test]
    fn bottlenecked_flow_frees_residual_for_the_other() {
        // Flow 0 crosses a narrow private link (100 MB/s) and the
        // shared link (1 GB/s); flow 1 crosses only the shared link.
        // Max-min: flow 0 capped at 100 MB/s, flow 1 takes the 900 MB/s
        // residual — not the naive 500/500 split.
        let mut links = vec![Link::new(1e8), Link::new(1e9)];
        let rates = share(&mut links, &[path(&[0, 1]), path(&[1])]);
        assert!((rates[0] - 1e8).abs() < 1.0, "capped flow got {}", rates[0]);
        assert!((rates[1] - 9e8).abs() < 1e3, "residual flow got {}", rates[1]);
    }

    #[test]
    fn loopback_flow_is_unconstrained() {
        let mut links = vec![Link::new(1e9)];
        let rates = share(&mut links, &[FlowPath::default(), path(&[0])]);
        assert!(rates[0].is_infinite());
        assert!((rates[1] - 1e9).abs() < 1.0);
    }

    #[test]
    fn max_min_properties_hold_on_random_topologies() {
        // Three invariants on random link sets and flow paths:
        //  1. conservation — per-link allocated rate <= capacity;
        //  2. bottleneck — every flow crosses at least one saturated
        //     link (otherwise its rate could rise: not max-min);
        //  3. optimality — a flow can only be "blocked" by a saturated
        //     link on which it has the (joint-)largest rate; raising it
        //     would necessarily shrink a smaller-or-equal flow.
        crate::util::prop::check(300, |rng| {
            let nlinks = 1 + rng.below(8) as usize;
            let mut links: Vec<Link> =
                (0..nlinks).map(|_| Link::new(1e6 + rng.below(1_000_000_000) as f64)).collect();
            let nflows = 1 + rng.below(12) as usize;
            let flows: Vec<FlowPath> = (0..nflows)
                .map(|_| {
                    let hops = 1 + rng.below(MAX_PATH_LINKS.min(nlinks) as u64) as usize;
                    let mut p = FlowPath::default();
                    let mut used = [false; 8];
                    for _ in 0..hops {
                        let l = rng.below(nlinks as u64) as usize;
                        if !used[l] {
                            used[l] = true;
                            p.push(l as u32);
                        }
                    }
                    p
                })
                .collect();
            let mut rates = vec![0.0; nflows];
            let mut frozen = Vec::new();
            fair_share(&mut links, &flows, &mut rates, &mut frozen);
            // 1. conservation + recompute link loads from scratch.
            let mut carried = vec![0.0f64; nlinks];
            for (i, f) in flows.iter().enumerate() {
                if rates[i] <= 0.0 {
                    return Err(format!("flow {i} got non-positive rate {}", rates[i]));
                }
                for li in f.iter() {
                    carried[li] += rates[i];
                }
            }
            for (li, &c) in carried.iter().enumerate() {
                if c > links[li].capacity * (1.0 + 1e-6) + 1.0 {
                    return Err(format!(
                        "link {li} oversubscribed: {c} > {}",
                        links[li].capacity
                    ));
                }
            }
            let tight =
                |li: usize| carried[li] >= links[li].capacity * (1.0 - 1e-6) - 1.0;
            for (i, f) in flows.iter().enumerate() {
                // 2. bottleneck saturation.
                if !f.iter().any(tight) {
                    return Err(format!("flow {i} has headroom on every link"));
                }
                // 3. max-min optimality: some saturated link where this
                // flow's rate is maximal among its sharers.
                let blocked = f.iter().any(|li| {
                    tight(li)
                        && flows.iter().enumerate().all(|(j, g)| {
                            !g.iter().any(|lj| lj == li)
                                || rates[j] <= rates[i] * (1.0 + 1e-6) + 1.0
                        })
                });
                if !blocked {
                    return Err(format!("flow {i} is not max-min blocked"));
                }
            }
            Ok(())
        });
    }
}
