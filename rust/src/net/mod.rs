//! Network substrate: fat-tree topology, NIC/link bandwidth accounting and
//! splitter-cable configurations.
//!
//! Two roles in the reproduction:
//!
//! * In the DES, each node's NIC directions are FIFO rate servers
//!   ([`nic::Nic`]); per-class byte counters produce the Fig-11a bandwidth
//!   series. The paper shows network utilization never exceeds ~6% of the
//!   100 Gbps links — our model confirms the same headroom, and it also
//!   models the purpose-built data center's 10/50 Gbps links where the
//!   margin shrinks.
//! * For the TCO study (§7), [`topology`] builds and validates fat-trees —
//!   the 1024-node three-level homogeneous tree of Table 3 and the
//!   splitter-cable two-level design of Figure 16 — counting switches,
//!   cables and ports, which feed the `tco` price book.

pub mod nic;
pub mod topology;

pub use nic::{Direction, Nic};
pub use topology::{FatTree, SplitterPlan};
