//! Network substrate: fat-tree topology, NIC/link bandwidth accounting,
//! splitter-cable configurations, and the contention-aware fabric.
//!
//! Three roles in the reproduction:
//!
//! * In the DES, each node's NIC directions are FIFO rate servers
//!   ([`nic::Nic`]); per-class byte counters produce the Fig-11a bandwidth
//!   series. The paper shows network utilization never exceeds ~6% of the
//!   100 Gbps links — our model confirms the same headroom, and it also
//!   models the purpose-built data center's 10/50 Gbps links where the
//!   margin shrinks.
//! * When a [`path::NetworkSpec`] is installed, every fabric hop becomes a
//!   transfer over concrete ToR/spine links whose capacity concurrent
//!   flows split max-min fairly ([`link`] + [`path`]) — the measured form
//!   of Fig-11's bandwidth wall: oversubscribed uplinks slow fetches,
//!   replication, and recovery down instead of merely being metered.
//! * For the TCO study (§7), [`topology`] builds and validates fat-trees —
//!   the 1024-node three-level homogeneous tree of Table 3 and the
//!   splitter-cable two-level design of Figure 16 — counting switches,
//!   cables and ports, which feed the `tco` price book.

pub mod link;
pub mod nic;
pub mod path;
pub mod topology;

pub use link::{FlowPath, Link};
pub use nic::{Direction, Nic};
pub use path::{NetworkSpec, PathNet, Placement, NO_NODE};
pub use topology::{FatTree, SplitterPlan};
