//! Per-node NIC model: full-duplex, each direction an independent FIFO rate
//! server, with per-class byte accounting for the Fig-11a breakdown
//! (producer read/write, consumer read/write, broker read/write).

use crate::sim::resource::FifoServer;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Into the node (receive).
    Rx,
    /// Out of the node (transmit).
    Tx,
}

/// A full-duplex NIC with byte accounting.
#[derive(Clone, Debug)]
pub struct Nic {
    /// Receive direction, directly drivable by the DES (the consumer
    /// fetch path submits response bytes here).
    pub rx: FifoServer,
    /// Transmit direction (the producer dispatch path serializes here).
    pub tx: FifoServer,
    bw: f64,
    /// One-way propagation + switching latency within the data center
    /// (fat-tree, a few switch hops).
    pub transit_us: u64,
}

impl Nic {
    pub fn new(bandwidth_bytes_per_sec: f64) -> Self {
        Nic {
            rx: FifoServer::new(bandwidth_bytes_per_sec, 0),
            tx: FifoServer::new(bandwidth_bytes_per_sec, 0),
            bw: bandwidth_bytes_per_sec,
            transit_us: crate::config::hardware::WIRE_TRANSIT_US,
        }
    }

    /// Submit a transfer in `dir` at `now`; returns the time the last byte
    /// has left (Tx) or arrived (Rx), including transit latency.
    pub fn transfer(&mut self, now: u64, dir: Direction, bytes: f64) -> u64 {
        let srv = match dir {
            Direction::Rx => &mut self.rx,
            Direction::Tx => &mut self.tx,
        };
        srv.submit(now, bytes) + self.transit_us
    }

    /// Utilization of a direction over `[0, now]` as a fraction of link
    /// rate (the Fig-11a y-axis).
    pub fn utilization(&self, now: u64, dir: Direction) -> f64 {
        match dir {
            Direction::Rx => self.rx.utilization(now),
            Direction::Tx => self.tx.utilization(now),
        }
    }

    /// Average achieved bandwidth in bytes/s over `[0, now]`.
    pub fn throughput(&self, now: u64, dir: Direction) -> f64 {
        match dir {
            Direction::Rx => self.rx.throughput(now),
            Direction::Tx => self.tx.throughput(now),
        }
    }

    pub fn bandwidth(&self) -> f64 {
        self.bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::gbps;

    #[test]
    fn full_duplex_independence() {
        let mut n = Nic::new(gbps(100));
        let rx_done = n.transfer(0, Direction::Rx, 12.5e9); // 1 second
        let tx_done = n.transfer(0, Direction::Tx, 12.5e9); // concurrent
        assert_eq!(rx_done, tx_done);
        assert!((rx_done as i64 - 1_000_030).abs() <= 1);
    }

    #[test]
    fn same_direction_serializes() {
        let mut n = Nic::new(gbps(100));
        let a = n.transfer(0, Direction::Tx, 12.5e9);
        let b = n.transfer(0, Direction::Tx, 12.5e9);
        assert!(b > a);
    }

    #[test]
    fn utilization_matches_fig11a_scale() {
        // 6 Gbps of traffic on a 100 Gbps NIC over 1 s = 6% (the paper's
        // peak broker network utilization at 8x).
        let mut n = Nic::new(gbps(100));
        for i in 0..100 {
            n.transfer(i * 10_000, Direction::Rx, 7.5e6);
        }
        let u = n.utilization(1_000_000, Direction::Rx);
        assert!((u - 0.06).abs() < 0.005, "u={u}");
    }

    #[test]
    fn transit_latency_applied() {
        let mut n = Nic::new(gbps(100));
        let done = n.transfer(0, Direction::Tx, 12_500.0); // 1 us wire time
        assert_eq!(done, 1 + 30);
    }
}
