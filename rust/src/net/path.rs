//! Contention-aware path transfers on a two-tier ToR/spine topology.
//!
//! [`PathNet`] places every simulation node (brokers first, then client
//! units in build order) into racks and routes each node pair over
//! concrete directed [`Link`]s:
//!
//! * per-node **access links** (up + down, capacity = the node's line
//!   rate [`NetworkSpec::link_bw`]) — the ToR edge ports;
//! * per-rack **uplink/downlink** into the spine, sized at
//!   `rack_size x link_bw / oversub` — the oversubscription knob the
//!   paper's Fig-11 bandwidth wall turns on. The spine itself is
//!   non-blocking (as in the Table-3 fat tree), so cross-rack paths are
//!   4 hops: src access up, src-rack uplink, dst-rack downlink, dst
//!   access down; intra-rack paths use only the two access links.
//!
//! Concurrent transfers split every shared link max-min fairly
//! ([`crate::net::link::fair_share`]), recomputed at **entry/exit
//! epochs**: whenever a transfer starts or completes, all active
//! transfers' progress is advanced to `now`, rates are re-solved, and
//! any asynchronous transfer whose rate changed gets its completion
//! re-estimated — the old completion event is invalidated by a
//! generation bump (the caller carries `(xfer, gen)` in its event and
//! [`PathNet::complete`] ignores stale pairs). Synchronous transfers
//! (fetch responses, recovery chunks — paths that must return a finish
//! time immediately) lock their estimate at entry using their max-min
//! share at that instant, and occupy their links until a caller-
//! scheduled release event fires.
//!
//! Everything is deterministic: index-ordered `f64` arithmetic, no RNG,
//! no wall clock — `jobs=N` sweeps stay byte-identical.

use crate::net::link::{fair_share, FlowPath, Link};
use crate::net::topology::FatTree;

/// Sentinel node id: "this endpoint is not placed on the topology".
/// Transfers involving an unplaced endpoint fall back to the caller's
/// fixed-latency path.
pub const NO_NODE: u32 = u32::MAX;

/// Completion-estimate cap for a stalled transfer (a zero-capacity
/// link); far beyond any horizon, safely below `u64::MAX` arithmetic.
const STALLED_US: u64 = 1 << 50;

/// Where client nodes land relative to broker nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Clients striped across the same racks as the brokers (rack =
    /// `node % n_racks`): replication, produce, and fetch traffic all
    /// compete on the shared oversubscribed uplinks.
    CoLocated,
    /// Brokers packed into their own rack(s) (rack = `node /
    /// rack_size`): with `rack_size >= brokers`, replication stays
    /// intra-rack on dedicated access links — the placement mitigation
    /// arm of the net-path experiment.
    BrokerIsolated,
}

/// Two-tier topology + fairness parameters (the `with_network` knobs).
#[derive(Clone, Copy, Debug)]
pub struct NetworkSpec {
    /// ToR uplink oversubscription factor: rack uplink capacity =
    /// `rack_size * link_bw / oversub`. 1.0 is non-blocking.
    pub oversub: f64,
    /// Per-node access-link line rate, bytes/sec each direction.
    pub link_bw: f64,
    /// Nodes per rack (edge-switch down-ports).
    pub rack_size: usize,
    pub placement: Placement,
}

impl NetworkSpec {
    pub fn new(oversub: f64, link_bw: f64) -> NetworkSpec {
        NetworkSpec { oversub, link_bw, rack_size: 8, placement: Placement::CoLocated }
    }

    /// Derive rack size from a BOM fat tree: an edge switch dedicates
    /// half its ports downward, so `ports_per_switch / 2` nodes share
    /// one ToR (Table-3 layout).
    pub fn from_fat_tree(topo: &FatTree, oversub: f64, link_bw: f64) -> NetworkSpec {
        NetworkSpec {
            oversub,
            link_bw,
            rack_size: (topo.ports_per_switch / 2).max(1),
            placement: Placement::CoLocated,
        }
    }

    pub fn with_rack_size(mut self, rack_size: usize) -> NetworkSpec {
        self.rack_size = rack_size.max(1);
        self
    }

    pub fn with_placement(mut self, placement: Placement) -> NetworkSpec {
        self.placement = placement;
        self
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum XferState {
    Free,
    /// Allocated, path resolved, not yet on the links (its start event
    /// is in flight).
    Prepared,
    Active,
}

/// One transfer: remaining bytes, current max-min rate, and the payload
/// event the caller wants back at completion.
#[derive(Clone, Copy, Debug)]
struct Transfer<P> {
    remaining: f64,
    /// Bytes/sec under the current allocation (`f64::INFINITY` for
    /// loopback paths).
    rate: f64,
    /// Last epoch this transfer's progress was integrated to.
    last_us: u64,
    /// Staleness generation: bumped whenever the completion estimate
    /// is invalidated (rate change) or the slot is recycled.
    gen: u32,
    /// Propagation latency the caller adds after the last byte lands.
    prop_us: u64,
    payload: Option<P>,
    path: FlowPath,
    state: XferState,
    /// Locked-estimate transfer: completion fixed at entry, never
    /// re-estimated (fetch/recovery legs that must return a time
    /// synchronously).
    sync: bool,
}

/// The contention-aware fabric: racks, links, and in-flight transfers.
#[derive(Debug)]
pub struct PathNet<P> {
    spec: NetworkSpec,
    /// Node -> rack.
    racks: Vec<u32>,
    /// `[2 * node]` up / `[2 * node + 1]` down access links, then per
    /// rack uplink/downlink starting at `rack_base`.
    links: Vec<Link>,
    rack_base: usize,
    transfers: Vec<Transfer<P>>,
    free: Vec<u32>,
    /// Active transfer ids, insertion-ordered (deterministic).
    active: Vec<u32>,
    /// Epoch recompute scratch (no steady-state allocation).
    paths_scratch: Vec<FlowPath>,
    rates_scratch: Vec<f64>,
    frozen_scratch: Vec<bool>,
    /// Re-estimations the last epoch produced: `(done_us, xfer, gen)`
    /// for the caller to schedule as fresh completion events.
    pub resched: Vec<(u64, u32, u32)>,
    /// Transfers that entered at less than their solo (uncontended)
    /// bottleneck rate — the headline contention counter.
    pub contended_transfers: u64,
}

impl<P: Copy> PathNet<P> {
    /// Build the topology for `brokers + clients` nodes. Brokers are
    /// nodes `0..brokers`; client units follow in world build order.
    pub fn new(spec: NetworkSpec, brokers: usize, clients: usize) -> PathNet<P> {
        let nodes = (brokers + clients).max(1);
        let n_racks = nodes.div_ceil(spec.rack_size).max(1);
        let racks: Vec<u32> = (0..nodes)
            .map(|node| match spec.placement {
                Placement::CoLocated => (node % n_racks) as u32,
                Placement::BrokerIsolated => (node / spec.rack_size) as u32,
            })
            .collect();
        let rack_base = 2 * nodes;
        let uplink_bw = spec.rack_size as f64 * spec.link_bw / spec.oversub.max(1e-9);
        let mut links = Vec::with_capacity(rack_base + 2 * n_racks);
        for _ in 0..nodes {
            links.push(Link::new(spec.link_bw)); // up
            links.push(Link::new(spec.link_bw)); // down
        }
        for _ in 0..n_racks {
            links.push(Link::new(uplink_bw)); // rack uplink
            links.push(Link::new(uplink_bw)); // rack downlink
        }
        PathNet {
            spec,
            racks,
            links,
            rack_base,
            transfers: Vec::new(),
            free: Vec::new(),
            active: Vec::new(),
            paths_scratch: Vec::new(),
            rates_scratch: Vec::new(),
            frozen_scratch: Vec::new(),
            resched: Vec::new(),
            contended_transfers: 0,
        }
    }

    pub fn spec(&self) -> &NetworkSpec {
        &self.spec
    }

    pub fn rack_of(&self, node: u32) -> u32 {
        self.racks[node as usize]
    }

    fn route(&self, src: u32, dst: u32) -> FlowPath {
        let mut p = FlowPath::default();
        if src == dst {
            return p; // loopback: no shared medium
        }
        p.push(2 * src);
        let (rs, rd) = (self.racks[src as usize], self.racks[dst as usize]);
        if rs != rd {
            p.push((self.rack_base + 2 * rs as usize) as u32);
            p.push((self.rack_base + 2 * rd as usize + 1) as u32);
        }
        p.push(2 * dst + 1);
        p
    }

    /// Solo bottleneck rate of a path (min link capacity), used to
    /// detect contention at entry.
    fn solo_rate(&self, path: &FlowPath) -> f64 {
        path.iter().map(|li| self.links[li].capacity).fold(f64::INFINITY, f64::min)
    }

    fn alloc_slot(&mut self) -> u32 {
        match self.free.pop() {
            Some(x) => x,
            None => {
                self.transfers.push(Transfer {
                    remaining: 0.0,
                    rate: 0.0,
                    last_us: 0,
                    gen: 0,
                    prop_us: 0,
                    payload: None,
                    path: FlowPath::default(),
                    state: XferState::Free,
                    sync: false,
                });
                (self.transfers.len() - 1) as u32
            }
        }
    }

    /// Allocate a transfer whose start event is still in flight (the
    /// sender is serializing). [`PathNet::start`] puts it on the links.
    pub fn prepare(&mut self, src: u32, dst: u32, bytes: f64, prop_us: u64, payload: Option<P>) -> u32 {
        let path = self.route(src, dst);
        let x = self.alloc_slot();
        let t = &mut self.transfers[x as usize];
        debug_assert_eq!(t.state, XferState::Free);
        t.remaining = bytes.max(0.0);
        t.rate = 0.0;
        t.prop_us = prop_us;
        t.payload = payload;
        t.path = path;
        t.state = XferState::Prepared;
        t.sync = false;
        x
    }

    /// Integrate all active transfers' progress up to `now`.
    fn advance(&mut self, now: u64) {
        for &xi in &self.active {
            let t = &mut self.transfers[xi as usize];
            let elapsed = now.saturating_sub(t.last_us);
            if elapsed > 0 && t.rate.is_finite() {
                t.remaining = (t.remaining - t.rate * elapsed as f64 / 1e6).max(0.0);
            }
            t.last_us = now;
        }
    }

    fn duration_us(remaining: f64, rate: f64) -> u64 {
        if remaining <= 0.0 || rate.is_infinite() {
            return 0;
        }
        if rate <= 0.0 {
            return STALLED_US;
        }
        let us = (remaining / rate * 1e6).ceil();
        if us >= STALLED_US as f64 { STALLED_US } else { us as u64 }
    }

    /// Re-solve the max-min allocation at `now`. Every async transfer
    /// except `fresh` whose rate changed is re-estimated: its gen bumps
    /// (invalidating the completion event in the queue) and a
    /// `(done, xfer, gen)` entry is pushed to [`PathNet::resched`].
    fn recompute(&mut self, now: u64, fresh: Option<u32>) {
        let n = self.active.len();
        self.paths_scratch.clear();
        self.paths_scratch.extend(self.active.iter().map(|&xi| self.transfers[xi as usize].path));
        self.rates_scratch.clear();
        self.rates_scratch.resize(n, 0.0);
        fair_share(
            &mut self.links,
            &self.paths_scratch,
            &mut self.rates_scratch,
            &mut self.frozen_scratch,
        );
        for k in 0..n {
            let xi = self.active[k];
            let new_rate = self.rates_scratch[k];
            let t = &mut self.transfers[xi as usize];
            if t.rate == new_rate {
                continue;
            }
            t.rate = new_rate;
            if t.sync || Some(xi) == fresh {
                // Locked estimates never move; the fresh transfer's
                // first estimate is the caller's return value.
                continue;
            }
            t.gen = t.gen.wrapping_add(1);
            let done = now + Self::duration_us(t.remaining, t.rate);
            self.resched.push((done, xi, t.gen));
        }
    }

    /// Charge the utilization meters and the contention counter for a
    /// transfer entering the links.
    fn account_entry(&mut self, xi: u32) {
        let t = self.transfers[xi as usize];
        let solo = self.solo_rate(&t.path);
        for li in t.path.iter() {
            self.links[li].bytes_carried += t.remaining;
        }
        if t.rate < solo * (1.0 - 1e-9) {
            self.contended_transfers += 1;
        }
    }

    /// Activate a prepared transfer at `now` (its serialization
    /// finished). Returns `(done_us, gen)` — the caller schedules its
    /// completion event at `done_us` carrying `(xfer, gen)`, then
    /// drains [`PathNet::resched`] for displaced neighbors.
    pub fn start(&mut self, now: u64, xfer: u32) -> (u64, u32) {
        debug_assert_eq!(self.transfers[xfer as usize].state, XferState::Prepared);
        self.advance(now);
        {
            let t = &mut self.transfers[xfer as usize];
            t.state = XferState::Active;
            t.last_us = now;
            t.rate = 0.0;
        }
        self.active.push(xfer);
        self.recompute(now, Some(xfer));
        self.account_entry(xfer);
        let t = &self.transfers[xfer as usize];
        (now + Self::duration_us(t.remaining, t.rate), t.gen)
    }

    /// Start a locked-estimate transfer at `now`: the finish time is
    /// computed from the max-min share at entry and never revised, so
    /// call sites that must return a completion time synchronously
    /// (fetch responses, recovery chunks) can use it — the transfer
    /// still loads its links until the caller's release event calls
    /// [`PathNet::complete`] with the returned `(xfer, gen)`.
    pub fn transfer_sync(&mut self, now: u64, src: u32, dst: u32, bytes: f64) -> (u32, u32, u64) {
        let x = self.prepare(src, dst, bytes, 0, None);
        self.transfers[x as usize].sync = true;
        let (done, gen) = self.start(now, x);
        (x, gen, done)
    }

    /// A completion event fired. Stale `(xfer, gen)` pairs (the rate
    /// changed since, or the slot was recycled) return `None`; a live
    /// pair removes the transfer, re-solves the allocation, and hands
    /// back `(prop_us, payload)` for the caller to deliver.
    pub fn complete(&mut self, now: u64, xfer: u32, gen: u32) -> Option<(u64, Option<P>)> {
        let t = &self.transfers[xfer as usize];
        if t.state != XferState::Active || t.gen != gen {
            return None;
        }
        let prop = t.prop_us;
        let payload = t.payload;
        self.advance(now);
        let pos = self.active.iter().position(|&x| x == xfer).expect("active transfer listed");
        self.active.swap_remove(pos);
        {
            let t = &mut self.transfers[xfer as usize];
            t.state = XferState::Free;
            t.gen = t.gen.wrapping_add(1);
            t.payload = None;
        }
        self.free.push(xfer);
        self.recompute(now, None);
        Some((prop, payload))
    }

    pub fn active_transfers(&self) -> usize {
        self.active.len()
    }

    /// Peak mean utilization across the rack uplinks/downlinks over
    /// `[0, elapsed_us]` — the oversubscription pressure gauge.
    pub fn max_uplink_util(&self, elapsed_us: u64) -> f64 {
        self.links[self.rack_base..]
            .iter()
            .map(|l| l.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    /// Peak mean utilization across the per-node access links.
    pub fn max_access_util(&self, elapsed_us: u64) -> f64 {
        self.links[..self.rack_base]
            .iter()
            .map(|l| l.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(oversub: f64, placement: Placement) -> PathNet<u32> {
        // 2 brokers + 6 clients, racks of 4.
        let spec = NetworkSpec::new(oversub, 1e9).with_rack_size(4).with_placement(placement);
        PathNet::new(spec, 2, 6)
    }

    #[test]
    fn single_transfer_matches_the_closed_form() {
        // 1 GB across 1 GB/s access links, no contention: exactly 1 s.
        let mut n = net(1.0, Placement::CoLocated);
        let x = n.prepare(2, 0, 1e9, 30, Some(7));
        let (done, gen) = n.start(0, x);
        assert_eq!(done, 1_000_000);
        assert!(n.resched.is_empty(), "no neighbors to displace");
        let (prop, payload) = n.complete(done, x, gen).expect("live completion");
        assert_eq!(prop, 30);
        assert_eq!(payload, Some(7));
        assert_eq!(n.contended_transfers, 0);
    }

    #[test]
    fn two_transfers_into_one_node_each_get_half() {
        // Both target node 0's down link: rates halve, both finish at
        // 2 s; the second entry displaces the first's estimate.
        let mut n = net(1.0, Placement::CoLocated);
        let a = n.prepare(2, 0, 1e9, 0, Some(1));
        let (done_a, _gen_a) = n.start(0, a);
        assert_eq!(done_a, 1_000_000);
        let b = n.prepare(3, 0, 1e9, 0, Some(2));
        let (done_b, gen_b) = n.start(0, b);
        assert_eq!(done_b, 2_000_000);
        // The first transfer was re-estimated to 2 s as well.
        assert_eq!(n.resched.len(), 1);
        let (re_done, re_x, re_gen) = n.resched[0];
        assert_eq!(re_x, a);
        assert_eq!(re_done, 2_000_000);
        // Its original completion event is now stale.
        assert!(n.complete(1_000_000, a, re_gen.wrapping_sub(1)).is_none());
        n.resched.clear();
        // B completes at 2 s; that exit epoch re-rates A (0 bytes left,
        // rate doubles), bumping its gen and rescheduling it at the
        // same instant — the event-driven self-correction the fabric
        // relies on: the displaced event is skipped, the fresh one
        // completes the transfer.
        assert!(n.complete(2_000_000, b, gen_b).is_some());
        assert!(n.complete(2_000_000, a, re_gen).is_none(), "displaced again by B's exit");
        let (re_done2, _, re_gen2) =
            *n.resched.iter().find(|(_, x, _)| *x == a).expect("A rescheduled at B's exit");
        assert_eq!(re_done2, 2_000_000);
        assert!(n.complete(re_done2, a, re_gen2).is_some());
        assert_eq!(n.contended_transfers, 1, "only the second entered contended");
    }

    #[test]
    fn exit_epoch_speeds_up_the_survivor() {
        // A finishes at 1 s; B (same bottleneck) then speeds up from
        // half rate to full and its completion is re-estimated earlier.
        let mut n = net(1.0, Placement::CoLocated);
        let a = n.prepare(2, 0, 0.5e9, 0, None);
        let (_, gen_a) = n.start(0, a);
        let b = n.prepare(3, 0, 1e9, 0, None);
        let (done_b0, _) = n.start(0, b);
        assert_eq!(done_b0, 2_000_000, "B at half rate initially");
        // B's entry (same instant) displaced A's 0.5 s solo estimate:
        // the original gen is stale; the resched entry carries the
        // live one — 0.5 GB at the halved 0.5 GB/s rate lands at 1 s.
        assert!(n.complete(1_000_000, a, gen_a).is_none(), "stale gen ignored");
        let re = n
            .resched
            .iter()
            .find(|(_, x, _)| *x == a)
            .map(|&(d, _, g)| (d, g));
        let (done_a, gen_a2) = re.expect("A re-estimated after B joined");
        assert_eq!(done_a, 1_000_000);
        n.resched.clear();
        assert!(n.complete(done_a, a, gen_a2).is_some());
        // B re-estimated: 0.5 GB left at full rate -> 1.5 s total.
        let (done_b1, _, _) = *n.resched.iter().find(|(_, x, _)| *x == b).expect("B resched");
        assert_eq!(done_b1, 1_500_000);
    }

    #[test]
    fn oversubscribed_uplink_throttles_cross_rack_transfers() {
        // CoLocated, racks of 4, 8 nodes -> 2 racks; node i rack i % 2.
        // Four cross-rack transfers from rack 0 to rack 1 share rack
        // 0's uplink: at oversub 8 the uplink is 4 * 1 GB/s / 8 =
        // 0.5 GB/s, so each flow gets 0.125 GB/s instead of 1 GB/s.
        let mut n = net(8.0, Placement::CoLocated);
        // Distinct sources in rack 0 (nodes 0,2,4,6), distinct
        // destinations in rack 1 (nodes 1,3,5,7).
        let mut last_done = 0;
        for (s, d) in [(0u32, 1u32), (2, 3), (4, 5), (6, 7)] {
            let x = n.prepare(s, d, 1e9, 0, None);
            let (done, _) = n.start(0, x);
            last_done = done;
        }
        assert_eq!(last_done, 8_000_000, "4 flows on a 0.5 GB/s uplink");
        assert_eq!(n.contended_transfers, 3, "all but the first entered contended");
        assert!(n.max_uplink_util(8_000_000) > 0.9);
    }

    #[test]
    fn broker_isolated_keeps_broker_traffic_off_the_uplinks() {
        // BrokerIsolated with rack_size 4 >= 2 brokers: nodes 0,1 (the
        // brokers) share rack 0, so replication (0 -> 1) is intra-rack.
        let mut n = net(8.0, Placement::BrokerIsolated);
        assert_eq!(n.rack_of(0), n.rack_of(1));
        let x = n.prepare(0, 1, 1e9, 0, None);
        let (done, _) = n.start(0, x);
        assert_eq!(done, 1_000_000, "full access rate, no uplink crossed");
        assert_eq!(n.max_uplink_util(1_000_000), 0.0);
    }

    #[test]
    fn sync_transfer_locks_its_estimate() {
        let mut n = net(1.0, Placement::CoLocated);
        let (x, gen, done) = n.transfer_sync(0, 2, 0, 1e9);
        assert_eq!(done, 1_000_000);
        // A competitor halves the sync flow's rate, but no resched
        // entry is produced for it (locked estimate)...
        let b = n.prepare(3, 0, 1e9, 0, None);
        n.start(0, b);
        assert!(!n.resched.iter().any(|&(_, xi, _)| xi == x));
        // ...and its release at the locked time still completes it.
        assert!(n.complete(done, x, gen).is_some());
    }

    #[test]
    fn slot_recycling_invalidates_stale_completions() {
        let mut n = net(1.0, Placement::CoLocated);
        let a = n.prepare(2, 0, 1e6, 0, Some(1));
        let (done_a, gen_a) = n.start(0, a);
        assert!(n.complete(done_a, a, gen_a).is_some());
        // Slot reused by a fresh transfer: the old (xfer, gen) pair
        // must not complete it.
        let b = n.prepare(3, 1, 1e9, 0, Some(2));
        assert_eq!(a, b, "slot recycled");
        let (_, gen_b) = n.start(done_a, b);
        assert!(n.complete(done_a, b, gen_a).is_none());
        assert_ne!(gen_a, gen_b);
    }

    #[test]
    fn loopback_transfer_is_instant() {
        let mut n = net(4.0, Placement::CoLocated);
        let (_, _, done) = n.transfer_sync(5, 3, 3, 1e12);
        assert_eq!(done, 5);
    }
}
