//! Fat-tree topology designer.
//!
//! Produces switch/cable bills-of-materials for the two data centers the
//! paper costs out:
//!
//! * [`FatTree::three_level`] — the homogeneous design (Table 3): a
//!   three-level non-blocking fat-tree of 32-port 100 GbE switches for 1024
//!   nodes → 160 switches, 3072 cables.
//! * [`SplitterPlan::purpose_built`] — the Figure-16 design: brokers share
//!   100 GbE ports via 2×50 G splitters; producer/consumer nodes hang off
//!   40 GbE switches via 4×10 G splitters; a two-level 100 GbE core ties it
//!   together → 28 100 G switches, 14 40 G switches and the Table-4 cable
//!   counts.

/// Bill of materials for a three-level fat tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FatTree {
    pub nodes: usize,
    pub ports_per_switch: usize,
    pub edge_switches: usize,
    pub agg_switches: usize,
    pub core_switches: usize,
    /// Node-to-edge cables.
    pub node_cables: usize,
    /// Switch-to-switch cables (edge-agg + agg-core).
    pub fabric_cables: usize,
}

impl FatTree {
    /// Non-blocking three-level fat tree: every switch uses half its ports
    /// downward and half upward (except core, all downward).
    pub fn three_level(nodes: usize, ports_per_switch: usize) -> FatTree {
        assert!(ports_per_switch >= 2 && ports_per_switch % 2 == 0);
        let half = ports_per_switch / 2;
        let edge = nodes.div_ceil(half);
        let agg = edge; // one agg per edge in this balanced layout
        let agg_uplinks = agg * half;
        let core = agg_uplinks.div_ceil(ports_per_switch);
        FatTree {
            nodes,
            ports_per_switch,
            edge_switches: edge,
            agg_switches: agg,
            core_switches: core,
            node_cables: nodes,
            fabric_cables: edge * half + agg * half,
        }
    }

    pub fn total_switches(&self) -> usize {
        self.edge_switches + self.agg_switches + self.core_switches
    }

    pub fn total_cables(&self) -> usize {
        self.node_cables + self.fabric_cables
    }

    /// Non-blocking check: aggregate uplink capacity at each level covers
    /// the downlink capacity.
    pub fn is_nonblocking(&self) -> bool {
        let half = self.ports_per_switch / 2;
        self.edge_switches * half >= self.nodes
            && self.core_switches * self.ports_per_switch >= self.agg_switches * half
    }
}

/// Bill of materials for the purpose-built (Fig 16) network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SplitterPlan {
    pub broker_nodes: usize,
    pub compute_nodes: usize,
    /// 100 GbE switches (edge + core).
    pub switches_100g: usize,
    pub edge_100g: usize,
    pub core_100g: usize,
    /// 40 GbE switches fronting the compute nodes.
    pub switches_40g: usize,
    /// 100 G → 2×50 G copper splitters (brokers, two per cable).
    pub copper_splitters_50g: usize,
    /// 40 G → 4×10 G copper splitters (compute, four per cable).
    pub copper_splitters_10g: usize,
    /// 100 G → 2×50 G optical splitters (feeding 40 G switches).
    pub optical_splitters_50g: usize,
    /// 100 G optical interconnects (edge-core fabric).
    pub optical_interconnects: usize,
}

impl SplitterPlan {
    /// Figure-16 design rules:
    /// * two brokers share one 100 G edge port via a 2×50 G copper splitter;
    /// * four compute nodes share one 40 G switch port via a 4×10 G copper
    ///   splitter; a 40 G switch dedicates 16 ports downward;
    /// * each pair of 40 G switches is fed from 100 G edge ports through
    ///   2×50 G optical splitters (full 800 Gbps feed per switch);
    /// * a two-level 100 GbE fat tree (16 uplinks per edge switch, one core
    ///   port per edge switch) carries the fabric.
    pub fn purpose_built(broker_nodes: usize, compute_nodes: usize) -> SplitterPlan {
        let copper_splitters_50g = broker_nodes.div_ceil(2);
        let copper_splitters_10g = compute_nodes.div_ceil(4);
        let switches_40g = copper_splitters_10g.div_ceil(16);
        let optical_splitters_50g = switches_40g.div_ceil(2);

        // 100G edge layer: 16 down-ports per edge switch.
        let edge_for_brokers = copper_splitters_50g.div_ceil(16);
        let edge_for_40g = switches_40g.div_ceil(2);
        let edge_100g = edge_for_brokers + edge_for_40g;
        // Two-level fat tree: each edge switch runs 16 uplinks, one to each
        // of 16 core switches.
        let uplinks_per_edge = 16;
        let core_100g = uplinks_per_edge;
        let optical_interconnects = edge_100g * uplinks_per_edge;

        SplitterPlan {
            broker_nodes,
            compute_nodes,
            switches_100g: edge_100g + core_100g,
            edge_100g,
            core_100g,
            switches_40g,
            copper_splitters_50g,
            copper_splitters_10g,
            optical_splitters_50g,
            optical_interconnects,
        }
    }

    /// Bandwidth delivered to each node class (bytes/s), for validating the
    /// design against the application's measured needs (§7.2: producers and
    /// consumers need ~4 Gbps, brokers ~24 Gbps).
    pub fn broker_bw(&self) -> f64 {
        crate::util::units::gbps(50)
    }

    pub fn compute_bw(&self) -> f64 {
        crate::util::units::gbps(10)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_homogeneous_tree() {
        // "The nodes are connected in a three-level fat-tree topology using
        //  32-port Mellanox Ethernet switches": 1024 nodes -> 160 switches,
        //  3072 cables (Table 3 quantities).
        let t = FatTree::three_level(1024, 32);
        assert_eq!(t.edge_switches, 64);
        assert_eq!(t.agg_switches, 64);
        assert_eq!(t.core_switches, 32);
        assert_eq!(t.total_switches(), 160);
        assert_eq!(t.total_cables(), 3072);
        assert!(t.is_nonblocking());
    }

    #[test]
    fn small_tree_sane() {
        let t = FatTree::three_level(40, 32);
        assert!(t.total_switches() >= 3);
        assert!(t.is_nonblocking());
        assert_eq!(t.node_cables, 40);
    }

    #[test]
    fn table4_purpose_built_counts() {
        // Table 4 quantities: 157 brokers, 867 compute ->
        // 28x 100G switches, 14x 40G switches, 79 copper 2x50G, 217 copper
        // 4x10G, 7 optical 2x50G, 192 optical interconnects.
        let p = SplitterPlan::purpose_built(157, 867);
        assert_eq!(p.copper_splitters_50g, 79);
        assert_eq!(p.copper_splitters_10g, 217);
        assert_eq!(p.switches_40g, 14);
        assert_eq!(p.optical_splitters_50g, 7);
        assert_eq!(p.edge_100g, 12);
        assert_eq!(p.core_100g, 16);
        assert_eq!(p.switches_100g, 28);
        assert_eq!(p.optical_interconnects, 192);
    }

    #[test]
    fn purpose_built_bandwidth_covers_measured_needs() {
        let p = SplitterPlan::purpose_built(157, 867);
        // §7.2: broker needs ~24 Gbps, compute ~4 Gbps; the design doubles
        // both (50 and 10 Gbps).
        assert!(p.broker_bw() >= 2.0 * crate::util::units::gbps(24));
        assert!(p.compute_bw() >= 2.0 * crate::util::units::gbps(4));
    }

    #[test]
    fn scaling_monotone_property() {
        crate::util::prop::check(100, |rng| {
            let n1 = 1 + rng.below(2000) as usize;
            let n2 = n1 + 1 + rng.below(500) as usize;
            let t1 = FatTree::three_level(n1, 32);
            let t2 = FatTree::three_level(n2, 32);
            crate::util::prop::assert_holds(
                t2.total_switches() >= t1.total_switches()
                    && t2.total_cables() > t1.total_cables()
                    && t1.is_nonblocking()
                    && t2.is_nonblocking(),
                "fat tree scales monotonically and stays non-blocking",
            )
        });
    }
}
