//! Cascading broker failure: a second kill while the first victim is
//! still re-replicating.
//!
//! PR 7's failover scenario kills one broker and measures the recovery;
//! its open question — does recovery bandwidth self-throttle or amplify
//! the overload? — gets sharp exactly when the failure *cascades*: the
//! cluster loses a second broker while the first is still catching up,
//! so the ISR collapses below quorum and every produce is refused at
//! admission. This module packages that schedule on the same 3-tenant
//! registry as [`failover`](crate::pipeline::failover), crossed with the
//! two resilience levers this PR adds:
//!
//! * **Client retries** ([`RetryPolicy`]): with retries off, the outage
//!   converts offered records into final rejections — measured loss.
//!   With retries on, clients buffer and re-offer through the outage,
//!   converting that loss into bounded tail-latency inflation (and
//!   `client_dropped` once the retry buffer overflows).
//! * **Election policy**
//!   ([`ElectionPolicy`](crate::pipeline::fabric::ElectionPolicy)):
//!   under `Clean`, the double kill leaves the partitions leaderless
//!   until a victim restarts — a measured availability gap. Under
//!   `Unclean`, the still-catching-up first victim is elected leader
//!   and its missing replay window is discarded as
//!   `unclean_lost_bytes` — data loss as a measured policy choice.
//!
//! The schedule: kill [`FIRST_VICTIM`] (broker 1), restart it, and then
//! — [`CascadeSpec::kill_gap_us`] into its catch-up — kill *both*
//! surviving brokers (a correlated rack/power event), restarting them
//! [`CascadeSpec::outage_us`] later. The gap controls how far broker
//! 1's catch-up has progressed when it suddenly becomes the only
//! survivor, which is exactly the unclean-election divergence:
//! `unclean_lost_bytes` shrinks monotonically as the gap grows.
//! `experiments::cascade` sweeps gap × retry × election
//! (`aitax experiment cascade`); `tests/resilience_differential.rs`
//! pins the extended conservation identity through the double kill.
//!
//! [`RetryPolicy`]: crate::pipeline::dc::RetryPolicy

use crate::pipeline::catchup::{self, CatchupSpec};
use crate::pipeline::dc::RetryPolicy;
use crate::pipeline::fabric::{ElectionPolicy, FaultPlan};
use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantReport, MultiTenantSim};
use crate::util::units::SEC;

/// The first broker killed (same victim as the failover scenario): its
/// catch-up is what the cascading second kill interrupts.
pub const FIRST_VICTIM: u32 = 1;

/// How long past the second kill the observation window stays open —
/// wide enough to cover the correlated outage, the restarts, and the
/// retry-drain period where buffered records finally commit (the tail
/// inflation the retry arm is supposed to show).
pub const OBSERVE_TAIL_US: u64 = 6 * SEC;

/// One cascading-failure scenario point.
#[derive(Clone, Copy, Debug)]
pub struct CascadeSpec {
    /// Virtual instant the first victim (broker 1) dies.
    pub first_kill_at_us: u64,
    /// Virtual instant it comes back and starts replaying its backlog.
    pub first_restart_at_us: u64,
    /// How far into that catch-up the correlated second failure lands:
    /// brokers 0 and 2 both die at `first_restart_at_us + kill_gap_us`,
    /// leaving the still-out-of-sync broker 1 as the only survivor.
    pub kill_gap_us: u64,
    /// How long the correlated outage lasts before brokers 0 and 2
    /// restart.
    pub outage_us: u64,
    /// Client resilience arm: `None` is the PR 7 reject-is-loss client;
    /// `Some` arms every tenant's producers with the policy.
    pub retry: Option<RetryPolicy>,
    /// Leader-election arm for the whole-ISR-dead moment.
    pub election: ElectionPolicy,
    /// `true`: per-class GPS spindle scheduler; `false`: seed FIFO.
    pub classed: bool,
    /// Re-replication pacing, bytes/sec per recovering broker.
    pub recovery_bytes_per_sec: f64,
    /// Per-broker page-cache capacity (bytes) for the measured read
    /// path.
    pub cache_bytes: f64,
}

impl CascadeSpec {
    /// The canonical retry arm used by the experiment sweep: enough
    /// attempts and backoff headroom to ride out the correlated outage,
    /// with a buffer small enough that a long outage visibly overflows
    /// into `client_dropped`.
    pub fn default_retry() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 6,
            base_backoff_us: 100_000,
            max_backoff_us: 800_000,
            request_timeout_us: 1_000_000,
            buffer_bytes: 512e6,
        }
    }

    /// Virtual instant the correlated second failure hits.
    pub fn second_kill_at_us(&self) -> u64 {
        self.first_restart_at_us + self.kill_gap_us
    }

    /// Virtual instant brokers 0 and 2 come back.
    pub fn second_restart_at_us(&self) -> u64 {
        self.second_kill_at_us() + self.outage_us
    }

    /// The tail-observation window: request creations in
    /// `[second kill, second kill + OBSERVE_TAIL_US]` feed the windowed
    /// p99. Unlike the failover sweep this window *opens at the kill*:
    /// the outage itself — and what each resilience arm turns it into
    /// (loss, retry-delayed commits, or unclean continuation) — is the
    /// measurement, not a nuisance transient.
    pub fn observe_window(&self) -> (u64, u64) {
        let k2 = self.second_kill_at_us();
        (k2, k2 + OBSERVE_TAIL_US)
    }

    /// The fault schedule this spec induces. The second kill fells both
    /// survivors at the same virtual instant (broker 0 first, then 2 —
    /// a correlated failure, not two independent ones), which is what
    /// forces the whole-ISR-dead election the policy arm decides.
    pub fn plan(&self) -> FaultPlan {
        let k2 = self.second_kill_at_us();
        let r2 = self.second_restart_at_us();
        let mut plan = FaultPlan::new()
            .kill_broker(self.first_kill_at_us, FIRST_VICTIM)
            .restart_broker(self.first_restart_at_us, FIRST_VICTIM)
            .kill_broker(k2, 0)
            .kill_broker(k2, 2)
            .restart_broker(r2, 0)
            .restart_broker(r2, 2)
            .with_recovery_bandwidth(self.recovery_bytes_per_sec)
            .with_election(self.election);
        if self.retry.is_some() {
            // The retry arm always runs idempotent: a retransmit racing
            // a slow ack must be suppressed, not double-committed.
            plan = plan.with_idempotence();
        }
        plan
    }
}

/// The 3-tenant cascade registry at one scenario point: the
/// [`catchup`] registry (same fleets, weights, seeds), the cascading
/// fault schedule, the outage observation window on every tenant, and —
/// on the retry arm — the client policy on every tenant's producers.
pub fn registry(spec: CascadeSpec, horizon_us: u64) -> MultiTenantConfig {
    let (ws, we) = spec.observe_window();
    let mut cfg = catchup::registry(
        CatchupSpec {
            lag_us: 0,
            cache_bytes: spec.cache_bytes,
            classed_reads: spec.classed,
        },
        horizon_us,
    );
    for t in &mut cfg.tenants {
        *t = t.clone().with_observe_window(ws, we);
        if let Some(policy) = spec.retry {
            *t = t.clone().with_retry(policy);
        }
    }
    cfg.with_faults(spec.plan())
}

/// Run one cascade scenario point.
pub fn run(spec: CascadeSpec, horizon_us: u64) -> MultiTenantReport {
    MultiTenantSim::new(registry(spec, horizon_us)).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;
    use crate::pipeline::fabric::FaultEvent;

    fn spec() -> CascadeSpec {
        CascadeSpec {
            first_kill_at_us: 3 * SEC,
            first_restart_at_us: 4 * SEC,
            kill_gap_us: SEC / 2,
            outage_us: SEC,
            retry: None,
            election: ElectionPolicy::Clean,
            classed: true,
            recovery_bytes_per_sec: 400e6,
            cache_bytes: 200e6,
        }
    }

    /// Scaled-down cascade world (small fleets, short horizon) so unit
    /// tests stay fast; full-size runs live in `experiments::cascade`.
    fn small_cascade(s: CascadeSpec, horizon_us: u64) -> MultiTenantConfig {
        let mut cfg = registry(s, horizon_us);
        cfg.tenants[0].cfg.deployment = Deployment {
            producers: 20,
            consumers: 30,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 30,
        };
        cfg.tenants[1].cfg.deployment = Deployment {
            producers: 4,
            consumers: 6,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 6,
        };
        cfg.tenants[1].cfg.calibration.train.batch_bytes = 250_000.0;
        cfg.tenants[1].cfg.calibration.train.fetch_min_bytes = 500_000;
        cfg.fabric = cfg.tenants[0].cfg.clone();
        cfg
    }

    #[test]
    fn registry_wires_the_cascading_schedule() {
        let s = CascadeSpec { retry: Some(CascadeSpec::default_retry()), ..spec() };
        let cfg = registry(s, 15 * SEC);
        assert_eq!(cfg.tenants.len(), 3);
        let plan = cfg.faults.as_ref().expect("cascade installs a plan");
        let k2 = 4 * SEC + SEC / 2;
        assert_eq!(
            plan.events,
            vec![
                FaultEvent::Kill { at_us: 3 * SEC, broker: FIRST_VICTIM },
                FaultEvent::Restart { at_us: 4 * SEC, broker: FIRST_VICTIM },
                FaultEvent::Kill { at_us: k2, broker: 0 },
                FaultEvent::Kill { at_us: k2, broker: 2 },
                FaultEvent::Restart { at_us: k2 + SEC, broker: 0 },
                FaultEvent::Restart { at_us: k2 + SEC, broker: 2 },
            ]
        );
        assert!(plan.idempotent, "the retry arm must run idempotent");
        for t in &cfg.tenants {
            assert_eq!(t.cfg.retry_max_attempts, 6);
            assert_eq!(t.cfg.observe_window_us, Some((k2, k2 + OBSERVE_TAIL_US)));
        }
        cfg.validate().unwrap();
    }

    #[test]
    fn clean_cascade_survives_and_conserves() {
        let r = MultiTenantSim::new(small_cascade(spec(), 12 * SEC)).run();
        let f = r.fault.as_ref().expect("plan ⇒ fault accounting");
        assert!(f.records_rejected > 0, "a leaderless window must reject");
        assert_eq!(f.unclean_elections, 0, "clean policy never goes unclean");
        assert_eq!(f.unclean_lost_bytes, 0.0);
        assert_eq!(f.min_isr_violations, 0, "no commit below quorum, ever");
        assert_eq!(f.conservation_residual(), 0, "identity must close");
        for t in &r.tenants {
            assert!(t.completed > 0, "tenant {} starved", t.name);
        }
        assert_eq!(r.clamped_events, 0);
    }

    #[test]
    fn unclean_election_trades_bytes_for_availability() {
        let clean = MultiTenantSim::new(small_cascade(spec(), 12 * SEC)).run();
        let unclean = MultiTenantSim::new(small_cascade(
            CascadeSpec { election: ElectionPolicy::Unclean, ..spec() },
            12 * SEC,
        ))
        .run();
        let fc = clean.fault.as_ref().unwrap();
        let fu = unclean.fault.as_ref().unwrap();
        assert!(fu.unclean_elections > 0, "the double kill must force one");
        assert!(
            fu.unclean_lost_bytes > 0.0,
            "electing a catching-up replica discards its missing window"
        );
        assert!(
            fu.records_rejected < fc.records_rejected,
            "unclean continuation must shrink the rejection window: {} vs {}",
            fu.records_rejected,
            fc.records_rejected
        );
        assert_eq!(fu.conservation_residual(), 0);
    }

    #[test]
    fn retries_convert_final_loss_into_delay() {
        let bare = MultiTenantSim::new(small_cascade(spec(), 14 * SEC)).run();
        let armed = MultiTenantSim::new(small_cascade(
            CascadeSpec { retry: Some(CascadeSpec::default_retry()), ..spec() },
            14 * SEC,
        ))
        .run();
        let fb = bare.fault.as_ref().unwrap();
        let fa = armed.fault.as_ref().unwrap();
        assert_eq!(fb.records_retried, 0, "no policy ⇒ no retries");
        assert!(fa.records_retried > 0, "the outage must trigger retries");
        assert!(
            fa.records_rejected_final + fa.records_client_dropped
                < fb.records_rejected_final,
            "retries must save records: armed {}+{} vs bare {}",
            fa.records_rejected_final,
            fa.records_client_dropped,
            fb.records_rejected_final
        );
        assert!(
            fa.records_committed > fb.records_committed,
            "saved records must land as commits"
        );
        assert_eq!(fa.conservation_residual(), 0);
        assert_eq!(fb.conservation_residual(), 0);
    }

    #[test]
    fn unclean_divergence_shrinks_as_the_gap_grows() {
        let near = CascadeSpec {
            election: ElectionPolicy::Unclean,
            kill_gap_us: SEC / 4,
            ..spec()
        };
        let far = CascadeSpec {
            election: ElectionPolicy::Unclean,
            kill_gap_us: 2 * SEC,
            ..spec()
        };
        let rn = MultiTenantSim::new(small_cascade(near, 14 * SEC)).run();
        let rf = MultiTenantSim::new(small_cascade(far, 14 * SEC)).run();
        let near_loss = rn.fault.as_ref().unwrap().unclean_lost_bytes;
        let far_loss = rf.fault.as_ref().unwrap().unclean_lost_bytes;
        assert!(
            far_loss < near_loss,
            "more catch-up time before the second kill must mean less \
             divergence to discard: near {near_loss} vs far {far_loss}"
        );
    }
}
