//! Catch-up consumers: the scenario where Fig 11's "reads are free"
//! assumption breaks.
//!
//! The paper argues consumer reads cost nothing because brokers serve
//! them from the OS page cache (§5.4) — true for *streaming* consumers
//! that read right behind the producers. But a consumer that falls
//! behind — a crashed replica rejoining, a batch job replaying a topic,
//! a training reader restarted from an old checkpoint — must drain a
//! backlog that may have aged out of the cache window, and every cold
//! byte comes off the same NVMe spindle the producers are writing to.
//! This module packages that scenario on the N-tenant registry:
//!
//! * **facerec** — the §5.3 acceleration deployment at 4× (stable
//!   alone), streaming consumers; its ~420 MB/s of replicated appends
//!   is the cache-eviction pressure.
//! * **train-ingest** — 16 shard writers at ~160 MB/s whose consumers
//!   start [`CatchupSpec::lag_us`] behind
//!   ([`TenantDef::with_consumer_lag`]): at resume they fetch the whole
//!   accumulated backlog, and whatever lies below the cache window
//!   becomes one sustained cold-read burst on every broker.
//! * **rpc** — the latency canary: byte-wise negligible, but its 2 kB
//!   appends commit through the same spindle the cold reads occupy.
//!
//! With `classed_reads = false` the burst hits the seed FIFO spindle and
//! every tenant's produce path waits it out; with `classed_reads = true`
//! the cold reads carry the catch-up tenant's class through the same
//! GPS write scheduler PR 4 installed ([`QosPolicy::storage_weights`]
//! via [`MultiTenantConfig::with_storage_qos`]), so the replay drains at
//! weight 1 while facerec and rpc keep their shares.
//! `experiments::read_path` sweeps lag depth × cache size × the two
//! arms (`aitax experiment read-path`).
//!
//! [`QosPolicy::storage_weights`]: crate::broker::qos::QosPolicy

use crate::config::{Config, Deployment};
use crate::pipeline::dc::WorkloadKind;
use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantReport, MultiTenantSim, TenantDef};

/// Scheduling-class weights, shared with `experiments::storage_qos`:
/// the latency tenants outrank the bulk replayer.
pub const FACEREC_WEIGHT: f64 = 4.0;
pub const TRAIN_WEIGHT: f64 = 1.0;
pub const RPC_WEIGHT: f64 = 8.0;

/// Face Recognition acceleration factor (stable alone at 4×).
pub const ACCEL_FACEREC: f64 = 4.0;

/// One catch-up scenario point.
#[derive(Clone, Copy, Debug)]
pub struct CatchupSpec {
    /// How far behind the train tenant's consumers start (µs). 0 = a
    /// fully streaming world (the control arm).
    pub lag_us: u64,
    /// Per-broker page-cache capacity (bytes) for the measured read
    /// path.
    pub cache_bytes: f64,
    /// `true`: cold reads and writes share the per-class GPS spindle
    /// scheduler at the tenant weights; `false`: the seed FIFO spindle.
    pub classed_reads: bool,
}

/// The 3-tenant catch-up registry at one scenario point, on the paper's
/// 3-broker fabric, with the measured read path enabled. No quotas and
/// no CPU weights in either arm — the sweep isolates the read path.
pub fn registry(spec: CatchupSpec, horizon_us: u64) -> MultiTenantConfig {
    let mut fr = Config::default();
    fr.deployment = Deployment::facerec_accel();
    fr.accel = ACCEL_FACEREC;
    fr.duration_us = horizon_us;
    fr.seed = 0xACCE1;

    let mut tr = Config::default();
    tr.deployment = Deployment::train_ingest();
    tr.duration_us = horizon_us;
    tr.seed = 0x7EA1;

    let mut rpc = Config::default();
    rpc.deployment = Deployment::rpc_service();
    rpc.duration_us = horizon_us;
    rpc.seed = 0x59C;

    let fabric = fr.clone();
    MultiTenantConfig::new(fabric, horizon_us)
        .tenant(
            TenantDef::new("facerec", WorkloadKind::FaceRec, fr).with_weight(FACEREC_WEIGHT),
        )
        .tenant(
            TenantDef::new("train-ingest", WorkloadKind::TrainIngest, tr)
                .with_weight(TRAIN_WEIGHT)
                .with_consumer_lag(spec.lag_us),
        )
        .tenant(TenantDef::new("rpc", WorkloadKind::Rpc, rpc).with_weight(RPC_WEIGHT))
        .with_read_cache(spec.cache_bytes)
        .with_storage_qos(spec.classed_reads)
}

/// Run one catch-up scenario point.
pub fn run(spec: CatchupSpec, horizon_us: u64) -> MultiTenantReport {
    MultiTenantSim::new(registry(spec, horizon_us)).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::SEC;

    #[test]
    fn registry_wires_the_scenario() {
        let spec = CatchupSpec {
            lag_us: 10 * SEC,
            cache_bytes: 2e9,
            classed_reads: true,
        };
        let cfg = registry(spec, 20 * SEC);
        assert_eq!(cfg.tenants.len(), 3);
        assert_eq!(cfg.read_cache_bytes, Some(2e9));
        assert!(cfg.storage_qos);
        assert!(!cfg.qos_enabled, "no quotas in either arm");
        assert!(!cfg.weighted_cpu, "no CPU weights in either arm");
        assert_eq!(cfg.tenants[1].cfg.consumer_lag_start_us, 10 * SEC);
        assert_eq!(cfg.tenants[0].cfg.consumer_lag_start_us, 0);
        assert_eq!(cfg.tenants[1].qos.weight, TRAIN_WEIGHT);
        cfg.validate().unwrap();
    }

    /// Scaled-down catch-up world (small fleets, short horizon) so the
    /// unit test stays fast; the full-size acceptance runs live in
    /// `experiments::read_path`.
    fn small_catchup(lag_us: u64, cache_bytes: f64) -> MultiTenantConfig {
        let mut cfg = registry(
            CatchupSpec { lag_us, cache_bytes, classed_reads: false },
            10 * SEC,
        );
        cfg.tenants[0].cfg.deployment = Deployment {
            producers: 20,
            consumers: 30,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 30,
        };
        cfg.tenants[1].cfg.deployment = Deployment {
            producers: 4,
            consumers: 6,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 6,
        };
        cfg.tenants[1].cfg.calibration.train.batch_bytes = 250_000.0;
        cfg.tenants[1].cfg.calibration.train.fetch_min_bytes = 500_000;
        cfg.fabric = cfg.tenants[0].cfg.clone();
        cfg
    }

    #[test]
    fn lagging_tenant_sleeps_then_drains_its_backlog() {
        let behind = MultiTenantSim::new(small_catchup(5 * SEC, 50e6)).run();
        let live = MultiTenantSim::new(small_catchup(0, 50e6)).run();
        let tr_behind = behind.tenant("train-ingest").unwrap();
        let tr_live = live.tenant("train-ingest").unwrap();
        // The lagging consumers still complete work — after the resume.
        assert!(tr_behind.completed > 0, "catch-up tenant never resumed");
        assert!(
            tr_behind.completed < tr_live.completed,
            "sleeping 5 of 10 s must cost completions: {} vs {}",
            tr_behind.completed,
            tr_live.completed
        );
        // And the drain went cold: a 50 MB window cannot hold 5 s of
        // this world's log traffic.
        assert!(behind.cache_hit_ratio < 1.0);
        assert!(behind.device_read_share > 0.0);
        // The zero-lag arm stays effectively warm.
        assert!(live.cache_hit_ratio > behind.cache_hit_ratio);
        // The streaming tenants never starve in either arm.
        for r in [&behind, &live] {
            assert!(r.tenant("facerec").unwrap().completed > 0);
            assert!(r.tenant("rpc").unwrap().completed > 0);
        }
    }
}
