//! The data-center deployment layer: reusable
//! [`sim::world`](crate::sim::world) components.
//!
//! Face Recognition and Object Detection used to be two hand-rolled
//! ~500-LoC event loops that duplicated the producer/partition/consumer
//! machinery. This module factors that machinery into components on the
//! [`World`](crate::sim::world::World) kernel:
//!
//! * [`ProducerClient`] — one per tenant; runs every producer container's
//!   frame/tick cycle, the client-side linger/batch hold, and the dispatch
//!   through the producer NIC into the fabric.
//! * [`PartitionQueue`] — leader routing + consumer pinning + the
//!   committed-record queue for one topic partition (stored in the shared
//!   [`DcState`] because producers, the fabric, and consumers all touch
//!   partitions at the same virtual instant).
//! * [`ConsumerPoller`] — one per tenant; poll scheduling,
//!   `fetch.min.bytes`/`fetch.max.wait` withholding, the fetch path, and
//!   serial busy-until service on each 1-core consumer container.
//! * [`FabricHub`] — the existing event-driven broker
//!   [`Fabric`](crate::pipeline::fabric::Fabric) wrapped as a component:
//!   fabric hop events route here and commit notifications fan back out
//!   to partitions and consumer wakeups.
//!
//! A **tenant** is one workload (Face Recognition, Object Detection,
//! training ingest, or an RPC-style service) with its own producers,
//! consumers, partitions, and metrics. Tenants share the broker fabric,
//! the storage devices, and the byte meters — which is exactly what lets
//! `pipeline::mixed` run N applications on one substrate and measure
//! cross-tenant interference, something the per-workload monoliths could
//! not express.
//!
//! **QoS hooks** (see [`crate::broker::qos`] and `docs/architecture.md`):
//! when [`build_with_qos`] installs a policy, the produce path charges
//! the tenant's produce [`TokenBucket`] at dispatch — a throttled record
//! is re-scheduled as [`DcEvent::DispatchAdmitted`] at its admission time
//! (backpressure in the `ProducerClient`) — and the fetch path charges
//! the fetch bucket after each fetch, muting the poll loop through
//! [`ConsumerGate::throttled_until`]. Request-CPU work carries the tenant
//! id as a scheduling class so the fabric's weighted scheduler (when
//! enabled) gives each tenant its configured share; the same class rides
//! every in-flight record down to the broker NVMe write queues, where
//! [`QosPolicy::storage_weights`](crate::broker::qos::QosPolicy) (when
//! set) swaps the FIFO write path for the per-class GPS scheduler.
//! Replication-aware quotas charge `bytes × RF` at dispatch
//! ([`TenantState::produce_charge_factor`]) so a produce budget is
//! denominated in write-path bytes. With no policy every hook is inert.
//!
//! Fidelity contract: for a single-tenant world with QoS disabled this
//! module reproduces the legacy simulators *event for event* — same event
//! queue insertion order, same RNG draw order, same metric updates — so
//! reports are bit-identical for a given seed (`tests/golden_reports.rs`
//! holds the legacy loops as a differential reference, and
//! `tests/qos_regression.rs` pins the QoS-off no-op contract).

use std::collections::{HashMap, VecDeque};

use crate::broker::qos::{QosPolicy, TokenBucket};
use crate::config::calibration::{ObjDetCosts, RpcCosts, TrainCosts};
use crate::config::{AccelProtocol, Config, KafkaTuning};
use crate::config::hardware::NvmeSpec;
use crate::metrics::bandwidth::{BandwidthMeter, Class};
use crate::metrics::tax::{Segment, TaxBreakdown, TaxCell, TaxSummary};
use crate::metrics::trace::{TraceRecorder, TraceSpec};
use crate::net::topology::FatTree;
use crate::net::{NetworkSpec, Nic};
use crate::pipeline::fabric::{
    Fabric, FabricEv, FabricOut, FaultEvent, FaultPlan, SendOutcome, WIRE_US,
};
use crate::pipeline::stage::StageModel;
use crate::pipeline::video::BurstSchedule;
use crate::sim::queue::Population;
use crate::sim::resource::FifoServer;
use crate::sim::world::{CompId, Component, Ctx, World};
use crate::util::rng::Rng;
use crate::util::stats::Histogram;

/// Framing overhead per Face Recognition record on the wire (batch header
/// amortized + record header; see `broker::record`).
pub const FACEREC_RECORD_OVERHEAD: f64 = 32.0;
/// Object Detection framing overhead, folded into the item bytes at
/// production time (the legacy simulator did the same).
pub const OBJDET_RECORD_OVERHEAD: f64 = 64.0;

/// Sentinel partition meaning "choose at dispatch time" (Face Recognition
/// picks the partition when the record leaves the client, consuming the
/// producer's RNG at that moment).
pub const PARTITION_UNROUTED: u32 = u32::MAX;

/// Population sampling period (0.25 s), the Fig-7 resolution.
const POPULATION_SAMPLE_US: u64 = 250_000;

/// Client-side produce resilience: what a producer does when the fabric
/// rejects a send (dead leader / ISR below quorum) or an ack times out.
///
/// Disabled (`Config::retry_max_attempts == 0`, the default) the client
/// is the PR 7 client bit for bit: a rejected record is dropped and
/// counted at the fabric. Enabled, rejected records re-enter a bounded
/// in-client buffer ([`RetryPolicy::buffer_bytes`]) and are re-offered
/// with exponential backoff; records an in-flight ack never arrives for
/// are retransmitted after [`RetryPolicy::request_timeout_us`] (the
/// fabric's idempotence layer suppresses the duplicate if the original
/// is still alive — see `pipeline/fabric.rs`). When the buffer
/// overflows, records are dropped *at the client* and counted
/// (`client_dropped`): graceful degradation instead of silent loss.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total send attempts per record (first try included). A record
    /// whose last attempt is rejected takes the PR 7 final-loss path.
    pub max_attempts: u32,
    /// Backoff before re-offering failed attempt 1; doubles per attempt.
    pub base_backoff_us: u64,
    /// Exponential backoff cap.
    pub max_backoff_us: u64,
    /// Producer ack timeout: an admitted record unacked this long is
    /// retransmitted (Kafka's `request.timeout.ms`).
    pub request_timeout_us: u64,
    /// In-client retry buffer bound (`buffer.memory`): bytes of
    /// rejected records awaiting their backoff. Overflow drops at the
    /// client, counted per tenant.
    pub buffer_bytes: f64,
}

impl RetryPolicy {
    /// Deterministic backoff before re-offering failed attempt
    /// `attempt` (1-based): exponential in the attempt number, capped
    /// at `max_backoff_us`, plus a zero-RNG jitter hashed from the
    /// record's client sequence number so same-instant rejections don't
    /// re-herd — nothing here draws from an RNG stream, so `jobs=N`
    /// sweeps stay bit-identical.
    pub fn backoff_us(&self, attempt: u32, seq: u64) -> u64 {
        let shift = attempt.saturating_sub(1);
        let exp = if shift >= 32 {
            u64::MAX
        } else {
            self.base_backoff_us.saturating_mul(1u64 << shift)
        };
        let jitter = (seq.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 48)
            % (self.base_backoff_us / 2 + 1);
        self.max_backoff_us.min(exp) + jitter
    }
}

/// Which workload a tenant runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    FaceRec,
    ObjDet,
    /// Training-data ingest: large sequential batch writes at a steady
    /// cadence, throughput-tuned consumers (see `pipeline::train`).
    TrainIngest,
    /// RPC-style low-latency service: small records, immediate fetch
    /// (`fetch.min.bytes` = 1), tight tail SLO (see `pipeline::rpc`).
    Rpc,
}

impl WorkloadKind {
    /// Short lowercase label for reports and JSON.
    pub fn label(&self) -> &'static str {
        match self {
            WorkloadKind::FaceRec => "facerec",
            WorkloadKind::ObjDet => "objdet",
            WorkloadKind::TrainIngest => "train-ingest",
            WorkloadKind::Rpc => "rpc",
        }
    }
}

/// A record in flight (sizes + timestamps only — the §5.2 emulation
/// argument: brokers can't tell payloads from garbage of the same size).
/// Face Recognition items are faces; Object Detection items are frames.
#[derive(Clone, Copy, Debug)]
pub struct Item {
    /// When the work entered the pipeline (frame start / tick epoch).
    pub created_us: u64,
    /// When the producer finished local processing (detect end / send
    /// done) — the epoch broker wait is measured from.
    pub ready_us: u64,
    /// When the record became visible to consumers (commit time).
    pub visible_us: u64,
    pub bytes: f64,
    /// Client records this item stands for: 1 on the per-record path,
    /// >1 for a flow-aggregated macro-record ([`ProducerKind::Flow`]),
    /// whose `bytes` are the records' aggregate payload. Metrics weight
    /// by this count so tenant means match the per-record simulation.
    pub count: u64,
    /// Latency provenance (PR 10): per-segment µs accumulator, charged
    /// at every hop only when the world was built with provenance armed
    /// ([`FabricSpec::provenance`]) — otherwise it stays at its
    /// construction state and the world is bit-exact to the
    /// pre-provenance build.
    pub tax: TaxCell,
}

/// Events routed between data-center components.
#[derive(Debug)]
pub enum DcEvent {
    /// Producer `p` (tenant-local index) begins its next frame/tick cycle.
    Produce(u32),
    /// A record leaves producer `p`'s client toward `partition`
    /// ([`PARTITION_UNROUTED`] = pick at dispatch).
    Dispatch { producer: u32, partition: u32, item: Item },
    /// A previously quota-throttled record re-entering the send path at
    /// its admission time (partition already resolved, bucket already
    /// charged — see the QoS hooks in the module docs).
    DispatchAdmitted { producer: u32, partition: u32, item: Item },
    /// A buffered (previously rejected) record re-entering the send
    /// path at the end of its retry backoff ([`RetryPolicy`]). `attempt`
    /// is the attempt about to be made (1-based); `seq` the client
    /// sequence number backing the deterministic jitter and ack
    /// matching. The record itself stays parked in the [`ItemPool`]
    /// under `token` — its `created_us` is untouched, so e2e latency
    /// keeps measuring from the *first* attempt.
    RetryFire { producer: u32, partition: u32, token: u64, attempt: u32, seq: u64 },
    /// Producer-side ack timeout for in-flight attempt `attempt` of the
    /// record under `token`: if the commit has not arrived by now (the
    /// token/`seq` pair is still pending), the client retransmits.
    AckCheck { producer: u32, partition: u32, token: u64, attempt: u32, seq: u64 },
    /// Broker-fabric hop (routed to [`FabricHub`]).
    Fabric(FabricEv),
    /// Consumer `c` (tenant-local index) polls its partitions.
    Poll(u32),
    /// World-level fault `i` of the installed [`FaultPlan`] fires
    /// (routed to [`FabricHub`]; never scheduled in an immortal world).
    Fault(u32),
}

/// One topic partition: leader broker, pinned consumer, committed queue.
#[derive(Debug)]
pub struct PartitionQueue {
    pub tenant: u8,
    /// Leader broker index in the shared fabric.
    pub leader: u32,
    /// Tenant-local index of the pinned consumer.
    pub consumer: u32,
    pub queue: VecDeque<Item>,
}

/// Token pool for records traversing the fabric.
#[derive(Debug, Default)]
pub struct ItemPool {
    in_flight: Vec<Item>,
    free: Vec<u64>,
}

impl ItemPool {
    pub fn alloc(&mut self, item: Item) -> u64 {
        match self.free.pop() {
            Some(token) => {
                self.in_flight[token as usize] = item;
                token
            }
            None => {
                self.in_flight.push(item);
                (self.in_flight.len() - 1) as u64
            }
        }
    }

    pub fn release(&mut self, token: u64) -> Item {
        self.free.push(token);
        self.in_flight[token as usize]
    }

    /// Peek a live record without releasing it (the retry path re-offers
    /// a parked record from its original token).
    pub fn get(&self, token: u64) -> Item {
        self.in_flight[token as usize]
    }

    /// Mutable access to a parked record (the provenance path charges
    /// retry backoff/timeout windows on the *pooled* copy, so they
    /// survive until the record is released at commit).
    pub fn get_mut(&mut self, token: u64) -> &mut Item {
        &mut self.in_flight[token as usize]
    }
}

/// Consumer-side fetch tuning + wire framing for one tenant.
#[derive(Clone, Copy, Debug)]
pub struct FetchTuning {
    /// Per-record overhead added on the wire and in fetch accounting
    /// (zero when the overhead is folded into item bytes at production).
    pub record_overhead: f64,
    pub fetch_min_bytes: usize,
    pub fetch_max_wait_us: u64,
    /// `max.partition.fetch.bytes`-style cap: one poll drains at most
    /// this many bytes per partition, then immediately re-polls for the
    /// rest — so a catch-up drain is a train of bounded requests instead
    /// of one giant fetch. At least one record is always fetched
    /// (Kafka's oversized-record escape hatch). `usize::MAX` (the
    /// default) is the uncapped pre-PR-6 behavior, bit for bit.
    pub max_partition_fetch_bytes: usize,
}

/// Cross-component per-consumer scheduling state (the "mailbox" the
/// fabric commit path uses to wake a pinned consumer).
#[derive(Clone, Copy, Debug, Default)]
pub struct ConsumerGate {
    pub poll_scheduled: bool,
    pub busy_until: u64,
    /// Fetch-quota mute: polls before this instant are deferred to it
    /// (Kafka's throttled-channel semantics; 0 = unmuted).
    pub throttled_until: u64,
}

/// Everything measured for one tenant.
#[derive(Debug)]
pub struct TenantMetrics {
    /// Ingestion stage durations.
    pub hist_ingest: Histogram,
    /// Face Recognition: detection; Object Detection: tick-start delay.
    pub hist_prep: Histogram,
    /// Broker wait (ready -> service start).
    pub hist_wait: Histogram,
    /// Consumer-side service (identify / R-CNN detect).
    pub hist_service: Histogram,
    pub hist_e2e: Histogram,
    /// End-to-end latency of items created inside the tenant's
    /// observation window ([`Config::observe_window_us`]); empty when no
    /// window is set. Lets a failover run report the p99 *through* the
    /// failure window.
    pub hist_e2e_window: Histogram,
    /// Items in system (Fig 7).
    pub population: Population,
    /// Dense per-second e2e latency aggregation, bucketed by *arrival*
    /// second (a face arriving during a surge experiences the congestion
    /// wherever its completion lands).
    pub lat_sum: Vec<u64>,
    pub lat_n: Vec<u64>,
    /// Producer cycles completed (frames for FR, ticks for OD).
    pub frames_total: u64,
    /// Post-warmup producer cycles (FR's `frames_ingested`).
    pub frames_measured: u64,
    /// Producer→broker bytes this tenant put on the wire. The shared
    /// [`BandwidthMeter`] only has class-wide totals, which in a mixed
    /// world blend tenants; per-tenant NIC figures come from here.
    pub net_tx_bytes: f64,
    /// Broker→consumer bytes this tenant fetched.
    pub net_rx_bytes: f64,
    /// Items sent into the fabric (faces produced / frames sent).
    pub produced: u64,
    pub completed: u64,
    /// Completions inside the measurement window (throughput numerator).
    pub completed_in_window: u64,
    /// Client records re-offered to the fabric by the retry layer
    /// (record-weighted: a retried macro-record counts its aggregate).
    /// Every retry attempt — backoff re-offer or ack-timeout
    /// retransmit — counts here, which is what makes the extended
    /// conservation identity close (the fabric counts each attempt in
    /// `offered`).
    pub retries: u64,
    /// Records dropped at the client because the retry buffer
    /// overflowed ([`RetryPolicy::buffer_bytes`]) — the graceful-
    /// degradation loss mode, never silent.
    pub client_dropped: u64,
    /// Fabric rejections the client absorbed instead of letting stand:
    /// rejections that were retried *plus* rejections converted into
    /// `client_dropped`. `fabric.rejected - absorbed_rejects` is the
    /// *final* rejection count in the extended identity.
    pub absorbed_rejects: u64,
    /// Latency provenance (PR 10): per-segment attribution of this
    /// tenant's end-to-end latency, armed (`Some`) only when the world
    /// was built with [`FabricSpec::provenance`]. Ingested in the serve
    /// loop under the same warmup/horizon gate as `hist_e2e`.
    pub tax: Option<TaxBreakdown>,
}

impl TenantMetrics {
    fn new(horizon_us: u64) -> Self {
        let n_secs = (horizon_us / 1_000_000 + 2) as usize;
        TenantMetrics {
            hist_ingest: Histogram::new(),
            hist_prep: Histogram::new(),
            hist_wait: Histogram::new(),
            hist_service: Histogram::new(),
            hist_e2e: Histogram::new(),
            hist_e2e_window: Histogram::new(),
            population: Population::new(POPULATION_SAMPLE_US),
            lat_sum: vec![0; n_secs],
            lat_n: vec![0; n_secs],
            frames_total: 0,
            frames_measured: 0,
            net_tx_bytes: 0.0,
            net_rx_bytes: 0.0,
            produced: 0,
            completed: 0,
            completed_in_window: 0,
            retries: 0,
            client_dropped: 0,
            absorbed_rejects: 0,
            tax: None,
        }
    }

    /// Mean per-node NIC utilization over `[0, elapsed]` for one tenant
    /// (same formula as `BandwidthMeter::utilization`, computed from the
    /// tenant's own byte totals and fleet size).
    pub fn per_node_net_util(bytes: f64, elapsed_us: u64, nodes: usize, capacity: f64) -> f64 {
        if elapsed_us == 0 || capacity <= 0.0 {
            return 0.0;
        }
        bytes * 1e6 / (elapsed_us as f64 * nodes.max(1) as f64) / capacity
    }

    /// The Fig-7 (time, mean e2e) series from the per-second buckets.
    pub fn latency_series(&self) -> Vec<(u64, u64)> {
        self.lat_sum
            .iter()
            .zip(&self.lat_n)
            .enumerate()
            .filter(|(_, (_, &n))| n > 0)
            .map(|(sec, (&sum, &n))| (sec as u64 * 1_000_000, sum / n))
            .collect()
    }
}

/// Per-tenant shared state: fetch tuning, consumer gates, partition
/// slice, metrics, and the component ids events route to.
#[derive(Debug)]
pub struct TenantState {
    pub kind: WorkloadKind,
    pub fetch: FetchTuning,
    pub gates: Vec<ConsumerGate>,
    pub metrics: TenantMetrics,
    /// This tenant's slice of the global partition index space.
    pub part_base: u32,
    pub part_count: u32,
    pub warmup_us: u64,
    pub producer_comp: CompId,
    pub poller_comp: CompId,
    /// Produce byte-rate quota (QoS); `None` = uncapped.
    pub produce_bucket: Option<TokenBucket>,
    /// Bytes charged against the produce bucket per client byte: `1.0`
    /// for Kafka-style client-byte metering, the fabric's replication
    /// factor for replication-aware (write-path-byte) quotas — see
    /// [`crate::broker::qos::TenantQuota::replication_aware`].
    pub produce_charge_factor: f64,
    /// Fetch byte-rate quota (QoS); `None` = uncapped.
    pub fetch_bucket: Option<TokenBucket>,
    /// `(start_us, end_us)` of the windowed-latency observation
    /// ([`Config::observe_window_us`]); `None` = no windowed histogram.
    pub observe_window: Option<(u64, u64)>,
    /// Client produce-retry policy ([`Config::retry_policy`]); `None`
    /// (the default) is the PR 7 reject-is-loss client bit for bit.
    pub retry: Option<RetryPolicy>,
    /// Bytes of rejected records currently parked in the client retry
    /// buffer awaiting their backoff (bounded by
    /// [`RetryPolicy::buffer_bytes`]).
    pub retry_buffered_bytes: f64,
}

/// The shared substrate every component can reach through [`Ctx`].
pub struct DcState {
    pub fabric: Fabric,
    pub meter: BandwidthMeter,
    pub partitions: Vec<PartitionQueue>,
    pub items: ItemPool,
    pub fabric_out: Vec<FabricOut>,
    pub tenants: Vec<TenantState>,
    pub fabric_comp: CompId,
    pub horizon_us: u64,
    /// True when any tenant has a [`RetryPolicy`]; gates every retry
    /// hook so a retry-free world does no extra work (and stays
    /// bit-exact to PR 7).
    pub retry_armed: bool,
    /// token → client seq of sends awaiting an ack. An [`AckCheck`]
    /// whose (token, seq) no longer matches is stale (the commit
    /// arrived, or a newer send reused the token) and ignored. Only
    /// point lookups — never iterated — so the map's hash order can't
    /// leak into event order.
    ///
    /// [`AckCheck`]: DcEvent::AckCheck
    pub retry_pending: HashMap<u64, u64>,
    /// Monotone client sequence counter: unique per (re)buffered or
    /// admitted send, feeding the zero-RNG backoff jitter and the
    /// stale-ack discrimination above.
    pub retry_seq: u64,
    /// Latency provenance (PR 10): global rebalance pause windows
    /// `(start_us, end_us)` recorded by [`reassign_leaders`], so the
    /// serve loop can attribute the overlap of a record's visible wait
    /// to [`Segment::Rebalance`]. Only appended when provenance is
    /// armed; a handful of entries per fault schedule.
    pub rebalance_pauses: Vec<(u64, u64)>,
    /// Flight recorder ([`TraceRecorder`]); `None` (the default) records
    /// nothing.
    pub trace: Option<TraceRecorder>,
}

/// Route buffered fabric outputs: schedule hop events to the
/// [`FabricHub`]; on commit, make the record visible on its partition and
/// wake the pinned consumer through its gate.
pub fn drain_fabric(ctx: &mut Ctx<'_, DcEvent, DcState>) {
    let mut i = 0;
    while i < ctx.shared.fabric_out.len() {
        let o = ctx.shared.fabric_out[i];
        i += 1;
        match o {
            FabricOut::Schedule(t, fev) => {
                // Past times clamp to now inside `EventQueue::at`.
                let dst = ctx.shared.fabric_comp;
                ctx.at(t, dst, DcEvent::Fabric(fev));
            }
            FabricOut::Committed { token, partition, at } => {
                let (wake, dst, consumer) = {
                    let s = &mut *ctx.shared;
                    if s.retry_armed {
                        // The ack arrived: retire any outstanding
                        // timeout watch before the token is recycled.
                        s.retry_pending.remove(&token);
                    }
                    let mut item = s.items.release(token);
                    if s.fabric.provenance_enabled() {
                        // Absorb the winning fabric copy's cell and
                        // settle the telescoping residual (retransmit
                        // overlap / loss gaps) against ClientWait.
                        if let Some(cell) = s.fabric.take_committed_tax(token) {
                            item.tax.reconcile(&cell, item.created_us, at);
                        }
                    }
                    item.visible_us = at;
                    let part = &mut s.partitions[partition as usize];
                    let tenant = part.tenant as usize;
                    let consumer = part.consumer;
                    part.queue.push_back(item);
                    let ts = &mut s.tenants[tenant];
                    let gate = &mut ts.gates[consumer as usize];
                    if gate.poll_scheduled {
                        continue;
                    }
                    gate.poll_scheduled = true;
                    (at.max(gate.busy_until), ts.poller_comp, consumer)
                };
                ctx.at(wake, dst, DcEvent::Poll(consumer));
            }
        }
    }
    ctx.shared.fabric_out.clear();
}

// ---------------------------------------------------------------------------
// FabricHub
// ---------------------------------------------------------------------------

/// Stop-the-world pause a consumer group takes when partition leadership
/// moves (Kafka's eager rebalance, abbreviated to one constant): every
/// consumer owning a moved partition defers its polls this long.
pub const REBALANCE_PAUSE_US: u64 = 500_000;

/// The broker fabric wrapped as a component: hop events land here, the
/// device state itself lives in [`DcState`] so producers (send) and
/// consumers (fetch) can drive it synchronously at the same instant.
/// Also the injection point for world-level faults: the installed
/// [`FaultPlan`]'s events are scheduled as [`DcEvent::Fault`] at build
/// time and applied here (kill / restart / partition + the dc-side
/// leader re-election and rebalance pauses).
pub struct FabricHub {
    /// The fault schedule, indexed by [`DcEvent::Fault`] (empty in an
    /// immortal world).
    faults: Vec<FaultEvent>,
}

impl Component<DcEvent, DcState> for FabricHub {
    fn on_event(&mut self, ctx: &mut Ctx<'_, DcEvent, DcState>, ev: DcEvent) {
        let now = ctx.now();
        match ev {
            DcEvent::Fabric(fev) => {
                {
                    let s = &mut *ctx.shared;
                    if let Some(tr) = s.trace.as_mut() {
                        // Network epochs are per-transfer; decimate them
                        // through the recorder's sampling so a contended
                        // run doesn't flood the ring.
                        if matches!(fev, FabricEv::NetStart { .. }) {
                            tr.instant_sampled("net-epoch", now);
                        }
                    }
                    s.fabric.handle(now, fev, &mut s.meter, &mut s.fabric_out);
                }
                drain_fabric(ctx);
            }
            DcEvent::Fault(i) => {
                let fault = self.faults[i as usize];
                match fault {
                    FaultEvent::Kill { broker, .. } => {
                        {
                            let s = &mut *ctx.shared;
                            if let Some(tr) = s.trace.as_mut() {
                                tr.instant("broker-kill", now);
                            }
                            s.fabric.kill_broker(now, broker, &mut s.fabric_out);
                        }
                        reassign_leaders(ctx, broker);
                    }
                    FaultEvent::Restart { broker, .. } => {
                        let s = &mut *ctx.shared;
                        if let Some(tr) = s.trace.as_mut() {
                            tr.instant("broker-restart", now);
                        }
                        s.fabric.restart_broker(now, broker, &mut s.fabric_out);
                    }
                    FaultEvent::Partition { a, b, duration_us, .. } => {
                        let s = &mut *ctx.shared;
                        if let Some(tr) = s.trace.as_mut() {
                            tr.instant("net-partition", now);
                        }
                        s.fabric.partition_links(now, a, b, duration_us, &mut s.fabric_out);
                    }
                }
                drain_fabric(ctx);
            }
            _ => debug_assert!(false, "unexpected event routed to FabricHub"),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Re-elect every partition led by the dead `broker` per the fabric's
/// [`ElectionPolicy`](crate::pipeline::fabric::ElectionPolicy) — ring
/// order among alive in-sync replicas, with an out-of-sync fallback
/// (divergence measured as `unclean_lost_bytes`) only under `Unclean` —
/// and pause the consumers owning the moved partitions for the
/// rebalance window ([`REBALANCE_PAUSE_US`]): their gates' `busy_until`
/// defers any poll landing inside it. If no electable broker remains
/// the partition keeps its dead leader and new produces are rejected at
/// admission until a restart.
fn reassign_leaders(ctx: &mut Ctx<'_, DcEvent, DcState>, broker: u32) {
    let now = ctx.now();
    let s = &mut *ctx.shared;
    if !s.partitions.iter().any(|p| p.leader == broker) {
        return;
    }
    // One election per kill, not per partition: the ring scan is
    // partition-independent, and the unclean branch counts the
    // replica's divergence exactly once.
    if s.fabric.provenance_enabled() {
        // One global pause window per election; the serve loop splits a
        // record's visible wait against these so stop-the-world time is
        // attributed to Segment::Rebalance, not BrokerWait.
        s.rebalance_pauses.push((now, now + REBALANCE_PAUSE_US));
    }
    if let Some(tr) = s.trace.as_mut() {
        tr.instant("leader-election", now);
    }
    let elected = s.fabric.elect_leader(broker);
    for pi in 0..s.partitions.len() {
        if s.partitions[pi].leader != broker {
            continue;
        }
        if let Some(cand) = elected {
            s.partitions[pi].leader = cand;
        }
        let (tenant, consumer) = {
            let part = &s.partitions[pi];
            (part.tenant as usize, part.consumer as usize)
        };
        let gate = &mut s.tenants[tenant].gates[consumer];
        gate.busy_until = gate.busy_until.max(now + REBALANCE_PAUSE_US);
    }
}

// ---------------------------------------------------------------------------
// ProducerClient
// ---------------------------------------------------------------------------

/// Workload-specific producer behavior.
pub enum ProducerKind {
    /// §3/§4: ingest + detect on a 1-core pipelined container; each face
    /// is its own record held for the client linger before dispatch.
    FaceRec {
        stages: StageModel,
        /// Global burst timeline (None = the §5.3 one-face-per-frame
        /// acceleration deployments).
        schedule: Option<BurstSchedule>,
        linger_us: u64,
        face_bytes: f64,
    },
    /// Generic open-loop tick producer shared by the Object Detection,
    /// training-ingest and RPC tenants: every `tick_us` each producer
    /// prepares and sends `records_per_tick` records through its
    /// send-path server, so an overrunning send path shows up as
    /// tick-start delay (Fig 14's "Delay"). Object Detection is the
    /// §6 instance: 30 FPS ticks, `records_per_tick = k` frames under k×
    /// acceleration, constant frame bytes (`bytes_cv = 0`).
    Tick {
        tick_us: u64,
        records_per_tick: usize,
        record_bytes: f64,
        /// Lognormal cv of the record size (0 = constant-size records).
        bytes_cv: f64,
        /// Producer-side compute per record before the send (µs mean;
        /// recorded in the ingest histogram).
        prep_us: f64,
        prep_cv: f64,
        /// Serialization + client cost per record on the send server.
        send_us_per_record: f64,
    },
    /// Hybrid fluid/discrete scaling: each producer *unit* is one flow
    /// standing for thousands of [`Tick`](ProducerKind::Tick) clients.
    /// Every coalescing quantum the flow converts its population's
    /// offered rate (`clients × records_per_tick / tick_us`) into whole
    /// records via a fractional carry accumulator and emits **one
    /// macro-record per owned partition** carrying the aggregate bytes
    /// and a record count — so the quota buckets, the fabric NIC/CPU/
    /// storage hops, and the read path see the same byte stream the
    /// per-record simulation offers, in ~`partitions / quantum` events
    /// instead of one per record.
    ///
    /// The fluid boundary (see `docs/architecture.md`): per-record RNG
    /// draws (size, prep) collapse to their means; the per-client send
    /// server is left idle and its mean latency applied as a constant
    /// offset (a flow stands for N *parallel* clients, each far below
    /// send saturation, so no single-server queue is the right model);
    /// creation epochs take the quantum-window midpoint so mean e2e
    /// matches the smeared per-record arrivals. No RNG runs on this
    /// path — flow worlds are trivially jobs-deterministic.
    Flow {
        tick_us: u64,
        records_per_tick: usize,
        record_bytes: f64,
        prep_us: f64,
        send_us_per_record: f64,
        /// Coalescing quantum (µs): all flows wake on this shared grid
        /// ([`Ctx::at_self_aligned`]).
        quantum_us: u64,
        /// One entry per producer unit (flow).
        flows: Vec<FlowState>,
    },
}

/// Deterministic rate-process state of one flow ([`ProducerKind::Flow`]).
#[derive(Clone, Debug)]
pub struct FlowState {
    /// Client population this flow aggregates.
    pub clients: u64,
    /// Fractional records carried to the next quantum, so the long-run
    /// emitted count is exactly `clients × rate × elapsed` (no drift).
    pub carry: f64,
    /// Last wake time (µs) — the integration window start.
    pub last_us: u64,
    /// Round-robin cursor distributing the per-quantum remainder across
    /// owned partitions.
    pub rr: u32,
}

/// Per-producer container state.
pub struct ProducerUnit {
    pub rng: Rng,
    /// Host NIC: dispatch serializes on `nic.tx` (bit-exact the old
    /// single FIFO server); the rx direction is idle on producers.
    pub nic: Nic,
    /// Send-path server (serialization + Kafka client), us of work.
    /// Exercised by Object Detection; idle for Face Recognition.
    pub send: FifoServer,
    /// Frames (FR) / ticks (OD) started.
    pub cycles: u64,
    /// Network node id on the contention-aware fabric (brokers are
    /// `0..B`, client units follow in world build order). Unused —
    /// carried but never read — when the network is disabled.
    pub node: u32,
}

/// One tenant's producer fleet: frame/tick cycles, linger, dispatch.
pub struct ProducerClient {
    tenant: u8,
    kind: ProducerKind,
    units: Vec<ProducerUnit>,
}

impl ProducerClient {
    /// Max producer send-path utilization over `[0, elapsed]` (the Fig-14
    /// "Delay" culprit).
    pub fn max_send_util(&self, elapsed_us: u64) -> f64 {
        self.units
            .iter()
            .map(|u| u.send.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    fn produce(&mut self, ctx: &mut Ctx<'_, DcEvent, DcState>, p: u32) {
        let now = ctx.now();
        let t = self.tenant as usize;
        let horizon = ctx.shared.horizon_us;
        let pid = p as usize;
        match &mut self.kind {
            ProducerKind::FaceRec { stages, schedule, linger_us, face_bytes } => {
                let u = &mut self.units[pid];
                let faces = match schedule {
                    Some(sched) => sched.faces_at(now, &mut u.rng),
                    None => 1,
                };
                let ingest_us = stages.ingest(&mut u.rng);
                let detect_us = stages.detect(&mut u.rng, faces);
                let detect_end = now + ingest_us + detect_us;
                u.cycles += 1;
                {
                    let ts = &mut ctx.shared.tenants[t];
                    ts.metrics.frames_total += 1;
                    if now >= ts.warmup_us {
                        ts.metrics.frames_measured += 1;
                        ts.metrics.hist_ingest.record(ingest_us.max(1));
                        ts.metrics.hist_prep.record(detect_us.max(1));
                    }
                }
                // Each face is its own record; the 2020-era Kafka default
                // partitioner round-robins unkeyed records, so a frame's
                // faces scatter across partitions (chosen at dispatch).
                // The linger is the client-side hold before shipping.
                for _ in 0..faces {
                    let bytes = u.rng.lognormal_mean_cv(*face_bytes, 0.25).max(1024.0);
                    let item = Item {
                        created_us: now,
                        ready_us: detect_end,
                        visible_us: 0,
                        bytes,
                        count: 1,
                        tax: TaxCell::new(now),
                    };
                    {
                        let ts = &mut ctx.shared.tenants[t];
                        ts.metrics.produced += 1;
                        ts.metrics.population.enter(detect_end.min(horizon));
                    }
                    ctx.at_self(
                        detect_end + *linger_us,
                        DcEvent::Dispatch { producer: p, partition: PARTITION_UNROUTED, item },
                    );
                }
                // Pipelined single-core container: next frame starts when
                // this one's ingest+detect completes.
                ctx.at_self(detect_end.max(now + 1), DcEvent::Produce(p));
            }
            ProducerKind::Tick {
                tick_us,
                records_per_tick,
                record_bytes,
                bytes_cv,
                prep_us,
                prep_cv,
                send_us_per_record,
            } => {
                let (part_base, part_count) = {
                    let ts = &ctx.shared.tenants[t];
                    (ts.part_base, ts.part_count)
                };
                {
                    let ts = &mut ctx.shared.tenants[t];
                    ts.metrics.frames_total += 1;
                    if now >= ts.warmup_us {
                        ts.metrics.frames_measured += 1;
                    }
                }
                let u = &mut self.units[pid];
                u.cycles += 1;
                // Fig 14's "Delay": the send server may still be draining
                // the previous tick's records; this tick starts late.
                let delay = u.send.backlog_us(now);
                let start = now + delay;
                for _ in 0..*records_per_tick {
                    let prep = u
                        .rng
                        .lognormal_mean_cv(prep_us.max(1.0), *prep_cv)
                        .round()
                        .max(1.0) as u64;
                    let t_ready = start + prep;
                    let t_sent = u.send.submit(t_ready, *send_us_per_record);
                    let bytes = if *bytes_cv > 0.0 {
                        u.rng.lognormal_mean_cv(*record_bytes, *bytes_cv).max(64.0)
                    } else {
                        *record_bytes
                    };
                    {
                        let ts = &mut ctx.shared.tenants[t];
                        ts.metrics.produced += 1;
                        if now >= ts.warmup_us {
                            ts.metrics.hist_ingest.record(prep.max(1));
                            ts.metrics.hist_prep.record(delay.max(1));
                        }
                        ts.metrics.population.enter(t_sent.min(horizon));
                    }
                    // Random partition per record so the brokers can fully
                    // load-balance (§6.3) — deterministic rotation across
                    // same-cadence producers would convoy the consumers.
                    let partition = part_base + u.rng.below(part_count as u64) as u32;
                    let item = Item {
                        created_us: now,
                        ready_us: t_sent,
                        visible_us: 0,
                        bytes,
                        count: 1,
                        tax: TaxCell::new(now),
                    };
                    ctx.at_self(
                        t_sent + WIRE_US,
                        DcEvent::Dispatch { producer: p, partition, item },
                    );
                }
                ctx.at_self(now + *tick_us, DcEvent::Produce(p));
            }
            ProducerKind::Flow {
                tick_us,
                records_per_tick,
                record_bytes,
                prep_us,
                send_us_per_record,
                quantum_us,
                flows,
            } => {
                let (part_base, part_count, warmup) = {
                    let ts = &mut ctx.shared.tenants[t];
                    ts.metrics.frames_total += 1;
                    if now >= ts.warmup_us {
                        ts.metrics.frames_measured += 1;
                    }
                    (ts.part_base, ts.part_count, ts.warmup_us)
                };
                self.units[pid].cycles += 1;
                let nflows = flows.len() as u32;
                let st = &mut flows[pid];
                let elapsed = now - st.last_us;
                st.last_us = now;
                // Deterministic rate integration with fractional carry:
                // offered records this window, whole part emitted now,
                // fraction carried forward.
                let offered = st.clients as f64 * *records_per_tick as f64 * elapsed as f64
                    / *tick_us as f64
                    + st.carry;
                let emit = offered.floor() as u64;
                st.carry = offered - emit as f64;
                if emit > 0 {
                    // Window-midpoint creation epoch: per-record arrivals
                    // smear uniformly over the quantum, so the mean
                    // creation time of the batch is the midpoint.
                    let created = now - elapsed / 2;
                    let prep = prep_us.max(1.0).round() as u64;
                    let t_ready = now + prep;
                    // Mean client send latency as a constant offset; the
                    // send server itself stays idle (see the Flow docs).
                    let t_sent = t_ready + send_us_per_record.round() as u64;
                    // Flow `pid` owns partitions {pid, pid+nflows, ...}
                    // within the tenant slice (strided so every flow's
                    // macro-records spread over the brokers).
                    let owned = (part_count - pid as u32 + nflows - 1) / nflows;
                    let base_each = emit / owned as u64;
                    let rem = (emit % owned as u64) as u32;
                    // Rotate which partitions absorb the remainder so no
                    // partition is systematically heavier.
                    let rr = st.rr % owned;
                    st.rr = (rr + rem) % owned;
                    {
                        let ts = &mut ctx.shared.tenants[t];
                        ts.metrics.produced += emit;
                        if now >= warmup {
                            ts.metrics.hist_ingest.record_n(prep.max(1), emit);
                            // Flow send paths never overrun (N parallel
                            // clients): tick-start delay is identically ~0.
                            ts.metrics.hist_prep.record_n(1, emit);
                        }
                        ts.metrics.population.enter_n(t_sent.min(horizon), emit as i64);
                    }
                    for k in 0..owned {
                        let idx = (rr + k) % owned;
                        let recs = base_each + u64::from(k < rem);
                        if recs == 0 {
                            continue;
                        }
                        let partition = part_base + pid as u32 + idx * nflows;
                        let item = Item {
                            created_us: created,
                            ready_us: t_sent,
                            visible_us: 0,
                            bytes: recs as f64 * *record_bytes,
                            count: recs,
                            tax: TaxCell::new(created),
                        };
                        ctx.at_self(
                            t_sent + WIRE_US,
                            DcEvent::Dispatch { producer: p, partition, item },
                        );
                    }
                }
                let q = (*quantum_us).max(1);
                ctx.at_self_aligned(now + q, q, DcEvent::Produce(p));
            }
        }
    }

    /// Send one record into the fabric. `admitted` marks a record that
    /// already paid its produce quota (re-dispatched at its admission
    /// time); fresh records charge the tenant's bucket here and are
    /// deferred via [`DcEvent::DispatchAdmitted`] when over quota.
    fn dispatch(
        &mut self,
        ctx: &mut Ctx<'_, DcEvent, DcState>,
        p: u32,
        partition: u32,
        mut item: Item,
        admitted: bool,
    ) {
        let now = ctx.now();
        let t = self.tenant as usize;
        if ctx.shared.fabric.provenance_enabled() {
            // Fresh records charge any client-buffer wait since creation;
            // re-dispatched records spent the gap parked by their quota
            // bucket (the deferral below), so it lands in Throttle.
            let seg = if admitted { Segment::Throttle } else { Segment::ClientWait };
            item.tax.charge(seg, now);
        }
        let pid = p as usize;
        let partition = if partition == PARTITION_UNROUTED {
            // Random rotation at dispatch time: deterministic lockstep
            // rotation across same-cadence producers would convoy
            // consumers.
            let (base, count) = {
                let ts = &ctx.shared.tenants[t];
                (ts.part_base, ts.part_count)
            };
            base + self.units[pid].rng.below(count as u64) as u32
        } else {
            partition
        };
        let overhead = ctx.shared.tenants[t].fetch.record_overhead;
        // Macro-records pay the framing overhead once per client record
        // (`count == 1` multiplies by 1.0 — exact, the per-record path).
        let bytes = item.bytes + overhead * item.count as f64;
        if !admitted {
            let factor = ctx.shared.tenants[t].produce_charge_factor;
            if let Some(bucket) = &mut ctx.shared.tenants[t].produce_bucket {
                // Replication-aware quotas charge what the record costs
                // the shared write path (`bytes × RF`), not what it costs
                // the client NIC.
                let throttle = bucket.charge(now, bytes * factor);
                if throttle >= crate::broker::qos::NEVER_US {
                    // Zero-rate quota: the record can never be admitted.
                    // Drop it instead of parking an unreachable event in
                    // the queue for the rest of the run.
                    return;
                }
                if throttle > 0 {
                    ctx.at_self(
                        now.saturating_add(throttle),
                        DcEvent::DispatchAdmitted { producer: p, partition, item },
                    );
                    return;
                }
            }
        }
        let mut ack: Option<(u64, u64)> = None;
        let mut fire: Option<(u64, u64, u32)> = None;
        let token;
        {
            let s = &mut *ctx.shared;
            token = s.items.alloc(item);
            let leader = s.partitions[partition as usize].leader;
            let sent = s.fabric.send_grouped_classed_from(
                now,
                partition,
                leader,
                bytes,
                item.count,
                token,
                self.tenant,
                self.units[pid].node,
                &mut s.meter,
                &mut self.units[pid].nic.tx,
                &mut s.fabric_out,
            );
            if sent {
                s.tenants[t].metrics.net_tx_bytes += bytes;
                if let Some(policy) = s.tenants[t].retry {
                    // Watch for the ack: if the commit hasn't arrived
                    // by the request timeout, retransmit.
                    let seq = s.retry_seq;
                    s.retry_seq += 1;
                    s.retry_pending.insert(token, seq);
                    ack = Some((now + policy.request_timeout_us, seq));
                }
            } else if s.tenants[t].retry.is_some() {
                // Resilient client: park the record and back off
                // instead of letting the rejection stand (this was
                // attempt 1).
                fire = client_reject(s, t, token, bytes, 1, now);
            } else {
                // Fault-mode admission rejection (dead leader / ISR below
                // quorum): no commit will ever arrive for this token, so
                // the record leaves the system here — free the token and
                // balance the population the produce step entered.
                s.items.release(token);
                let horizon = s.horizon_us;
                s.tenants[t]
                    .metrics
                    .population
                    .exit_n(now.min(horizon), item.count as i64);
            }
        }
        if let Some((at, seq)) = ack {
            ctx.at_self(at, DcEvent::AckCheck { producer: p, partition, token, attempt: 1, seq });
        }
        if let Some((at, seq, attempt)) = fire {
            ctx.at_self(at, DcEvent::RetryFire { producer: p, partition, token, attempt, seq });
        }
        drain_fabric(ctx);
    }

    /// A buffered record's backoff expired: leave the client buffer and
    /// re-offer it to the fabric through the idempotent retry entry
    /// point. Retried macro-records ride the flow fast path whole
    /// (`Item.count` preserved), and their e2e clock still runs from the
    /// first attempt (`Item.created_us` is untouched in the pool).
    fn retry_fire(
        &mut self,
        ctx: &mut Ctx<'_, DcEvent, DcState>,
        p: u32,
        partition: u32,
        token: u64,
        attempt: u32,
        seq: u64,
    ) {
        let now = ctx.now();
        let t = self.tenant as usize;
        let pid = p as usize;
        let mut ack: Option<(u64, u64)> = None;
        let mut fire: Option<(u64, u64, u32)> = None;
        {
            let s = &mut *ctx.shared;
            if s.fabric.provenance_enabled() {
                // The backoff window just spent parked in the client
                // buffer is client wait; charging it here keeps the
                // commit-time reconcile residual at zero for the common
                // reject→backoff→admit path.
                s.items.get_mut(token).tax.charge(Segment::ClientWait, now);
            }
            let item = s.items.get(token);
            let overhead = s.tenants[t].fetch.record_overhead;
            let bytes = item.bytes + overhead * item.count as f64;
            let ts = &mut s.tenants[t];
            ts.retry_buffered_bytes = (ts.retry_buffered_bytes - bytes).max(0.0);
            ts.metrics.retries += item.count;
            let policy = ts.retry.expect("RetryFire on a tenant without a RetryPolicy");
            let leader = s.partitions[partition as usize].leader;
            let outcome = s.fabric.send_retry_grouped_classed_from(
                now,
                partition,
                leader,
                bytes,
                item.count,
                token,
                self.tenant,
                self.units[pid].node,
                &mut s.meter,
                &mut self.units[pid].nic.tx,
                &mut s.fabric_out,
            );
            match outcome {
                SendOutcome::Admitted => {
                    s.tenants[t].metrics.net_tx_bytes += bytes;
                    s.retry_pending.insert(token, seq);
                    ack = Some((now + policy.request_timeout_us, seq));
                }
                SendOutcome::Duplicate => {
                    // A live in-flight copy already exists at the
                    // fabric — nothing new on the wire; keep watching
                    // for its ack.
                    s.retry_pending.insert(token, seq);
                    ack = Some((now + policy.request_timeout_us, seq));
                }
                SendOutcome::Rejected => {
                    fire = client_reject(s, t, token, bytes, attempt, now);
                }
            }
        }
        if let Some((at, ack_seq)) = ack {
            ctx.at_self(
                at,
                DcEvent::AckCheck { producer: p, partition, token, attempt, seq: ack_seq },
            );
        }
        if let Some((at, next_seq, next_attempt)) = fire {
            ctx.at_self(
                at,
                DcEvent::RetryFire {
                    producer: p,
                    partition,
                    token,
                    attempt: next_attempt,
                    seq: next_seq,
                },
            );
        }
        drain_fabric(ctx);
    }

    /// Ack timeout for in-flight attempt `attempt`: if the commit still
    /// hasn't arrived, retransmit (attempt `attempt + 1`). The fabric's
    /// dedup layer keeps a retransmit racing a slow original from
    /// double-committing, and "un-loses" a record whose broker died
    /// with it in flight.
    fn ack_check(
        &mut self,
        ctx: &mut Ctx<'_, DcEvent, DcState>,
        p: u32,
        partition: u32,
        token: u64,
        attempt: u32,
        seq: u64,
    ) {
        let now = ctx.now();
        let t = self.tenant as usize;
        let pid = p as usize;
        let mut ack: Option<(u64, u32)> = None;
        let mut fire: Option<(u64, u64, u32)> = None;
        {
            let s = &mut *ctx.shared;
            if s.retry_pending.get(&token) != Some(&seq) {
                // Acked (the commit removed the entry) or superseded by
                // a newer send that reused the token: stale check.
                return;
            }
            let policy = s.tenants[t].retry.expect("AckCheck on a tenant without a RetryPolicy");
            if attempt >= policy.max_attempts {
                // Out of attempts with the ack still outstanding: stop
                // watching, but leave the record's fate to the fabric —
                // it may still commit (released then), or its broker
                // died with it and it is already counted lost.
                // Releasing the token here would hand a possibly
                // in-flight record's pool slot to a new record.
                s.retry_pending.remove(&token);
                return;
            }
            if s.fabric.provenance_enabled() {
                // The ack-timeout window counts as client wait. If the
                // slow original commits anyway, the fabric copy measured
                // the same wall-clock span — the commit-time reconcile
                // settles the double-charge back out of ClientWait.
                s.items.get_mut(token).tax.charge(Segment::ClientWait, now);
            }
            let item = s.items.get(token);
            let overhead = s.tenants[t].fetch.record_overhead;
            let bytes = item.bytes + overhead * item.count as f64;
            s.tenants[t].metrics.retries += item.count;
            let leader = s.partitions[partition as usize].leader;
            let outcome = s.fabric.send_retry_grouped_classed_from(
                now,
                partition,
                leader,
                bytes,
                item.count,
                token,
                self.tenant,
                self.units[pid].node,
                &mut s.meter,
                &mut self.units[pid].nic.tx,
                &mut s.fabric_out,
            );
            match outcome {
                SendOutcome::Admitted => {
                    s.tenants[t].metrics.net_tx_bytes += bytes;
                    ack = Some((now + policy.request_timeout_us, attempt + 1));
                }
                SendOutcome::Duplicate => {
                    ack = Some((now + policy.request_timeout_us, attempt + 1));
                }
                SendOutcome::Rejected => {
                    // Admission refused the retransmit, which implies no
                    // live fabric copy exists (an active copy would have
                    // been suppressed as Duplicate above) — safe to park
                    // the record client-side.
                    s.retry_pending.remove(&token);
                    fire = client_reject(s, t, token, bytes, attempt + 1, now);
                }
            }
        }
        if let Some((at, next_attempt)) = ack {
            ctx.at_self(
                at,
                DcEvent::AckCheck { producer: p, partition, token, attempt: next_attempt, seq },
            );
        }
        if let Some((at, next_seq, next_attempt)) = fire {
            ctx.at_self(
                at,
                DcEvent::RetryFire {
                    producer: p,
                    partition,
                    token,
                    attempt: next_attempt,
                    seq: next_seq,
                },
            );
        }
        drain_fabric(ctx);
    }
}

/// Client-side disposition of a rejected attempt `attempt` (1-based) on
/// a retry-armed tenant. Either the rejection becomes *final* (attempts
/// exhausted — the PR 7 loss path, record released and counted at the
/// fabric), or the client absorbs it: parked in the bounded retry
/// buffer for a deterministic backoff (returns the
/// `(fire_at, seq, next_attempt)` to schedule), or — buffer full —
/// dropped at the client and counted (`client_dropped`).
fn client_reject(
    s: &mut DcState,
    t: usize,
    token: u64,
    bytes: f64,
    attempt: u32,
    now: u64,
) -> Option<(u64, u64, u32)> {
    let count = s.items.get(token).count;
    let horizon = s.horizon_us;
    let policy = s.tenants[t].retry.expect("client_reject on a tenant without a RetryPolicy");
    if attempt >= policy.max_attempts {
        // Final rejection: the record leaves the system exactly as a
        // retry-free client's would.
        s.items.release(token);
        s.tenants[t].metrics.population.exit_n(now.min(horizon), count as i64);
        return None;
    }
    if s.tenants[t].retry_buffered_bytes + bytes > policy.buffer_bytes {
        // Buffer overflow: graceful degradation, measured. The
        // rejection is still absorbed (it is not final — the client
        // converted it into a client-side drop).
        let m = &mut s.tenants[t].metrics;
        m.absorbed_rejects += count;
        m.client_dropped += count;
        m.population.exit_n(now.min(horizon), count as i64);
        s.items.release(token);
        return None;
    }
    let ts = &mut s.tenants[t];
    ts.metrics.absorbed_rejects += count;
    ts.retry_buffered_bytes += bytes;
    let seq = s.retry_seq;
    s.retry_seq += 1;
    Some((now + policy.backoff_us(attempt, seq), seq, attempt + 1))
}

impl Component<DcEvent, DcState> for ProducerClient {
    fn on_event(&mut self, ctx: &mut Ctx<'_, DcEvent, DcState>, ev: DcEvent) {
        match ev {
            DcEvent::Produce(p) => self.produce(ctx, p),
            DcEvent::Dispatch { producer, partition, item } => {
                self.dispatch(ctx, producer, partition, item, false)
            }
            DcEvent::DispatchAdmitted { producer, partition, item } => {
                self.dispatch(ctx, producer, partition, item, true)
            }
            DcEvent::RetryFire { producer, partition, token, attempt, seq } => {
                self.retry_fire(ctx, producer, partition, token, attempt, seq)
            }
            DcEvent::AckCheck { producer, partition, token, attempt, seq } => {
                self.ack_check(ctx, producer, partition, token, attempt, seq)
            }
            _ => debug_assert!(false, "unexpected event for ProducerClient"),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

// ---------------------------------------------------------------------------
// ConsumerPoller
// ---------------------------------------------------------------------------

/// Consumer-side service-time model.
pub enum ServiceModel {
    /// Identification on a 1-core container.
    FaceRec(StageModel),
    /// Log-normal service (ObjDet R-CNN detection — already divided by
    /// the acceleration factor —, training steps, RPC handlers).
    Lognormal { mean_us: f64, cv: f64 },
}

/// Per-consumer container state.
pub struct ConsumerUnit {
    pub rng: Rng,
    /// Host NIC: fetch responses land on `nic.rx` (bit-exact the old
    /// single FIFO server); the tx direction is idle on consumers.
    pub nic: Nic,
    pub done: u64,
    /// Network node id on the contention-aware fabric (see
    /// [`ProducerUnit::node`]).
    pub node: u32,
}

/// One tenant's consumer fleet: poll scheduling, fetch, serial service.
pub struct ConsumerPoller {
    tenant: u8,
    service: ServiceModel,
    units: Vec<ConsumerUnit>,
    /// Global partition ids owned by each tenant-local consumer.
    owned: Vec<Vec<u32>>,
    /// Fetch scratch, reused across polls so the steady-state fetch path
    /// allocates nothing: items grouped as per-partition runs, each run
    /// kept sorted by `ready_us` while it is collected.
    fetched: Vec<Item>,
    /// Scratch: half-open `[head, end)` bounds of each run in `fetched`;
    /// `head` advances as the serve loop merges the runs.
    runs: Vec<(u32, u32)>,
    /// Scratch, parallel to `runs`: fetch-transfer completion time of
    /// each run's partition (latency provenance: the serve loop charges
    /// `[poll, run_done]` to [`Segment::Fetch`]).
    run_done: Vec<u64>,
}

impl ConsumerPoller {
    fn new(
        tenant: u8,
        service: ServiceModel,
        units: Vec<ConsumerUnit>,
        owned: Vec<Vec<u32>>,
    ) -> ConsumerPoller {
        ConsumerPoller {
            tenant,
            service,
            units,
            owned,
            fetched: Vec::new(),
            runs: Vec::new(),
            run_done: Vec::new(),
        }
    }

    /// Consumers that have completed at least one item (debug telemetry).
    pub fn active_units(&self) -> usize {
        self.units.iter().filter(|u| u.done > 0).count()
    }

    fn poll(&mut self, ctx: &mut Ctx<'_, DcEvent, DcState>, c: u32) {
        let now = ctx.now();
        let t = self.tenant as usize;
        let cid = c as usize;
        {
            let gate = &mut ctx.shared.tenants[t].gates[cid];
            gate.poll_scheduled = false;
            if now < gate.busy_until {
                gate.poll_scheduled = true;
                let busy = gate.busy_until;
                ctx.at_self(busy, DcEvent::Poll(c));
                return;
            }
            // Fetch-quota mute (QoS): the channel stays silent until the
            // previous fetch's throttle delay has elapsed.
            if now < gate.throttled_until {
                gate.poll_scheduled = true;
                let until = gate.throttled_until;
                ctx.at_self(until, DcEvent::Poll(c));
                return;
            }
        }
        let fetch = ctx.shared.tenants[t].fetch;
        // Gather visible records across owned partitions.
        let mut avail_bytes = 0.0;
        let mut oldest_visible = u64::MAX;
        for &pi in &self.owned[cid] {
            for it in ctx.shared.partitions[pi as usize].queue.iter() {
                if it.visible_us <= now {
                    avail_bytes += it.bytes + fetch.record_overhead * it.count as f64;
                    oldest_visible = oldest_visible.min(it.visible_us);
                } else {
                    break;
                }
            }
        }
        if avail_bytes == 0.0 {
            return; // a commit will wake us through the gate
        }
        // fetch.min.bytes / fetch.max.wait withholding (§5.5).
        if (avail_bytes as usize) < fetch.fetch_min_bytes {
            let deadline = oldest_visible + fetch.fetch_max_wait_us;
            if now < deadline {
                ctx.shared.tenants[t].gates[cid].poll_scheduled = true;
                ctx.at_self(deadline, DcEvent::Poll(c));
                return;
            }
        }
        // Fetch all visible records per owned partition. Each partition's
        // run is kept sorted by producer-ready time as it is collected
        // (the committed queues are nearly ready-ordered already, so the
        // insertion point is almost always the run's tail); the scratch
        // buffers are reused across polls, so the steady-state fetch path
        // allocates nothing.
        self.fetched.clear();
        self.runs.clear();
        self.run_done.clear();
        let mut deliver_at = now;
        let mut fetched_bytes = 0.0;
        for &pi in &self.owned[cid] {
            let run_start = self.fetched.len();
            let mut part_bytes = 0.0;
            let leader;
            {
                let part = &mut ctx.shared.partitions[pi as usize];
                leader = part.leader;
                while let Some(it) = part.queue.front() {
                    if it.visible_us > now {
                        break;
                    }
                    let it_bytes = it.bytes + fetch.record_overhead * it.count as f64;
                    // Per-partition fetch cap: stop once this poll's take
                    // from the partition would exceed the cap (always at
                    // least one record); the end-of-serve re-poll drains
                    // the remainder as its own bounded request.
                    if part_bytes > 0.0
                        && part_bytes + it_bytes > fetch.max_partition_fetch_bytes as f64
                    {
                        break;
                    }
                    part_bytes += it_bytes;
                    let item = *it;
                    part.queue.pop_front();
                    let mut at = self.fetched.len();
                    while at > run_start && self.fetched[at - 1].ready_us > item.ready_us {
                        at -= 1;
                    }
                    self.fetched.insert(at, item);
                }
            }
            if self.fetched.len() > run_start {
                self.runs.push((run_start as u32, self.fetched.len() as u32));
                let s = &mut *ctx.shared;
                s.tenants[t].metrics.net_rx_bytes += part_bytes;
                fetched_bytes += part_bytes;
                // The global partition id is the read-path group key, so
                // a lagging consumer's fetch is split against what is
                // actually still cached for *this* partition.
                let done = s.fabric.fetch_group_classed_to(
                    now,
                    leader,
                    pi,
                    part_bytes,
                    self.tenant,
                    self.units[cid].node,
                    &mut self.units[cid].nic.rx,
                    &mut s.meter,
                    &mut s.fabric_out,
                );
                self.run_done.push(done);
                deliver_at = deliver_at.max(done);
            }
        }
        // Fetch responses on the contention-aware network queue link
        // release (and re-estimate) events; flush them into the world.
        // A no-op — `fabric_out` stays empty — when the network is off.
        drain_fabric(ctx);
        if self.fetched.is_empty() {
            return;
        }
        // Charge the fetch quota (QoS): over-quota fetches mute this
        // consumer's poll loop for the throttle delay.
        let throttled_until = match &mut ctx.shared.tenants[t].fetch_bucket {
            Some(bucket) => {
                let throttle = bucket.charge(now, fetched_bytes);
                if throttle > 0 { now.saturating_add(throttle) } else { 0 }
            }
            None => 0,
        };
        // Serve each record serially on the 1-core container, oldest
        // producer-ready first: a stable k-way merge across the sorted
        // per-partition runs (ties pick the earliest run, then queue
        // order), which reproduces the old global stable sort record for
        // record without re-sorting the already-sorted runs.
        let horizon = ctx.shared.horizon_us;
        let mut busy = ctx.shared.tenants[t].gates[cid].busy_until.max(deliver_at);
        let is_facerec = matches!(self.service, ServiceModel::FaceRec(_));
        let provenance = ctx.shared.fabric.provenance_enabled();
        for _ in 0..self.fetched.len() {
            let mut best: Option<usize> = None;
            let mut best_key = 0u64;
            for (ri, &(head, end)) in self.runs.iter().enumerate() {
                if head < end {
                    let key = self.fetched[head as usize].ready_us;
                    if best.is_none() || key < best_key {
                        best_key = key;
                        best = Some(ri);
                    }
                }
            }
            let best = best.expect("merge invariant: an unexhausted run remains");
            let head = self.runs[best].0;
            self.runs[best].0 += 1;
            let it = self.fetched[head as usize];
            let start = busy;
            let wait_us = start.saturating_sub(it.ready_us);
            let k = it.count;
            // A macro-record occupies the container for k records' worth
            // of mean service time (deterministic — the fluid path draws
            // no RNG); a plain record takes the exact per-record draw.
            let dur = if k <= 1 {
                match &self.service {
                    ServiceModel::FaceRec(stages) => stages.identify(&mut self.units[cid].rng),
                    ServiceModel::Lognormal { mean_us, cv } => self.units[cid]
                        .rng
                        .lognormal_mean_cv(*mean_us, *cv)
                        .round()
                        .max(1.0) as u64,
                }
            } else {
                match &self.service {
                    // Flow mode is tick-workload-only (asserted at build).
                    ServiceModel::FaceRec(_) => unreachable!("flow macro-record on facerec"),
                    ServiceModel::Lognormal { mean_us, .. } => {
                        (*mean_us * k as f64).round().max(1.0) as u64
                    }
                }
            };
            busy = start + dur;
            // Latency provenance: finish the ledger on a local copy of
            // the record's cell (`it` is the serve-loop copy; the pool
            // slot is long released). The chain is monotone — visible ≤
            // poll ≤ fetch-done ≤ service-start ≤ service-end — so the
            // telescoping charges partition [created, busy] exactly.
            let mut cell = it.tax;
            if provenance {
                let paused = pause_overlap(&ctx.shared.rebalance_pauses, cell.last_us, now);
                cell.charge_split(Segment::Rebalance, paused, Segment::BrokerWait, now);
                cell.charge(Segment::Fetch, self.run_done[best]);
                cell.charge(Segment::BrokerWait, start);
                cell.charge(Segment::Service, busy);
            }
            self.units[cid].done += k;
            let ts = &mut ctx.shared.tenants[t];
            ts.metrics.population.exit_n(busy.min(horizon), k as i64);
            ts.metrics.completed += k;
            if busy >= ts.warmup_us && busy <= horizon {
                ts.metrics.completed_in_window += k;
            }
            let in_window = it.created_us >= ts.warmup_us && busy <= horizon;
            if in_window {
                ts.metrics.hist_wait.record_n(wait_us.max(1), k);
                if is_facerec {
                    ts.metrics.hist_service.record(dur.max(1));
                } else if k <= 1 {
                    ts.metrics.hist_service.record(dur);
                } else {
                    // Per-record service value, weighted by the records
                    // the macro stands for.
                    ts.metrics
                        .hist_service
                        .record_n(((dur as f64 / k as f64).round() as u64).max(1), k);
                }
                let e2e = busy - it.created_us;
                ts.metrics.hist_e2e.record_n(e2e.max(1), k);
                if let Some(tb) = ts.metrics.tax.as_mut() {
                    tb.record(&cell, e2e, k);
                }
                if let Some((ws, we)) = ts.observe_window {
                    if it.created_us >= ws && it.created_us <= we {
                        ts.metrics.hist_e2e_window.record_n(e2e.max(1), k);
                    }
                }
                let sec = (it.created_us / 1_000_000) as usize;
                if sec < ts.metrics.lat_sum.len() {
                    ts.metrics.lat_sum[sec] += e2e * k;
                    ts.metrics.lat_n[sec] += k;
                }
            }
            if provenance && in_window {
                if let Some(tr) = ctx.shared.trace.as_mut() {
                    tr.record_span(self.tenant, it.created_us, &cell);
                }
            }
        }
        {
            let gate = &mut ctx.shared.tenants[t].gates[cid];
            gate.busy_until = busy;
            gate.throttled_until = throttled_until;
            gate.poll_scheduled = true;
        }
        // Immediately look for more work when we free up (or when the
        // fetch-quota mute expires, whichever is later).
        ctx.at_self(busy.max(throttled_until), DcEvent::Poll(c));
    }
}

/// Microseconds of `[lo, hi)` covered by the rebalance-pause windows.
/// Windows from elections less than a pause apart can overlap; the
/// `charge_split` consuming this clamps to the interval length, so an
/// over-estimate here can never inflate a record's total.
fn pause_overlap(windows: &[(u64, u64)], lo: u64, hi: u64) -> u64 {
    let mut total = 0;
    for &(ws, we) in windows {
        let a = ws.max(lo);
        let b = we.min(hi);
        if b > a {
            total += b - a;
        }
    }
    total
}

impl Component<DcEvent, DcState> for ConsumerPoller {
    fn on_event(&mut self, ctx: &mut Ctx<'_, DcEvent, DcState>, ev: DcEvent) {
        match ev {
            DcEvent::Poll(c) => self.poll(ctx, c),
            _ => debug_assert!(false, "unexpected event for ConsumerPoller"),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

// ---------------------------------------------------------------------------
// World assembly
// ---------------------------------------------------------------------------

/// The shared broker substrate for a world (one per simulation, even with
/// multiple tenants).
#[derive(Clone, Debug)]
pub struct FabricSpec {
    pub brokers: usize,
    pub drives_per_broker: usize,
    pub replication: usize,
    pub nvme: NvmeSpec,
    pub effective_write_bw: f64,
    pub net_bw: f64,
    pub tuning: KafkaTuning,
    /// Per-broker page-cache capacity for the measured read path;
    /// `None` (the default) keeps the seed's hardcoded cache hits.
    pub read_cache_bytes: Option<f64>,
    /// World-level fault schedule + membership policy; `None` (the
    /// default) is the immortal fabric bit for bit. `Some` installs the
    /// fault machinery even when the event list is empty — the
    /// installed-but-inert case `tests/failover_differential.rs` pins
    /// bit-exact against `None`.
    pub faults: Option<FaultPlan>,
    /// Contention-aware ToR/spine network ([`Fabric::enable_network`]);
    /// `None` (the default) keeps every wire hop at the fixed transit,
    /// bit for bit (pinned by `tests/net_differential.rs`).
    pub network: Option<NetworkSpec>,
    /// Latency provenance: charge every [`Item`]'s per-segment tax cell
    /// at each hop and arm the per-tenant [`TaxBreakdown`]. `false` (the
    /// default) takes none of the charging branches — the record flow is
    /// bit-exact (pinned by `tests/tax_differential.rs`).
    pub provenance: bool,
    /// Opt-in flight recorder; implies nothing unless [`Self::provenance`]
    /// is also set (spans come from tax cells). World instants (faults,
    /// elections, net epochs) record whenever the recorder exists.
    pub trace: Option<TraceSpec>,
}

impl FabricSpec {
    /// Derive the fabric of a single-tenant run from its config.
    pub fn from_config(cfg: &Config) -> FabricSpec {
        let d = &cfg.deployment;
        FabricSpec {
            brokers: d.brokers,
            drives_per_broker: d.drives_per_broker,
            replication: d.replication,
            nvme: cfg.node.nvme,
            effective_write_bw: cfg.calibration.broker_write_capacity(
                cfg.node.nvme.write_bw,
                d.drives_per_broker,
                d.brokers,
            ),
            net_bw: cfg.node.net_bw,
            tuning: cfg.tuning,
            read_cache_bytes: None,
            faults: None,
            network: None,
            provenance: false,
            trace: None,
        }
    }

    /// Enable the measured read path with a per-broker page cache of
    /// `bytes` (see [`Fabric::enable_read_path`]).
    pub fn with_read_cache(mut self, bytes: f64) -> FabricSpec {
        self.read_cache_bytes = Some(bytes);
        self
    }

    /// Install a [`FaultPlan`] (see [`Fabric::enable_faults`]); its
    /// events are scheduled into the world at build time.
    pub fn with_faults(mut self, plan: FaultPlan) -> FabricSpec {
        self.faults = Some(plan);
        self
    }

    /// Route every wire hop over a two-tier ToR/spine network derived
    /// from `topo`'s switch radix: racks hold `ports_per_switch / 2`
    /// nodes on `link_bw` access links, and each rack's spine uplink
    /// carries `rack capacity / oversub` — `oversub > 1` is the classic
    /// oversubscribed fat-tree edge. See [`Fabric::enable_network`].
    pub fn with_network(mut self, topo: &FatTree, oversub: f64, link_bw: f64) -> FabricSpec {
        self.network = Some(NetworkSpec::from_fat_tree(topo, oversub, link_bw));
        self
    }

    /// [`FabricSpec::with_network`] from an explicit [`NetworkSpec`]
    /// (rack size and placement control).
    pub fn with_network_spec(mut self, spec: NetworkSpec) -> FabricSpec {
        self.network = Some(spec);
        self
    }

    /// Arm latency provenance (per-record tax cells + per-tenant
    /// [`TaxBreakdown`]).
    pub fn with_provenance(mut self) -> FabricSpec {
        self.provenance = true;
        self
    }

    /// Install the flight recorder (see [`TraceRecorder`]).
    pub fn with_trace(mut self, spec: TraceSpec) -> FabricSpec {
        self.trace = Some(spec);
        self
    }

    fn build(&self) -> Fabric {
        let mut fabric = Fabric::new(
            self.brokers,
            self.drives_per_broker,
            self.replication,
            self.nvme,
            self.effective_write_bw,
            self.net_bw,
            self.tuning,
        );
        if let Some(bytes) = self.read_cache_bytes {
            fabric.enable_read_path(bytes);
        }
        if let Some(plan) = &self.faults {
            fabric.enable_faults(plan.min_isr, plan.recovery_bytes_per_sec);
            fabric.set_election(plan.election);
            if plan.idempotent {
                fabric.enable_dedup();
            }
        }
        if self.provenance {
            fabric.enable_provenance();
        }
        fabric
    }
}

/// One tenant's workload definition for [`build`].
pub struct TenantSpec<'a> {
    pub kind: WorkloadKind,
    pub cfg: &'a Config,
}

/// Assemble a world: shared fabric + per-tenant producer/poller
/// components, partitions, gates, and initial events.
///
/// Tenants are built strictly in order, each from its own master RNG
/// (seeded exactly as the legacy simulators did), so a single-tenant
/// world reproduces the legacy event and RNG sequences verbatim.
pub fn build(tenants: &[TenantSpec<'_>], fabric: &FabricSpec, horizon_us: u64) -> World<DcEvent, DcState> {
    build_with_qos(tenants, fabric, None, horizon_us)
}

/// [`build`] with an optional broker QoS policy: tenant `i` is scheduling
/// class `i`. Installs the weighted request-CPU scheduler on the fabric
/// (when the policy carries weights) and the per-tenant produce/fetch
/// token buckets. `None` is bit-identical to [`build`].
pub fn build_with_qos(
    tenants: &[TenantSpec<'_>],
    fabric: &FabricSpec,
    qos: Option<&QosPolicy>,
    horizon_us: u64,
) -> World<DcEvent, DcState> {
    let mut meter = BandwidthMeter::new();
    meter.set_nodes(
        Class::Producer,
        tenants.iter().map(|t| t.cfg.deployment.producers).sum(),
    );
    meter.set_nodes(
        Class::Consumer,
        tenants.iter().map(|t| t.cfg.deployment.consumers).sum(),
    );
    meter.set_nodes(Class::Broker, fabric.brokers);

    let mut partitions: Vec<PartitionQueue> = Vec::new();
    let mut tenant_states: Vec<TenantState> = Vec::new();
    for (tenant, spec) in tenants.iter().enumerate() {
        let d = &spec.cfg.deployment;
        let part_base = partitions.len() as u32;
        for p in 0..d.partitions {
            partitions.push(PartitionQueue {
                tenant: tenant as u8,
                leader: (p % fabric.brokers) as u32,
                consumer: (p % d.consumers) as u32,
                queue: VecDeque::new(),
            });
        }
        let cap = spec.cfg.tuning.max_partition_fetch_bytes;
        let fetch = match spec.kind {
            WorkloadKind::FaceRec => FetchTuning {
                record_overhead: FACEREC_RECORD_OVERHEAD,
                fetch_min_bytes: spec.cfg.tuning.fetch_min_bytes,
                fetch_max_wait_us: spec.cfg.tuning.fetch_max_wait_us,
                max_partition_fetch_bytes: cap,
            },
            WorkloadKind::ObjDet => {
                let od = &spec.cfg.calibration.objdet;
                FetchTuning {
                    record_overhead: 0.0,
                    fetch_min_bytes: od.fetch_min_bytes,
                    fetch_max_wait_us: od.fetch_max_wait_us,
                    max_partition_fetch_bytes: cap,
                }
            }
            WorkloadKind::TrainIngest => {
                let tr = &spec.cfg.calibration.train;
                FetchTuning {
                    record_overhead: 0.0,
                    fetch_min_bytes: tr.fetch_min_bytes,
                    fetch_max_wait_us: tr.fetch_max_wait_us,
                    max_partition_fetch_bytes: cap,
                }
            }
            WorkloadKind::Rpc => {
                let rpc = &spec.cfg.calibration.rpc;
                FetchTuning {
                    record_overhead: 0.0,
                    fetch_min_bytes: rpc.fetch_min_bytes,
                    fetch_max_wait_us: rpc.fetch_max_wait_us,
                    max_partition_fetch_bytes: cap,
                }
            }
        };
        let quota = qos.map(|p| p.quota(tenant)).unwrap_or_default();
        // Catch-up scenarios: a tenant whose consumers start
        // `consumer_lag_start_us` behind sleeps through that window (the
        // gate defers the first poll), then drains its backlog — through
        // cold device reads once the backlog ages out of the page-cache
        // window. Zero (the default) is the all-zero `ConsumerGate`.
        let lag_gate = ConsumerGate {
            busy_until: spec.cfg.consumer_lag_start_us,
            ..ConsumerGate::default()
        };
        tenant_states.push(TenantState {
            kind: spec.kind,
            fetch,
            gates: vec![lag_gate; d.consumers],
            metrics: TenantMetrics::new(horizon_us),
            part_base,
            part_count: d.partitions as u32,
            warmup_us: (horizon_us as f64 * spec.cfg.warmup_frac) as u64,
            producer_comp: CompId::INVALID,
            poller_comp: CompId::INVALID,
            produce_bucket: quota.produce_bucket(),
            produce_charge_factor: if quota.replication_aware {
                fabric.replication as f64
            } else {
                1.0
            },
            fetch_bucket: quota.fetch_bucket(),
            observe_window: spec.cfg.observe_window_us,
            retry: spec.cfg.retry_policy(),
            retry_buffered_bytes: 0.0,
        });
    }
    if fabric.provenance {
        for ts in &mut tenant_states {
            ts.metrics.tax = Some(TaxBreakdown::new());
        }
    }
    let retry_armed = tenant_states.iter().any(|ts| ts.retry.is_some());

    let mut shared_fabric = fabric.build();
    if retry_armed {
        // Client retries require idempotent commits: a retransmit
        // racing a slow ack would otherwise be admitted as a second
        // live copy of the same token and double-commit it. The dedup
        // scan lives in the fault layer, so a retry-armed world arms it
        // even under an empty schedule (pinned observationally inert by
        // `tests/failover_differential.rs`).
        if !shared_fabric.faults_enabled() {
            let defaults = FaultPlan::new();
            shared_fabric.enable_faults(defaults.min_isr, defaults.recovery_bytes_per_sec);
        }
        shared_fabric.enable_dedup();
    }
    if let Some(weights) = qos.and_then(|p| p.cpu_weights.as_deref()) {
        shared_fabric.enable_weighted_cpu(weights);
    }
    if let Some(weights) = qos.and_then(|p| p.storage_weights.as_deref()) {
        shared_fabric.enable_storage_qos(weights);
    }
    if let Some(spec) = fabric.network {
        // Client node count must match the ids handed out below:
        // producer units then consumer units, tenant by tenant.
        let clients: usize = tenants
            .iter()
            .map(|t| producer_unit_count(t.cfg) + t.cfg.deployment.consumers)
            .sum();
        shared_fabric.enable_network(spec, clients);
    }
    let state = DcState {
        fabric: shared_fabric,
        meter,
        partitions,
        items: ItemPool::default(),
        fabric_out: Vec::new(),
        tenants: tenant_states,
        fabric_comp: CompId::INVALID,
        horizon_us,
        retry_armed,
        retry_pending: HashMap::new(),
        retry_seq: 1,
        rebalance_pauses: Vec::new(),
        trace: fabric.trace.map(TraceRecorder::new),
    };
    let mut world = World::new(state);

    // Network node ids: brokers occupy 0..B; every client unit gets the
    // next id in world build order (producers then consumers, tenant by
    // tenant) — the order `producer_unit_count` mirrors above.
    let mut next_node = fabric.brokers as u32;
    for (tenant, spec) in tenants.iter().enumerate() {
        let cfg = spec.cfg;
        let d = &cfg.deployment;
        match spec.kind {
            WorkloadKind::FaceRec => {
                assert_eq!(
                    cfg.flow_clients, 0,
                    "flow aggregation (flow_clients) supports tick workloads only"
                );
                let stages = StageModel::new(cfg.calibration.stages, cfg.accel, cfg.protocol);
                let mut master = Rng::new(cfg.seed);
                // Acceleration-emulation runs use 1 face/frame (§5.3);
                // otherwise every producer replays the same video, so face
                // surges come from one shared burst timeline (§3.3, Fig 7).
                let one_face = matches!(cfg.protocol, AccelProtocol::Emulation)
                    && d.producers == crate::config::Deployment::facerec_accel().producers;
                let schedule = (!one_face).then(|| {
                    BurstSchedule::new(
                        cfg.calibration.faces.clone(),
                        horizon_us + crate::util::units::SEC,
                        &mut master,
                    )
                });
                let units =
                    producer_units(&mut master, d.producers, cfg.node.net_bw, &mut next_node);
                let consumers =
                    consumer_units(&mut master, d.consumers, cfg.node.net_bw, &mut next_node);

                let cycle =
                    stages.producer_cycle_mean_us(cfg.calibration.faces.mean_faces) as u64;
                let producer = world.add(Box::new(ProducerClient {
                    tenant: tenant as u8,
                    kind: ProducerKind::FaceRec {
                        stages,
                        schedule,
                        linger_us: cfg.tuning.linger_us,
                        face_bytes: cfg.face_bytes,
                    },
                    units,
                }));
                let owned = owned_partitions(&world.shared, tenant);
                let poller = world.add(Box::new(ConsumerPoller::new(
                    tenant as u8,
                    ServiceModel::FaceRec(stages),
                    consumers,
                    owned,
                )));
                world.shared.tenants[tenant].producer_comp = producer;
                world.shared.tenants[tenant].poller_comp = poller;
                for p in 0..d.producers {
                    // Stagger starts across one mean cycle to avoid a herd.
                    let jitter = (p as u64 * cycle.max(1)) / d.producers as u64;
                    world.schedule(jitter, producer, DcEvent::Produce(p as u32));
                }
            }
            WorkloadKind::ObjDet => {
                let od: &ObjDetCosts = &cfg.calibration.objdet;
                let k = cfg.accel;
                // Effective per-frame send cost with Kafka's batching
                // amortization (§6.3: "producers and the brokers manage to
                // intelligently batch").
                let send_us_per_frame = od.send_frame_us * (1.0 - od.batch_amort)
                    + od.send_frame_us * od.batch_amort / k;
                add_tick_tenant(
                    &mut world,
                    tenant,
                    cfg,
                    cfg.seed ^ 0x0BDE7,
                    &mut next_node,
                    ProducerKind::Tick {
                        tick_us: od.tick_us,
                        // Emulation protocol: ingestion and detection
                        // compute divide by k; k frames per 30 FPS tick.
                        records_per_tick: k.round().max(1.0) as usize,
                        record_bytes: od.frame_bytes + OBJDET_RECORD_OVERHEAD,
                        bytes_cv: 0.0,
                        prep_us: od.ingest_us / k,
                        prep_cv: 0.15,
                        send_us_per_record: send_us_per_frame,
                    },
                    ServiceModel::Lognormal {
                        mean_us: od.detect_us / k,
                        cv: od.detect_cv,
                    },
                );
            }
            WorkloadKind::TrainIngest => {
                let tr: &TrainCosts = &cfg.calibration.train;
                add_tick_tenant(
                    &mut world,
                    tenant,
                    cfg,
                    cfg.seed ^ 0x7EA17,
                    &mut next_node,
                    ProducerKind::Tick {
                        tick_us: tr.tick_us,
                        records_per_tick: tr.batches_per_tick,
                        record_bytes: tr.batch_bytes,
                        bytes_cv: tr.bytes_cv,
                        prep_us: tr.prep_us,
                        prep_cv: tr.prep_cv,
                        send_us_per_record: tr.send_batch_us,
                    },
                    ServiceModel::Lognormal { mean_us: tr.step_us, cv: tr.step_cv },
                );
            }
            WorkloadKind::Rpc => {
                let rpc: &RpcCosts = &cfg.calibration.rpc;
                add_tick_tenant(
                    &mut world,
                    tenant,
                    cfg,
                    cfg.seed ^ 0x59C5,
                    &mut next_node,
                    ProducerKind::Tick {
                        tick_us: rpc.period_us,
                        records_per_tick: 1,
                        record_bytes: rpc.request_bytes,
                        bytes_cv: rpc.bytes_cv,
                        prep_us: rpc.prep_us,
                        prep_cv: rpc.prep_cv,
                        send_us_per_record: rpc.send_request_us,
                    },
                    ServiceModel::Lognormal { mean_us: rpc.handle_us, cv: rpc.handle_cv },
                );
            }
        }
    }

    let fault_events = fabric
        .faults
        .as_ref()
        .map(|plan| plan.events.clone())
        .unwrap_or_default();
    let fabric_comp = world.add(Box::new(FabricHub { faults: fault_events.clone() }));
    world.shared.fabric_comp = fabric_comp;
    for (i, ev) in fault_events.iter().enumerate() {
        world.schedule(ev.at_us(), fabric_comp, DcEvent::Fault(i as u32));
    }
    world
}

/// Register a [`ProducerKind::Tick`] tenant (Object Detection, training
/// ingest, RPC): producer + poller components, comp-id wiring, and
/// jittered initial ticks. Kept as one helper so the registration order
/// — which the determinism contract depends on — cannot diverge between
/// tick workloads.
///
/// When `cfg.flow_clients > 0` the tick producer fleet is replaced by a
/// small set of [`ProducerKind::Flow`] rate processes aggregating that
/// client population (`cfg.flow_processes` flows, default
/// `min(partitions, 32)`), waking on the shared `cfg.flow_quantum_us`
/// grid. `flow_clients == 0` (the default) is the unchanged per-record
/// path, bit for bit.
fn add_tick_tenant(
    world: &mut World<DcEvent, DcState>,
    tenant: usize,
    cfg: &Config,
    seed: u64,
    next_node: &mut u32,
    kind: ProducerKind,
    service: ServiceModel,
) {
    let d = &cfg.deployment;
    let net_bw = cfg.node.net_bw;
    let &ProducerKind::Tick {
        tick_us,
        records_per_tick,
        record_bytes,
        prep_us,
        send_us_per_record,
        ..
    } = &kind
    else {
        unreachable!("add_tick_tenant requires ProducerKind::Tick");
    };
    let mut master = Rng::new(seed);
    if cfg.flow_clients > 0 {
        // Hybrid fluid mode: up to 32 flows (never more than partitions
        // or clients) each owning a strided partition subset, so the
        // aggregate ~N× byte stream spreads over many producer NICs
        // instead of falsely bottlenecking on one.
        let clients = cfg.flow_clients;
        let auto = d.partitions.min(32);
        let nflows = (if cfg.flow_processes > 0 { cfg.flow_processes } else { auto })
            .min(d.partitions)
            .max(1)
            .min(clients as usize);
        let flows: Vec<FlowState> = (0..nflows as u64)
            .map(|f| FlowState {
                clients: clients / nflows as u64 + u64::from(f < clients % nflows as u64),
                carry: 0.0,
                last_us: 0,
                rr: 0,
            })
            .collect();
        let units = producer_units(&mut master, nflows, net_bw, next_node);
        let consumers = consumer_units(&mut master, d.consumers, net_bw, next_node);
        let producer = world.add(Box::new(ProducerClient {
            tenant: tenant as u8,
            kind: ProducerKind::Flow {
                tick_us,
                records_per_tick,
                record_bytes,
                prep_us,
                send_us_per_record,
                quantum_us: cfg.flow_quantum_us.max(1),
                flows: flows.clone(),
            },
            units,
        }));
        let owned = owned_partitions(&world.shared, tenant);
        let poller = world.add(Box::new(ConsumerPoller::new(
            tenant as u8,
            service,
            consumers,
            owned,
        )));
        world.shared.tenants[tenant].producer_comp = producer;
        world.shared.tenants[tenant].poller_comp = poller;
        for (f, st) in flows.iter().enumerate() {
            // A zero-client flow emits nothing, ever: schedule no wake.
            if st.clients > 0 {
                world.schedule(0, producer, DcEvent::Produce(f as u32));
            }
        }
        return;
    }
    let units = producer_units(&mut master, d.producers, net_bw, next_node);
    let consumers = consumer_units(&mut master, d.consumers, net_bw, next_node);
    let producer = world.add(Box::new(ProducerClient {
        tenant: tenant as u8,
        kind,
        units,
    }));
    let owned = owned_partitions(&world.shared, tenant);
    let poller = world.add(Box::new(ConsumerPoller::new(
        tenant as u8,
        service,
        consumers,
        owned,
    )));
    world.shared.tenants[tenant].producer_comp = producer;
    world.shared.tenants[tenant].poller_comp = poller;
    for p in 0..d.producers {
        let jitter = (p as u64 * tick_us) / d.producers as u64;
        world.schedule(jitter, producer, DcEvent::Produce(p as u32));
    }
}

fn producer_units(
    master: &mut Rng,
    count: usize,
    net_bw: f64,
    next_node: &mut u32,
) -> Vec<ProducerUnit> {
    (0..count)
        .map(|_| {
            let node = *next_node;
            *next_node += 1;
            ProducerUnit {
                rng: master.fork(),
                nic: Nic::new(net_bw),
                send: FifoServer::new(1e6, 0),
                cycles: 0,
                node,
            }
        })
        .collect()
}

fn consumer_units(
    master: &mut Rng,
    count: usize,
    net_bw: f64,
    next_node: &mut u32,
) -> Vec<ConsumerUnit> {
    (0..count)
        .map(|_| {
            let node = *next_node;
            *next_node += 1;
            ConsumerUnit { rng: master.fork(), nic: Nic::new(net_bw), done: 0, node }
        })
        .collect()
}

/// Producer units a tenant will create — must mirror the branch in
/// [`add_tick_tenant`] exactly, because [`Fabric::enable_network`] sizes
/// the node table from this count *before* the units exist.
fn producer_unit_count(cfg: &Config) -> usize {
    let d = &cfg.deployment;
    if cfg.flow_clients > 0 {
        let auto = d.partitions.min(32);
        (if cfg.flow_processes > 0 { cfg.flow_processes } else { auto })
            .min(d.partitions)
            .max(1)
            .min(cfg.flow_clients as usize)
    } else {
        d.producers
    }
}

/// Compact, workload-agnostic per-tenant results view — the common
/// denominator of the per-workload reports, used by the N-tenant registry
/// (`pipeline::mixed`) and the QoS experiment's p99-vs-share sweeps.
#[derive(Clone, Debug)]
pub struct TenantSummary {
    pub name: String,
    pub kind: WorkloadKind,
    pub produced: u64,
    pub completed: u64,
    /// Completions per second inside the measurement window.
    pub throughput_per_sec: f64,
    /// Broker wait (ready → service start).
    pub wait_mean_us: f64,
    pub wait_p99_us: u64,
    pub e2e_mean_us: f64,
    pub e2e_p99_us: u64,
    /// End-to-end p99 over items created inside the tenant's
    /// observation window ([`Config::observe_window_us`]); 0 when no
    /// window is configured (an empty histogram's p99).
    pub e2e_p99_window_us: u64,
    pub stable: bool,
    /// Producer→broker bytes this tenant put on the wire (per-tenant
    /// NIC meter — the shared [`BandwidthMeter`] only has class totals).
    pub net_tx_bytes: f64,
    /// Broker→consumer bytes this tenant fetched.
    pub net_rx_bytes: f64,
    /// End-of-run consumer lag summed over the tenant's partitions
    /// (bytes still unread past the fetch offsets). Zero when the
    /// measured read path is disabled — and in any healthy streaming
    /// run; nonzero means the tenant ended the horizon still behind.
    pub consumer_lag_bytes: u64,
    /// Client records re-offered by the retry layer (0 with no
    /// [`RetryPolicy`]).
    pub retries: u64,
    /// Records dropped at the client on retry-buffer overflow.
    pub client_dropped: u64,
    /// Fabric rejections the client absorbed (retried or converted to
    /// `client_dropped`) instead of letting stand as final loss.
    pub absorbed_rejects: u64,
    /// Per-segment latency attribution (`Some` only when the world was
    /// built with [`FabricSpec::with_provenance`]).
    pub tax: Option<TaxSummary>,
}

/// Summarize tenant `tenant` of a finished world.
pub fn summary_for_tenant(
    world: &World<DcEvent, DcState>,
    tenant: usize,
    name: &str,
) -> TenantSummary {
    let ts = &world.shared.tenants[tenant];
    let m = &ts.metrics;
    let elapsed = world.shared.horizon_us;
    let measured = elapsed.saturating_sub(ts.warmup_us);
    TenantSummary {
        name: name.to_string(),
        kind: ts.kind,
        produced: m.produced,
        completed: m.completed,
        throughput_per_sec: if measured > 0 {
            m.completed_in_window as f64 * 1e6 / measured as f64
        } else {
            0.0
        },
        wait_mean_us: m.hist_wait.mean(),
        wait_p99_us: m.hist_wait.p99(),
        e2e_mean_us: m.hist_e2e.mean(),
        e2e_p99_us: m.hist_e2e.p99(),
        e2e_p99_window_us: m.hist_e2e_window.p99(),
        stable: m.population.verdict(elapsed).stable,
        net_tx_bytes: m.net_tx_bytes,
        net_rx_bytes: m.net_rx_bytes,
        consumer_lag_bytes: (ts.part_base..ts.part_base + ts.part_count)
            .map(|g| world.shared.fabric.group_lag_bytes(g))
            .sum(),
        retries: m.retries,
        client_dropped: m.client_dropped,
        absorbed_rejects: m.absorbed_rejects,
        tax: m.tax.as_ref().map(|tb| tb.summary()),
    }
}

/// Consumer -> owned global partition ids for one tenant (avoids scanning
/// all partitions on every poll).
fn owned_partitions(state: &DcState, tenant: usize) -> Vec<Vec<u32>> {
    let ts = &state.tenants[tenant];
    let mut owned: Vec<Vec<u32>> = vec![Vec::new(); ts.gates.len()];
    for idx in ts.part_base..ts.part_base + ts.part_count {
        let part = &state.partitions[idx as usize];
        owned[part.consumer as usize].push(idx);
    }
    owned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::qos::TenantQuota;
    use crate::config::Deployment;

    fn tiny_facerec() -> Config {
        let mut cfg = Config::default();
        cfg.deployment = Deployment {
            producers: 8,
            consumers: 12,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 12,
        };
        cfg.duration_us = 5 * crate::util::units::SEC;
        cfg.seed = 0x51;
        cfg
    }

    #[test]
    fn partition_mapping_round_robins_leaders_and_consumers() {
        let cfg = tiny_facerec();
        let spec = FabricSpec::from_config(&cfg);
        let world = build(
            &[TenantSpec { kind: WorkloadKind::FaceRec, cfg: &cfg }],
            &spec,
            cfg.duration_us,
        );
        let parts = &world.shared.partitions;
        assert_eq!(parts.len(), 12);
        assert_eq!(parts[0].leader, 0);
        assert_eq!(parts[1].leader, 1);
        assert_eq!(parts[3].leader, 0);
        assert_eq!(parts[5].consumer, 5);
        // 3 components: producer client, consumer poller, fabric hub.
        assert_eq!(world.component_count(), 3);
    }

    #[test]
    fn single_tenant_world_moves_items_end_to_end() {
        let cfg = tiny_facerec();
        let spec = FabricSpec::from_config(&cfg);
        let mut world = build(
            &[TenantSpec { kind: WorkloadKind::FaceRec, cfg: &cfg }],
            &spec,
            cfg.duration_us,
        );
        world.run_until(cfg.duration_us);
        let m = &world.shared.tenants[0].metrics;
        assert!(m.frames_total > 100, "frames={}", m.frames_total);
        assert!(m.produced > 0, "no faces produced");
        assert!(m.completed > 0, "no faces identified");
        assert!(m.completed <= m.produced);
    }

    fn tiny_tick(kind: WorkloadKind, seed: u64) -> Config {
        let mut cfg = Config::default();
        cfg.deployment = Deployment {
            producers: 4,
            consumers: 6,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 6,
        };
        cfg.duration_us = 5 * crate::util::units::SEC;
        cfg.seed = seed;
        // Keep the tiny worlds light: small training batches.
        if kind == WorkloadKind::TrainIngest {
            cfg.calibration.train.batch_bytes = 200_000.0;
            cfg.calibration.train.fetch_min_bytes = 400_000;
        }
        cfg
    }

    #[test]
    fn four_tenant_world_runs_every_workload_kind() {
        let fr = tiny_facerec();
        let mut od = tiny_facerec();
        od.seed = 0xD07;
        let tr = tiny_tick(WorkloadKind::TrainIngest, 0x7EA1);
        let rpc = tiny_tick(WorkloadKind::Rpc, 0x59C);
        let spec = FabricSpec::from_config(&fr);
        let mut world = build(
            &[
                TenantSpec { kind: WorkloadKind::FaceRec, cfg: &fr },
                TenantSpec { kind: WorkloadKind::ObjDet, cfg: &od },
                TenantSpec { kind: WorkloadKind::TrainIngest, cfg: &tr },
                TenantSpec { kind: WorkloadKind::Rpc, cfg: &rpc },
            ],
            &spec,
            fr.duration_us,
        );
        world.run_until(fr.duration_us);
        for t in 0..4 {
            let m = &world.shared.tenants[t].metrics;
            assert!(m.produced > 0, "tenant {t} produced nothing");
            assert!(m.completed > 0, "tenant {t} completed nothing");
            let s = summary_for_tenant(&world, t, "x");
            assert_eq!(s.completed, m.completed);
            assert!(s.e2e_p99_us > 0);
        }
    }

    #[test]
    fn zero_produce_quota_starves_only_the_capped_tenant() {
        let fr = tiny_facerec();
        let tr = tiny_tick(WorkloadKind::TrainIngest, 0x7EA1);
        let spec = FabricSpec::from_config(&fr);
        let qos = QosPolicy {
            cpu_weights: None,
            storage_weights: None,
            quotas: vec![
                TenantQuota::default(),
                TenantQuota { produce_bytes_per_sec: Some(0.0), ..Default::default() },
            ],
        };
        let mut world = build_with_qos(
            &[
                TenantSpec { kind: WorkloadKind::FaceRec, cfg: &fr },
                TenantSpec { kind: WorkloadKind::TrainIngest, cfg: &tr },
            ],
            &spec,
            Some(&qos),
            fr.duration_us,
        );
        world.run_until(fr.duration_us);
        let fr_m = &world.shared.tenants[0].metrics;
        let tr_m = &world.shared.tenants[1].metrics;
        assert!(fr_m.completed > 0, "uncapped tenant must keep flowing");
        assert!(tr_m.produced > 0, "capped tenant still produces locally");
        assert_eq!(tr_m.completed, 0, "zero quota must admit nothing");
        assert_eq!(tr_m.net_tx_bytes, 0.0, "no capped bytes may reach the wire");
    }

    #[test]
    fn ample_quota_and_all_equal_weights_change_nothing_observable() {
        // Quota far above offered load + no CPU weights: the QoS hooks
        // charge buckets but never delay, so the run must be identical.
        let fr = tiny_facerec();
        let spec = FabricSpec::from_config(&fr);
        let tenants = [TenantSpec { kind: WorkloadKind::FaceRec, cfg: &fr }];
        let mut base = build(&tenants, &spec, fr.duration_us);
        base.run_until(fr.duration_us);
        let qos = QosPolicy {
            cpu_weights: None,
            storage_weights: None,
            quotas: vec![TenantQuota {
                produce_bytes_per_sec: Some(1e15),
                fetch_bytes_per_sec: Some(1e15),
                ..Default::default()
            }],
        };
        let mut capped = build_with_qos(&tenants, &spec, Some(&qos), fr.duration_us);
        capped.run_until(fr.duration_us);
        let a = &base.shared.tenants[0].metrics;
        let b = &capped.shared.tenants[0].metrics;
        assert_eq!(a.produced, b.produced);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.hist_e2e.p99(), b.hist_e2e.p99());
        assert_eq!(a.net_tx_bytes, b.net_tx_bytes);
        assert_eq!(base.processed(), capped.processed());
    }

    #[test]
    fn tight_produce_quota_rate_limits_wire_bytes() {
        // Train tenant offers ~4 × 2 MB/s = 8 MB/s (200 kB × 10/s × 4);
        // cap it to 2 MB/s and the wire bytes must track the cap.
        let tr = tiny_tick(WorkloadKind::TrainIngest, 0x7EA1);
        let spec = FabricSpec::from_config(&tr);
        let quota = 2_000_000.0;
        let qos = QosPolicy {
            cpu_weights: None,
            storage_weights: None,
            quotas: vec![TenantQuota {
                produce_bytes_per_sec: Some(quota),
                ..Default::default()
            }],
        };
        let mut world = build_with_qos(
            &[TenantSpec { kind: WorkloadKind::TrainIngest, cfg: &tr }],
            &spec,
            Some(&qos),
            tr.duration_us,
        );
        world.run_until(tr.duration_us);
        let m = &world.shared.tenants[0].metrics;
        let secs = tr.duration_us as f64 / 1e6;
        assert!(m.completed > 0);
        assert!(
            m.net_tx_bytes <= quota * secs * 1.3,
            "wire bytes {} must respect the {} B/s cap",
            m.net_tx_bytes,
            quota
        );
        assert!(
            m.net_tx_bytes >= quota * secs * 0.5,
            "cap should still let ~quota through, got {}",
            m.net_tx_bytes
        );
    }

    #[test]
    fn replication_aware_quota_meters_write_path_bytes() {
        // Train offers ~8 MB/s of client bytes on an RF=3 fabric. A
        // 6 MB/s produce budget admits ~6 MB/s when metering client
        // bytes, but only ~2 MB/s (6 / RF) when the bucket is
        // denominated in write-path bytes — the same budget now pays for
        // the 3 device copies each record costs.
        let tr = tiny_tick(WorkloadKind::TrainIngest, 0x7EA1);
        let spec = FabricSpec::from_config(&tr);
        let budget = 6_000_000.0;
        let run = |aware: bool| {
            let qos = QosPolicy {
                cpu_weights: None,
                storage_weights: None,
                quotas: vec![TenantQuota {
                    produce_bytes_per_sec: Some(budget),
                    replication_aware: aware,
                    ..Default::default()
                }],
            };
            let mut world = build_with_qos(
                &[TenantSpec { kind: WorkloadKind::TrainIngest, cfg: &tr }],
                &spec,
                Some(&qos),
                tr.duration_us,
            );
            world.run_until(tr.duration_us);
            world.shared.tenants[0].metrics.net_tx_bytes
        };
        let plain = run(false);
        let aware = run(true);
        let secs = tr.duration_us as f64 / 1e6;
        let rf = spec.replication as f64;
        assert!(
            aware <= budget / rf * secs * 1.3,
            "replication-aware wire bytes {aware} must track budget/RF"
        );
        assert!(
            aware >= budget / rf * secs * 0.5,
            "replication-aware cap should still admit ~budget/RF, got {aware}"
        );
        assert!(
            aware < 0.6 * plain,
            "RF={rf} must shrink admitted bytes: {aware} vs {plain}"
        );
    }

    #[test]
    fn storage_weights_install_the_write_scheduler() {
        let fr = tiny_facerec();
        let spec = FabricSpec::from_config(&fr);
        let qos = QosPolicy {
            cpu_weights: None,
            storage_weights: Some(vec![1.0]),
            quotas: Vec::new(),
        };
        let tenants = [TenantSpec { kind: WorkloadKind::FaceRec, cfg: &fr }];
        let mut world = build_with_qos(&tenants, &spec, Some(&qos), fr.duration_us);
        assert!(world.shared.fabric.storage_qos_enabled());
        assert!(!world.shared.fabric.weighted_cpu_enabled());
        world.run_until(fr.duration_us);
        assert!(world.shared.tenants[0].metrics.completed > 0);
    }

    #[test]
    fn two_tenant_world_keeps_partition_spaces_disjoint() {
        let fr = tiny_facerec();
        let mut od = Config::default();
        od.deployment = Deployment {
            producers: 2,
            consumers: 20,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 20,
        };
        od.duration_us = fr.duration_us;
        od.seed = 0xD07;
        let spec = FabricSpec::from_config(&fr);
        let mut world = build(
            &[
                TenantSpec { kind: WorkloadKind::FaceRec, cfg: &fr },
                TenantSpec { kind: WorkloadKind::ObjDet, cfg: &od },
            ],
            &spec,
            fr.duration_us,
        );
        assert_eq!(world.shared.tenants[0].part_base, 0);
        assert_eq!(world.shared.tenants[1].part_base, 12);
        assert_eq!(world.shared.partitions.len(), 32);
        world.run_until(fr.duration_us);
        for t in 0..2 {
            let m = &world.shared.tenants[t].metrics;
            assert!(m.produced > 0, "tenant {t} produced nothing");
            assert!(m.completed > 0, "tenant {t} completed nothing");
        }
        // Items stayed inside their tenant: every queued leftover belongs
        // to the partition's own tenant slice.
        for (i, p) in world.shared.partitions.iter().enumerate() {
            let ts = &world.shared.tenants[p.tenant as usize];
            assert!(
                (i as u32) >= ts.part_base && (i as u32) < ts.part_base + ts.part_count
            );
        }
    }

    // In-tree ports of the Python property simulations that vetted the
    // PR 6 flow arithmetic (previously living outside the repo; see
    // ROADMAP "toolchain debt"). They mirror the exact expressions of
    // the production paths above so tier-1 re-checks them every run.

    #[test]
    fn flow_carry_conservation_property() {
        // Mirror of the ProducerKind::Flow rate integration: over any
        // wake pattern, emitted + carry equals the exact offered total
        // (no drift), and the carry is always a proper fraction.
        crate::util::prop::check(200, |rng| {
            let clients = 1 + rng.below(1_000_000);
            let records_per_tick = 1 + rng.below(8);
            let tick_us = 1_000 + rng.below(100_000);
            let mut carry = 0.0f64;
            let mut last_us = 0u64;
            let mut emitted = 0u64;
            let mut now = 0u64;
            for _ in 0..300 {
                now += 1 + rng.below(50_000);
                let elapsed = now - last_us;
                last_us = now;
                let offered = clients as f64 * records_per_tick as f64 * elapsed as f64
                    / tick_us as f64
                    + carry;
                let emit = offered.floor() as u64;
                carry = offered - emit as f64;
                emitted += emit;
                if !(0.0..1.0).contains(&carry) {
                    return Err(format!("carry out of [0,1): {carry}"));
                }
            }
            let exact = clients as f64 * records_per_tick as f64 * now as f64 / tick_us as f64;
            let total = emitted as f64 + carry;
            // f64 accumulation tolerance: 300 additions of values up to
            // ~1e10 records; relative error stays well under 1e-9.
            if (total - exact).abs() > 1.0 + exact * 1e-9 {
                return Err(format!("drift: emitted+carry {total} vs exact {exact}"));
            }
            Ok(())
        });
    }

    #[test]
    fn capped_drain_conservation_property() {
        // Mirror of the per-partition fetch cap in ConsumerPoller::poll:
        // repeatedly draining a queue under max_partition_fetch_bytes
        // takes every byte exactly once (conservation), each poll's take
        // respects the cap except for the single-oversized-record escape
        // hatch, and the drain terminates.
        crate::util::prop::check(200, |rng| {
            let cap = 1_000.0 + rng.below(50_000) as f64;
            let mut queue: VecDeque<f64> = (0..1 + rng.below(200))
                .map(|_| 1.0 + rng.below(20_000) as f64)
                .collect();
            let total: f64 = queue.iter().sum();
            let largest = queue.iter().cloned().fold(0.0, f64::max);
            let mut taken = 0.0f64;
            let mut polls = 0;
            while !queue.is_empty() {
                polls += 1;
                if polls > 100_000 {
                    return Err("drain did not terminate".into());
                }
                let mut part_bytes = 0.0f64;
                while let Some(&it_bytes) = queue.front() {
                    if part_bytes > 0.0 && part_bytes + it_bytes > cap {
                        break;
                    }
                    part_bytes += it_bytes;
                    queue.pop_front();
                }
                if part_bytes > cap.max(largest) {
                    return Err(format!("poll took {part_bytes} > cap {cap}"));
                }
                if part_bytes == 0.0 {
                    return Err("livelock: poll took nothing".into());
                }
                taken += part_bytes;
            }
            if (taken - total).abs() > 1e-6 * total.max(1.0) {
                return Err(format!("conservation: took {taken} of {total}"));
            }
            Ok(())
        });
    }

    #[test]
    fn fault_plan_schedules_events_and_reassigns_leaders() {
        // A kill at t=1s must re-elect the dead broker's partition
        // leaders to alive brokers and pause the affected consumers.
        let cfg = tiny_facerec();
        let spec = FabricSpec::from_config(&cfg)
            .with_faults(FaultPlan::new().kill_broker(1_000_000, 0));
        let mut world = build(
            &[TenantSpec { kind: WorkloadKind::FaceRec, cfg: &cfg }],
            &spec,
            cfg.duration_us,
        );
        assert!(world.shared.fabric.faults_enabled());
        world.run_until(cfg.duration_us);
        assert!(!world.shared.fabric.broker_alive(0));
        for p in &world.shared.partitions {
            assert_ne!(p.leader, 0, "partition still led by the dead broker");
            assert!(world.shared.fabric.broker_alive(p.leader));
        }
        // The world kept moving records after the failover.
        let m = &world.shared.tenants[0].metrics;
        assert!(m.completed > 0);
        let s = world.shared.fabric.fault_stats().unwrap();
        assert_eq!(s.min_isr_violations, 0);
    }
}
