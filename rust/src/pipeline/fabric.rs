//! Event-driven broker fabric shared by the Face Recognition and Object
//! Detection simulators.
//!
//! Models the full `acks=all` produce path of the Kafka-like substrate as
//! a chain of *events at true arrival times*:
//!
//! ```text
//! client send ──wire──▶ leader NIC ─▶ leader request CPU ─▶ leader NVMe
//!                                          │
//!                                          ├─▶ follower₁ NIC ─▶ CPU ─▶ NVMe ─▶ ack
//!                                          └─▶ follower₂ NIC ─▶ CPU ─▶ NVMe ─▶ ack
//! commit = leader write done ∧ all follower acks
//! ```
//!
//! Why events per hop: resource servers drain in virtual time; submitting
//! a hop at a *future* time (the previous hop's completion, computed
//! synchronously) freezes the downstream server's drain clock and, with
//! the replication mesh's cross-broker feedback, the phantom backlogs
//! amplify unboundedly. Scheduling each hop when it actually arrives keeps
//! every server's clock honest. (The consumer fetch path is chained
//! synchronously — its queueing is bounded by the request-CPU backlog,
//! which stays small in stable runs, and the approximation error does not
//! feed back.)

use crate::broker::qos::WeightedCpuScheduler;
use crate::config::hardware::NvmeSpec;
use crate::config::KafkaTuning;
use crate::metrics::bandwidth::{BandwidthMeter, Channel, Class, Dir};
use crate::metrics::tax::{Segment, TaxCell};
use crate::net::path::{NetworkSpec, PathNet, NO_NODE};
use crate::sim::resource::FifoServer;
use crate::storage::cache::PageCache;
use crate::storage::device::StorageDevice;

/// One-way wire/switch transit within the data center (fat tree, µs).
pub const WIRE_US: u64 = crate::config::hardware::WIRE_TRANSIT_US;
/// Replication ack transit back to the leader.
pub const ACK_TRANSIT_US: u64 = 60;
/// Size of a replication ack frame on the contention-aware fabric. Acks
/// are latency messages, not bandwidth flows; they cross the network as
/// tiny transfers so a saturated uplink delays (but barely loads) them.
pub const ACK_BYTES: f64 = 64.0;

/// Sentinel partition group for fetches with no partition identity
/// (legacy entry points); such reads are always served from memory,
/// reproducing the seed's hardcoded-hit behavior.
pub const NO_GROUP: u32 = u32::MAX;

/// A broker node's devices.
pub struct BrokerNode {
    pub storage: StorageDevice,
    pub nic_rx: FifoServer,
    pub nic_tx: FifoServer,
    pub req_cpu: FifoServer,
    /// Weighted request-CPU scheduler, installed by
    /// [`Fabric::enable_weighted_cpu`]. When present it replaces the FIFO
    /// `req_cpu` on the produce and fetch paths; when absent (the
    /// default) request handling is bit-for-bit the pre-QoS FIFO.
    pub req_cpu_wfq: Option<WeightedCpuScheduler>,
}

impl BrokerNode {
    /// Submit `cpu` µs of request-handling work of scheduling class
    /// `class`; FIFO unless a weighted scheduler is installed.
    fn cpu_submit(&mut self, at: u64, class: u8, cpu: f64) -> u64 {
        match &mut self.req_cpu_wfq {
            Some(wfq) => wfq.submit(at, class as usize, cpu),
            None => self.req_cpu.submit(at, cpu),
        }
    }
}

/// Fabric-internal events. The host simulator embeds these in its own
/// event enum and routes them back to [`Fabric::handle`].
#[derive(Clone, Copy, Debug)]
pub enum FabricEv {
    LeaderArrive { fid: u32 },
    LeaderCpuDone { fid: u32 },
    LeaderStored { fid: u32 },
    FollowerArrive { fid: u32, broker: u32 },
    FollowerCpuDone { fid: u32, broker: u32 },
    /// Replication ack arriving back at the leader. `broker` identifies
    /// the acking follower so the fault layer can match it against the
    /// record's pending-ack mask; without faults it is ignored.
    ReplicaAck { fid: u32, broker: u32 },
    /// Re-replication catch-up tick for a recovering broker: drain one
    /// bandwidth-bounded chunk of its missed-byte backlog as cold reads
    /// off the source leaders' spindles ([`Fabric::enable_faults`]).
    /// Never scheduled in a fault-free world.
    Recovery { broker: u32 },
    /// A prepared network transfer's serialization finished; it enters
    /// the shared links now ([`Fabric::enable_network`]). Never
    /// scheduled without the contention-aware fabric.
    NetStart { xfer: u32 },
    /// A network transfer's estimated last byte arrives. `gen` guards
    /// against re-estimates: when contention changed the transfer's
    /// fair-share rate after this event was scheduled, the generation
    /// won't match and the event is ignored (a fresher one is queued).
    NetDone { xfer: u32, gen: u32 },
}

/// Outputs of a fabric step: new events to schedule, or a commit
/// notification carrying the host's token.
#[derive(Clone, Copy, Debug)]
pub enum FabricOut {
    Schedule(u64, FabricEv),
    /// The record is durably replicated and visible to consumers.
    Committed { token: u64, partition: u32, at: u64 },
}

/// Outcome of a retransmission ([`Fabric::send_retry_grouped_classed`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendOutcome {
    /// Admitted as a fresh produce; a commit (or loss) will follow.
    Admitted,
    /// Rejected at admission (dead leader / ISR below quorum); the
    /// client decides whether the rejection is final.
    Rejected,
    /// Suppressed by broker-side dedup: the original attempt is still in
    /// flight and will resolve the record — committing this copy too
    /// would deliver it twice. The client should keep waiting.
    Duplicate,
}

/// Token value marking an in-flight slot whose record identity has been
/// retired (committed and freed, or a repaired loss) so later dedup
/// scans cannot match it against a reused item token.
const RETIRED_TOKEN: u64 = u64::MAX;

struct InFlight {
    token: u64,
    partition: u32,
    leader: u32,
    bytes: f64,
    /// Client records this produce stands for (1 on the per-record path;
    /// >1 for a flow-aggregated macro-record). Request CPU is charged per
    /// record, so a macro pays `records × request_cpu_us` plus the
    /// per-byte term — the same total broker CPU the per-record
    /// simulation would pay for the same stream.
    records: u64,
    /// Scheduling class (tenant id) for weighted request-CPU service.
    class: u8,
    remaining_acks: u8,
    leader_stored: bool,
    active: bool,
    /// Fault mode only: bitmask over replica offsets `r` (1..RF) whose
    /// acks are still awaited. Maintained so a broker kill can resolve
    /// the acks that will never arrive, and stale follower events from
    /// before a kill can be recognized and dropped. Unused (0) without
    /// faults.
    pending: u8,
    /// Fault mode only: in-sync replica count (leader included) this
    /// record was fanned out to — what "ISR quorum" meant for *this*
    /// record. Checked against `min_isr` at commit; `replication`
    /// without faults.
    isr: u8,
    /// Latency provenance (PR 10): per-segment µs accumulator covering
    /// this attempt's fabric traversal, `[send, commit]`. Initialized at
    /// send; charged at each hop only when [`Fabric::enable_provenance`]
    /// armed the fabric, so the disabled path never touches it.
    tax: TaxCell,
}

/// The measured consumer read path (opt-in; see
/// [`Fabric::enable_read_path`]): one OS page cache per broker keyed by
/// partition group, plus the per-group consumer offsets that turn cache
/// residency into a function of the actual produce/consume gap.
#[derive(Clone, Debug)]
struct ReadPath {
    /// One page cache per broker (index = broker id). Every durable
    /// write — leader and follower — mirrors an append, so capacity
    /// pressure on a broker comes from *all* log traffic it carries,
    /// including replication follower writes of other partitions.
    caches: Vec<PageCache>,
    /// Consumer offset per partition group (bytes fetched so far);
    /// grows on demand. One pinned consumer per partition makes a
    /// single offset per group exact. (Hit/miss byte totals live in the
    /// caches themselves — [`PageCache::byte_counters`] — summed by
    /// [`Fabric::read_path_stats`].)
    consumed: Vec<u64>,
}

/// Aggregate read-path counters ([`Fabric::read_path_stats`]).
#[derive(Clone, Copy, Debug)]
pub struct ReadPathStats {
    /// Fetched bytes served from broker memory.
    pub hit_bytes: f64,
    /// Fetched bytes that went to the device read path.
    pub miss_bytes: f64,
}

impl ReadPathStats {
    /// Byte-weighted cache hit ratio (1.0 before any fetch).
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hit_bytes + self.miss_bytes;
        if total == 0.0 {
            1.0
        } else {
            self.hit_bytes / total
        }
    }

    /// Fraction of fetched bytes served by the device (0.0 before any
    /// fetch) — the complement of [`ReadPathStats::hit_ratio`].
    pub fn device_read_share(&self) -> f64 {
        1.0 - self.hit_ratio()
    }
}

// ---------------------------------------------------------------------------
// Failure and membership dynamics (opt-in)
// ---------------------------------------------------------------------------

/// Interval between re-replication catch-up ticks: a recovering broker
/// drains `recovery_bytes_per_sec × 10 ms` of its missed-byte backlog
/// per tick, so the catch-up stream is paced rather than one burst.
pub const RECOVERY_TICK_US: u64 = 10_000;

/// One world-level fault, injected at an absolute virtual time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEvent {
    /// Broker `broker` fail-stops: loses RAM (page cache), stops
    /// processing, drops out of every ISR. Its on-disk log survives.
    Kill { at_us: u64, broker: u32 },
    /// Broker `broker` rejoins as an out-of-sync follower and starts
    /// replaying its missed bytes (a maximally-lagged consumer of the
    /// surviving leaders); it re-enters ISRs once the backlog drains.
    Restart { at_us: u64, broker: u32 },
    /// The links between brokers `a` and `b` drop for `duration_us`:
    /// fan-outs across the cut are skipped (the far side falls out of
    /// sync) until the heal, after which catch-up replication runs.
    Partition { at_us: u64, a: u32, b: u32, duration_us: u64 },
}

impl FaultEvent {
    /// The virtual instant this fault fires.
    pub fn at_us(&self) -> u64 {
        match *self {
            FaultEvent::Kill { at_us, .. }
            | FaultEvent::Restart { at_us, .. }
            | FaultEvent::Partition { at_us, .. } => at_us,
        }
    }
}

/// Leader-election policy when a partition's leader dies.
///
/// `Clean` (the default, Kafka's `unclean.leader.election.enable=false`)
/// elects only alive **in-sync** replicas; if the whole ISR is gone the
/// partition stays leaderless — every produce is rejected at admission
/// until a replica returns. Availability is sacrificed, data never is.
///
/// `Unclean` elects the first *alive* replica in ring order even if it
/// is out of sync. The elected replica's log becomes the truth: every
/// byte in its un-replayed catch-up backlog is permanently gone, counted
/// in [`FaultStats::unclean_lost_bytes`] — data loss becomes a measured
/// policy choice, never silent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ElectionPolicy {
    #[default]
    Clean,
    Unclean,
}

/// A world-level fault schedule plus the membership policy knobs.
/// `FaultPlan::default()` (no events, `min_isr = 1`) installed on a
/// world is observationally inert — pinned bit-exact by
/// `tests/failover_differential.rs`.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// Minimum in-sync replicas (leader included) a produce needs at
    /// admission; below it the send is rejected (Kafka's
    /// NotEnoughReplicas), counted in [`FaultStats::records_rejected`].
    pub min_isr: usize,
    /// Re-replication read bandwidth per recovering broker (bytes/s):
    /// how fast catch-up cold-reads the missed bytes off the source
    /// leaders' spindles.
    pub recovery_bytes_per_sec: f64,
    /// What happens when a partition's whole ISR is dead
    /// ([`ElectionPolicy`]). `Clean` by default.
    pub election: ElectionPolicy,
    /// Broker-side duplicate suppression for retrying producers
    /// ([`Fabric::enable_dedup`]); off by default. With no client
    /// retransmissions the dedup machinery is observationally inert.
    pub idempotent: bool,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            events: Vec::new(),
            min_isr: 1,
            recovery_bytes_per_sec: 400e6,
            election: ElectionPolicy::Clean,
            idempotent: false,
        }
    }
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Fail-stop `broker` at `at_us`.
    pub fn kill_broker(mut self, at_us: u64, broker: u32) -> Self {
        self.events.push(FaultEvent::Kill { at_us, broker });
        self
    }

    /// Rejoin `broker` at `at_us` (catch-up replication follows).
    pub fn restart_broker(mut self, at_us: u64, broker: u32) -> Self {
        self.events.push(FaultEvent::Restart { at_us, broker });
        self
    }

    /// Cut the `a`↔`b` links for `duration_us` starting at `at_us`.
    pub fn partition_fabric(mut self, at_us: u64, a: u32, b: u32, duration_us: u64) -> Self {
        self.events.push(FaultEvent::Partition { at_us, a, b, duration_us });
        self
    }

    pub fn with_min_isr(mut self, min_isr: usize) -> Self {
        self.min_isr = min_isr;
        self
    }

    pub fn with_recovery_bandwidth(mut self, bytes_per_sec: f64) -> Self {
        self.recovery_bytes_per_sec = bytes_per_sec;
        self
    }

    /// Pick the leader-election policy (`Clean` by default).
    pub fn with_election(mut self, election: ElectionPolicy) -> Self {
        self.election = election;
        self
    }

    /// Enable broker-side duplicate suppression for retrying producers.
    pub fn with_idempotence(mut self) -> Self {
        self.idempotent = true;
        self
    }
}

/// Fault-mode accounting ([`Fabric::fault_stats`]). The conservation
/// contract pinned by `tests/failover_differential.rs`:
/// `records_offered == records_committed + records_rejected +
/// records_lost + active in-flight`. With retrying producers each
/// retransmission re-enters `records_offered`, so the identity extends
/// (pinned by `tests/resilience_differential.rs`) to
/// `offered − retries == committed + (rejected − rejections absorbed by
/// the client) + lost + in-flight`, with the client-side terms summed
/// from the tenants' retry counters.
#[derive(Clone, Debug, Default)]
pub struct FaultStats {
    /// Produce attempts entering the fabric (post-dispatch).
    pub records_offered: u64,
    pub bytes_offered: f64,
    /// Commits (every one satisfied its ISR quorum).
    pub records_committed: u64,
    pub bytes_committed: f64,
    /// Admission rejections: leader dead or ISR below `min_isr`.
    pub records_rejected: u64,
    pub bytes_rejected: f64,
    /// Records that died with their leader (or lost quorum after the
    /// leader stored them) — Kafka would truncate these on recovery.
    pub records_lost: u64,
    pub bytes_lost: f64,
    /// Bytes that skipped an unavailable follower at fan-out and were
    /// queued for re-replication.
    pub missed_bytes: f64,
    /// Bytes catch-up actually replayed (device cold reads at the
    /// source + follower re-writes). Equals `missed_bytes` once every
    /// recovery completes — the high-water-mark equality invariant.
    pub rereplicated_bytes: f64,
    /// Commits that would have violated the ISR quorum. Admission and
    /// fan-out checks make this structurally unreachable; it exists so
    /// the invariant is counted, not assumed.
    pub min_isr_violations: u64,
    /// `(broker, virtual time)` at which each recovery completed (the
    /// last missed byte applied and the broker back in sync).
    pub recovered_at_us: Vec<(u32, u64)>,
    /// Retransmissions suppressed by broker-side dedup
    /// ([`Fabric::enable_dedup`]): the original was still in flight, so
    /// admitting the duplicate would have double-committed the record.
    pub dedup_suppressed_records: u64,
    pub dedup_suppressed_bytes: f64,
    /// Log divergence consumed by unclean elections: bytes an elected
    /// out-of-sync replica had not yet replayed when its log became the
    /// truth ([`ElectionPolicy::Unclean`]).
    pub unclean_lost_bytes: f64,
    /// Elections that promoted an out-of-sync replica.
    pub unclean_elections: u64,
}

/// One recovering broker's claim on bytes it missed from one source:
/// replayed in FIFO order against the source leader's spindle.
#[derive(Clone, Copy, Debug)]
struct PendingReplay {
    group: u32,
    /// Source broker holding the bytes (the partition leader at the
    /// time of the miss).
    leader: u32,
    class: u8,
    bytes: f64,
}

/// Per-world fault machinery, installed by [`Fabric::enable_faults`].
/// `None` on [`Fabric`] (the default) keeps every code path bit-exact
/// to the immortal fabric.
#[derive(Clone, Debug)]
struct FaultState {
    min_isr: usize,
    recovery_bytes_per_sec: f64,
    alive: Vec<bool>,
    in_sync: Vec<bool>,
    /// Severed broker pairs: `(min, max, healed_at_us)`.
    blocked: Vec<(u32, u32, u64)>,
    /// Per-broker missed-byte backlog awaiting re-replication.
    replay: Vec<Vec<PendingReplay>>,
    /// Per-broker queued [`FabricEv::Recovery`] ticks (coalesces
    /// duplicate kicks from restart + partition heals).
    recovery_ticks: Vec<u32>,
    /// Per-broker latest catch-up apply completion (device + NIC +
    /// follower write), for the recovery-duration stamp.
    last_apply_us: Vec<u64>,
    /// Leader-election policy ([`Fabric::set_election`]).
    election: ElectionPolicy,
    /// Broker-side duplicate suppression ([`Fabric::enable_dedup`]).
    dedup: bool,
    stats: FaultStats,
}

impl FaultState {
    fn new(brokers: usize, min_isr: usize, recovery_bytes_per_sec: f64) -> Self {
        FaultState {
            min_isr,
            recovery_bytes_per_sec,
            alive: vec![true; brokers],
            in_sync: vec![true; brokers],
            blocked: Vec::new(),
            replay: vec![Vec::new(); brokers],
            recovery_ticks: vec![0; brokers],
            last_apply_us: vec![0; brokers],
            election: ElectionPolicy::Clean,
            dedup: false,
            stats: FaultStats::default(),
        }
    }

    /// Is the `a`↔`b` link currently cut?
    fn link_blocked(&self, a: u32, b: u32, now: u64) -> bool {
        let (lo, hi) = (a.min(b), a.max(b));
        self.blocked
            .iter()
            .any(|&(x, y, until)| x == lo && y == hi && now < until)
    }

    /// Can `follower` take a replica write from `leader` right now?
    fn follower_available(&self, leader: u32, follower: u32, now: u64) -> bool {
        self.alive[follower as usize]
            && self.in_sync[follower as usize]
            && !self.link_blocked(leader, follower, now)
    }

    /// Queue bytes a skipped follower will have to replay, merging into
    /// an existing backlog entry with the same (group, source, class).
    fn note_missed(&mut self, follower: u32, group: u32, leader: u32, class: u8, bytes: f64) {
        self.stats.missed_bytes += bytes;
        self.in_sync[follower as usize] = false;
        let backlog = &mut self.replay[follower as usize];
        if let Some(e) = backlog
            .iter_mut()
            .find(|e| e.group == group && e.leader == leader && e.class == class)
        {
            e.bytes += bytes;
        } else {
            backlog.push(PendingReplay { group, leader, class, bytes });
        }
    }
}

/// The broker fabric: brokers + in-flight produce state.
pub struct Fabric {
    pub brokers: Vec<BrokerNode>,
    tuning: KafkaTuning,
    replication: usize,
    inflight: Vec<InFlight>,
    free: Vec<u32>,
    /// Measured read path; `None` (the default) keeps the seed's
    /// hardcoded cache hits bit for bit.
    read_path: Option<ReadPath>,
    /// Failure/membership machinery; `None` (the default) is the
    /// immortal fabric bit for bit.
    faults: Option<FaultState>,
    /// Contention-aware ToR/spine network; `None` (the default) keeps
    /// every hop at the fixed [`WIRE_US`] transit, bit for bit.
    net: Option<PathNet<FabricEv>>,
    /// Latency provenance (PR 10): when armed, every in-flight record's
    /// [`TaxCell`] is charged at each fabric hop and handed to the
    /// client layer at commit via [`Fabric::take_committed_tax`]. Off by
    /// default; charging is pure arithmetic on timestamps the fabric
    /// already computes, so the disabled path is bit-exact.
    provenance: bool,
    /// Commit-time cells awaiting pickup by the dc layer, keyed by the
    /// record token (drained by [`Fabric::take_committed_tax`]; stays
    /// empty when provenance is off).
    committed_tax: Vec<(u64, TaxCell)>,
}

/// Flush the network's re-estimate queue as [`FabricEv::NetDone`]
/// events: every active transfer whose fair-share rate just changed got
/// a fresh completion estimate; the stale event already in the host
/// queue will miss on its generation.
fn drain_resched(net: &mut PathNet<FabricEv>, out: &mut Vec<FabricOut>) {
    for (t, xfer, gen) in net.resched.drain(..) {
        out.push(FabricOut::Schedule(t, FabricEv::NetDone { xfer, gen }));
    }
}

impl Fabric {
    pub fn new(
        brokers: usize,
        drives_per_broker: usize,
        replication: usize,
        nvme: NvmeSpec,
        effective_write_bw: f64,
        net_bw: f64,
        tuning: KafkaTuning,
    ) -> Self {
        assert!(replication >= 1 && replication <= brokers);
        Fabric {
            brokers: (0..brokers)
                .map(|_| BrokerNode {
                    storage: StorageDevice::new(nvme, drives_per_broker, effective_write_bw),
                    nic_rx: FifoServer::new(net_bw, 0),
                    nic_tx: FifoServer::new(net_bw, 0),
                    // Request handling is parallel across Kafka's network/
                    // IO threads; modeled as an aggregate us-of-work server.
                    req_cpu: FifoServer::new(1e6 * tuning.request_handler_cores as f64, 0),
                    req_cpu_wfq: None,
                })
                .collect(),
            tuning,
            replication,
            inflight: Vec::new(),
            free: Vec::new(),
            read_path: None,
            faults: None,
            net: None,
            provenance: false,
            committed_tax: Vec::new(),
        }
    }

    pub fn broker_count(&self) -> usize {
        self.brokers.len()
    }

    /// Install per-tenant scheduling classes on every broker's request
    /// CPU: class `i` (the tenant id passed to [`Fabric::send_classed`] /
    /// [`Fabric::fetch_classed`]) receives a `weights[i] / Σweights`
    /// share under contention. Replaces the FIFO request CPU; call before
    /// any traffic flows.
    pub fn enable_weighted_cpu(&mut self, weights: &[f64]) {
        let rate = 1e6 * self.tuning.request_handler_cores as f64;
        for b in &mut self.brokers {
            b.req_cpu_wfq = Some(WeightedCpuScheduler::new(rate, weights));
        }
    }

    /// Whether weighted request-CPU scheduling is active.
    pub fn weighted_cpu_enabled(&self) -> bool {
        self.brokers.first().map_or(false, |b| b.req_cpu_wfq.is_some())
    }

    /// Install per-tenant scheduling classes on every broker's NVMe
    /// write path: class `i` (the tenant id carried by each in-flight
    /// record) receives a `weights[i] / Σweights` share of the write
    /// bandwidth under contention. Replaces the FIFO write queue; call
    /// before any traffic flows. With this disabled (the default) every
    /// write takes the pre-QoS FIFO path bit for bit.
    pub fn enable_storage_qos(&mut self, weights: &[f64]) {
        for b in &mut self.brokers {
            b.storage.enable_write_qos(weights);
        }
    }

    /// Whether weighted write scheduling is active on the storage path.
    pub fn storage_qos_enabled(&self) -> bool {
        self.brokers
            .first()
            .map_or(false, |b| b.storage.write_qos_enabled())
    }

    /// Install the measured read path: one [`PageCache`] of
    /// `cache_bytes_per_broker` on every broker, keyed by partition
    /// group. Every durable write then mirrors an append into the
    /// broker's cache, and every [`Fabric::fetch_group_classed`] is
    /// split against the group's cached window at the consumer's actual
    /// offset — cold bytes go to the device read path, where they
    /// contend with replicated writes on the same spindle
    /// ([`StorageDevice::read_cold_classed`]; classed when storage QoS
    /// weights are installed). Call before any traffic flows. With this
    /// disabled (the default) every fetch is served from memory, bit
    /// for bit the seed behavior.
    pub fn enable_read_path(&mut self, cache_bytes_per_broker: f64) {
        self.read_path = Some(ReadPath {
            caches: (0..self.brokers.len())
                .map(|_| PageCache::new(cache_bytes_per_broker))
                .collect(),
            consumed: Vec::new(),
        });
    }

    /// Whether the measured read path is active.
    pub fn read_path_enabled(&self) -> bool {
        self.read_path.is_some()
    }

    /// Aggregate read-path hit/miss byte totals, summed across the
    /// per-broker caches (`None` when disabled).
    pub fn read_path_stats(&self) -> Option<ReadPathStats> {
        self.read_path.as_ref().map(|rp| {
            let (hit_bytes, miss_bytes) = rp
                .caches
                .iter()
                .map(PageCache::byte_counters)
                .fold((0.0, 0.0), |(h, m), (ch, cm)| (h + ch, m + cm));
            ReadPathStats { hit_bytes, miss_bytes }
        })
    }

    /// Consumer lag of one partition group in bytes — the gap between
    /// the group's appended high-water mark and its consumer's fetch
    /// offset. Zero when the read path is disabled.
    pub fn group_lag_bytes(&self, group: u32) -> u64 {
        let Some(rp) = &self.read_path else { return 0 };
        let appended = rp
            .caches
            .iter()
            .map(|c| c.appended_of(group))
            .max()
            .unwrap_or(0);
        let consumed = rp.consumed.get(group as usize).copied().unwrap_or(0);
        appended.saturating_sub(consumed)
    }

    /// Install the contention-aware network: every wire hop (produce
    /// send, replication fan-out, replication ack, fetch response,
    /// recovery catch-up stream) becomes a transfer over concrete
    /// ToR/spine links whose capacity concurrent flows split max-min
    /// fairly ([`crate::net::path::PathNet`]). Brokers are nodes
    /// `0..brokers`; client units are nodes `brokers..brokers+clients`
    /// (assigned in world build order). Call before any traffic flows.
    /// With this disabled (the default) every hop pays the fixed
    /// [`WIRE_US`] / [`ACK_TRANSIT_US`] transit, bit for bit the
    /// pre-network fabric.
    pub fn enable_network(&mut self, spec: NetworkSpec, clients: usize) {
        self.net = Some(PathNet::new(spec, self.brokers.len(), clients));
    }

    /// Whether the contention-aware network is installed.
    pub fn network_enabled(&self) -> bool {
        self.net.is_some()
    }

    /// Arm latency provenance: from now on every in-flight record's
    /// [`TaxCell`] is charged at each fabric hop ([`Segment::Network`],
    /// CPU queue/service, [`Segment::StorageWrite`],
    /// [`Segment::Replication`]) and the commit-time cell is queued for
    /// [`Fabric::take_committed_tax`]. Call before any traffic flows.
    /// With this disabled (the default) no cell is ever charged and the
    /// fabric is bit-exact to the pre-provenance build.
    pub fn enable_provenance(&mut self) {
        self.provenance = true;
    }

    /// Whether latency provenance is armed.
    pub fn provenance_enabled(&self) -> bool {
        self.provenance
    }

    /// Claim the committed fabric cell for `token` (provenance only;
    /// `None` when disarmed or when the commit predates arming). The
    /// buffer holds only commits not yet drained by the dc layer — one
    /// event-turn's worth — so the scan is O(few).
    pub fn take_committed_tax(&mut self, token: u64) -> Option<TaxCell> {
        let pos = self.committed_tax.iter().position(|&(t, _)| t == token)?;
        Some(self.committed_tax.swap_remove(pos).1)
    }

    /// Transfers that entered the network below their solo (uncontended)
    /// rate — the contention event counter. Zero when disabled.
    pub fn net_contended_transfers(&self) -> u64 {
        self.net.as_ref().map_or(0, |n| n.contended_transfers)
    }

    /// Peak mean utilization across the rack uplinks/downlinks (0.0 when
    /// the network is disabled).
    pub fn net_max_uplink_util(&self, elapsed_us: u64) -> f64 {
        self.net.as_ref().map_or(0.0, |n| n.max_uplink_util(elapsed_us))
    }

    /// Peak mean utilization across the node access links (0.0 when the
    /// network is disabled).
    pub fn net_max_access_util(&self, elapsed_us: u64) -> f64 {
        self.net.as_ref().map_or(0.0, |n| n.max_access_util(elapsed_us))
    }

    /// Install the failure/membership machinery: liveness + ISR state
    /// per broker, pending-ack masks on in-flight records, `min_isr`
    /// admission, and paced catch-up re-replication at
    /// `recovery_bytes_per_sec`. With every broker alive and no link
    /// cut, the machinery is observationally inert — the fan-out,
    /// commit, and ack paths produce the exact event stream of the
    /// immortal fabric (pinned by `tests/failover_differential.rs`).
    /// Call before any traffic flows.
    pub fn enable_faults(&mut self, min_isr: usize, recovery_bytes_per_sec: f64) {
        assert!(
            self.replication <= 8,
            "fault mode tracks pending acks in a u8 mask (replication <= 8)"
        );
        assert!(min_isr >= 1 && min_isr <= self.replication);
        self.faults = Some(FaultState::new(
            self.brokers.len(),
            min_isr,
            recovery_bytes_per_sec,
        ));
    }

    /// Whether the failure machinery is installed.
    pub fn faults_enabled(&self) -> bool {
        self.faults.is_some()
    }

    /// Pick the leader-election policy (fault mode only; `Clean` is the
    /// default and the bit-exact PR 7-compatible choice on every
    /// schedule whose candidates are all in sync).
    pub fn set_election(&mut self, election: ElectionPolicy) {
        self.faults.as_mut().expect("enable_faults first").election = election;
    }

    /// Enable broker-side duplicate suppression for retrying producers:
    /// a retransmission ([`Fabric::send_retry_grouped_classed`]) whose
    /// original attempt is still in flight is suppressed instead of
    /// committed twice, and a retransmission of a *lost* record repairs
    /// the loss accounting (the retry, not the crash, decides the
    /// record's fate). Inert unless retransmissions actually arrive.
    pub fn enable_dedup(&mut self) {
        self.faults.as_mut().expect("enable_faults first").dedup = true;
    }

    /// Whether broker-side dedup is enabled.
    pub fn dedup_enabled(&self) -> bool {
        self.faults.as_ref().map_or(false, |fs| fs.dedup)
    }

    /// Elect a new leader for partitions led by dead broker `dead`,
    /// ring-order. Both policies prefer an alive in-sync replica (the
    /// exact PR 7 scan when everyone but the victim is healthy). When
    /// the whole ISR is gone, `Clean` returns `None` — the partition
    /// stays leaderless and admission rejects until a replica returns —
    /// while `Unclean` promotes the first alive out-of-sync replica,
    /// consuming its un-replayed backlog as measured divergence
    /// ([`FaultStats::unclean_lost_bytes`]): the new leader's log is now
    /// the truth, so it rejoins the ISR with nothing left to replay.
    pub fn elect_leader(&mut self, dead: u32) -> Option<u32> {
        let n = self.brokers.len() as u32;
        for r in 1..n {
            let cand = (dead + r) % n;
            if self.broker_alive(cand) && self.broker_in_sync(cand) {
                return Some(cand);
            }
        }
        let fs = self.faults.as_mut()?;
        if fs.election != ElectionPolicy::Unclean {
            return None;
        }
        for r in 1..n {
            let cand = (dead + r) % n;
            if fs.alive[cand as usize] {
                let divergence: f64 = fs.replay[cand as usize].iter().map(|e| e.bytes).sum();
                fs.stats.unclean_lost_bytes += divergence;
                fs.stats.unclean_elections += 1;
                fs.replay[cand as usize].clear();
                fs.in_sync[cand as usize] = true;
                return Some(cand);
            }
        }
        None
    }

    /// Fault-mode accounting (`None` when faults are disabled).
    pub fn fault_stats(&self) -> Option<&FaultStats> {
        self.faults.as_ref().map(|fs| &fs.stats)
    }

    /// Liveness of one broker (true when faults are disabled).
    pub fn broker_alive(&self, broker: u32) -> bool {
        self.faults
            .as_ref()
            .map_or(true, |fs| fs.alive[broker as usize])
    }

    /// ISR membership of one broker (true when faults are disabled).
    pub fn broker_in_sync(&self, broker: u32) -> bool {
        self.faults
            .as_ref()
            .map_or(true, |fs| fs.in_sync[broker as usize])
    }

    /// Bytes one broker still has to replay before rejoining ISRs.
    pub fn recovery_backlog_bytes(&self, broker: u32) -> f64 {
        self.faults
            .as_ref()
            .map_or(0.0, |fs| {
                fs.replay[broker as usize].iter().map(|e| e.bytes).sum()
            })
    }

    /// Active (uncommitted, unlost) in-flight records and bytes — the
    /// residual term of the fault-mode conservation identity.
    pub fn active_in_flight(&self) -> (u64, f64) {
        self.inflight
            .iter()
            .filter(|f| f.active)
            .fold((0, 0.0), |(r, b), f| (r + f.records, b + f.bytes))
    }

    /// Total bytes read from the device across brokers (cold fetches +
    /// re-replication), for the re-replication read-share metric.
    pub fn device_read_bytes(&self) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.storage.bytes_read_device())
            .sum()
    }

    /// Fail-stop `broker` at `now`: it leaves every ISR, loses its RAM
    /// (page cache evicted; the on-disk log survives), and every
    /// in-flight record touching it is resolved — records it led are
    /// lost, acks it owed are skipped (their bytes queue for
    /// re-replication) so surviving records commit on the shrunken ISR
    /// instead of hanging. Panics without [`Fabric::enable_faults`].
    pub fn kill_broker(&mut self, now: u64, broker: u32, out: &mut Vec<FabricOut>) {
        let n = self.brokers.len();
        {
            let fs = self.faults.as_mut().expect("enable_faults first");
            fs.alive[broker as usize] = false;
            fs.in_sync[broker as usize] = false;
        }
        // A crash loses the page cache, not the log: drop the cached
        // window, keep the per-group appended (high-water) counters.
        if let Some(rp) = &mut self.read_path {
            rp.caches[broker as usize].evict_all();
        }
        // Resolve in-flight state. Indexed loop: maybe_commit needs
        // &mut self. The fid is intentionally NOT freed on loss — stale
        // events referencing it may still be queued (see the pending
        // mask docs); the leak is bounded by in-flight count per kill.
        for fid in 0..self.inflight.len() as u32 {
            let (active, leader, partition, class, bytes, pending) = {
                let f = &self.inflight[fid as usize];
                (f.active, f.leader, f.partition, f.class, f.bytes, f.pending)
            };
            if !active {
                continue;
            }
            if leader == broker {
                self.lose(fid);
                continue;
            }
            let r = (broker as usize + n - leader as usize) % n;
            if r >= 1 && r < self.replication && pending & (1 << r) != 0 {
                {
                    let f = &mut self.inflight[fid as usize];
                    f.pending &= !(1 << r);
                    debug_assert!(f.remaining_acks > 0);
                    f.remaining_acks -= 1;
                }
                self.faults
                    .as_mut()
                    .unwrap()
                    .note_missed(broker, partition, leader, class, bytes);
                self.maybe_commit(fid, now, out);
            }
        }
    }

    /// Rejoin `broker` at `now` as an alive, out-of-sync follower, and
    /// kick catch-up: its missed bytes replay off the source leaders at
    /// the recovery bandwidth; it re-enters ISRs when the backlog is
    /// empty. Panics without [`Fabric::enable_faults`].
    pub fn restart_broker(&mut self, now: u64, broker: u32, out: &mut Vec<FabricOut>) {
        let fs = self.faults.as_mut().expect("enable_faults first");
        fs.alive[broker as usize] = true;
        fs.recovery_ticks[broker as usize] += 1;
        out.push(FabricOut::Schedule(now, FabricEv::Recovery { broker }));
    }

    /// Cut the `a`↔`b` links until `now + duration_us`. Fan-outs across
    /// the cut are skipped from now on (the skipped side falls out of
    /// sync and accrues replay backlog); packets already in flight are
    /// delivered. At the heal instant both ends get a catch-up kick.
    /// Panics without [`Fabric::enable_faults`].
    pub fn partition_links(
        &mut self,
        now: u64,
        a: u32,
        b: u32,
        duration_us: u64,
        out: &mut Vec<FabricOut>,
    ) {
        let healed_at = now + duration_us;
        let fs = self.faults.as_mut().expect("enable_faults first");
        fs.blocked.retain(|&(_, _, until)| until > now);
        fs.blocked.push((a.min(b), a.max(b), healed_at));
        for broker in [a, b] {
            fs.recovery_ticks[broker as usize] += 1;
            out.push(FabricOut::Schedule(healed_at, FabricEv::Recovery { broker }));
        }
    }

    /// Mark an active record as lost (leader death / quorum loss before
    /// commit). The fid stays allocated: queued events may still name it.
    fn lose(&mut self, fid: u32) {
        let f = &mut self.inflight[fid as usize];
        if !f.active {
            return;
        }
        f.active = false;
        let (records, bytes) = (f.records, f.bytes);
        let fs = self.faults.as_mut().expect("lose() is fault-mode only");
        fs.stats.records_lost += records;
        fs.stats.bytes_lost += bytes;
    }

    fn request_cpu_us(&self, bytes: f64) -> f64 {
        self.tuning.request_cpu_us + self.tuning.per_byte_cpu_us * bytes
    }

    /// Request CPU for a batch standing for `records` client records:
    /// the fixed per-request cost is paid once per record (the broker
    /// would have parsed/validated each), the per-byte cost once per
    /// byte. `records <= 1` takes the exact per-record expression.
    fn request_cpu_us_n(&self, bytes: f64, records: u64) -> f64 {
        if records <= 1 {
            self.request_cpu_us(bytes)
        } else {
            self.tuning.request_cpu_us * records as f64 + self.tuning.per_byte_cpu_us * bytes
        }
    }

    fn alloc(&mut self, inf: InFlight) -> u32 {
        if let Some(fid) = self.free.pop() {
            self.inflight[fid as usize] = inf;
            fid
        } else {
            self.inflight.push(inf);
            (self.inflight.len() - 1) as u32
        }
    }

    /// Begin a produce: the record leaves the client now; returns the
    /// event that should be scheduled (leader NIC arrival). Requests sent
    /// through this entry point run in scheduling class 0.
    ///
    /// Returns whether the produce was admitted: always `true` in an
    /// immortal world; `false` only in fault mode when the leader is
    /// dead or the ISR is below `min_isr` (the caller should release
    /// its token — no commit will ever arrive).
    pub fn send(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        token: u64,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) -> bool {
        self.send_classed(now, partition, leader, bytes, token, 0, meter, producer_nic, out)
    }

    /// [`Fabric::send`] with an explicit scheduling class (tenant id).
    /// The class rides the record through every request-CPU hop (leader
    /// and followers); it is inert unless weighted scheduling is enabled.
    #[allow(clippy::too_many_arguments)]
    pub fn send_classed(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        token: u64,
        class: u8,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) -> bool {
        self.send_grouped_classed(
            now, partition, leader, bytes, 1, token, class, meter, producer_nic, out,
        )
    }

    /// [`Fabric::send_classed`] for a batch standing for `records` client
    /// records (flow-aggregation macro-records). Bytes ride the NIC /
    /// storage hops in aggregate; request CPU is charged per record via
    /// [`Fabric::request_cpu_us_n`]. `records == 1` is exactly
    /// [`Fabric::send_classed`].
    #[allow(clippy::too_many_arguments)]
    pub fn send_grouped_classed(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        records: u64,
        token: u64,
        class: u8,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) -> bool {
        self.send_grouped_classed_from(
            now, partition, leader, bytes, records, token, class, NO_NODE, meter, producer_nic,
            out,
        )
    }

    /// [`Fabric::send_grouped_classed`] with the producer's network node
    /// identity. With the contention-aware network installed and
    /// `src_node != NO_NODE`, the wire hop becomes a transfer over the
    /// producer's access link and (cross-rack) the shared uplinks;
    /// otherwise the send pays the fixed [`WIRE_US`] transit, bit for
    /// bit the pre-network path.
    #[allow(clippy::too_many_arguments)]
    pub fn send_grouped_classed_from(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        records: u64,
        token: u64,
        class: u8,
        src_node: u32,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) -> bool {
        // Fault-mode admission: a dead leader or an ISR below min_isr
        // refuses the produce (Kafka's NotEnoughReplicas), counted as a
        // rejection. With every broker healthy this computes isr ==
        // replication and charges nothing extra.
        if let Some(fs) = &self.faults {
            let n = self.brokers.len();
            let mut isr = 1usize;
            for r in 1..self.replication {
                let fb = ((leader as usize + r) % n) as u32;
                if fs.follower_available(leader, fb, now) {
                    isr += 1;
                }
            }
            let rejected = !fs.alive[leader as usize] || isr < fs.min_isr;
            let fs = self.faults.as_mut().unwrap();
            fs.stats.records_offered += records;
            fs.stats.bytes_offered += bytes;
            if rejected {
                fs.stats.records_rejected += records;
                fs.stats.bytes_rejected += bytes;
                return false;
            }
        }
        meter.add(Class::Producer, Channel::Network, Dir::Write, bytes);
        let t_ser = producer_nic.submit(now, bytes);
        let fid = self.alloc(InFlight {
            token,
            partition,
            leader,
            bytes,
            records,
            class,
            remaining_acks: (self.replication - 1) as u8,
            leader_stored: false,
            active: true,
            pending: 0,
            isr: self.replication as u8,
            // Fabric cell covers [send, commit]; charged only when
            // provenance is armed.
            tax: TaxCell::new(now),
        });
        self.emit_transfer(
            t_ser,
            src_node,
            leader,
            bytes,
            WIRE_US,
            FabricEv::LeaderArrive { fid },
            out,
        );
        true
    }

    /// Route one asynchronous wire hop: with the network installed and
    /// both endpoints mapped, prepare a transfer that enters the shared
    /// links when serialization finishes at `t_ser` (its payload event
    /// fires `prop_us` after the last byte arrives); otherwise schedule
    /// the payload at the fixed `t_ser + prop_us`, bit for bit the
    /// pre-network arithmetic.
    fn emit_transfer(
        &mut self,
        t_ser: u64,
        src: u32,
        dst: u32,
        bytes: f64,
        prop_us: u64,
        ev: FabricEv,
        out: &mut Vec<FabricOut>,
    ) {
        match &mut self.net {
            Some(net) if src != NO_NODE && dst != NO_NODE => {
                let xfer = net.prepare(src, dst, bytes, prop_us, Some(ev));
                out.push(FabricOut::Schedule(t_ser, FabricEv::NetStart { xfer }));
            }
            _ => out.push(FabricOut::Schedule(t_ser + prop_us, ev)),
        }
    }

    /// A client retransmission of a record already offered once under
    /// the same `token` (its per-producer sequence number — tokens are
    /// unique per live record, so the token *is* the idempotence key).
    ///
    /// With dedup enabled the broker first checks the token against its
    /// in-flight state: an **active** original suppresses the duplicate
    /// (counted, [`SendOutcome::Duplicate`]) — this is the retry racing
    /// a slow ack, and admitting it would commit the record twice. A
    /// **lost** original (its slot is retained precisely so this scan
    /// can find it) is repaired: the loss accounting is reversed and the
    /// slot's identity retired, because the record's fate now rides this
    /// retransmission. After the dedup step (or immediately, without
    /// dedup) the retransmission takes the normal admission path.
    #[allow(clippy::too_many_arguments)]
    pub fn send_retry_grouped_classed(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        records: u64,
        token: u64,
        class: u8,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) -> SendOutcome {
        self.send_retry_grouped_classed_from(
            now, partition, leader, bytes, records, token, class, NO_NODE, meter, producer_nic,
            out,
        )
    }

    /// [`Fabric::send_retry_grouped_classed`] with the producer's
    /// network node identity (see
    /// [`Fabric::send_grouped_classed_from`]).
    #[allow(clippy::too_many_arguments)]
    pub fn send_retry_grouped_classed_from(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        records: u64,
        token: u64,
        class: u8,
        src_node: u32,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) -> SendOutcome {
        debug_assert_ne!(token, RETIRED_TOKEN);
        if self.dedup_enabled() {
            let mut repair: Option<u32> = None;
            for (fid, f) in self.inflight.iter().enumerate() {
                if f.token != token {
                    continue;
                }
                if f.active {
                    let fs = self.faults.as_mut().unwrap();
                    fs.stats.records_offered += records;
                    fs.stats.bytes_offered += bytes;
                    fs.stats.dedup_suppressed_records += records;
                    fs.stats.dedup_suppressed_bytes += bytes;
                    return SendOutcome::Duplicate;
                }
                repair = Some(fid as u32);
                break;
            }
            if let Some(fid) = repair {
                // Reverse the loss with the slot's own numbers (exact in
                // u64) and retire its identity so a later retry of a
                // record that happens to reuse this item token cannot
                // re-match the slot.
                let (r, b) = {
                    let f = &mut self.inflight[fid as usize];
                    let rb = (f.records, f.bytes);
                    f.token = RETIRED_TOKEN;
                    rb
                };
                let fs = self.faults.as_mut().unwrap();
                fs.stats.records_lost -= r;
                fs.stats.bytes_lost -= b;
            }
        }
        if self.send_grouped_classed_from(
            now, partition, leader, bytes, records, token, class, src_node, meter, producer_nic,
            out,
        ) {
            SendOutcome::Admitted
        } else {
            SendOutcome::Rejected
        }
    }

    /// Advance one fabric event.
    pub fn handle(&mut self, now: u64, ev: FabricEv, meter: &mut BandwidthMeter, out: &mut Vec<FabricOut>) {
        match ev {
            FabricEv::LeaderArrive { fid } => {
                let (leader, bytes, records, class) = {
                    let f = &self.inflight[fid as usize];
                    (f.leader as usize, f.bytes, f.records, f.class)
                };
                if self.faults.is_some() {
                    if !self.inflight[fid as usize].active {
                        return; // already lost (leader died mid-flight)
                    }
                    if !self.broker_alive(leader as u32) {
                        self.lose(fid);
                        return;
                    }
                }
                meter.add(Class::Broker, Channel::Network, Dir::Read, bytes);
                let cpu = self.request_cpu_us_n(bytes, records);
                let b = &mut self.brokers[leader];
                let t_rx = b.nic_rx.submit(now, bytes);
                let t_cpu = b.cpu_submit(t_rx, class, cpu);
                if self.provenance {
                    // [send, t_rx] is producer-NIC serialization + wire
                    // (+ contention) + leader-NIC drain; [t_rx, t_cpu]
                    // splits into the ideal uncontended service time
                    // (work / cores) vs queueing behind other requests.
                    let svc_ideal =
                        (cpu / self.tuning.request_handler_cores as f64).round() as u64;
                    let f = &mut self.inflight[fid as usize];
                    f.tax.charge(Segment::Network, t_rx);
                    f.tax.charge_split(Segment::CpuService, svc_ideal, Segment::CpuQueue, t_cpu);
                }
                out.push(FabricOut::Schedule(t_cpu, FabricEv::LeaderCpuDone { fid }));
            }
            FabricEv::LeaderCpuDone { fid } => {
                let (leader, bytes, class, partition) = {
                    let f = &self.inflight[fid as usize];
                    (f.leader as usize, f.bytes, f.class, f.partition)
                };
                if self.faults.is_some() {
                    if !self.inflight[fid as usize].active {
                        return;
                    }
                    if !self.broker_alive(leader as u32) {
                        self.lose(fid);
                        return;
                    }
                }
                // Durable write on the leader, in the record's tenant
                // class (inert unless storage QoS is enabled).
                meter.add(Class::Broker, Channel::Storage, Dir::Write, bytes);
                let t_wr = self.brokers[leader].storage.write_classed(now, bytes, class);
                if self.provenance {
                    // [cpu done, t_wr]: NVMe write queue + device time
                    // for the leader append.
                    self.inflight[fid as usize].tax.charge(Segment::StorageWrite, t_wr);
                }
                if let Some(rp) = &mut self.read_path {
                    rp.caches[leader].append_group(partition, bytes);
                }
                out.push(FabricOut::Schedule(t_wr, FabricEv::LeaderStored { fid }));
                // Fan out to followers.
                let n = self.brokers.len();
                if self.faults.is_some() {
                    // Availability-aware fan-out: dead / out-of-sync /
                    // partitioned followers are skipped — their bytes
                    // queue for re-replication — and the record's
                    // awaited-ack set is rebuilt from who is actually
                    // reachable. With everyone healthy this schedules
                    // the exact events of the immortal branch below.
                    let mut pending = 0u8;
                    let mut acks = 0u8;
                    for r in 1..self.replication {
                        let fb = ((leader + r) % n) as u32;
                        let available = self
                            .faults
                            .as_ref()
                            .unwrap()
                            .follower_available(leader as u32, fb, now);
                        if available {
                            pending |= 1 << r;
                            acks += 1;
                            meter.add(Class::Broker, Channel::Network, Dir::Write, bytes);
                            let t_ser = self.brokers[leader].nic_tx.submit(now, bytes);
                            self.emit_transfer(
                                t_ser,
                                leader as u32,
                                fb,
                                bytes,
                                WIRE_US,
                                FabricEv::FollowerArrive { fid, broker: fb },
                                out,
                            );
                        } else {
                            self.faults.as_mut().unwrap().note_missed(
                                fb, partition, leader as u32, class, bytes,
                            );
                        }
                    }
                    let min_isr = self.faults.as_ref().unwrap().min_isr;
                    let f = &mut self.inflight[fid as usize];
                    f.remaining_acks = acks;
                    f.pending = pending;
                    f.isr = 1 + acks;
                    if ((1 + acks) as usize) < min_isr {
                        // The ISR shrank below quorum between admission
                        // and fan-out: the leader stored it, but it can
                        // never legally commit — lost (Kafka truncates).
                        self.lose(fid);
                    }
                } else {
                    for r in 1..self.replication {
                        let fb = ((leader + r) % n) as u32;
                        meter.add(Class::Broker, Channel::Network, Dir::Write, bytes);
                        let t_ser = self.brokers[leader].nic_tx.submit(now, bytes);
                        self.emit_transfer(
                            t_ser,
                            leader as u32,
                            fb,
                            bytes,
                            WIRE_US,
                            FabricEv::FollowerArrive { fid, broker: fb },
                            out,
                        );
                    }
                }
            }
            FabricEv::FollowerArrive { fid, broker } => {
                let (bytes, records, class) = {
                    let f = &self.inflight[fid as usize];
                    (f.bytes, f.records, f.class)
                };
                if self.faults.is_some() && self.stale_follower_event(fid, broker) {
                    return;
                }
                meter.add(Class::Broker, Channel::Network, Dir::Read, bytes);
                let cpu = self.request_cpu_us_n(bytes, records);
                let b = &mut self.brokers[broker as usize];
                let t_rx = b.nic_rx.submit(now, bytes);
                let t_cpu = b.cpu_submit(t_rx, class, cpu);
                out.push(FabricOut::Schedule(
                    t_cpu,
                    FabricEv::FollowerCpuDone { fid, broker },
                ));
            }
            FabricEv::FollowerCpuDone { fid, broker } => {
                let (bytes, class, partition, leader) = {
                    let f = &self.inflight[fid as usize];
                    (f.bytes, f.class, f.partition, f.leader)
                };
                if self.faults.is_some() && self.stale_follower_event(fid, broker) {
                    return;
                }
                meter.add(Class::Broker, Channel::Storage, Dir::Write, bytes);
                let t_wr = self.brokers[broker as usize]
                    .storage
                    .write_classed(now, bytes, class);
                if let Some(rp) = &mut self.read_path {
                    rp.caches[broker as usize].append_group(partition, bytes);
                }
                // The ack is a tiny frame riding the same fabric back
                // to the leader; without the network it is the fixed
                // transit, bit for bit.
                self.emit_transfer(
                    t_wr,
                    broker,
                    leader,
                    ACK_BYTES,
                    ACK_TRANSIT_US,
                    FabricEv::ReplicaAck { fid, broker },
                    out,
                );
            }
            FabricEv::LeaderStored { fid } => {
                if self.faults.is_some() {
                    if !self.inflight[fid as usize].active {
                        return;
                    }
                    let leader = self.inflight[fid as usize].leader;
                    if !self.broker_alive(leader) {
                        self.lose(fid);
                        return;
                    }
                }
                self.inflight[fid as usize].leader_stored = true;
                self.maybe_commit(fid, now, out);
            }
            FabricEv::ReplicaAck { fid, broker } => {
                if self.faults.is_some() {
                    if !self.inflight[fid as usize].active {
                        return;
                    }
                    let leader = self.inflight[fid as usize].leader;
                    if !self.broker_alive(leader) {
                        // The ack arrived at a dead leader: the record
                        // can never commit.
                        self.lose(fid);
                        return;
                    }
                    let n = self.brokers.len();
                    let r = (broker as usize + n - leader as usize) % n;
                    let f = &mut self.inflight[fid as usize];
                    if r == 0 || r >= self.replication || f.pending & (1 << r) == 0 {
                        return; // stale: this ack was already resolved
                    }
                    f.pending &= !(1 << r);
                    debug_assert!(f.remaining_acks > 0);
                    f.remaining_acks -= 1;
                    self.maybe_commit(fid, now, out);
                    return;
                }
                let f = &mut self.inflight[fid as usize];
                debug_assert!(f.remaining_acks > 0);
                f.remaining_acks -= 1;
                self.maybe_commit(fid, now, out);
            }
            FabricEv::Recovery { broker } => {
                self.recovery_tick(now, broker, meter, out);
            }
            FabricEv::NetStart { xfer } => {
                let net = self.net.as_mut().expect("NetStart without enable_network");
                let (done, gen) = net.start(now, xfer);
                out.push(FabricOut::Schedule(done, FabricEv::NetDone { xfer, gen }));
                drain_resched(net, out);
            }
            FabricEv::NetDone { xfer, gen } => {
                let net = self.net.as_mut().expect("NetDone without enable_network");
                if let Some((prop_us, payload)) = net.complete(now, xfer, gen) {
                    // Sync transfers (fetch / recovery legs) carry no
                    // payload: their delivery time was already returned
                    // to the caller; this event just releases the links.
                    if let Some(ev) = payload {
                        out.push(FabricOut::Schedule(now + prop_us, ev));
                    }
                }
                drain_resched(net, out);
            }
        }
    }

    /// Fault-mode validity check for follower-side events: drop events
    /// aimed at a dead broker, and events whose pending-ack bit was
    /// already resolved (by the ack itself or by a kill) — they belong
    /// to a previous life of this fid.
    fn stale_follower_event(&self, fid: u32, broker: u32) -> bool {
        if !self.broker_alive(broker) {
            return true;
        }
        let f = &self.inflight[fid as usize];
        if !f.active {
            return true;
        }
        let n = self.brokers.len();
        let r = (broker as usize + n - f.leader as usize) % n;
        r == 0 || r >= self.replication || f.pending & (1 << r) == 0
    }

    /// One paced catch-up tick for a recovering broker: cold-read up to
    /// `recovery_bytes_per_sec × RECOVERY_TICK_US` missed bytes off the
    /// source leaders (request CPU + device read on the write spindle +
    /// NIC out/in + the follower's own durable write — the maximally-
    /// lagged-consumer path), then either rejoin the ISR or reschedule.
    fn recovery_tick(
        &mut self,
        now: u64,
        broker: u32,
        meter: &mut BandwidthMeter,
        out: &mut Vec<FabricOut>,
    ) {
        let b = broker as usize;
        let Some(fs) = self.faults.as_mut() else {
            debug_assert!(false, "Recovery event without fault mode");
            return;
        };
        fs.recovery_ticks[b] = fs.recovery_ticks[b].saturating_sub(1);
        if fs.recovery_ticks[b] > 0 {
            return; // a duplicate kick; the queued tick will do the work
        }
        if !fs.alive[b] {
            return; // killed again mid-recovery; a restart re-kicks
        }
        if fs.replay[b].is_empty() {
            if !fs.in_sync[b] {
                fs.in_sync[b] = true;
                let at = now.max(fs.last_apply_us[b]);
                fs.stats.recovered_at_us.push((broker, at));
            }
            return;
        }
        let mut budget = fs.recovery_bytes_per_sec * (RECOVERY_TICK_US as f64 / 1e6);
        // Latest network delivery this tick: with the contention-aware
        // fabric the next tick waits for it, so the catch-up stream is
        // self-clocked by the wire instead of piling transfers onto a
        // saturated uplink. Zero (inert) without the network.
        let mut net_gate = 0u64;
        let mut i = 0;
        while budget > 1.0 && i < fs.replay[b].len() {
            let e = fs.replay[b][i];
            let src = e.leader as usize;
            if !fs.alive[src] {
                i += 1; // source down: defer this entry, try the next
                continue;
            }
            let take = e.bytes.min(budget);
            budget -= take;
            let cpu = self.tuning.request_cpu_us + self.tuning.per_byte_cpu_us * take;
            let t_cpu = self.brokers[src].cpu_submit(now, e.class, cpu);
            meter.add(Class::Broker, Channel::Storage, Dir::Read, take);
            let t_read = self.brokers[src]
                .storage
                .read_cold_classed(t_cpu, take, e.class);
            meter.add(Class::Broker, Channel::Network, Dir::Write, take);
            let t_ser = self.brokers[src].nic_tx.submit(t_read, take);
            let t_net = match &mut self.net {
                Some(net) => {
                    let (xfer, gen, done) = net.transfer_sync(now, src as u32, broker, take);
                    out.push(FabricOut::Schedule(done, FabricEv::NetDone { xfer, gen }));
                    drain_resched(net, out);
                    net_gate = net_gate.max(done);
                    t_ser.max(done) + WIRE_US
                }
                None => t_ser + WIRE_US,
            };
            meter.add(Class::Broker, Channel::Network, Dir::Read, take);
            let t_rx = self.brokers[b].nic_rx.submit(t_net, take);
            meter.add(Class::Broker, Channel::Storage, Dir::Write, take);
            let t_wr = self.brokers[b].storage.write_classed(t_rx, take, e.class);
            if let Some(rp) = &mut self.read_path {
                rp.caches[b].append_group(e.group, take);
            }
            fs.last_apply_us[b] = fs.last_apply_us[b].max(t_wr);
            fs.stats.rereplicated_bytes += take;
            let entry = &mut fs.replay[b][i];
            entry.bytes -= take;
            if entry.bytes <= 1e-9 {
                fs.replay[b].remove(i);
            } else {
                i += 1;
            }
        }
        if fs.replay[b].is_empty() {
            fs.in_sync[b] = true;
            let at = now.max(fs.last_apply_us[b]);
            fs.stats.recovered_at_us.push((broker, at));
        } else {
            fs.recovery_ticks[b] += 1;
            out.push(FabricOut::Schedule(
                (now + RECOVERY_TICK_US).max(net_gate),
                FabricEv::Recovery { broker },
            ));
        }
    }

    fn maybe_commit(&mut self, fid: u32, now: u64, out: &mut Vec<FabricOut>) {
        let (active, leader_stored, remaining, isr, records, bytes) = {
            let f = &self.inflight[fid as usize];
            (
                f.active,
                f.leader_stored,
                f.remaining_acks,
                f.isr,
                f.records,
                f.bytes,
            )
        };
        if !(active && leader_stored && remaining == 0) {
            return;
        }
        if let Some(fs) = &mut self.faults {
            if (isr as usize) < fs.min_isr {
                // Structurally unreachable — admission and fan-out both
                // enforce the quorum — counted rather than assumed so
                // the differential suite can assert it stayed zero.
                fs.stats.min_isr_violations += 1;
                self.lose(fid);
                return;
            }
            fs.stats.records_committed += records;
            fs.stats.bytes_committed += bytes;
        }
        let dedup = self.dedup_enabled();
        let provenance = self.provenance;
        let f = &mut self.inflight[fid as usize];
        f.active = false;
        out.push(FabricOut::Committed {
            token: f.token,
            partition: f.partition,
            at: now,
        });
        // Capture the record identity before dedup retires the slot: the
        // dc layer claims the commit cell by this token.
        let (token, mut cell) = (f.token, f.tax);
        if dedup {
            // The item token can be released and reused once the commit
            // is delivered; retire the slot's copy so a later dedup scan
            // cannot match this freed slot against the token's next life.
            f.token = RETIRED_TOKEN;
        }
        if provenance {
            // [leader stored ∨ last follower ack, commit]: waiting for
            // the ISR quorum.
            cell.charge(Segment::Replication, now);
            self.committed_tax.push((token, cell));
        }
        self.free.push(fid);
    }

    /// Consumer fetch: request CPU at the leader, page-cache read, NIC out
    /// to the consumer. Returns the delivery completion time. Chained
    /// synchronously — see the module docs for why this is acceptable.
    pub fn fetch(
        &mut self,
        now: u64,
        leader: u32,
        bytes: f64,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
    ) -> u64 {
        self.fetch_classed(now, leader, bytes, 0, consumer_nic_rx, meter)
    }

    /// [`Fabric::fetch`] with an explicit scheduling class (tenant id);
    /// inert unless weighted request-CPU scheduling is enabled. No
    /// partition identity, so with the read path enabled the fetch is
    /// still served from memory (the [`NO_GROUP`] contract).
    pub fn fetch_classed(
        &mut self,
        now: u64,
        leader: u32,
        bytes: f64,
        class: u8,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
    ) -> u64 {
        self.fetch_group_classed(now, leader, NO_GROUP, bytes, class, consumer_nic_rx, meter)
    }

    /// [`Fabric::fetch_classed`] with the partition group identity the
    /// measured read path needs. With the read path disabled (or
    /// `group == NO_GROUP`) this is the seed fetch, bit for bit: request
    /// CPU, a free page-cache read, NIC out. With it enabled, the fetch
    /// range `(consumed, consumed + bytes]` of the group is split
    /// against the leader's cached window — resident bytes stay free,
    /// cold bytes go to the device read path in the fetch's scheduling
    /// class, where they contend with the replicated write stream on
    /// the same spindle.
    #[allow(clippy::too_many_arguments)]
    pub fn fetch_group_classed(
        &mut self,
        now: u64,
        leader: u32,
        group: u32,
        bytes: f64,
        class: u8,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
    ) -> u64 {
        let mut tmp = Vec::new();
        let t = self.fetch_group_classed_to(
            now, leader, group, bytes, class, NO_NODE, consumer_nic_rx, meter, &mut tmp,
        );
        // The NO_NODE path never touches the network, so it has no
        // release events to schedule (and allocates nothing above).
        debug_assert!(tmp.is_empty());
        t
    }

    /// [`Fabric::fetch_group_classed`] with the consumer's network node
    /// identity. With the contention-aware network installed and
    /// `dst_node != NO_NODE`, the response bytes cross the broker's
    /// access link and (cross-rack) the shared uplinks as a transfer
    /// whose rate is locked at its max-min share on entry; the link
    /// release event it needs goes through `out`. The fetch stays
    /// synchronous — it returns the delivery completion time — so under
    /// contention the locked estimate is the response's network time.
    #[allow(clippy::too_many_arguments)]
    pub fn fetch_group_classed_to(
        &mut self,
        now: u64,
        leader: u32,
        group: u32,
        bytes: f64,
        class: u8,
        dst_node: u32,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
        out: &mut Vec<FabricOut>,
    ) -> u64 {
        let cpu = self.request_cpu_us(bytes);
        let b = &mut self.brokers[leader as usize];
        let t_cpu = b.cpu_submit(now, class, cpu);
        let t_read = match &mut self.read_path {
            Some(rp) if group != NO_GROUP => {
                let idx = group as usize;
                if idx >= rp.consumed.len() {
                    rp.consumed.resize(idx + 1, 0);
                }
                let cache = &mut rp.caches[leader as usize];
                let start = rp.consumed[idx];
                let want = bytes.ceil() as u64;
                let (hit, miss) = cache.read_range_group(group, start, want);
                // Advance the consumer offset; clamp to the group's
                // high-water mark so per-fetch rounding cannot push the
                // offset past what was actually appended.
                rp.consumed[idx] = (start + want).min(cache.appended_of(group)).max(start);
                let mut t = t_cpu;
                if hit > 0 {
                    t = b.storage.read(t_cpu, hit as f64, true);
                }
                if miss > 0 {
                    meter.add(Class::Broker, Channel::Storage, Dir::Read, miss as f64);
                    t = t.max(b.storage.read_cold_classed(t_cpu, miss as f64, class));
                }
                t
            }
            _ => b.storage.read(t_cpu, bytes, true), // page cache (seed path)
        };
        let t_ser = b.nic_tx.submit(t_read, bytes);
        let t_net = match &mut self.net {
            Some(net) if dst_node != NO_NODE => {
                let (xfer, gen, done) = net.transfer_sync(now, leader, dst_node, bytes);
                out.push(FabricOut::Schedule(done, FabricEv::NetDone { xfer, gen }));
                drain_resched(net, out);
                // Delivery is gated by both the serialization chain and
                // the network transfer; uncontended they coincide.
                t_ser.max(done) + WIRE_US
            }
            _ => t_ser + WIRE_US,
        };
        let t_rx = consumer_nic_rx.submit(t_net, bytes);
        meter.add(Class::Broker, Channel::Network, Dir::Write, bytes);
        meter.add(Class::Consumer, Channel::Network, Dir::Read, bytes);
        t_rx
    }

    /// Max spec-relative storage-write utilization across brokers (Fig 11b).
    pub fn max_storage_write_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.storage.write_spec_utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_storage_read_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.storage.read_spec_utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_nic_rx_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.nic_rx.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_nic_tx_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.nic_tx.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_cpu_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| match &b.req_cpu_wfq {
                Some(wfq) => wfq.utilization(elapsed_us),
                None => b.req_cpu.utilization(elapsed_us),
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::EventQueue;

    fn fabric() -> Fabric {
        let nvme = NvmeSpec::p4510_1tb();
        Fabric::new(
            3,
            1,
            3,
            nvme,
            0.7 * nvme.write_bw,
            crate::util::units::gbps(100),
            KafkaTuning::default(),
        )
    }

    /// Drive a single produce through the fabric and return commit time.
    fn run_one(f: &mut Fabric, now: u64, bytes: f64) -> (u64, u64) {
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        f.send(now, 0, 0, bytes, 42, &mut meter, &mut nic, &mut out);
        let mut committed = None;
        loop {
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(t, ev) => q.at(t, ev),
                    FabricOut::Committed { token, at, .. } => committed = Some((token, at)),
                }
            }
            match q.pop() {
                Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                None => break,
            }
        }
        committed.expect("record should commit")
    }

    #[test]
    fn produce_commits_after_replication() {
        let mut f = fabric();
        let (token, at) = run_one(&mut f, 1000, 37_300.0);
        assert_eq!(token, 42);
        // Commit after nic + cpu + leader write + follower write + ack.
        assert!(at > 1000 + 100, "commit too early: {at}");
        assert!(at < 1000 + 20_000, "commit too slow: {at}");
        // All three brokers wrote the record (leader + 2 followers).
        let wrote = f
            .brokers
            .iter()
            .filter(|b| b.storage.bytes_written() > 0.0)
            .count();
        assert_eq!(wrote, 3);
    }

    #[test]
    fn replication_one_writes_once() {
        let nvme = NvmeSpec::p4510_1tb();
        let mut f = Fabric::new(
            3,
            1,
            1,
            nvme,
            0.7 * nvme.write_bw,
            crate::util::units::gbps(100),
            KafkaTuning::default(),
        );
        run_one(&mut f, 0, 10_000.0);
        let wrote = f
            .brokers
            .iter()
            .filter(|b| b.storage.bytes_written() > 0.0)
            .count();
        assert_eq!(wrote, 1);
    }

    #[test]
    fn sustained_load_no_phantom_backlog() {
        // Offer 30% of effective write bandwidth for 10 simulated seconds;
        // per-broker backlogs must stay bounded (the ratchet bug this
        // fabric exists to prevent).
        let mut f = fabric();
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        // ~1850 records/s x 37.3kB x 3 replication / 3 brokers ≈ 207 MB/s
        // per broker ≈ 27% of the 770 MB/s effective bandwidth.
        let mut commits = 0u64;
        let mut last_commit = 0u64;
        for i in 0..18_500u64 {
            let t = i * 540;
            // Drain fabric events up to t first.
            while q.peek_time().map(|pt| pt <= t).unwrap_or(false) {
                let (et, ev) = q.pop().unwrap();
                f.handle(et, ev, &mut meter, &mut out);
                for o in out.drain(..) {
                    match o {
                        FabricOut::Schedule(st, sev) => q.at(st, sev),
                        FabricOut::Committed { at, .. } => {
                            commits += 1;
                            last_commit = at;
                        }
                    }
                }
            }
            f.send(t, (i % 64) as u32, (i % 3) as u32, bytes, i, &mut meter, &mut nic, &mut out);
            for o in out.drain(..) {
                if let FabricOut::Schedule(st, sev) = o {
                    q.at(st, sev);
                }
            }
        }
        // Finish draining.
        while let Some((et, ev)) = q.pop() {
            f.handle(et, ev, &mut meter, &mut out);
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(st, sev) => q.at(st, sev),
                    FabricOut::Committed { at, .. } => {
                        commits += 1;
                        last_commit = at;
                    }
                }
            }
        }
        assert_eq!(commits, 18_500);
        // Last send at ~10s; commits must complete shortly after (no
        // multi-second phantom queues at 27% utilization).
        assert!(
            last_commit < 10_000_000 + 200_000,
            "phantom backlog: last commit at {last_commit}"
        );
        for b in &f.brokers {
            assert!(b.storage.write_spec_utilization(10_000_000) < 0.35);
        }
    }

    #[test]
    fn weighted_cpu_commits_and_accounts_utilization() {
        let mut f = fabric();
        f.enable_weighted_cpu(&[1.0, 4.0]);
        assert!(f.weighted_cpu_enabled());
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        // One record per class through the full produce path.
        f.send_classed(0, 0, 0, 37_300.0, 1, 0, &mut meter, &mut nic, &mut out);
        f.send_classed(0, 1, 1, 37_300.0, 2, 1, &mut meter, &mut nic, &mut out);
        let mut commits = 0;
        loop {
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(t, ev) => q.at(t, ev),
                    FabricOut::Committed { .. } => commits += 1,
                }
            }
            match q.pop() {
                Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                None => break,
            }
        }
        assert_eq!(commits, 2, "both classes must commit under WFQ");
        assert!(f.max_cpu_util(1_000_000) > 0.0);
    }

    #[test]
    fn grouped_send_charges_request_cpu_per_record() {
        // A macro-record standing for k client records must pay the same
        // broker request CPU the k individual sends would have paid: the
        // fixed per-request cost k times plus the per-byte term once.
        let run_grouped = |records: u64, bytes: f64| -> Fabric {
            let mut f = fabric();
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            f.send_grouped_classed(0, 0, 0, bytes, records, 9, 0, &mut meter, &mut nic, &mut out);
            loop {
                for o in out.drain(..) {
                    if let FabricOut::Schedule(t, ev) = o {
                        q.at(t, ev);
                    }
                }
                match q.pop() {
                    Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                    None => break,
                }
            }
            f
        };
        let elapsed = 1_000_000u64;
        let k = 16u64;
        let bytes = 2_000.0 * k as f64;
        let one = run_grouped(1, bytes).max_cpu_util(elapsed);
        let grouped = run_grouped(k, bytes).max_cpu_util(elapsed);
        // Leader CPU: the grouped request pays (k-1) extra fixed costs.
        let extra = (k - 1) as f64 * KafkaTuning::default().request_cpu_us / elapsed as f64;
        assert!(
            (grouped - one - extra).abs() < 1e-9,
            "grouped {grouped} vs single {one}, expected extra {extra}"
        );
    }

    #[test]
    fn grouped_send_of_one_record_matches_send_classed() {
        // records == 1 must be the exact send_classed path: same commit
        // time, same meters, same utilizations.
        let run = |grouped: bool| -> (u64, f64) {
            let mut f = fabric();
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            if grouped {
                f.send_grouped_classed(0, 0, 0, 37_300.0, 1, 5, 0, &mut meter, &mut nic, &mut out);
            } else {
                f.send_classed(0, 0, 0, 37_300.0, 5, 0, &mut meter, &mut nic, &mut out);
            }
            let mut committed = 0;
            loop {
                for o in out.drain(..) {
                    match o {
                        FabricOut::Schedule(t, ev) => q.at(t, ev),
                        FabricOut::Committed { at, .. } => committed = at,
                    }
                }
                match q.pop() {
                    Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                    None => break,
                }
            }
            (committed, f.max_cpu_util(1_000_000))
        };
        let (at_a, cpu_a) = run(false);
        let (at_b, cpu_b) = run(true);
        assert_eq!(at_a, at_b);
        assert_eq!(cpu_a.to_bits(), cpu_b.to_bits());
    }

    #[test]
    fn storage_qos_shields_light_class_from_write_hol_blocking() {
        // Pre-load every broker's write queue with ~1 s of class-0 bulk
        // writes, then produce one small class-1 record through each
        // fabric variant. With the FIFO write path the record's commit
        // waits out the backlog; with storage QoS its class drains at its
        // own share and the commit lands orders of magnitude earlier.
        let commit_with = |qos: bool| -> u64 {
            let mut f = fabric();
            if qos {
                f.enable_storage_qos(&[1.0, 9.0]);
                assert!(f.storage_qos_enabled());
            }
            for b in 0..3u32 {
                // ~770 MB at 770 MB/s effective = ~1 s of backlog each.
                f.brokers[b as usize].storage.write_classed(0, 770e6, 0);
            }
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            f.send_classed(0, 0, 0, 2_000.0, 7, 1, &mut meter, &mut nic, &mut out);
            let mut committed = None;
            loop {
                for o in out.drain(..) {
                    match o {
                        FabricOut::Schedule(t, ev) => q.at(t, ev),
                        FabricOut::Committed { at, .. } => committed = Some(at),
                    }
                }
                match q.pop() {
                    Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                    None => break,
                }
            }
            committed.expect("record should commit")
        };
        let fifo = commit_with(false);
        let qos = commit_with(true);
        assert!(fifo > 900_000, "FIFO commit should wait out the backlog: {fifo}");
        assert!(qos < 50_000, "QoS commit should bypass the bulk backlog: {qos}");
    }

    #[test]
    fn fetch_is_fast_from_page_cache() {
        let mut f = fabric();
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let t = f.fetch(5_000, 0, 37_300.0, &mut nic, &mut meter);
        // cpu (~112us) + nic transfer (~3us) + wire.
        assert!(t > 5_000 && t < 5_600, "fetch delivered at {t}");
        assert_eq!(f.max_storage_read_util(1_000_000), 0.0);
    }

    #[test]
    fn read_path_streaming_fetch_stays_memory_speed() {
        // Ample cache + a consumer reading right behind the appender:
        // every fetch is resident, the device read path stays idle, and
        // the delivery time matches the seed's hardcoded-hit fetch.
        let mut f = fabric();
        f.enable_read_path(1e9);
        assert!(f.read_path_enabled());
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut t_commit = 0;
        for i in 0..20 {
            let (_, at) = run_one(&mut f, i * 50_000, 37_300.0);
            t_commit = at;
        }
        let t = f.fetch_group_classed(
            t_commit,
            0,
            0,
            20.0 * 37_300.0,
            0,
            &mut nic,
            &mut meter,
        );
        assert!(t < t_commit + 2_000, "streaming fetch delivered at {t}");
        let stats = f.read_path_stats().unwrap();
        assert_eq!(stats.hit_ratio(), 1.0);
        assert_eq!(stats.device_read_share(), 0.0);
        assert_eq!(f.max_storage_read_util(t_commit), 0.0);
        assert_eq!(f.group_lag_bytes(0), 0, "fetch drained the whole group");
    }

    #[test]
    fn read_path_lagging_fetch_splits_to_the_device() {
        // A 50 kB cache holds barely one 37.3 kB record per broker; a
        // consumer that never polled while 20 records landed reads the
        // evicted majority from the device — and that cold read queues
        // on the same spindle the writes use.
        let mut f = fabric();
        f.enable_read_path(50_000.0);
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut t_commit = 0;
        for i in 0..20 {
            let (_, at) = run_one(&mut f, i * 50_000, 37_300.0);
            t_commit = at;
        }
        let backlog = 20.0 * 37_300.0;
        assert!(f.group_lag_bytes(0) >= backlog as u64 - 20);
        let t = f.fetch_group_classed(t_commit, 0, 0, backlog, 0, &mut nic, &mut meter);
        let stats = f.read_path_stats().unwrap();
        assert!(
            stats.hit_ratio() < 0.1,
            "19 of 20 records were evicted: hit ratio {}",
            stats.hit_ratio()
        );
        assert!(stats.device_read_share() > 0.9);
        assert!(f.max_storage_read_util(t_commit) > 0.0, "device reads must show up");
        // ~700 kB cold at the 770 MB/s effective spindle rate ≈ 0.9 ms
        // of device time — far slower than the memory-speed fetch.
        assert!(t > t_commit + 800, "cold fetch delivered too fast: {t}");
        assert_eq!(f.group_lag_bytes(0), 0, "catch-up fetch drained the lag");
    }

    #[test]
    fn read_path_disabled_reports_no_stats() {
        let f = fabric();
        assert!(!f.read_path_enabled());
        assert!(f.read_path_stats().is_none());
        assert_eq!(f.group_lag_bytes(7), 0);
    }

    // -- failure / membership dynamics ----------------------------------

    /// Drain the event queue to empty, counting commits.
    fn drain_all(
        f: &mut Fabric,
        q: &mut EventQueue<FabricEv>,
        meter: &mut BandwidthMeter,
        out: &mut Vec<FabricOut>,
    ) -> u64 {
        let mut commits = 0;
        loop {
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(t, ev) => {
                        q.at(t, ev);
                    }
                    FabricOut::Committed { .. } => commits += 1,
                }
            }
            match q.pop() {
                Some((t, ev)) => f.handle(t, ev, meter, out),
                None => break,
            }
        }
        commits
    }

    /// The fault-mode conservation identity, exact in u64.
    fn assert_conservation(f: &Fabric) {
        let s = f.fault_stats().unwrap();
        let (active, _) = f.active_in_flight();
        assert_eq!(
            s.records_offered,
            s.records_committed + s.records_rejected + s.records_lost + active,
            "conservation: {s:?} active={active}"
        );
    }

    #[test]
    fn faults_installed_but_inert_matches_immortal_commit() {
        let run = |faults: bool| -> (u64, u64) {
            let mut f = fabric();
            if faults {
                f.enable_faults(1, 400e6);
                assert!(f.faults_enabled());
            }
            run_one(&mut f, 1000, 37_300.0)
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn dead_leader_rejects_at_admission() {
        let mut f = fabric();
        f.enable_faults(1, 400e6);
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut out = Vec::new();
        f.kill_broker(0, 0, &mut out);
        assert!(!f.broker_alive(0));
        let admitted = f.send(10, 0, 0, 37_300.0, 1, &mut meter, &mut nic, &mut out);
        assert!(!admitted);
        let s = f.fault_stats().unwrap();
        assert_eq!(s.records_rejected, 1);
        assert_eq!(s.records_offered, 1);
        // A send to a *surviving* leader still goes through.
        let admitted = f.send(10, 1, 1, 37_300.0, 2, &mut meter, &mut nic, &mut out);
        assert!(admitted);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let commits = drain_all(&mut f, &mut q, &mut meter, &mut out);
        assert_eq!(commits, 1);
        assert_conservation(&f);
    }

    #[test]
    fn min_isr_blocks_admission_below_quorum() {
        let mut f = fabric();
        f.enable_faults(3, 400e6); // quorum = all three replicas
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut out = Vec::new();
        f.kill_broker(0, 2, &mut out);
        // Leader 0 is alive but its ISR is {0, 1} < 3.
        let admitted = f.send(10, 0, 0, 37_300.0, 1, &mut meter, &mut nic, &mut out);
        assert!(!admitted);
        assert_eq!(f.fault_stats().unwrap().records_rejected, 1);
        assert_conservation(&f);
    }

    #[test]
    fn kill_follower_commits_on_shrunken_isr_and_queues_replay() {
        let mut f = fabric();
        f.enable_faults(1, 400e6);
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        f.kill_broker(0, 2, &mut out);
        assert!(f.send(0, 0, 0, bytes, 1, &mut meter, &mut nic, &mut out));
        let commits = drain_all(&mut f, &mut q, &mut meter, &mut out);
        assert_eq!(commits, 1, "record must commit on the shrunken ISR");
        let s = f.fault_stats().unwrap();
        assert_eq!(s.records_committed, 1);
        assert!((s.missed_bytes - bytes).abs() < 1e-9);
        assert!((f.recovery_backlog_bytes(2) - bytes).abs() < 1e-9);
        assert!(!f.broker_in_sync(2));
        // The dead follower never wrote.
        assert_eq!(f.brokers[2].storage.bytes_written(), 0.0);
        assert_conservation(&f);
    }

    #[test]
    fn kill_leader_mid_flight_loses_the_record() {
        let mut f = fabric();
        f.enable_faults(1, 400e6);
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        assert!(f.send(0, 0, 0, 37_300.0, 1, &mut meter, &mut nic, &mut out));
        // The record is in flight toward leader 0; the leader dies.
        f.kill_broker(1, 0, &mut out);
        let commits = drain_all(&mut f, &mut q, &mut meter, &mut out);
        assert_eq!(commits, 0);
        let s = f.fault_stats().unwrap();
        assert_eq!(s.records_lost, 1);
        assert_eq!(s.min_isr_violations, 0);
        assert_conservation(&f);
    }

    #[test]
    fn kill_mid_replication_resolves_pending_ack_and_drops_stale_events() {
        // Let the fan-out reach follower 1's CPU, then kill follower 1:
        // its pending ack resolves immediately (the commit must not hang),
        // the queued FollowerCpuDone is recognized as stale and dropped
        // (no durable write on the dead broker), and the bytes queue for
        // replay.
        let mut f = fabric();
        f.enable_faults(1, 400e6);
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        assert!(f.send(0, 0, 0, bytes, 1, &mut meter, &mut nic, &mut out));
        let mut killed = false;
        let mut commits = 0;
        loop {
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(t, ev) => {
                        q.at(t, ev);
                    }
                    FabricOut::Committed { .. } => commits += 1,
                }
            }
            let Some((t, ev)) = q.pop() else { break };
            f.handle(t, ev, &mut meter, &mut out);
            if !killed {
                if let FabricEv::FollowerArrive { broker: 1, .. } = ev {
                    // Handled: FollowerCpuDone for broker 1 is now queued.
                    f.kill_broker(t, 1, &mut out);
                    killed = true;
                }
            }
        }
        assert!(killed, "fan-out must have reached follower 1");
        assert_eq!(commits, 1, "commit must not hang on the dead follower");
        let s = f.fault_stats().unwrap();
        assert_eq!(s.records_committed, 1);
        assert!((s.missed_bytes - bytes).abs() < 1e-9);
        // The stale FollowerCpuDone was dropped before the write.
        assert_eq!(f.brokers[1].storage.bytes_written(), 0.0);
        assert_conservation(&f);
    }

    #[test]
    fn restart_replays_backlog_and_rejoins_isr() {
        let mut f = fabric();
        f.enable_faults(1, 400e6);
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        f.kill_broker(0, 2, &mut out);
        for i in 0..5u64 {
            assert!(f.send(i * 1_000, 0, 0, bytes, i, &mut meter, &mut nic, &mut out));
        }
        let commits = drain_all(&mut f, &mut q, &mut meter, &mut out);
        assert_eq!(commits, 5);
        let missed = f.fault_stats().unwrap().missed_bytes;
        assert!((missed - 5.0 * bytes).abs() < 1e-6);
        let read_before = f.device_read_bytes();
        f.restart_broker(100_000, 2, &mut out);
        assert!(f.broker_alive(2));
        assert!(!f.broker_in_sync(2), "out of sync until the backlog drains");
        drain_all(&mut f, &mut q, &mut meter, &mut out);
        let s = f.fault_stats().unwrap();
        assert!(
            (s.rereplicated_bytes - missed).abs() < 1e-6,
            "replayed {} of {} missed bytes",
            s.rereplicated_bytes,
            missed
        );
        assert_eq!(f.recovery_backlog_bytes(2), 0.0);
        assert!(f.broker_in_sync(2));
        // Catch-up cold-read the bytes off the source leader's device.
        assert!(f.device_read_bytes() > read_before);
        // The recovered broker durably re-wrote the missed bytes.
        assert!(f.brokers[2].storage.bytes_written() >= missed - 1e-6);
        let s = f.fault_stats().unwrap();
        assert_eq!(s.recovered_at_us.len(), 1);
        let (rb, rt) = s.recovered_at_us[0];
        assert_eq!(rb, 2);
        assert!(rt >= 100_000, "recovered before the restart: {rt}");
        assert_conservation(&f);
    }

    #[test]
    fn recovery_duration_decreases_with_bandwidth() {
        let recover = |bw: f64| -> u64 {
            let mut f = fabric();
            f.enable_faults(1, bw);
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            f.kill_broker(0, 2, &mut out);
            for i in 0..200u64 {
                assert!(f.send(
                    i * 500,
                    (i % 8) as u32,
                    0,
                    37_300.0,
                    i,
                    &mut meter,
                    &mut nic,
                    &mut out
                ));
            }
            drain_all(&mut f, &mut q, &mut meter, &mut out);
            f.restart_broker(200_000, 2, &mut out);
            drain_all(&mut f, &mut q, &mut meter, &mut out);
            let s = f.fault_stats().unwrap();
            assert_eq!(s.recovered_at_us.len(), 1);
            s.recovered_at_us[0].1 - 200_000
        };
        let slow = recover(50e6);
        let medium = recover(200e6);
        let fast = recover(800e6);
        assert!(
            slow > medium && medium > fast,
            "recovery must speed up with bandwidth: {slow} / {medium} / {fast}"
        );
    }

    #[test]
    fn partition_skips_fanout_until_heal_then_catches_up() {
        let mut f = fabric();
        f.enable_faults(1, 400e6);
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        // Cut leader 0 from follower 1 for 500 ms; follower 2 unaffected.
        f.partition_links(0, 0, 1, 500_000, &mut out);
        assert!(f.send(10, 0, 0, bytes, 1, &mut meter, &mut nic, &mut out));
        let commits = drain_all(&mut f, &mut q, &mut meter, &mut out);
        assert_eq!(commits, 1, "commit proceeds on the reachable ISR");
        let s = f.fault_stats().unwrap();
        assert!((s.missed_bytes - bytes).abs() < 1e-9);
        // The heal-time Recovery kick was queued by partition_links and
        // drained above (it was scheduled at t=500_000); broker 1 must be
        // back in sync with the backlog replayed.
        assert!(f.broker_in_sync(1));
        assert!((s.rereplicated_bytes - bytes).abs() < 1e-9);
        assert_eq!(f.recovery_backlog_bytes(1), 0.0);
        assert_conservation(&f);
    }

    /// The extended identity with driver-tracked retransmissions:
    /// every retransmit adds to `offered`, so the driver's retry count
    /// must be subtracted before the PR 7 identity closes.
    fn assert_conservation_with_retries(f: &Fabric, retries: u64) {
        let s = f.fault_stats().unwrap();
        let (active, _) = f.active_in_flight();
        assert_eq!(
            s.records_offered - retries,
            s.records_committed + s.records_rejected + s.records_lost + active,
            "extended conservation: {s:?} active={active} retries={retries}"
        );
    }

    #[test]
    fn dedup_suppresses_a_retransmit_racing_its_own_ack() {
        // The original is still in flight when the client times out and
        // retransmits. Without dedup the fabric would admit a second
        // live copy of token 7 and commit it twice; with dedup the
        // duplicate is counted and dropped, and exactly one commit
        // lands.
        let mut f = fabric();
        f.enable_faults(1, 400e6);
        f.enable_dedup();
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        assert!(f.send(0, 0, 0, bytes, 7, &mut meter, &mut nic, &mut out));
        let outcome = f.send_retry_grouped_classed(
            500, 0, 0, bytes, 1, 7, 0, &mut meter, &mut nic, &mut out,
        );
        assert_eq!(outcome, SendOutcome::Duplicate);
        let commits = drain_all(&mut f, &mut q, &mut meter, &mut out);
        assert_eq!(commits, 1, "the duplicate must not double-commit");
        let s = f.fault_stats().unwrap();
        assert_eq!(s.records_committed, 1);
        assert_eq!(s.dedup_suppressed_records, 1);
        assert!((s.dedup_suppressed_bytes - bytes).abs() < 1e-9);
        // offered counts both attempts; one was the retransmit.
        assert_eq!(s.records_offered, 2);
        assert_conservation_with_retries(&f, 1);
    }

    #[test]
    fn retransmit_of_a_lost_record_repairs_the_loss() {
        // Leader 0 dies with token 3 in flight: the record is lost. The
        // client's retransmit to the re-elected leader finds the lost
        // slot, reverses the loss accounting (the retry now owns the
        // record's fate), and commits on the survivors.
        let mut f = fabric();
        f.enable_faults(1, 400e6);
        f.enable_dedup();
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        assert!(f.send(0, 0, 0, bytes, 3, &mut meter, &mut nic, &mut out));
        f.kill_broker(1, 0, &mut out);
        assert_eq!(drain_all(&mut f, &mut q, &mut meter, &mut out), 0);
        assert_eq!(f.fault_stats().unwrap().records_lost, 1);
        let elected = f.elect_leader(0).expect("survivors are in sync");
        let outcome = f.send_retry_grouped_classed(
            2_000, 0, elected, bytes, 1, 3, 0, &mut meter, &mut nic, &mut out,
        );
        assert_eq!(outcome, SendOutcome::Admitted);
        let commits = drain_all(&mut f, &mut q, &mut meter, &mut out);
        assert_eq!(commits, 1, "the retransmit must commit the record");
        let s = f.fault_stats().unwrap();
        assert_eq!(s.records_lost, 0, "the retry un-lost the record");
        assert_eq!(s.records_committed, 1);
        assert_eq!(s.dedup_suppressed_records, 0);
        assert_conservation_with_retries(&f, 1);
    }

    #[test]
    fn clean_election_stops_where_unclean_proceeds_at_a_counted_cost() {
        // Build the cascade's terminal state by hand: follower 2 died,
        // missed bytes, restarted (alive, out of sync, backlog not yet
        // replayed) — then both in-sync brokers die. Clean election
        // finds no candidate; unclean promotes broker 2 and counts its
        // un-replayed backlog as divergence.
        let setup = || {
            let mut f = fabric();
            f.enable_faults(1, 400e6);
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            f.kill_broker(0, 2, &mut out);
            for i in 0..5u64 {
                assert!(f.send(i * 1_000, 0, 0, 37_300.0, i, &mut meter, &mut nic, &mut out));
            }
            drain_all(&mut f, &mut q, &mut meter, &mut out);
            f.restart_broker(100_000, 2, &mut out);
            // Do NOT drain: broker 2 is alive but still owes its replay.
            assert!(f.broker_alive(2) && !f.broker_in_sync(2));
            f.kill_broker(100_001, 0, &mut out);
            f.kill_broker(100_001, 1, &mut out);
            f
        };
        let mut clean = setup();
        assert_eq!(clean.elect_leader(0), None, "clean: whole ISR is gone");
        assert_eq!(clean.fault_stats().unwrap().unclean_elections, 0);

        let mut unclean = setup();
        let backlog = unclean.recovery_backlog_bytes(2);
        assert!(backlog > 0.0);
        unclean.set_election(ElectionPolicy::Unclean);
        assert_eq!(unclean.elect_leader(0), Some(2));
        let s = unclean.fault_stats().unwrap();
        assert_eq!(s.unclean_elections, 1);
        assert!(
            (s.unclean_lost_bytes - backlog).abs() < 1e-9,
            "divergence must equal the un-replayed backlog: {} vs {backlog}",
            s.unclean_lost_bytes
        );
        // The elected replica's log is now the truth: nothing left to
        // replay, and it is in sync by definition.
        assert_eq!(unclean.recovery_backlog_bytes(2), 0.0);
        assert!(unclean.broker_in_sync(2));
    }
}
