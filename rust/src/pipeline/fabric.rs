//! Event-driven broker fabric shared by the Face Recognition and Object
//! Detection simulators.
//!
//! Models the full `acks=all` produce path of the Kafka-like substrate as
//! a chain of *events at true arrival times*:
//!
//! ```text
//! client send ──wire──▶ leader NIC ─▶ leader request CPU ─▶ leader NVMe
//!                                          │
//!                                          ├─▶ follower₁ NIC ─▶ CPU ─▶ NVMe ─▶ ack
//!                                          └─▶ follower₂ NIC ─▶ CPU ─▶ NVMe ─▶ ack
//! commit = leader write done ∧ all follower acks
//! ```
//!
//! Why events per hop: resource servers drain in virtual time; submitting
//! a hop at a *future* time (the previous hop's completion, computed
//! synchronously) freezes the downstream server's drain clock and, with
//! the replication mesh's cross-broker feedback, the phantom backlogs
//! amplify unboundedly. Scheduling each hop when it actually arrives keeps
//! every server's clock honest. (The consumer fetch path is chained
//! synchronously — its queueing is bounded by the request-CPU backlog,
//! which stays small in stable runs, and the approximation error does not
//! feed back.)

use crate::broker::qos::WeightedCpuScheduler;
use crate::config::hardware::NvmeSpec;
use crate::config::KafkaTuning;
use crate::metrics::bandwidth::{BandwidthMeter, Channel, Class, Dir};
use crate::sim::resource::FifoServer;
use crate::storage::cache::PageCache;
use crate::storage::device::StorageDevice;

/// One-way wire/switch transit within the data center (fat tree, µs).
pub const WIRE_US: u64 = 30;
/// Replication ack transit back to the leader.
pub const ACK_TRANSIT_US: u64 = 60;

/// Sentinel partition group for fetches with no partition identity
/// (legacy entry points); such reads are always served from memory,
/// reproducing the seed's hardcoded-hit behavior.
pub const NO_GROUP: u32 = u32::MAX;

/// A broker node's devices.
pub struct BrokerNode {
    pub storage: StorageDevice,
    pub nic_rx: FifoServer,
    pub nic_tx: FifoServer,
    pub req_cpu: FifoServer,
    /// Weighted request-CPU scheduler, installed by
    /// [`Fabric::enable_weighted_cpu`]. When present it replaces the FIFO
    /// `req_cpu` on the produce and fetch paths; when absent (the
    /// default) request handling is bit-for-bit the pre-QoS FIFO.
    pub req_cpu_wfq: Option<WeightedCpuScheduler>,
}

impl BrokerNode {
    /// Submit `cpu` µs of request-handling work of scheduling class
    /// `class`; FIFO unless a weighted scheduler is installed.
    fn cpu_submit(&mut self, at: u64, class: u8, cpu: f64) -> u64 {
        match &mut self.req_cpu_wfq {
            Some(wfq) => wfq.submit(at, class as usize, cpu),
            None => self.req_cpu.submit(at, cpu),
        }
    }
}

/// Fabric-internal events. The host simulator embeds these in its own
/// event enum and routes them back to [`Fabric::handle`].
#[derive(Clone, Copy, Debug)]
pub enum FabricEv {
    LeaderArrive { fid: u32 },
    LeaderCpuDone { fid: u32 },
    LeaderStored { fid: u32 },
    FollowerArrive { fid: u32, broker: u32 },
    FollowerCpuDone { fid: u32, broker: u32 },
    ReplicaAck { fid: u32 },
}

/// Outputs of a fabric step: new events to schedule, or a commit
/// notification carrying the host's token.
#[derive(Clone, Copy, Debug)]
pub enum FabricOut {
    Schedule(u64, FabricEv),
    /// The record is durably replicated and visible to consumers.
    Committed { token: u64, partition: u32, at: u64 },
}

struct InFlight {
    token: u64,
    partition: u32,
    leader: u32,
    bytes: f64,
    /// Client records this produce stands for (1 on the per-record path;
    /// >1 for a flow-aggregated macro-record). Request CPU is charged per
    /// record, so a macro pays `records × request_cpu_us` plus the
    /// per-byte term — the same total broker CPU the per-record
    /// simulation would pay for the same stream.
    records: u64,
    /// Scheduling class (tenant id) for weighted request-CPU service.
    class: u8,
    remaining_acks: u8,
    leader_stored: bool,
    active: bool,
}

/// The measured consumer read path (opt-in; see
/// [`Fabric::enable_read_path`]): one OS page cache per broker keyed by
/// partition group, plus the per-group consumer offsets that turn cache
/// residency into a function of the actual produce/consume gap.
#[derive(Clone, Debug)]
struct ReadPath {
    /// One page cache per broker (index = broker id). Every durable
    /// write — leader and follower — mirrors an append, so capacity
    /// pressure on a broker comes from *all* log traffic it carries,
    /// including replication follower writes of other partitions.
    caches: Vec<PageCache>,
    /// Consumer offset per partition group (bytes fetched so far);
    /// grows on demand. One pinned consumer per partition makes a
    /// single offset per group exact. (Hit/miss byte totals live in the
    /// caches themselves — [`PageCache::byte_counters`] — summed by
    /// [`Fabric::read_path_stats`].)
    consumed: Vec<u64>,
}

/// Aggregate read-path counters ([`Fabric::read_path_stats`]).
#[derive(Clone, Copy, Debug)]
pub struct ReadPathStats {
    /// Fetched bytes served from broker memory.
    pub hit_bytes: f64,
    /// Fetched bytes that went to the device read path.
    pub miss_bytes: f64,
}

impl ReadPathStats {
    /// Byte-weighted cache hit ratio (1.0 before any fetch).
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hit_bytes + self.miss_bytes;
        if total == 0.0 {
            1.0
        } else {
            self.hit_bytes / total
        }
    }

    /// Fraction of fetched bytes served by the device (0.0 before any
    /// fetch) — the complement of [`ReadPathStats::hit_ratio`].
    pub fn device_read_share(&self) -> f64 {
        1.0 - self.hit_ratio()
    }
}

/// The broker fabric: brokers + in-flight produce state.
pub struct Fabric {
    pub brokers: Vec<BrokerNode>,
    tuning: KafkaTuning,
    replication: usize,
    inflight: Vec<InFlight>,
    free: Vec<u32>,
    /// Measured read path; `None` (the default) keeps the seed's
    /// hardcoded cache hits bit for bit.
    read_path: Option<ReadPath>,
}

impl Fabric {
    pub fn new(
        brokers: usize,
        drives_per_broker: usize,
        replication: usize,
        nvme: NvmeSpec,
        effective_write_bw: f64,
        net_bw: f64,
        tuning: KafkaTuning,
    ) -> Self {
        assert!(replication >= 1 && replication <= brokers);
        Fabric {
            brokers: (0..brokers)
                .map(|_| BrokerNode {
                    storage: StorageDevice::new(nvme, drives_per_broker, effective_write_bw),
                    nic_rx: FifoServer::new(net_bw, 0),
                    nic_tx: FifoServer::new(net_bw, 0),
                    // Request handling is parallel across Kafka's network/
                    // IO threads; modeled as an aggregate us-of-work server.
                    req_cpu: FifoServer::new(1e6 * tuning.request_handler_cores as f64, 0),
                    req_cpu_wfq: None,
                })
                .collect(),
            tuning,
            replication,
            inflight: Vec::new(),
            free: Vec::new(),
            read_path: None,
        }
    }

    pub fn broker_count(&self) -> usize {
        self.brokers.len()
    }

    /// Install per-tenant scheduling classes on every broker's request
    /// CPU: class `i` (the tenant id passed to [`Fabric::send_classed`] /
    /// [`Fabric::fetch_classed`]) receives a `weights[i] / Σweights`
    /// share under contention. Replaces the FIFO request CPU; call before
    /// any traffic flows.
    pub fn enable_weighted_cpu(&mut self, weights: &[f64]) {
        let rate = 1e6 * self.tuning.request_handler_cores as f64;
        for b in &mut self.brokers {
            b.req_cpu_wfq = Some(WeightedCpuScheduler::new(rate, weights));
        }
    }

    /// Whether weighted request-CPU scheduling is active.
    pub fn weighted_cpu_enabled(&self) -> bool {
        self.brokers.first().map_or(false, |b| b.req_cpu_wfq.is_some())
    }

    /// Install per-tenant scheduling classes on every broker's NVMe
    /// write path: class `i` (the tenant id carried by each in-flight
    /// record) receives a `weights[i] / Σweights` share of the write
    /// bandwidth under contention. Replaces the FIFO write queue; call
    /// before any traffic flows. With this disabled (the default) every
    /// write takes the pre-QoS FIFO path bit for bit.
    pub fn enable_storage_qos(&mut self, weights: &[f64]) {
        for b in &mut self.brokers {
            b.storage.enable_write_qos(weights);
        }
    }

    /// Whether weighted write scheduling is active on the storage path.
    pub fn storage_qos_enabled(&self) -> bool {
        self.brokers
            .first()
            .map_or(false, |b| b.storage.write_qos_enabled())
    }

    /// Install the measured read path: one [`PageCache`] of
    /// `cache_bytes_per_broker` on every broker, keyed by partition
    /// group. Every durable write then mirrors an append into the
    /// broker's cache, and every [`Fabric::fetch_group_classed`] is
    /// split against the group's cached window at the consumer's actual
    /// offset — cold bytes go to the device read path, where they
    /// contend with replicated writes on the same spindle
    /// ([`StorageDevice::read_cold_classed`]; classed when storage QoS
    /// weights are installed). Call before any traffic flows. With this
    /// disabled (the default) every fetch is served from memory, bit
    /// for bit the seed behavior.
    pub fn enable_read_path(&mut self, cache_bytes_per_broker: f64) {
        self.read_path = Some(ReadPath {
            caches: (0..self.brokers.len())
                .map(|_| PageCache::new(cache_bytes_per_broker))
                .collect(),
            consumed: Vec::new(),
        });
    }

    /// Whether the measured read path is active.
    pub fn read_path_enabled(&self) -> bool {
        self.read_path.is_some()
    }

    /// Aggregate read-path hit/miss byte totals, summed across the
    /// per-broker caches (`None` when disabled).
    pub fn read_path_stats(&self) -> Option<ReadPathStats> {
        self.read_path.as_ref().map(|rp| {
            let (hit_bytes, miss_bytes) = rp
                .caches
                .iter()
                .map(PageCache::byte_counters)
                .fold((0.0, 0.0), |(h, m), (ch, cm)| (h + ch, m + cm));
            ReadPathStats { hit_bytes, miss_bytes }
        })
    }

    /// Consumer lag of one partition group in bytes — the gap between
    /// the group's appended high-water mark and its consumer's fetch
    /// offset. Zero when the read path is disabled.
    pub fn group_lag_bytes(&self, group: u32) -> u64 {
        let Some(rp) = &self.read_path else { return 0 };
        let appended = rp
            .caches
            .iter()
            .map(|c| c.appended_of(group))
            .max()
            .unwrap_or(0);
        let consumed = rp.consumed.get(group as usize).copied().unwrap_or(0);
        appended.saturating_sub(consumed)
    }

    fn request_cpu_us(&self, bytes: f64) -> f64 {
        self.tuning.request_cpu_us + self.tuning.per_byte_cpu_us * bytes
    }

    /// Request CPU for a batch standing for `records` client records:
    /// the fixed per-request cost is paid once per record (the broker
    /// would have parsed/validated each), the per-byte cost once per
    /// byte. `records <= 1` takes the exact per-record expression.
    fn request_cpu_us_n(&self, bytes: f64, records: u64) -> f64 {
        if records <= 1 {
            self.request_cpu_us(bytes)
        } else {
            self.tuning.request_cpu_us * records as f64 + self.tuning.per_byte_cpu_us * bytes
        }
    }

    fn alloc(&mut self, inf: InFlight) -> u32 {
        if let Some(fid) = self.free.pop() {
            self.inflight[fid as usize] = inf;
            fid
        } else {
            self.inflight.push(inf);
            (self.inflight.len() - 1) as u32
        }
    }

    /// Begin a produce: the record leaves the client now; returns the
    /// event that should be scheduled (leader NIC arrival). Requests sent
    /// through this entry point run in scheduling class 0.
    pub fn send(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        token: u64,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) {
        self.send_classed(now, partition, leader, bytes, token, 0, meter, producer_nic, out)
    }

    /// [`Fabric::send`] with an explicit scheduling class (tenant id).
    /// The class rides the record through every request-CPU hop (leader
    /// and followers); it is inert unless weighted scheduling is enabled.
    #[allow(clippy::too_many_arguments)]
    pub fn send_classed(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        token: u64,
        class: u8,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) {
        self.send_grouped_classed(
            now, partition, leader, bytes, 1, token, class, meter, producer_nic, out,
        )
    }

    /// [`Fabric::send_classed`] for a batch standing for `records` client
    /// records (flow-aggregation macro-records). Bytes ride the NIC /
    /// storage hops in aggregate; request CPU is charged per record via
    /// [`Fabric::request_cpu_us_n`]. `records == 1` is exactly
    /// [`Fabric::send_classed`].
    #[allow(clippy::too_many_arguments)]
    pub fn send_grouped_classed(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        records: u64,
        token: u64,
        class: u8,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) {
        meter.add(Class::Producer, Channel::Network, Dir::Write, bytes);
        let t_tx = producer_nic.submit(now, bytes) + WIRE_US;
        let fid = self.alloc(InFlight {
            token,
            partition,
            leader,
            bytes,
            records,
            class,
            remaining_acks: (self.replication - 1) as u8,
            leader_stored: false,
            active: true,
        });
        out.push(FabricOut::Schedule(t_tx, FabricEv::LeaderArrive { fid }));
    }

    /// Advance one fabric event.
    pub fn handle(&mut self, now: u64, ev: FabricEv, meter: &mut BandwidthMeter, out: &mut Vec<FabricOut>) {
        match ev {
            FabricEv::LeaderArrive { fid } => {
                let (leader, bytes, records, class) = {
                    let f = &self.inflight[fid as usize];
                    (f.leader as usize, f.bytes, f.records, f.class)
                };
                meter.add(Class::Broker, Channel::Network, Dir::Read, bytes);
                let cpu = self.request_cpu_us_n(bytes, records);
                let b = &mut self.brokers[leader];
                let t_rx = b.nic_rx.submit(now, bytes);
                let t_cpu = b.cpu_submit(t_rx, class, cpu);
                out.push(FabricOut::Schedule(t_cpu, FabricEv::LeaderCpuDone { fid }));
            }
            FabricEv::LeaderCpuDone { fid } => {
                let (leader, bytes, class, partition) = {
                    let f = &self.inflight[fid as usize];
                    (f.leader as usize, f.bytes, f.class, f.partition)
                };
                // Durable write on the leader, in the record's tenant
                // class (inert unless storage QoS is enabled).
                meter.add(Class::Broker, Channel::Storage, Dir::Write, bytes);
                let t_wr = self.brokers[leader].storage.write_classed(now, bytes, class);
                if let Some(rp) = &mut self.read_path {
                    rp.caches[leader].append_group(partition, bytes);
                }
                out.push(FabricOut::Schedule(t_wr, FabricEv::LeaderStored { fid }));
                // Fan out to followers.
                let n = self.brokers.len();
                for r in 1..self.replication {
                    let fb = ((leader + r) % n) as u32;
                    meter.add(Class::Broker, Channel::Network, Dir::Write, bytes);
                    let t_out = self.brokers[leader].nic_tx.submit(now, bytes) + WIRE_US;
                    out.push(FabricOut::Schedule(
                        t_out,
                        FabricEv::FollowerArrive { fid, broker: fb },
                    ));
                }
            }
            FabricEv::FollowerArrive { fid, broker } => {
                let (bytes, records, class) = {
                    let f = &self.inflight[fid as usize];
                    (f.bytes, f.records, f.class)
                };
                meter.add(Class::Broker, Channel::Network, Dir::Read, bytes);
                let cpu = self.request_cpu_us_n(bytes, records);
                let b = &mut self.brokers[broker as usize];
                let t_rx = b.nic_rx.submit(now, bytes);
                let t_cpu = b.cpu_submit(t_rx, class, cpu);
                out.push(FabricOut::Schedule(
                    t_cpu,
                    FabricEv::FollowerCpuDone { fid, broker },
                ));
            }
            FabricEv::FollowerCpuDone { fid, broker } => {
                let (bytes, class, partition) = {
                    let f = &self.inflight[fid as usize];
                    (f.bytes, f.class, f.partition)
                };
                meter.add(Class::Broker, Channel::Storage, Dir::Write, bytes);
                let t_wr = self.brokers[broker as usize]
                    .storage
                    .write_classed(now, bytes, class);
                if let Some(rp) = &mut self.read_path {
                    rp.caches[broker as usize].append_group(partition, bytes);
                }
                out.push(FabricOut::Schedule(
                    t_wr + ACK_TRANSIT_US,
                    FabricEv::ReplicaAck { fid },
                ));
            }
            FabricEv::LeaderStored { fid } => {
                self.inflight[fid as usize].leader_stored = true;
                self.maybe_commit(fid, now, out);
            }
            FabricEv::ReplicaAck { fid } => {
                let f = &mut self.inflight[fid as usize];
                debug_assert!(f.remaining_acks > 0);
                f.remaining_acks -= 1;
                self.maybe_commit(fid, now, out);
            }
        }
    }

    fn maybe_commit(&mut self, fid: u32, now: u64, out: &mut Vec<FabricOut>) {
        let f = &mut self.inflight[fid as usize];
        if f.active && f.leader_stored && f.remaining_acks == 0 {
            f.active = false;
            out.push(FabricOut::Committed {
                token: f.token,
                partition: f.partition,
                at: now,
            });
            self.free.push(fid);
        }
    }

    /// Consumer fetch: request CPU at the leader, page-cache read, NIC out
    /// to the consumer. Returns the delivery completion time. Chained
    /// synchronously — see the module docs for why this is acceptable.
    pub fn fetch(
        &mut self,
        now: u64,
        leader: u32,
        bytes: f64,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
    ) -> u64 {
        self.fetch_classed(now, leader, bytes, 0, consumer_nic_rx, meter)
    }

    /// [`Fabric::fetch`] with an explicit scheduling class (tenant id);
    /// inert unless weighted request-CPU scheduling is enabled. No
    /// partition identity, so with the read path enabled the fetch is
    /// still served from memory (the [`NO_GROUP`] contract).
    pub fn fetch_classed(
        &mut self,
        now: u64,
        leader: u32,
        bytes: f64,
        class: u8,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
    ) -> u64 {
        self.fetch_group_classed(now, leader, NO_GROUP, bytes, class, consumer_nic_rx, meter)
    }

    /// [`Fabric::fetch_classed`] with the partition group identity the
    /// measured read path needs. With the read path disabled (or
    /// `group == NO_GROUP`) this is the seed fetch, bit for bit: request
    /// CPU, a free page-cache read, NIC out. With it enabled, the fetch
    /// range `(consumed, consumed + bytes]` of the group is split
    /// against the leader's cached window — resident bytes stay free,
    /// cold bytes go to the device read path in the fetch's scheduling
    /// class, where they contend with the replicated write stream on
    /// the same spindle.
    #[allow(clippy::too_many_arguments)]
    pub fn fetch_group_classed(
        &mut self,
        now: u64,
        leader: u32,
        group: u32,
        bytes: f64,
        class: u8,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
    ) -> u64 {
        let cpu = self.request_cpu_us(bytes);
        let b = &mut self.brokers[leader as usize];
        let t_cpu = b.cpu_submit(now, class, cpu);
        let t_read = match &mut self.read_path {
            Some(rp) if group != NO_GROUP => {
                let idx = group as usize;
                if idx >= rp.consumed.len() {
                    rp.consumed.resize(idx + 1, 0);
                }
                let cache = &mut rp.caches[leader as usize];
                let start = rp.consumed[idx];
                let want = bytes.ceil() as u64;
                let (hit, miss) = cache.read_range_group(group, start, want);
                // Advance the consumer offset; clamp to the group's
                // high-water mark so per-fetch rounding cannot push the
                // offset past what was actually appended.
                rp.consumed[idx] = (start + want).min(cache.appended_of(group)).max(start);
                let mut t = t_cpu;
                if hit > 0 {
                    t = b.storage.read(t_cpu, hit as f64, true);
                }
                if miss > 0 {
                    meter.add(Class::Broker, Channel::Storage, Dir::Read, miss as f64);
                    t = t.max(b.storage.read_cold_classed(t_cpu, miss as f64, class));
                }
                t
            }
            _ => b.storage.read(t_cpu, bytes, true), // page cache (seed path)
        };
        let t_tx = b.nic_tx.submit(t_read, bytes) + WIRE_US;
        let t_rx = consumer_nic_rx.submit(t_tx, bytes);
        meter.add(Class::Broker, Channel::Network, Dir::Write, bytes);
        meter.add(Class::Consumer, Channel::Network, Dir::Read, bytes);
        t_rx
    }

    /// Max spec-relative storage-write utilization across brokers (Fig 11b).
    pub fn max_storage_write_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.storage.write_spec_utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_storage_read_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.storage.read_spec_utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_nic_rx_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.nic_rx.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_nic_tx_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.nic_tx.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_cpu_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| match &b.req_cpu_wfq {
                Some(wfq) => wfq.utilization(elapsed_us),
                None => b.req_cpu.utilization(elapsed_us),
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::EventQueue;

    fn fabric() -> Fabric {
        let nvme = NvmeSpec::p4510_1tb();
        Fabric::new(
            3,
            1,
            3,
            nvme,
            0.7 * nvme.write_bw,
            crate::util::units::gbps(100),
            KafkaTuning::default(),
        )
    }

    /// Drive a single produce through the fabric and return commit time.
    fn run_one(f: &mut Fabric, now: u64, bytes: f64) -> (u64, u64) {
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        f.send(now, 0, 0, bytes, 42, &mut meter, &mut nic, &mut out);
        let mut committed = None;
        loop {
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(t, ev) => q.at(t, ev),
                    FabricOut::Committed { token, at, .. } => committed = Some((token, at)),
                }
            }
            match q.pop() {
                Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                None => break,
            }
        }
        committed.expect("record should commit")
    }

    #[test]
    fn produce_commits_after_replication() {
        let mut f = fabric();
        let (token, at) = run_one(&mut f, 1000, 37_300.0);
        assert_eq!(token, 42);
        // Commit after nic + cpu + leader write + follower write + ack.
        assert!(at > 1000 + 100, "commit too early: {at}");
        assert!(at < 1000 + 20_000, "commit too slow: {at}");
        // All three brokers wrote the record (leader + 2 followers).
        let wrote = f
            .brokers
            .iter()
            .filter(|b| b.storage.bytes_written() > 0.0)
            .count();
        assert_eq!(wrote, 3);
    }

    #[test]
    fn replication_one_writes_once() {
        let nvme = NvmeSpec::p4510_1tb();
        let mut f = Fabric::new(
            3,
            1,
            1,
            nvme,
            0.7 * nvme.write_bw,
            crate::util::units::gbps(100),
            KafkaTuning::default(),
        );
        run_one(&mut f, 0, 10_000.0);
        let wrote = f
            .brokers
            .iter()
            .filter(|b| b.storage.bytes_written() > 0.0)
            .count();
        assert_eq!(wrote, 1);
    }

    #[test]
    fn sustained_load_no_phantom_backlog() {
        // Offer 30% of effective write bandwidth for 10 simulated seconds;
        // per-broker backlogs must stay bounded (the ratchet bug this
        // fabric exists to prevent).
        let mut f = fabric();
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        // ~1850 records/s x 37.3kB x 3 replication / 3 brokers ≈ 207 MB/s
        // per broker ≈ 27% of the 770 MB/s effective bandwidth.
        let mut commits = 0u64;
        let mut last_commit = 0u64;
        for i in 0..18_500u64 {
            let t = i * 540;
            // Drain fabric events up to t first.
            while q.peek_time().map(|pt| pt <= t).unwrap_or(false) {
                let (et, ev) = q.pop().unwrap();
                f.handle(et, ev, &mut meter, &mut out);
                for o in out.drain(..) {
                    match o {
                        FabricOut::Schedule(st, sev) => q.at(st, sev),
                        FabricOut::Committed { at, .. } => {
                            commits += 1;
                            last_commit = at;
                        }
                    }
                }
            }
            f.send(t, (i % 64) as u32, (i % 3) as u32, bytes, i, &mut meter, &mut nic, &mut out);
            for o in out.drain(..) {
                if let FabricOut::Schedule(st, sev) = o {
                    q.at(st, sev);
                }
            }
        }
        // Finish draining.
        while let Some((et, ev)) = q.pop() {
            f.handle(et, ev, &mut meter, &mut out);
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(st, sev) => q.at(st, sev),
                    FabricOut::Committed { at, .. } => {
                        commits += 1;
                        last_commit = at;
                    }
                }
            }
        }
        assert_eq!(commits, 18_500);
        // Last send at ~10s; commits must complete shortly after (no
        // multi-second phantom queues at 27% utilization).
        assert!(
            last_commit < 10_000_000 + 200_000,
            "phantom backlog: last commit at {last_commit}"
        );
        for b in &f.brokers {
            assert!(b.storage.write_spec_utilization(10_000_000) < 0.35);
        }
    }

    #[test]
    fn weighted_cpu_commits_and_accounts_utilization() {
        let mut f = fabric();
        f.enable_weighted_cpu(&[1.0, 4.0]);
        assert!(f.weighted_cpu_enabled());
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        // One record per class through the full produce path.
        f.send_classed(0, 0, 0, 37_300.0, 1, 0, &mut meter, &mut nic, &mut out);
        f.send_classed(0, 1, 1, 37_300.0, 2, 1, &mut meter, &mut nic, &mut out);
        let mut commits = 0;
        loop {
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(t, ev) => q.at(t, ev),
                    FabricOut::Committed { .. } => commits += 1,
                }
            }
            match q.pop() {
                Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                None => break,
            }
        }
        assert_eq!(commits, 2, "both classes must commit under WFQ");
        assert!(f.max_cpu_util(1_000_000) > 0.0);
    }

    #[test]
    fn grouped_send_charges_request_cpu_per_record() {
        // A macro-record standing for k client records must pay the same
        // broker request CPU the k individual sends would have paid: the
        // fixed per-request cost k times plus the per-byte term once.
        let run_grouped = |records: u64, bytes: f64| -> Fabric {
            let mut f = fabric();
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            f.send_grouped_classed(0, 0, 0, bytes, records, 9, 0, &mut meter, &mut nic, &mut out);
            loop {
                for o in out.drain(..) {
                    if let FabricOut::Schedule(t, ev) = o {
                        q.at(t, ev);
                    }
                }
                match q.pop() {
                    Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                    None => break,
                }
            }
            f
        };
        let elapsed = 1_000_000u64;
        let k = 16u64;
        let bytes = 2_000.0 * k as f64;
        let one = run_grouped(1, bytes).max_cpu_util(elapsed);
        let grouped = run_grouped(k, bytes).max_cpu_util(elapsed);
        // Leader CPU: the grouped request pays (k-1) extra fixed costs.
        let extra = (k - 1) as f64 * KafkaTuning::default().request_cpu_us / elapsed as f64;
        assert!(
            (grouped - one - extra).abs() < 1e-9,
            "grouped {grouped} vs single {one}, expected extra {extra}"
        );
    }

    #[test]
    fn grouped_send_of_one_record_matches_send_classed() {
        // records == 1 must be the exact send_classed path: same commit
        // time, same meters, same utilizations.
        let run = |grouped: bool| -> (u64, f64) {
            let mut f = fabric();
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            if grouped {
                f.send_grouped_classed(0, 0, 0, 37_300.0, 1, 5, 0, &mut meter, &mut nic, &mut out);
            } else {
                f.send_classed(0, 0, 0, 37_300.0, 5, 0, &mut meter, &mut nic, &mut out);
            }
            let mut committed = 0;
            loop {
                for o in out.drain(..) {
                    match o {
                        FabricOut::Schedule(t, ev) => q.at(t, ev),
                        FabricOut::Committed { at, .. } => committed = at,
                    }
                }
                match q.pop() {
                    Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                    None => break,
                }
            }
            (committed, f.max_cpu_util(1_000_000))
        };
        let (at_a, cpu_a) = run(false);
        let (at_b, cpu_b) = run(true);
        assert_eq!(at_a, at_b);
        assert_eq!(cpu_a.to_bits(), cpu_b.to_bits());
    }

    #[test]
    fn storage_qos_shields_light_class_from_write_hol_blocking() {
        // Pre-load every broker's write queue with ~1 s of class-0 bulk
        // writes, then produce one small class-1 record through each
        // fabric variant. With the FIFO write path the record's commit
        // waits out the backlog; with storage QoS its class drains at its
        // own share and the commit lands orders of magnitude earlier.
        let commit_with = |qos: bool| -> u64 {
            let mut f = fabric();
            if qos {
                f.enable_storage_qos(&[1.0, 9.0]);
                assert!(f.storage_qos_enabled());
            }
            for b in 0..3u32 {
                // ~770 MB at 770 MB/s effective = ~1 s of backlog each.
                f.brokers[b as usize].storage.write_classed(0, 770e6, 0);
            }
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            f.send_classed(0, 0, 0, 2_000.0, 7, 1, &mut meter, &mut nic, &mut out);
            let mut committed = None;
            loop {
                for o in out.drain(..) {
                    match o {
                        FabricOut::Schedule(t, ev) => q.at(t, ev),
                        FabricOut::Committed { at, .. } => committed = Some(at),
                    }
                }
                match q.pop() {
                    Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                    None => break,
                }
            }
            committed.expect("record should commit")
        };
        let fifo = commit_with(false);
        let qos = commit_with(true);
        assert!(fifo > 900_000, "FIFO commit should wait out the backlog: {fifo}");
        assert!(qos < 50_000, "QoS commit should bypass the bulk backlog: {qos}");
    }

    #[test]
    fn fetch_is_fast_from_page_cache() {
        let mut f = fabric();
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let t = f.fetch(5_000, 0, 37_300.0, &mut nic, &mut meter);
        // cpu (~112us) + nic transfer (~3us) + wire.
        assert!(t > 5_000 && t < 5_600, "fetch delivered at {t}");
        assert_eq!(f.max_storage_read_util(1_000_000), 0.0);
    }

    #[test]
    fn read_path_streaming_fetch_stays_memory_speed() {
        // Ample cache + a consumer reading right behind the appender:
        // every fetch is resident, the device read path stays idle, and
        // the delivery time matches the seed's hardcoded-hit fetch.
        let mut f = fabric();
        f.enable_read_path(1e9);
        assert!(f.read_path_enabled());
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut t_commit = 0;
        for i in 0..20 {
            let (_, at) = run_one(&mut f, i * 50_000, 37_300.0);
            t_commit = at;
        }
        let t = f.fetch_group_classed(
            t_commit,
            0,
            0,
            20.0 * 37_300.0,
            0,
            &mut nic,
            &mut meter,
        );
        assert!(t < t_commit + 2_000, "streaming fetch delivered at {t}");
        let stats = f.read_path_stats().unwrap();
        assert_eq!(stats.hit_ratio(), 1.0);
        assert_eq!(stats.device_read_share(), 0.0);
        assert_eq!(f.max_storage_read_util(t_commit), 0.0);
        assert_eq!(f.group_lag_bytes(0), 0, "fetch drained the whole group");
    }

    #[test]
    fn read_path_lagging_fetch_splits_to_the_device() {
        // A 50 kB cache holds barely one 37.3 kB record per broker; a
        // consumer that never polled while 20 records landed reads the
        // evicted majority from the device — and that cold read queues
        // on the same spindle the writes use.
        let mut f = fabric();
        f.enable_read_path(50_000.0);
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut t_commit = 0;
        for i in 0..20 {
            let (_, at) = run_one(&mut f, i * 50_000, 37_300.0);
            t_commit = at;
        }
        let backlog = 20.0 * 37_300.0;
        assert!(f.group_lag_bytes(0) >= backlog as u64 - 20);
        let t = f.fetch_group_classed(t_commit, 0, 0, backlog, 0, &mut nic, &mut meter);
        let stats = f.read_path_stats().unwrap();
        assert!(
            stats.hit_ratio() < 0.1,
            "19 of 20 records were evicted: hit ratio {}",
            stats.hit_ratio()
        );
        assert!(stats.device_read_share() > 0.9);
        assert!(f.max_storage_read_util(t_commit) > 0.0, "device reads must show up");
        // ~700 kB cold at the 770 MB/s effective spindle rate ≈ 0.9 ms
        // of device time — far slower than the memory-speed fetch.
        assert!(t > t_commit + 800, "cold fetch delivered too fast: {t}");
        assert_eq!(f.group_lag_bytes(0), 0, "catch-up fetch drained the lag");
    }

    #[test]
    fn read_path_disabled_reports_no_stats() {
        let f = fabric();
        assert!(!f.read_path_enabled());
        assert!(f.read_path_stats().is_none());
        assert_eq!(f.group_lag_bytes(7), 0);
    }
}
