//! Event-driven broker fabric shared by the Face Recognition and Object
//! Detection simulators.
//!
//! Models the full `acks=all` produce path of the Kafka-like substrate as
//! a chain of *events at true arrival times*:
//!
//! ```text
//! client send ──wire──▶ leader NIC ─▶ leader request CPU ─▶ leader NVMe
//!                                          │
//!                                          ├─▶ follower₁ NIC ─▶ CPU ─▶ NVMe ─▶ ack
//!                                          └─▶ follower₂ NIC ─▶ CPU ─▶ NVMe ─▶ ack
//! commit = leader write done ∧ all follower acks
//! ```
//!
//! Why events per hop: resource servers drain in virtual time; submitting
//! a hop at a *future* time (the previous hop's completion, computed
//! synchronously) freezes the downstream server's drain clock and, with
//! the replication mesh's cross-broker feedback, the phantom backlogs
//! amplify unboundedly. Scheduling each hop when it actually arrives keeps
//! every server's clock honest. (The consumer fetch path is chained
//! synchronously — its queueing is bounded by the request-CPU backlog,
//! which stays small in stable runs, and the approximation error does not
//! feed back.)

use crate::broker::qos::WeightedCpuScheduler;
use crate::config::hardware::NvmeSpec;
use crate::config::KafkaTuning;
use crate::metrics::bandwidth::{BandwidthMeter, Channel, Class, Dir};
use crate::sim::resource::FifoServer;
use crate::storage::device::StorageDevice;

/// One-way wire/switch transit within the data center (fat tree, µs).
pub const WIRE_US: u64 = 30;
/// Replication ack transit back to the leader.
pub const ACK_TRANSIT_US: u64 = 60;

/// A broker node's devices.
pub struct BrokerNode {
    pub storage: StorageDevice,
    pub nic_rx: FifoServer,
    pub nic_tx: FifoServer,
    pub req_cpu: FifoServer,
    /// Weighted request-CPU scheduler, installed by
    /// [`Fabric::enable_weighted_cpu`]. When present it replaces the FIFO
    /// `req_cpu` on the produce and fetch paths; when absent (the
    /// default) request handling is bit-for-bit the pre-QoS FIFO.
    pub req_cpu_wfq: Option<WeightedCpuScheduler>,
}

impl BrokerNode {
    /// Submit `cpu` µs of request-handling work of scheduling class
    /// `class`; FIFO unless a weighted scheduler is installed.
    fn cpu_submit(&mut self, at: u64, class: u8, cpu: f64) -> u64 {
        match &mut self.req_cpu_wfq {
            Some(wfq) => wfq.submit(at, class as usize, cpu),
            None => self.req_cpu.submit(at, cpu),
        }
    }
}

/// Fabric-internal events. The host simulator embeds these in its own
/// event enum and routes them back to [`Fabric::handle`].
#[derive(Clone, Copy, Debug)]
pub enum FabricEv {
    LeaderArrive { fid: u32 },
    LeaderCpuDone { fid: u32 },
    LeaderStored { fid: u32 },
    FollowerArrive { fid: u32, broker: u32 },
    FollowerCpuDone { fid: u32, broker: u32 },
    ReplicaAck { fid: u32 },
}

/// Outputs of a fabric step: new events to schedule, or a commit
/// notification carrying the host's token.
#[derive(Clone, Copy, Debug)]
pub enum FabricOut {
    Schedule(u64, FabricEv),
    /// The record is durably replicated and visible to consumers.
    Committed { token: u64, partition: u32, at: u64 },
}

struct InFlight {
    token: u64,
    partition: u32,
    leader: u32,
    bytes: f64,
    /// Scheduling class (tenant id) for weighted request-CPU service.
    class: u8,
    remaining_acks: u8,
    leader_stored: bool,
    active: bool,
}

/// The broker fabric: brokers + in-flight produce state.
pub struct Fabric {
    pub brokers: Vec<BrokerNode>,
    tuning: KafkaTuning,
    replication: usize,
    inflight: Vec<InFlight>,
    free: Vec<u32>,
}

impl Fabric {
    pub fn new(
        brokers: usize,
        drives_per_broker: usize,
        replication: usize,
        nvme: NvmeSpec,
        effective_write_bw: f64,
        net_bw: f64,
        tuning: KafkaTuning,
    ) -> Self {
        assert!(replication >= 1 && replication <= brokers);
        Fabric {
            brokers: (0..brokers)
                .map(|_| BrokerNode {
                    storage: StorageDevice::new(nvme, drives_per_broker, effective_write_bw),
                    nic_rx: FifoServer::new(net_bw, 0),
                    nic_tx: FifoServer::new(net_bw, 0),
                    // Request handling is parallel across Kafka's network/
                    // IO threads; modeled as an aggregate us-of-work server.
                    req_cpu: FifoServer::new(1e6 * tuning.request_handler_cores as f64, 0),
                    req_cpu_wfq: None,
                })
                .collect(),
            tuning,
            replication,
            inflight: Vec::new(),
            free: Vec::new(),
        }
    }

    pub fn broker_count(&self) -> usize {
        self.brokers.len()
    }

    /// Install per-tenant scheduling classes on every broker's request
    /// CPU: class `i` (the tenant id passed to [`Fabric::send_classed`] /
    /// [`Fabric::fetch_classed`]) receives a `weights[i] / Σweights`
    /// share under contention. Replaces the FIFO request CPU; call before
    /// any traffic flows.
    pub fn enable_weighted_cpu(&mut self, weights: &[f64]) {
        let rate = 1e6 * self.tuning.request_handler_cores as f64;
        for b in &mut self.brokers {
            b.req_cpu_wfq = Some(WeightedCpuScheduler::new(rate, weights));
        }
    }

    /// Whether weighted request-CPU scheduling is active.
    pub fn weighted_cpu_enabled(&self) -> bool {
        self.brokers.first().map_or(false, |b| b.req_cpu_wfq.is_some())
    }

    /// Install per-tenant scheduling classes on every broker's NVMe
    /// write path: class `i` (the tenant id carried by each in-flight
    /// record) receives a `weights[i] / Σweights` share of the write
    /// bandwidth under contention. Replaces the FIFO write queue; call
    /// before any traffic flows. With this disabled (the default) every
    /// write takes the pre-QoS FIFO path bit for bit.
    pub fn enable_storage_qos(&mut self, weights: &[f64]) {
        for b in &mut self.brokers {
            b.storage.enable_write_qos(weights);
        }
    }

    /// Whether weighted write scheduling is active on the storage path.
    pub fn storage_qos_enabled(&self) -> bool {
        self.brokers
            .first()
            .map_or(false, |b| b.storage.write_qos_enabled())
    }

    fn request_cpu_us(&self, bytes: f64) -> f64 {
        self.tuning.request_cpu_us + self.tuning.per_byte_cpu_us * bytes
    }

    fn alloc(&mut self, inf: InFlight) -> u32 {
        if let Some(fid) = self.free.pop() {
            self.inflight[fid as usize] = inf;
            fid
        } else {
            self.inflight.push(inf);
            (self.inflight.len() - 1) as u32
        }
    }

    /// Begin a produce: the record leaves the client now; returns the
    /// event that should be scheduled (leader NIC arrival). Requests sent
    /// through this entry point run in scheduling class 0.
    pub fn send(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        token: u64,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) {
        self.send_classed(now, partition, leader, bytes, token, 0, meter, producer_nic, out)
    }

    /// [`Fabric::send`] with an explicit scheduling class (tenant id).
    /// The class rides the record through every request-CPU hop (leader
    /// and followers); it is inert unless weighted scheduling is enabled.
    #[allow(clippy::too_many_arguments)]
    pub fn send_classed(
        &mut self,
        now: u64,
        partition: u32,
        leader: u32,
        bytes: f64,
        token: u64,
        class: u8,
        meter: &mut BandwidthMeter,
        producer_nic: &mut FifoServer,
        out: &mut Vec<FabricOut>,
    ) {
        meter.add(Class::Producer, Channel::Network, Dir::Write, bytes);
        let t_tx = producer_nic.submit(now, bytes) + WIRE_US;
        let fid = self.alloc(InFlight {
            token,
            partition,
            leader,
            bytes,
            class,
            remaining_acks: (self.replication - 1) as u8,
            leader_stored: false,
            active: true,
        });
        out.push(FabricOut::Schedule(t_tx, FabricEv::LeaderArrive { fid }));
    }

    /// Advance one fabric event.
    pub fn handle(&mut self, now: u64, ev: FabricEv, meter: &mut BandwidthMeter, out: &mut Vec<FabricOut>) {
        match ev {
            FabricEv::LeaderArrive { fid } => {
                let (leader, bytes, class) = {
                    let f = &self.inflight[fid as usize];
                    (f.leader as usize, f.bytes, f.class)
                };
                meter.add(Class::Broker, Channel::Network, Dir::Read, bytes);
                let cpu = self.request_cpu_us(bytes);
                let b = &mut self.brokers[leader];
                let t_rx = b.nic_rx.submit(now, bytes);
                let t_cpu = b.cpu_submit(t_rx, class, cpu);
                out.push(FabricOut::Schedule(t_cpu, FabricEv::LeaderCpuDone { fid }));
            }
            FabricEv::LeaderCpuDone { fid } => {
                let (leader, bytes, class) = {
                    let f = &self.inflight[fid as usize];
                    (f.leader as usize, f.bytes, f.class)
                };
                // Durable write on the leader, in the record's tenant
                // class (inert unless storage QoS is enabled).
                meter.add(Class::Broker, Channel::Storage, Dir::Write, bytes);
                let t_wr = self.brokers[leader].storage.write_classed(now, bytes, class);
                out.push(FabricOut::Schedule(t_wr, FabricEv::LeaderStored { fid }));
                // Fan out to followers.
                let n = self.brokers.len();
                for r in 1..self.replication {
                    let fb = ((leader + r) % n) as u32;
                    meter.add(Class::Broker, Channel::Network, Dir::Write, bytes);
                    let t_out = self.brokers[leader].nic_tx.submit(now, bytes) + WIRE_US;
                    out.push(FabricOut::Schedule(
                        t_out,
                        FabricEv::FollowerArrive { fid, broker: fb },
                    ));
                }
            }
            FabricEv::FollowerArrive { fid, broker } => {
                let (bytes, class) = {
                    let f = &self.inflight[fid as usize];
                    (f.bytes, f.class)
                };
                meter.add(Class::Broker, Channel::Network, Dir::Read, bytes);
                let cpu = self.request_cpu_us(bytes);
                let b = &mut self.brokers[broker as usize];
                let t_rx = b.nic_rx.submit(now, bytes);
                let t_cpu = b.cpu_submit(t_rx, class, cpu);
                out.push(FabricOut::Schedule(
                    t_cpu,
                    FabricEv::FollowerCpuDone { fid, broker },
                ));
            }
            FabricEv::FollowerCpuDone { fid, broker } => {
                let (bytes, class) = {
                    let f = &self.inflight[fid as usize];
                    (f.bytes, f.class)
                };
                meter.add(Class::Broker, Channel::Storage, Dir::Write, bytes);
                let t_wr = self.brokers[broker as usize]
                    .storage
                    .write_classed(now, bytes, class);
                out.push(FabricOut::Schedule(
                    t_wr + ACK_TRANSIT_US,
                    FabricEv::ReplicaAck { fid },
                ));
            }
            FabricEv::LeaderStored { fid } => {
                self.inflight[fid as usize].leader_stored = true;
                self.maybe_commit(fid, now, out);
            }
            FabricEv::ReplicaAck { fid } => {
                let f = &mut self.inflight[fid as usize];
                debug_assert!(f.remaining_acks > 0);
                f.remaining_acks -= 1;
                self.maybe_commit(fid, now, out);
            }
        }
    }

    fn maybe_commit(&mut self, fid: u32, now: u64, out: &mut Vec<FabricOut>) {
        let f = &mut self.inflight[fid as usize];
        if f.active && f.leader_stored && f.remaining_acks == 0 {
            f.active = false;
            out.push(FabricOut::Committed {
                token: f.token,
                partition: f.partition,
                at: now,
            });
            self.free.push(fid);
        }
    }

    /// Consumer fetch: request CPU at the leader, page-cache read, NIC out
    /// to the consumer. Returns the delivery completion time. Chained
    /// synchronously — see the module docs for why this is acceptable.
    pub fn fetch(
        &mut self,
        now: u64,
        leader: u32,
        bytes: f64,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
    ) -> u64 {
        self.fetch_classed(now, leader, bytes, 0, consumer_nic_rx, meter)
    }

    /// [`Fabric::fetch`] with an explicit scheduling class (tenant id);
    /// inert unless weighted request-CPU scheduling is enabled.
    pub fn fetch_classed(
        &mut self,
        now: u64,
        leader: u32,
        bytes: f64,
        class: u8,
        consumer_nic_rx: &mut FifoServer,
        meter: &mut BandwidthMeter,
    ) -> u64 {
        let cpu = self.request_cpu_us(bytes);
        let b = &mut self.brokers[leader as usize];
        let t_cpu = b.cpu_submit(now, class, cpu);
        let t_read = b.storage.read(t_cpu, bytes, true); // page cache
        let t_tx = b.nic_tx.submit(t_read, bytes) + WIRE_US;
        let t_rx = consumer_nic_rx.submit(t_tx, bytes);
        meter.add(Class::Broker, Channel::Network, Dir::Write, bytes);
        meter.add(Class::Consumer, Channel::Network, Dir::Read, bytes);
        t_rx
    }

    /// Max spec-relative storage-write utilization across brokers (Fig 11b).
    pub fn max_storage_write_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.storage.write_spec_utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_storage_read_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.storage.read_spec_utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_nic_rx_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.nic_rx.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_nic_tx_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| b.nic_tx.utilization(elapsed_us))
            .fold(0.0, f64::max)
    }

    pub fn max_cpu_util(&self, elapsed_us: u64) -> f64 {
        self.brokers
            .iter()
            .map(|b| match &b.req_cpu_wfq {
                Some(wfq) => wfq.utilization(elapsed_us),
                None => b.req_cpu.utilization(elapsed_us),
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::EventQueue;

    fn fabric() -> Fabric {
        let nvme = NvmeSpec::p4510_1tb();
        Fabric::new(
            3,
            1,
            3,
            nvme,
            0.7 * nvme.write_bw,
            crate::util::units::gbps(100),
            KafkaTuning::default(),
        )
    }

    /// Drive a single produce through the fabric and return commit time.
    fn run_one(f: &mut Fabric, now: u64, bytes: f64) -> (u64, u64) {
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        f.send(now, 0, 0, bytes, 42, &mut meter, &mut nic, &mut out);
        let mut committed = None;
        loop {
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(t, ev) => q.at(t, ev),
                    FabricOut::Committed { token, at, .. } => committed = Some((token, at)),
                }
            }
            match q.pop() {
                Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                None => break,
            }
        }
        committed.expect("record should commit")
    }

    #[test]
    fn produce_commits_after_replication() {
        let mut f = fabric();
        let (token, at) = run_one(&mut f, 1000, 37_300.0);
        assert_eq!(token, 42);
        // Commit after nic + cpu + leader write + follower write + ack.
        assert!(at > 1000 + 100, "commit too early: {at}");
        assert!(at < 1000 + 20_000, "commit too slow: {at}");
        // All three brokers wrote the record (leader + 2 followers).
        let wrote = f
            .brokers
            .iter()
            .filter(|b| b.storage.bytes_written() > 0.0)
            .count();
        assert_eq!(wrote, 3);
    }

    #[test]
    fn replication_one_writes_once() {
        let nvme = NvmeSpec::p4510_1tb();
        let mut f = Fabric::new(
            3,
            1,
            1,
            nvme,
            0.7 * nvme.write_bw,
            crate::util::units::gbps(100),
            KafkaTuning::default(),
        );
        run_one(&mut f, 0, 10_000.0);
        let wrote = f
            .brokers
            .iter()
            .filter(|b| b.storage.bytes_written() > 0.0)
            .count();
        assert_eq!(wrote, 1);
    }

    #[test]
    fn sustained_load_no_phantom_backlog() {
        // Offer 30% of effective write bandwidth for 10 simulated seconds;
        // per-broker backlogs must stay bounded (the ratchet bug this
        // fabric exists to prevent).
        let mut f = fabric();
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        let bytes = 37_300.0;
        // ~1850 records/s x 37.3kB x 3 replication / 3 brokers ≈ 207 MB/s
        // per broker ≈ 27% of the 770 MB/s effective bandwidth.
        let mut commits = 0u64;
        let mut last_commit = 0u64;
        for i in 0..18_500u64 {
            let t = i * 540;
            // Drain fabric events up to t first.
            while q.peek_time().map(|pt| pt <= t).unwrap_or(false) {
                let (et, ev) = q.pop().unwrap();
                f.handle(et, ev, &mut meter, &mut out);
                for o in out.drain(..) {
                    match o {
                        FabricOut::Schedule(st, sev) => q.at(st, sev),
                        FabricOut::Committed { at, .. } => {
                            commits += 1;
                            last_commit = at;
                        }
                    }
                }
            }
            f.send(t, (i % 64) as u32, (i % 3) as u32, bytes, i, &mut meter, &mut nic, &mut out);
            for o in out.drain(..) {
                if let FabricOut::Schedule(st, sev) = o {
                    q.at(st, sev);
                }
            }
        }
        // Finish draining.
        while let Some((et, ev)) = q.pop() {
            f.handle(et, ev, &mut meter, &mut out);
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(st, sev) => q.at(st, sev),
                    FabricOut::Committed { at, .. } => {
                        commits += 1;
                        last_commit = at;
                    }
                }
            }
        }
        assert_eq!(commits, 18_500);
        // Last send at ~10s; commits must complete shortly after (no
        // multi-second phantom queues at 27% utilization).
        assert!(
            last_commit < 10_000_000 + 200_000,
            "phantom backlog: last commit at {last_commit}"
        );
        for b in &f.brokers {
            assert!(b.storage.write_spec_utilization(10_000_000) < 0.35);
        }
    }

    #[test]
    fn weighted_cpu_commits_and_accounts_utilization() {
        let mut f = fabric();
        f.enable_weighted_cpu(&[1.0, 4.0]);
        assert!(f.weighted_cpu_enabled());
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let mut q: EventQueue<FabricEv> = EventQueue::new();
        let mut out = Vec::new();
        // One record per class through the full produce path.
        f.send_classed(0, 0, 0, 37_300.0, 1, 0, &mut meter, &mut nic, &mut out);
        f.send_classed(0, 1, 1, 37_300.0, 2, 1, &mut meter, &mut nic, &mut out);
        let mut commits = 0;
        loop {
            for o in out.drain(..) {
                match o {
                    FabricOut::Schedule(t, ev) => q.at(t, ev),
                    FabricOut::Committed { .. } => commits += 1,
                }
            }
            match q.pop() {
                Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                None => break,
            }
        }
        assert_eq!(commits, 2, "both classes must commit under WFQ");
        assert!(f.max_cpu_util(1_000_000) > 0.0);
    }

    #[test]
    fn storage_qos_shields_light_class_from_write_hol_blocking() {
        // Pre-load every broker's write queue with ~1 s of class-0 bulk
        // writes, then produce one small class-1 record through each
        // fabric variant. With the FIFO write path the record's commit
        // waits out the backlog; with storage QoS its class drains at its
        // own share and the commit lands orders of magnitude earlier.
        let commit_with = |qos: bool| -> u64 {
            let mut f = fabric();
            if qos {
                f.enable_storage_qos(&[1.0, 9.0]);
                assert!(f.storage_qos_enabled());
            }
            for b in 0..3u32 {
                // ~770 MB at 770 MB/s effective = ~1 s of backlog each.
                f.brokers[b as usize].storage.write_classed(0, 770e6, 0);
            }
            let mut meter = BandwidthMeter::new();
            let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
            let mut q: EventQueue<FabricEv> = EventQueue::new();
            let mut out = Vec::new();
            f.send_classed(0, 0, 0, 2_000.0, 7, 1, &mut meter, &mut nic, &mut out);
            let mut committed = None;
            loop {
                for o in out.drain(..) {
                    match o {
                        FabricOut::Schedule(t, ev) => q.at(t, ev),
                        FabricOut::Committed { at, .. } => committed = Some(at),
                    }
                }
                match q.pop() {
                    Some((t, ev)) => f.handle(t, ev, &mut meter, &mut out),
                    None => break,
                }
            }
            committed.expect("record should commit")
        };
        let fifo = commit_with(false);
        let qos = commit_with(true);
        assert!(fifo > 900_000, "FIFO commit should wait out the backlog: {fifo}");
        assert!(qos < 50_000, "QoS commit should bypass the bulk backlog: {qos}");
    }

    #[test]
    fn fetch_is_fast_from_page_cache() {
        let mut f = fabric();
        let mut meter = BandwidthMeter::new();
        let mut nic = FifoServer::new(crate::util::units::gbps(100), 0);
        let t = f.fetch(5_000, 0, 37_300.0, &mut nic, &mut meter);
        // cpu (~112us) + nic transfer (~3us) + wire.
        assert!(t > 5_000 && t < 5_600, "fetch delivered at {t}");
        assert_eq!(f.max_storage_read_util(1_000_000), 0.0);
    }
}
