//! The *Face Recognition* data-center simulation.
//!
//! This is the crate's centerpiece: the paper's deployment (Fig 4) run at
//! full logical scale in virtual time. Producers (ingest/detect
//! containers) parse synthetic video streams and emit face thumbnails
//! through a Kafka-style client; records flow through the event-driven
//! broker [`fabric`](crate::pipeline::fabric) (leader NIC → request CPU →
//! NVMe write → 2 follower replications → `acks=all` commit); partition-
//! pinned consumers (identification containers) fetch and process faces
//! serially.
//!
//! Everything the paper measures is emergent here:
//! * the Fig-6 latency breakdown and §4.2 tails,
//! * the Fig-7 correlation between latency and faces-in-system,
//! * the Fig-10 latency/throughput acceleration sweep and its 8×
//!   instability,
//! * the Fig-11 network/storage utilization split,
//! * the Fig-15 mitigation sweeps (drives, brokers, thumbnail size),
//! * §5.5's growing broker-wait fraction.

use std::collections::VecDeque;

use crate::config::{AccelProtocol, Config};
use crate::metrics::bandwidth::{BandwidthMeter, Channel, Class, Dir};
use crate::pipeline::fabric::{Fabric, FabricEv, FabricOut};
use crate::pipeline::stage::StageModel;
use crate::pipeline::video::BurstSchedule;
use crate::sim::engine::EventQueue;
use crate::sim::queue::{InstabilityVerdict, Population};
use crate::sim::resource::FifoServer;
use crate::util::rng::Rng;
use crate::util::stats::Histogram;

/// Framing overhead per record on the wire (batch header amortized +
/// record header; see `broker::record`).
const RECORD_OVERHEAD: f64 = 32.0;

#[derive(Debug)]
enum Ev {
    /// Producer `p` begins its next frame cycle.
    Frame(u32),
    /// Producer `p`'s record leaves the client (post-linger).
    Dispatch(u32, SimFace),
    /// Broker-fabric hop.
    Fabric(FabricEv),
    /// Consumer `c` polls its partitions.
    Poll(u32),
}

/// A face record in flight (sizes + timestamps only — the §5.2 emulation
/// argument: brokers can't tell payloads from garbage of the same size).
#[derive(Clone, Copy, Debug)]
struct SimFace {
    frame_start_us: u64,
    detect_end_us: u64,
    visible_us: u64,
    bytes: f64,
}

struct ProducerState {
    rng: Rng,
    nic: FifoServer, // tx direction only is exercised
    frames: u64,
}

struct PartitionState {
    leader: u32,
    queue: VecDeque<SimFace>,
    consumer: u32,
}

struct ConsumerState {
    rng: Rng,
    nic_rx: FifoServer,
    busy_until: u64,
    poll_scheduled: bool,
    faces_done: u64,
}

/// Simulation results for one run.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub accel: f64,
    pub elapsed_us: u64,
    /// Stage means (us).
    pub ingest_mean_us: f64,
    pub detect_mean_us: f64,
    pub wait_mean_us: f64,
    pub identify_mean_us: f64,
    /// Face-level end-to-end latency.
    pub e2e_mean_us: f64,
    pub e2e_p99_us: u64,
    pub ingest_p99_us: u64,
    pub detect_p99_us: u64,
    pub wait_p99_us: u64,
    pub identify_p99_us: u64,
    /// Broker wait as a fraction of mean end-to-end latency (§5.5).
    pub wait_fraction: f64,
    pub frames_ingested: u64,
    pub faces_produced: u64,
    pub faces_completed: u64,
    pub throughput_fps: f64,
    pub mean_faces_per_frame: f64,
    pub verdict: InstabilityVerdict,
    /// Max across brokers, relative to per-drive spec bandwidth (Fig 11b).
    pub storage_write_util: f64,
    pub storage_read_util: f64,
    /// Broker NIC utilizations (Fig 11a).
    pub broker_net_rx_util: f64,
    pub broker_net_tx_util: f64,
    pub broker_cpu_util: f64,
    pub producer_net_tx_util: f64,
    pub consumer_net_rx_util: f64,
    /// (time, faces-in-system) samples for Fig 7.
    pub population: Vec<(u64, i64)>,
    /// (completion time, face e2e latency) samples for Fig 7.
    pub latency_series: Vec<(u64, u64)>,
}

impl SimReport {
    pub fn total_mean_us(&self) -> f64 {
        self.ingest_mean_us + self.detect_mean_us + self.wait_mean_us + self.identify_mean_us
    }
}

/// The simulator.
pub struct FaceRecSim {
    cfg: Config,
}

impl FaceRecSim {
    pub fn new(cfg: Config) -> Self {
        cfg.deployment.validate().expect("invalid deployment");
        FaceRecSim { cfg }
    }

    /// Run to the configured horizon and report.
    pub fn run(&self) -> SimReport {
        let cfg = &self.cfg;
        let d = &cfg.deployment;
        let stages = StageModel::new(cfg.calibration.stages.clone(), cfg.accel, cfg.protocol);
        let mut master = Rng::new(cfg.seed);
        let horizon = cfg.duration_us;
        let warmup = (horizon as f64 * cfg.warmup_frac) as u64;

        // ---- build the world ----
        // Acceleration-emulation runs use 1 face/frame (§5.3); otherwise
        // every producer replays the same video, so face surges come from
        // a single shared burst timeline (§3.3, Fig 7).
        let one_face = matches!(cfg.protocol, AccelProtocol::Emulation)
            && d.producers == crate::config::Deployment::facerec_accel().producers;
        let schedule = (!one_face).then(|| {
            BurstSchedule::new(
                cfg.calibration.faces.clone(),
                horizon + crate::util::units::SEC,
                &mut master,
            )
        });
        let mut producers: Vec<ProducerState> = (0..d.producers)
            .map(|_| ProducerState {
                rng: master.fork(),
                nic: FifoServer::new(cfg.node.net_bw, 0),
                frames: 0,
            })
            .collect();

        let write_cap = cfg.calibration.broker_write_capacity(
            cfg.node.nvme.write_bw,
            d.drives_per_broker,
            d.brokers,
        );
        let mut fabric = Fabric::new(
            d.brokers,
            d.drives_per_broker,
            d.replication,
            cfg.node.nvme,
            write_cap,
            cfg.node.net_bw,
            cfg.tuning.clone(),
        );

        let mut partitions: Vec<PartitionState> = (0..d.partitions)
            .map(|p| PartitionState {
                leader: (p % d.brokers) as u32,
                queue: VecDeque::new(),
                consumer: (p % d.consumers) as u32,
            })
            .collect();

        let mut consumers: Vec<ConsumerState> = (0..d.consumers)
            .map(|_| ConsumerState {
                rng: master.fork(),
                nic_rx: FifoServer::new(cfg.node.net_bw, 0),
                busy_until: 0,
                poll_scheduled: false,
                faces_done: 0,
            })
            .collect();

        // Consumer index per partition list (owned partitions), to avoid
        // scanning all partitions on every poll.
        let mut owned: Vec<Vec<u32>> = vec![Vec::new(); d.consumers];
        for (idx, part) in partitions.iter().enumerate() {
            owned[part.consumer as usize].push(idx as u32);
        }

        let mut meter = BandwidthMeter::new();
        meter.set_nodes(Class::Producer, d.producers);
        meter.set_nodes(Class::Consumer, d.consumers);
        meter.set_nodes(Class::Broker, d.brokers);

        let mut hist_ingest = Histogram::new();
        let mut hist_detect = Histogram::new();
        let mut hist_wait = Histogram::new();
        let mut hist_identify = Histogram::new();
        let mut hist_e2e = Histogram::new();
        let mut population = Population::new(250_000); // 0.25 s sampling
        // Dense per-second latency aggregation for the Fig-7 series.
        let n_secs = (horizon / 1_000_000 + 2) as usize;
        let mut lat_sum = vec![0u64; n_secs];
        let mut lat_n = vec![0u64; n_secs];
        let mut faces_produced = 0u64;
        let mut faces_completed = 0u64;
        let mut completed_in_window = 0u64;
        let mut frames_ingested = 0u64;

        // In-flight faces keyed by fabric token.
        let mut in_flight: Vec<SimFace> = Vec::new();
        let mut free_tokens: Vec<u64> = Vec::new();

        let mut q: EventQueue<Ev> = EventQueue::new();
        let cycle = stages.producer_cycle_mean_us(cfg.calibration.faces.mean_faces) as u64;
        for p in 0..d.producers {
            // Stagger starts across one mean cycle to avoid a herd.
            let jitter = (p as u64 * cycle.max(1)) / d.producers as u64;
            q.at(jitter, Ev::Frame(p as u32));
        }

        let linger = cfg.tuning.linger_us;
        let mut fabric_out: Vec<FabricOut> = Vec::new();

        while let Some((now, ev)) = q.pop() {
            if now > horizon {
                break;
            }
            match ev {
                Ev::Frame(p) => {
                    let pid = p as usize;
                    let faces = match &schedule {
                        Some(sched) => sched.faces_at(now, &mut producers[pid].rng),
                        None => 1,
                    };
                    let ingest_us = stages.ingest(&mut producers[pid].rng);
                    let detect_us = stages.detect(&mut producers[pid].rng, faces);
                    let detect_end = now + ingest_us + detect_us;
                    producers[pid].frames += 1;
                    if now >= warmup {
                        frames_ingested += 1;
                        hist_ingest.record(ingest_us.max(1));
                        hist_detect.record(detect_us.max(1));
                    }
                    // Each face is its own record; the 2020-era Kafka
                    // default partitioner round-robins unkeyed records, so
                    // a frame's faces scatter across partitions. The linger
                    // is the client-side hold before the record ships.
                    for _ in 0..faces {
                        let bytes = producers[pid]
                            .rng
                            .lognormal_mean_cv(cfg.face_bytes, 0.25)
                            .max(1024.0);
                        let face = SimFace {
                            frame_start_us: now,
                            detect_end_us: detect_end,
                            visible_us: 0,
                            bytes,
                        };
                        faces_produced += 1;
                        population.enter(detect_end.min(horizon));
                        q.at(detect_end + linger, Ev::Dispatch(p, face));
                    }
                    // Pipelined single-core container: next frame starts
                    // when this one's ingest+detect completes.
                    q.at(detect_end.max(now + 1), Ev::Frame(p));
                }
                Ev::Dispatch(p, face) => {
                    let pid = p as usize;
                    // Random rotation: deterministic lockstep rotation
                    // across same-cadence producers would convoy consumers.
                    let part = producers[pid].rng.below(partitions.len() as u64) as u32;
                    let token = free_tokens.pop().unwrap_or_else(|| {
                        in_flight.push(face);
                        (in_flight.len() - 1) as u64
                    });
                    in_flight[token as usize] = face;
                    let leader = partitions[part as usize].leader;
                    let bytes = face.bytes + RECORD_OVERHEAD;
                    let nic = &mut producers[pid].nic;
                    fabric.send(now, part, leader, bytes, token, &mut meter, nic, &mut fabric_out);
                    drain_fabric(
                        &mut fabric_out,
                        &mut q,
                        &mut partitions,
                        &mut consumers,
                        &in_flight,
                        &mut free_tokens,
                    );
                }
                Ev::Fabric(fev) => {
                    fabric.handle(now, fev, &mut meter, &mut fabric_out);
                    drain_fabric(
                        &mut fabric_out,
                        &mut q,
                        &mut partitions,
                        &mut consumers,
                        &in_flight,
                        &mut free_tokens,
                    );
                }
                Ev::Poll(c) => {
                    let cid = c as usize;
                    consumers[cid].poll_scheduled = false;
                    if now < consumers[cid].busy_until {
                        consumers[cid].poll_scheduled = true;
                        let t = consumers[cid].busy_until;
                        q.at(t, Ev::Poll(c));
                        continue;
                    }
                    // Gather visible records across owned partitions.
                    let mut avail_bytes = 0.0;
                    let mut oldest_visible = u64::MAX;
                    for &pi in &owned[cid] {
                        for f in partitions[pi as usize].queue.iter() {
                            if f.visible_us <= now {
                                avail_bytes += f.bytes + RECORD_OVERHEAD;
                                oldest_visible = oldest_visible.min(f.visible_us);
                            } else {
                                break;
                            }
                        }
                    }
                    if avail_bytes == 0.0 {
                        continue; // a commit Deliver will wake us
                    }
                    if (avail_bytes as usize) < cfg.tuning.fetch_min_bytes {
                        let deadline = oldest_visible + cfg.tuning.fetch_max_wait_us;
                        if now < deadline {
                            consumers[cid].poll_scheduled = true;
                            q.at(deadline, Ev::Poll(c));
                            continue;
                        }
                    }
                    // Fetch all visible records per owned partition.
                    let mut fetched: Vec<SimFace> = Vec::new();
                    let mut deliver_at = now;
                    for &pi in &owned[cid] {
                        let part = &mut partitions[pi as usize];
                        let mut part_bytes = 0.0;
                        let mut any = false;
                        while let Some(f) = part.queue.front() {
                            if f.visible_us <= now {
                                part_bytes += f.bytes + RECORD_OVERHEAD;
                                fetched.push(*f);
                                part.queue.pop_front();
                                any = true;
                            } else {
                                break;
                            }
                        }
                        if any {
                            let t = fabric.fetch(
                                now,
                                part.leader,
                                part_bytes,
                                &mut consumers[cid].nic_rx,
                                &mut meter,
                            );
                            deliver_at = deliver_at.max(t);
                        }
                    }
                    // Identify each face serially on the 1-core container.
                    fetched.sort_by_key(|f| f.detect_end_us);
                    let mut busy = consumers[cid].busy_until.max(deliver_at);
                    for f in fetched {
                        let start = busy;
                        let wait_us = start.saturating_sub(f.detect_end_us);
                        let dur = stages.identify(&mut consumers[cid].rng);
                        busy = start + dur;
                        consumers[cid].faces_done += 1;
                        population.exit(busy.min(horizon));
                        faces_completed += 1;
                        if busy >= warmup && busy <= horizon {
                            completed_in_window += 1;
                        }
                        if f.frame_start_us >= warmup && busy <= horizon {
                            hist_wait.record(wait_us.max(1));
                            hist_identify.record(dur.max(1));
                            let e2e = busy - f.frame_start_us;
                            hist_e2e.record(e2e.max(1));
                            // Bucket by *arrival* time: a face arriving
                            // during a surge experiences the congestion,
                            // wherever its completion lands (Fig 7).
                            let sec = (f.frame_start_us / 1_000_000) as usize;
                            if sec < lat_sum.len() {
                                lat_sum[sec] += e2e;
                                lat_n[sec] += 1;
                            }
                        }
                    }
                    consumers[cid].busy_until = busy;
                    // Immediately look for more work when we free up.
                    consumers[cid].poll_scheduled = true;
                    q.at(busy, Ev::Poll(c));
                }
            }
        }

        // ---- aggregate ----
        if std::env::var("AITAX_SIM_DEBUG").is_ok() {
            let active = consumers.iter().filter(|c| c.faces_done > 0).count();
            let qtot: usize = partitions.iter().map(|p| p.queue.len()).sum();
            eprintln!(
                "[sim-debug] active_consumers={active}/{} qtot={qtot} events={} cpu_util={:.2} storage_util={:.2}",
                consumers.len(),
                q.processed(),
                fabric.max_cpu_util(horizon),
                fabric.max_storage_write_util(horizon),
            );
        }
        let elapsed = horizon;
        let wait_mean = hist_wait.mean();
        let total = hist_ingest.mean() + hist_detect.mean() + wait_mean + hist_identify.mean();
        let measured_window = elapsed.saturating_sub(warmup);
        let mean_faces = {
            let total_frames: u64 = producers.iter().map(|p| p.frames).sum();
            if total_frames == 0 {
                0.0
            } else {
                faces_produced as f64 / total_frames as f64
            }
        };

        SimReport {
            accel: cfg.accel,
            elapsed_us: elapsed,
            ingest_mean_us: hist_ingest.mean(),
            detect_mean_us: hist_detect.mean(),
            wait_mean_us: wait_mean,
            identify_mean_us: hist_identify.mean(),
            e2e_mean_us: hist_e2e.mean(),
            e2e_p99_us: hist_e2e.p99(),
            ingest_p99_us: hist_ingest.p99(),
            detect_p99_us: hist_detect.p99(),
            wait_p99_us: hist_wait.p99(),
            identify_p99_us: hist_identify.p99(),
            wait_fraction: if total > 0.0 { wait_mean / total } else { 0.0 },
            frames_ingested,
            faces_produced,
            faces_completed,
            throughput_fps: if measured_window > 0 {
                completed_in_window as f64 * 1e6 / measured_window as f64
            } else {
                0.0
            },
            mean_faces_per_frame: mean_faces,
            verdict: population.verdict(elapsed),
            storage_write_util: fabric.max_storage_write_util(elapsed),
            storage_read_util: fabric.max_storage_read_util(elapsed),
            broker_net_rx_util: fabric.max_nic_rx_util(elapsed),
            broker_net_tx_util: fabric.max_nic_tx_util(elapsed),
            broker_cpu_util: fabric.max_cpu_util(elapsed),
            producer_net_tx_util: meter.utilization(
                Class::Producer,
                Channel::Network,
                Dir::Write,
                elapsed,
                cfg.node.net_bw,
            ),
            consumer_net_rx_util: meter.utilization(
                Class::Consumer,
                Channel::Network,
                Dir::Read,
                elapsed,
                cfg.node.net_bw,
            ),
            population: population.samples().to_vec(),
            latency_series: lat_sum
                .iter()
                .zip(&lat_n)
                .enumerate()
                .filter(|(_, (_, &n))| n > 0)
                .map(|(sec, (&sum, &n))| (sec as u64 * 1_000_000, sum / n))
                .collect(),
        }
    }
}

/// Route fabric outputs: schedule hop events; on commit, make the record
/// visible on its partition and wake the owning consumer.
fn drain_fabric(
    out: &mut Vec<FabricOut>,
    q: &mut EventQueue<Ev>,
    partitions: &mut [PartitionState],
    consumers: &mut [ConsumerState],
    in_flight: &[SimFace],
    free_tokens: &mut Vec<u64>,
) {
    for o in out.drain(..) {
        match o {
            FabricOut::Schedule(t, fev) => q.at(t.max(q.now()), Ev::Fabric(fev)),
            FabricOut::Committed { token, partition, at } => {
                let mut face = in_flight[token as usize];
                free_tokens.push(token);
                face.visible_us = at;
                let part = &mut partitions[partition as usize];
                part.queue.push_back(face);
                let cs = &mut consumers[part.consumer as usize];
                if !cs.poll_scheduled {
                    cs.poll_scheduled = true;
                    q.at(at.max(q.now()).max(cs.busy_until), Ev::Poll(part.consumer));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;

    /// Paper-scale §4.2 deployment, shortened horizon.
    fn paper_config(accel: f64) -> Config {
        let mut cfg = Config::default();
        cfg.deployment = Deployment::facerec_paper();
        cfg.duration_us = 30 * crate::util::units::SEC;
        cfg.accel = accel;
        cfg.seed = 0xBEEF;
        cfg
    }

    /// §5.3 acceleration deployment (1 face/frame).
    fn accel_config(accel: f64) -> Config {
        let mut cfg = Config::default();
        cfg.deployment = Deployment::facerec_accel();
        cfg.duration_us = 30 * crate::util::units::SEC;
        cfg.accel = accel;
        cfg.seed = 0xACCE1;
        cfg
    }

    #[test]
    fn baseline_breakdown_shape() {
        let r = FaceRecSim::new(paper_config(1.0)).run();
        assert!(r.frames_ingested > 50_000, "frames={}", r.frames_ingested);
        assert!(r.faces_completed > 30_000, "faces={}", r.faces_completed);
        // Stage means near the paper's Fig-6 values.
        assert!((r.ingest_mean_us - 18_800.0).abs() / 18_800.0 < 0.05, "{}", r.ingest_mean_us);
        assert!((r.detect_mean_us - 80_500.0).abs() / 80_500.0 < 0.10, "{}", r.detect_mean_us);
        assert!(
            (r.identify_mean_us - 131_500.0).abs() / 131_500.0 < 0.05,
            "{}",
            r.identify_mean_us
        );
        // Broker wait is a large fraction but the system is stable.
        assert!(r.wait_mean_us > 50_000.0, "wait={}", r.wait_mean_us);
        assert!(r.wait_mean_us < 260_000.0, "wait={}", r.wait_mean_us);
        assert!(r.verdict.stable, "growth={}", r.verdict.growth_per_sec);
        // ~0.64 faces/frame from the video model. The realized per-frame
        // mean runs slightly below the stationary 0.64 because bursting
        // producers spend longer per frame (length-biased sampling), which
        // the paper's real deployment also exhibits.
        assert!((0.45..0.75).contains(&r.mean_faces_per_frame), "{}", r.mean_faces_per_frame);
    }

    #[test]
    fn conservation_of_faces() {
        let r = FaceRecSim::new(paper_config(1.0)).run();
        assert!(r.faces_completed <= r.faces_produced);
        // In a stable system most produced faces complete.
        assert!(
            r.faces_completed as f64 > 0.9 * r.faces_produced as f64,
            "completed {} of {}",
            r.faces_completed,
            r.faces_produced
        );
    }

    #[test]
    fn acceleration_raises_throughput_and_cuts_latency() {
        let r1 = FaceRecSim::new(accel_config(1.0)).run();
        let r4 = FaceRecSim::new(accel_config(4.0)).run();
        assert!(r4.throughput_fps > 2.0 * r1.throughput_fps);
        assert!(r4.e2e_mean_us < r1.e2e_mean_us);
        assert!(r1.verdict.stable && r4.verdict.stable);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = FaceRecSim::new(accel_config(2.0)).run();
        let b = FaceRecSim::new(accel_config(2.0)).run();
        assert_eq!(a.faces_completed, b.faces_completed);
        assert_eq!(a.e2e_p99_us, b.e2e_p99_us);
    }

    #[test]
    fn storage_util_grows_with_acceleration_and_saturates_at_8x() {
        let r1 = FaceRecSim::new(accel_config(1.0)).run();
        let r4 = FaceRecSim::new(accel_config(4.0)).run();
        let r8 = FaceRecSim::new(accel_config(8.0)).run();
        // Fig 11b: ~10% at 1x, growing roughly linearly...
        assert!((0.06..0.16).contains(&r1.storage_write_util), "{}", r1.storage_write_util);
        assert!(r4.storage_write_util > 2.5 * r1.storage_write_util);
        // ...until 8x destabilizes the system (the paper's headline).
        assert!(!r8.verdict.stable, "8x should be unstable");
        // Reads stay near zero (page cache).
        assert!(r1.storage_read_util < 0.01);
    }

    #[test]
    fn six_x_stable_with_high_wait_fraction() {
        // §5.5: wait fraction grows to ~79% at 6x but the system holds.
        let r6 = FaceRecSim::new(accel_config(6.0)).run();
        assert!(r6.verdict.stable, "6x should be stable");
        assert!(
            r6.wait_fraction > 0.55,
            "wait fraction at 6x = {}",
            r6.wait_fraction
        );
    }

    #[test]
    fn network_far_from_saturation() {
        // Fig 11a: broker network peaks ~6% at 8x; at 4x it's below that.
        let r4 = FaceRecSim::new(accel_config(4.0)).run();
        assert!(r4.broker_net_rx_util < 0.06, "{}", r4.broker_net_rx_util);
        assert!(r4.producer_net_tx_util < 0.01);
    }
}
