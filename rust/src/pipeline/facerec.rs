//! The *Face Recognition* data-center simulation.
//!
//! This is the crate's centerpiece: the paper's deployment (Fig 4) run at
//! full logical scale in virtual time. Since the `sim::world` refactor the
//! file is a thin *workload definition*: the producer/partition/consumer
//! machinery lives in the reusable component layer
//! ([`pipeline::dc`](crate::pipeline::dc)), and this module contributes
//! only what is Face-Recognition-specific — the frame source and stage
//! costs (wired up in `dc::build`), and the [`SimReport`] assembly below.
//!
//! Everything the paper measures is emergent here:
//! * the Fig-6 latency breakdown and §4.2 tails,
//! * the Fig-7 correlation between latency and faces-in-system,
//! * the Fig-10 latency/throughput acceleration sweep and its 8×
//!   instability,
//! * the Fig-11 network/storage utilization split,
//! * the Fig-15 mitigation sweeps (drives, brokers, thumbnail size),
//! * §5.5's growing broker-wait fraction.

use crate::config::Config;
use crate::pipeline::dc::{self, DcEvent, DcState, TenantMetrics};
use crate::sim::queue::InstabilityVerdict;
use crate::sim::world::World;

/// Simulation results for one run.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub accel: f64,
    pub elapsed_us: u64,
    /// Stage means (us).
    pub ingest_mean_us: f64,
    pub detect_mean_us: f64,
    pub wait_mean_us: f64,
    pub identify_mean_us: f64,
    /// Face-level end-to-end latency.
    pub e2e_mean_us: f64,
    pub e2e_p99_us: u64,
    pub ingest_p99_us: u64,
    pub detect_p99_us: u64,
    pub wait_p99_us: u64,
    pub identify_p99_us: u64,
    /// Broker wait as a fraction of mean end-to-end latency (§5.5).
    pub wait_fraction: f64,
    pub frames_ingested: u64,
    pub faces_produced: u64,
    pub faces_completed: u64,
    pub throughput_fps: f64,
    pub mean_faces_per_frame: f64,
    pub verdict: InstabilityVerdict,
    /// Max across brokers, relative to per-drive spec bandwidth (Fig 11b).
    pub storage_write_util: f64,
    pub storage_read_util: f64,
    /// Broker NIC utilizations (Fig 11a).
    pub broker_net_rx_util: f64,
    pub broker_net_tx_util: f64,
    pub broker_cpu_util: f64,
    pub producer_net_tx_util: f64,
    pub consumer_net_rx_util: f64,
    /// (time, faces-in-system) samples for Fig 7.
    pub population: Vec<(u64, i64)>,
    /// (completion time, face e2e latency) samples for Fig 7.
    pub latency_series: Vec<(u64, u64)>,
    /// Past-time schedules clamped by the event queue — zero in every
    /// healthy run (`tests/golden_reports.rs` asserts it).
    pub clamped_events: u64,
}

impl SimReport {
    pub fn total_mean_us(&self) -> f64 {
        self.ingest_mean_us + self.detect_mean_us + self.wait_mean_us + self.identify_mean_us
    }
}

/// Assemble a [`SimReport`] for the Face Recognition tenant `tenant` of a
/// finished world. Shared with `pipeline::mixed`, whose per-tenant
/// breakdowns are exactly this report computed over a shared fabric.
///
/// Stage latencies, counters, and the producer/consumer NIC figures are
/// *per-tenant* (the NIC utilizations come from the tenant's own byte
/// totals). The broker/fabric figures are *substrate-wide*: in a mixed
/// world they include the other tenants' traffic, which is the
/// cross-tenant interference the mixed scenario exists to measure.
pub fn report_for_tenant(world: &World<DcEvent, DcState>, cfg: &Config, tenant: usize) -> SimReport {
    let s = &world.shared;
    let ts = &s.tenants[tenant];
    let m = &ts.metrics;
    let elapsed = s.horizon_us;
    let warmup = ts.warmup_us;

    let wait_mean = m.hist_wait.mean();
    let total = m.hist_ingest.mean() + m.hist_prep.mean() + wait_mean + m.hist_service.mean();
    let measured_window = elapsed.saturating_sub(warmup);
    let mean_faces = if m.frames_total == 0 {
        0.0
    } else {
        m.produced as f64 / m.frames_total as f64
    };

    SimReport {
        accel: cfg.accel,
        elapsed_us: elapsed,
        ingest_mean_us: m.hist_ingest.mean(),
        detect_mean_us: m.hist_prep.mean(),
        wait_mean_us: wait_mean,
        identify_mean_us: m.hist_service.mean(),
        e2e_mean_us: m.hist_e2e.mean(),
        e2e_p99_us: m.hist_e2e.p99(),
        ingest_p99_us: m.hist_ingest.p99(),
        detect_p99_us: m.hist_prep.p99(),
        wait_p99_us: m.hist_wait.p99(),
        identify_p99_us: m.hist_service.p99(),
        wait_fraction: if total > 0.0 { wait_mean / total } else { 0.0 },
        frames_ingested: m.frames_measured,
        faces_produced: m.produced,
        faces_completed: m.completed,
        throughput_fps: if measured_window > 0 {
            m.completed_in_window as f64 * 1e6 / measured_window as f64
        } else {
            0.0
        },
        mean_faces_per_frame: mean_faces,
        verdict: m.population.verdict(elapsed),
        storage_write_util: s.fabric.max_storage_write_util(elapsed),
        storage_read_util: s.fabric.max_storage_read_util(elapsed),
        broker_net_rx_util: s.fabric.max_nic_rx_util(elapsed),
        broker_net_tx_util: s.fabric.max_nic_tx_util(elapsed),
        broker_cpu_util: s.fabric.max_cpu_util(elapsed),
        producer_net_tx_util: TenantMetrics::per_node_net_util(
            m.net_tx_bytes,
            elapsed,
            cfg.deployment.producers,
            cfg.node.net_bw,
        ),
        consumer_net_rx_util: TenantMetrics::per_node_net_util(
            m.net_rx_bytes,
            elapsed,
            cfg.deployment.consumers,
            cfg.node.net_bw,
        ),
        population: m.population.samples().to_vec(),
        latency_series: m.latency_series(),
        clamped_events: world.clamped(),
    }
}

/// The simulator: one Face Recognition tenant on a dedicated world.
pub struct FaceRecSim {
    cfg: Config,
}

impl FaceRecSim {
    pub fn new(cfg: Config) -> Self {
        cfg.deployment.validate().expect("invalid deployment");
        FaceRecSim { cfg }
    }

    /// Run to the configured horizon and report.
    pub fn run(&self) -> SimReport {
        let cfg = &self.cfg;
        let spec = dc::FabricSpec::from_config(cfg);
        let mut world = dc::build(
            &[dc::TenantSpec { kind: dc::WorkloadKind::FaceRec, cfg }],
            &spec,
            cfg.duration_us,
        );
        world.run_until(cfg.duration_us);
        if std::env::var("AITAX_SIM_DEBUG").is_ok() {
            let s = &world.shared;
            let ts = &s.tenants[0];
            let active = world
                .component::<dc::ConsumerPoller>(ts.poller_comp)
                .map(|p| p.active_units())
                .unwrap_or(0);
            let qtot: usize = s.partitions.iter().map(|p| p.queue.len()).sum();
            eprintln!(
                "[sim-debug] active_consumers={active}/{} qtot={qtot} events={} cpu_util={:.2} storage_util={:.2}",
                ts.gates.len(),
                world.processed(),
                s.fabric.max_cpu_util(cfg.duration_us),
                s.fabric.max_storage_write_util(cfg.duration_us),
            );
        }
        report_for_tenant(&world, cfg, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;

    /// Paper-scale §4.2 deployment, shortened horizon.
    fn paper_config(accel: f64) -> Config {
        let mut cfg = Config::default();
        cfg.deployment = Deployment::facerec_paper();
        cfg.duration_us = 30 * crate::util::units::SEC;
        cfg.accel = accel;
        cfg.seed = 0xBEEF;
        cfg
    }

    /// §5.3 acceleration deployment (1 face/frame).
    fn accel_config(accel: f64) -> Config {
        let mut cfg = Config::default();
        cfg.deployment = Deployment::facerec_accel();
        cfg.duration_us = 30 * crate::util::units::SEC;
        cfg.accel = accel;
        cfg.seed = 0xACCE1;
        cfg
    }

    #[test]
    fn baseline_breakdown_shape() {
        let r = FaceRecSim::new(paper_config(1.0)).run();
        assert!(r.frames_ingested > 50_000, "frames={}", r.frames_ingested);
        assert!(r.faces_completed > 30_000, "faces={}", r.faces_completed);
        // Stage means near the paper's Fig-6 values.
        assert!((r.ingest_mean_us - 18_800.0).abs() / 18_800.0 < 0.05, "{}", r.ingest_mean_us);
        assert!((r.detect_mean_us - 80_500.0).abs() / 80_500.0 < 0.10, "{}", r.detect_mean_us);
        assert!(
            (r.identify_mean_us - 131_500.0).abs() / 131_500.0 < 0.05,
            "{}",
            r.identify_mean_us
        );
        // Broker wait is a large fraction but the system is stable.
        assert!(r.wait_mean_us > 50_000.0, "wait={}", r.wait_mean_us);
        assert!(r.wait_mean_us < 260_000.0, "wait={}", r.wait_mean_us);
        assert!(r.verdict.stable, "growth={}", r.verdict.growth_per_sec);
        // ~0.64 faces/frame from the video model. The realized per-frame
        // mean runs slightly below the stationary 0.64 because bursting
        // producers spend longer per frame (length-biased sampling), which
        // the paper's real deployment also exhibits.
        assert!((0.45..0.75).contains(&r.mean_faces_per_frame), "{}", r.mean_faces_per_frame);
    }

    #[test]
    fn conservation_of_faces() {
        let r = FaceRecSim::new(paper_config(1.0)).run();
        assert!(r.faces_completed <= r.faces_produced);
        // In a stable system most produced faces complete.
        assert!(
            r.faces_completed as f64 > 0.9 * r.faces_produced as f64,
            "completed {} of {}",
            r.faces_completed,
            r.faces_produced
        );
    }

    #[test]
    fn acceleration_raises_throughput_and_cuts_latency() {
        let r1 = FaceRecSim::new(accel_config(1.0)).run();
        let r4 = FaceRecSim::new(accel_config(4.0)).run();
        assert!(r4.throughput_fps > 2.0 * r1.throughput_fps);
        assert!(r4.e2e_mean_us < r1.e2e_mean_us);
        assert!(r1.verdict.stable && r4.verdict.stable);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = FaceRecSim::new(accel_config(2.0)).run();
        let b = FaceRecSim::new(accel_config(2.0)).run();
        assert_eq!(a.faces_completed, b.faces_completed);
        assert_eq!(a.e2e_p99_us, b.e2e_p99_us);
    }

    #[test]
    fn storage_util_grows_with_acceleration_and_saturates_at_8x() {
        let r1 = FaceRecSim::new(accel_config(1.0)).run();
        let r4 = FaceRecSim::new(accel_config(4.0)).run();
        let r8 = FaceRecSim::new(accel_config(8.0)).run();
        // Fig 11b: ~10% at 1x, growing roughly linearly...
        assert!((0.06..0.16).contains(&r1.storage_write_util), "{}", r1.storage_write_util);
        assert!(r4.storage_write_util > 2.5 * r1.storage_write_util);
        // ...until 8x destabilizes the system (the paper's headline).
        assert!(!r8.verdict.stable, "8x should be unstable");
        // Reads stay near zero (page cache).
        assert!(r1.storage_read_util < 0.01);
    }

    #[test]
    fn six_x_stable_with_high_wait_fraction() {
        // §5.5: wait fraction grows to ~79% at 6x but the system holds.
        let r6 = FaceRecSim::new(accel_config(6.0)).run();
        assert!(r6.verdict.stable, "6x should be stable");
        assert!(
            r6.wait_fraction > 0.55,
            "wait fraction at 6x = {}",
            r6.wait_fraction
        );
    }

    #[test]
    fn network_far_from_saturation() {
        // Fig 11a: broker network peaks ~6% at 8x; at 4x it's below that.
        let r4 = FaceRecSim::new(accel_config(4.0)).run();
        assert!(r4.broker_net_rx_util < 0.06, "{}", r4.broker_net_rx_util);
        assert!(r4.producer_net_tx_util < 0.01);
    }
}
