//! Broker failover: the scenario where the fabric's membership changes
//! mid-run.
//!
//! The paper measures the AI tax on a *healthy* fabric; every
//! steady-state number implicitly assumes all brokers up and every
//! replica in sync. But the tax is worst exactly when that assumption
//! breaks: a broker crash loses its page cache, moves its partition
//! leadership, and — on restart — replays everything it missed as a
//! maximally-lagged consumer whose catch-up reads come cold off the
//! surviving brokers' spindles ([`Fabric`] fault mode, PR 5's measured
//! read path). This module packages that scenario on the same 3-tenant
//! registry as [`catchup`](crate::pipeline::catchup):
//!
//! * **facerec** — §5.3 acceleration at 4×, the bulk write pressure that
//!   accumulates the re-replication debt while the victim is down.
//! * **train-ingest** — large sequential writes; its partitions led by
//!   the victim must re-elect and its acks shrink to the surviving ISR.
//! * **rpc** — the latency canary. Its tail through the failover window
//!   ([`TenantDef::with_observe_window`]) is the headline number: with
//!   FIFO storage the recovery's cold reads and classed writes stall the
//!   canary's 2 kB commits; with the GPS spindle scheduler
//!   ([`MultiTenantConfig::with_storage_qos`]) the replay drains at the
//!   bulk weight while the canary keeps its share.
//!
//! The schedule is one [`FaultPlan`]: kill [`VICTIM`] at
//! [`FailoverSpec::kill_at_us`], restart it at
//! [`FailoverSpec::restart_at_us`]. On the kill, the deployment layer
//! re-elects every partition the victim led and pauses the affected
//! consumers for the rebalance
//! ([`dc::REBALANCE_PAUSE_US`](crate::pipeline::dc::REBALANCE_PAUSE_US));
//! commits continue on the shrunken ISR. On the restart, the victim
//! drains its replay backlog at
//! [`FailoverSpec::recovery_bytes_per_sec`] and rejoins the ISR when the
//! last byte lands. `experiments::failover` sweeps kill time × storage
//! arm × recovery bandwidth (`aitax experiment failover`);
//! `tests/failover_differential.rs` pins the empty-plan world bit-exact
//! to the immortal fabric.
//!
//! [`Fabric`]: crate::pipeline::fabric::Fabric

use crate::pipeline::catchup::{self, CatchupSpec};
use crate::pipeline::fabric::FaultPlan;
use crate::pipeline::mixed::{MultiTenantConfig, MultiTenantReport, MultiTenantSim};
use crate::util::units::SEC;

/// The broker the plan kills. Broker 0 hosts the most partition leaders
/// under round-robin assignment; killing broker 1 exercises both roles —
/// leader for a third of the partitions, follower for the rest.
pub const VICTIM: u32 = 1;

/// How long past the restart the observation window stays open — sized
/// to sit inside the re-replication contention period at every swept
/// recovery bandwidth, so every arm's tail is measured over the same
/// set of request-creation instants.
pub const OBSERVE_TAIL_US: u64 = 4 * SEC;

/// One failover scenario point.
#[derive(Clone, Copy, Debug)]
pub struct FailoverSpec {
    /// Virtual instant the victim broker dies.
    pub kill_at_us: u64,
    /// Virtual instant it comes back (empty page cache, out of the ISR,
    /// replaying its backlog).
    pub restart_at_us: u64,
    /// `true`: the per-class GPS spindle scheduler carries recovery
    /// reads/writes at the bulk weight; `false`: the seed FIFO spindle.
    pub classed: bool,
    /// Re-replication pacing, bytes/sec of replay drained by the
    /// recovering broker.
    pub recovery_bytes_per_sec: f64,
    /// Per-broker page-cache capacity (bytes) for the measured read
    /// path — small enough that the victim's missed window ages out and
    /// its catch-up goes to the device.
    pub cache_bytes: f64,
}

impl FailoverSpec {
    /// The tail-observation window: request creations in
    /// `[restart, restart + OBSERVE_TAIL_US]` feed the windowed p99
    /// ([`crate::pipeline::dc::TenantSummary::e2e_p99_window_us`]).
    ///
    /// The window opens at the *restart*, not the kill: the kill-time
    /// transient (leader re-election plus the
    /// [`REBALANCE_PAUSE_US`](crate::pipeline::dc::REBALANCE_PAUSE_US)
    /// consumer pause) hits both storage arms identically and would
    /// swamp the p99 either way. What the sweep isolates is the
    /// re-replication period, where the catch-up stream's cold reads
    /// and classed writes contend with live traffic on the surviving
    /// spindles — the period the storage arm actually changes.
    pub fn observe_window(&self) -> (u64, u64) {
        (self.restart_at_us, self.restart_at_us + OBSERVE_TAIL_US)
    }

    /// The fault schedule this spec induces.
    pub fn plan(&self) -> FaultPlan {
        FaultPlan::new()
            .kill_broker(self.kill_at_us, VICTIM)
            .restart_broker(self.restart_at_us, VICTIM)
            .with_recovery_bandwidth(self.recovery_bytes_per_sec)
    }
}

/// The 3-tenant failover registry at one scenario point: the
/// [`catchup`] registry (same fleets, weights, and seeds — zero consumer
/// lag, the brokers make their own) plus the fault schedule and the
/// failover observation window on every tenant.
pub fn registry(spec: FailoverSpec, horizon_us: u64) -> MultiTenantConfig {
    let (ws, we) = spec.observe_window();
    let mut cfg = catchup::registry(
        CatchupSpec {
            lag_us: 0,
            cache_bytes: spec.cache_bytes,
            classed_reads: spec.classed,
        },
        horizon_us,
    );
    for t in &mut cfg.tenants {
        *t = t.clone().with_observe_window(ws, we);
    }
    cfg.with_faults(spec.plan())
}

/// Run one failover scenario point.
pub fn run(spec: FailoverSpec, horizon_us: u64) -> MultiTenantReport {
    MultiTenantSim::new(registry(spec, horizon_us)).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;
    use crate::pipeline::fabric::FaultEvent;

    fn spec() -> FailoverSpec {
        FailoverSpec {
            kill_at_us: 3 * SEC,
            restart_at_us: 5 * SEC,
            classed: true,
            recovery_bytes_per_sec: 400e6,
            cache_bytes: 200e6,
        }
    }

    #[test]
    fn registry_wires_the_scenario() {
        let cfg = registry(spec(), 15 * SEC);
        assert_eq!(cfg.tenants.len(), 3);
        assert!(cfg.storage_qos);
        assert_eq!(cfg.read_cache_bytes, Some(200e6));
        let plan = cfg.faults.as_ref().expect("failover installs a plan");
        assert_eq!(
            plan.events,
            vec![
                FaultEvent::Kill { at_us: 3 * SEC, broker: VICTIM },
                FaultEvent::Restart { at_us: 5 * SEC, broker: VICTIM },
            ]
        );
        assert_eq!(plan.recovery_bytes_per_sec, 400e6);
        for t in &cfg.tenants {
            assert_eq!(
                t.cfg.observe_window_us,
                Some((5 * SEC, 5 * SEC + OBSERVE_TAIL_US)),
                "every tenant observes the re-replication window"
            );
        }
        cfg.validate().unwrap();
    }

    /// Scaled-down failover world (small fleets, short horizon) so the
    /// unit test stays fast; full-size runs live in
    /// `experiments::failover`.
    fn small_failover(s: FailoverSpec, horizon_us: u64) -> MultiTenantConfig {
        let mut cfg = registry(s, horizon_us);
        cfg.tenants[0].cfg.deployment = Deployment {
            producers: 20,
            consumers: 30,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 30,
        };
        cfg.tenants[1].cfg.deployment = Deployment {
            producers: 4,
            consumers: 6,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 6,
        };
        cfg.tenants[1].cfg.calibration.train.batch_bytes = 250_000.0;
        cfg.tenants[1].cfg.calibration.train.fetch_min_bytes = 500_000;
        cfg.fabric = cfg.tenants[0].cfg.clone();
        cfg
    }

    #[test]
    fn failover_world_survives_a_kill_and_recovers() {
        let r = MultiTenantSim::new(small_failover(spec(), 12 * SEC)).run();
        let f = r.fault.as_ref().expect("plan ⇒ fault accounting");
        // The victim missed replication traffic while down and replayed
        // every byte of it after the restart.
        assert!(f.missed_bytes > 0.0, "2 s of downtime must miss bytes");
        assert!(f.rereplicated_bytes > 0.0, "the restart must replay");
        assert_eq!(f.backlog_bytes, 0.0, "12 s horizon outlives recovery");
        let done = f.recovery_done_us.expect("recovery must finish");
        assert!(done >= 5 * SEC, "cannot recover before the restart");
        assert_eq!(f.min_isr_violations, 0, "no commit below quorum, ever");
        // Nobody starves, and the canary's windowed tail is populated.
        for t in &r.tenants {
            assert!(t.completed > 0, "tenant {} starved", t.name);
        }
        let rpc = r.tenant("rpc").unwrap();
        assert!(
            rpc.e2e_p99_window_us > 0,
            "the observe window must capture failover-era requests"
        );
        assert_eq!(r.clamped_events, 0);
    }

    #[test]
    fn recovery_finishes_sooner_with_more_bandwidth() {
        // Catch-up must outrun the ~45 MB/s this small world keeps
        // writing while the victim is out of sync, so both arms sit
        // above it — the slow one barely, the fast one by an order of
        // magnitude.
        let slow = FailoverSpec { recovery_bytes_per_sec: 100e6, ..spec() };
        let fast = FailoverSpec { recovery_bytes_per_sec: 600e6, ..spec() };
        let rs = MultiTenantSim::new(small_failover(slow, 12 * SEC)).run();
        let rf = MultiTenantSim::new(small_failover(fast, 12 * SEC)).run();
        let ds = rs.fault.as_ref().unwrap().recovery_done_us.expect("slow arm finishes");
        let df = rf.fault.as_ref().unwrap().recovery_done_us.expect("fast arm finishes");
        assert!(
            df < ds,
            "10× recovery bandwidth must shorten the outage: fast {} vs slow {}",
            df,
            ds
        );
    }
}
