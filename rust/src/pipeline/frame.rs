//! Pipeline data types: frames, faces and identities.
//!
//! In live mode these carry real pixel buffers that flow through the
//! broker and into PJRT inference; in the DES only their sizes matter
//! (the paper's §5.2 emulation move: "rather than sending face thumbnails
//! to brokers, we send meaningless data whose size matches").

/// A video frame.
#[derive(Clone, Debug)]
pub struct Frame {
    pub id: u64,
    pub stream: u32,
    /// Capture timestamp (us).
    pub ts_us: u64,
    pub width: u32,
    pub height: u32,
    /// Interleaved RGB f32 pixels (live mode) or empty (simulation).
    pub pixels: Vec<f32>,
}

impl Frame {
    /// Synthesize a frame with `faces` bright square "faces" on a dark
    /// background — enough signal for the AOT detector to find them.
    pub fn synthetic(id: u64, stream: u32, ts_us: u64, side: u32, faces: &[(u32, u32)]) -> Frame {
        let mut pixels = vec![0.1f32; (side * side * 3) as usize];
        let fs = side / 8; // face side
        for &(cx, cy) in faces {
            for dy in 0..fs {
                for dx in 0..fs {
                    let x = (cx + dx).min(side - 1);
                    let y = (cy + dy).min(side - 1);
                    let base = ((y * side + x) * 3) as usize;
                    // A bright blob with a darker "eye line" to give the
                    // conv features something non-uniform.
                    let v = if dy == fs / 3 { 0.4 } else { 0.9 };
                    pixels[base] = v;
                    pixels[base + 1] = v * 0.8;
                    pixels[base + 2] = v * 0.7;
                }
            }
        }
        Frame {
            id,
            stream,
            ts_us,
            width: side,
            height: side,
            pixels,
        }
    }

    pub fn bytes(&self) -> usize {
        self.pixels.len() * 4
    }
}

/// A detected face thumbnail (what flows through the "faces" topic).
#[derive(Clone, Debug)]
pub struct Face {
    pub frame_id: u64,
    pub stream: u32,
    /// Time face detection finished for this face (broker-wait epoch).
    pub detected_at_us: u64,
    /// Thumbnail pixels (live) — 160x160x3 in the paper, smaller here.
    pub thumbnail: Vec<f32>,
    /// Size on the wire (sim mode uses this; live mode uses thumbnail).
    pub wire_bytes: u32,
}

impl Face {
    pub fn payload_bytes(&self) -> usize {
        if self.thumbnail.is_empty() {
            self.wire_bytes as usize
        } else {
            self.thumbnail.len() * 4
        }
    }

    /// Serialize for the broker (live mode): header + f32 pixels.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + self.thumbnail.len() * 4);
        out.extend_from_slice(&self.frame_id.to_le_bytes());
        out.extend_from_slice(&self.stream.to_le_bytes());
        out.extend_from_slice(&self.detected_at_us.to_le_bytes());
        out.extend_from_slice(&(self.thumbnail.len() as u32).to_le_bytes());
        for px in &self.thumbnail {
            out.extend_from_slice(&px.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<Face> {
        anyhow::ensure!(buf.len() >= 24, "face header truncated");
        let frame_id = u64::from_le_bytes(buf[0..8].try_into().unwrap());
        let stream = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        let detected_at_us = u64::from_le_bytes(buf[12..20].try_into().unwrap());
        let n = u32::from_le_bytes(buf[20..24].try_into().unwrap()) as usize;
        anyhow::ensure!(buf.len() == 24 + n * 4, "face payload truncated");
        let mut thumbnail = Vec::with_capacity(n);
        for i in 0..n {
            let o = 24 + i * 4;
            thumbnail.push(f32::from_le_bytes(buf[o..o + 4].try_into().unwrap()));
        }
        Ok(Face {
            frame_id,
            stream,
            detected_at_us,
            wire_bytes: (24 + n * 4) as u32,
            thumbnail,
        })
    }
}

/// Final output: who was in the frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Identity {
    pub frame_id: u64,
    pub stream: u32,
    /// Index into the known-faces gallery.
    pub person: u32,
    /// SVM decision score.
    pub score: f32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_frame_has_faces() {
        let f = Frame::synthetic(1, 0, 0, 64, &[(8, 8), (40, 40)]);
        assert_eq!(f.pixels.len(), 64 * 64 * 3);
        let bright = f.pixels.iter().filter(|&&p| p > 0.5).count();
        assert!(bright > 50, "faces should add bright pixels: {bright}");
    }

    #[test]
    fn empty_frame_is_dark() {
        let f = Frame::synthetic(1, 0, 0, 64, &[]);
        assert!(f.pixels.iter().all(|&p| p < 0.2));
    }

    #[test]
    fn face_encode_decode_roundtrip() {
        let face = Face {
            frame_id: 42,
            stream: 3,
            detected_at_us: 123_456,
            thumbnail: vec![0.25, 0.5, 0.75],
            wire_bytes: 0,
        };
        let wire = face.encode();
        let d = Face::decode(&wire).unwrap();
        assert_eq!(d.frame_id, 42);
        assert_eq!(d.stream, 3);
        assert_eq!(d.detected_at_us, 123_456);
        assert_eq!(d.thumbnail, face.thumbnail);
        assert_eq!(d.wire_bytes as usize, wire.len());
    }

    #[test]
    fn face_decode_rejects_garbage() {
        assert!(Face::decode(&[0u8; 5]).is_err());
        let face = Face {
            frame_id: 1,
            stream: 0,
            detected_at_us: 0,
            thumbnail: vec![1.0; 4],
            wire_bytes: 0,
        };
        let wire = face.encode();
        assert!(Face::decode(&wire[..wire.len() - 2]).is_err());
    }

    #[test]
    fn sim_face_uses_wire_bytes() {
        let face = Face {
            frame_id: 0,
            stream: 0,
            detected_at_us: 0,
            thumbnail: vec![],
            wire_bytes: 37_300,
        };
        assert_eq!(face.payload_bytes(), 37_300);
    }
}
