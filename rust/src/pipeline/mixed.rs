//! Mixed tenancy: Face Recognition *and* Object Detection sharing one
//! broker fabric and storage.
//!
//! The paper measures each application on a dedicated cluster; the
//! `sim::world` component kernel lets us go one step further and ask the
//! question a real AI data center faces: what happens when heterogeneous
//! AI pipelines share the coordination substrate? Both tenants keep their
//! own producers, consumers, and topic partitions, but every produce and
//! fetch contends for the same broker NICs, request CPUs, and NVMe write
//! path — so one tenant's acceleration becomes the other tenant's broker
//! wait. This was structurally impossible with the per-workload
//! monolithic simulators (one event enum, one state machine each).
//!
//! [`MixedReport`] carries the two per-tenant reports (same fields as the
//! dedicated runs, so all existing analyses apply) plus the shared-broker
//! view; `experiments::mixed` sweeps the facerec:objdet mix Fig-11/15
//! style.

use crate::config::Config;
use crate::pipeline::dc::{self, FabricSpec, TenantSpec, WorkloadKind};
use crate::pipeline::facerec::{self, SimReport};
use crate::pipeline::objdet::{self, ObjDetReport};

/// Configuration of a two-tenant deployment on one shared fabric.
///
/// Each tenant keeps its own workload config (deployment sizes, accel,
/// seeds, calibration); the *fabric* — brokers, drives, replication,
/// device specs, Kafka tuning — is taken from `fabric`, because there is
/// only one broker fleet in a mixed world.
#[derive(Clone, Debug)]
pub struct MixedConfig {
    pub facerec: Config,
    pub objdet: Config,
    /// Fabric-defining config (brokers / drives / replication / node
    /// hardware / tuning). Defaults to the Face Recognition config.
    pub fabric: Config,
    /// Shared virtual horizon (both tenants must run the same clock).
    pub duration_us: u64,
}

impl MixedConfig {
    /// The §5.3 + §6.3 acceleration deployments side by side on the
    /// paper's 3-broker fabric.
    pub fn paper_accel(facerec_accel: f64, objdet_accel: f64) -> Self {
        let mut fr = Config::default();
        fr.deployment = crate::config::Deployment::facerec_accel();
        fr.accel = facerec_accel;
        fr.seed = 0xACCE1;
        let mut od = Config::default();
        od.deployment = crate::config::Deployment::objdet_accel();
        od.accel = objdet_accel;
        od.seed = 0xD07;
        let duration_us = fr.duration_us;
        MixedConfig {
            fabric: fr.clone(),
            facerec: fr,
            objdet: od,
            duration_us,
        }
    }

    pub fn with_duration(mut self, duration_us: u64) -> Self {
        self.duration_us = duration_us;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.facerec.deployment.validate()?;
        self.objdet.deployment.validate()?;
        anyhow::ensure!(self.duration_us > 0, "mixed run needs a horizon");
        Ok(())
    }
}

/// Results of one mixed-tenancy run.
#[derive(Clone, Debug)]
pub struct MixedReport {
    /// Per-tenant breakdowns, same shape as the dedicated simulators'.
    /// Broker-side utilization fields inside them are substrate-wide.
    pub facerec: SimReport,
    pub objdet: ObjDetReport,
    /// Shared-broker view (max across brokers, like Fig 11).
    pub broker_storage_write_util: f64,
    pub broker_storage_read_util: f64,
    pub broker_net_rx_util: f64,
    pub broker_net_tx_util: f64,
    pub broker_cpu_util: f64,
    /// Events dispatched by the world (DES throughput numerator).
    pub events: u64,
}

impl MixedReport {
    /// True when both tenants' populations are stable.
    pub fn stable(&self) -> bool {
        self.facerec.verdict.stable && self.objdet.verdict.stable
    }
}

/// The mixed-tenancy simulator: two tenants, one world, one fabric.
pub struct MixedSim {
    cfg: MixedConfig,
}

impl MixedSim {
    pub fn new(cfg: MixedConfig) -> Self {
        cfg.validate().expect("invalid mixed deployment");
        MixedSim { cfg }
    }

    pub fn run(&self) -> MixedReport {
        let c = &self.cfg;
        // One fabric for everyone, sized by the fabric config.
        let spec = FabricSpec::from_config(&c.fabric);
        let mut world = dc::build(
            &[
                TenantSpec { kind: WorkloadKind::FaceRec, cfg: &c.facerec },
                TenantSpec { kind: WorkloadKind::ObjDet, cfg: &c.objdet },
            ],
            &spec,
            c.duration_us,
        );
        world.run_until(c.duration_us);

        let elapsed = c.duration_us;
        let s = &world.shared;
        MixedReport {
            broker_storage_write_util: s.fabric.max_storage_write_util(elapsed),
            broker_storage_read_util: s.fabric.max_storage_read_util(elapsed),
            broker_net_rx_util: s.fabric.max_nic_rx_util(elapsed),
            broker_net_tx_util: s.fabric.max_nic_tx_util(elapsed),
            broker_cpu_util: s.fabric.max_cpu_util(elapsed),
            events: world.processed(),
            facerec: facerec::report_for_tenant(&world, &c.facerec, 0),
            objdet: objdet::report_for_tenant(&world, &c.objdet, 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;
    use crate::util::units::SEC;

    /// Scaled-down tenants so the test world stays fast.
    fn small_mixed(fr_accel: f64, od_accel: f64) -> MixedConfig {
        let mut cfg = MixedConfig::paper_accel(fr_accel, od_accel);
        cfg.facerec.deployment = Deployment {
            producers: 75,
            consumers: 114,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 114,
        };
        cfg.objdet.deployment = Deployment {
            producers: 5,
            consumers: 480,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 480,
        };
        cfg.fabric = cfg.facerec.clone();
        cfg.with_duration(20 * SEC)
    }

    #[test]
    fn both_tenants_make_progress_on_a_shared_fabric() {
        let r = MixedSim::new(small_mixed(1.0, 1.0)).run();
        assert!(r.facerec.faces_completed > 0, "facerec starved");
        assert!(r.objdet.frames_detected > 0, "objdet starved");
        assert!(r.stable(), "small mixed load should be stable");
        assert!(r.events > 10_000, "events={}", r.events);
    }

    #[test]
    fn shared_broker_carries_both_tenants_load() {
        // The shared-broker write utilization must at least match what the
        // busier tenant would drive alone: tenants add load, never shed it.
        let mixed = MixedSim::new(small_mixed(1.0, 1.0)).run();
        let mut fr_alone = small_mixed(1.0, 1.0).facerec;
        fr_alone.duration_us = 20 * SEC;
        let solo = crate::pipeline::facerec::FaceRecSim::new(fr_alone).run();
        assert!(
            mixed.broker_storage_write_util > solo.storage_write_util,
            "mixed {} <= solo {}",
            mixed.broker_storage_write_util,
            solo.storage_write_util
        );
    }

    #[test]
    fn accelerating_one_tenant_taxes_the_other() {
        // Cross-tenant interference: pushing Object Detection harder must
        // raise the shared storage-write pressure Face Recognition sees.
        let calm = MixedSim::new(small_mixed(1.0, 1.0)).run();
        let noisy = MixedSim::new(small_mixed(1.0, 6.0)).run();
        assert!(
            noisy.broker_storage_write_util > 1.2 * calm.broker_storage_write_util,
            "calm {} noisy {}",
            calm.broker_storage_write_util,
            noisy.broker_storage_write_util
        );
    }

    #[test]
    fn deterministic_given_seeds() {
        let a = MixedSim::new(small_mixed(2.0, 2.0)).run();
        let b = MixedSim::new(small_mixed(2.0, 2.0)).run();
        assert_eq!(a.facerec.faces_completed, b.facerec.faces_completed);
        assert_eq!(a.objdet.frames_detected, b.objdet.frames_detected);
        assert_eq!(a.events, b.events);
    }
}
