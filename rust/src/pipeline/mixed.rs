//! Multi-tenancy: N heterogeneous AI pipelines sharing one broker fabric
//! and storage.
//!
//! The paper measures each application on a dedicated cluster; the
//! `sim::world` component kernel lets us go one step further and ask the
//! question a real AI data center faces: what happens when heterogeneous
//! AI pipelines share the coordination substrate? Every tenant keeps its
//! own producers, consumers, and topic partitions, but every produce and
//! fetch contends for the same broker NICs, request CPUs, and NVMe write
//! path — so one tenant's acceleration becomes the other tenant's broker
//! wait. This was structurally impossible with the per-workload
//! monolithic simulators (one event enum, one state machine each).
//!
//! Two APIs, one machine:
//!
//! * [`TenantDef`] / [`MultiTenantConfig`] / [`MultiTenantSim`] — the
//!   N-tenant registry: any mix of [`WorkloadKind`]s, each with its own
//!   config and an optional per-tenant QoS spec (scheduling-class weight
//!   plus produce/fetch quotas, realized through
//!   [`crate::broker::qos::QosPolicy`]). Reports are generic
//!   [`TenantSummary`]s plus the shared-broker view.
//! * [`MixedConfig`] / [`MixedSim`] — the original two-tenant
//!   facerec+objdet scenario, kept verbatim (it builds the identical
//!   world; `tests/qos_regression.rs` pins that the registry path with
//!   QoS disabled reproduces it bit for bit). [`MixedReport`] carries the
//!   two full per-tenant reports, so all existing analyses apply;
//!   `experiments::mixed` sweeps the facerec:objdet mix Fig-11/15 style.

use crate::broker::qos::{QosPolicy, TenantQuota};
use crate::config::Config;
use crate::metrics::trace::TraceSpec;
use crate::net::NetworkSpec;
use crate::pipeline::dc::{self, FabricSpec, TenantSpec, TenantSummary, WorkloadKind};
use crate::pipeline::fabric::FaultPlan;
use crate::pipeline::facerec::{self, SimReport};
use crate::pipeline::objdet::{self, ObjDetReport};
use crate::util::json::Json;

/// Configuration of a two-tenant deployment on one shared fabric.
///
/// Each tenant keeps its own workload config (deployment sizes, accel,
/// seeds, calibration); the *fabric* — brokers, drives, replication,
/// device specs, Kafka tuning — is taken from `fabric`, because there is
/// only one broker fleet in a mixed world.
#[derive(Clone, Debug)]
pub struct MixedConfig {
    pub facerec: Config,
    pub objdet: Config,
    /// Fabric-defining config (brokers / drives / replication / node
    /// hardware / tuning). Defaults to the Face Recognition config.
    pub fabric: Config,
    /// Shared virtual horizon (both tenants must run the same clock).
    pub duration_us: u64,
}

impl MixedConfig {
    /// The §5.3 + §6.3 acceleration deployments side by side on the
    /// paper's 3-broker fabric.
    pub fn paper_accel(facerec_accel: f64, objdet_accel: f64) -> Self {
        let mut fr = Config::default();
        fr.deployment = crate::config::Deployment::facerec_accel();
        fr.accel = facerec_accel;
        fr.seed = 0xACCE1;
        let mut od = Config::default();
        od.deployment = crate::config::Deployment::objdet_accel();
        od.accel = objdet_accel;
        od.seed = 0xD07;
        let duration_us = fr.duration_us;
        MixedConfig {
            fabric: fr.clone(),
            facerec: fr,
            objdet: od,
            duration_us,
        }
    }

    pub fn with_duration(mut self, duration_us: u64) -> Self {
        self.duration_us = duration_us;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.facerec.deployment.validate()?;
        self.objdet.deployment.validate()?;
        anyhow::ensure!(self.duration_us > 0, "mixed run needs a horizon");
        Ok(())
    }
}

/// Results of one mixed-tenancy run.
#[derive(Clone, Debug)]
pub struct MixedReport {
    /// Per-tenant breakdowns, same shape as the dedicated simulators'.
    /// Broker-side utilization fields inside them are substrate-wide.
    pub facerec: SimReport,
    pub objdet: ObjDetReport,
    /// Shared-broker view (max across brokers, like Fig 11).
    pub broker_storage_write_util: f64,
    pub broker_storage_read_util: f64,
    pub broker_net_rx_util: f64,
    pub broker_net_tx_util: f64,
    pub broker_cpu_util: f64,
    /// Events dispatched by the world (DES throughput numerator).
    pub events: u64,
    /// Past-time schedules clamped by the event queue — zero in every
    /// healthy run (`tests/qos_regression.rs` asserts it).
    pub clamped_events: u64,
}

impl MixedReport {
    /// True when both tenants' populations are stable.
    pub fn stable(&self) -> bool {
        self.facerec.verdict.stable && self.objdet.verdict.stable
    }
}

/// The mixed-tenancy simulator: two tenants, one world, one fabric.
pub struct MixedSim {
    cfg: MixedConfig,
}

impl MixedSim {
    pub fn new(cfg: MixedConfig) -> Self {
        cfg.validate().expect("invalid mixed deployment");
        MixedSim { cfg }
    }

    pub fn run(&self) -> MixedReport {
        let c = &self.cfg;
        // One fabric for everyone, sized by the fabric config.
        let spec = FabricSpec::from_config(&c.fabric);
        let mut world = dc::build(
            &[
                TenantSpec { kind: WorkloadKind::FaceRec, cfg: &c.facerec },
                TenantSpec { kind: WorkloadKind::ObjDet, cfg: &c.objdet },
            ],
            &spec,
            c.duration_us,
        );
        world.run_until(c.duration_us);

        let elapsed = c.duration_us;
        let s = &world.shared;
        MixedReport {
            broker_storage_write_util: s.fabric.max_storage_write_util(elapsed),
            broker_storage_read_util: s.fabric.max_storage_read_util(elapsed),
            broker_net_rx_util: s.fabric.max_nic_rx_util(elapsed),
            broker_net_tx_util: s.fabric.max_nic_tx_util(elapsed),
            broker_cpu_util: s.fabric.max_cpu_util(elapsed),
            events: world.processed(),
            clamped_events: world.clamped(),
            facerec: facerec::report_for_tenant(&world, &c.facerec, 0),
            objdet: objdet::report_for_tenant(&world, &c.objdet, 1),
        }
    }
}

// ---------------------------------------------------------------------------
// N-tenant registry
// ---------------------------------------------------------------------------

/// Per-tenant QoS settings in the registry (realized as a
/// [`QosPolicy`] when the world is built with QoS enabled).
#[derive(Clone, Copy, Debug)]
pub struct TenantQosSpec {
    /// Scheduling-class weight (share under contention). One weight
    /// drives both classed servers: the broker request CPU (when
    /// [`MultiTenantConfig::weighted_cpu`]) and the NVMe write path
    /// (when [`MultiTenantConfig::storage_qos`]).
    pub weight: f64,
    /// Produce byte-rate cap, bytes/sec (`None` = uncapped).
    pub produce_bytes_per_sec: Option<f64>,
    /// Denominate the produce cap in write-path bytes (`bytes × RF`
    /// charged per record) instead of client bytes — see
    /// [`TenantQuota::replication_aware`].
    pub charge_replicated: bool,
    /// Fetch byte-rate cap, bytes/sec (`None` = uncapped).
    pub fetch_bytes_per_sec: Option<f64>,
}

impl Default for TenantQosSpec {
    fn default() -> Self {
        TenantQosSpec {
            weight: 1.0,
            produce_bytes_per_sec: None,
            charge_replicated: false,
            fetch_bytes_per_sec: None,
        }
    }
}

/// One tenant in the registry: a named workload with its own config and
/// QoS spec. Registration order is the scheduling-class id.
#[derive(Clone, Debug)]
pub struct TenantDef {
    pub name: String,
    pub kind: WorkloadKind,
    pub cfg: Config,
    pub qos: TenantQosSpec,
}

impl TenantDef {
    pub fn new(name: &str, kind: WorkloadKind, cfg: Config) -> Self {
        TenantDef {
            name: name.to_string(),
            kind,
            cfg,
            qos: TenantQosSpec::default(),
        }
    }

    pub fn with_weight(mut self, weight: f64) -> Self {
        self.qos.weight = weight;
        self
    }

    pub fn with_produce_quota(mut self, bytes_per_sec: f64) -> Self {
        self.qos.produce_bytes_per_sec = Some(bytes_per_sec);
        self
    }

    /// Produce cap denominated in **write-path** bytes: the bucket is
    /// charged `bytes × RF` per record, so this budget is what the
    /// tenant may cost the shared NVMe write path, not what it may put
    /// on the client wire.
    pub fn with_replicated_produce_quota(mut self, write_bytes_per_sec: f64) -> Self {
        self.qos.produce_bytes_per_sec = Some(write_bytes_per_sec);
        self.qos.charge_replicated = true;
        self
    }

    pub fn with_fetch_quota(mut self, bytes_per_sec: f64) -> Self {
        self.qos.fetch_bytes_per_sec = Some(bytes_per_sec);
        self
    }

    /// Catch-up scenario: this tenant's consumers start `lag_us` behind
    /// (no polls before that virtual instant), then drain the backlog —
    /// through cold device reads once it ages out of the page-cache
    /// window, when the registry enables the measured read path
    /// ([`MultiTenantConfig::with_read_cache`]).
    pub fn with_consumer_lag(mut self, lag_us: u64) -> Self {
        self.cfg.consumer_lag_start_us = lag_us;
        self
    }

    /// Hybrid fluid/discrete scaling: represent this tenant's producer
    /// population as `clients` clients aggregated into a handful of
    /// deterministic rate processes instead of one component (and one
    /// event stream) per client. The flow producers emit batched
    /// macro-records on the coalescing quantum
    /// ([`Self::with_flow_quantum`]), so a million-client tenant costs a
    /// few events per quantum rather than millions per second, while the
    /// broker fabric still sees the same offered byte stream, aggregate
    /// request-CPU, quota charges, and read-path traffic.
    /// Tick-style workloads only ([`WorkloadKind::TrainIngest`] /
    /// [`WorkloadKind::Rpc`]); `tests/flow_differential.rs` pins that
    /// tenant means converge to the per-record simulation as N grows.
    pub fn with_flow_clients(mut self, clients: u64) -> Self {
        self.cfg.flow_clients = clients;
        self.cfg.deployment.producers = clients.max(1) as usize;
        self
    }

    /// Coalescing quantum for flow-aggregated producers, µs (default
    /// [`crate::config::Config::flow_quantum_us`]): macro-records are
    /// emitted on this grid, so it bounds both the event rate and the
    /// burstiness the fluid approximation injects.
    pub fn with_flow_quantum(mut self, quantum_us: u64) -> Self {
        self.cfg.flow_quantum_us = quantum_us;
        self
    }

    /// Restrict this tenant's windowed tail metric
    /// ([`TenantSummary::e2e_p99_window_us`]) to requests *created*
    /// inside `[start_us, end_us]` — e.g. the failover window, so a
    /// broker crash's tail damage isn't averaged away by minutes of
    /// healthy traffic on either side.
    pub fn with_observe_window(mut self, start_us: u64, end_us: u64) -> Self {
        self.cfg.observe_window_us = Some((start_us, end_us));
        self
    }

    /// Arm this tenant's producers with a client retry policy
    /// ([`crate::pipeline::dc::RetryPolicy`]): rejected / unacked sends
    /// are buffered and re-offered with deterministic backoff instead
    /// of standing as loss. Off by default (the PR 7 client).
    pub fn with_retry(mut self, policy: crate::pipeline::dc::RetryPolicy) -> Self {
        self.cfg.retry_max_attempts = policy.max_attempts;
        self.cfg.retry_base_backoff_us = policy.base_backoff_us;
        self.cfg.retry_max_backoff_us = policy.max_backoff_us;
        self.cfg.retry_request_timeout_us = policy.request_timeout_us;
        self.cfg.retry_buffer_bytes = policy.buffer_bytes;
        self
    }
}

/// An N-tenant deployment on one shared fabric.
#[derive(Clone, Debug)]
pub struct MultiTenantConfig {
    pub tenants: Vec<TenantDef>,
    /// Fabric-defining config (brokers / drives / replication / node
    /// hardware / tuning) — one broker fleet for everyone.
    pub fabric: Config,
    /// Shared virtual horizon.
    pub duration_us: u64,
    /// Apply each tenant's quotas (and, with [`Self::weighted_cpu`], its
    /// scheduling-class weight). `false` = the pre-QoS shared-FIFO broker.
    pub qos_enabled: bool,
    /// Replace the FIFO request CPU with the deficit-weighted scheduler
    /// (only meaningful when [`Self::qos_enabled`]).
    pub weighted_cpu: bool,
    /// Replace the FIFO NVMe write queue on every broker with the
    /// per-class GPS scheduler (tenant weights). Independent of
    /// [`Self::qos_enabled`] so the storage mitigation can be studied in
    /// isolation from quotas — `experiments::storage_qos` does exactly
    /// that.
    pub storage_qos: bool,
    /// Operator-facing **per-broker write budget** (bytes/sec of device
    /// writes). Translated into a replication-aware produce quota per
    /// tenant that has no explicit produce cap:
    /// `budget × brokers / tenants` write-path bytes each (see
    /// [`crate::broker::qos::write_budget_per_tenant_rate`]). Setting it
    /// via [`Self::with_broker_write_budget`] turns quota enforcement
    /// ([`Self::qos_enabled`]) on; a later `with_qos(false)` turns
    /// enforcement — budget included — back off.
    pub broker_write_budget: Option<f64>,
    /// Per-broker page-cache capacity of the **measured read path**
    /// (bytes); `None` (the default) keeps the seed's hardcoded cache
    /// hits. When set, consumer fetches are split against each broker's
    /// cached window at the tenant's actual consume offsets, and cold
    /// bytes contend with replicated writes on the NVMe spindle —
    /// classed at the tenant weights when [`Self::storage_qos`] is on,
    /// FIFO otherwise.
    pub read_cache_bytes: Option<f64>,
    /// Failure schedule injected into the shared fabric (broker kills /
    /// restarts / fabric partitions, plus ISR + recovery parameters).
    /// `None` — and an *empty* plan — leave the world bit-exact to the
    /// immortal fabric (`tests/failover_differential.rs` pins both).
    pub faults: Option<FaultPlan>,
    /// Contention-aware ToR/spine network on the shared fabric
    /// ([`FabricSpec::with_network_spec`]); `None` (the default) keeps
    /// every wire hop at the fixed transit, bit for bit
    /// (`tests/net_differential.rs` pins it).
    pub network: Option<NetworkSpec>,
    /// Latency provenance ([`FabricSpec::with_provenance`]): per-record
    /// tax cells + per-tenant [`TaxSummary`] in the report. `false`
    /// (the default) is bit-exact (`tests/tax_differential.rs`).
    ///
    /// [`TaxSummary`]: crate::metrics::tax::TaxSummary
    pub provenance: bool,
    /// Opt-in flight recorder; the run's sampled trace lands in
    /// [`MultiTenantReport::trace`] as Chrome trace-event JSON.
    pub trace: Option<TraceSpec>,
}

impl MultiTenantConfig {
    pub fn new(fabric: Config, duration_us: u64) -> Self {
        MultiTenantConfig {
            tenants: Vec::new(),
            fabric,
            duration_us,
            qos_enabled: false,
            weighted_cpu: false,
            storage_qos: false,
            broker_write_budget: None,
            read_cache_bytes: None,
            faults: None,
            network: None,
            provenance: false,
            trace: None,
        }
    }

    pub fn tenant(mut self, def: TenantDef) -> Self {
        self.tenants.push(def);
        self
    }

    pub fn with_qos(mut self, enabled: bool) -> Self {
        self.qos_enabled = enabled;
        self.weighted_cpu = enabled;
        self
    }

    /// Enable (or disable) the per-class NVMe write scheduler.
    pub fn with_storage_qos(mut self, enabled: bool) -> Self {
        self.storage_qos = enabled;
        self
    }

    /// Enable the measured read path with an explicit per-broker
    /// page-cache capacity (see [`Self::read_cache_bytes`]).
    pub fn with_read_cache(mut self, bytes: f64) -> Self {
        self.read_cache_bytes = Some(bytes);
        self
    }

    /// Inject a failure schedule into the shared fabric (see
    /// [`Self::faults`]).
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Route every wire hop over a contention-aware ToR/spine network
    /// (see [`Self::network`]).
    pub fn with_network(mut self, spec: NetworkSpec) -> Self {
        self.network = Some(spec);
        self
    }

    /// Arm latency provenance (see [`Self::provenance`]).
    pub fn with_provenance(mut self) -> Self {
        self.provenance = true;
        self
    }

    /// Install the flight recorder (see [`Self::trace`]).
    pub fn with_trace(mut self, spec: TraceSpec) -> Self {
        self.trace = Some(spec);
        self
    }

    /// Enable the measured read path at the calibrated default
    /// capacity: [`crate::config::Calibration::page_cache_capacity`] of
    /// the fabric node's RAM (the capacity that must reproduce the
    /// §5.4 `read_cache_hit` target under nominal lag).
    pub fn with_default_read_cache(self) -> Self {
        let bytes = self
            .fabric
            .calibration
            .page_cache_capacity(self.fabric.node.memory);
        self.with_read_cache(bytes)
    }

    /// Set the per-broker write budget (see [`Self::broker_write_budget`]).
    /// A budget is a quota mechanism, so this also enables quota
    /// enforcement — without touching [`Self::weighted_cpu`] or
    /// [`Self::storage_qos`] — rather than silently holding a value that
    /// would never bind.
    pub fn with_broker_write_budget(mut self, bytes_per_sec_per_broker: f64) -> Self {
        self.broker_write_budget = Some(bytes_per_sec_per_broker);
        self.qos_enabled = true;
        self
    }

    /// The [`QosPolicy`] this registry induces (`None` when every
    /// mechanism is disabled).
    pub fn policy(&self) -> Option<QosPolicy> {
        if !self.qos_enabled && !self.storage_qos {
            return None;
        }
        // The write budget translates into a replication-aware produce
        // rate for every tenant without an explicit cap of its own.
        let budget_rate = self.broker_write_budget.map(|b| {
            crate::broker::qos::write_budget_per_tenant_rate(
                b,
                self.fabric.deployment.brokers,
                self.tenants.len(),
            )
        });
        Some(QosPolicy {
            cpu_weights: (self.qos_enabled && self.weighted_cpu)
                .then(|| self.tenants.iter().map(|t| t.qos.weight).collect()),
            storage_weights: self
                .storage_qos
                .then(|| self.tenants.iter().map(|t| t.qos.weight).collect()),
            quotas: if self.qos_enabled {
                self.tenants
                    .iter()
                    .map(|t| match (t.qos.produce_bytes_per_sec, budget_rate) {
                        (None, Some(rate)) => TenantQuota {
                            produce_bytes_per_sec: Some(rate),
                            fetch_bytes_per_sec: t.qos.fetch_bytes_per_sec,
                            burst_bytes: None,
                            replication_aware: true,
                        },
                        _ => TenantQuota {
                            produce_bytes_per_sec: t.qos.produce_bytes_per_sec,
                            fetch_bytes_per_sec: t.qos.fetch_bytes_per_sec,
                            burst_bytes: None,
                            replication_aware: t.qos.charge_replicated,
                        },
                    })
                    .collect()
            } else {
                Vec::new()
            },
        })
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.tenants.is_empty(), "registry needs tenants");
        anyhow::ensure!(self.duration_us > 0, "multi-tenant run needs a horizon");
        anyhow::ensure!(
            self.tenants.len() <= u8::MAX as usize,
            "tenant ids are u8"
        );
        for t in &self.tenants {
            t.cfg.deployment.validate()?;
            anyhow::ensure!(t.qos.weight > 0.0, "tenant {} needs weight > 0", t.name);
        }
        Ok(())
    }
}

/// Fabric-level failure accounting for one N-tenant run — present only
/// when a [`FaultPlan`] was installed (even an empty one, so a run can
/// assert that a fault-capable world stayed fault-free).
#[derive(Clone, Debug)]
pub struct FaultReport {
    /// Produce attempts that reached the fabric. The conservation
    /// identity `offered == committed + rejected + lost + in_flight`
    /// holds exactly (u64 arithmetic, pinned by
    /// `tests/failover_differential.rs`).
    pub records_offered: u64,
    /// Commits — every one satisfied its ISR quorum.
    pub records_committed: u64,
    /// Records still in flight at the horizon (produced, not yet
    /// committed, lost, or rejected).
    pub records_in_flight: u64,
    /// Replication bytes a dead or partitioned follower missed and now
    /// owes the log (the re-replication debt).
    pub missed_bytes: f64,
    /// Bytes the recovery path replayed into restarted followers — cold
    /// device reads on the source, classed writes on the sink.
    pub rereplicated_bytes: f64,
    /// Records lost to a dead leader or a collapsed ISR.
    pub records_lost: u64,
    /// Records refused at admission (dead leader / ISR below quorum).
    pub records_rejected: u64,
    /// Commits that would have violated `min_isr` — structurally
    /// unreachable (admission + fan-out guard it); pinned at zero by
    /// `tests/failover_differential.rs`.
    pub min_isr_violations: u64,
    /// Virtual instant the *last* recovering broker drained its replay
    /// backlog and rejoined the ISR. `None` while any broker is still
    /// dead, catching up, or was never disturbed.
    pub recovery_done_us: Option<u64>,
    /// Share of all NVMe device-read bytes consumed by re-replication —
    /// the catch-up reads competing with lagging consumers for the
    /// spindle.
    pub rereplication_read_share: f64,
    /// Replay bytes still owed at the horizon (0.0 once recovered).
    pub backlog_bytes: f64,
    /// Client retry attempts summed over the tenants (0 without a
    /// [`crate::pipeline::dc::RetryPolicy`]). With retries, the
    /// identity extends to `offered − retried == committed +
    /// rejected_final + lost + in_flight + client_dropped`, still
    /// u64-exact (`tests/resilience_differential.rs`).
    pub records_retried: u64,
    /// Records dropped at the clients on retry-buffer overflow.
    pub records_client_dropped: u64,
    /// Rejections that stood: `records_rejected` minus the rejections
    /// the clients absorbed (retried or converted to client drops).
    pub records_rejected_final: u64,
    /// Duplicate retransmits the brokers' idempotence layer suppressed
    /// (0 without [`FaultPlan::with_idempotence`]).
    pub records_dedup_suppressed: u64,
    /// Committed bytes discarded by electing out-of-sync replicas (0
    /// under [`ElectionPolicy::Clean`], the default) — data loss as a
    /// measured policy choice, never silent.
    ///
    /// [`ElectionPolicy::Clean`]: crate::pipeline::fabric::ElectionPolicy::Clean
    pub unclean_lost_bytes: f64,
    /// Out-of-sync leader elections taken (unclean policy only).
    pub unclean_elections: u64,
}

impl FaultReport {
    /// Residual of the extended conservation identity
    /// `offered − retried − committed − rejected_final − lost −
    /// in_flight − client_dropped` as a signed count — 0 in every
    /// healthy run, whatever the fault schedule.
    pub fn conservation_residual(&self) -> i64 {
        self.records_offered as i64
            - self.records_retried as i64
            - self.records_committed as i64
            - self.records_rejected_final as i64
            - self.records_lost as i64
            - self.records_in_flight as i64
            - self.records_client_dropped as i64
    }
}

/// Results of one N-tenant run: generic per-tenant summaries plus the
/// shared-broker view.
#[derive(Clone, Debug)]
pub struct MultiTenantReport {
    pub tenants: Vec<TenantSummary>,
    pub broker_storage_write_util: f64,
    /// Max per-broker device-read utilization (spec-relative) — nonzero
    /// only when the measured read path sees cache misses.
    pub broker_storage_read_util: f64,
    pub broker_net_rx_util: f64,
    pub broker_cpu_util: f64,
    /// Byte-weighted page-cache hit ratio across all fetches (1.0 when
    /// the measured read path is disabled: the seed's assumption).
    pub cache_hit_ratio: f64,
    /// Fraction of fetched bytes served by the NVMe read path (0.0 when
    /// the read path is disabled).
    pub device_read_share: f64,
    pub events: u64,
    /// Past-time schedules clamped by the event queue — zero in every
    /// healthy run (`tests/qos_regression.rs` asserts it).
    pub clamped_events: u64,
    /// Failure accounting (`None` when no [`FaultPlan`] was installed).
    pub fault: Option<FaultReport>,
    /// Transfers whose max-min share was below their solo share at some
    /// epoch — zero when no network is installed or nothing contends.
    pub net_contended_transfers: u64,
    /// Peak time-averaged rack-uplink utilization (0.0 without a network).
    pub net_max_uplink_util: f64,
    /// Flight-recorder contents as Chrome trace-event JSON (`None`
    /// unless [`MultiTenantConfig::with_trace`] installed the recorder).
    pub trace: Option<Json>,
}

impl MultiTenantReport {
    pub fn tenant(&self, name: &str) -> Option<&TenantSummary> {
        self.tenants.iter().find(|t| t.name == name)
    }
}

/// The N-tenant simulator: any workload mix, one world, one fabric,
/// optional broker QoS.
pub struct MultiTenantSim {
    cfg: MultiTenantConfig,
}

impl MultiTenantSim {
    pub fn new(cfg: MultiTenantConfig) -> Self {
        cfg.validate().expect("invalid multi-tenant deployment");
        MultiTenantSim { cfg }
    }

    pub fn run(&self) -> MultiTenantReport {
        let c = &self.cfg;
        let mut spec = FabricSpec::from_config(&c.fabric);
        if let Some(bytes) = c.read_cache_bytes {
            spec = spec.with_read_cache(bytes);
        }
        if let Some(plan) = &c.faults {
            spec = spec.with_faults(plan.clone());
        }
        if let Some(net) = c.network {
            spec = spec.with_network_spec(net);
        }
        if c.provenance {
            spec = spec.with_provenance();
        }
        if let Some(tr) = c.trace {
            spec = spec.with_trace(tr);
        }
        let tenant_specs: Vec<TenantSpec<'_>> = c
            .tenants
            .iter()
            .map(|t| TenantSpec { kind: t.kind, cfg: &t.cfg })
            .collect();
        let policy = c.policy();
        let mut world =
            dc::build_with_qos(&tenant_specs, &spec, policy.as_ref(), c.duration_us);
        world.run_until(c.duration_us);

        let elapsed = c.duration_us;
        let read_stats = world.shared.fabric.read_path_stats();
        let tenants: Vec<TenantSummary> = c
            .tenants
            .iter()
            .enumerate()
            .map(|(i, t)| dc::summary_for_tenant(&world, i, &t.name))
            .collect();
        let fault = world.shared.fabric.fault_stats().map(|fs| {
            let fabric = &world.shared.fabric;
            let brokers = c.fabric.deployment.brokers as u32;
            let backlog_bytes: f64 =
                (0..brokers).map(|b| fabric.recovery_backlog_bytes(b)).sum();
            let all_in_sync =
                (0..brokers).all(|b| fabric.broker_alive(b) && fabric.broker_in_sync(b));
            let device_reads = fabric.device_read_bytes();
            let retried: u64 = tenants.iter().map(|t| t.retries).sum();
            let dropped: u64 = tenants.iter().map(|t| t.client_dropped).sum();
            let absorbed: u64 = tenants.iter().map(|t| t.absorbed_rejects).sum();
            FaultReport {
                records_offered: fs.records_offered,
                records_committed: fs.records_committed,
                records_in_flight: fabric.active_in_flight().0,
                missed_bytes: fs.missed_bytes,
                rereplicated_bytes: fs.rereplicated_bytes,
                records_lost: fs.records_lost,
                records_rejected: fs.records_rejected,
                min_isr_violations: fs.min_isr_violations,
                recovery_done_us: (all_in_sync && backlog_bytes == 0.0)
                    .then(|| fs.recovered_at_us.iter().map(|&(_, t)| t).max())
                    .flatten(),
                rereplication_read_share: if device_reads > 0.0 {
                    (fs.rereplicated_bytes / device_reads).min(1.0)
                } else {
                    0.0
                },
                backlog_bytes,
                records_retried: retried,
                records_client_dropped: dropped,
                records_rejected_final: fs.records_rejected.saturating_sub(absorbed),
                records_dedup_suppressed: fs.dedup_suppressed_records,
                unclean_lost_bytes: fs.unclean_lost_bytes,
                unclean_elections: fs.unclean_elections,
            }
        });
        MultiTenantReport {
            tenants,
            broker_storage_write_util: world.shared.fabric.max_storage_write_util(elapsed),
            broker_storage_read_util: world.shared.fabric.max_storage_read_util(elapsed),
            broker_net_rx_util: world.shared.fabric.max_nic_rx_util(elapsed),
            broker_cpu_util: world.shared.fabric.max_cpu_util(elapsed),
            cache_hit_ratio: read_stats.map_or(1.0, |s| s.hit_ratio()),
            device_read_share: read_stats.map_or(0.0, |s| s.device_read_share()),
            events: world.processed(),
            clamped_events: world.clamped(),
            fault,
            net_contended_transfers: world.shared.fabric.net_contended_transfers(),
            net_max_uplink_util: world.shared.fabric.net_max_uplink_util(elapsed),
            trace: world.shared.trace.as_ref().map(|t| t.to_chrome_json()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;
    use crate::util::units::SEC;

    /// Scaled-down tenants so the test world stays fast.
    fn small_mixed(fr_accel: f64, od_accel: f64) -> MixedConfig {
        let mut cfg = MixedConfig::paper_accel(fr_accel, od_accel);
        cfg.facerec.deployment = Deployment {
            producers: 75,
            consumers: 114,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 114,
        };
        cfg.objdet.deployment = Deployment {
            producers: 5,
            consumers: 480,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 480,
        };
        cfg.fabric = cfg.facerec.clone();
        cfg.with_duration(20 * SEC)
    }

    #[test]
    fn both_tenants_make_progress_on_a_shared_fabric() {
        let r = MixedSim::new(small_mixed(1.0, 1.0)).run();
        assert!(r.facerec.faces_completed > 0, "facerec starved");
        assert!(r.objdet.frames_detected > 0, "objdet starved");
        assert!(r.stable(), "small mixed load should be stable");
        assert!(r.events > 10_000, "events={}", r.events);
    }

    #[test]
    fn shared_broker_carries_both_tenants_load() {
        // The shared-broker write utilization must at least match what the
        // busier tenant would drive alone: tenants add load, never shed it.
        let mixed = MixedSim::new(small_mixed(1.0, 1.0)).run();
        let mut fr_alone = small_mixed(1.0, 1.0).facerec;
        fr_alone.duration_us = 20 * SEC;
        let solo = crate::pipeline::facerec::FaceRecSim::new(fr_alone).run();
        assert!(
            mixed.broker_storage_write_util > solo.storage_write_util,
            "mixed {} <= solo {}",
            mixed.broker_storage_write_util,
            solo.storage_write_util
        );
    }

    #[test]
    fn accelerating_one_tenant_taxes_the_other() {
        // Cross-tenant interference: pushing Object Detection harder must
        // raise the shared storage-write pressure Face Recognition sees.
        let calm = MixedSim::new(small_mixed(1.0, 1.0)).run();
        let noisy = MixedSim::new(small_mixed(1.0, 6.0)).run();
        assert!(
            noisy.broker_storage_write_util > 1.2 * calm.broker_storage_write_util,
            "calm {} noisy {}",
            calm.broker_storage_write_util,
            noisy.broker_storage_write_util
        );
    }

    #[test]
    fn deterministic_given_seeds() {
        let a = MixedSim::new(small_mixed(2.0, 2.0)).run();
        let b = MixedSim::new(small_mixed(2.0, 2.0)).run();
        assert_eq!(a.facerec.faces_completed, b.facerec.faces_completed);
        assert_eq!(a.objdet.frames_detected, b.objdet.frames_detected);
        assert_eq!(a.events, b.events);
    }

    /// A small 3-tenant registry: facerec + training ingest + rpc.
    fn small_registry() -> MultiTenantConfig {
        let mut fr = Config::default();
        fr.deployment = Deployment {
            producers: 40,
            consumers: 60,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 60,
        };
        fr.seed = 0xACCE1;
        let mut tr = Config::default();
        tr.deployment = Deployment {
            producers: 8,
            consumers: 8,
            brokers: 3,
            drives_per_broker: 1,
            replication: 3,
            partitions: 8,
        };
        tr.calibration.train.batch_bytes = 250_000.0;
        tr.calibration.train.fetch_min_bytes = 500_000;
        tr.seed = 0x7EA1;
        let mut rpc = Config::default();
        rpc.deployment = Deployment::rpc_service();
        rpc.seed = 0x59C;
        let fabric = fr.clone();
        MultiTenantConfig::new(fabric, 10 * SEC)
            .tenant(TenantDef::new("facerec", WorkloadKind::FaceRec, fr))
            .tenant(
                TenantDef::new("train", WorkloadKind::TrainIngest, tr)
                    .with_produce_quota(1_000_000.0),
            )
            .tenant(TenantDef::new("rpc", WorkloadKind::Rpc, rpc).with_weight(8.0))
    }

    #[test]
    fn registry_runs_n_tenants_without_qos() {
        let mut cfg = small_registry();
        cfg.qos_enabled = false;
        let r = MultiTenantSim::new(cfg).run();
        assert_eq!(r.tenants.len(), 3);
        for t in &r.tenants {
            assert!(t.completed > 0, "tenant {} starved", t.name);
        }
        assert!(r.tenant("rpc").is_some());
        assert!(r.events > 10_000);
    }

    #[test]
    fn registry_applies_quotas_and_weights_when_enabled() {
        let off = MultiTenantSim::new(small_registry()).run();
        let on = MultiTenantSim::new(small_registry().with_qos(true)).run();
        // The train tenant offers 8 × 2.5 MB/s = 20 MB/s but is capped to
        // 1 MB/s: its wire bytes must collapse relative to the open run.
        let train_off = off.tenant("train").unwrap();
        let train_on = on.tenant("train").unwrap();
        assert!(train_off.completed > 0 && train_on.completed > 0);
        assert!(
            (train_on.completed as f64) < 0.5 * train_off.completed as f64,
            "quota must throttle train completions: {} vs {}",
            train_on.completed,
            train_off.completed
        );
        // The protected tenants keep flowing under QoS.
        assert!(on.tenant("facerec").unwrap().completed > 0);
        assert!(on.tenant("rpc").unwrap().completed > 0);
    }

    #[test]
    fn storage_qos_alone_induces_only_storage_weights() {
        let cfg = small_registry().with_storage_qos(true);
        assert!(!cfg.qos_enabled);
        let policy = cfg.policy().expect("storage QoS induces a policy");
        assert!(policy.cpu_weights.is_none());
        assert_eq!(policy.storage_weights.as_ref().map(Vec::len), Some(3));
        assert!(policy.quotas.is_empty(), "quotas stay off without with_qos");
        // And the world runs with the write scheduler installed.
        let r = MultiTenantSim::new(cfg).run();
        for t in &r.tenants {
            assert!(t.completed > 0, "tenant {} starved", t.name);
        }
    }

    #[test]
    fn read_path_disabled_reports_seed_assumptions() {
        let r = MultiTenantSim::new(small_registry()).run();
        assert_eq!(r.cache_hit_ratio, 1.0, "no read path ⇒ the seed's free reads");
        assert_eq!(r.device_read_share, 0.0);
        assert_eq!(r.broker_storage_read_util, 0.0);
        for t in &r.tenants {
            assert_eq!(t.consumer_lag_bytes, 0);
        }
    }

    #[test]
    fn lagging_consumer_with_small_cache_reads_from_the_device() {
        // 50 MB of per-broker cache holds ~2 s of this registry's log
        // traffic; the train tenant's consumers start 5 s behind, so
        // most of their backlog has aged out and must come cold from
        // the NVMe read path.
        let mut cfg = small_registry().with_read_cache(50e6);
        cfg.tenants[1] = cfg.tenants[1].clone().with_consumer_lag(5 * SEC);
        let r = MultiTenantSim::new(cfg).run();
        assert!(
            r.cache_hit_ratio < 1.0,
            "lagging fetches must miss: hit ratio {}",
            r.cache_hit_ratio
        );
        assert!(r.device_read_share > 0.0);
        assert!(r.broker_storage_read_util > 0.0, "device reads must be visible");
        // The healthy tenants keep streaming from memory.
        assert!(r.tenant("facerec").unwrap().completed > 0);
        assert!(r.tenant("rpc").unwrap().completed > 0);
    }

    #[test]
    fn default_read_cache_comes_from_the_calibration() {
        let cfg = small_registry().with_default_read_cache();
        let expect = cfg
            .fabric
            .calibration
            .page_cache_capacity(cfg.fabric.node.memory);
        assert_eq!(cfg.read_cache_bytes, Some(expect));
        assert!(expect > 250e9, "384 GB node ⇒ ~288 GB page cache");
    }

    #[test]
    fn fault_report_present_iff_a_plan_is_installed() {
        let bare = MultiTenantSim::new(small_registry()).run();
        assert!(bare.fault.is_none(), "no plan ⇒ no fault accounting");

        // An empty plan arms the machinery without disturbing anyone:
        // accounting runs, but every damage counter stays zero and no
        // recovery stamp exists.
        let armed = MultiTenantSim::new(
            small_registry().with_faults(FaultPlan::new()),
        )
        .run();
        let f = armed.fault.as_ref().expect("plan ⇒ fault accounting");
        assert_eq!(f.records_lost, 0);
        assert_eq!(f.records_rejected, 0);
        assert_eq!(f.min_isr_violations, 0);
        assert_eq!(f.missed_bytes, 0.0);
        assert_eq!(f.rereplicated_bytes, 0.0);
        assert_eq!(f.backlog_bytes, 0.0);
        assert!(f.recovery_done_us.is_none(), "nothing was ever disturbed");
        assert!(armed.tenant("facerec").unwrap().completed > 0);
    }

    #[test]
    fn kill_and_restart_surface_in_the_fault_report() {
        let plan = FaultPlan::new()
            .kill_broker(3 * SEC, 1)
            .restart_broker(5 * SEC, 1);
        let r = MultiTenantSim::new(small_registry().with_faults(plan)).run();
        let f = r.fault.as_ref().expect("plan ⇒ fault accounting");
        assert!(f.missed_bytes > 0.0, "a dead follower must miss bytes");
        assert!(
            f.rereplicated_bytes > 0.0,
            "the restart must replay the backlog"
        );
        assert_eq!(f.min_isr_violations, 0);
        let done = f.recovery_done_us.expect("10 s horizon outlives recovery");
        assert!(done >= 5 * SEC, "recovery cannot finish before the restart");
        assert!(f.backlog_bytes == 0.0, "recovered ⇒ no residual backlog");
        assert!(f.rereplication_read_share > 0.0);
        for t in &r.tenants {
            assert!(t.completed > 0, "tenant {} starved by the failover", t.name);
        }
    }

    #[test]
    fn write_budget_fills_only_uncapped_tenants() {
        // The registry's train tenant carries an explicit 1 MB/s produce
        // cap; the budget must leave it alone and cover the other two
        // with replication-aware quotas at budget × brokers / tenants.
        // Setting a budget alone enables quota enforcement (it would be
        // a silent no-op otherwise) without installing CPU weights.
        let cfg = small_registry().with_broker_write_budget(300e6);
        assert!(cfg.qos_enabled, "a budget must turn quota enforcement on");
        let policy = cfg.policy().unwrap();
        assert!(policy.cpu_weights.is_none());
        assert!(policy.storage_weights.is_none());
        let expected = crate::broker::qos::write_budget_per_tenant_rate(300e6, 3, 3);
        assert_eq!(policy.quotas.len(), 3);
        assert_eq!(policy.quotas[0].produce_bytes_per_sec, Some(expected));
        assert!(policy.quotas[0].replication_aware);
        assert_eq!(policy.quotas[1].produce_bytes_per_sec, Some(1_000_000.0));
        assert!(!policy.quotas[1].replication_aware);
        assert_eq!(policy.quotas[2].produce_bytes_per_sec, Some(expected));
        assert!(policy.quotas[2].replication_aware);
    }
}
