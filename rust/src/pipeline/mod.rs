//! The paper's applications: *Face Recognition* (§3) and *Object
//! Detection* (§6), plus the models and the shared deployment layer they
//! are built from.
//!
//! Layering (bottom to top):
//!
//! * [`frame`] — frames, faces, identities (the data the pipeline moves).
//! * [`video`] — the synthetic video-stream source: 0–5 faces per frame,
//!   0.64 mean, Markov-modulated bursts (§3.3's measured distribution).
//! * [`stage`] — per-stage compute-cost models with AI/support splits
//!   (Fig 8) and acceleration protocols (§5.1 vs §5.2).
//! * [`scaling`] — the Fig-5/Fig-12 container core-scaling curves.
//! * [`fabric`] — the event-driven broker substrate (leader NIC → request
//!   CPU → NVMe write → replication → `acks=all` commit).
//! * [`dc`] — the deployment layer on the [`sim::world`](crate::sim::world)
//!   kernel: `ProducerClient`, `PartitionQueue`, `ConsumerPoller`, and the
//!   fabric wrapped as a component. Both applications (and any future
//!   workload) are expressed as *tenants* of this one machine.
//! * [`facerec`] — Face Recognition as a thin workload definition: frame
//!   source + stage costs + report assembly. Regenerates Figs 6, 7, 10,
//!   11, 15.
//! * [`objdet`] — Object Detection likewise (Figs 13, 14).
//! * [`train`] — the training-ingest tenant (large sequential writes)
//!   and [`rpc`] — the RPC-style low-latency tenant; both ~100-LoC
//!   workload definitions over the same deployment layer.
//! * [`mixed`] — multi-tenancy, the scenario the component kernel makes
//!   possible: an N-tenant registry ([`mixed::TenantDef`]) colocating any
//!   mix of workloads on one broker fabric and storage, with per-tenant
//!   latency breakdowns, cross-tenant interference, and optional broker
//!   QoS ([`crate::broker::qos`]).
//! * [`catchup`] — the lagging-consumer scenario on the measured read
//!   path ([`fabric::Fabric::enable_read_path`]): a tenant whose
//!   consumers start behind and must drain their backlog through cold
//!   device reads that contend with the replicated write stream.
//! * [`failover`] — failure and membership dynamics: a [`FaultPlan`]
//!   kills a broker mid-run (leadership re-elects, commits continue on
//!   the shrunken ISR, consumers pause for the rebalance) and restarts
//!   it (the victim replays its missed bytes as a maximally-lagged
//!   consumer through the measured read path, then rejoins the ISR).
//! * [`cascade`] — cascading failure on top of [`failover`]: a second,
//!   correlated kill lands while the first victim is still catching up,
//!   crossed with the client-resilience levers (retrying producers with
//!   idempotent commits, clean vs unclean leader election).
//!
//! [`FaultPlan`]: fabric::FaultPlan

pub mod cascade;
pub mod catchup;
pub mod dc;
pub mod fabric;
pub mod facerec;
pub mod failover;
pub mod frame;
pub mod mixed;
pub mod objdet;
pub mod rpc;
pub mod scaling;
pub mod stage;
pub mod train;
pub mod video;

pub use facerec::{FaceRecSim, SimReport};
pub use frame::{Face, Frame, Identity};
pub use mixed::{
    MixedConfig, MixedReport, MixedSim, MultiTenantConfig, MultiTenantReport, MultiTenantSim,
    TenantDef, TenantQosSpec,
};
pub use objdet::{ObjDetReport, ObjDetSim};
pub use rpc::RpcSim;
pub use stage::StageModel;
pub use train::TrainIngestSim;
pub use video::VideoSource;
