//! The paper's applications: *Face Recognition* (§3) and *Object
//! Detection* (§6), plus the models they are built from.
//!
//! * [`frame`] — frames, faces, identities (the data the pipeline moves).
//! * [`video`] — the synthetic video-stream source: 0–5 faces per frame,
//!   0.64 mean, Markov-modulated bursts (§3.3's measured distribution).
//! * [`stage`] — per-stage compute-cost models with AI/support splits
//!   (Fig 8) and acceleration protocols (§5.1 vs §5.2).
//! * [`scaling`] — the Fig-5/Fig-12 container core-scaling curves.
//! * [`facerec`] — the Face Recognition data-center simulation: producers →
//!   Kafka-style brokers (batching, replication, storage) → consumers, in
//!   virtual time. Regenerates Figs 6, 7, 10, 11, 15.
//! * [`objdet`] — the Object Detection simulation (Figs 13, 14).

pub mod fabric;
pub mod facerec;
pub mod frame;
pub mod objdet;
pub mod scaling;
pub mod stage;
pub mod video;

pub use facerec::{FaceRecSim, SimReport};
pub use frame::{Face, Frame, Identity};
pub use objdet::{ObjDetReport, ObjDetSim};
pub use stage::StageModel;
pub use video::VideoSource;
