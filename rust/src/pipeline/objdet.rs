//! The *Object Detection* data-center simulation (§6).
//!
//! A thin workload definition over the component layer
//! ([`pipeline::dc`](crate::pipeline::dc)), keeping only what is §6
//! specific:
//!
//! * two stages — ingestion (no AI) and R-CNN detection (all the AI);
//! * every frame is always sent through Kafka (no face-count variability);
//! * producers are rate-limited to 30 FPS ticks; under k× acceleration a
//!   producer sends k frames per tick ("the acceleration factor dictates
//!   the number of simultaneous video feeds each producer can process");
//! * a new AI-tax component appears: the **Delay** between when a frame
//!   set *should* start processing and when it actually does, caused by
//!   the producer send path overrunning the 33.3 ms tick (Fig 14).

use crate::config::Config;
use crate::pipeline::dc::{self, DcEvent, DcState, ProducerClient};
use crate::sim::queue::InstabilityVerdict;
use crate::sim::world::World;

/// Results of one Object Detection run.
#[derive(Clone, Debug)]
pub struct ObjDetReport {
    pub accel: f64,
    pub ingest_mean_us: f64,
    /// Fig 14's "Delay": tick-start lag from producer send backlog.
    pub delay_mean_us: f64,
    pub wait_mean_us: f64,
    pub detect_mean_us: f64,
    pub e2e_mean_us: f64,
    pub e2e_p99_us: u64,
    pub frames_sent: u64,
    pub frames_detected: u64,
    pub throughput_fps: f64,
    pub verdict: InstabilityVerdict,
    pub storage_write_util: f64,
    pub producer_send_util: f64,
    /// Past-time schedules clamped by the event queue — zero in every
    /// healthy run (`tests/golden_reports.rs` asserts it).
    pub clamped_events: u64,
}

impl ObjDetReport {
    pub fn total_mean_us(&self) -> f64 {
        self.ingest_mean_us + self.delay_mean_us + self.wait_mean_us + self.detect_mean_us
    }
}

/// Assemble an [`ObjDetReport`] for the Object Detection tenant `tenant`
/// of a finished world (shared with `pipeline::mixed`; the storage figure
/// is substrate-wide, which is the point of the mixed scenario).
pub fn report_for_tenant(
    world: &World<DcEvent, DcState>,
    cfg: &Config,
    tenant: usize,
) -> ObjDetReport {
    let s = &world.shared;
    let ts = &s.tenants[tenant];
    let m = &ts.metrics;
    let elapsed = s.horizon_us;
    let measured = elapsed.saturating_sub(ts.warmup_us);
    let producer_send_util = world
        .component::<ProducerClient>(ts.producer_comp)
        .expect("objdet tenant has a ProducerClient")
        .max_send_util(elapsed);

    ObjDetReport {
        accel: cfg.accel,
        ingest_mean_us: m.hist_ingest.mean(),
        delay_mean_us: m.hist_prep.mean(),
        wait_mean_us: m.hist_wait.mean(),
        detect_mean_us: m.hist_service.mean(),
        e2e_mean_us: m.hist_e2e.mean(),
        e2e_p99_us: m.hist_e2e.p99(),
        frames_sent: m.produced,
        frames_detected: m.completed,
        throughput_fps: if measured > 0 {
            m.completed_in_window as f64 * 1e6 / measured as f64
        } else {
            0.0
        },
        verdict: m.population.verdict(elapsed),
        storage_write_util: s.fabric.max_storage_write_util(elapsed),
        producer_send_util,
        clamped_events: world.clamped(),
    }
}

/// The Object Detection simulator: one tenant on a dedicated world.
pub struct ObjDetSim {
    cfg: Config,
}

impl ObjDetSim {
    pub fn new(cfg: Config) -> Self {
        cfg.deployment.validate().expect("invalid deployment");
        ObjDetSim { cfg }
    }

    pub fn run(&self) -> ObjDetReport {
        let cfg = &self.cfg;
        let spec = dc::FabricSpec::from_config(cfg);
        let mut world = dc::build(
            &[dc::TenantSpec { kind: dc::WorkloadKind::ObjDet, cfg }],
            &spec,
            cfg.duration_us,
        );
        world.run_until(cfg.duration_us);
        report_for_tenant(&world, cfg, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;

    fn config(accel: f64) -> Config {
        let mut cfg = Config::default();
        // §6.3 deployment: 21 producers, 2016 consumers, 3 brokers.
        cfg.deployment = Deployment::objdet_accel();
        cfg.duration_us = 30 * crate::util::units::SEC;
        cfg.accel = accel;
        cfg.seed = 0xD07;
        cfg
    }

    #[test]
    fn baseline_breakdown() {
        let r = ObjDetSim::new(config(1.0)).run();
        // Fig 13: ingestion 4.5 ms, detection 687 ms.
        assert!((r.ingest_mean_us - 4_500.0).abs() / 4_500.0 < 0.1, "{}", r.ingest_mean_us);
        assert!(
            (r.detect_mean_us - 687_000.0).abs() / 687_000.0 < 0.1,
            "{}",
            r.detect_mean_us
        );
        assert!(r.verdict.stable);
        // §6.3: "At 1x, the throughput is 630 FPS, as expected."
        assert!((r.throughput_fps - 630.0).abs() < 40.0, "{}", r.throughput_fps);
        assert!(r.delay_mean_us < 10_000.0, "delay={}", r.delay_mean_us);
    }

    #[test]
    fn throughput_scales_with_acceleration() {
        let r1 = ObjDetSim::new(config(1.0)).run();
        let r4 = ObjDetSim::new(config(4.0)).run();
        assert!(r4.throughput_fps > 3.0 * r1.throughput_fps);
        assert!(r4.verdict.stable);
    }

    #[test]
    fn delay_dominates_at_16x() {
        let r16 = ObjDetSim::new(config(16.0)).run();
        // Fig 14: at 16x the send path overruns the tick and the system
        // destabilizes; the producer send server saturates.
        assert!(
            r16.delay_mean_us > 30_000.0 || !r16.verdict.stable,
            "delay={} stable={}",
            r16.delay_mean_us,
            r16.verdict.stable
        );
        assert!(r16.producer_send_util > 0.9, "{}", r16.producer_send_util);
    }

    #[test]
    fn deterministic() {
        let a = ObjDetSim::new(config(2.0)).run();
        let b = ObjDetSim::new(config(2.0)).run();
        assert_eq!(a.frames_detected, b.frames_detected);
    }
}
