//! The *Object Detection* data-center simulation (§6).
//!
//! Structure mirrors Face Recognition with the §6 differences:
//!
//! * two stages — ingestion (no AI) and R-CNN detection (all the AI);
//! * every frame is always sent through Kafka (no face-count variability);
//! * producers are rate-limited to 30 FPS ticks; under k× acceleration a
//!   producer sends k frames per tick ("the acceleration factor dictates
//!   the number of simultaneous video feeds each producer can process");
//! * a new AI-tax component appears: the **Delay** between when a frame
//!   set *should* start processing and when it actually does, caused by
//!   the producer send path overrunning the 33.3 ms tick (Fig 14).

use std::collections::VecDeque;

use crate::config::Config;
use crate::metrics::bandwidth::{BandwidthMeter, Class};
use crate::pipeline::fabric::{Fabric, FabricEv, FabricOut, WIRE_US};
use crate::sim::engine::EventQueue;
use crate::sim::queue::{InstabilityVerdict, Population};
use crate::sim::resource::FifoServer;
use crate::util::rng::Rng;
use crate::util::stats::Histogram;

const RECORD_OVERHEAD: f64 = 64.0;

#[derive(Debug)]
enum Ev {
    /// Producer `p` hits its next 30 FPS tick.
    Tick(u32),
    /// A frame leaves producer `.0`'s send path toward partition `.1`.
    Dispatch(u32, u32, SimFrame),
    /// Broker-fabric hop.
    Fabric(FabricEv),
    /// Consumer `c` polls.
    Poll(u32),
}

#[derive(Clone, Copy, Debug)]
struct SimFrame {
    /// When the frame's tick was *scheduled* (delay epoch).
    scheduled_us: u64,
    /// When ingestion + send finished (broker-wait epoch).
    sent_done_us: u64,
    visible_us: u64,
    bytes: f64,
}

struct ProducerState {
    rng: Rng,
    /// Send-path server (serialization + Kafka client), in us of work.
    send: FifoServer,
    nic: FifoServer,
    ticks: u64,
}

struct PartitionState {
    leader: u32,
    queue: VecDeque<SimFrame>,
    consumer: u32,
}

struct ConsumerState {
    rng: Rng,
    nic_rx: FifoServer,
    busy_until: u64,
    poll_scheduled: bool,
}

/// Results of one Object Detection run.
#[derive(Clone, Debug)]
pub struct ObjDetReport {
    pub accel: f64,
    pub ingest_mean_us: f64,
    /// Fig 14's "Delay": tick-start lag from producer send backlog.
    pub delay_mean_us: f64,
    pub wait_mean_us: f64,
    pub detect_mean_us: f64,
    pub e2e_mean_us: f64,
    pub e2e_p99_us: u64,
    pub frames_sent: u64,
    pub frames_detected: u64,
    pub throughput_fps: f64,
    pub verdict: InstabilityVerdict,
    pub storage_write_util: f64,
    pub producer_send_util: f64,
}

impl ObjDetReport {
    pub fn total_mean_us(&self) -> f64 {
        self.ingest_mean_us + self.delay_mean_us + self.wait_mean_us + self.detect_mean_us
    }
}

/// The Object Detection simulator.
pub struct ObjDetSim {
    cfg: Config,
}

impl ObjDetSim {
    pub fn new(cfg: Config) -> Self {
        cfg.deployment.validate().expect("invalid deployment");
        ObjDetSim { cfg }
    }

    pub fn run(&self) -> ObjDetReport {
        let cfg = &self.cfg;
        let d = &cfg.deployment;
        let od = &cfg.calibration.objdet;
        let k = cfg.accel;
        let horizon = cfg.duration_us;
        let warmup = (horizon as f64 * cfg.warmup_frac) as u64;
        let mut master = Rng::new(cfg.seed ^ 0x0BDE7);

        // Effective per-frame send cost with Kafka's batching amortization
        // (§6.3: "producers and the brokers manage to intelligently batch").
        let send_us_per_frame =
            od.send_frame_us * (1.0 - od.batch_amort) + od.send_frame_us * od.batch_amort / k;
        // Emulation protocol: ingestion and detection compute divide by k.
        let ingest_us = od.ingest_us / k;
        let detect_mean_us = od.detect_us / k;
        let frames_per_tick = k.round().max(1.0) as usize;

        let mut producers: Vec<ProducerState> = (0..d.producers)
            .map(|_| ProducerState {
                rng: master.fork(),
                send: FifoServer::new(1e6, 0),
                nic: FifoServer::new(cfg.node.net_bw, 0),
                ticks: 0,
            })
            .collect();
        let write_cap = cfg.calibration.broker_write_capacity(
            cfg.node.nvme.write_bw,
            d.drives_per_broker,
            d.brokers,
        );
        let mut fabric = Fabric::new(
            d.brokers,
            d.drives_per_broker,
            d.replication,
            cfg.node.nvme,
            write_cap,
            cfg.node.net_bw,
            cfg.tuning.clone(),
        );
        let mut partitions: Vec<PartitionState> = (0..d.partitions)
            .map(|p| PartitionState {
                leader: (p % d.brokers) as u32,
                queue: VecDeque::new(),
                consumer: (p % d.consumers) as u32,
            })
            .collect();
        let mut consumers: Vec<ConsumerState> = (0..d.consumers)
            .map(|_| ConsumerState {
                rng: master.fork(),
                nic_rx: FifoServer::new(cfg.node.net_bw, 0),
                busy_until: 0,
                poll_scheduled: false,
            })
            .collect();
        let mut owned: Vec<Vec<u32>> = vec![Vec::new(); d.consumers];
        for (idx, part) in partitions.iter().enumerate() {
            owned[part.consumer as usize].push(idx as u32);
        }

        let mut meter = BandwidthMeter::new();
        meter.set_nodes(Class::Producer, d.producers);
        meter.set_nodes(Class::Consumer, d.consumers);
        meter.set_nodes(Class::Broker, d.brokers);

        let mut hist_ingest = Histogram::new();
        let mut hist_delay = Histogram::new();
        let mut hist_wait = Histogram::new();
        let mut hist_detect = Histogram::new();
        let mut hist_e2e = Histogram::new();
        let mut population = Population::new(250_000);
        let mut frames_sent = 0u64;
        let mut frames_detected = 0u64;
        let mut completed_in_window = 0u64;

        let mut in_flight: Vec<SimFrame> = Vec::new();
        let mut free_tokens: Vec<u64> = Vec::new();
        let mut fabric_out: Vec<FabricOut> = Vec::new();

        let mut q: EventQueue<Ev> = EventQueue::new();
        for p in 0..d.producers {
            let jitter = (p as u64 * od.tick_us) / d.producers as u64;
            q.at(jitter, Ev::Tick(p as u32));
        }

        while let Some((now, ev)) = q.pop() {
            if now > horizon {
                break;
            }
            match ev {
                Ev::Tick(p) => {
                    let pid = p as usize;
                    producers[pid].ticks += 1;
                    // Fig 14's Delay: the send server may still be draining
                    // the previous set; the new set starts late.
                    let delay = producers[pid].send.backlog_us(now);
                    let start = now + delay;
                    for _ in 0..frames_per_tick {
                        let ing = producers[pid]
                            .rng
                            .lognormal_mean_cv(ingest_us.max(1.0), 0.15)
                            .round()
                            .max(1.0) as u64;
                        let t_ing = start + ing;
                        let t_sent = producers[pid].send.submit(t_ing, send_us_per_frame);
                        let bytes = od.frame_bytes + RECORD_OVERHEAD;
                        frames_sent += 1;
                        if now >= warmup {
                            hist_ingest.record(ing.max(1));
                            hist_delay.record(delay.max(1));
                        }
                        population.enter(t_sent.min(horizon));
                        // Each frame goes to a different partition so the
                        // brokers can fully load-balance (§6.3). Random
                        // choice — a deterministic rotation across 21
                        // same-cadence producers convoys the consumers.
                        let part_idx =
                            producers[pid].rng.below(partitions.len() as u64) as u32;
                        let frame = SimFrame {
                            scheduled_us: now,
                            sent_done_us: t_sent,
                            visible_us: 0,
                            bytes,
                        };
                        q.at(t_sent + WIRE_US, Ev::Dispatch(p, part_idx, frame));
                    }
                    q.at(now + od.tick_us, Ev::Tick(p));
                }
                Ev::Dispatch(p, part_idx, frame) => {
                    let pid = p as usize;
                    let token = free_tokens.pop().unwrap_or_else(|| {
                        in_flight.push(frame);
                        (in_flight.len() - 1) as u64
                    });
                    in_flight[token as usize] = frame;
                    let leader = partitions[part_idx as usize].leader;
                    let nic = &mut producers[pid].nic;
                    fabric.send(now, part_idx, leader, frame.bytes, token, &mut meter, nic, &mut fabric_out);
                    drain_fabric(
                        &mut fabric_out,
                        &mut q,
                        &mut partitions,
                        &mut consumers,
                        &in_flight,
                        &mut free_tokens,
                    );
                }
                Ev::Fabric(fev) => {
                    fabric.handle(now, fev, &mut meter, &mut fabric_out);
                    drain_fabric(
                        &mut fabric_out,
                        &mut q,
                        &mut partitions,
                        &mut consumers,
                        &in_flight,
                        &mut free_tokens,
                    );
                }
                Ev::Poll(c) => {
                    let cid = c as usize;
                    consumers[cid].poll_scheduled = false;
                    if now < consumers[cid].busy_until {
                        consumers[cid].poll_scheduled = true;
                        let t = consumers[cid].busy_until;
                        q.at(t, Ev::Poll(c));
                        continue;
                    }
                    // fetch.min.bytes / fetch.max.wait withholding (§5.5),
                    // with Object Detection's throughput-oriented tuning.
                    let mut avail_bytes = 0.0;
                    let mut oldest_visible = u64::MAX;
                    for &pi in &owned[cid] {
                        for f in partitions[pi as usize].queue.iter() {
                            if f.visible_us <= now {
                                avail_bytes += f.bytes;
                                oldest_visible = oldest_visible.min(f.visible_us);
                            } else {
                                break;
                            }
                        }
                    }
                    if avail_bytes == 0.0 {
                        continue; // a commit Deliver will wake us
                    }
                    if (avail_bytes as usize) < od.fetch_min_bytes {
                        let deadline = oldest_visible + od.fetch_max_wait_us;
                        if now < deadline {
                            consumers[cid].poll_scheduled = true;
                            q.at(deadline, Ev::Poll(c));
                            continue;
                        }
                    }
                    let mut fetched: Vec<SimFrame> = Vec::new();
                    let mut deliver_at = now;
                    for &pi in &owned[cid] {
                        let part = &mut partitions[pi as usize];
                        let mut part_bytes = 0.0;
                        let mut any = false;
                        while let Some(f) = part.queue.front() {
                            if f.visible_us <= now {
                                part_bytes += f.bytes;
                                fetched.push(*f);
                                part.queue.pop_front();
                                any = true;
                            } else {
                                break;
                            }
                        }
                        if any {
                            let t = fabric.fetch(
                                now,
                                part.leader,
                                part_bytes,
                                &mut consumers[cid].nic_rx,
                                &mut meter,
                            );
                            deliver_at = deliver_at.max(t);
                        }
                    }
                    if fetched.is_empty() {
                        continue;
                    }
                    fetched.sort_by_key(|f| f.sent_done_us);
                    let mut busy = consumers[cid].busy_until.max(deliver_at);
                    for f in fetched {
                        let start = busy;
                        let wait = start.saturating_sub(f.sent_done_us);
                        let dur = consumers[cid]
                            .rng
                            .lognormal_mean_cv(detect_mean_us, od.detect_cv)
                            .round()
                            .max(1.0) as u64;
                        busy = start + dur;
                        population.exit(busy.min(horizon));
                        frames_detected += 1;
                        if busy >= warmup && busy <= horizon {
                            completed_in_window += 1;
                        }
                        if f.scheduled_us >= warmup && busy <= horizon {
                            hist_wait.record(wait.max(1));
                            hist_detect.record(dur);
                            hist_e2e.record((busy - f.scheduled_us).max(1));
                        }
                    }
                    consumers[cid].busy_until = busy;
                    consumers[cid].poll_scheduled = true;
                    q.at(busy, Ev::Poll(c));
                }
            }
        }

        let elapsed = horizon;
        let measured = elapsed.saturating_sub(warmup);
        let producer_send_util = producers
            .iter()
            .map(|p| p.send.utilization(elapsed))
            .fold(0.0, f64::max);

        ObjDetReport {
            accel: k,
            ingest_mean_us: hist_ingest.mean(),
            delay_mean_us: hist_delay.mean(),
            wait_mean_us: hist_wait.mean(),
            detect_mean_us: hist_detect.mean(),
            e2e_mean_us: hist_e2e.mean(),
            e2e_p99_us: hist_e2e.p99(),
            frames_sent,
            frames_detected,
            throughput_fps: if measured > 0 {
                completed_in_window as f64 * 1e6 / measured as f64
            } else {
                0.0
            },
            verdict: population.verdict(elapsed),
            storage_write_util: fabric.max_storage_write_util(elapsed),
            producer_send_util,
        }
    }
}

/// Route fabric outputs (same pattern as `facerec::drain_fabric`).
fn drain_fabric(
    out: &mut Vec<FabricOut>,
    q: &mut EventQueue<Ev>,
    partitions: &mut [PartitionState],
    consumers: &mut [ConsumerState],
    in_flight: &[SimFrame],
    free_tokens: &mut Vec<u64>,
) {
    for o in out.drain(..) {
        match o {
            FabricOut::Schedule(t, fev) => q.at(t.max(q.now()), Ev::Fabric(fev)),
            FabricOut::Committed { token, partition, at } => {
                let mut frame = in_flight[token as usize];
                free_tokens.push(token);
                frame.visible_us = at;
                let part = &mut partitions[partition as usize];
                part.queue.push_back(frame);
                let cs = &mut consumers[part.consumer as usize];
                if !cs.poll_scheduled {
                    cs.poll_scheduled = true;
                    q.at(at.max(q.now()).max(cs.busy_until), Ev::Poll(part.consumer));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;

    fn config(accel: f64) -> Config {
        let mut cfg = Config::default();
        // §6.3 deployment: 21 producers, 2016 consumers, 3 brokers.
        cfg.deployment = Deployment::objdet_accel();
        cfg.duration_us = 30 * crate::util::units::SEC;
        cfg.accel = accel;
        cfg.seed = 0xD07;
        cfg
    }

    #[test]
    fn baseline_breakdown() {
        let r = ObjDetSim::new(config(1.0)).run();
        // Fig 13: ingestion 4.5 ms, detection 687 ms.
        assert!((r.ingest_mean_us - 4_500.0).abs() / 4_500.0 < 0.1, "{}", r.ingest_mean_us);
        assert!(
            (r.detect_mean_us - 687_000.0).abs() / 687_000.0 < 0.1,
            "{}",
            r.detect_mean_us
        );
        assert!(r.verdict.stable);
        // §6.3: "At 1x, the throughput is 630 FPS, as expected."
        assert!((r.throughput_fps - 630.0).abs() < 40.0, "{}", r.throughput_fps);
        assert!(r.delay_mean_us < 10_000.0, "delay={}", r.delay_mean_us);
    }

    #[test]
    fn throughput_scales_with_acceleration() {
        let r1 = ObjDetSim::new(config(1.0)).run();
        let r4 = ObjDetSim::new(config(4.0)).run();
        assert!(r4.throughput_fps > 3.0 * r1.throughput_fps);
        assert!(r4.verdict.stable);
    }

    #[test]
    fn delay_dominates_at_16x() {
        let r16 = ObjDetSim::new(config(16.0)).run();
        // Fig 14: at 16x the send path overruns the tick and the system
        // destabilizes; the producer send server saturates.
        assert!(
            r16.delay_mean_us > 30_000.0 || !r16.verdict.stable,
            "delay={} stable={}",
            r16.delay_mean_us,
            r16.verdict.stable
        );
        assert!(r16.producer_send_util > 0.9, "{}", r16.producer_send_util);
    }

    #[test]
    fn deterministic() {
        let a = ObjDetSim::new(config(2.0)).run();
        let b = ObjDetSim::new(config(2.0)).run();
        assert_eq!(a.frames_detected, b.frames_detected);
    }
}
