//! The *RPC-style low-latency* tenant: small records, tight tail SLO.
//!
//! The second ROADMAP workload: request/response traffic riding the same
//! broker substrate — 2 kB records, `fetch.min.bytes` = 1 so consumers
//! fetch the instant a record commits, and sub-millisecond handlers. Its
//! byte footprint is negligible (a few MB/s against the brokers'
//! hundreds), which is precisely what makes it the canary for
//! cross-tenant interference: every microsecond of its end-to-end budget
//! is broker mechanism — NIC, request CPU, NVMe commit, replication —
//! so when a bulk tenant saturates the shared write path, the RPC p99
//! explodes long before any throughput metric moves. The
//! `experiments::qos` sweeps measure that against the
//! [`slo_p99_us`](crate::config::calibration::RpcCosts::slo_p99_us)
//! objective, with broker QoS classes/quotas as the mitigation.
//!
//! A thin workload definition over [`pipeline::dc`](crate::pipeline::dc):
//! costs from [`RpcCosts`](crate::config::calibration::RpcCosts),
//! mechanics from `ProducerKind::Tick` with one request per period.

use crate::config::Config;
use crate::pipeline::dc::{self, TenantSummary, WorkloadKind};

/// Results of one dedicated RPC-tenant run.
#[derive(Clone, Debug)]
pub struct RpcReport {
    pub summary: TenantSummary,
    /// The configured p99 objective, for SLO verdicts.
    pub slo_p99_us: u64,
}

impl RpcReport {
    /// Did the run meet its end-to-end p99 objective?
    pub fn slo_met(&self) -> bool {
        self.summary.e2e_p99_us <= self.slo_p99_us
    }
}

/// The simulator: one RPC tenant on a dedicated world.
pub struct RpcSim {
    cfg: Config,
}

impl RpcSim {
    pub fn new(cfg: Config) -> Self {
        cfg.deployment.validate().expect("invalid deployment");
        RpcSim { cfg }
    }

    pub fn run(&self) -> RpcReport {
        let cfg = &self.cfg;
        let spec = dc::FabricSpec::from_config(cfg);
        let mut world = dc::build(
            &[dc::TenantSpec { kind: WorkloadKind::Rpc, cfg }],
            &spec,
            cfg.duration_us,
        );
        world.run_until(cfg.duration_us);
        RpcReport {
            summary: dc::summary_for_tenant(&world, 0, "rpc"),
            slo_p99_us: cfg.calibration.rpc.slo_p99_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;

    fn config() -> Config {
        let mut cfg = Config::default();
        cfg.deployment = Deployment::rpc_service();
        cfg.duration_us = 10 * crate::util::units::SEC;
        cfg.seed = 0x59C;
        cfg
    }

    #[test]
    fn dedicated_rpc_meets_its_slo_with_room() {
        let r = RpcSim::new(config()).run();
        // 20 clients × 100 req/s × 10 s ≈ 20k requests.
        assert!(
            (15_000..=25_000).contains(&r.summary.produced),
            "requests={}",
            r.summary.produced
        );
        assert!(r.summary.stable);
        assert!(
            r.slo_met(),
            "dedicated run must meet the SLO: p99 {} vs {}",
            r.summary.e2e_p99_us,
            r.slo_p99_us
        );
        // On an idle fabric the p99 should not even be close — the SLO
        // headroom is what colocation later eats.
        assert!(
            r.summary.e2e_p99_us < r.slo_p99_us / 2,
            "p99 {} should be far below the {} SLO when alone",
            r.summary.e2e_p99_us,
            r.slo_p99_us
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = RpcSim::new(config()).run();
        let b = RpcSim::new(config()).run();
        assert_eq!(a.summary.completed, b.summary.completed);
        assert_eq!(a.summary.e2e_p99_us, b.summary.e2e_p99_us);
    }
}
