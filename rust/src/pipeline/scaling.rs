//! Container core-scaling curves (Figs 5 and 12).
//!
//! The model (`config::calibration::CoreScaling`) is
//! `latency(c) = serial + parallel/c + interference·(c−1)`; this module
//! wraps it with the sweep + reporting used by the Fig-5/Fig-12 benches and
//! the deployment advisor (how many cores to give each container, §3.5's
//! conclusion: one core per FR container, 14 per ObjDet container).

use crate::config::calibration::CoreScaling;

/// One row of a core-scaling sweep.
#[derive(Clone, Copy, Debug)]
pub struct ScalingPoint {
    pub cores: usize,
    /// Latency relative to 1 core.
    pub relative_latency: f64,
    pub speedup: f64,
}

/// Sweep a scaling curve over core counts.
pub fn sweep(curve: &CoreScaling, max_cores: usize) -> Vec<ScalingPoint> {
    (1..=max_cores)
        .map(|c| {
            let rel = curve.latency(c) / curve.latency(1);
            ScalingPoint {
                cores: c,
                relative_latency: rel,
                speedup: 1.0 / rel,
            }
        })
        .collect()
}

/// The core count minimizing latency.
pub fn best_cores(curve: &CoreScaling, max_cores: usize) -> usize {
    sweep(curve, max_cores)
        .iter()
        .min_by(|a, b| a.relative_latency.total_cmp(&b.relative_latency))
        .map(|p| p.cores)
        .unwrap_or(1)
}

/// Throughput-optimal allocation: cores_per_container × containers is
/// fixed at `total_cores`; pick the allocation maximizing aggregate
/// throughput = containers / latency(cores). For curves with poor scaling
/// this lands on 1 core per container — §3.5's choice for FR.
pub fn throughput_optimal_cores(curve: &CoreScaling, total_cores: usize) -> usize {
    (1..=total_cores)
        .max_by(|&a, &b| {
            let ta = (total_cores / a) as f64 / curve.latency(a);
            let tb = (total_cores / b) as f64 / curve.latency(b);
            ta.total_cmp(&tb)
        })
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_fr_containers_prefer_one_core_for_throughput() {
        // §3.5: "we optimize for throughput by assigning a single core to
        // each container".
        assert_eq!(
            throughput_optimal_cores(&CoreScaling::ingest_detect(), 56),
            1
        );
        assert_eq!(
            throughput_optimal_cores(&CoreScaling::identification(), 56),
            1
        );
    }

    #[test]
    fn fig12_objdet_prefers_many_cores() {
        // §6.1: near-linear scaling; latency keeps dropping to 14 cores, so
        // the latency-optimal allocation is large.
        let best = best_cores(&CoreScaling::objdet_detection(), 28);
        assert!(best >= 14, "best={best}");
    }

    #[test]
    fn fr_latency_upturn_detected() {
        let pts = sweep(&CoreScaling::identification(), 16);
        let best = best_cores(&CoreScaling::identification(), 16);
        // Latency at 16 cores is worse than at the optimum — Fig 5's
        // "computational latency actually increases".
        assert!(pts[15].relative_latency > pts[best - 1].relative_latency);
    }

    #[test]
    fn speedup_is_inverse_latency() {
        for p in sweep(&CoreScaling::objdet_detection(), 8) {
            assert!((p.speedup * p.relative_latency - 1.0).abs() < 1e-12);
        }
    }
}
