//! Per-stage compute-cost models with AI/support splits and acceleration.
//!
//! Each Face Recognition stage is decomposed per Fig 8: an **AI share**
//! (TensorFlow kernels in the paper; our PJRT inference in live mode), a
//! **Kafka-client share**, and a **support share** (resize, crop, IPC,
//! event logging, loop management). Acceleration is applied per the two
//! protocols:
//!
//! * [`AccelProtocol::AiShareOnly`] (§5.1, Fig 9) — only the AI share is
//!   divided by the factor; Amdahl's law applies.
//! * [`AccelProtocol::Emulation`] (§5.2, Figs 10–15) — everything except
//!   the Kafka-client share is divided ("only the most basic loop controls
//!   and Kafka code are left in their original state").

use crate::config::calibration::StageCosts;
use crate::config::AccelProtocol;
use crate::util::rng::Rng;

/// Samples per-stage compute durations (us) for the FR pipeline.
#[derive(Clone, Copy, Debug)]
pub struct StageModel {
    pub costs: StageCosts,
    pub accel: f64,
    pub protocol: AccelProtocol,
}

impl StageModel {
    pub fn new(costs: StageCosts, accel: f64, protocol: AccelProtocol) -> Self {
        assert!(accel >= 1.0, "acceleration factor must be >= 1");
        StageModel {
            costs,
            accel,
            protocol,
        }
    }

    /// Apply acceleration to a stage given its AI fraction.
    /// Deterministic core used by both sampling and the Fig-9 analytics.
    ///
    /// Under [`AccelProtocol::Emulation`] the whole *compute* time divides
    /// by the factor (§5.2 replaces all stage compute with scaled sleeps).
    /// The Kafka-client work that stays at native speed is **not** part of
    /// this number — it is modeled explicitly in the broker fabric
    /// (request CPU, linger, fetch timers), which is exactly why §5.5's
    /// waiting-time share grows under acceleration.
    pub fn accelerate(&self, base_us: f64, ai_frac: f64) -> f64 {
        match self.protocol {
            AccelProtocol::AiShareOnly => {
                base_us * (1.0 - ai_frac) + base_us * ai_frac / self.accel
            }
            AccelProtocol::Emulation => base_us / self.accel,
        }
    }

    /// Ingestion time for one frame.
    pub fn ingest(&self, rng: &mut Rng) -> u64 {
        let base = rng.lognormal_mean_cv(self.costs.ingest_us, self.costs.ingest_cv);
        self.accelerate(base, self.costs.ingest_ai_frac).round() as u64
    }

    /// Face-detection time for one frame containing `faces` faces.
    ///
    /// Bimodal: a log-normal body plus a rare slow path whose probability/
    /// multiplier are fitted to the paper's detection tail (p99 1.84 s vs
    /// 74.8 ms mean). The body mean is deflated so the *overall* mean stays
    /// at `detect_us`.
    pub fn detect(&self, rng: &mut Rng, faces: usize) -> u64 {
        let c = &self.costs;
        let inflation = 1.0 + c.detect_slow_prob * (c.detect_slow_mult - 1.0);
        let body_mean = c.detect_us / inflation;
        let mut base = rng.lognormal_mean_cv(body_mean, c.detect_cv);
        if rng.chance(c.detect_slow_prob) {
            base *= c.detect_slow_mult;
        }
        base += c.detect_per_face_us * faces as f64;
        self.accelerate(base, c.detect_ai_frac).round() as u64
    }

    /// Identification time for one face.
    pub fn identify(&self, rng: &mut Rng) -> u64 {
        let base = rng.lognormal_mean_cv(self.costs.identify_us, self.costs.identify_cv);
        self.accelerate(base, self.costs.identify_ai_frac).round() as u64
    }

    /// Mean producer cycle time (ingest + detect, serial in the one-core
    /// ingest/detect container) — the pipeline's frame period.
    pub fn producer_cycle_mean_us(&self, mean_faces: f64) -> f64 {
        let ingest = self.accelerate(self.costs.ingest_us, self.costs.ingest_ai_frac);
        let detect = self.accelerate(
            self.costs.detect_us + self.costs.detect_per_face_us * mean_faces,
            self.costs.detect_ai_frac,
        );
        ingest + detect
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(accel: f64, protocol: AccelProtocol) -> StageModel {
        StageModel::new(StageCosts::default(), accel, protocol)
    }

    #[test]
    fn no_accel_means_are_paper_values() {
        let m = model(1.0, AccelProtocol::Emulation);
        let mut rng = Rng::new(1);
        let n = 40_000;
        let ingest: f64 = (0..n).map(|_| m.ingest(&mut rng) as f64).sum::<f64>() / n as f64;
        let identify: f64 = (0..n).map(|_| m.identify(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((ingest - 18_800.0).abs() / 18_800.0 < 0.02, "{ingest}");
        assert!((identify - 131_500.0).abs() / 131_500.0 < 0.02, "{identify}");
    }

    #[test]
    fn detect_mean_includes_per_face_cost() {
        let m = model(1.0, AccelProtocol::Emulation);
        let mut rng = Rng::new(2);
        let n = 60_000;
        let d0: f64 = (0..n).map(|_| m.detect(&mut rng, 0) as f64).sum::<f64>() / n as f64;
        let d5: f64 = (0..n).map(|_| m.detect(&mut rng, 5) as f64).sum::<f64>() / n as f64;
        assert!(d5 > d0 + 4.0 * 9_000.0, "d0={d0} d5={d5}");
    }

    #[test]
    fn detect_tail_is_heavy() {
        // §4.2: detection p99 = 1.84 s vs 74.8 ms mean.
        let m = model(1.0, AccelProtocol::Emulation);
        let mut rng = Rng::new(3);
        let mut hist = crate::util::stats::Histogram::new();
        for _ in 0..100_000 {
            hist.record(m.detect(&mut rng, 1));
        }
        let p99 = hist.p99() as f64;
        assert!(
            (0.8e6..3.0e6).contains(&p99),
            "detect p99 {p99} outside the paper's band (~1.84 s)"
        );
    }

    #[test]
    fn amdahl_protocol_respects_asymptote() {
        // Detection is 42% AI: speedup can never exceed 1/(1-0.42) = 1.724.
        let base = 74_800.0;
        for accel in [2.0, 8.0, 32.0, 1e9] {
            let m = model(accel, AccelProtocol::AiShareOnly);
            let t = m.accelerate(base, 0.42);
            let speedup = base / t;
            assert!(speedup < 1.0 / (1.0 - 0.42) + 1e-6);
        }
        let m = model(1e9, AccelProtocol::AiShareOnly);
        let s = base / m.accelerate(base, 0.42);
        assert!((s - 1.724).abs() < 0.01, "asymptote {s}");
    }

    #[test]
    fn emulation_protocol_divides_everything() {
        // §5.2 emulation scales all stage compute; Kafka-client costs are
        // modeled in the broker fabric, not here.
        let m = model(8.0, AccelProtocol::Emulation);
        let t = m.accelerate(131_500.0, 0.88);
        assert!((t - 131_500.0 / 8.0).abs() < 1.0);
    }

    #[test]
    fn paper_fig9_quoted_points() {
        // "Detection ... achieving 1.59x overall speedup at 8x acceleration
        //  and 1.66x at 16x. Identification at 16x achieves 5.6x, at 32x
        //  6.6x."
        let detect = |k: f64| {
            let m = model(k, AccelProtocol::AiShareOnly);
            74_800.0 / m.accelerate(74_800.0, 0.42)
        };
        let ident = |k: f64| {
            let m = model(k, AccelProtocol::AiShareOnly);
            131_500.0 / m.accelerate(131_500.0, 0.88)
        };
        assert!((detect(8.0) - 1.59).abs() < 0.02, "{}", detect(8.0));
        assert!((detect(16.0) - 1.66).abs() < 0.02, "{}", detect(16.0));
        assert!((ident(16.0) - 5.6).abs() < 0.2, "{}", ident(16.0));
        assert!((ident(32.0) - 6.6).abs() < 0.2, "{}", ident(32.0));
    }

    #[test]
    fn producer_cycle_gives_about_ten_fps() {
        // §4.2: "the throughput per stream is around 10 frames per second".
        let m = model(1.0, AccelProtocol::Emulation);
        let cycle = m.producer_cycle_mean_us(0.64);
        let fps = 1e6 / cycle;
        assert!((9.0..12.0).contains(&fps), "fps={fps}");
    }

    #[test]
    fn acceleration_shrinks_emulated_times() {
        let m1 = model(1.0, AccelProtocol::Emulation);
        let m8 = model(8.0, AccelProtocol::Emulation);
        let mut r1 = Rng::new(9);
        let mut r8 = Rng::new(9);
        for _ in 0..100 {
            assert!(m8.identify(&mut r8) < m1.identify(&mut r1));
        }
    }
}
