//! The *training-ingest* tenant: large sequential writes.
//!
//! The ROADMAP's first new workload beyond the paper's two applications —
//! a data-loader fleet streaming ~1 MB shard batches through the broker
//! to training readers. Its AI-tax signature is the opposite of Face
//! Recognition's: almost no producer compute, enormous bytes-per-record,
//! throughput-tuned consumers (`fetch.min.bytes` of several batches). It
//! exists to stress the shared NVMe write path — colocate it with a
//! latency-sensitive tenant and the broker wait it manufactures lands on
//! *them* (the `experiments::qos` sweeps quantify exactly that, and the
//! per-tenant produce quota in [`crate::broker::qos`] is the mitigation).
//!
//! Like `facerec`/`objdet`, this file is a thin workload definition over
//! [`pipeline::dc`](crate::pipeline::dc): costs come from
//! [`TrainCosts`](crate::config::calibration::TrainCosts), the mechanics
//! from `ProducerKind::Tick`, and the report below is the generic
//! [`TenantSummary`] plus the tenant's storage pressure.

use crate::config::Config;
use crate::pipeline::dc::{self, TenantSummary, WorkloadKind};

/// Results of one dedicated training-ingest run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub summary: TenantSummary,
    /// Substrate-wide max storage-write utilization (spec-relative).
    pub storage_write_util: f64,
    /// Produce bytes this tenant put on the wire.
    pub net_tx_bytes: f64,
}

/// The simulator: one training-ingest tenant on a dedicated world.
pub struct TrainIngestSim {
    cfg: Config,
}

impl TrainIngestSim {
    pub fn new(cfg: Config) -> Self {
        cfg.deployment.validate().expect("invalid deployment");
        TrainIngestSim { cfg }
    }

    pub fn run(&self) -> TrainReport {
        let cfg = &self.cfg;
        let spec = dc::FabricSpec::from_config(cfg);
        let mut world = dc::build(
            &[dc::TenantSpec { kind: WorkloadKind::TrainIngest, cfg }],
            &spec,
            cfg.duration_us,
        );
        world.run_until(cfg.duration_us);
        TrainReport {
            summary: dc::summary_for_tenant(&world, 0, "train-ingest"),
            storage_write_util: world.shared.fabric.max_storage_write_util(cfg.duration_us),
            net_tx_bytes: world.shared.tenants[0].metrics.net_tx_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;

    fn config() -> Config {
        let mut cfg = Config::default();
        cfg.deployment = Deployment::train_ingest();
        cfg.duration_us = 10 * crate::util::units::SEC;
        cfg.seed = 0x7EA1;
        cfg
    }

    #[test]
    fn steady_ingest_is_stable_and_write_heavy() {
        let r = TrainIngestSim::new(config()).run();
        // 16 writers × 10 batches/s × 10 s ≈ 1600 batches.
        assert!(
            (1_200..=1_800).contains(&r.summary.produced),
            "batches={}",
            r.summary.produced
        );
        assert!(r.summary.completed > 0, "no batches consumed");
        assert!(r.summary.stable, "dedicated ingest must be stable");
        // ~160 MB/s of produce against the 1.1 GB/s spec drive ≈ 15%
        // spec-relative (×3 replication / 3 brokers cancels out).
        assert!(
            (0.05..0.40).contains(&r.storage_write_util),
            "write util={}",
            r.storage_write_util
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = TrainIngestSim::new(config()).run();
        let b = TrainIngestSim::new(config()).run();
        assert_eq!(a.summary.completed, b.summary.completed);
        assert_eq!(a.summary.e2e_p99_us, b.summary.e2e_p99_us);
    }
}
